"""Ch. 6 workflow on the tc subsystem: pick the fastest tensor-contraction
algorithm — batched-kernel candidates included — from deduplicated
cache-aware micro-benchmarks, at a fraction of one execution's cost.

    PYTHONPATH=src python examples/contraction_selection.py [--fast]
"""

import argparse
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import numpy as np                                          # noqa: E402

from repro.core.contractions import (ContractionSpec,       # noqa: E402
                                     measure_contraction)
from repro.tc import ContractionPredictor, is_batched_kernel  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--n", type=int, default=64)
    args = ap.parse_args()
    n = 32 if args.fast else args.n

    # a batched contraction: C[bik] = sum_j A[bij] * B[bjk] — the batched
    # gemm kernel turns the whole contraction into ONE kernel call
    spec = ContractionSpec.parse("bij,bjk->bik")
    sizes = dict(b=8, i=n, j=n, k=n)

    t0 = time.perf_counter()
    pred = ContractionPredictor(spec, sizes, repetitions=3)
    ranked = pred.rank()                      # numpy backend
    t_pred = time.perf_counter() - t0
    n_batched = sum(is_batched_kernel(a.kernel) for a in pred.algorithms)
    print(f"== {spec.einsum_expr()} with sizes {sizes}: "
          f"{len(pred.algorithms)} candidates "
          f"({n_batched} batched-kernel) ==")
    print(f"   deduplicated micro-benchmark suite: "
          f"{pred.n_benchmarks} benchmarks for {len(pred.algorithms)} "
          f"algorithms, {pred.suite.cost_seconds:.2f}s; "
          f"ranking took {t_pred:.2f}s total")
    for r in ranked[:5]:
        tag = " (batched kernel)" if is_batched_kernel(r.algorithm.kernel) \
            else ""
        print(f"   {r.name:34s} predicted {r.runtime.med * 1e3:9.2f} ms"
              f"{tag}")
    print("   ...")
    print(f"   {ranked[-1].name:34s} predicted "
          f"{ranked[-1].runtime.med * 1e3:9.2f} ms")

    # the jax backend reuses the same suite measurements + compiled batch
    t0 = time.perf_counter()
    ranked_jax = pred.rank(backend="jax")
    print(f"   backend='jax' re-rank: {(time.perf_counter() - t0) * 1e3:.1f}"
          f" ms, winner {'agrees' if ranked_jax[0].name == ranked[0].name else 'DISAGREES'}")

    print("== validate: execute best and median ==")
    rng = np.random.default_rng(0)
    A = rng.standard_normal([sizes[i] for i in spec.a_idx]).astype(np.float32)
    B = rng.standard_normal([sizes[i] for i in spec.b_idx]).astype(np.float32)
    best, median = ranked[0], ranked[len(ranked) // 2]
    t_best = measure_contraction(best.algorithm, A, B, sizes, 3).med
    t_median = measure_contraction(median.algorithm, A, B, sizes, 3).med
    print(f"   best:   {t_best * 1e3:9.2f} ms measured ({best.name})")
    print(f"   median: {t_median * 1e3:9.2f} ms measured "
          f"({t_median / t_best:.0f}x slower, {median.name})")
    frac = pred.prediction_cost_fraction(t_median)
    print(f"   suite cost = {frac:.2f}x one median-candidate execution "
          f"({'OK: fraction' if frac < 1 else 'NOT a fraction'})")
    assert t_best < t_median
    print("contraction_selection OK")


if __name__ == "__main__":
    main()
