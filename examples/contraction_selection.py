"""Ch. 6 workflow: pick the fastest BLAS-based tensor-contraction algorithm
via cache-aware micro-benchmarks — at a fraction of one execution's cost.

    PYTHONPATH=src python examples/contraction_selection.py [--fast]
"""

import argparse
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import numpy as np                                          # noqa: E402

from repro.core.contractions import (ContractionSpec,       # noqa: E402
                                     execute, generate_algorithms,
                                     measure_contraction,
                                     rank_contraction_algorithms)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--n", type=int, default=64)
    args = ap.parse_args()
    n = 32 if args.fast else args.n

    # the paper's running example: C[abc] = A[ai] * B[ibc] with skewed i=8
    spec = ContractionSpec.parse("abc=ai,ibc")
    sizes = dict(a=n, b=n, c=n, i=8)
    algs = generate_algorithms(spec)
    print(f"== {spec.einsum_expr()} with sizes {sizes}: "
          f"{len(algs)} candidate algorithms ==")

    t0 = time.perf_counter()
    ranked = rank_contraction_algorithms(spec, sizes, algorithms=algs,
                                         repetitions=3)
    t_pred = time.perf_counter() - t0
    print(f"   micro-benchmark prediction of all {len(algs)} algorithms: "
          f"{t_pred:.1f}s")
    for alg, t in ranked[:5]:
        print(f"   {alg.name:34s} predicted {t * 1e3:9.2f} ms")
    print("   ...")
    worst = ranked[-1]
    print(f"   {worst[0].name:34s} predicted {worst[1] * 1e3:9.2f} ms")

    print("== validate: execute best and worst ==")
    rng = np.random.default_rng(0)
    A = rng.standard_normal((n, 8)).astype(np.float32)
    B = rng.standard_normal((8, n, n)).astype(np.float32)
    t_best = measure_contraction(ranked[0][0], A, B, sizes, 3).med
    t_worst = measure_contraction(ranked[-1][0], A, B, sizes, 3).med
    print(f"   best:  {t_best * 1e3:9.2f} ms measured")
    print(f"   worst: {t_worst * 1e3:9.2f} ms measured "
          f"({t_worst / t_best:.0f}x slower)")
    assert t_best < t_worst
    print("contraction_selection OK")


if __name__ == "__main__":
    main()
