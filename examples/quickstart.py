"""Quickstart: end-to-end training with checkpoint/restart fault tolerance.

Trains a reduced deepseek-style decoder on the synthetic pipeline,
simulates a mid-run failure, and resumes from the latest checkpoint —
demonstrating the training loop, data determinism, atomic checkpointing
and the straggler watchdog in one run.

    PYTHONPATH=src python examples/quickstart.py [--fast] [--steps N]
"""

import argparse
import shutil
import sys
import tempfile
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.configs import get_config, reduced              # noqa: E402
from repro.train.data import DataConfig                    # noqa: E402
from repro.train.optimizer import AdamW                    # noqa: E402
from repro.train.train_loop import TrainConfig, train      # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--d-model", type=int, default=128)
    ap.add_argument("--layers", type=int, default=4)
    args = ap.parse_args()
    steps = 30 if args.fast else args.steps
    d_model = 64 if args.fast else args.d_model
    layers = 2 if args.fast else args.layers

    cfg = reduced(get_config("deepseek-7b"), n_layers=layers,
                  d_model=d_model, d_ff=4 * d_model, vocab=512)
    data_cfg = DataConfig(vocab=cfg.vocab, seq_len=64, global_batch=8)
    ckpt_dir = tempfile.mkdtemp(prefix="repro_quickstart_")
    try:
        interrupt_at = max(10, steps // 2)
        print(f"== phase 1: train to step {interrupt_at} "
              f"(simulated failure) ==")
        tc1 = TrainConfig(steps=interrupt_at, ckpt_dir=ckpt_dir,
                          ckpt_every=max(5, interrupt_at // 3))
        _, _, rep1 = train(cfg, data_cfg, tc1, opt=AdamW(lr=3e-4))
        print(f"   loss {rep1.losses[0]:.3f} -> {rep1.final_loss:.3f} "
              f"({len(rep1.losses)} steps)")

        print(f"== phase 2: restart, resume to step {steps} ==")
        tc2 = TrainConfig(steps=steps, ckpt_dir=ckpt_dir,
                          ckpt_every=max(5, steps // 4))
        _, _, rep2 = train(cfg, data_cfg, tc2, opt=AdamW(lr=3e-4))
        assert rep2.resumed_from is not None, "resume did not happen"
        print(f"   resumed from step {rep2.resumed_from}; "
              f"loss -> {rep2.final_loss:.3f} "
              f"(stragglers flagged: {len(rep2.straggler_steps)})")
        assert rep2.final_loss < rep1.losses[0], "loss did not improve"
        print("quickstart OK")
    finally:
        shutil.rmtree(ckpt_dir, ignore_errors=True)


if __name__ == "__main__":
    main()
