"""Batched serving demo: continuous-batching decode over multiple requests.

    PYTHONPATH=src python examples/serve_batched.py [--fast] [--arch <id>]
"""

import argparse
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import jax                                                   # noqa: E402
import jax.numpy as jnp                                      # noqa: E402
import numpy as np                                           # noqa: E402

from repro.configs import get_config, reduced                # noqa: E402
from repro.models import init_params                         # noqa: E402
from repro.serve.engine import Request, ServeEngine          # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--arch", default="deepseek-7b")
    ap.add_argument("--requests", type=int, default=6)
    args = ap.parse_args()
    n_req = 3 if args.fast else args.requests

    cfg = reduced(get_config(args.arch))
    if not cfg.causal:
        raise SystemExit(f"{cfg.name} is encoder-only; pick a decoder arch")
    print(f"== serving {cfg.name} (reduced) ==")
    params = init_params(cfg, jax.random.PRNGKey(0), dtype=jnp.float32)
    engine = ServeEngine(cfg, params, batch_slots=3, ctx_len=64)
    rng = np.random.default_rng(0)
    reqs = [Request(uid=i, prompt=rng.integers(0, cfg.vocab, 8,
                                               dtype=np.int32),
                    max_new_tokens=6) for i in range(n_req)]
    stats = engine.run(reqs)
    for r in reqs:
        print(f"   request {r.uid}: {len(r.out_tokens)} tokens "
              f"-> {r.out_tokens}")
    print(f"   {stats.tokens_out} tokens in {stats.decode_steps} decode "
          f"steps ({stats.tokens_per_s:.1f} tok/s incl. host overhead)")
    print("serve_batched OK")


if __name__ == "__main__":
    main()
