"""Batched serving demo: model-guided continuous batching vs FIFO.

    PYTHONPATH=src python examples/serve_batched.py [--fast] [--arch <id>]

One ``PredictorSession`` measures the engine's step-kernel cost model;
the same open-loop request trace is then served twice — under the FIFO
baseline (blocking prefill, first-come-first-served) and under the
``ModelGuidedScheduler``, whose per-tick admit/defer/interleave
decisions come from the measured ``StepCostModel``.
"""

import argparse
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import jax                                                   # noqa: E402
import jax.numpy as jnp                                      # noqa: E402
import numpy as np                                           # noqa: E402

from repro.configs import get_config, reduced                # noqa: E402
from repro.models import init_params                         # noqa: E402
from repro.serve import (FifoScheduler, ModelGuidedScheduler,  # noqa: E402
                         Request, ServeEngine)
from repro.tc import PredictorSession                        # noqa: E402

SLOTS = 3
CTX = 64


def make_requests(cfg, n, mean_gap_s=0.01):
    """One open-loop trace (regenerate with the same seed per policy)."""
    rng = np.random.default_rng(0)
    reqs, t = [], 0.0
    for uid in range(n):
        plen = int(rng.choice((4, 8, 24)))
        reqs.append(Request(uid=uid,
                            prompt=rng.integers(0, cfg.vocab, plen,
                                                dtype=np.int32),
                            max_new_tokens=6, arrival_s=t))
        t += float(rng.exponential(mean_gap_s))
    return reqs


def serve(cfg, params, scheduler, n):
    engine = ServeEngine(cfg, params, batch_slots=SLOTS, ctx_len=CTX)
    reqs = make_requests(cfg, n)
    t0 = time.perf_counter()
    stats = engine.run(reqs, scheduler=scheduler)
    wall = time.perf_counter() - t0
    goodput = sum(len(r.out_tokens) for r in reqs) / wall
    return reqs, stats, goodput


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--arch", default="deepseek-7b")
    ap.add_argument("--requests", type=int, default=8)
    args = ap.parse_args()
    n_req = 4 if args.fast else args.requests

    cfg = reduced(get_config(args.arch))
    if not cfg.causal:
        raise SystemExit(f"{cfg.name} is encoder-only; pick a decoder arch")
    print(f"== serving {cfg.name} (reduced) ==")
    params = init_params(cfg, jax.random.PRNGKey(0), dtype=jnp.float32)

    # one session owns the suite/cache; the step-cost model is measured
    # once and drives every scheduling decision
    session = PredictorSession()
    model = session.step_cost_model(cfg, slots=SLOTS)
    print(f"   step model: {model.n_benchmarks} micro-benchmarks in "
          f"{model.build_seconds:.2f}s")

    for name, sched in (("fifo", FifoScheduler()),
                        ("guided", ModelGuidedScheduler(model))):
        reqs, stats, goodput = serve(cfg, params, sched, n_req)
        assert all(r.done for r in reqs)
        print(f"   {name:6s}: {stats.tokens_out} tokens, "
              f"goodput={goodput:6.1f} tok/s "
              f"p50={stats.latency_ms(50):6.1f}ms "
              f"p99={stats.latency_ms(99):6.1f}ms "
              f"tick_overhead={stats.tick_overhead_ms:.3f}ms")
    print("serve_batched OK")


if __name__ == "__main__":
    main()
