"""Size-sweep autotuning: rank a contraction's candidate algorithms across
a whole grid of operand sizes from ONE shared micro-benchmark suite.

The per-signature models are size-parametric (t(n) = first + per_call * n
over the loop count), so a new size point re-predicts from existing
measurements wherever its (equation, shapes, cache-class) keys are
unchanged — here the swept batch size ``b`` is loop-only for every
loop-nest candidate, so extra points only measure the batched-kernel
signatures whose shapes contain ``b``.  The whole sweep's suite cost is
reported as a fraction of ONE executed contraction.

    PYTHONPATH=src python examples/size_sweep_autotune.py [--fast]
"""

import argparse
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import numpy as np                                          # noqa: E402

from repro.core.contractions import (ContractionSpec,       # noqa: E402
                                     execute)
from repro.tc import (PredictorSession,                     # noqa: E402
                      is_batched_kernel)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--n", type=int, default=48)
    args = ap.parse_args()
    n = 24 if args.fast else args.n

    # C[bik] = sum_j A[bij] * B[bjk], autotuned across three batch sizes
    spec = ContractionSpec.parse("bij,bjk->bik")
    grid = [dict(b=b, i=n, j=n, k=n) for b in (4, 8, 16)]

    # rank the first point, snapshot the suite, then extend to the whole
    # grid ON THE SAME SESSION: its suite re-predicts already-measured
    # signatures free, so the snapshot diff is what the extra points cost
    sess = PredictorSession(repetitions=3)
    t0 = time.perf_counter()
    sess.rank_contraction_sweep(spec, grid[:1])
    suite = sess.suite
    first_point = suite.counters()
    sweep = sess.rank_contraction_sweep(spec, grid)
    t_sweep = time.perf_counter() - t0
    extra = suite.n_benchmarks - int(first_point["n_benchmarks"])
    print(f"== {spec.einsum_expr()} across b={[g['b'] for g in grid]} "
          f"(i=j=k={n}) ==")
    print(f"   ONE suite for {len(grid)} size points: "
          f"{suite.n_benchmarks} distinct benchmarks for "
          f"{suite.requests} requests ({suite.cost_seconds:.2f}s measuring, "
          f"{t_sweep:.2f}s total)")
    for sizes, ranking in zip(grid, sweep.rankings):
        w = ranking[0]
        tag = " (batched kernel)" if is_batched_kernel(w.algorithm.kernel) \
            else ""
        print(f"   b={sizes['b']:3d}: winner {w.name:34s} "
              f"predicted {w.runtime.med * 1e3:9.3f} ms{tag}")
    print(f"   first point alone needs {int(first_point['n_benchmarks'])} "
          f"benchmarks -> the 2 extra size points added only {extra} "
          f"(loop-nest candidates re-predict for free)")

    # suite cost as a fraction of ONE mid-ranked execution at the largest
    # size — the paper's "merely a fraction of a contraction's runtime"
    largest = grid[-1]
    ranking = sweep.rankings[-1]
    mid = ranking[len(ranking) // 2]
    rng = np.random.default_rng(0)
    A = rng.standard_normal([largest[i] for i in spec.a_idx]
                            ).astype(np.float32)
    B = rng.standard_normal([largest[i] for i in spec.b_idx]
                            ).astype(np.float32)
    t0 = time.perf_counter()
    execute(mid.algorithm, A, B, largest)
    t_exec = time.perf_counter() - t0
    frac = sweep.cost_fraction(t_exec)
    print(f"   one execution of {mid.name} at b={largest['b']}: "
          f"{t_exec:.2f}s -> whole-sweep suite cost = {frac:.3f}x of it "
          f"({'OK: a fraction' if frac < 1 else 'NOT a fraction'})")
    assert len(sweep.rankings) == len(grid)
    assert extra < int(first_point["n_benchmarks"])
    print("size_sweep_autotune OK")


if __name__ == "__main__":
    main()
