"""Einsum-path selection on the tc chain layer: pick the fastest pairwise
contraction path of a multi-operand einsum — per-step algorithms included —
from one shared deduplicated micro-benchmark suite.

    PYTHONPATH=src python examples/einsum_path_selection.py [--fast]
"""

import argparse
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import numpy as np                                          # noqa: E402

from repro.tc import (ChainPredictor, ChainSpec,            # noqa: E402
                      execute_chain, execute_chain_reference,
                      validate_paths)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--n", type=int, default=48)
    args = ap.parse_args()
    n = 24 if args.fast else args.n

    # a 4-operand chain: the two early contractions (over i,j and k,l)
    # force real loop nests, and the middle index b ties the halves
    chain = ChainSpec.parse("aij,ijb,bkl,klc->ac")
    sizes = dict(a=8, b=8, c=8, i=n, j=n, k=n, l=n)

    # every enumerated path computes the same einsum — bit-equal on
    # integer-valued operands (any association order sums exact integers)
    validate_paths(chain, sizes)
    print(f"== {chain.einsum_expr()} with sizes {sizes}: all "
          f"{len(chain.paths())} paths validated bit-equal ==")

    t0 = time.perf_counter()
    pred = ChainPredictor(chain, sizes, repetitions=3,
                          memory_limit_bytes=64 * 2 ** 20)
    ranked = pred.rank_paths()                # numpy backend
    t_pred = time.perf_counter() - t0
    print(f"   {len(pred.paths)} memory-feasible paths, "
          f"{pred.n_benchmarks} shared micro-benchmarks "
          f"({pred.suite.requests} requested), ranking took {t_pred:.2f}s")
    for r in ranked:
        steps = " ; ".join(s.name for s in r.steps)
        print(f"   {r.name:16s} predicted {r.runtime.med * 1e3:9.2f} ms"
              f"  [{steps}]")

    # the jax backend reuses the same suite measurements + compiled batches
    t0 = time.perf_counter()
    ranked_jax = pred.rank_paths(backend="jax")
    agree = ranked_jax[0].name == ranked[0].name
    print(f"   backend='jax' re-rank: "
          f"{(time.perf_counter() - t0) * 1e3:.1f} ms, winner "
          f"{'agrees' if agree else 'DISAGREES'}")

    # the step-by-step per-algorithm oracle on the same measurements
    oracle = pred.rank_paths_oracle(fresh=False)
    print(f"   per-algorithm oracle top path: {oracle[0].name} "
          f"({'agrees' if oracle[0].name == ranked[0].name else 'DISAGREES'})")

    print("== validate: execute predicted-best and predicted-worst ==")
    rng = np.random.default_rng(0)
    ops = [rng.standard_normal([sizes[i] for i in idx]).astype(np.float32)
           for idx in chain.operands]
    best, worst = ranked[0], ranked[-1]
    t0 = time.perf_counter()
    out = execute_chain(chain, best, ops, sizes)
    t_best = time.perf_counter() - t0
    t0 = time.perf_counter()
    execute_chain(chain, worst, ops, sizes)
    t_worst = time.perf_counter() - t0
    # norm-relative error: float32 chains legitimately differ from the
    # one-shot einsum by association order, element-wise near cancellations
    ref = execute_chain_reference(chain, ops)
    err = np.linalg.norm(out - ref) / np.linalg.norm(ref)
    print(f"   best:  {t_best * 1e3:9.2f} ms measured ({best.name}), "
          f"rel err {err:.1e}")
    print(f"   worst: {t_worst * 1e3:9.2f} ms measured "
          f"({t_worst / t_best:.0f}x slower, {worst.name})")
    frac = pred.prediction_cost_fraction(t_worst)
    print(f"   suite cost = {frac:.2f}x one worst-path execution "
          f"(amortizes across chains; a fraction only at realistic sizes "
          f"— see the smoke benchmark)")
    assert err < 1e-3 and t_best < t_worst
    print("einsum_path_selection OK")


if __name__ == "__main__":
    main()
