"""The paper's core workflow: select the fastest blocked algorithm and a
near-optimal block size WITHOUT executing any candidate (§4.5/§4.6).

1. generate measurement-based performance models for the kernels (once per
   platform — cached under experiments/models/),
2. rank the 3 Cholesky variants and the 8 triangular-inversion variants by
   predicted runtime,
3. pick the block size by predicted argmin,
4. validate the selections against real timed executions.

    PYTHONPATH=src python examples/autotune_blocked.py [--fast]
"""

import argparse
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))
sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

import numpy as np                                          # noqa: E402

from benchmarks.common import (build_model_set, lower_nonsing,  # noqa: E402
                               median_time, spd)
from repro.core import optimize_block_size, rank_algorithms  # noqa: E402
from repro.dla import ExecEngine, blocked                   # noqa: E402
from repro.dla.tracers import CHOLESKY_TRACERS, TRTRI_TRACERS  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--n", type=int, default=224)
    args = ap.parse_args()
    n = 128 if args.fast else args.n
    b_candidates = (16, 32, 48, 64, 96)

    print("== generating / loading kernel performance models ==")
    ms, gen_s = build_model_set()
    print(f"   model set ready ({gen_s:.0f}s generation)")

    print(f"== Cholesky: rank 3 variants at n={n} (no execution) ==")
    t0 = time.perf_counter()
    ranked = rank_algorithms(CHOLESKY_TRACERS, ms, n, 48)
    t_rank = time.perf_counter() - t0
    for r in ranked:
        print(f"   {r.name}: predicted {r.runtime.med * 1e3:7.2f} ms")
    best = ranked[0].name
    print(f"   predicted winner: {best}  ({t_rank * 1e3:.0f} ms to rank)")

    print("== validate against execution ==")
    A0 = spd(n)
    meas = {}
    for v in (1, 2, 3):
        def run(v=v):
            eng = ExecEngine()
            blocked.potrf(eng, eng.bind("A", A0), n, 48, variant=v)
        meas[f"potrf{v}"] = median_time(run, 5)
        print(f"   potrf{v}: measured {meas[f'potrf{v}'] * 1e3:7.2f} ms")
    meas_best = min(meas, key=meas.get)
    print(f"   measured winner: {meas_best} "
          f"({'MATCH' if meas_best == best else 'within-noise mismatch'})")

    print(f"== block-size optimization for {best} ==")
    variant = int(best[-1])
    tracer = CHOLESKY_TRACERS[best]
    b_pred, profile = optimize_block_size(tracer, ms, n, b_candidates)
    print("   predicted profile: " +
          " ".join(f"b={b}:{t * 1e3:.2f}ms" for b, t in profile.items()))
    meas_profile = {}
    for b in b_candidates:
        def run(b=b):
            eng = ExecEngine()
            blocked.potrf(eng, eng.bind("A", A0), n, b, variant=variant)
        meas_profile[b] = median_time(run, 5)
    b_opt = min(meas_profile, key=meas_profile.get)
    yld = meas_profile[b_opt] / meas_profile[b_pred]
    print(f"   b_pred={b_pred} b_opt={b_opt} performance yield={yld:.1%}")

    print("== triangular inversion: rank all 8 variants ==")
    ranked = rank_algorithms(TRTRI_TRACERS, ms, n, 48)
    for r in ranked[:3]:
        print(f"   {r.name}: predicted {r.runtime.med * 1e3:7.2f} ms")
    print(f"   ... ({len(ranked)} variants ranked)")
    print("autotune_blocked OK")


if __name__ == "__main__":
    main()
