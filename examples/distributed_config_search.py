"""Beyond-paper: rank sharding configurations by prediction, not execution.

The paper selects the fastest blocked algorithm by predicting each
candidate from per-kernel models (§4.5).  At cluster scale the candidates
are *sharding strategies* of one (arch × shape) cell and the "model" is
the three-term roofline of each candidate's compiled dry-run: compiling
takes seconds, executing each candidate on 256 chips is what this avoids.

    PYTHONPATH=src python examples/distributed_config_search.py \
        [--arch deepseek-7b] [--shape train_4k]

NOTE: needs the 512-device dry-run environment; this script sets the
XLA host-device flag itself and must run as a fresh process.
"""

import os

os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512")

import argparse     # noqa: E402
import sys          # noqa: E402
from pathlib import Path  # noqa: E402

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.launch.dryrun import lower_cell                  # noqa: E402
from repro.perf.predictor import ConfigCandidate, rank_configs  # noqa: E402
from repro.perf.roofline import RooflineTerms               # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="deepseek-7b")
    ap.add_argument("--shape", default="train_4k")
    args = ap.parse_args()

    def build(strategy, remat):
        def fn():
            _, meta = lower_cell(args.arch, args.shape, strategy=strategy,
                                 remat_policy=remat, verbose=False)
            return RooflineTerms(
                flops=meta["flops"], bytes_accessed=meta["bytes"],
                coll_bytes=meta["coll_bytes"],
                n_devices=meta["n_devices"],
                model_flops=meta["model_flops"])
        return fn

    candidates = [
        ConfigCandidate("tp (Megatron TP+FSDP)", build("tp", None)),
        ConfigCandidate("dp (pure DP + ZeRO-3)", build("dp", None)),
        ConfigCandidate("dp + dots-remat", build("dp", "dots"),
                        note="memory > HBM on v5e; see EXPERIMENTS §Perf"),
    ]
    print(f"== ranking sharding configs for {args.arch} x {args.shape} "
          f"(16x16 mesh) by compiled-dry-run prediction ==")
    ranked = rank_configs(candidates, extract=lambda x: x)
    for r in ranked:
        t = r.terms
        print(f"   {r.name:24s} predicted step {t.bound_s * 1e3:8.0f} ms "
              f"(compute {t.compute_s * 1e3:6.0f} / memory "
              f"{t.memory_s * 1e3:6.1f} / collective "
              f"{t.collective_s * 1e3:6.0f}) dominant={t.dominant}"
              + (f"  [{r.note}]" if r.note else ""))
    print(f"selected: {ranked[0].name}")
    print("distributed_config_search OK")


if __name__ == "__main__":
    main()
