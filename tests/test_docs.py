"""Tier-1 docs checks: every exported name is documented, and the docs
site's internal links resolve.

Snippet *execution* (the slower half of the docs lint) runs in the CI
fast lane as a separate step: ``python tools/check_docs.py``.
"""

import importlib.util
import inspect
import re
import sys
from pathlib import Path

import pytest

import repro.core
import repro.tc

ROOT = Path(__file__).resolve().parents[1]


def _load_check_docs():
    spec = importlib.util.spec_from_file_location(
        "check_docs", ROOT / "tools" / "check_docs.py")
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def _documented_constants(package) -> set:
    """Names assigned in any of the package's modules directly under a
    ``#:`` doc comment (the Sphinx convention this codebase uses)."""
    out = set()
    for name, mod in sys.modules.items():
        if not name.startswith(package.__name__):
            continue
        try:
            src = inspect.getsource(mod).splitlines()
        except (OSError, TypeError):
            continue
        for i, line in enumerate(src):
            # plain or annotated assignments, tuple targets included:
            # "NAME = ...", "NAME: int = ...", "WARM, COLD = ..."
            m = re.match(
                r"([A-Za-z_]\w*(?:\s*,\s*[A-Za-z_]\w*)*)\s*(?::[^=]+)?=",
                line)
            if not m:
                continue
            j = i - 1
            while j >= 0 and src[j].lstrip().startswith("#"):
                if src[j].lstrip().startswith("#:"):
                    out.update(p.strip() for p in m.group(1).split(","))
                    break
                j -= 1
    return out


@pytest.mark.parametrize("mod", [repro.core, repro.tc],
                         ids=["core", "tc"])
def test_all_exports_have_docstrings(mod):
    """Every ``__all__`` member: functions/classes carry a real docstring
    (a dataclass's auto-generated signature doc does not count), and
    constants carry a ``#:`` doc comment at their definition."""
    # resolve every export FIRST: lazily re-exported names (repro.tc's
    # __getattr__ over the device module) only import their defining
    # module on attribute access, and the #: scan must see that module
    exports = {name: getattr(mod, name) for name in mod.__all__}
    constants = _documented_constants(mod)
    missing = []
    for name, obj in exports.items():
        if inspect.isclass(obj) or inspect.isroutine(obj):
            doc = inspect.getdoc(obj) or ""
            if not doc.strip() or doc.startswith(f"{name}("):
                missing.append(name)
        elif name not in constants:
            missing.append(name)
    assert not missing, (f"{mod.__name__}: undocumented exports: "
                         f"{sorted(missing)}")


def test_docs_internal_links_resolve():
    check = _load_check_docs()
    problems = []
    for path in check.doc_files([]):
        problems += check.check_links(path)
    assert not problems, "\n".join(problems)


def test_docs_have_runnable_snippets():
    # the walkthrough docs must keep executable examples (the CI lint
    # step executes them; here we only pin that they exist)
    check = _load_check_docs()
    for name in ("prediction-pipeline.md", "contraction-prediction.md"):
        assert check.snippets_of(ROOT / "docs" / name), name
