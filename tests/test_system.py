"""End-to-end system tests: the paper's pipeline from model generation to
algorithm selection, exercised on real timed JAX kernels (small sizes)."""

import numpy as np
import pytest

# real model generation = measured kernel timings: nightly lane only
pytestmark = pytest.mark.slow

from repro.core import (GeneratorConfig, KernelBenchmark, ModelSet,
                        generate_model, predict_runtime, rank_algorithms)
from repro.core.grids import Domain
from repro.dla.kernels import KERNELS
from repro.dla.tracers import CHOLESKY_TRACERS


@pytest.fixture(scope="module")
def cholesky_models():
    """Generate real measured models for the Cholesky kernel set (small)."""
    cfg = GeneratorConfig(overfit=0, oversampling=2, repetitions=3,
                          error_bound=0.10, min_width=64, max_pieces=8)
    ms = ModelSet()
    specs = [
        ("potf2", (("L",),), Domain((16,), (160,))),
        ("trsm", (("R", "L", "T", "N", 1), ("L", "L", "N", "N", -1),
                  ("R", "L", "N", "N", -1)),
         Domain((16, 16), (160, 160))),
        ("syrk", (("L", "N", -1, 1),), Domain((16, 16), (160, 160))),
        ("gemm", (("N", "T", -1, 1),), Domain((16, 16, 16),
                                              (160, 160, 160))),
        ("trmm", (("R", "L", "N", "N", 1), ("L", "L", "N", "N", 1)),
         Domain((16, 16), (160, 160))),
        ("trti2", (("L", "N"),), Domain((16,), (160,))),
    ]
    for kname, cases, dom in specs:
        kd = KERNELS[kname]
        bench = KernelBenchmark(
            name=kname, cases=cases, domain=dom,
            cost_exponents=kd.cost_exponents,
            make_call=lambda case, sizes, _kd=kd: _kd.make_call(case, sizes),
        )
        model, _ = generate_model(bench, cfg)
        ms.add(model)
    return ms


def test_end_to_end_prediction_sane(cholesky_models):
    """Predict blocked Cholesky runtime; compare order of magnitude against
    a real execution (detailed accuracy lives in the benchmarks)."""
    import time

    from repro.dla import ExecEngine, blocked

    ms = cholesky_models
    n, b = 128, 32
    calls = CHOLESKY_TRACERS["potrf3"](n, b)
    pred = predict_runtime(calls, ms)

    rng = np.random.default_rng(0)
    a = rng.standard_normal((n, n))
    A0 = a @ a.T + n * np.eye(n)
    eng = ExecEngine()
    blocked.potrf(eng, eng.bind("A", A0), n, b, variant=3)  # warm-up
    times = []
    for _ in range(5):
        eng = ExecEngine()
        A = eng.bind("A", A0)
        t0 = time.perf_counter()
        blocked.potrf(eng, A, n, b, variant=3)
        times.append(time.perf_counter() - t0)
    measured = sorted(times)[len(times) // 2]
    assert pred.med > 0
    # engine adds python/slicing overhead over pure kernels: generous band
    assert pred.med < measured * 5 and measured < pred.med * 50


def test_variant_ranking_is_produced(cholesky_models):
    ranked = rank_algorithms(CHOLESKY_TRACERS, cholesky_models, 128, 32)
    assert len(ranked) == 3
    assert ranked[0].runtime.med <= ranked[-1].runtime.med


def test_trtri_ranking_with_same_models(cholesky_models):
    from repro.dla.tracers import TRTRI_TRACERS

    tracers = {k: TRTRI_TRACERS[k] for k in ("trtri1", "trtri5")}
    ranked = rank_algorithms(tracers, cholesky_models, 128, 32)
    assert len(ranked) == 2
