"""Make the repo root importable so tests can reach ``benchmarks.*``.

``pip install -e .`` only installs the ``src/`` packages; the benchmarks
package (synthetic model sets, smoke utilities) lives at the repo root and
is only on ``sys.path`` when pytest is launched as ``python -m pytest``
from the checkout.  Insert the root explicitly so a bare ``pytest`` run
collects cleanly too.
"""

import sys
from pathlib import Path

_ROOT = str(Path(__file__).resolve().parents[1])
if _ROOT not in sys.path:
    sys.path.insert(0, _ROOT)
