"""Tests for the fused one-dispatch prediction path.

``PredictionEngine.predict_compiled`` evaluates a whole compiled batch —
piece lookup, design matrices, per-group matmuls AND the config-wise
scatter-add — as one fused program (a single jitted XLA dispatch on
``backend="jax"``, one precomputed-scatter accumulate on ``"numpy"``).
Three paths must agree: the fused path, the per-group reference path
(:meth:`~repro.core.predict.PredictionEngine.predict_compiled_grouped`)
and the scalar per-call oracle (:func:`~repro.core.predict.
predict_runtime`) — to ~1e-8 across the full tracer catalog.  Padding
is load-bearing: padded rows scatter into a dropped segment, so results
must be BIT-stable under any re-padding.
"""

import numpy as np
import pytest

from benchmarks.common import catalog_synthetic_model_set
from repro.core import (CompiledCalls, KernelCall, PredictionEngine,
                        compile_calls, predict_runtime)
from repro.core.sampler import STATS
from repro.dla.tracers import ALL_TRACERS

REL = 1e-8


@pytest.fixture(scope="module")
def models():
    return catalog_synthetic_model_set()


@pytest.fixture(scope="module")
def catalog_seqs():
    # the full tracer catalog at one (n, b): every kernel, degenerate
    # tail calls included — deliberately UNEVEN group sizes, so the row
    # padding is exercised on every group
    return [tracer(264, 56) for tracer in ALL_TRACERS.values()]


def _scalar_reference(seqs, models):
    return np.array([[getattr(predict_runtime(seq, models), s)
                      for s in STATS] for seq in seqs])


@pytest.mark.parametrize("backend", ["numpy", "jax"])
def test_fused_matches_grouped_and_scalar_on_full_catalog(
        models, catalog_seqs, backend):
    eng = PredictionEngine(models, backend=backend)
    compiled = compile_calls(catalog_seqs)
    fused = eng.predict_compiled(compiled)
    grouped = eng.predict_compiled_grouped(compiled)
    ref = _scalar_reference(catalog_seqs, models)
    np.testing.assert_allclose(fused, ref, rtol=REL, atol=0)
    np.testing.assert_allclose(grouped, ref, rtol=REL, atol=0)
    np.testing.assert_allclose(fused, grouped, rtol=REL, atol=0)


@pytest.mark.parametrize("backend", ["numpy", "jax"])
@pytest.mark.parametrize("pad", [1, 7, 64])
def test_padding_rows_never_leak(models, catalog_seqs, backend, pad):
    """Re-padding the row axis must not change a single bit: padding rows
    evaluate to exact zeros and scatter into the dropped segment."""
    eng = PredictionEngine(models, backend=backend)
    base = eng.predict_compiled(compile_calls(catalog_seqs))
    rows = max(g.sizes.shape[0]
               for g in compile_calls(catalog_seqs).groups)
    repadded = compile_calls(catalog_seqs, pad_rows_to=rows + pad)
    np.testing.assert_array_equal(eng.predict_compiled(repadded), base)


def test_fused_batch_structure(catalog_seqs):
    compiled = compile_calls(catalog_seqs, pad_rows_to=None)
    fused = compiled.fused
    g = len(compiled.groups)
    assert fused.sizes.shape[0] == g
    assert fused.sizes.shape[1] == max(fused.rows)
    assert fused.sizes.shape[2] == max(fused.dims)
    assert fused.rows == tuple(grp.sizes.shape[0]
                               for grp in compiled.groups)
    assert fused.dims == tuple(grp.sizes.shape[1]
                               for grp in compiled.groups)
    # flat_config concatenates the per-group config indices in order
    np.testing.assert_array_equal(
        fused.flat_config,
        np.concatenate([grp.config for grp in compiled.groups]))
    assert fused.flat_config.shape == (compiled.n_calls,)
    # segments: real rows carry their config, padding rows the dropped
    # segment n_configs; padded dims of live rows are a benign 1.0
    seg = fused.segments.reshape(g, -1)
    for gi, grp in enumerate(compiled.groups):
        k, d = grp.sizes.shape
        np.testing.assert_array_equal(seg[gi, :k], grp.config)
        assert np.all(seg[gi, k:] == compiled.n_configs)
        assert np.all(fused.sizes[gi, k:] == 0.0)
        assert np.all(fused.sizes[gi, :k, d:] == 1.0)


def test_hand_built_compiled_derives_fused_lazily(models, catalog_seqs):
    eager = compile_calls(catalog_seqs)
    lazy = CompiledCalls(n_configs=eager.n_configs, groups=eager.groups)
    assert lazy.fused is None
    got = PredictionEngine(models).predict_compiled(lazy)
    assert lazy.fused is not None          # derived + memoized on first use
    np.testing.assert_array_equal(
        got, PredictionEngine(models).predict_compiled(eager))


@pytest.mark.parametrize("backend", ["numpy", "jax"])
def test_empty_and_all_degenerate_batches(models, backend):
    eng = PredictionEngine(models, backend=backend)
    # configs with no calls at all predict all-zero statistics
    out = eng.predict_batch([[], []])
    assert out.shape == (2, len(STATS))
    assert np.all(out == 0.0)
    # an unmodeled case whose every call is degenerate needs no model;
    # a live call to it still raises (scalar-path parity)
    degen = [KernelCall("gemm", ("MISSING",), (0, 64, 64))]
    assert np.all(eng.predict_batch([degen]) == 0.0)
    with pytest.raises(KeyError):
        eng.predict_batch(
            [degen + [KernelCall("gemm", ("MISSING",), (64, 64, 64))]])


def test_fused_model_tensors_track_model_mutation(models, catalog_seqs):
    """A mutated case model must not serve stale fused tensors."""
    eng = PredictionEngine(models)
    compiled = compile_calls(catalog_seqs)
    first = eng._fused_model_tensors(compiled)
    assert eng._fused_model_tensors(compiled) is first      # memoized
    model = models["gemm"]
    case = next(iter(model.cases))
    cm = model.cases[case]
    piece = cm.pieces[0]
    cm.pieces[0] = piece                    # same object: still cached
    assert eng._fused_model_tensors(compiled) is first
    import copy
    cm.pieces[0] = copy.deepcopy(piece)     # replaced: tensors rebuilt
    try:
        assert eng._fused_model_tensors(compiled) is not first
    finally:
        cm.pieces[0] = piece


def test_repadding_property_many_shapes(models):
    """Vary group sizes and paddings; fused results must stay bit-stable
    and padding must never leak into any config's totals."""
    hypothesis = pytest.importorskip("hypothesis")
    from hypothesis import given, settings
    from hypothesis import strategies as st

    tracers = list(ALL_TRACERS.values())

    @settings(max_examples=20, deadline=None)
    @given(data=st.data())
    def run(data):
        k = data.draw(st.integers(min_value=1, max_value=4))
        picks = data.draw(st.lists(
            st.integers(min_value=0, max_value=len(tracers) - 1),
            min_size=k, max_size=k))
        b = data.draw(st.sampled_from([8, 24, 56, 120]))
        pad = data.draw(st.integers(min_value=0, max_value=50))
        seqs = [tracers[i](264, b) for i in picks]
        eng = PredictionEngine(models)
        base = eng.predict_compiled(compile_calls(seqs))
        repadded = eng.predict_compiled(
            compile_calls(seqs, pad_rows_to=pad))
        np.testing.assert_array_equal(repadded, base)
        ref = _scalar_reference(seqs, models)
        np.testing.assert_allclose(base, ref, rtol=REL, atol=0)

    run()
