"""Tests for the size-parametric suite models (repro.tc.parametric).

The tentpole contract, pinned against the measured oracle: after a
budgeted refinement pass over a size grid, a sweep over grid points
whose shapes were NEVER measured issues **zero** fresh micro-benchmarks
(the suite's ``measured`` counter proves it, ``predicted_parametric``
counts the keys served from models instead) and its rankings agree with
the exact-shape measurement path (``benchmark_fresh`` / ``rank_oracle``)
— which stays intact as the per-shape equivalence oracle.

All measurement goes through an injected deterministic ``measure_fn``
whose runtime is linear in ``key.call_bytes`` — inside the fitted
basis's span, so held-out predictions are exact up to float noise and
the oracle comparisons are equivalence checks, not statistical ones.
"""

import pytest

from repro.store import PARAMETRIC_MODEL_SET, ModelStore, kendall_tau
from repro.tc import PredictorSession
from repro.tc.parametric import (ParametricModels, cost_exponents, key_at,
                                 signature_dims, signature_of, size_point)
from repro.tc.suite import MicroBenchmarkSuite
from repro.core.sampler import Stats

SPEC = "bij,bjk->bik"
#: the refinement pass sees only the grid ENDPOINTS; the cheap cartesian
#: root grid over [lo, hi] samples {lo, mid, hi} per varying dim
REFINE_GRID = [dict(b=8, i=i, j=64, k=64) for i in (32, 96)]
#: held-out sizes strictly inside the fitted domains but never on any
#: refinement grid (the root samples i-derived extents at 32/64/96)
HOLDOUTS = [dict(b=8, i=i, j=64, k=64) for i in (40, 56)]


def fake_measure(key, repetitions):
    """Deterministic pure function of the key: exact reproducibility."""
    t = 1e-9 * key.call_bytes + 2e-6 + 5e-7 * key.classes.count("cold")
    return Stats(0.95 * t, t, 1.1 * t, 1.01 * t, 0.02 * t), 1e-3


def fake_suite(**kw):
    return MicroBenchmarkSuite(measure_fn=fake_measure, **kw)


def parametric_session(**kw):
    return PredictorSession(suite=fake_suite(), parametric=True, **kw)


def refined_session(**kw):
    sess = parametric_session(**kw)
    sess.refine_parametric(SPEC, REFINE_GRID)
    return sess


# ----------------------------------------------------------- signatures ----

def test_signature_point_roundtrip():
    sess = parametric_session()
    pred = sess.contraction_predictor(SPEC, HOLDOUTS[0])
    keys = pred.benchmark_keys()          # pure key arithmetic
    assert sess.suite.n_benchmarks == 0   # ...nothing was measured
    for key in keys:
        sig = signature_of(key)
        point = size_point(key)
        assert key_at(sig, point) == key
        dims = signature_dims(key.equation)
        assert len(point) == len(dims)
        assert cost_exponents(key.equation) == ((1,) * len(dims),)


def test_size_point_rejects_inconsistent_key():
    sess = parametric_session()
    key = sess.contraction_predictor(SPEC, HOLDOUTS[0]).benchmark_keys()[0]
    bad = key.__class__(equation=key.equation, a_shape=key.a_shape,
                        b_shape=key.b_shape,
                        out_shape=tuple(n + 1 for n in key.out_shape),
                        classes=key.classes)
    with pytest.raises(ValueError):
        size_point(bad)


# ------------------------------------------- the zero-measurement sweep ----

def test_sweep_over_unmeasured_shapes_measures_nothing():
    sess = refined_session()
    budget = sess.parametric.config.max_points
    for model in sess.parametric.models.values():
        # per-signature fresh sampling respects the budget (the root
        # grid, at most 3 points per varying dim here, never exceeds it)
        assert model.n_refine_measured <= budget
    before = sess.suite.counters()
    sweep = sess.rank_contraction_sweep(SPEC, HOLDOUTS)
    after = sess.suite.counters()
    # the acceptance pin: the sweep itself issued ZERO micro-benchmarks
    assert after["measured"] == before["measured"]
    assert after["n_benchmarks"] == before["n_benchmarks"]
    assert sweep.predicted_parametric > 0
    assert after["predicted_parametric"] == sweep.predicted_parametric
    assert len(sweep.rankings) == len(HOLDOUTS)


def test_sweep_agrees_with_measured_oracle_at_holdouts():
    sess = refined_session()
    sweep = sess.rank_contraction_sweep(SPEC, HOLDOUTS)
    for sizes, ranking in zip(HOLDOUTS, sweep.rankings):
        pred = sess.contraction_predictor(SPEC, sizes)
        oracle = pred.rank_oracle(stat="med", fresh=True)
        oracle_med = {r.name: r.runtime.med for r in oracle}
        # top-1 agreement (modulo exact ties: the predicted winner's
        # measured runtime equals the measured optimum)
        assert oracle_med[ranking[0].name] == \
            pytest.approx(oracle[0].runtime.med, rel=1e-9)
        # per-candidate totals from the parametric predictions match the
        # fresh exact measurements
        for r in ranking:
            assert r.runtime.med == pytest.approx(oracle_med[r.name],
                                                  rel=1e-6)
        assert kendall_tau([r.name for r in ranking],
                           [r.name for r in oracle]) >= 0.98


def test_holdout_predictions_within_band_of_fresh_measurements():
    REL_BAND = 0.02   # the pinned band; exact-span data lands ~1e-12
    sess = refined_session()
    for sizes in HOLDOUTS:
        pred = sess.contraction_predictor(SPEC, sizes)
        for alg, key in zip(pred.algorithms, pred.benchmark_keys()):
            mb = sess.parametric.predict(key)
            assert mb is not None      # the grid is fully covered
            assert mb.seconds == 0.0   # predictions cost no wall-clock
            fresh = sess.suite.benchmark_fresh(alg, sizes)
            assert mb.stats.med == pytest.approx(fresh.stats.med,
                                                 rel=REL_BAND)
            assert mb.stats.min == pytest.approx(fresh.stats.min,
                                                 rel=REL_BAND)
            assert mb.first == pytest.approx(fresh.first, rel=REL_BAND)


def test_oracle_path_stays_apart_from_predictions():
    sess = refined_session()
    sess.rank_contraction_algorithms(SPEC, HOLDOUTS[0])
    before = sess.suite.counters()
    assert before["predicted_parametric"] > 0
    sess.contraction_predictor(SPEC, HOLDOUTS[0]).rank_oracle(fresh=True)
    after = sess.suite.counters()
    # oracle measurements enter neither results nor the prediction set,
    # and their wall-clock lands in the oracle bucket
    assert after["measured"] == before["measured"]
    assert after["n_benchmarks"] == before["n_benchmarks"]
    assert after["predicted_parametric"] == before["predicted_parametric"]
    assert after["oracle_cost_seconds"] > before["oracle_cost_seconds"]


def test_measurement_supersedes_prediction():
    sess = refined_session()
    sess.rank_contraction_algorithms(SPEC, HOLDOUTS[0])
    key = next(iter(sess.suite.predictions))
    n_predicted = sess.suite.predicted_parametric
    mb = sess.suite.measure_key(key)
    assert sess.suite.predicted_parametric == n_predicted - 1
    assert sess.suite.results[key] is mb


def test_out_of_domain_falls_back_to_measurement():
    sess = refined_session()
    before = sess.suite.counters()
    far = dict(b=8, i=512, j=64, k=64)   # far outside the fitted [32, 96]
    sess.rank_contraction_algorithms(SPEC, far)
    after = sess.suite.counters()
    # no guessing outside the fitted domain: the size-dependent keys
    # fell back to the exact-shape measurement path
    assert after["measured"] > before["measured"]


def test_refit_widens_domain_without_losing_coverage():
    sess = refined_session()
    n_sigs = sess.parametric.n_signatures
    wide = dict(b=8, i=160, j=64, k=64)
    summary = sess.refine_parametric(SPEC, [wide])
    assert summary["signatures_fitted"] > 0
    assert summary["measured"] > 0
    assert sess.parametric.n_signatures == n_sigs   # refit, not new sigs
    for sizes in HOLDOUTS + [wide]:
        pred = sess.contraction_predictor(SPEC, sizes)
        assert all(sess.parametric.covers(k) or k in sess.suite.results
                   for k in pred.benchmark_keys())
    # a repeat of the original request is fully covered: no work at all
    summary = sess.refine_parametric(SPEC, REFINE_GRID)
    assert summary == {"signatures_fitted": 0,
                       "signatures_covered": summary["signatures_covered"],
                       "measured": 0}
    assert summary["signatures_covered"] == n_sigs


def test_refine_parametric_requires_parametric_session():
    sess = PredictorSession(suite=fake_suite())
    with pytest.raises(ValueError, match="parametric"):
        sess.refine_parametric(SPEC, REFINE_GRID)


def test_chain_sweep_predicts_unmeasured_steps():
    chain = "ab,bc,cd->ad"
    grid = [dict(a=8, b=8, c=c, d=8) for c in (32, 96)]
    holdo = [dict(a=8, b=8, c=c, d=8) for c in (40, 56)]
    sess = parametric_session()
    sess.refine_parametric(chain, grid, max_loop_perms=2)
    before = sess.suite.counters()
    sweep = sess.rank_einsum_sweep(chain, holdo, max_loop_perms=2)
    after = sess.suite.counters()
    assert after["measured"] == before["measured"]
    assert sweep.predicted_parametric > 0


# ---------------------------------------------------------- persistence ----

def test_store_roundtrip_warm_session_predicts_without_measuring(tmp_path):
    sess = refined_session()
    sweep = sess.rank_contraction_sweep(SPEC, HOLDOUTS)
    path = tmp_path / "store.json"
    store = sess.save_store(path)
    assert PARAMETRIC_MODEL_SET in store.model_sets
    # predictions are NOT measurements: the store holds only measured keys
    assert store.n_keys == len(sess.suite.results)
    # the parametric payload round-trips bit-exactly (json floats via repr)
    loaded = ModelStore.load(path, fingerprint=store.fingerprint)
    assert loaded.to_payload() == store.to_payload()

    warm = PredictorSession(store=path)
    assert warm.parametric is not None    # auto-enabled by the stored models
    assert warm.parametric.n_signatures == sess.parametric.n_signatures
    warm_sweep = warm.rank_contraction_sweep(SPEC, HOLDOUTS)
    # zero fresh measurements AND bit-identical rankings to the original
    assert warm.suite.measured == 0
    assert warm.suite.predicted_parametric > 0
    for a, b in zip(sweep.rankings, warm_sweep.rankings):
        assert [(r.name, r.runtime) for r in a] == \
            [(r.name, r.runtime) for r in b]


def test_parametric_registry_is_shared_via_suite():
    suite = fake_suite()
    a = PredictorSession(suite=suite, parametric=True)
    b = PredictorSession(suite=suite)          # inherits the suite's registry
    assert b.parametric is a.parametric
    assert isinstance(a.parametric, ParametricModels)
