"""Distribution tests: sharding rules, sharded train step, elastic reshard."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs import get_config, reduced
from repro.distributed.elastic import reshard_tree
from repro.distributed.sharding import (cache_specs, data_specs,
                                        param_specs, simple_batch_spec)
from repro.launch.mesh import make_local_mesh
from repro.launch.steps import (abstract_params, input_specs,
                                make_train_step)
from repro.models import init_params
from repro.train.optimizer import AdamW


def _mesh11():
    return jax.make_mesh((1, 1), ("data", "model"))


def test_param_specs_divisibility():
    cfg = get_config("grok-1-314b")
    params_abs = abstract_params(cfg)
    mesh = _mesh11()

    # on a 1x1 mesh every dim divides: specs exist for all leaves
    specs = param_specs(params_abs, mesh)
    leaves = jax.tree_util.tree_leaves(
        specs, is_leaf=lambda x: isinstance(x, P))
    assert leaves and all(isinstance(s, P) for s in leaves)


def test_grok_experts_not_sharded_on_16():
    """grok has 8 experts: EP on a 16-wide model axis must NOT apply."""
    import os
    cfg = get_config("grok-1-314b")
    params_abs = abstract_params(cfg)
    devs = np.array(jax.devices() * 256)[:256].reshape(16, 16)
    mesh = Mesh(devs, ("data", "model"))
    specs = param_specs(params_abs, mesh)
    moe_spec = specs["blocks"]["p0"]["moe"].w_gate  # (np, E, d, f)
    # expert dim (8) cannot take the 16-wide axis; d_ff (32768) can
    assert moe_spec[1] != "model"
    assert "model" in tuple(moe_spec)


def test_arctic_experts_ep_sharded():
    cfg = get_config("arctic-480b")
    params_abs = abstract_params(cfg)
    devs = np.array(jax.devices() * 256)[:256].reshape(16, 16)
    mesh = Mesh(devs, ("data", "model"))
    specs = param_specs(params_abs, mesh)
    moe_spec = specs["blocks"]["p0"]["moe"].w_gate
    assert moe_spec[1] == "model"      # 128 experts over 16 => EP


def test_batch_spec_divisibility():
    devs = np.array(jax.devices() * 512)[:512].reshape(2, 16, 16)
    mesh = Mesh(devs, ("pod", "data", "model"))
    assert simple_batch_spec(mesh, 256) == P(("pod", "data"))
    assert simple_batch_spec(mesh, 2) == P(("pod",))
    assert simple_batch_spec(mesh, 1) == P()


def test_cache_specs_structure():
    cfg = get_config("jamba-v0.1-52b")
    devs = np.array(jax.devices() * 256)[:256].reshape(16, 16)
    mesh = Mesh(devs, ("data", "model"))
    specs = cache_specs(cfg, mesh, 128)
    for pi, spec in enumerate(cfg.block_pattern):
        entry = specs[f"p{pi}"]
        if spec.mixer == "attn":
            assert isinstance(entry, tuple) and len(entry) == 2
        else:
            assert isinstance(entry, P)


def test_sharded_train_step_runs():
    cfg = reduced(get_config("deepseek-7b"), n_layers=2, d_model=64,
                  d_ff=128, vocab=256)
    mesh = _mesh11()
    params = init_params(cfg, jax.random.PRNGKey(0), dtype=jnp.float32)
    opt = AdamW(lr=1e-3)
    opt_state = opt.init(params)
    pspecs = param_specs(params, mesh)
    sh = jax.tree_util.tree_map(lambda s: NamedSharding(mesh, s), pspecs,
                                is_leaf=lambda x: isinstance(x, P))
    params = jax.tree_util.tree_map(jax.device_put, params, sh)
    step = jax.jit(make_train_step(cfg, opt))
    batch = {"inputs": jnp.zeros((2, 32), jnp.int32),
             "labels": jnp.zeros((2, 32), jnp.int32)}
    with mesh:
        p2, o2, loss = step(params, opt_state, batch)
    assert np.isfinite(float(loss))


def test_elastic_reshard_roundtrip(tmp_path):
    """Checkpoint on one mesh, restore re-placed on another."""
    from repro.distributed.elastic import resume_on_mesh
    from repro.train import checkpoint as ck

    cfg = reduced(get_config("deepseek-7b"), n_layers=2, d_model=64,
                  d_ff=128, vocab=256)
    params = init_params(cfg, jax.random.PRNGKey(0), dtype=jnp.float32)
    ck.save(str(tmp_path), 7, params)
    mesh2 = _mesh11()                      # the "new" mesh after failure
    restored, step = resume_on_mesh(str(tmp_path), params, mesh2)
    assert step == 7
    a = jax.tree_util.tree_leaves(params)[0]
    b = jax.tree_util.tree_leaves(restored)[0]
    np.testing.assert_allclose(np.asarray(a), np.asarray(b))


def test_input_specs_all_cells():
    """input_specs must produce pure ShapeDtypeStructs for every cell."""
    from repro.configs import SHAPES, all_configs

    for arch, cfg in all_configs().items():
        for sname in cfg.shapes:
            spec = input_specs(cfg, SHAPES[sname])
            for leaf in jax.tree_util.tree_leaves(spec):
                assert isinstance(leaf, jax.ShapeDtypeStruct), (arch, sname)
