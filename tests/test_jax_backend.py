"""Equivalence-oracle tests for the JAX prediction backend + trace cache.

The scalar per-call path (`predict_runtime`) is the reference oracle; both
batched backends — ``backend="numpy"`` and the jitted ``backend="jax"``
(padded per-(kernel, case) tensors, float64 XLA programs) — must agree with
it to ~1e-8 across the full tracer catalog.  A cached ``sweep`` must return
bit-identical results to an uncached one.
"""

import numpy as np
import pytest

from benchmarks.common import catalog_synthetic_model_set
from repro.core import (PredictionEngine, TraceCache, fit_relative,
                        monomial_basis, predict_runtime, stack_polynomials)
from repro.core.sampler import STATS
from repro.dla.tracers import ALL_TRACERS, CHOLESKY_TRACERS, TRTRI_TRACERS

REL = 1e-8

CATALOG = ALL_TRACERS


def _rel_close(a, b, tol=REL):
    return abs(a - b) <= tol * max(1.0, abs(a), abs(b))


@pytest.fixture(scope="module")
def models():
    return catalog_synthetic_model_set()


def test_backends_match_scalar_oracle_on_full_catalog(models):
    n, b = 264, 56
    seqs = [tracer(n, b) for tracer in CATALOG.values()]
    got_np = PredictionEngine(models).predict_batch(seqs)
    got_jax = PredictionEngine(models, backend="jax").predict_batch(seqs)
    for i, (name, tracer) in enumerate(CATALOG.items()):
        ref = predict_runtime(tracer(n, b), models)
        for j, s in enumerate(STATS):
            assert _rel_close(got_np[i, j], getattr(ref, s)), (name, s)
            assert _rel_close(got_jax[i, j], getattr(ref, s)), (name, s)


def test_jax_estimate_batch_degenerate_and_out_of_domain(models):
    """Degenerate rows estimate 0 and out-of-domain rows clamp, both exactly
    like the numpy batch path."""
    model = models["gemm"]
    case = next(iter(model.cases))
    pts = np.array([[0, 64, 64], [64, -8, 64], [64, 64, 64],
                    [4, 4, 4], [5000, 5000, 5000]], dtype=np.float64)
    ref = model.estimate_batch(case, pts)
    got = model.estimate_batch(case, pts, backend="jax")
    assert np.all(got[:2] == 0.0)
    np.testing.assert_allclose(got, ref, rtol=REL, atol=0)


def test_unknown_backend_rejected(models):
    with pytest.raises(ValueError, match="backend"):
        PredictionEngine(models, backend="torch")


def test_conflicting_backend_and_engine_rejected(models):
    """An explicit backend= must not be silently overridden by engine=."""
    from repro.core import rank_algorithms

    eng = PredictionEngine(models, backend="jax")
    with pytest.raises(ValueError, match="conflicts"):
        rank_algorithms(CHOLESKY_TRACERS, models, 264, 56,
                        backend="numpy", engine=eng)
    # the scalar oracle has no backend: an explicit one must not be dropped
    with pytest.raises(ValueError, match="scalar"):
        rank_algorithms(CHOLESKY_TRACERS, models, 264, 56,
                        batched=False, backend="jax")
    # matching or omitted backend is fine
    ranked = rank_algorithms(CHOLESKY_TRACERS, models, 264, 56, engine=eng)
    assert rank_algorithms(CHOLESKY_TRACERS, models, 264, 56,
                           backend="jax", engine=eng) == ranked


def test_stacked_polynomials_eval_jax_matches_numpy():
    rng = np.random.default_rng(41)
    pts = rng.uniform(8, 512, size=(40, 2))
    vals = 1e-9 * pts[:, 0] ** 2 * pts[:, 1] + 1e-6
    full = monomial_basis([(2, 1)])
    polys = [fit_relative(pts, vals * f, full) for f in (0.9, 1.0, 1.1)]
    # a constant-basis polynomial lands in a second group: exercises padding
    polys.append(fit_relative(pts, np.full(len(pts), 3e-8), [(0, 0)]))
    stacked = stack_polynomials(polys)
    query = rng.uniform(4, 600, size=(25, 2))
    np.testing.assert_allclose(stacked.eval_jax(query), stacked(query),
                               rtol=REL, atol=0)


def test_cached_sweep_bit_identical_to_uncached(models):
    tracer = CHOLESKY_TRACERS["potrf3"]
    candidates = [8 * (i + 1) for i in range(16)]
    eng = PredictionEngine(models)
    first = eng.sweep(tracer, 256, candidates)
    assert (eng.cache.hits, eng.cache.misses) == (0, len(candidates))
    again = eng.sweep(tracer, 256, candidates)
    # the compiled batch is reused outright: zero extra traces (one
    # whole-batch hit), and the prediction is bit-identical
    assert eng.cache.misses == len(candidates)
    assert eng.cache.hits == 1
    np.testing.assert_array_equal(again, first)
    # an uncached engine computes the same bits
    uncached = PredictionEngine(models).sweep(tracer, 256, candidates)
    np.testing.assert_array_equal(uncached, first)
    # the sweep artifact itself is one object, reusable via predict_compiled
    compiled = eng.compile_sweep(tracer, 256, candidates)
    assert compiled is eng.compile_sweep(tracer, 256, candidates)
    np.testing.assert_array_equal(eng.predict_compiled(compiled), first)


def test_trace_cache_shared_across_engines_and_backends(models):
    cache = TraceCache()
    eng_np = PredictionEngine(models, cache=cache)
    eng_jax = PredictionEngine(models, backend="jax", cache=cache)
    tracer = TRTRI_TRACERS["trtri1"]
    ns, bs = [128, 192], [16, 32, 48]
    grid_np = eng_np.grid(tracer, ns, bs)
    misses = cache.misses
    grid_jax = eng_jax.grid(tracer, ns, bs)
    assert cache.misses == misses  # second backend re-traced nothing
    assert grid_np.shape == grid_jax.shape == (len(ns), len(bs), len(STATS))
    np.testing.assert_allclose(grid_jax, grid_np, rtol=REL, atol=0)


def test_selection_entry_points_agree_across_backends(models):
    from repro.core import optimize_block_size, rank_algorithms

    tracers = dict(CHOLESKY_TRACERS)
    ranked_np = rank_algorithms(tracers, models, 264, 56)
    ranked_jax = rank_algorithms(tracers, models, 264, 56, backend="jax")
    assert [r.name for r in ranked_np] == [r.name for r in ranked_jax]
    candidates = [16, 32, 48, 64]
    b_np, prof_np = optimize_block_size(CHOLESKY_TRACERS["potrf2"], models,
                                        264, candidates)
    b_jax, prof_jax = optimize_block_size(CHOLESKY_TRACERS["potrf2"], models,
                                          264, candidates, backend="jax")
    assert b_np == b_jax
    for b in candidates:
        assert _rel_close(prof_np[b], prof_jax[b])


def test_compile_traces_helper_matches_per_config_compile(models):
    from repro.dla import Matrix, blocked, compile_traces

    fns = [lambda e, b=b: blocked.potrf(e, Matrix("A", 128, 128), 128, b, 2)
           for b in (16, 32)]
    compiled = compile_traces(fns)
    assert compiled.n_configs == 2
    stats = PredictionEngine(models, backend="jax").predict_compiled(compiled)
    assert stats.shape == (2, len(STATS))
    assert np.all(stats[:, :4] > 0)
