"""Tests for repro.store: persistence, warm start, drift, tournament.

The store's headline contract: a ``PredictorSession`` warm-started from
a saved ``ModelStore`` produces **bit-identical** rankings to the
in-memory session the store was captured from, with **zero** new
micro-benchmarks (the suite's ``measured`` counter proves it).  The
in-memory session is the equivalence oracle for every warm-started
session — see the oracle table in ``docs/architecture.md``.  All
measurement here goes through an injected deterministic ``measure_fn``,
so equality checks are exact, not statistical.
"""

import json
import warnings

import numpy as np
import pytest

from benchmarks.common import catalog_synthetic_model_set
from repro.core import PredictionEngine, compile_calls
from repro.core.fitting import fit_relative
from repro.core.grids import Domain
from repro.core.model import ModelSet, PerformanceModel, Piece
from repro.core.sampler import STATS, Stats
from repro.dla.tracers import ALL_TRACERS
from repro.store import (SCHEMA_VERSION, DriftProbe, ModelStore,
                         PlatformFingerprint, Snapshot, StoreMismatchError,
                         current_fingerprint, frozen_workloads,
                         kendall_tau, run_tournament, workload)
from repro.tc import PredictorSession
from repro.tc.suite import MicroBenchmark, MicroBenchmarkSuite

SPEC = "bij,bjk->bik"
SIZES = dict(b=4, i=16, j=16, k=16)
CHAIN = "ab,bc,cd->ad"
CHAIN_SIZES = dict(a=8, b=8, c=8, d=8)
SWEEP_GRID = [dict(SIZES, b=b) for b in (4, 8)]


def fake_measure(key, repetitions):
    """Deterministic pure function of the key: exact reproducibility."""
    t = 1e-9 * key.call_bytes + 2e-6 + 5e-7 * key.classes.count("cold")
    return Stats(0.95 * t, t, 1.1 * t, 1.01 * t, 0.02 * t), 1e-3


def scaled_measure(factor):
    """A measure_fn reading ``factor``x slower than :func:`fake_measure`."""
    def fn(key, repetitions):
        s, first = fake_measure(key, repetitions)
        return Stats(s.min * factor, s.med * factor, s.max * factor,
                     s.mean * factor, s.std * factor), first
    return fn


def fake_suite(**kw):
    return MicroBenchmarkSuite(measure_fn=fake_measure, **kw)


def fake_session(**kw):
    return PredictorSession(suite=fake_suite(), **kw)


def rank_everything(sess):
    """Contraction + chain + sweep rankings as comparable value tuples.

    ``Stats`` is a frozen dataclass of floats, so the extracted
    ``(name, runtime)`` pairs compare field-exactly — equality between
    two sessions' outputs is bit-identity of the predictions.
    """
    contraction = [(r.name, r.runtime) for r in
                   sess.rank_contraction_algorithms(SPEC, SIZES)]
    chain = [(r.name, r.runtime) for r in
             sess.rank_einsum_paths(CHAIN, CHAIN_SIZES, max_loop_perms=2)]
    sweep = [[(r.name, r.runtime) for r in ranking]
             for ranking in sess.rank_contraction_sweep(SPEC,
                                                        SWEEP_GRID).rankings]
    return contraction, chain, sweep


# ------------------------------------------------------------- round trip --

def test_store_round_trips_measurements_exactly(tmp_path):
    sess = fake_session()
    sess.rank_contraction_algorithms(SPEC, SIZES)
    store = sess.save_store()
    path = tmp_path / "store.json"
    store.save(path)
    loaded = ModelStore.load(path, fingerprint=store.fingerprint)
    # MicroBenchmark/Stats are frozen dataclasses (== is field-exact) and
    # json floats round-trip via repr, so this is bit-exact equality
    assert loaded.measurements == store.measurements
    assert loaded.suite_meta == store.suite_meta
    assert loaded.fingerprint == store.fingerprint


def test_store_refuses_non_finite_measurements():
    sess = fake_session()
    sess.rank_contraction_algorithms(SPEC, SIZES)
    store = ModelStore.from_suite(sess.suite)
    key = next(iter(store.measurements))
    bad = Stats(0.0, float("nan"), 0.0, 0.0, 0.0)
    store.measurements[key] = MicroBenchmark(key=key, stats=bad,
                                             first=0.0, seconds=0.0)
    with pytest.raises(ValueError, match="non-finite"):
        store.to_payload()


def test_suite_protocol_conflict_on_merge():
    sess = fake_session()
    sess.rank_contraction_algorithms(SPEC, SIZES)
    store = ModelStore.from_suite(sess.suite)
    with pytest.raises(ValueError, match="measurement protocol"):
        store.add_suite(fake_suite(repetitions=3))


# ------------------------------------------------------- refusal to load --

def test_fingerprint_mismatch_refuses_and_lists_fields(tmp_path):
    sess = fake_session()
    sess.rank_contraction_algorithms(SPEC, SIZES)
    path = tmp_path / "store.json"
    sess.save_store(path)
    other = PlatformFingerprint(
        cpu="other-cpu", cores=1, backend="other", device_kind="other",
        libraries="other", dtype="float64", repro_version="0.0.0")
    with pytest.raises(StoreMismatchError) as err:
        ModelStore.load(path, fingerprint=other)
    # the refusal names every differing field
    for field in ("cpu", "backend", "dtype"):
        assert field in str(err.value)
    # the escape hatch loads anyway, keeping the STORED fingerprint
    loaded = ModelStore.load(path, fingerprint=other, allow_mismatch=True)
    assert loaded.n_keys == len(sess.suite.results)
    assert loaded.fingerprint == current_fingerprint()


def test_schema_bump_refuses_even_with_allow_mismatch(tmp_path):
    sess = fake_session()
    sess.rank_contraction_algorithms(SPEC, SIZES)
    path = tmp_path / "store.json"
    sess.save_store(path)
    payload = json.loads(path.read_text())
    assert payload["schema_version"] == SCHEMA_VERSION
    payload["schema_version"] = SCHEMA_VERSION + 1
    path.write_text(json.dumps(payload))
    with pytest.raises(StoreMismatchError, match="schema_version"):
        ModelStore.load(path)
    with pytest.raises(StoreMismatchError, match="schema_version"):
        ModelStore.load(path, allow_mismatch=True)   # schema gap is final


def test_session_store_and_suite_are_exclusive():
    sess = fake_session()
    sess.rank_contraction_algorithms(SPEC, SIZES)
    store = sess.save_store()
    with pytest.raises(ValueError, match="store= or suite="):
        PredictorSession(store=store, suite=fake_suite())
    # repetitions may restate the stored protocol, never contradict it
    with pytest.raises(ValueError, match="repetitions"):
        PredictorSession(store=store, repetitions=3)
    PredictorSession(store=store, repetitions=5)     # matches: fine


# ---------------------------------------------------------- warm start --

def test_warm_started_rankings_bit_identical_with_zero_measurements(
        tmp_path):
    sess = fake_session()
    in_memory = rank_everything(sess)
    path = tmp_path / "store.json"
    sess.save_store(path)

    warm = PredictorSession(store=str(path))
    warm_rankings = rank_everything(warm)
    counters = warm.counters()
    assert counters["measured"] == 0, "warm start must not re-measure"
    assert counters["loaded"] == len(sess.suite.results)
    assert warm_rankings == in_memory


def test_warm_start_amortized_cost_accounting():
    sess = fake_session()
    sess.rank_contraction_algorithms(SPEC, SIZES)
    store = sess.save_store()
    warm = PredictorSession(store=store)
    warm.rank_contraction_algorithms(SPEC, SIZES)
    suite = warm.suite
    assert suite.cost_seconds == 0.0            # nothing measured here
    assert suite.loaded_cost_seconds > 0.0      # but not claimed free
    assert suite.cost_fraction(1.0) == 0.0      # marginal cost: zero
    assert suite.cost_fraction(1.0, include_loaded=True) == \
        pytest.approx(suite.loaded_cost_seconds)


def test_counters_partition_by_provenance():
    sess = fake_session()
    sess.rank_contraction_algorithms(SPEC, SIZES)
    store = sess.save_store()

    warm = PredictorSession(store=store)
    warm.suite.measure_fn = fake_measure
    c = warm.counters()
    assert c["loaded"] == len(store.measurements) and c["measured"] == 0

    # a NEW problem measures fresh benchmarks on top of the loaded ones
    warm.rank_contraction_algorithms("ij,jk->ik", dict(i=8, j=8, k=8))
    c = warm.counters()
    assert c["measured"] > 0
    # refresh moves a loaded key into the refreshed bucket: the three
    # buckets always partition n_benchmarks
    key = sorted(store.measurements, key=str)[0]
    warm.suite.refresh(key)
    c = warm.counters()
    assert c["refreshed"] == 1
    assert c["loaded"] == len(store.measurements) - 1
    assert c["loaded"] + c["measured"] + c["refreshed"] == c["n_benchmarks"]
    # refreshing an already-refreshed key does not double-count
    warm.suite.refresh(key)
    assert warm.counters()["refreshed"] == 1


# ----------------------------------------------------------------- drift --

def test_drift_probe_ratios_and_threshold():
    sess = fake_session()
    sess.rank_contraction_algorithms(SPEC, SIZES)
    probe = DriftProbe(sess.suite, max_keys=4, threshold=1.5,
                       measure_fn=scaled_measure(2.0))
    readings = probe.probe()
    assert 0 < len(readings) <= 4
    for r in readings:
        assert r.ratio == pytest.approx(2.0)
    assert len(probe.stale()) == len(readings)
    assert probe.max_ratio() == pytest.approx(2.0)
    # a wider band declares the same readings healthy
    lax = DriftProbe(sess.suite, max_keys=4, threshold=2.5,
                     measure_fn=scaled_measure(2.0))
    assert lax.stale() == []
    # speedups are drift too: the band is two-sided
    fast = DriftProbe(sess.suite, max_keys=4, threshold=1.5,
                      measure_fn=scaled_measure(0.4))
    assert len(fast.stale()) == len(fast.probe())


def test_drift_probe_subset_is_deterministic():
    sess = fake_session()
    rank_everything(sess)
    assert len(sess.suite.results) > 6
    a = DriftProbe(sess.suite, max_keys=6).keys()
    b = DriftProbe(sess.suite, max_keys=6).keys()
    assert a == b and len(a) == 6
    assert len(set(a)) == 6


def test_drift_probe_does_not_touch_suite_counters():
    sess = fake_session()
    sess.rank_contraction_algorithms(SPEC, SIZES)
    before = sess.counters()
    DriftProbe(sess.suite, measure_fn=scaled_measure(3.0)).probe()
    assert sess.counters() == before


def test_drift_refresh_repairs_in_place():
    sess = fake_session()
    sess.rank_contraction_algorithms(SPEC, SIZES)
    probe = DriftProbe(sess.suite, max_keys=4, threshold=1.5,
                       measure_fn=scaled_measure(2.0))
    stale_keys = [r.key for r in probe.stale()]
    replaced = probe.refresh()
    assert [mb.key for mb in replaced] == stale_keys
    assert sess.counters()["refreshed"] == len(stale_keys)
    # repaired measurements now match the drifted platform: re-probing
    # against the same backend reads ratio 1
    again = DriftProbe(sess.suite, max_keys=4, threshold=1.5,
                       measure_fn=scaled_measure(2.0))
    assert again.stale() == []
    # and the suite's own measure_fn was restored after the repair
    assert sess.suite.measure_fn is fake_measure


def test_session_check_drift_warns_and_refreshes():
    sess = fake_session()
    sess.rank_contraction_algorithms(SPEC, SIZES)
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        readings = sess.check_drift(measure_fn=scaled_measure(2.0),
                                    refresh=True)
    assert any("model drift" in str(w.message) for w in caught)
    assert all(r.ratio == pytest.approx(2.0) for r in readings)
    assert sess.counters()["refreshed"] == len(readings)
    # the refreshed keys now reflect the drifted platform: re-probing
    # against it is quiet
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        sess.check_drift(measure_fn=scaled_measure(2.0))
    assert not caught


def test_drift_probe_rejects_degenerate_threshold():
    with pytest.raises(ValueError, match="threshold"):
        DriftProbe(fake_suite(), threshold=1.0)


# ------------------------------------------------------------ tournament --

def _two_snapshots(tmp_path):
    """A faithful store and a rank-scrambling distorted copy."""
    sess = fake_session()
    sess.rank_contraction_algorithms(SPEC, SIZES)
    faithful = sess.save_store(tmp_path / "faithful.json")
    distorted = ModelStore.load(tmp_path / "faithful.json",
                                fingerprint=faithful.fingerprint)
    for i, key in enumerate(sorted(distorted.measurements, key=str)):
        mb = distorted.measurements[key]
        f = 1.0 + 0.9 * ((i * 7919) % 13) / 13   # non-uniform: breaks order
        s = mb.stats
        distorted.measurements[key] = MicroBenchmark(
            key=key, stats=Stats(s.min * f, s.med * f, s.max * f,
                                 s.mean * f, s.std), first=mb.first,
            seconds=mb.seconds)
    return faithful, distorted


def test_tournament_scores_and_orders_snapshots(tmp_path):
    faithful, distorted = _two_snapshots(tmp_path)
    loads = [workload("contraction", "contraction", SPEC, SIZES)]
    result = run_tournament(
        [Snapshot("distorted", distorted), Snapshot("faithful", faithful)],
        loads, oracle_session=fake_session(), measure_fn=fake_measure)
    assert result.scores[0].name == "faithful"
    winner = result.winner
    assert winner.rel_err == 0.0
    assert winner.top1_rate == 1.0
    assert winner.rank_agreement == 1.0
    assert winner.new_benchmarks == 0
    loser = result.scores[-1]
    assert loser.rel_err > 0.0


def test_tournament_payload_is_schema_stamped(tmp_path):
    faithful, distorted = _two_snapshots(tmp_path)
    loads = [workload("contraction", "contraction", SPEC, SIZES)]
    result = run_tournament(
        [Snapshot("a", faithful), Snapshot("b", distorted)], loads,
        oracle_session=fake_session(), measure_fn=fake_measure)
    path = tmp_path / "TOURNAMENT.json"
    result.save(path)
    payload = json.loads(path.read_text())
    assert payload["schema_version"] == SCHEMA_VERSION
    assert len(payload["scoreboard"]) == 2
    row = payload["scoreboard"][0]
    for field in ("name", "rel_err", "top1_rate", "rank_agreement",
                  "suite_cost_seconds", "new_benchmarks"):
        assert field in row
    assert payload["workloads"] == ["contraction"]


def test_tournament_needs_two_snapshots(tmp_path):
    faithful, _ = _two_snapshots(tmp_path)
    with pytest.raises(ValueError, match="at least 2"):
        run_tournament([Snapshot("only", faithful)])


def test_kendall_tau_reference_values():
    assert kendall_tau("abcd", "abcd") == 1.0
    assert kendall_tau("abcd", "dcba") == -1.0
    # one adjacent swap in 4 elements: 5 concordant pairs, 1 discordant
    assert kendall_tau("abcd", "abdc") == pytest.approx(4 / 6)
    # disjoint / trivial orderings have nothing to disagree about
    assert kendall_tau("ab", "cd") == 1.0
    assert kendall_tau("a", "a") == 1.0


def test_frozen_workloads_match_bench_smoke_constants():
    """The tournament's frozen literals mirror the bench smoke specs —
    if a bench spec moves, this test pins the decision: either move the
    frozen workloads too (breaking cross-commit score comparability, on
    purpose) or keep them frozen and update this pin."""
    import benchmarks.bench_contractions as bc
    import benchmarks.bench_einsum_paths as bp
    import benchmarks.bench_serving as bs
    by_name = {w.name: w for w in frozen_workloads()}
    contraction = by_name["contraction_smoke"]
    assert contraction.expr == bc.SMOKE_SPEC
    assert dict(contraction.sizes) == bc.SMOKE_SIZES
    chain = by_name["einsum_path_smoke"]
    assert chain.expr == bp.SMOKE_CHAIN
    assert dict(chain.sizes) == bp.SMOKE_SIZES
    opts = dict(chain.options)
    assert opts["kernels"] == bp.SMOKE_KERNELS
    assert opts["max_loop_perms"] == bp.SMOKE_LOOP_PERMS
    assert opts["memory_limit_bytes"] == bp.SMOKE_LIMIT
    serving = by_name["serving_step_proj"]
    sizes = dict(serving.sizes)
    assert sizes["j"] == bs.SMOKE_ARCH["d_model"]
    assert sizes["k"] == bs.SMOKE_ARCH["d_ff"]
    assert sizes["b"] == bs.SLOTS
    # the smoke subset drops only the expensive chain workload
    assert {w.name for w in frozen_workloads(smoke=True)} == \
        {"contraction_smoke", "serving_step_proj"}


# --------------------------------------- model save/load round-trip (io) --

def _quadratic_model(kernel="gemm"):
    """A tiny fitted model whose case is a NESTED tuple, like the tc
    per-signature cases."""
    xs = [[float(n)] for n in range(4, 44, 4)]
    case = ("ab,bc->ac", (8, 8), (8, 8), (8, 8), ("warm", "cold"))
    basis = [(0,), (1,), (2,)]
    m = PerformanceModel(kernel=kernel, setup="test")
    polys = {}
    for j, stat in enumerate(STATS):
        ys = [(1 + 0.1 * j) * (2e-9 * x[0] ** 2 + 1e-6) for x in xs]
        polys[stat] = fit_relative(xs, ys, basis)
    m.add_piece(case, Piece(domain=Domain((4,), (40,)), polys=polys))
    return m, case


def test_performance_model_from_dict_freezes_nested_cases(tmp_path):
    m, case = _quadratic_model()
    path = tmp_path / "model.json"
    m.save(str(path))
    loaded = PerformanceModel.load(str(path))
    # the json round trip turns the case's nested tuples into lists;
    # from_dict must freeze them back or the case neither hashes nor
    # matches the tuples lookups are keyed by
    assert list(loaded.cases) == [case]
    assert loaded.estimate(case, (16,)) == m.estimate(case, (16,))


def test_performance_model_load_refinalizes_padded_tensors(tmp_path):
    m, case = _quadratic_model()
    m.finalize()
    path = tmp_path / "model.json"
    m.save(str(path))
    loaded = PerformanceModel.load(str(path))
    # from_dict re-finalized: the padded case tensors are already built
    cm = loaded.cases[case]
    assert getattr(cm, "_jax_cache", None) is not None
    for got, ref in zip(cm.padded_tensors(),
                        m.cases[case].padded_tensors()):
        np.testing.assert_array_equal(got, ref)


def test_model_set_save_load_predict_compiled_bit_equal(tmp_path):
    """The regression the store layer depends on: ModelSet artifacts
    survive a save/load cycle, proven by BIT-equal ``predict_compiled``
    output over the full tracer catalog."""
    models = catalog_synthetic_model_set()
    seqs = [tracer(264, 56) for tracer in ALL_TRACERS.values()]
    compiled = compile_calls(seqs)
    before = PredictionEngine(models,
                              backend="numpy").predict_compiled(compiled)
    path = tmp_path / "models.json"
    models.save(str(path))
    loaded = ModelSet.load(str(path))
    assert set(loaded.models) == set(models.models)
    after = PredictionEngine(loaded,
                             backend="numpy").predict_compiled(compiled)
    np.testing.assert_array_equal(after, before)


def test_model_sets_round_trip_through_store(tmp_path):
    sess = fake_session()
    sess.rank_contraction_algorithms(SPEC, SIZES)
    path = tmp_path / "store.json"
    store = sess.save_store(path)
    assert len(store.model_sets) == 1
    loaded = ModelStore.load(path, fingerprint=store.fingerprint)
    (name, ms), = loaded.model_sets.items()
    original = store.model_set(name)
    assert json.dumps(ms.to_dict(), sort_keys=True) == \
        json.dumps(original.to_dict(), sort_keys=True)
