"""Tests for the adaptive-refinement generator (paper §3.2.5, §3.3).

The generator was dormant until the size-parametric suite models started
driving it; these tests pin its contract directly, with analytic sample
functions instead of real measurements: convergence on smooth curves,
splitting on curves one polynomial cannot capture, measurement caching
(no point is ever sampled twice), the fresh-measurement budget, and the
deterministic point ordering the parametric layer's bit-stability
guarantees rest on.
"""

import pytest

from repro.core.grids import Domain, grid_points
from repro.core.refinement import GeneratorConfig, _Cache, refine
from repro.core.sampler import STATS, Stats


def analytic_sample_fn(fn, log=None):
    """SampleFn evaluating an analytic runtime curve ``fn(point) -> sec``.

    ``log`` (optional list) records every batch of points requested, in
    request order, so tests can assert on sampling behaviour.
    """

    def sample(points):
        if log is not None:
            log.append(tuple(points))
        return {p: Stats.from_samples([fn(p)]) for p in points}

    return sample


def counting_sample_fn(fn):
    """Like :func:`analytic_sample_fn` but counts samples per point."""
    counts = {}

    def sample(points):
        out = {}
        for p in points:
            counts[p] = counts.get(p, 0) + 1
            out[p] = Stats.from_samples([fn(p)])
        return out

    return sample, counts


# a cheap configuration: linear basis (overfit=0), 3 points per dim
CHEAP = GeneratorConfig(overfit=0, oversampling=1, grid="cartesian",
                        error_bound=0.02, min_width=16, round_to=8)

LINEAR = lambda p: 2e-9 * p[0] + 1e-6  # exactly in the linear basis's span


def kinked(p):
    """A performance cliff at x=128: no single linear fit works."""
    x = p[0]
    return 1e-6 * x if x <= 128 else 2.5e-6 * x - 1.92e-4


# ------------------------------------------------------------ convergence --


def test_refine_linear_curve_one_piece():
    dom = Domain((32,), (256,))
    pieces = refine(dom, analytic_sample_fn(LINEAR), [(1,)], CHEAP)
    assert len(pieces) == 1
    piece = pieces[0]
    assert piece.domain == dom
    assert set(piece.polys) == set(STATS)
    # data in the basis span -> the fit reproduces the curve everywhere
    # in the domain, not just at sampled points
    for x in (32, 40, 100, 200, 256):
        est = piece.estimate((x,))
        assert est["med"] == pytest.approx(LINEAR((x,)), rel=1e-9)


def test_refine_splits_on_performance_cliff():
    dom = Domain((32,), (256,))
    pieces = refine(dom, analytic_sample_fn(kinked), [(1,)], CHEAP)
    assert len(pieces) > 1
    # the pieces tile the original domain without gaps or overlap
    spans = sorted((p.domain.lo[0], p.domain.hi[0]) for p in pieces)
    assert spans[0][0] == dom.lo[0] and spans[-1][1] == dom.hi[0]
    for (_, hi_a), (lo_b, _) in zip(spans, spans[1:]):
        assert hi_a == lo_b
    # away from the cliff the local linear fits are accurate
    for x in (40, 64, 224, 248):
        piece = next(p for p in pieces if p.domain.contains((x,)))
        assert piece.estimate((x,))["med"] == \
            pytest.approx(kinked((x,)), rel=CHEAP.error_bound)


def test_refine_2d_multilinear_curve():
    dom = Domain((32, 32), (128, 128))
    fn = lambda p: 1e-9 * p[0] * p[1] + 5e-7
    pieces = refine(dom, analytic_sample_fn(fn), [(1, 1)], CHEAP)
    assert len(pieces) == 1
    assert pieces[0].estimate((100, 50))["med"] == \
        pytest.approx(fn((100, 50)), rel=1e-9)


# ---------------------------------------------------------------- caching --


def test_cache_never_resamples():
    fn, counts = counting_sample_fn(LINEAR)
    cache = _Cache(fn)
    pts = [(32,), (64,), (96,)]
    first = cache.get(pts)
    again = cache.get(pts)
    assert first == again
    assert cache.measured_points == len(pts)
    assert all(c == 1 for c in counts.values())


def test_refine_never_resamples_across_levels():
    # the cliff forces several refinement levels; shared grid points (the
    # domain endpoints reappear in the halves) must be measured only once
    fn, counts = counting_sample_fn(kinked)
    pieces = refine(Domain((32,), (256,)), fn, [(1,)], CHEAP)
    assert len(pieces) > 1
    assert counts and all(c == 1 for c in counts.values())


def test_refine_known_points_served_without_sampling():
    dom = Domain((32,), (256,))
    # pre-measure exactly the root grid the cheap config will request
    grid = grid_points(dom, [2 + CHEAP.oversampling], kind=CHEAP.grid,
                       round_to=CHEAP.round_to)
    known = {p: Stats.from_samples([LINEAR(p)]) for p in grid}
    fn, counts = counting_sample_fn(LINEAR)
    pieces = refine(dom, fn, [(1,)], CHEAP, known=known)
    # the linear curve converges at the root -> zero fresh measurements
    assert len(pieces) == 1
    assert counts == {}


# ----------------------------------------------------------------- budget --


def test_max_points_budget_stops_refinement():
    dom = Domain((32,), (256,))
    fn, counts = counting_sample_fn(kinked)
    budget = 3  # the cheap root grid is exactly 3 points
    config = GeneratorConfig(**{**CHEAP.__dict__, "max_points": budget})
    pieces = refine(dom, fn, [(1,)], config)
    # the root fit misses the cliff, but the budget forbids splitting
    assert len(pieces) == 1
    assert sum(counts.values()) == budget


def test_known_points_do_not_consume_budget():
    dom = Domain((32,), (256,))
    grid = grid_points(dom, [3], kind="cartesian", round_to=8)
    known = {p: Stats.from_samples([kinked(p)]) for p in grid}
    fn, counts = counting_sample_fn(kinked)
    config = GeneratorConfig(**{**CHEAP.__dict__, "max_points": 6})
    pieces = refine(dom, fn, [(1,)], config, known=known)
    # the root grid came for free, so the budget still allows splitting
    assert len(pieces) > 1
    assert 0 < sum(counts.values()) <= config.max_points + len(grid)


# ---------------------------------------------------------- determinism ----


def test_refine_point_ordering_deterministic():
    runs = []
    for _ in range(2):
        log = []
        pieces = refine(Domain((32,), (256,)), analytic_sample_fn(kinked, log),
                        [(1,)], CHEAP)
        runs.append((log, pieces))
    (log_a, pieces_a), (log_b, pieces_b) = runs
    assert log_a == log_b  # identical batches, in identical order
    assert len(pieces_a) == len(pieces_b)
    for pa, pb in zip(pieces_a, pieces_b):
        assert pa.domain == pb.domain
        for s in STATS:
            assert pa.polys[s].coeffs.tolist() == pb.polys[s].coeffs.tolist()


# --------------------------------------------------- Stats.from_samples ----


def test_stats_single_sample():
    s = Stats.from_samples([3.5e-6])
    assert s.min == s.med == s.max == s.mean == 3.5e-6
    assert s.std == 0.0


def test_stats_zero_variance():
    s = Stats.from_samples([2e-6] * 7)
    assert s.min == s.med == s.max == s.mean == 2e-6
    assert s.std == 0.0


def test_stats_empty_raises():
    with pytest.raises(ValueError):
        Stats.from_samples([])


def test_stats_even_count_median_interpolates():
    s = Stats.from_samples([1.0, 2.0, 3.0, 4.0])
    assert s.med == 2.5
    assert s.min == 1.0 and s.max == 4.0 and s.mean == 2.5
