"""Tests for repro.tc: batched kernels, suite deduplication, predictor."""

import numpy as np
import pytest

from repro.core.contractions import (ContractionSpec, cold_pool_size,
                                     execute, execute_reference,
                                     rank_contraction_algorithms)
from repro.core.contractions import generate_algorithms as loop_algorithms
from repro.core.sampler import STATS, Stats
from repro.core.selection import select_contraction_algorithm
from repro.tc import (COLD, WARM, ContractionPredictor, MicroBenchmarkSuite,
                      benchmark_key, canonical_equation, generate_algorithms,
                      is_batched_kernel, kernel_batch_dims,
                      rank_contraction_sweep, slice_call_bytes,
                      validate_algorithms)

RNG = np.random.default_rng(7)


def fake_measure(key, repetitions):
    """Deterministic synthetic timing, a pure function of the signature."""
    t = 1e-9 * key.call_bytes + 2e-6 + 5e-7 * key.classes.count("cold")
    stats = Stats(min=0.95 * t, med=t, max=1.1 * t, mean=1.01 * t,
                  std=0.02 * t)
    return stats, 1e-3


def fake_suite(repetitions=4):
    return MicroBenchmarkSuite(repetitions=repetitions,
                               measure_fn=fake_measure)


# ------------------------------------------------------- batched kernels --

def test_batched_algorithms_bij_bjk():
    spec = ContractionSpec.parse("bij,bjk->bik")
    algs = generate_algorithms(spec)
    loop_only = loop_algorithms(spec)
    batched = [a for a in algs if is_batched_kernel(a.kernel)]
    assert len(algs) == len(loop_only) + len(batched)
    assert batched
    # the batch index is no longer loop-only: every surviving batched
    # algorithm absorbs it into the kernel call
    for alg in batched:
        assert "b" in alg.kernel_dims, alg.name
        assert "b" not in alg.loop_order, alg.name
    # the whole contraction as ONE batched matmul
    one_call = [a for a in batched
                if a.kernel == "gemm_batch" and not a.loop_order]
    assert len(one_call) == 1
    assert one_call[0].kernel_equation() == "bij,bjk->bik"
    # every generated algorithm (loop-only and batched) matches the einsum
    validate_algorithms(spec, algs, dict(b=3, i=4, j=5, k=6), rng=RNG)


def test_batched_algorithms_three_index():
    # no shared batch index: the batched kernels absorb free output indices
    # (broadcasting the operand that lacks them) instead
    spec = ContractionSpec.parse("abc,cd->abd")
    algs = generate_algorithms(spec)
    batched = [a for a in algs if is_batched_kernel(a.kernel)]
    assert batched
    assert any(a.kernel == "gemm_batch" and not a.loop_order
               and a.kernel_equation() == "abc,cd->abd" for a in batched)
    validate_algorithms(spec, algs, dict(a=3, b=4, c=5, d=6), rng=RNG)


def test_batched_generation_deduplicates_equations():
    # a batched gemv over the full free range IS a gemm: candidates whose
    # kernel equation + loop order coincide with an existing algorithm are
    # dropped, so no two algorithms are operationally identical
    spec = ContractionSpec.parse("bij,bjk->bik")
    algs = generate_algorithms(spec)
    keys = [(a.kernel_equation(), a.loop_order) for a in algs]
    assert len(keys) == len(set(keys))


def test_loop_only_generation_unchanged():
    spec = ContractionSpec.parse("abc=ai,ibc")
    assert len(generate_algorithms(spec, include_batched=False)) == \
        len(loop_algorithms(spec)) == 36


# ------------------------------------------------------------ pool sizing --

def test_cold_pool_size_scales_with_repetitions():
    cache = 32 * 2 ** 20
    # tiny calls: the old hard cap of 8 would cycle back into cache for
    # repetitions > 8; now every call gets its own buffer
    assert cold_pool_size(20, 1024, cache) == 21
    assert cold_pool_size(5, 1024, cache) == 6
    # big calls: a few buffers already span the cache
    assert cold_pool_size(100, 16 * 2 ** 20, cache) == 3
    assert cold_pool_size(1, 64, cache) == 2   # floor


# ------------------------------------------------------------------ suite --

def _one_call_gemm_batch(spec):
    return next(a for a in generate_algorithms(spec)
                if a.kernel == "gemm_batch" and not a.loop_order)


def test_batched_kernel_classes_are_per_batch_slice():
    # strided batch access: the cache working set of a batched kernel is
    # ONE slice's operands.  At b=16, n=512 the stacked call is 48 MB
    # (beyond the 32 MB capacity) but a slice is 3 MB — the operands must
    # classify WARM, where whole-operand accounting said cold.
    spec = ContractionSpec.parse("bij,bjk->bik")
    alg = _one_call_gemm_batch(spec)
    assert kernel_batch_dims(alg) == ("b",)
    sizes = dict(b=16, i=512, j=512, k=512)
    assert slice_call_bytes(alg, sizes) == 4 * 3 * 512 * 512
    assert benchmark_key(alg, sizes).classes == (WARM, WARM)
    # a slice that itself overflows the cache stays cold
    big = dict(b=2, i=2048, j=2048, k=2048)
    assert benchmark_key(alg, big).classes == (COLD, COLD)
    # plain kernels are untouched by the slice rule
    plain = next(a for a in loop_algorithms(spec) if a.kernel == "gemm")
    assert kernel_batch_dims(plain) == ()
    a_sh, b_sh, o_sh = plain.kernel_shapes(sizes)
    assert slice_call_bytes(plain, sizes) == 4 * (
        np.prod(a_sh) + np.prod(b_sh) + np.prod(o_sh))


def test_benchmark_keys_canonicalize_equations():
    # einsum is invariant under index renaming: ij,jk->ik and ik,kl->il at
    # equal shapes are ONE measurement (what lets chain steps share a suite)
    assert canonical_equation("ik,kl->il") == "ab,bc->ac"
    assert canonical_equation("bij,bjk->bik") == "abc,acd->abd"
    a1 = loop_algorithms(ContractionSpec.parse("ij,jk->ik"))
    a2 = loop_algorithms(ContractionSpec.parse("ik,kl->il"))
    sizes1 = dict(i=8, j=8, k=8)
    sizes2 = dict(i=8, k=8, l=8)
    keys1 = {benchmark_key(a, sizes1) for a in a1}
    keys2 = {benchmark_key(a, sizes2) for a in a2}
    assert keys1 == keys2
    suite = fake_suite()
    for a in a1:
        suite.benchmark(a, sizes1)
    n = suite.n_benchmarks
    for a in a2:
        suite.benchmark(a, sizes2)
    assert suite.n_benchmarks == n     # nothing new to measure


def test_arrival_override_forces_cold():
    spec = ContractionSpec.parse("ij,jk->ik")
    sizes = dict(i=8, j=8, k=8)
    alg = loop_algorithms(spec)[0]
    warm = benchmark_key(alg, sizes)
    assert warm.classes == (WARM, WARM)
    forced = benchmark_key(alg, sizes, arrival={"A": COLD})
    assert forced.classes == (COLD, WARM)
    # WARM arrival defers to the access distance: no-op on a warm operand
    assert benchmark_key(alg, sizes, arrival={"A": WARM}) == warm
    # distinct keys => distinct measurements in the suite
    suite = fake_suite()
    mb_warm = suite.benchmark(alg, sizes)
    mb_cold = suite.benchmark(alg, sizes, arrival={"A": COLD})
    assert mb_warm.key != mb_cold.key
    assert suite.n_benchmarks == 2


def test_suite_deduplicates_and_accounts_cost():
    spec = ContractionSpec.parse("bij,bjk->bik")
    sizes = dict(b=4, i=16, j=16, k=16)
    suite = fake_suite()
    algs = generate_algorithms(spec)
    results = [suite.benchmark(a, sizes) for a in algs]
    assert suite.requests == len(algs)
    assert suite.n_benchmarks < len(algs)       # strict deduplication
    assert suite.cost_seconds > 0
    # shared: algorithms with equal keys got the identical result object
    by_key = {}
    for alg, mb in zip(algs, results):
        key = benchmark_key(alg, sizes)
        assert mb.key == key
        assert by_key.setdefault(key, mb) is mb


def test_oracle_measurements_do_not_inflate_suite_cost():
    spec = ContractionSpec.parse("bij,bjk->bik")
    sizes = dict(b=2, i=4, j=4, k=4)
    pred = ContractionPredictor(spec, sizes, suite=fake_suite())
    pred.rank()
    cost = pred.suite.cost_seconds
    pred.rank_oracle()               # validation must not change the metric
    assert pred.suite.cost_seconds == cost
    assert pred.suite.oracle_cost_seconds > 0
    assert pred.prediction_cost_fraction(1.0) == pytest.approx(cost)


def test_repetitions_suite_conflict_raises():
    suite = fake_suite(repetitions=4)
    with pytest.raises(ValueError):
        ContractionPredictor("bij,bjk->bik", dict(b=2, i=4, j=4, k=4),
                             suite=suite, repetitions=3)
    # matching or unspecified repetitions are fine
    ContractionPredictor("bij,bjk->bik", dict(b=2, i=4, j=4, k=4),
                         suite=suite, repetitions=4)
    ContractionPredictor("bij,bjk->bik", dict(b=2, i=4, j=4, k=4),
                         suite=suite)


def test_suite_real_measurement_tiny():
    # the real cache-aware path on a tiny kernel: sane stats + overhead
    spec = ContractionSpec.parse("ab=ai,ib")
    sizes = dict(a=4, b=4, i=4)
    suite = MicroBenchmarkSuite(repetitions=2)
    alg = loop_algorithms(spec)[0]
    mb = suite.benchmark(alg, sizes)
    assert mb.stats.med > 0 and mb.first > 0
    assert suite.cost_seconds >= mb.seconds > 0


# -------------------------------------------------------------- predictor --

def test_predictor_matches_oracle_and_backends():
    spec = ContractionSpec.parse("bij,bjk->bik")
    sizes = dict(b=4, i=16, j=16, k=16)
    pred = ContractionPredictor(spec, sizes, suite=fake_suite())
    ranked = pred.rank()
    assert pred.suite.n_benchmarks < len(pred.algorithms)
    assert any(is_batched_kernel(r.algorithm.kernel) for r in ranked)
    # un-deduplicated per-algorithm oracle: identical ordering and stats
    oracle = pred.rank_oracle()
    assert [r.name for r in ranked] == [r.name for r in oracle]
    for s in STATS:
        np.testing.assert_allclose(
            [getattr(r.runtime, s) for r in ranked],
            [getattr(r.runtime, s) for r in oracle], rtol=1e-12)
    # jax backend: same compiled batch, ~1e-8 agreement, same ordering
    np.testing.assert_allclose(pred.predict("numpy"), pred.predict("jax"),
                               rtol=1e-8)
    assert [r.name for r in pred.rank(backend="jax")] == \
        [r.name for r in ranked]


def test_predictor_includes_first_call_overhead_once():
    spec = ContractionSpec.parse("bij,bjk->bik")
    sizes = dict(b=4, i=8, j=8, k=8)
    pred = ContractionPredictor(spec, sizes, suite=fake_suite())
    for r in pred.rank():
        mb = pred.suite.results[r.benchmark]
        expect = mb.first + mb.stats.med * r.n_iterations
        np.testing.assert_allclose(r.runtime.med, expect, rtol=1e-12)
        # std of n uncorrelated calls adds in quadrature (Eq. 4.3)
        np.testing.assert_allclose(r.runtime.std,
                                   mb.stats.std * r.n_iterations ** 0.5,
                                   rtol=1e-12)


def test_predictor_reuses_trace_cache():
    pred = ContractionPredictor("bij,bjk->bik", dict(b=2, i=4, j=4, k=4),
                                suite=fake_suite())
    pred.rank()
    requests, benchmarks = pred.suite.requests, pred.suite.n_benchmarks
    hits = pred.cache.hits
    pred.rank()                      # compiled batch + measurements reused
    assert pred.cache.hits > hits
    assert pred.suite.requests == requests
    assert pred.suite.n_benchmarks == benchmarks


def test_rank_contraction_algorithms_batched_routes_through_tc():
    spec = ContractionSpec.parse("bij,bjk->bik")
    sizes = dict(b=2, i=4, j=4, k=4)
    suite = fake_suite()
    ranked = rank_contraction_algorithms(spec, sizes, suite=suite)
    assert suite.requests > 0        # went through the shared suite
    assert any(is_batched_kernel(a.kernel) for a, _ in ranked)
    ts = [t for _, t in ranked]
    assert ts == sorted(ts) and all(t > 0 for t in ts)
    with pytest.raises(ValueError):
        rank_contraction_algorithms(spec, sizes, batched=False,
                                    suite=suite)


def test_select_contraction_algorithm():
    suite = fake_suite()
    pred = ContractionPredictor("bij,bjk->bik", dict(b=2, i=4, j=4, k=4),
                                suite=suite)
    name = select_contraction_algorithm("bij,bjk->bik",
                                        dict(b=2, i=4, j=4, k=4),
                                        predictor=pred)
    assert name == pred.rank()[0].name
    # a predictor built for a different contraction (or sizes) must not
    # silently answer for the requested one
    with pytest.raises(ValueError):
        select_contraction_algorithm("ai,ib->ab", dict(a=4, i=4, b=4),
                                     predictor=pred)
    with pytest.raises(ValueError):
        select_contraction_algorithm("bij,bjk->bik",
                                     dict(b=3, i=4, j=4, k=4),
                                     predictor=pred)


def test_prediction_cost_fraction():
    pred = ContractionPredictor("bij,bjk->bik", dict(b=2, i=4, j=4, k=4),
                                suite=fake_suite())
    pred.prepare()
    frac = pred.prediction_cost_fraction(1.0)
    assert frac == pytest.approx(pred.suite.cost_seconds)


# ------------------------------------------------------------ size sweep --

SWEEP_GRID = [dict(b=2, i=8, j=8, k=8), dict(b=4, i=8, j=8, k=8),
              dict(b=8, i=8, j=8, k=8)]


def test_size_sweep_matches_independent_predictors():
    """Every size point of a shared-suite sweep ranks exactly like a
    fresh standalone predictor at that size (deterministic measure_fn:
    shared measurements are bit-interchangeable)."""
    sweep = rank_contraction_sweep("bij,bjk->bik", SWEEP_GRID,
                                   suite=fake_suite())
    assert len(sweep.rankings) == len(SWEEP_GRID)
    for sizes, ranking in zip(SWEEP_GRID, sweep.rankings):
        solo = ContractionPredictor("bij,bjk->bik", sizes,
                                    suite=fake_suite()).rank()
        assert [r.name for r in ranking] == [r.name for r in solo]
        assert [r.runtime for r in ranking] == [r.runtime for r in solo]
    assert [w.name for w in sweep.winners] == \
        [r[0].name for r in sweep.rankings]


def test_size_sweep_measures_only_new_keys():
    """One shared suite across the grid: identical keys are measured
    once, and sweeping a loop-only dimension (b with batched kernels
    excluded: no kernel shape contains b) measures NOTHING new."""
    suite = fake_suite()
    sweep = rank_contraction_sweep("bij,bjk->bik", SWEEP_GRID, suite=suite)
    assert suite.n_benchmarks < suite.requests
    assert sweep.n_benchmarks == suite.n_benchmarks
    loop_only = fake_suite()
    rank_contraction_sweep("bij,bjk->bik", SWEEP_GRID[:1], suite=loop_only,
                           include_batched=False)
    first_point = loop_only.counters()
    rank_contraction_sweep("bij,bjk->bik", SWEEP_GRID, suite=loop_only,
                           include_batched=False)
    assert loop_only.n_benchmarks == first_point["n_benchmarks"]
    assert loop_only.cost_seconds == first_point["cost_seconds"]


def test_size_sweep_core_entry_point_and_errors():
    per_point = rank_contraction_algorithms("bij,bjk->bik",
                                            sizes_grid=SWEEP_GRID,
                                            suite=fake_suite())
    assert len(per_point) == len(SWEEP_GRID)
    sweep = rank_contraction_sweep("bij,bjk->bik", SWEEP_GRID,
                                   suite=fake_suite())
    for got, ranking in zip(per_point, sweep.rankings):
        assert [a.name for a, _ in got] == [r.name for r in ranking]
        assert [t for _, t in got] == [r.runtime.med for r in ranking]
    # the shared TraceCache is reachable through the core entry too
    from repro.core.predict import TraceCache
    cache = TraceCache()
    rank_contraction_algorithms("bij,bjk->bik", sizes_grid=SWEEP_GRID,
                                suite=fake_suite(), cache=cache)
    assert cache.misses > 0        # compiled batches built on the shared cache
    with pytest.raises(ValueError, match="cache"):
        rank_contraction_algorithms("bij,bjk->bik", SWEEP_GRID[0],
                                    batched=False, cache=TraceCache())
    with pytest.raises(ValueError, match="not both"):
        rank_contraction_algorithms("bij,bjk->bik", SWEEP_GRID[0],
                                    sizes_grid=SWEEP_GRID)
    with pytest.raises(ValueError, match="batched"):
        rank_contraction_algorithms("bij,bjk->bik", sizes_grid=SWEEP_GRID,
                                    batched=False)
    with pytest.raises(ValueError, match="sizes"):
        rank_contraction_algorithms("bij,bjk->bik")
    with pytest.raises(ValueError, match="size point"):
        rank_contraction_sweep("bij,bjk->bik", [], suite=fake_suite())
    with pytest.raises(ValueError, match="repetitions"):
        rank_contraction_sweep("bij,bjk->bik", SWEEP_GRID,
                               suite=fake_suite(repetitions=4),
                               repetitions=3)


# ---------------------------------------------- batched execution (slow) --

@pytest.mark.slow
def test_batched_algorithms_larger_sizes():
    spec = ContractionSpec.parse("bij,bjk->bik")
    sizes = dict(b=6, i=24, j=20, k=16)
    algs = [a for a in generate_algorithms(spec)
            if is_batched_kernel(a.kernel)]
    A = RNG.standard_normal([sizes[i] for i in spec.a_idx]).astype(np.float32)
    B = RNG.standard_normal([sizes[i] for i in spec.b_idx]).astype(np.float32)
    ref = execute_reference(spec, A, B)
    for alg in algs:
        np.testing.assert_allclose(execute(alg, A, B, sizes), ref,
                                   rtol=2e-4, atol=2e-4, err_msg=alg.name)
