"""Equivalence + speedup tests for the batched prediction engine.

The scalar per-call path (`estimate` / `predict_runtime`) is the reference
oracle; the batched path (`estimate_batch` / `PredictionEngine`) must agree
to ~1e-10 across random models, out-of-domain (clamped) points and degenerate
zero-size calls — and beat the scalar block-size sweep by >= 10x.
"""

import time

import numpy as np
import pytest

from repro.core import (Domain, KernelCall, ModelSet, PerformanceModel,
                        Piece, PredictionEngine, compile_calls, fit_relative,
                        monomial_basis, optimize_algorithm_and_block_size,
                        optimize_block_size, predict_runtime, rank_algorithms,
                        select_algorithm)
from repro.core.sampler import STATS, Stats


def _rel_close(a, b, tol=1e-10):
    return abs(a - b) <= tol * max(1.0, abs(a), abs(b))


def _random_model(rng, kernel="k", ndim=2, n_pieces=3, cases=(("C",),)):
    """A random piecewise model fitted through the real relative-LSQ path."""
    m = PerformanceModel(kernel=kernel)
    for case in cases:
        edges = np.sort(rng.integers(2, 64, size=n_pieces - 1)) * 8
        bounds = [8] + [int(e) + 8 for e in edges] + [600]
        for i in range(n_pieces):
            dom = Domain(tuple([bounds[i]] + [8] * (ndim - 1)),
                         tuple([bounds[i + 1]] + [512] * (ndim - 1)))
            axes = [np.linspace(l, h, 5) for l, h in zip(dom.lo, dom.hi)]
            pts = np.stack(np.meshgrid(*axes, indexing="ij"),
                           axis=-1).reshape(-1, ndim)
            coef = rng.uniform(1e-10, 1e-8)
            const = rng.uniform(1e-7, 1e-5)
            vals = coef * np.prod(pts, axis=1) * pts[:, 0] + const
            basis = monomial_basis([tuple([2] + [1] * (ndim - 1))])
            polys = {s: fit_relative(pts, vals * f, basis)
                     for s, f in (("min", 0.95), ("med", 1.0), ("max", 1.1),
                                  ("mean", 1.01))}
            # std on a different (constant) basis: exercises stacking groups
            polys["std"] = fit_relative(pts, np.full(len(pts), const * 0.05),
                                        [tuple([0] * ndim)])
            m.add_piece(case, Piece(domain=dom, polys=polys))
    return m


@pytest.mark.parametrize("ndim", [1, 2, 3])
def test_estimate_batch_matches_scalar_random_models(ndim):
    rng = np.random.default_rng(ndim)
    model = _random_model(rng, ndim=ndim)
    # in-domain, out-of-domain (clamped both sides) and boundary points
    pts = rng.integers(-64, 900, size=(300, ndim))
    batch = model.estimate_batch(("C",), pts.astype(np.float64))
    for i, p in enumerate(pts):
        scalar = model.estimate(("C",), tuple(int(x) for x in p))
        for j, s in enumerate(STATS):
            assert _rel_close(batch[i, j], scalar[s]), (p, s)


def test_degenerate_zero_size_rows_are_zero():
    rng = np.random.default_rng(7)
    model = _random_model(rng, ndim=2)
    pts = np.array([[0, 64], [64, 0], [-8, 128], [64, 64]], dtype=np.float64)
    batch = model.estimate_batch(("C",), pts)
    assert np.all(batch[:3] == 0.0)
    assert np.all(batch[3] > 0.0)


def test_degenerate_calls_need_no_model_like_scalar_path():
    """All-degenerate calls to an unmodeled case estimate to zero without a
    case lookup (scalar parity); any live call to it still raises KeyError."""
    rng = np.random.default_rng(13)
    model = _random_model(rng, ndim=2)
    ms = ModelSet({"k": model})
    degen = [KernelCall("k", ("MISSING",), (0, 64))]
    ref = predict_runtime(degen, ms)
    got = PredictionEngine(ms).predict_stats([degen])[0]
    assert got == ref == Stats(0, 0, 0, 0, 0)
    with pytest.raises(KeyError):
        PredictionEngine(ms).predict_batch(
            [degen + [KernelCall("k", ("MISSING",), (64, 64))]])


def test_estimate_batch_no_extrapolate_raises():
    rng = np.random.default_rng(11)
    model = _random_model(rng, ndim=2)
    cm = model.cases[("C",)]
    with pytest.raises(KeyError):
        cm.estimate_batch(np.array([[10_000.0, 10_000.0]]),
                          extrapolate=False)


def _tracer_for(kernel, case=("C",), calls_per_iter=3):
    """Cheap synthetic blocked-algorithm tracer: n/b iterations of shrinking
    panels, mimicking a Cholesky-style call sequence with degenerate tails."""
    def tracer(n, b):
        out = []
        for i in range(max(1, n // b)):
            rest = n - (i + 1) * b  # hits 0 on the last iteration: Example 4.1
            for _ in range(calls_per_iter):
                out.append(KernelCall(kernel, case, (b, max(rest, 0))))
        return out
    return tracer


def test_prediction_engine_matches_predict_runtime():
    rng = np.random.default_rng(3)
    ms = ModelSet({"k": _random_model(rng, "k"),
                   "k2": _random_model(rng, "k2")})
    engine = PredictionEngine(ms)
    seqs = [_tracer_for("k")(n, b) + _tracer_for("k2")(n, b)
            for n, b in ((512, 32), (512, 8), (96, 96), (256, 40))]
    batch = engine.predict_stats(seqs)
    for seq, got in zip(seqs, batch):
        ref = predict_runtime(seq, ms)
        for s in STATS:
            assert _rel_close(getattr(got, s), getattr(ref, s)), s


def test_compile_calls_groups_and_counts():
    seqs = [[KernelCall("a", ("X",), (8, 8)), KernelCall("b", ("Y",), (4,))],
            [KernelCall("a", ("X",), (16, 16))]]
    compiled = compile_calls(seqs)
    assert compiled.n_configs == 2
    assert compiled.n_calls == 3
    by_key = {(g.kernel, g.case): g for g in compiled.groups}
    assert set(by_key) == {("a", ("X",)), ("b", ("Y",))}
    np.testing.assert_array_equal(by_key[("a", ("X",))].config, [0, 1])


def test_trace_engine_compile_roundtrip():
    from repro.dla import TraceEngine, blocked
    from repro.dla.engine import Matrix

    eng = TraceEngine()
    blocked.potrf(eng, Matrix("A", 128, 128), 128, 32, variant=3)
    compiled = eng.compile()
    assert compiled.n_configs == 1
    assert compiled.n_calls == len(eng.calls)
    assert {g.kernel for g in compiled.groups} <= \
        {"potf2", "trsm", "syrk", "gemm"}


def test_rank_algorithms_batched_matches_scalar():
    rng = np.random.default_rng(5)
    ms = ModelSet({"fast": _random_model(rng, "fast"),
                   "slow": _random_model(rng, "slow")})
    tracers = {"a": _tracer_for("slow"), "b": _tracer_for("fast"),
               "c": _tracer_for("slow", calls_per_iter=5)}
    for stat in ("med", "mean"):
        got = rank_algorithms(tracers, ms, 512, 64, stat=stat)
        ref = rank_algorithms(tracers, ms, 512, 64, stat=stat, batched=False)
        assert [r.name for r in got] == [r.name for r in ref]
        for g, r in zip(got, ref):
            assert _rel_close(getattr(g.runtime, stat),
                              getattr(r.runtime, stat))


def test_select_algorithm_matches_scalar_oracle():
    """select_algorithm's batched winner equals the scalar-path oracle's
    (batched=False) and both equal rank_algorithms' top entry."""
    rng = np.random.default_rng(7)
    ms = ModelSet({"fast": _random_model(rng, "fast"),
                   "slow": _random_model(rng, "slow")})
    tracers = {"a": _tracer_for("slow"), "b": _tracer_for("fast"),
               "c": _tracer_for("slow", calls_per_iter=5)}
    got = select_algorithm(tracers, ms, 512, 64)
    ref = select_algorithm(tracers, ms, 512, 64, batched=False)
    assert got == ref
    assert got == rank_algorithms(tracers, ms, 512, 64)[0].name


def test_block_size_sweep_identical_and_10x_faster():
    """Acceptance: >= 64-candidate sweep, identical argmin, stats to 1e-10,
    >= 10x speedup over the scalar per-call loop."""
    rng = np.random.default_rng(17)
    ms = ModelSet({"k": _random_model(rng, "k", n_pieces=4)})
    tracer = _tracer_for("k")
    n = 1024
    candidates = [8 * (i + 1) for i in range(64)]

    b_batched, prof_batched = optimize_block_size(tracer, ms, n, candidates)
    b_scalar, prof_scalar = optimize_block_size(tracer, ms, n, candidates,
                                                batched=False)
    assert b_batched == b_scalar
    assert set(prof_batched) == set(prof_scalar)
    for b in candidates:
        assert _rel_close(prof_batched[b], prof_scalar[b])

    def best_of(fn, reps=3):
        fn()  # warm-up: BLAS/allocator init must not skew the comparison
        best = float("inf")
        for _ in range(reps):
            t0 = time.perf_counter()
            fn()
            best = min(best, time.perf_counter() - t0)
        return best

    t_scalar = best_of(lambda: optimize_block_size(tracer, ms, n, candidates,
                                                   batched=False))
    t_batched = best_of(lambda: optimize_block_size(tracer, ms, n,
                                                    candidates))
    assert t_scalar / t_batched >= 10.0, (t_scalar, t_batched)


def test_joint_optimization_matches_scalar():
    rng = np.random.default_rng(23)
    ms = ModelSet({"k": _random_model(rng, "k"),
                   "k2": _random_model(rng, "k2")})
    tracers = {"a": _tracer_for("k"), "b": _tracer_for("k2")}
    candidates = [16, 32, 64, 128]
    got = optimize_algorithm_and_block_size(tracers, ms, 512, candidates)
    ref = optimize_algorithm_and_block_size(tracers, ms, 512, candidates,
                                            batched=False)
    assert got[:2] == ref[:2]
    assert _rel_close(got[2], ref[2])


def test_rank_traced_configs_matches_rank_algorithms():
    """The perf-layer config-ranking bridge agrees with core selection."""
    from repro.perf import rank_traced_configs

    rng = np.random.default_rng(31)
    ms = ModelSet({"k": _random_model(rng, "k"),
                   "k2": _random_model(rng, "k2")})
    tracers = {"a": _tracer_for("k"), "b": _tracer_for("k2")}
    got = rank_traced_configs(tracers, ms, 512, 64)
    ref = rank_algorithms(tracers, ms, 512, 64)
    assert [r.name for r in got] == [r.name for r in ref]
    for g, r in zip(got, ref):
        assert _rel_close(g.predicted_s, r.runtime.med)
        assert _rel_close(g.runtime.std, r.runtime.std)


def test_grid_prediction_shape_and_values():
    rng = np.random.default_rng(29)
    ms = ModelSet({"k": _random_model(rng, "k")})
    engine = PredictionEngine(ms)
    tracer = _tracer_for("k")
    ns, bs = [128, 256], [16, 32, 64]
    grid = engine.grid(tracer, ns, bs)
    assert grid.shape == (len(ns), len(bs), len(STATS))
    for i, n in enumerate(ns):
        for j, b in enumerate(bs):
            ref = predict_runtime(tracer(n, b), ms)
            for k, s in enumerate(STATS):
                assert _rel_close(grid[i, j, k], getattr(ref, s)), (n, b, s)
