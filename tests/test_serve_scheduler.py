"""Tests for repro.serve.scheduler: decision logic on scripted step-cost
models, FIFO equivalence with the pre-refactor engine loop, interleaved
prefill correctness, stats synchronization, and the tick-overhead budget."""

import time

import jax
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.models import init_params
from repro.serve import (FifoScheduler, ModelGuidedScheduler, Plan, Request,
                         ServeEngine, StepCostModel)
from repro.tc.suite import COLD, WARM


def scripted_model(slots, *, warm=1.0, cold=None, per_occ=None):
    """A StepCostModel with scripted (not measured) tick costs."""
    cold = warm if cold is None else cold
    tick_s = {}
    for occ in range(1, slots + 1):
        w = per_occ[occ - 1] if per_occ is not None else warm
        tick_s[(occ, WARM)] = w
        tick_s[(occ, COLD)] = cold if per_occ is None else w
    return StepCostModel(tick_s=tick_s, slots=slots)


class FakeEngine:
    """Duck-typed engine state for pure decision tests (no jax)."""

    def __init__(self, slots):
        self.slots = slots
        self.active = {}
        self.prefilling = {}
        self.prefill_done = {}

    def free_slots(self):
        return [s for s in range(self.slots)
                if s not in self.active and s not in self.prefilling]


def req(uid, prompt_len=4, max_new=8):
    return Request(uid=uid,
                   prompt=np.ones(prompt_len, dtype=np.int32),
                   max_new_tokens=max_new)


# ----------------------------------------------------- decision logic --

def test_plan_trivial_cases():
    sched = ModelGuidedScheduler(scripted_model(2))
    eng = FakeEngine(2)
    assert sched.plan(eng, []) == Plan()          # nothing waiting
    eng.active = {0: req(0), 1: req(1)}
    assert sched.plan(eng, [req(2)]) == Plan()    # no free slot


def test_idle_engine_admits_immediately():
    # ties between defer and admit must admit: an idle engine with one
    # waiting request serves it NOW, not after max_defer passes
    sched = ModelGuidedScheduler(scripted_model(2))
    eng = FakeEngine(2)
    r = req(0)
    plan = sched.plan(eng, [r])
    assert plan.admit_interleaved == (r,)


def test_shortest_job_admitted_first():
    # one free slot, two waiting: admitting the shorter request first
    # minimizes the predicted sum of completion times
    sched = ModelGuidedScheduler(scripted_model(2))
    eng = FakeEngine(2)
    eng.active = {0: req(9, max_new=50)}
    long_req = req(1, prompt_len=40, max_new=16)
    short_req = req(2, prompt_len=4, max_new=4)
    plan = sched.plan(eng, [long_req, short_req])
    assert plan.admit_interleaved == (short_req,)


def test_defer_when_occupancy_is_expensive():
    # scripted occupancy-dependent costs: adding a lane makes every tick
    # 50x more expensive, so deferring wins while a lane is busy
    sched = ModelGuidedScheduler(
        scripted_model(2, per_occ=[1.0, 50.0]), max_defer=3)
    eng = FakeEngine(2)
    eng.active = {0: req(9, max_new=3)}
    r = req(1, prompt_len=2, max_new=2)
    assert sched.plan(eng, [r]) == Plan()


def test_force_admit_bounds_starvation():
    sched = ModelGuidedScheduler(
        scripted_model(2, per_occ=[1.0, 50.0]), max_defer=3)
    eng = FakeEngine(2)
    eng.active = {0: req(9, max_new=3)}
    r = req(1, prompt_len=2, max_new=2)
    for _ in range(3):
        assert sched.plan(eng, [r]) == Plan()
    plan = sched.plan(eng, [r])
    assert plan.admit_interleaved == (r,)


def test_model_tick_cost_clamps_occupancy():
    model = scripted_model(2, warm=1.0, cold=3.0)
    assert model.tick_cost(0) == model.tick_cost(1)
    assert model.tick_cost(99) == model.tick_cost(2)
    assert model.tick_cost(1, COLD) == 3.0
    assert model.service_ticks(req(0, prompt_len=5, max_new=7)) == 12


def test_tick_overhead_stays_sub_ms():
    # the regression the ISSUE pins: planning is dict lookups plus a
    # bounded rollout — it must stay well under a millisecond per tick
    sched = ModelGuidedScheduler(scripted_model(4))
    eng = FakeEngine(4)
    eng.active = {0: req(90, max_new=32), 1: req(91, max_new=7)}
    waiting = [req(i, prompt_len=4 + 11 * (i % 4), max_new=8)
               for i in range(8)]
    sched.plan(eng, waiting)  # warm any lazy setup
    t0 = time.perf_counter()
    n = 200
    for _ in range(n):
        sched.plan(eng, waiting)
    per_tick_ms = 1e3 * (time.perf_counter() - t0) / n
    assert per_tick_ms < 1.0, f"tick overhead {per_tick_ms:.3f} ms"


# ------------------------------------------------- engine equivalence --

CFG = reduced(get_config("deepseek-7b"), n_layers=2, d_model=64, d_ff=128,
              vocab=128)


@pytest.fixture(scope="module")
def params():
    return init_params(CFG, jax.random.PRNGKey(0))


def _engine(params):
    return ServeEngine(CFG, params, batch_slots=3, ctx_len=64)


def _trace(n=5):
    rng = np.random.default_rng(3)
    return [Request(uid=i,
                    prompt=rng.integers(1, CFG.vocab,
                                        size=int(rng.integers(2, 9))
                                        ).astype(np.int32),
                    max_new_tokens=int(rng.integers(2, 6)))
            for i in range(n)]


def test_fifo_policy_matches_legacy_loop(params):
    fifo = _engine(params)
    reqs = _trace()
    fifo.run(reqs, scheduler=FifoScheduler())

    # the pre-refactor loop, driven by hand through the step hooks, on an
    # identical trace — same admissions, same steps, same tokens
    legacy = _engine(params)
    reqs2 = _trace()
    queue = list(reqs2)
    while queue or legacy.active:
        while queue and legacy.add_request(queue[0]):
            queue.pop(0)
        legacy.step()

    assert {r.uid: r.out_tokens for r in reqs} == \
        {r.uid: r.out_tokens for r in reqs2}
    assert all(r.done for r in reqs)


def test_interleaved_prefill_matches_blocking_for_lone_request(params):
    # a lone request prefilled one token per fused step produces exactly
    # the tokens the blocking prefill produces
    blocking = _engine(params)
    r1 = _trace(1)[0]
    blocking.add_request(r1)
    while blocking.active:
        blocking.step()

    interleaved = _engine(params)
    r2 = _trace(1)[0]
    interleaved.begin_prefill(r2)
    while interleaved.active or interleaved.prefilling:
        interleaved.advance()

    assert r2.out_tokens == r1.out_tokens
    assert r2.done


def test_begin_prefill_rejects_busy_slot(params):
    eng = _engine(params)
    r1, r2 = _trace(2)
    slot = eng.begin_prefill(r1)
    with pytest.raises(ValueError, match="not free"):
        eng.begin_prefill(r2, slot=slot)
    eng.prefilling.clear()
    eng.prefill_done.clear()
    eng.active = {s: r1 for s in range(eng.slots)}
    with pytest.raises(ValueError, match="free slot"):
        eng.begin_prefill(r2)


def test_stats_synchronized_and_latencies_tracked(params):
    eng = _engine(params)
    reqs = _trace(4)
    stats = eng.run(reqs, scheduler=FifoScheduler())
    assert stats.prefill_s > 0.0
    assert stats.decode_s > 0.0
    assert stats.ticks > 0
    assert len(stats.latencies_s) == len(reqs)
    assert all(lat > 0 for lat in stats.latencies_s)
    assert stats.latency_ms(99) >= stats.latency_ms(50) > 0.0
    for r in reqs:
        assert r.latency_s is not None and r.latency_s > 0


def test_guided_run_serves_everything(params):
    eng = _engine(params)
    sched = ModelGuidedScheduler(
        scripted_model(3, warm=1e-3, cold=2e-3))
    reqs = _trace(6)
    stats = eng.run(reqs, scheduler=sched)
    assert all(r.done for r in reqs)
    assert all(len(r.out_tokens) == r.max_new_tokens for r in reqs)
    assert stats.tick_overhead_ms < 1.0
