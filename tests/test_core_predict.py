"""Unit tests: model structure, prediction statistics, selection (§4.1/4.5)."""

import math

import numpy as np
import pytest

from repro.core import (Domain, KernelCall, ModelSet, PerformanceModel,
                        Piece, Stats, absolute_relative_error, fit_relative,
                        monomial_basis, optimize_block_size,
                        performance_yield, predict_efficiency,
                        predict_performance, predict_runtime, rank_algorithms,
                        relative_error)


def _make_model(kernel="k", coef=1e-9, const=1e-6):
    pts = np.array([[x, y] for x in (8, 64, 128, 256, 512)
                    for y in (8, 64, 128, 256, 512)], dtype=float)
    vals = coef * pts[:, 0] ** 2 * pts[:, 1] + const
    basis = monomial_basis([(2, 1)])
    polys = {s: fit_relative(pts, vals, basis)
             for s in ("min", "med", "max", "mean")}
    std = fit_relative(pts, np.full(len(pts), const * 0.01), [(0, 0)])
    polys["std"] = std
    m = PerformanceModel(kernel=kernel)
    m.add_piece(("C",), Piece(Domain((8, 8), (512, 512)), polys))
    return m


def test_estimate_and_degenerate():
    ms = ModelSet({"k": _make_model()})
    est = ms.estimate("k", ("C",), (128, 128))
    true = 1e-9 * 128 ** 2 * 128 + 1e-6
    assert est["med"] == pytest.approx(true, rel=1e-6)
    # zero-size call estimates 0 (Example 4.1)
    assert ms.estimate("k", ("C",), (0, 128))["med"] == 0.0


def test_prediction_statistics_propagate():
    ms = ModelSet({"k": _make_model()})
    calls = [KernelCall("k", ("C",), (128, 128))] * 4
    rt = predict_runtime(calls, ms)
    one = ms.estimate("k", ("C",), (128, 128))
    assert rt.med == pytest.approx(4 * one["med"], rel=1e-9)
    # std adds in quadrature (Eq 4.3)
    assert rt.std == pytest.approx(2 * one["std"], rel=1e-9)


def test_performance_and_efficiency():
    rt = Stats(min=1.0, med=2.0, max=4.0, mean=2.0, std=0.1)
    perf = predict_performance(rt, cost_flops=8.0)
    assert perf["max"] == pytest.approx(8.0)   # cost / t_min
    assert perf["min"] == pytest.approx(2.0)   # cost / t_max
    eff = predict_efficiency(perf, peak_flops=8.0)
    assert eff["max"] == pytest.approx(1.0)


def test_ranking_and_block_size():
    ms = ModelSet({"fast": _make_model("fast", coef=1e-9),
                   "slow": _make_model("slow", coef=3e-9)})

    def tracer_for(kernel):
        def tracer(n, b):
            return [KernelCall(kernel, ("C",), (b, n))
                    for _ in range(max(1, n // b))]
        return tracer

    ranked = rank_algorithms({"a_slow": tracer_for("slow"),
                              "a_fast": tracer_for("fast")}, ms, 512, 64)
    assert ranked[0].name == "a_fast"

    # block-size optimization: model has n^2 b cost + const per call =>
    # larger b fewer calls but b^2 cost; optimum interior or boundary
    b_pred, profile = optimize_block_size(tracer_for("fast"), ms, 512,
                                          [8, 16, 32, 64, 128, 256])
    assert b_pred == min(profile, key=profile.get)

    measured = {b: profile[b] * 1.02 for b in profile}  # consistent meas.
    b_opt, yld = performance_yield(measured, b_pred)
    assert yld == pytest.approx(1.0)


def test_relative_error_zero_measurement_is_nan():
    # degenerate/empty measurements must not crash error sweeps (§4.2)
    assert math.isnan(relative_error(1.0, 0.0))
    assert math.isnan(relative_error(0.0, 0.0))
    assert math.isnan(absolute_relative_error(1.0, 0.0))
    assert relative_error(2.0, 1.0) == pytest.approx(1.0)
    assert absolute_relative_error(0.5, 1.0) == pytest.approx(0.5)


def test_model_set_missing_case():
    ms = ModelSet({"k": _make_model()})
    with pytest.raises(KeyError):
        ms.estimate("k", ("MISSING",), (64, 64))
