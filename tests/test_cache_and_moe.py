"""Tests: Ch.5 cache-study utilities + MoE implementation equivalence +
prefill/decode cache handoff."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.cachestudy import (calibrate_alpha, combine_estimates,
                                   measure_cache_effects)
from repro.configs import get_config, reduced
from repro.models import init_params
from repro.models.moe import init_moe, moe_forward, moe_forward_einsum
from repro.models.prefill import prefill
from repro.models.transformer import decode_step


@pytest.mark.slow
def test_cache_study_measures_both_modes():
    import functools

    fn = jax.jit(lambda a, b: a @ b)
    rng = np.random.default_rng(0)
    bufs = [(jnp.asarray(rng.standard_normal((64, 64)), jnp.float32),
             jnp.asarray(rng.standard_normal((64, 64)), jnp.float32))
            for _ in range(4)]

    def make_call_at(i):
        a, b = bufs[i % 4]
        return lambda: fn(a, b).block_until_ready()

    t = measure_cache_effects(make_call_at, repetitions=4, n_buffers=4)
    assert t.warm.med > 0 and t.cold.med > 0


def test_alpha_calibration_bounds():
    assert calibrate_alpha(1.0, 2.0, 1.5) == pytest.approx(0.5)
    assert calibrate_alpha(1.0, 2.0, 0.5) == 0.0      # clipped
    assert calibrate_alpha(1.0, 2.0, 3.0) == 1.0      # clipped
    assert combine_estimates(1.0, 2.0, 0.25) == pytest.approx(1.25)


@pytest.mark.parametrize("arch", ["grok-1-314b", "arctic-480b",
                                  "jamba-v0.1-52b"])
def test_moe_scatter_matches_einsum(arch):
    cfg = reduced(get_config(arch))
    key = jax.random.PRNGKey(1)
    p = init_moe(cfg, key, jnp.float32)
    x = jax.random.normal(key, (2, 16, cfg.d_model))
    # force both paths regardless of the arch's configured default
    from dataclasses import replace
    a = moe_forward(replace(cfg, moe_impl="scatter"), p, x)
    b = moe_forward_einsum(cfg, p, x)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=2e-5, atol=2e-5)


def test_moe_data_shards_reshape_equivalence():
    from dataclasses import replace

    cfg = reduced(get_config("grok-1-314b"))
    key = jax.random.PRNGKey(2)
    p = init_moe(cfg, key, jnp.float32)
    x = jax.random.normal(key, (4, 16, cfg.d_model))
    base = moe_forward(replace(cfg, moe_impl="scatter",
                               moe_data_shards=1), p, x)
    shard4 = moe_forward(replace(cfg, moe_impl="scatter",
                                 moe_data_shards=4), p, x)
    # per-shard capacity changes drop behaviour only when overflowing;
    # smoke capacity is lossless, so results agree
    np.testing.assert_allclose(np.asarray(base), np.asarray(shard4),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.slow
@pytest.mark.parametrize("arch", ["deepseek-7b", "gemma2-27b",
                                  "mamba2-2.7b"])
def test_prefill_then_decode_continues(arch):
    """Prefill caches must seed decode to match token-by-token replay."""
    cfg = reduced(get_config(arch))
    key = jax.random.PRNGKey(3)
    params = init_params(cfg, key, dtype=jnp.float32)
    b, s = 2, 16
    toks = jax.random.randint(key, (b, s), 0, cfg.vocab)
    logits_p, caches = prefill(cfg, params, toks)
    assert logits_p.shape == (b, 1, cfg.vocab)
    # one decode step continuing at position s
    nxt = jax.random.randint(key, (b, 1), 0, cfg.vocab)
    # prefill caches are sized to the prompt; decode expects ring caches —
    # re-embed into fresh decode caches via replay for the reference
    from repro.models import forward, init_decode_state

    caches2 = init_decode_state(cfg, b, s + 8, dtype=jnp.float32)
    for i in range(s):
        last, caches2 = decode_step(cfg, params, caches2, toks[:, i:i + 1],
                                    jnp.asarray(i, dtype=jnp.int32))
    # prefill last-token logits equal replayed last logits
    np.testing.assert_allclose(np.asarray(logits_p[:, 0]),
                               np.asarray(last[:, 0]),
                               rtol=2e-3, atol=2e-3)
    lg, _ = decode_step(cfg, params, caches2, nxt,
                        jnp.asarray(s, dtype=jnp.int32))
    assert lg.shape == (b, 1, cfg.vocab)
    assert not bool(jnp.isnan(lg).any())
