"""Training-substrate tests: loop, checkpointing, data, fault tolerance."""

import json
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.train import checkpoint as ck
from repro.train.compression import (compress_tree, decompress_tree,
                                     init_error)
from repro.train.data import DataConfig, batch_at
from repro.train.optimizer import AdamW, apply_updates
from repro.train.train_loop import TrainConfig, train


def _cfg():
    return reduced(get_config("deepseek-7b"), n_layers=2, d_model=64,
                   d_ff=128, vocab=256)


def test_data_determinism_and_skip_ahead():
    dc = DataConfig(vocab=256, seq_len=32, global_batch=4, seed=7)
    b1 = batch_at(dc, 5)
    b2 = batch_at(dc, 5)
    np.testing.assert_array_equal(b1["inputs"], b2["inputs"])
    b3 = batch_at(dc, 6)
    assert not np.array_equal(b1["inputs"], b3["inputs"])
    # labels are next-token shifted inputs
    np.testing.assert_array_equal(b1["inputs"][:, 1:], b1["labels"][:, :-1])


def test_loss_decreases():
    cfg = _cfg()
    dc = DataConfig(vocab=cfg.vocab, seq_len=32, global_batch=4)
    tc = TrainConfig(steps=30)
    _, _, report = train(cfg, dc, tc)
    assert len(report.losses) == 30
    assert report.losses[-1] < report.losses[0]
    assert not report.skipped_nan_steps


def test_checkpoint_roundtrip_and_crc(tmp_path):
    tree = {"a": jnp.arange(10, dtype=jnp.float32),
            "b": {"c": jnp.ones((3, 4))}}
    ck.save(str(tmp_path), 3, tree)
    restored, step = ck.restore(str(tmp_path), tree)
    assert step == 3
    np.testing.assert_array_equal(np.asarray(restored["a"]),
                                  np.asarray(tree["a"]))
    # corrupt a leaf -> restore must fail CRC
    victim = next(tmp_path.glob("step_*/arr_00000.npy"))
    data = bytearray(victim.read_bytes())
    data[-1] ^= 0xFF
    victim.write_bytes(bytes(data))
    assert ck.latest_step(str(tmp_path)) is None
    with pytest.raises(Exception):
        ck.restore(str(tmp_path), tree, step=3)


def test_checkpoint_keep_n_and_latest(tmp_path):
    tree = {"a": jnp.zeros(4)}
    for s in (1, 2, 3, 4, 5):
        ck.save(str(tmp_path), s, tree, keep=2)
    dirs = sorted(p.name for p in tmp_path.glob("step_*"))
    assert len(dirs) == 2
    assert ck.latest_step(str(tmp_path)) == 5


def test_resume_after_interrupt(tmp_path):
    cfg = _cfg()
    dc = DataConfig(vocab=cfg.vocab, seq_len=32, global_batch=4)
    # phase 1: 10 steps with checkpointing every 5
    tc1 = TrainConfig(steps=10, ckpt_dir=str(tmp_path), ckpt_every=5)
    _, _, rep1 = train(cfg, dc, tc1)
    assert ck.latest_step(str(tmp_path)) == 9
    # phase 2 (simulated restart after failure): resumes from step 9
    tc2 = TrainConfig(steps=20, ckpt_dir=str(tmp_path), ckpt_every=5)
    _, _, rep2 = train(cfg, dc, tc2)
    assert rep2.resumed_from == 9
    assert len(rep2.losses) == 10          # only the remaining steps


def test_async_checkpointer(tmp_path):
    tree = {"w": jnp.arange(100.0)}
    ac = ck.AsyncCheckpointer(str(tmp_path))
    ac.save_async(1, tree)
    ac.wait()
    assert ck.latest_step(str(tmp_path)) == 1


def test_compression_roundtrip_error_feedback():
    params = {"w": jnp.ones((64, 33)), "b": jnp.zeros((7,))}
    grads = jax.tree_util.tree_map(
        lambda p: jnp.asarray(np.random.default_rng(0).standard_normal(
            p.shape), jnp.float32), params)
    err = init_error(params)
    q, err1 = compress_tree(grads, err)
    deq = decompress_tree(q, grads)
    rel = (jnp.linalg.norm(deq["w"] - grads["w"]) /
           jnp.linalg.norm(grads["w"]))
    assert float(rel) < 0.02                # int8 per-chunk quantization
    # error feedback: residual equals exactly what was lost
    np.testing.assert_allclose(np.asarray(err1["w"]),
                               np.asarray(grads["w"] - deq["w"]),
                               atol=1e-6)


def test_nan_circuit_breaker():
    cfg = _cfg()
    dc = DataConfig(vocab=cfg.vocab, seq_len=32, global_batch=4)
    opt = AdamW(lr=1e-3)

    calls = {"n": 0}

    from repro.launch.steps import make_train_step
    inner = jax.jit(make_train_step(cfg, opt))

    def poisoned(params, opt_state, batch):
        calls["n"] += 1
        p, o, loss = inner(params, opt_state, batch)
        if calls["n"] == 3:
            return p, o, jnp.asarray(float("nan"))
        return p, o, loss

    tc = TrainConfig(steps=6)
    _, _, report = train(cfg, dc, tc, opt=opt, train_step=poisoned)
    assert report.skipped_nan_steps == [2]
    assert len(report.losses) == 5
