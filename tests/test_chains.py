"""Tests for repro.tc.chains: path enumeration, cache-state propagation,
chain composition, and the selection entry points."""

import numpy as np
import pytest

from repro.core.sampler import STATS, Stats
from repro.core.selection import rank_einsum_paths, select_einsum_path
from repro.tc import (COLD, WARM, ChainPredictor, ChainSpec,
                      MicroBenchmarkSuite, execute_chain,
                      execute_chain_reference, execute_path_reference,
                      rank_einsum_sweep, validate_paths)

RNG = np.random.default_rng(11)


def fake_measure(key, repetitions):
    """Deterministic synthetic timing, a pure function of the signature."""
    t = 1e-9 * key.call_bytes + 2e-6 + 5e-7 * key.classes.count("cold")
    stats = Stats(min=0.95 * t, med=t, max=1.1 * t, mean=1.01 * t,
                  std=0.02 * t)
    return stats, 1e-3


def fake_suite(repetitions=4, **kw):
    return MicroBenchmarkSuite(repetitions=repetitions,
                               measure_fn=fake_measure, **kw)


# ------------------------------------------------------------ spec/paths --

def test_parse_and_validation():
    c = ChainSpec.parse("ij,jk,kl->il")
    assert c.operands == ("ij", "jk", "kl") and c.out_idx == "il"
    assert c.einsum_expr() == "ij,jk,kl->il"
    assert ChainSpec.parse(c) is c
    with pytest.raises(ValueError):   # too many operands
        ChainSpec.parse("ab,bc,cd,de,ef,fg->ag")
    with pytest.raises(ValueError):   # diagonal within an operand
        ChainSpec.parse("ii,ij->j")
    with pytest.raises(ValueError):   # output index in no operand
        ChainSpec.parse("ij,jk->iz")
    with pytest.raises(ValueError):   # private index = sum reduction
        ChainSpec.parse("ijx,jk->ik")


def test_paths_counts_and_dedup():
    # unordered binary trees over N leaves: (2N-3)!! -> 3, 15 for N = 3, 4
    assert len(ChainSpec.parse("ij,jk,kl->il").paths()) == 3
    paths4 = ChainSpec.parse("ij,jk,kl,lm->im").paths()
    assert len(paths4) == 15
    assert len({p.name for p in paths4}) == 15
    # every path of an N-operand chain has N-1 steps, ending at the output
    for p in paths4:
        assert len(p.steps) == 3
        assert p.steps[-1].spec.out_idx == "im"


def test_paths_operational_dedup():
    # three identical operands: all three trees perform the same two step
    # contractions, so the operational dedup collapses them to ONE path
    assert len(ChainSpec.parse("ij,ij,ij->ij").paths()) == 1


def test_hyperedge_index_kept_as_batch():
    # an index shared by 3 operands must survive the first pairwise step
    # (it is still needed downstream), becoming a batch index of that step
    c = ChainSpec.parse("bi,bj,bk->ijk")
    for p in c.paths():
        first = p.steps[0].spec
        assert "b" in first.out_idx
        assert first.batch == ("b",)


def test_every_path_executes_bit_equal():
    # integer-valued operands: every association order sums the same exact
    # integers, so all 15 paths must be BIT-equal to the full einsum
    sizes = dict(i=4, j=5, k=6, l=3, m=4)
    validate_paths("ij,jk,kl,lm->im", sizes, rng=RNG)
    # and explicitly, not just via the helper:
    chain = ChainSpec.parse("ij,jk,kl,lm->im")
    ops = [RNG.integers(-3, 4, size=[sizes[i] for i in idx]
                        ).astype(np.float64) for idx in chain.operands]
    ref = execute_chain_reference(chain, ops)
    for p in chain.paths():
        assert np.array_equal(execute_path_reference(chain, p, ops), ref), \
            p.name


# -------------------------------------------------------- chain predictor --

def test_chain_totals_compose_with_first_once_per_signature():
    # uniform extents: the three steps of ((0.1).(2.3)) all lower to the
    # same canonical gemm signature, so the chain total must count the
    # first-call overhead ONCE, not three times
    sizes = {i: 8 for i in "ijklm"}
    pred = ChainPredictor("ij,jk,kl,lm->im", sizes, suite=fake_suite())
    ranked = pred.rank_paths()
    for r in ranked:
        keys = set()
        dup_first = 0.0
        for s in r.steps:
            if s.benchmark in keys:
                dup_first += s.first
            keys.add(s.benchmark)
        total = sum(s.runtime.med for s in r.steps) - dup_first
        np.testing.assert_allclose(r.runtime.med, total, rtol=1e-12)
        np.testing.assert_allclose(
            r.runtime.std,
            sum(s.runtime.std ** 2 for s in r.steps) ** 0.5, rtol=1e-12)
    best = ranked[0]
    # the balanced path's three uniform gemm steps share one signature
    assert best.name == "((0.1).(2.3))"
    assert len({s.benchmark for s in best.steps}) == 1
    assert best.runtime.med == pytest.approx(
        sum(s.runtime.med for s in best.steps) - 2 * best.steps[0].first)


def test_steps_share_one_suite_across_paths():
    sizes = {i: 8 for i in "ijklm"}
    suite = fake_suite()
    pred = ChainPredictor("ij,jk,kl,lm->im", sizes, suite=suite)
    pred.rank_paths()
    # canonical relabeling: renamed-but-identical steps (ij,jk->ik vs
    # kl,lm->km, ...) collapse onto shared signatures
    assert suite.n_benchmarks < suite.requests / 3
    n = suite.n_benchmarks
    pred.rank_paths(backend="jax")    # measurements fully reused
    assert suite.n_benchmarks == n


def test_intermediate_arrival_propagation():
    # i*k huge: the first step's output (64 MB) cannot fit the 32 MB cache,
    # so the consuming step must see it COLD regardless of loop structure
    sizes = dict(i=4096, j=4, k=4096, l=4)
    pred = ChainPredictor("ij,jk,kl->il", sizes, suite=fake_suite())
    big = next(p for p in pred.paths
               if p.steps[0].spec.out_idx == "ik")
    consuming = big.steps[1]
    op = "A" if consuming.inputs[0] >= 3 else "B"
    assert pred.arrival_classes(consuming) == {op: COLD}
    # the override flips algorithms whose in-loop distance alone says WARM
    stepped = pred.step_predictor(consuming)
    flipped = [a for a in stepped.algorithms
               if pred.suite.key_for(a, sizes).classes !=
               pred.suite.key_for(a, sizes,
                                  arrival={op: COLD}).classes]
    assert flipped
    # small intermediates arrive WARM: the propagated class defers to the
    # access distance and the keys coincide with the standalone ones
    small = ChainPredictor("ij,jk,kl->il", {i: 8 for i in "ijkl"},
                           suite=fake_suite())
    step = small.paths[0].steps[1]
    assert set(small.arrival_classes(step).values()) <= {WARM}


def test_backends_and_oracle_agree():
    sizes = {i: 8 for i in "ijklm"}
    pred = ChainPredictor("ij,jk,kl,lm->im", sizes, suite=fake_suite())
    ranked = pred.rank_paths()
    assert [r.name for r in pred.rank_paths(backend="jax")] == \
        [r.name for r in ranked]
    oracle = pred.rank_paths_oracle(fresh=False)
    assert [r.name for r in oracle] == [r.name for r in ranked]
    for s in STATS:
        np.testing.assert_allclose(
            [getattr(r.runtime, s) for r in ranked],
            [getattr(r.runtime, s) for r in oracle], rtol=1e-8)
    # fresh oracle re-measures per candidate without touching the suite's
    # accounted prediction cost
    cost = pred.suite.cost_seconds
    pred.rank_paths_oracle(fresh=True)
    assert pred.suite.cost_seconds == cost
    assert pred.suite.oracle_cost_seconds > 0


def test_memory_limit_prunes_outer_products():
    sizes = {i: 8 for i in "ijkl"}
    # the (0.2) pairing of ij,jk,kl shares no index: its intermediate is
    # the full 4-index outer product (16 KB at n=8)
    pred = ChainPredictor("ij,jk,kl->il", sizes, suite=fake_suite(),
                          memory_limit_bytes=8 * 1024)
    assert len(pred.paths) == 2
    for p in pred.paths:
        assert all(b <= 8 * 1024 for b in p.intermediate_bytes(sizes)[:-1])
    with pytest.raises(ValueError):
        ChainPredictor("ij,jk,kl->il", sizes, suite=fake_suite(),
                       memory_limit_bytes=16)


def test_execute_chain_matches_reference():
    sizes = {i: 6 for i in "ijklm"}
    chain = ChainSpec.parse("ij,jk,kl,lm->im")
    pred = ChainPredictor(chain, sizes, suite=fake_suite())
    best = pred.select_path()
    ops = [RNG.standard_normal([sizes[i] for i in idx]).astype(np.float32)
           for idx in chain.operands]
    got = execute_chain(chain, best, ops, sizes)
    ref = execute_chain_reference(chain, ops)
    np.testing.assert_allclose(got, ref, rtol=2e-4, atol=2e-4)


# ------------------------------------------------------------- selection --

def test_rank_and_select_einsum_path():
    sizes = {i: 8 for i in "ijkl"}
    pred = ChainPredictor("ij,jk,kl->il", sizes, suite=fake_suite())
    ranked = rank_einsum_paths("ij,jk,kl->il", sizes, predictor=pred)
    assert [r.name for r in ranked] == \
        [r.name for r in pred.rank_paths()]
    best = select_einsum_path("ij,jk,kl->il", sizes, predictor=pred)
    assert best.name == ranked[0].name
    # a predictor built for a different einsum (or sizes) must not
    # silently answer for the requested one
    with pytest.raises(ValueError):
        select_einsum_path("ij,jk->ik", dict(i=8, j=8, k=8),
                           predictor=pred)
    with pytest.raises(ValueError):
        select_einsum_path("ij,jk,kl->il", {i: 9 for i in "ijkl"},
                           predictor=pred)
    with pytest.raises(ValueError):   # repetitions fixed by the suite
        select_einsum_path("ij,jk,kl->il", sizes, predictor=pred,
                           repetitions=3)


def test_repetitions_suite_conflict_raises():
    with pytest.raises(ValueError):
        ChainPredictor("ij,jk,kl->il", {i: 8 for i in "ijkl"},
                       suite=fake_suite(repetitions=4), repetitions=3)


# ------------------------------------------------------------ size sweep --

CHAIN_SWEEP_GRID = [{i: 4 for i in "ijkl"}, {i: 6 for i in "ijkl"},
                    {i: 8 for i in "ijkl"}]


def test_chain_size_sweep_matches_independent_predictors():
    """Every size point of a shared-suite chain sweep ranks exactly like
    a fresh standalone ChainPredictor at that size."""
    sweep = rank_einsum_sweep("ij,jk,kl->il", CHAIN_SWEEP_GRID,
                              suite=fake_suite())
    assert len(sweep.rankings) == len(CHAIN_SWEEP_GRID)
    assert sweep.n_benchmarks < sweep.suite.requests   # cross-point dedup
    for sizes, ranking in zip(CHAIN_SWEEP_GRID, sweep.rankings):
        solo = ChainPredictor("ij,jk,kl->il", sizes,
                              suite=fake_suite()).rank_paths()
        assert [r.name for r in ranking] == [r.name for r in solo]
        assert [r.runtime for r in ranking] == [r.runtime for r in solo]
    assert [w.name for w in sweep.winners] == \
        [r[0].name for r in sweep.rankings]


def test_chain_size_sweep_core_entry_point_and_errors():
    suite = fake_suite()
    per_point = rank_einsum_paths("ij,jk,kl->il",
                                  sizes_grid=CHAIN_SWEEP_GRID,
                                  suite=suite)
    assert len(per_point) == len(CHAIN_SWEEP_GRID)
    for ranking in per_point:
        assert ranking[0].runtime.med <= ranking[-1].runtime.med
    # the core entry extended the SHARED suite, no fresh measurements
    sweep = rank_einsum_sweep("ij,jk,kl->il", CHAIN_SWEEP_GRID,
                              suite=fake_suite())
    assert suite.n_benchmarks == sweep.n_benchmarks
    assert [r.name for r in per_point[0]] == \
        [r.name for r in sweep.rankings[0]]
    with pytest.raises(ValueError, match="mode"):
        rank_einsum_paths("ij,jk,kl->il", CHAIN_SWEEP_GRID[0],
                          sizes_grid=CHAIN_SWEEP_GRID)
    with pytest.raises(ValueError, match="suite"):
        rank_einsum_paths("ij,jk,kl->il", CHAIN_SWEEP_GRID[0],
                          suite=fake_suite())
    with pytest.raises(ValueError, match="sizes"):
        rank_einsum_paths("ij,jk,kl->il")
    with pytest.raises(ValueError, match="size point"):
        rank_einsum_sweep("ij,jk,kl->il", [], suite=fake_suite())
    with pytest.raises(ValueError, match="repetitions"):
        rank_einsum_sweep("ij,jk,kl->il", CHAIN_SWEEP_GRID,
                          suite=fake_suite(repetitions=4), repetitions=3)
    # a size point where NO path survives the memory limit names itself
    with pytest.raises(ValueError, match="size point"):
        rank_einsum_sweep("ij,jk,kl->il",
                          [CHAIN_SWEEP_GRID[0], {i: 64 for i in "ijkl"}],
                          suite=fake_suite(), memory_limit_bytes=1)
