"""Per-kernel Pallas tests: shape/dtype sweeps vs the ref.py oracles."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import flash_attention, matmul, ssd, tile_legal
from repro.kernels.ref import attention_ref, matmul_ref, ssd_ref

RNG = np.random.default_rng(7)


def _arr(shape, dtype):
    return jnp.asarray(RNG.standard_normal(shape), dtype)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("m,n,k,bm,bn,bk", [
    (128, 128, 128, 64, 64, 64),
    (256, 128, 192, 128, 64, 64),
    (64, 64, 64, 64, 64, 64),
    (256, 256, 256, 128, 128, 128),
    (384, 128, 256, 128, 128, 64),
])
def test_matmul_sweep(m, n, k, bm, bn, bk, dtype):
    x, y = _arr((m, k), dtype), _arr((k, n), dtype)
    out = matmul(x, y, bm=bm, bn=bn, bk=bk, interpret=True)
    ref = matmul_ref(x, y)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("hq,hkv,s,d,kw", [
    (4, 4, 64, 32, dict(causal=True)),                 # MHA causal
    (4, 2, 128, 32, dict(causal=True)),                # GQA
    (4, 1, 64, 16, dict(causal=False)),                # MQA encoder
    (4, 2, 128, 32, dict(causal=True, window=32)),     # sliding window
    (4, 2, 64, 32, dict(causal=True, softcap=20.0)),   # gemma2 softcap
])
def test_flash_attention_sweep(hq, hkv, s, d, kw, dtype):
    b = 2
    q = _arr((b, hq, s, d), dtype)
    k = _arr((b, hkv, s, d), dtype)
    v = _arr((b, hkv, s, d), dtype)
    out = flash_attention(q, k, v, bq=32, bkv=32, interpret=True, **kw)
    ref = attention_ref(q, k, v, **kw)
    tol = 2e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("l,h,p,g,n,chunk", [
    (64, 4, 16, 2, 8, 16),
    (128, 4, 16, 1, 16, 32),
    (64, 8, 8, 4, 8, 64),     # chunk == L
])
def test_ssd_sweep(l, h, p, g, n, chunk):
    b = 2
    x = _arr((b, l, h, p), jnp.float32)
    dt = jnp.abs(_arr((b, l, h), jnp.float32)) * 0.1
    a_log = _arr((h,), jnp.float32) * 0.5
    bb = _arr((b, l, g, n), jnp.float32)
    cc = _arr((b, l, g, n), jnp.float32)
    out = ssd(x, dt, a_log, bb, cc, chunk=chunk, interpret=True)
    ref = ssd_ref(x, dt, a_log, bb, cc)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


def test_tile_legality():
    assert tile_legal(1024, 1024, 1024, 128, 128, 128)
    assert not tile_legal(1024, 1024, 1024, 100, 128, 128)  # misaligned
    assert not tile_legal(1024, 1024, 1024, 1024, 1024, 1024,
                          vmem_limit=2 ** 20)               # VMEM blow-up
    assert tile_legal(64, 64, 64, 64, 64, 64)               # small dims ok


def test_xla_fallbacks_match():
    from repro.kernels import ops
    x, y = _arr((128, 64), jnp.float32), _arr((64, 128), jnp.float32)
    np.testing.assert_allclose(
        np.asarray(ops.matmul(x, y, bm=64, bn=64, bk=64)),
        np.asarray(ops.matmul(x, y, use_pallas=False)),
        rtol=2e-5, atol=2e-5)
