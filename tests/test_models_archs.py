"""Per-architecture smoke tests (assignment requirement): reduced configs,
one forward/train step on CPU, output shapes + no NaNs; decode consistency."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import SHAPES, all_configs, get_config, reduced
from repro.launch.steps import make_train_step
from repro.models import (decode_step, forward, init_decode_state,
                          init_params, loss_fn)
from repro.train.optimizer import AdamW

ARCHS = sorted(all_configs())
KEY = jax.random.PRNGKey(0)


def _inputs(cfg, b, s, key=KEY):
    if cfg.frontend == "none":
        return jax.random.randint(key, (b, s), 0, cfg.vocab)
    return jax.random.normal(key, (b, s, cfg.frontend_dim),
                             dtype=jnp.float32)


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_no_nan(arch):
    cfg = reduced(get_config(arch))
    params = init_params(cfg, KEY, dtype=jnp.float32)
    b, s = 2, 64
    logits = forward(cfg, params, _inputs(cfg, b, s))
    assert logits.shape == (b, s, cfg.vocab)
    assert not bool(jnp.isnan(logits).any())


@pytest.mark.parametrize("arch", [
    pytest.param(a, marks=pytest.mark.slow) if a == "jamba-v0.1-52b" else a
    for a in ARCHS
])
def test_train_step(arch):
    cfg = reduced(get_config(arch))
    params = init_params(cfg, KEY, dtype=jnp.float32)
    opt = AdamW(lr=1e-3)
    opt_state = opt.init(params)
    step = jax.jit(make_train_step(cfg, opt))
    b, s = 2, 64
    batch = {"inputs": _inputs(cfg, b, s),
             "labels": jax.random.randint(KEY, (b, s), 0, cfg.vocab)}
    params2, opt_state2, loss = step(params, opt_state, batch)
    assert np.isfinite(float(loss))
    # parameters actually changed
    l0 = jax.tree_util.tree_leaves(params)[0]
    l1 = jax.tree_util.tree_leaves(params2)[0]
    assert not np.allclose(np.asarray(l0), np.asarray(l1))


@pytest.mark.slow
@pytest.mark.parametrize("arch", [a for a in ARCHS
                                  if get_config(a).causal])
def test_decode_matches_forward(arch):
    """Prefill-by-decode must reproduce the full-forward logits."""
    cfg = reduced(get_config(arch))
    params = init_params(cfg, KEY, dtype=jnp.float32)
    b, s = 2, 16
    toks = _inputs(cfg, b, s)
    full = forward(cfg, params, toks)
    caches = init_decode_state(cfg, b, 32, dtype=jnp.float32)
    last = None
    for i in range(s):
        tok = toks[:, i:i + 1]
        last, caches = decode_step(cfg, params, caches, tok,
                                   jnp.asarray(i, dtype=jnp.int32))
    np.testing.assert_allclose(np.asarray(last[:, 0]),
                               np.asarray(full[:, -1]),
                               rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("arch", ARCHS)
def test_shape_assignments_respect_family(arch):
    cfg = get_config(arch)
    if not cfg.causal:
        assert "decode_32k" not in cfg.shapes
        assert "long_500k" not in cfg.shapes
    if cfg.family in ("ssm", "hybrid"):
        assert "long_500k" in cfg.shapes
    for s in cfg.shapes:
        assert s in SHAPES


def test_gemma2_alternates_local_global():
    cfg = get_config("gemma2-27b")
    specs = cfg.layer_specs()
    assert specs[0].window == 4096 and specs[1].window == 0
    assert cfg.attn_softcap == 50.0 and cfg.logit_softcap == 30.0


def test_jamba_pattern_ratio():
    cfg = get_config("jamba-v0.1-52b")
    specs = cfg.layer_specs()
    attn = sum(1 for s in specs if s.mixer == "attn")
    assert attn * 7 == (len(specs) - attn)          # 1:7
    moe = sum(1 for s in specs if s.ffn == "moe")
    assert moe == len(specs) // 2                    # every other layer


def test_arctic_moe_plus_dense():
    cfg = get_config("arctic-480b")
    assert all(s.ffn == "moe+dense" for s in cfg.layer_specs())
    assert cfg.n_experts == 128 and cfg.top_k == 2


def test_param_counts_close_to_published():
    expected = {"deepseek-7b": 7, "gemma2-27b": 27, "grok-1-314b": 314,
                "arctic-480b": 480, "mamba2-2.7b": 2.7,
                "jamba-v0.1-52b": 52, "phi3-mini-3.8b": 3.8,
                "phi3-medium-14b": 14, "chameleon-34b": 34}
    for arch, bn in expected.items():
        n = get_config(arch).param_count() / 1e9
        assert abs(n - bn) / bn < 0.15, (arch, n, bn)
