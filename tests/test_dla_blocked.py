"""Integration tests: blocked algorithms vs numpy/LAPACK oracles (Ch. 1/4)."""

import numpy as np
import pytest

from repro.dla import ExecEngine, TraceEngine, blocked
from repro.dla.engine import Matrix
from repro.dla.tracers import (CHOLESKY_TRACERS, LAPACK_TRACERS,
                               SYLVESTER_TRACERS, TRTRI_TRACERS,
                               required_kernel_cases)
from repro.dla.kernels import KERNELS

RNG = np.random.default_rng(42)
N, B = 96, 32


def _spd(n):
    a = RNG.standard_normal((n, n))
    return a @ a.T + n * np.eye(n)


def _lower(n):
    a = np.tril(RNG.standard_normal((n, n)))
    np.fill_diagonal(a, np.abs(a.diagonal()) + n)
    return a


@pytest.mark.parametrize("variant", [1, 2, 3])
def test_potrf_variants(variant):
    A0 = _spd(N)
    ref = np.linalg.cholesky(A0)
    eng = ExecEngine()
    A = eng.bind("A", A0)
    blocked.potrf(eng, A, N, B, variant=variant)
    np.testing.assert_allclose(np.tril(eng.mats["A"]), ref,
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("variant", list(range(1, 9)))
def test_trtri_variants(variant):
    L0 = _lower(N)
    ref = np.linalg.inv(L0)
    eng = ExecEngine()
    A = eng.bind("A", L0)
    blocked.trtri(eng, A, N, B, variant=variant)
    np.testing.assert_allclose(np.tril(eng.mats["A"]), ref,
                               rtol=2e-4, atol=2e-4)


def test_lauum():
    L0 = _lower(N)
    eng = ExecEngine()
    A = eng.bind("A", L0)
    blocked.lauum(eng, A, N, B)
    ref = np.tril(np.tril(L0).T @ np.tril(L0))
    np.testing.assert_allclose(np.tril(eng.mats["A"]), ref,
                               rtol=2e-4, atol=2e-4)


def test_sygst():
    A0, L0 = _spd(N), _lower(N)
    eng = ExecEngine()
    A, L = eng.bind("A", A0), eng.bind("L", L0)
    blocked.sygst(eng, A, L, N, B)
    Li = np.linalg.inv(np.tril(L0))
    ref = np.tril(Li @ A0 @ Li.T)
    np.testing.assert_allclose(np.tril(eng.mats["A"]), ref,
                               rtol=2e-3, atol=2e-3)


def test_getrf():
    M0 = RNG.standard_normal((N, N)) + N * np.eye(N)
    eng = ExecEngine()
    A = eng.bind("A", M0)
    blocked.getrf(eng, A, N, B)
    R = eng.mats["A"]
    L = np.tril(R, -1) + np.eye(N)
    U = np.triu(R)
    np.testing.assert_allclose(L @ U, M0, rtol=2e-4, atol=2e-4)


def test_geqrf():
    m = 128
    M0 = RNG.standard_normal((m, N))
    eng = ExecEngine()
    A = eng.bind("A", M0)
    fac = blocked.geqrf_exec(eng, A, m, N, B)
    Rfac = np.triu(eng.mats["A"][:N, :N])
    Q = np.eye(m)
    for k, V, T in fac:
        H = np.eye(m)
        H[k:, k:] = np.eye(m - k) - V @ T @ V.T
        Q = Q @ H
    R_full = np.zeros((m, N))
    R_full[:N] = Rfac
    np.testing.assert_allclose(Q @ R_full, M0, rtol=1e-4, atol=1e-4)
    # Q orthogonal
    np.testing.assert_allclose(Q.T @ Q, np.eye(m), atol=1e-8)


@pytest.mark.slow
@pytest.mark.parametrize("alg", blocked.SYLVESTER_ALGORITHMS)
def test_sylvester_algorithms(alg):
    m, n = 64, 96
    Au = np.triu(RNG.standard_normal((m, m))) + m * np.eye(m)
    Bu = np.triu(RNG.standard_normal((n, n))) + n * np.eye(n)
    C0 = RNG.standard_normal((m, n))
    X = np.linalg.solve(
        np.kron(np.eye(n), Au) + np.kron(Bu.T, np.eye(m)),
        C0.flatten(order="F")).reshape((m, n), order="F")
    eng = ExecEngine()
    Am, Bm, Cm = eng.bind("A", Au), eng.bind("B", Bu), eng.bind("C", C0)
    blocked.sylvester(eng, Am, Bm, Cm, m, n, 32, algorithm=alg)
    np.testing.assert_allclose(eng.mats["C"], X, rtol=2e-4, atol=2e-4)


def test_trace_matches_execution_structure():
    """The traced call sequence must be identical to the executed one."""
    class RecordingExec(ExecEngine):
        def __init__(self):
            super().__init__()
            self.seq = []

        def _run(self, name, case, *ops):
            self.seq.append((name, tuple(case)))
            return super()._run(name, case, *ops)

    eng = RecordingExec()
    A = eng.bind("A", _spd(N))
    blocked.potrf(eng, A, N, B, variant=3)
    tr = TraceEngine()
    blocked.potrf(tr, Matrix("A", N, N), N, B, variant=3)
    traced = [(c.kernel, tuple(c.case)) for c in tr.calls
              if all(s > 0 for s in c.sizes)]
    assert traced == eng.seq


def test_all_traced_cases_have_kernels():
    need = required_kernel_cases()
    for kernel, cases in need.items():
        have = set(map(tuple, KERNELS[kernel].cases))
        missing = {c for c in cases if tuple(c) not in have}
        assert not missing, f"{kernel}: unregistered cases {missing}"


def test_tracer_call_counts_scale():
    calls_small = CHOLESKY_TRACERS["potrf3"](256, 64)
    calls_large = CHOLESKY_TRACERS["potrf3"](512, 64)
    assert len(calls_large) == 2 * len(calls_small)
    assert len(TRTRI_TRACERS) == 8
    assert len(SYLVESTER_TRACERS) == 8
    assert set(LAPACK_TRACERS) == {"lauum", "sygst", "trtri", "potrf",
                                   "getrf", "geqrf"}
