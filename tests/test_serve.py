"""Serving-engine tests: batched requests end-to-end on a small model."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced
from repro.models import init_params
from repro.serve.engine import Request, ServeEngine


def _engine(arch="deepseek-7b", slots=3, ctx=64):
    cfg = reduced(get_config(arch), n_layers=2, d_model=64, d_ff=128,
                  vocab=128)
    params = init_params(cfg, jax.random.PRNGKey(0), dtype=jnp.float32)
    return cfg, ServeEngine(cfg, params, batch_slots=slots, ctx_len=ctx)


def test_serve_completes_all_requests():
    cfg, eng = _engine()
    rng = np.random.default_rng(0)
    reqs = [Request(uid=i, prompt=rng.integers(0, cfg.vocab, 8,
                                               dtype=np.int32),
                    max_new_tokens=4) for i in range(5)]
    stats = eng.run(reqs)
    assert all(r.done for r in reqs)
    assert all(len(r.out_tokens) == 4 for r in reqs)
    assert stats.tokens_out == 20
    assert stats.decode_steps >= 4         # batching: fewer steps than 20


def test_serve_overflows_into_queue():
    cfg, eng = _engine(slots=2)
    rng = np.random.default_rng(1)
    reqs = [Request(uid=i, prompt=rng.integers(0, cfg.vocab, 4,
                                               dtype=np.int32),
                    max_new_tokens=3) for i in range(4)]
    eng.run(reqs)
    assert all(r.done for r in reqs)


def test_greedy_decode_is_deterministic():
    cfg, eng1 = _engine()
    _, eng2 = _engine()
    prompt = np.arange(6, dtype=np.int32)
    r1 = Request(uid=0, prompt=prompt, max_new_tokens=5)
    r2 = Request(uid=0, prompt=prompt, max_new_tokens=5)
    eng1.run([r1])
    eng2.run([r2])
    assert r1.out_tokens == r2.out_tokens
