"""Tests for repro.tc.device: device-resident Pallas kernel measurement,
H2D/D2H transfer terms, and measured tile ranking.

Three contracts anchor this file:

* the **analytic oracle**: the measured tile ranking
  (``rank_device_tiles`` / ``select_tiles``) operates over exactly the
  candidate set the pre-device analytic model (``predict_tile_time``,
  kept alive behind ``analytic=True``) ranks — reprolint's
  oracle-coverage gate pins the pairing to this module;
* **transfer fits recover their constants**: fitting the memcpy model
  against an injected synthetic probe reproduces the injected bandwidth
  and overhead, asymmetrically per direction;
* **warm stores rank with zero fresh measurements**: device models ride
  the ``ModelStore`` under its reserved ``__device__`` name, round-trip
  bit-exactly, and refuse to load across platform fingerprints.

Real sweeps run the actual Pallas kernels in interpret mode on tiny
configs; everything asserting exact values injects a deterministic
``sweep_fn`` / ``transfer_measure_fn`` instead.
"""

import numpy as np
import pytest

from repro.core.model import ModelSet
from repro.core.sampler import Stats
from repro.core.transfer import (D2H, H2D, fit_transfer, measure_transfers)
from repro.perf.tile_tuner import (TileChoice, _mxu_eff, predict_tile_time,
                                   rank_tiles, select_tiles)
from repro.store import (DEVICE_MODEL_SET, ModelStore, PlatformFingerprint,
                         StoreMismatchError)
from repro.store.drift import DriftProbe
from repro.tc import PredictorSession
from repro.tc.device import (DEVICE_KERNELS, RESIDENT, TIGHT, DeviceSuite,
                             device_key, vmem_class)
from repro.tc.suite import MicroBenchmarkSuite

CONFIGS = [(8, 8, 8), (16, 16, 16), (8, 16, 8)]


def synthetic_sweep(kernel_name, configs):
    """Deterministic pure function of (kernel, config): exact checks."""
    kernel = DEVICE_KERNELS[kernel_name]
    out = {}
    for cfg in configs:
        t = 1e-9 * kernel.vmem_bytes(cfg) + 1e-6
        out[cfg] = (Stats(0.9 * t, t, 1.2 * t, 1.02 * t, 0.05 * t),
                    1e-3, 10.0 * t)
    return out


def synthetic_xfer(direction, nbytes, repetitions):
    """Affine probe with known constants; D2H 3x slower than H2D."""
    bw = 3e9 if direction == H2D else 1e9
    return [2e-6 + nbytes / bw] * repetitions


def device_suite(suite=None, **kw):
    kw.setdefault("sweep_fn", synthetic_sweep)
    kw.setdefault("transfer_measure_fn", synthetic_xfer)
    return DeviceSuite(suite or MicroBenchmarkSuite(repetitions=2), **kw)


def fake_measure(key, repetitions):
    t = 1e-9 * key.call_bytes + 2e-6
    return Stats(0.95 * t, t, 1.1 * t, 1.01 * t, 0.02 * t), 1e-3


# ------------------------------------------------------------------ keys --

def test_device_key_carries_config_and_vmem_class():
    key = device_key("pallas_matmul", (8, 8, 8))
    assert key.config == (8, 8, 8)
    assert key.equation == "pallas_matmul"
    # proxy-problem operand shapes: 2 grid steps per dim
    assert (key.a_shape, key.b_shape, key.out_shape) == \
        ((16, 16), (16, 16), (16, 16))
    assert key.classes == (RESIDENT, RESIDENT)
    # a config whose working set exceeds half of VMEM classifies tight
    big = device_key("pallas_matmul", (1024, 1024, 1024))
    assert big.classes == (TIGHT, TIGHT)
    assert vmem_class(0) == RESIDENT


def test_einsum_protocol_refuses_device_keys():
    suite = MicroBenchmarkSuite(measure_fn=fake_measure, repetitions=2)
    key = device_key("pallas_matmul", (8, 8, 8))
    with pytest.raises(ValueError, match="device"):
        suite._measure(key, suite.repetitions)
    ds = device_suite(suite)
    ds.measure_grid("pallas_matmul", [(8, 8, 8)])
    # drift repair goes through the einsum protocol -> same refusal
    with pytest.raises(ValueError, match="device"):
        suite.refresh(key)


def test_sweep_dedup_and_cost_accounting():
    ds = device_suite()
    suite = ds.suite
    res = ds.measure_grid("pallas_matmul", CONFIGS + [CONFIGS[0]])
    assert set(res) == set(CONFIGS)
    assert suite.measured == len(CONFIGS)
    assert suite.cost_seconds > 0
    # every key deduplicates: nothing is ever measured twice
    before = suite.measured
    ds.measure_grid("pallas_matmul", CONFIGS)
    assert suite.measured == before
    counters = suite.counters()
    assert counters["measured"] == len(CONFIGS)


def test_real_interpret_sweep_measures_all_registered_kernels():
    """The actual device-resident loop, interpret mode, tiny configs."""
    suite = MicroBenchmarkSuite(repetitions=2)
    ds = DeviceSuite(suite, passes=2, transfer_measure_fn=synthetic_xfer)
    assert ds.interpret            # auto-gated off-accelerator
    for name, cfg in [("pallas_matmul", (8, 8, 8)),
                      ("flash_attention", (8, 8, 16)),
                      ("pallas_ssd", (8, 4, 4))]:
        mb = ds.measure_grid(name, [cfg])[cfg]
        assert mb.stats.med > 0 and mb.first > 0 and mb.seconds > 0
        assert mb.key.config == cfg
    assert suite.measured == 3


# -------------------------------------------------------------- ranking --

def test_rank_decomposes_transfer_and_compute():
    ds = device_suite()
    ranked = ds.rank("pallas_matmul", (64, 64, 64), CONFIGS)
    assert [r.config for r in ranked] == \
        sorted((r.config for r in ranked),
               key=lambda c: next(x.t_total for x in ranked
                                  if x.config == c))
    for r in ranked:
        assert r.t_total == pytest.approx(r.t_h2d + r.t_compute + r.t_d2h)
        assert r.t_h2d > 0 and r.t_d2h > 0
        assert r.source == "measured"
        kernel = DEVICE_KERNELS["pallas_matmul"]
        assert r.t_compute == pytest.approx(
            r.per_step_s * kernel.steps((64, 64, 64), r.config))
    # D2H is modeled 3x slower per byte but moves m*n vs m*k + k*n bytes
    h2d, d2h = ds.transfer_models()
    assert d2h.time(1 << 20) > h2d.time(1 << 20)


def test_select_tiles_measured_path_matches_analytic_candidates():
    """The measured ranking and its analytic oracle agree on the legal
    candidate set and both pick from it (CPU-interpret equivalence)."""
    sess = PredictorSession(repetitions=2)
    sess.device_suite(sweep_fn=synthetic_sweep,
                      transfer_measure_fn=synthetic_xfer)
    measured = rank_tiles(64, 64, 64, session=sess, candidates=(8, 16))
    analytic = rank_tiles(64, 64, 64, analytic=True, candidates=(8, 16))
    assert {(t.bm, t.bn, t.bk) for t in measured} == \
        {(t.bm, t.bn, t.bk) for t in analytic}
    choice = select_tiles(64, 64, 64, session=sess, candidates=(8, 16))
    assert choice == measured[0]
    assert choice.source in ("measured", "model")
    assert choice.t_compute > 0
    # the analytic oracle also backs select_tiles when no session exists
    fallback = select_tiles(64, 64, 64, candidates=(8, 16))
    assert fallback.source == "analytic"
    assert fallback.predicted_s == pytest.approx(predict_tile_time(
        64, 64, 64, fallback.bm, fallback.bn, fallback.bk))
    # session front-end reaches the same device ranking
    direct = sess.rank_device_tiles("pallas_matmul", (64, 64, 64),
                                    [(8, 8, 8), (16, 16, 16)])
    assert [r.config for r in direct] == \
        [r.config for r in sess.device_suite().rank(
            "pallas_matmul", (64, 64, 64), [(8, 8, 8), (16, 16, 16)])]


def test_mxu_eff_models_partial_passes():
    # the old min(b, 128) double-clamp scored every b >= 128 as full
    assert _mxu_eff(64) == pytest.approx(0.5)
    assert _mxu_eff(128) == pytest.approx(1.0)
    assert _mxu_eff(192) == pytest.approx(0.75)   # 192 = 1.5 passes
    assert _mxu_eff(256) == pytest.approx(1.0)


# ------------------------------------------------------------- transfer --

def test_transfer_fit_recovers_synthetic_constants():
    h2d, d2h, cost = measure_transfers(measure_fn=synthetic_xfer)
    assert cost >= 0
    for model, bw in ((h2d, 3e9), (d2h, 1e9)):
        assert model.overhead_s == pytest.approx(2e-6, rel=1e-6)
        assert model.bytes_per_s == pytest.approx(bw, rel=1e-6)
    # the directions are asymmetric, as fitted
    assert d2h.time(1 << 20) > 2.5 * h2d.time(1 << 20)


def test_transfer_models_round_trip_bit_exactly(tmp_path):
    h2d, d2h, _ = measure_transfers(measure_fn=synthetic_xfer)
    suite = MicroBenchmarkSuite(repetitions=2)
    ds = device_suite(suite)
    ds._transfer = (h2d, d2h)
    ds.measure_grid("pallas_matmul", CONFIGS)
    store = ModelStore.from_suite(suite)
    store.add_device_models(ds)
    path = tmp_path / "store.json"
    store.save(path)
    loaded = ModelStore.load(path, fingerprint=store.fingerprint)
    ds2 = device_suite(MicroBenchmarkSuite(repetitions=2))
    ds2.load_model_set(loaded.device_model_set())
    h2d2, d2h2 = ds2.transfer_models()
    for n in (0, 1 << 10, 1 << 20, 1 << 28):
        # json floats round-trip via repr: bit-exact, not approximate
        assert h2d2.time(n) == h2d.time(n)
        assert d2h2.time(n) == d2h.time(n)
    assert (h2d2.direction, d2h2.direction) == (H2D, D2H)


def test_fit_transfer_is_relative_affine():
    sizes = (1024, 4096, 16384)
    model = fit_transfer(H2D, sizes, [1e-6 + n / 2e9 for n in sizes])
    assert model.overhead_s == pytest.approx(1e-6, rel=1e-6)
    assert model.bytes_per_s == pytest.approx(2e9, rel=1e-6)


# ------------------------------------------------------ store warm start --

def test_warm_store_ranks_with_zero_fresh_measurements(tmp_path):
    cold = PredictorSession(repetitions=2)
    cold.device_suite(sweep_fn=synthetic_sweep,
                      transfer_measure_fn=synthetic_xfer)
    ranked = cold.rank_device_tiles("pallas_matmul", (64, 64, 64), CONFIGS)
    assert cold.suite.measured == len(CONFIGS)
    path = tmp_path / "store.json"
    cold.save_store(path)

    warm = PredictorSession(store=path)
    warm.device_suite(transfer_measure_fn=synthetic_xfer)
    again = warm.rank_device_tiles("pallas_matmul", (64, 64, 64), CONFIGS)
    assert warm.suite.measured == 0          # zero fresh measurements
    assert [(r.config, r.t_total, r.t_h2d, r.t_compute, r.t_d2h)
            for r in again] == \
        [(r.config, r.t_total, r.t_h2d, r.t_compute, r.t_d2h)
         for r in ranked]                    # bit-identical ranking
    # an unmeasured config inside the fitted domain predicts from the
    # loaded __device__ models — still zero fresh measurements
    extra = warm.rank_device_tiles("pallas_matmul", (64, 64, 64),
                                   [(8, 8, 16)])
    assert extra[0].source == "model"
    assert warm.suite.measured == 0


def test_device_model_set_refuses_foreign_fingerprint(tmp_path):
    """Regression: the reserved ``__device__`` set is fingerprint-gated
    like every payload — device timings must not cross platforms."""
    sess = PredictorSession(repetitions=2)
    sess.device_suite(sweep_fn=synthetic_sweep,
                      transfer_measure_fn=synthetic_xfer)
    sess.rank_device_tiles("pallas_matmul", (64, 64, 64), CONFIGS)
    path = tmp_path / "store.json"
    store = sess.save_store(path)
    assert DEVICE_MODEL_SET in store.model_sets
    other = PlatformFingerprint(
        cpu="other-cpu", cores=1, backend="tpu", device_kind="TPU v9",
        libraries="other", dtype="float32", repro_version="0.0.0")
    with pytest.raises(StoreMismatchError):
        ModelStore.load(path, fingerprint=other)
    # the explicit escape hatch still works and carries the device set
    loaded = ModelStore.load(path, fingerprint=other, allow_mismatch=True)
    assert loaded.device_model_set() is not None
    assert "pallas_matmul" in loaded.device_model_set()


def test_device_models_export_import_round_trip():
    ds = device_suite()
    ds.measure_grid("pallas_matmul", CONFIGS)
    ds.rank("pallas_matmul", (32, 32, 32), CONFIGS)   # fits transfer too
    ms = ds.to_model_set()
    assert sorted(ms.models) == ["memcpy_d2h", "memcpy_h2d",
                                 "pallas_matmul"]
    ms2 = ModelSet.from_dict(ms.to_dict())
    ds2 = device_suite(MicroBenchmarkSuite(repetitions=2))
    assert ds2.load_model_set(ms2) == 1
    # model predictions agree with the fit source at the fitted points
    for cfg in CONFIGS:
        pred = ds2._model_predict("pallas_matmul", (RESIDENT, RESIDENT),
                                  cfg, "med")
        measured = ds.suite.results[ds.key("pallas_matmul", cfg)].stats.med
        assert pred == pytest.approx(measured, rel=0.2)


def test_drift_probe_skips_device_keys():
    suite = MicroBenchmarkSuite(measure_fn=fake_measure, repetitions=2)
    ds = device_suite(suite)
    ds.measure_grid("pallas_matmul", CONFIGS)
    from repro.tc.suite import MicroBenchmarkKey
    einsum_key = MicroBenchmarkKey(
        equation="ab,bc->ac", a_shape=(8, 8), b_shape=(8, 8),
        out_shape=(8, 8), classes=("warm", "warm"))
    suite.measure_key(einsum_key)
    probe = DriftProbe(suite, max_keys=8)
    keys = probe.keys()
    assert keys and all(k.config is None for k in keys)
    readings = probe.probe()                 # refusal-free: einsum only
    assert len(readings) == len(keys)
