"""Tests for repro.tc.session: the unified PredictorSession entry point
and the one-release deprecation shims on the legacy call forms."""

import warnings

import numpy as np
import pytest

from repro.core.contractions import (ContractionSpec,
                                     rank_contraction_algorithms)
from repro.core.sampler import Stats
from repro.core.selection import (rank_einsum_paths,
                                  select_contraction_algorithm,
                                  select_einsum_path)
from repro.tc import (COLD, WARM, ChainPredictor, ContractionPredictor,
                      MicroBenchmarkSuite, PredictorSession)

SPEC = "ij,jk->ik"
SIZES = dict(i=6, j=5, k=4)
CHAIN = "ij,jk,kl->il"
CHAIN_SIZES = dict(i=4, j=5, k=6, l=3)


def fake_measure(key, repetitions):
    t = 1e-9 * key.call_bytes + 2e-6 + 5e-7 * key.classes.count("cold")
    stats = Stats(min=0.95 * t, med=t, max=1.1 * t, mean=1.01 * t,
                  std=0.02 * t)
    return stats, 1e-3


def fake_suite(repetitions=4):
    return MicroBenchmarkSuite(repetitions=repetitions,
                               measure_fn=fake_measure)


def fake_session(**kwargs):
    return PredictorSession(suite=fake_suite(), **kwargs)


# ------------------------------------------------------- session routing --

def test_session_contraction_ranking_matches_predictor():
    sess = fake_session()
    direct = ContractionPredictor(SPEC, SIZES, suite=fake_suite())
    got = sess.rank_contraction_algorithms(SPEC, SIZES)
    want = direct.rank(stat="med", backend="numpy")
    assert [r.name for r in got] == [r.name for r in want]
    np.testing.assert_allclose([r.runtime.med for r in got],
                               [r.runtime.med for r in want])


def test_session_select_is_rank_head():
    sess = fake_session()
    assert sess.select_contraction_algorithm(SPEC, SIZES) == \
        sess.rank_contraction_algorithms(SPEC, SIZES)[0].name


def test_session_chain_ranking_matches_predictor():
    sess = fake_session()
    direct = ChainPredictor(CHAIN, CHAIN_SIZES, suite=fake_suite())
    got = sess.rank_einsum_paths(CHAIN, CHAIN_SIZES)
    want = direct.rank_paths(stat="med", backend="numpy")
    assert [r.name for r in got] == [r.name for r in want]
    assert sess.select_einsum_path(CHAIN, CHAIN_SIZES).name == \
        got[0].name


def test_session_memoizes_predictors_and_shares_suite():
    sess = fake_session()
    p1 = sess.contraction_predictor(SPEC, SIZES)
    p2 = sess.contraction_predictor(SPEC, SIZES)
    assert p1 is p2
    sess.rank_contraction_algorithms(SPEC, SIZES)
    n = sess.suite.n_benchmarks
    sess.rank_contraction_algorithms(SPEC, SIZES)
    assert sess.suite.n_benchmarks == n  # second ranking: all shared
    # a different arrival state is a different predictor
    p3 = sess.contraction_predictor(SPEC, SIZES, arrival={"A": COLD})
    assert p3 is not p1


def test_session_sweeps_share_one_suite():
    sess = fake_session()
    grid = [dict(SIZES), dict(i=8, j=5, k=4)]
    sweep = sess.rank_contraction_sweep(SPEC, grid)
    assert len(sweep.rankings) == 2
    assert sweep.suite is sess.suite
    chain_sweep = sess.rank_einsum_sweep(CHAIN, [dict(CHAIN_SIZES)])
    assert chain_sweep.suite is sess.suite


def test_session_counters_expose_suite_and_trace_cache():
    sess = fake_session()
    sess.rank_contraction_algorithms(SPEC, SIZES)
    counters = sess.counters()
    assert counters["n_benchmarks"] > 0
    assert counters["trace_misses"] > 0
    sess.rank_contraction_algorithms(SPEC, SIZES)
    assert sess.counters()["trace_hits"] >= counters["trace_hits"]


def test_session_repetitions_conflicts_with_suite():
    with pytest.raises(ValueError, match="repetitions"):
        PredictorSession(suite=fake_suite(repetitions=4), repetitions=3)


# --------------------------------------------------------------- shims --

def test_legacy_rank_contraction_algorithms_warns_and_matches():
    sess = fake_session()
    want = [(a.name, t) for a, t in
            _session_ranked_tuples(sess)]
    with pytest.warns(DeprecationWarning, match="PredictorSession"):
        got = rank_contraction_algorithms(ContractionSpec.parse(SPEC),
                                          SIZES, suite=fake_suite())
    assert [(a.name, t) for a, t in got] == want


def _session_ranked_tuples(sess):
    return [(r.algorithm, r.runtime.med)
            for r in sess.rank_contraction_algorithms(SPEC, SIZES)]


def test_legacy_sizes_grid_warns_and_matches():
    grid = [dict(SIZES), dict(i=8, j=5, k=4)]
    with pytest.warns(DeprecationWarning, match="sizes_grid"):
        got = rank_contraction_algorithms(ContractionSpec.parse(SPEC),
                                          sizes_grid=grid,
                                          suite=fake_suite())
    sweep = fake_session().rank_contraction_sweep(SPEC, grid)
    assert [[a.name for a, _ in point] for point in got] == \
        [[r.name for r in ranking] for ranking in sweep.rankings]


def test_legacy_select_contraction_algorithm_via_session_kwarg():
    sess = fake_session()
    # session= is the undeprecated spelling: no warning
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        name = select_contraction_algorithm(SPEC, SIZES, session=sess)
    assert name == sess.select_contraction_algorithm(SPEC, SIZES)


def test_legacy_predictor_kwarg_warns():
    pred = ContractionPredictor(SPEC, SIZES, suite=fake_suite())
    with pytest.warns(DeprecationWarning, match="predictor"):
        name = select_contraction_algorithm(SPEC, SIZES, predictor=pred)
    assert name == fake_session().select_contraction_algorithm(SPEC, SIZES)


def test_legacy_rank_einsum_paths_warns_and_matches():
    sess = fake_session()
    want = [r.name for r in sess.rank_einsum_paths(CHAIN, CHAIN_SIZES)]
    pred = ChainPredictor(CHAIN, CHAIN_SIZES, suite=fake_suite())
    with pytest.warns(DeprecationWarning, match="predictor"):
        got = rank_einsum_paths(CHAIN, CHAIN_SIZES, predictor=pred)
    assert [r.name for r in got] == want
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        via_session = select_einsum_path(CHAIN, CHAIN_SIZES, session=sess)
    assert via_session.name == want[0]


def test_session_conflicts_with_legacy_kwargs():
    sess = fake_session()
    with pytest.raises(ValueError, match="session"):
        rank_contraction_algorithms(ContractionSpec.parse(SPEC), SIZES,
                                    session=sess, suite=fake_suite())
    with pytest.raises(ValueError, match="session"):
        select_contraction_algorithm(SPEC, SIZES, session=sess,
                                     backend="numpy")
    pred = ChainPredictor(CHAIN, CHAIN_SIZES, suite=fake_suite())
    with pytest.raises(ValueError, match="session"):
        rank_einsum_paths(CHAIN, CHAIN_SIZES, session=sess,
                          predictor=pred)
    with pytest.raises(ValueError, match="session"):
        rank_contraction_algorithms(ContractionSpec.parse(SPEC), SIZES,
                                    batched=False, session=sess)


def test_legacy_error_contracts_preserved():
    spec = ContractionSpec.parse(SPEC)
    with pytest.raises(ValueError, match="not both"):
        rank_contraction_algorithms(spec, SIZES, sizes_grid=[SIZES])
    with pytest.raises(ValueError, match="sizes"):
        rank_contraction_algorithms(spec)
    with pytest.raises(ValueError, match="mode"):
        rank_einsum_paths(CHAIN, CHAIN_SIZES, sizes_grid=[CHAIN_SIZES])
    with pytest.raises(ValueError, match="suite"):
        rank_einsum_paths(CHAIN, CHAIN_SIZES, suite=fake_suite())
    with pytest.raises(ValueError, match="repetitions"):
        select_contraction_algorithm(
            SPEC, SIZES, repetitions=3,
            predictor=ContractionPredictor(SPEC, SIZES,
                                           suite=fake_suite()))


# ------------------------------------------------------- serving facade --

def test_session_step_cost_model_facade():
    from repro.configs import get_config, reduced
    from repro.serve.scheduler import StepCostModel

    cfg = reduced(get_config("deepseek-7b"), n_layers=2, d_model=16,
                  d_ff=32, vocab=64)
    sess = fake_session()
    model = sess.step_cost_model(cfg, slots=3)
    assert isinstance(model, StepCostModel)
    assert model.slots == 3
    # the static-batch engine steps at full width whatever the occupancy
    assert model.tick_cost(1, WARM) == model.tick_cost(3, WARM)
    assert model.tick_cost(2, COLD) == model.tick_cost(3, COLD)
    assert model.tick_cost(1, WARM) > 0
    assert model.n_benchmarks > 0
    # model building went through THIS session's shared suite
    assert sess.suite.n_benchmarks == model.n_benchmarks
