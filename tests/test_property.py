"""Property-based tests (hypothesis) on system invariants."""

import numpy as np
import pytest

pytest.importorskip("hypothesis",
                    reason="hypothesis is a dev extra; install with [dev]")

from hypothesis import given, settings, strategies as st

from repro.core.contractions import (ContractionSpec, execute,
                                     execute_reference,
                                     generate_algorithms)
from repro.core.fitting import fit_relative, monomial_basis, relative_errors
from repro.core.grids import Domain, grid_points
from repro.core.sampler import Stats
from repro.train.compression import compress_tree, decompress_tree, init_error

import jax.numpy as jnp


@settings(max_examples=25, deadline=None)
@given(lo=st.integers(8, 256), width=st.integers(16, 2048),
       n=st.integers(2, 7),
       kind=st.sampled_from(["cartesian", "chebyshev"]))
def test_grid_points_inside_and_rounded(lo, width, n, kind):
    dom = Domain((lo,), (lo + width,))
    pts = grid_points(dom, (n,), kind=kind, round_to=8)
    assert pts, (lo, width, n)
    for p in pts:
        assert dom.contains(p)
        assert p[0] % 8 == 0


@settings(max_examples=25, deadline=None)
@given(lo=st.tuples(st.integers(8, 128), st.integers(8, 128)),
       w=st.tuples(st.integers(64, 1024), st.integers(64, 1024)))
def test_domain_split_partitions(lo, w):
    dom = Domain(lo, (lo[0] + w[0], lo[1] + w[1]))
    a, b, d = dom.split()
    # the two halves share exactly the split plane and cover the domain
    assert a.lo == dom.lo and b.hi == dom.hi
    assert a.hi[d] == b.lo[d]
    assert a.widths()[d] < dom.widths()[d]
    assert b.widths()[d] < dom.widths()[d]


@settings(max_examples=20, deadline=None)
@given(samples=st.lists(st.floats(1e-6, 1e3), min_size=1, max_size=50))
def test_stats_invariants(samples):
    s = Stats.from_samples(samples)
    assert s.min <= s.med <= s.max
    assert s.min <= s.mean <= s.max
    assert s.std >= 0


@settings(max_examples=15, deadline=None)
@given(coefs=st.lists(st.floats(1e-9, 1e-3), min_size=3, max_size=3),
       seed=st.integers(0, 100))
def test_exact_polynomials_fit_exactly(coefs, seed):
    """Relative LSQ recovers any positive polynomial in the basis span."""
    rng = np.random.default_rng(seed)
    pts = rng.integers(8, 512, size=(30, 1)).astype(float)
    c0, c1, c2 = coefs
    y = c0 + c1 * pts[:, 0] + c2 * pts[:, 0] ** 2
    poly = fit_relative(pts, y, monomial_basis([(2,)]))
    assert relative_errors(poly, pts, y).max() < 1e-6


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 1000))
def test_contraction_algorithms_agree(seed):
    """Every generated algorithm computes the same contraction."""
    rng = np.random.default_rng(seed)
    spec = ContractionSpec.parse("ab=ai,ib")
    sizes = dict(a=int(rng.integers(2, 10)), b=int(rng.integers(2, 10)),
                 i=int(rng.integers(2, 8)))
    A = rng.standard_normal((sizes["a"], sizes["i"])).astype(np.float32)
    B = rng.standard_normal((sizes["i"], sizes["b"])).astype(np.float32)
    ref = execute_reference(spec, A, B)
    for alg in generate_algorithms(spec):
        got = execute(alg, A, B, sizes)
        np.testing.assert_allclose(got, ref, rtol=1e-3, atol=1e-3)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 1000), rows=st.integers(1, 40),
       cols=st.integers(1, 40))
def test_compression_bounded_error(seed, rows, cols):
    rng = np.random.default_rng(seed)
    g = {"w": jnp.asarray(rng.standard_normal((rows, cols)), jnp.float32)}
    q, err = compress_tree(g, init_error(g))
    deq = decompress_tree(q, g)
    # int8 with per-chunk scales: max error <= scale/2 <= max|x|/254
    max_err = float(jnp.max(jnp.abs(deq["w"] - g["w"])))
    bound = float(jnp.max(jnp.abs(g["w"]))) / 127.0 + 1e-7
    assert max_err <= bound
