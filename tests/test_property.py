"""Property-based tests (hypothesis) on system invariants."""

import numpy as np
import pytest

pytest.importorskip("hypothesis",
                    reason="hypothesis is a dev extra; install with [dev]")

from hypothesis import HealthCheck, given, settings, strategies as st

from repro.core.contractions import (ContractionSpec, execute,
                                     execute_reference,
                                     generate_algorithms)
from repro.core.fitting import fit_relative, monomial_basis, relative_errors
from repro.core.grids import Domain, grid_points
from repro.core.sampler import Stats
from repro.train.compression import compress_tree, decompress_tree, init_error

import jax.numpy as jnp


@settings(max_examples=25, deadline=None)
@given(lo=st.integers(8, 256), width=st.integers(16, 2048),
       n=st.integers(2, 7),
       kind=st.sampled_from(["cartesian", "chebyshev"]))
def test_grid_points_inside_and_rounded(lo, width, n, kind):
    dom = Domain((lo,), (lo + width,))
    pts = grid_points(dom, (n,), kind=kind, round_to=8)
    assert pts, (lo, width, n)
    for p in pts:
        assert dom.contains(p)
        assert p[0] % 8 == 0


@settings(max_examples=25, deadline=None)
@given(lo=st.tuples(st.integers(8, 128), st.integers(8, 128)),
       w=st.tuples(st.integers(64, 1024), st.integers(64, 1024)))
def test_domain_split_partitions(lo, w):
    dom = Domain(lo, (lo[0] + w[0], lo[1] + w[1]))
    a, b, d = dom.split()
    # the two halves share exactly the split plane and cover the domain
    assert a.lo == dom.lo and b.hi == dom.hi
    assert a.hi[d] == b.lo[d]
    assert a.widths()[d] < dom.widths()[d]
    assert b.widths()[d] < dom.widths()[d]


@settings(max_examples=20, deadline=None)
@given(samples=st.lists(st.floats(1e-6, 1e3), min_size=1, max_size=50))
def test_stats_invariants(samples):
    s = Stats.from_samples(samples)
    assert s.min <= s.med <= s.max
    assert s.min <= s.mean <= s.max
    assert s.std >= 0


@settings(max_examples=15, deadline=None)
@given(coefs=st.lists(st.floats(1e-9, 1e-3), min_size=3, max_size=3),
       seed=st.integers(0, 100))
def test_exact_polynomials_fit_exactly(coefs, seed):
    """Relative LSQ recovers any positive polynomial in the basis span."""
    rng = np.random.default_rng(seed)
    pts = rng.integers(8, 512, size=(30, 1)).astype(float)
    c0, c1, c2 = coefs
    y = c0 + c1 * pts[:, 0] + c2 * pts[:, 0] ** 2
    poly = fit_relative(pts, y, monomial_basis([(2,)]))
    assert relative_errors(poly, pts, y).max() < 1e-6


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 1000))
def test_contraction_algorithms_agree(seed):
    """Every generated algorithm computes the same contraction."""
    rng = np.random.default_rng(seed)
    spec = ContractionSpec.parse("ab=ai,ib")
    sizes = dict(a=int(rng.integers(2, 10)), b=int(rng.integers(2, 10)),
                 i=int(rng.integers(2, 8)))
    A = rng.standard_normal((sizes["a"], sizes["i"])).astype(np.float32)
    B = rng.standard_normal((sizes["i"], sizes["b"])).astype(np.float32)
    ref = execute_reference(spec, A, B)
    for alg in generate_algorithms(spec):
        got = execute(alg, A, B, sizes)
        np.testing.assert_allclose(got, ref, rtol=1e-3, atol=1e-3)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 1000), rows=st.integers(1, 40),
       cols=st.integers(1, 40))
def test_compression_bounded_error(seed, rows, cols):
    rng = np.random.default_rng(seed)
    g = {"w": jnp.asarray(rng.standard_normal((rows, cols)), jnp.float32)}
    q, err = compress_tree(g, init_error(g))
    deq = decompress_tree(q, g)
    # int8 with per-chunk scales: max error <= scale/2 <= max|x|/254
    max_err = float(jnp.max(jnp.abs(deq["w"] - g["w"])))
    bound = float(jnp.max(jnp.abs(g["w"]))) / 127.0 + 1e-7
    assert max_err <= bound


# ------------------------------------------------ size-parametric models --

def _parametric_session(slope, intercept):
    """A parametric session whose measure_fn is linear in call_bytes."""
    from repro.core.sampler import Stats
    from repro.tc import PredictorSession
    from repro.tc.suite import MicroBenchmarkSuite

    def measure(key, repetitions):
        t = slope * key.call_bytes + intercept
        return Stats(0.95 * t, t, 1.1 * t, 1.01 * t, 0.02 * t), 1e-3

    return PredictorSession(suite=MicroBenchmarkSuite(measure_fn=measure),
                            parametric=True)


_PARAM_GRID = [dict(b=8, i=i, j=64, k=64) for i in (32, 96)]


@settings(max_examples=5, deadline=None)
@given(slope=st.floats(1e-10, 1e-8), intercept=st.floats(1e-7, 1e-5))
def test_parametric_refit_is_bit_stable(slope, intercept):
    """Two sessions fitting the same measurements produce identical models
    down to the polynomial coefficients — refinement is deterministic."""
    sessions = [_parametric_session(slope, intercept) for _ in range(2)]
    for sess in sessions:
        sess.refine_parametric("bij,bjk->bik", _PARAM_GRID)
    a, b = (s.parametric.models for s in sessions)
    assert set(a) == set(b)
    for sig in a:
        ma, mb = a[sig], b[sig]
        assert ma.domain == mb.domain
        assert ma.first_poly.coeffs.tolist() == mb.first_poly.coeffs.tolist()
        assert len(ma.case.pieces) == len(mb.case.pieces)
        for pa, pb in zip(ma.case.pieces, mb.case.pieces):
            assert pa.domain == pb.domain
            for s in ("min", "med", "max", "mean", "std"):
                assert pa.polys[s].coeffs.tolist() == \
                    pb.polys[s].coeffs.tolist()


@settings(max_examples=5, deadline=None)
@given(slope=st.floats(1e-10, 1e-8), intercept=st.floats(1e-7, 1e-5),
       queries=st.lists(st.integers(4, 12), min_size=2, max_size=5,
                        unique=True))
def test_parametric_predictions_monotone_in_flops(slope, intercept, queries):
    """Runtimes monotone in FLOP count stay monotone through the fit:
    along one growing size dimension, predicted medians never decrease."""
    sess = _parametric_session(slope, intercept)
    sess.refine_parametric("bij,bjk->bik", _PARAM_GRID)
    sig, model = sorted(sess.parametric.models.items(),
                        key=lambda kv: (kv[0].equation, kv[0].classes))[0]
    lo, hi = model.domain.lo, model.domain.hi
    grow = max(range(len(lo)), key=lambda d: hi[d] - lo[d])
    span = hi[grow] - lo[grow]
    meds = []
    for q in sorted(queries):
        point = tuple(lo[d] + (span * q // 16 if d == grow else 0)
                      for d in range(len(lo)))
        pred = model.predict(point)
        assert pred is not None
        meds.append(pred[0].med)
    assert meds == sorted(meds)


@settings(max_examples=5, deadline=None,
          suppress_health_check=[HealthCheck.function_scoped_fixture])
@given(slope=st.floats(1e-10, 1e-8), intercept=st.floats(1e-7, 1e-5))
def test_parametric_store_roundtrip_bit_exact(tmp_path, slope, intercept):
    """The parametric ModelSet payload survives a save/load bit-exactly
    (json floats round-trip via repr) for arbitrary fitted coefficients."""
    from repro.store import PARAMETRIC_MODEL_SET, ModelStore

    sess = _parametric_session(slope, intercept)
    sess.refine_parametric("bij,bjk->bik", _PARAM_GRID)
    path = tmp_path / f"store-{slope!r}-{intercept!r}.json"
    store = sess.save_store(path)
    loaded = ModelStore.load(path, fingerprint=store.fingerprint)
    assert PARAMETRIC_MODEL_SET in loaded.model_sets
    assert loaded.to_payload() == store.to_payload()
    # and the reloaded models predict bit-identically at a held-out shape
    from repro.tc import PredictorSession
    warm = PredictorSession(store=path)
    sizes = dict(b=8, i=40, j=64, k=64)
    a = [(r.name, r.runtime)
         for r in sess.rank_contraction_algorithms("bij,bjk->bik", sizes)]
    b = [(r.name, r.runtime)
         for r in warm.rank_contraction_algorithms("bij,bjk->bik", sizes)]
    assert a == b
    assert warm.suite.measured == 0
