"""Unit tests: polynomial fitting, grids, error measures (paper §3.2)."""

import numpy as np
import pytest

from repro.core import (Domain, GeneratorConfig, Polynomial, error_measure,
                        fit_relative, grid_points, monomial_basis, refine,
                        relative_errors)
from repro.core.grids import reused_points
from repro.core.sampler import Stats


def test_monomial_basis_trsm_example():
    # Example 3.12: cost m^2 n -> 6 monomials
    basis = monomial_basis([(2, 1)])
    assert len(basis) == 6
    assert (0, 0) in basis and (2, 1) in basis
    # with overfit +1 -> 12 monomials
    assert len(monomial_basis([(2, 1)], overfit=1)) == 12


def test_monomial_basis_union():
    basis = monomial_basis([(1, 2), (0, 3)])
    assert (1, 2) in basis and (0, 3) in basis
    assert (1, 3) not in basis


def test_fit_exact_polynomial():
    rng = np.random.default_rng(0)
    pts = rng.integers(8, 512, size=(40, 2)).astype(float)
    y = 3e-9 * pts[:, 0] ** 2 * pts[:, 1] + 5e-6
    poly = fit_relative(pts, y, monomial_basis([(2, 1)]))
    errs = relative_errors(poly, pts, y)
    assert errs.max() < 1e-8


def test_error_measures():
    errs = np.array([0.01, 0.02, 0.03, 0.5])
    assert error_measure(errs, "maximum") == pytest.approx(0.5)
    assert error_measure(errs, "average") == pytest.approx(np.mean(errs))
    assert error_measure(errs, "p90") <= 0.5


def test_grid_rounding_and_bounds():
    dom = Domain((24, 24), (536, 4152))
    for kind in ("cartesian", "chebyshev"):
        pts = grid_points(dom, (5, 6), kind=kind, round_to=8)
        for p in pts:
            assert dom.contains(p)
            assert p[0] % 8 == 0 and p[1] % 8 == 0


def test_cartesian_reuse_after_split():
    dom = Domain((0, 0), (512, 512))
    pts = grid_points(dom, (5, 5), kind="cartesian", round_to=8)
    lo, hi, d = dom.split()
    reused = reused_points(pts, lo)
    assert len(reused) >= len(pts) // 2 - 5


def test_domain_split_relative_largest():
    dom = Domain((24, 24), (536, 4152))
    lo, hi, d = dom.split()
    assert d == 1                      # n range is relatively larger
    assert lo.hi[1] == hi.lo[1]
    assert lo.hi[1] % 8 == 0


def test_refine_synthetic_converges():
    # piecewise behaviour: two regimes -> refinement must subdivide
    def timer(point):
        m, n = point
        base = 1e-9 * m * m * n + 1e-5
        if n > 520:
            base *= 2.0                # regime change mid-domain
        return base

    def sample(points):
        return {p: Stats(min=timer(p), med=timer(p), max=timer(p),
                         mean=timer(p), std=1e-9) for p in points}

    cfg = GeneratorConfig(overfit=0, oversampling=4, repetitions=1,
                          error_bound=0.01, min_width=32)
    pieces = refine(Domain((24, 24), (264, 1032)), sample, [(2, 1)], cfg)
    assert len(pieces) >= 2
    # every piece accurate at its own samples by construction; check center
    for piece in pieces:
        c = tuple((l + h) // 2 for l, h in zip(piece.domain.lo,
                                               piece.domain.hi))
        pred = piece.estimate(c)["med"]
        true = timer(c)
        assert abs(pred - true) / true < 0.15


def test_polynomial_serialization_roundtrip():
    pts = np.array([[8.0, 8.0], [16, 8], [8, 16], [64, 64], [128, 256],
                    [256, 128]])
    y = 2e-9 * pts[:, 0] * pts[:, 1] + 1e-6
    poly = fit_relative(pts, y, monomial_basis([(1, 1)]))
    poly2 = Polynomial.from_dict(poly.to_dict())
    q = np.array([[100.0, 200.0]])
    assert poly(q) == pytest.approx(poly2(q))
