"""Tests for the reprolint static-analysis package (tools/lint).

Each checker gets positive (finding fires) and negative (clean code
passes) fixtures built from inline sources, plus pragma suppression, a
baseline round-trip, and — the gate that matters — a self-check that the
repository itself lints clean, since CI runs ``python -m tools.lint`` as
a hard step before the test lane.
"""

from __future__ import annotations

import json
import sys
import textwrap
from collections import Counter
from pathlib import Path

import pytest

ROOT = Path(__file__).resolve().parents[1]
if str(ROOT) not in sys.path:                 # tools/ is not on PYTHONPATH
    sys.path.insert(0, str(ROOT))

from tools.lint import (REGISTRY, Finding, load_baseline,       # noqa: E402
                        run_lint, write_baseline)
from tools.lint.core import FileContext, python_snippets        # noqa: E402
from tools.lint.checkers.deprecated_kwargs import (             # noqa: E402
    deprecated_call_findings)


def ctx(source: str, rel: str = "src/repro/x.py") -> FileContext:
    return FileContext(rel, textwrap.dedent(source))


def findings_of(checker_id: str, source: str,
                rel: str = "src/repro/x.py"):
    return list(REGISTRY[checker_id].check_file(ctx(source, rel)))


# ---------------------------------------------------------------- host-sync --

HOT_SYNC = """
    import jax

    # reprolint: hot-path
    def tick(state):
        jax.block_until_ready(state)
        return state
"""


def test_host_sync_flags_marked_hot_path():
    out = findings_of("host-sync", HOT_SYNC)
    assert len(out) == 1
    assert "block_until_ready" in out[0].message
    assert out[0].line == 6


def test_host_sync_ignores_cold_functions():
    assert not findings_of("host-sync", """
        import jax

        def offline_report(state):
            jax.block_until_ready(state)   # fine: not a hot context
            return state
    """)


def test_host_sync_flags_jitted_bodies_and_nested_defs():
    out = findings_of("host-sync", """
        import jax

        @jax.jit
        def step(x):
            def inner():
                return float(x)
            return inner()
    """)
    assert len(out) == 1 and "float()" in out[0].message


@pytest.mark.parametrize("stmt,tag", [
    ("x.item()", ".item()"),
    ("np.asarray(x)", "np.asarray"),
    ("float(x)", "float()"),
])
def test_host_sync_forms(stmt, tag):
    out = findings_of("host-sync", f"""
        import numpy as np

        # reprolint: hot-path
        def tick(x):
            return {stmt}
    """)
    assert len(out) == 1 and tag in out[0].message


def test_host_sync_float_of_literal_is_fine():
    assert not findings_of("host-sync", """
        # reprolint: hot-path
        def tick(x):
            return float("inf")
    """)


def test_host_sync_hot_paths_table_matches_repo():
    """The built-in hot-path table only names functions that exist (a
    rename would silently drop coverage)."""
    import ast as ast_mod

    from tools.lint.checkers.host_sync import HOT_PATHS
    for rel, quals in HOT_PATHS.items():
        tree = ast_mod.parse((ROOT / rel).read_text())
        names = set()
        for node in ast_mod.walk(tree):
            if isinstance(node, ast_mod.ClassDef):
                names.update(f"{node.name}.{m.name}" for m in node.body
                             if isinstance(m, ast_mod.FunctionDef))
            elif isinstance(node, ast_mod.FunctionDef):
                names.add(node.name)
        missing = quals - names
        assert not missing, (rel, missing)


# ------------------------------------------------------------------ retrace --

def test_retrace_flags_undeclared_bool_param():
    out = findings_of("retrace", """
        import jax

        @jax.jit
        def f(x, causal: bool = False):
            return x
    """)
    assert len(out) == 1 and "causal" in out[0].message


def test_retrace_static_argnames_is_clean():
    assert not findings_of("retrace", """
        from functools import partial
        import jax

        @partial(jax.jit, static_argnames=("causal",))
        def f(x, causal: bool = False):
            return x
    """)


def test_retrace_static_argnums_resolves_positions():
    assert not findings_of("retrace", """
        from functools import partial
        import jax

        @partial(jax.jit, static_argnums=(1,))
        def f(x, mode="fast"):
            return x
    """)


def test_retrace_flags_branch_on_traced_param():
    out = findings_of("retrace", """
        import jax

        @jax.jit
        def f(x, n):
            if n > 3:
                return x * 2
            return x
    """)
    assert len(out) == 1 and "branches on traced" in out[0].message


def test_retrace_int_default_not_flagged():
    """Python int/float defaults trace as weak-typed operands without
    retracing — only branching on them is a hazard."""
    assert not findings_of("retrace", """
        import jax

        @jax.jit
        def f(x, scale=2.0, shift=1):
            return x * scale + shift
    """)


def test_retrace_jit_call_site_resolves_module_def():
    out = findings_of("retrace", """
        import jax

        def f(x, interpret=False):
            return x

        g = jax.jit(f)
    """)
    assert len(out) == 1 and "interpret" in out[0].message


# ---------------------------------------------------------- deprecated-kwarg --

def test_deprecated_kwarg_flags_legacy_call():
    out = findings_of("deprecated-kwarg", """
        from repro.tc import rank_contraction_sweep

        sweep = rank_contraction_sweep(spec, grid, suite=s, cache=c)
    """)
    assert len(out) == 1
    assert "PredictorSession" in out[0].message
    assert "cache=" in out[0].message and "suite=" in out[0].message


def test_deprecated_kwarg_session_form_is_clean():
    assert not findings_of("deprecated-kwarg", """
        sweep = rank_contraction_sweep(spec, grid, session=sess)
        ranked = sess.rank_contraction_sweep(spec, grid)
    """)


def test_deprecated_kwarg_explicit_none_forwarding_is_clean():
    assert not findings_of("deprecated-kwarg", """
        ranked = rank_einsum_paths(chain, sizes, backend=None,
                                   session=sess)
    """)


def test_deprecated_call_findings_reusable_entry():
    """tools/check_docs.py consumes this function directly."""
    import ast as ast_mod
    tree = ast_mod.parse(
        "rank_einsum_sweep(c, g, suite=s)")
    out = deprecated_call_findings(tree, "docs/x.md")
    assert len(out) == 1 and out[0].path == "docs/x.md"


# ----------------------------------------------------------- oracle-coverage --

def _oracle_repo(tmp_path: Path, test_body: str) -> Path:
    (tmp_path / "src").mkdir()
    (tmp_path / "tests").mkdir()
    (tmp_path / "src" / "sel.py").write_text(textwrap.dedent("""
        def select_algorithm(tracers, models, n, b):
            return "x"
    """))
    (tmp_path / "tests" / "test_sel.py").write_text(
        textwrap.dedent(test_body))
    return tmp_path


def _oracle_findings(root: Path):
    ctxs = [FileContext("src/sel.py", (root / "src" / "sel.py").read_text())]
    return [f for f in REGISTRY["oracle-coverage"].check_repo(ctxs, root)
            if "select_algorithm" in f.message]


def test_oracle_coverage_flags_untested_entry_point(tmp_path):
    out = _oracle_findings(_oracle_repo(tmp_path, """
        def test_nothing():
            pass
    """))
    assert len(out) == 1 and "no test module" in out[0].message
    assert (out[0].path, out[0].line) == ("src/sel.py", 2)


def test_oracle_coverage_flags_unpinned_fast_path(tmp_path):
    out = _oracle_findings(_oracle_repo(tmp_path, """
        def test_select():
            assert select_algorithm(t, m, 512, 64) == "a"
    """))
    assert len(out) == 1 and "unpinned" in out[0].message


def test_oracle_coverage_oracle_kwarg_satisfies(tmp_path):
    out = _oracle_findings(_oracle_repo(tmp_path, """
        def test_select():
            got = select_algorithm(t, m, 512, 64)
            ref = select_algorithm(t, m, 512, 64, batched=False)
            assert got == ref
    """))
    assert not out


def test_oracle_coverage_oracle_call_satisfies(tmp_path):
    out = _oracle_findings(_oracle_repo(tmp_path, """
        def test_select():
            assert select_algorithm(t, m, 512, 64) == "a"
            assert predict_runtime(calls, m).med > 0
    """))
    assert not out


def _refine_repo(tmp_path: Path, test_body: str) -> Path:
    (tmp_path / "src").mkdir()
    (tmp_path / "tests").mkdir()
    (tmp_path / "src" / "param.py").write_text(textwrap.dedent("""
        def refine_parametric(spec, grid):
            return {}
    """))
    (tmp_path / "tests" / "test_param.py").write_text(
        textwrap.dedent(test_body))
    return tmp_path


def _refine_findings(root: Path):
    ctxs = [FileContext("src/param.py",
                        (root / "src" / "param.py").read_text())]
    return [f for f in REGISTRY["oracle-coverage"].check_repo(ctxs, root)
            if "refine_parametric" in f.message]


def test_oracle_coverage_flags_unpinned_refine_parametric(tmp_path):
    # parametric predictions without a measured-oracle comparison are
    # exactly the "plausible but unpinned fast path" the checker exists for
    out = _refine_findings(_refine_repo(tmp_path, """
        def test_refine():
            assert sess.refine_parametric(spec, grid)["measured"] > 0
    """))
    assert len(out) == 1 and "unpinned" in out[0].message
    assert "benchmark_fresh" in out[0].message
    assert (out[0].path, out[0].line) == ("src/param.py", 2)


def test_oracle_coverage_measured_oracle_pins_refine_parametric(tmp_path):
    out = _refine_findings(_refine_repo(tmp_path, """
        def test_refine():
            sess.refine_parametric(spec, grid)
            fresh = suite.benchmark_fresh(alg, sizes)
            assert predicted.med == pytest.approx(fresh.stats.med)
    """))
    assert not out


def test_oracle_coverage_rank_oracle_pins_refine_parametric(tmp_path):
    out = _refine_findings(_refine_repo(tmp_path, """
        def test_refine():
            sess.refine_parametric(spec, grid)
            assert ranking[0].name == pred.rank_oracle()[0].name
    """))
    assert not out


# ----------------------------------------------------------- metric-tracking --

_RUN_PY = """
    SUITES = {
        "alpha": (bench_alpha, "desc"),
    }
    SMOKE_SUITES = ("alpha",)
"""

_COMPARE_PY = """
    METRICS = (
        ("alpha", "alpha_rank_s", False),
    )
    UNTRACKED = (
        ("alpha", "alpha_points"),
    )
    BACKEND_RATIOS = ()
    SERVING_RATIOS = ()
"""


def _metric_findings(bench_body: str, compare_py: str = _COMPARE_PY):
    ctxs = [
        FileContext("benchmarks/run.py", textwrap.dedent(_RUN_PY)),
        FileContext("benchmarks/compare_smoke.py",
                    textwrap.dedent(compare_py)),
        FileContext("benchmarks/bench_alpha.py",
                    textwrap.dedent(bench_body)),
    ]
    return list(REGISTRY["metric-tracking"].check_repo(ctxs, ROOT))


def test_metric_tracking_clean_when_all_known():
    assert not _metric_findings("""
        def run(report, results=None):
            results.update({"alpha_rank_s": 0.1})
            results["alpha_points"] = 3
    """)


def test_metric_tracking_flags_unknown_metric():
    out = _metric_findings("""
        def run(report, results=None):
            results.update({"alpha_rank_s": 0.1, "alpha_mystery": 1})
            results["alpha_points"] = 3
    """)
    assert len(out) == 1 and "alpha_mystery" in out[0].message
    assert out[0].path == "benchmarks/bench_alpha.py"


def test_metric_tracking_flags_non_literal_key():
    out = _metric_findings("""
        def run(report, results=None):
            results[f"alpha_{n}_s"] = 0.1
            results.update({"alpha_rank_s": 0.1})
            results["alpha_points"] = 3
    """)
    assert len(out) == 1 and "non-literal" in out[0].message


def test_metric_tracking_flags_unit_alias():
    out = _metric_findings("""
        def run(report, results=None):
            results.update({"alpha_rank_s": 0.1, "alpha_cost_msec": 2})
    """)
    msgs = " | ".join(f.message for f in out)
    assert "alpha_cost_msec" in msgs and "'_ms'" in msgs


def test_metric_tracking_flags_stale_table_row():
    out = _metric_findings("""
        def run(report, results=None):
            results.update({"alpha_rank_s": 0.1})
    """)
    assert len(out) == 1 and "stale table row" in out[0].message
    assert out[0].path == "benchmarks/compare_smoke.py"


# ------------------------------------------------------------ store-schema --

def test_store_schema_clean_writer():
    assert not findings_of("store-schema", """
        import json
        SCHEMA_VERSION = 1

        def save(path, data):
            payload = {"schema_version": SCHEMA_VERSION, "data": data}
            with open(path, "w") as f:
                json.dump(payload, f)
    """, rel="src/repro/store/writer.py")


def test_store_schema_imported_constant_is_clean():
    assert not findings_of("store-schema", """
        import json
        from .modelstore import SCHEMA_VERSION

        def save(path):
            json.dump({"schema_version": SCHEMA_VERSION}, open(path, "w"))
    """, rel="src/repro/store/other.py")


def test_store_schema_flags_writer_without_constant():
    out = findings_of("store-schema", """
        import json

        def save(path, data):
            json.dump({"data": data}, open(path, "w"))
    """, rel="src/repro/store/writer.py")
    assert len(out) == 1 and "SCHEMA_VERSION" in out[0].message


def test_store_schema_flags_unstamped_payload():
    out = findings_of("store-schema", """
        import json
        SCHEMA_VERSION = 1

        def save(path, data):
            json.dump({"data": data}, open(path, "w"))
    """, rel="src/repro/store/writer.py")
    assert len(out) == 1 and "schema_version" in out[0].message


def test_store_schema_flags_hardcoded_version_everywhere():
    out = findings_of("store-schema", """
        payload = {"schema_version": 1}
    """, rel="benchmarks/bench_x.py")
    assert len(out) == 1 and "hard-coded" in out[0].message


def test_store_schema_ignores_json_outside_store_package():
    assert not findings_of("store-schema", """
        import json

        def save(path, data):
            json.dump({"data": data}, open(path, "w"))
    """, rel="src/repro/core/model.py")


# ------------------------------------------------- pragmas, baseline, runner --

def test_pragma_suppresses_on_line_and_line_above():
    trailing = ctx("""
        import jax

        # reprolint: hot-path
        def tick(state):
            jax.block_until_ready(state)  # reprolint: allow[host-sync]
    """)
    above = ctx("""
        import jax

        # reprolint: hot-path
        def tick(state):
            # reprolint: allow[host-sync]
            jax.block_until_ready(state)
    """)
    for c in (trailing, above):
        f = list(REGISTRY["host-sync"].check_file(c))[0]
        assert c.allowed(f.checker, f.line)
    assert not above.allowed("retrace", 6)      # other checkers unaffected
    assert ctx("x = 1  # reprolint: allow[*]").allowed("host-sync", 1)


def test_docs_snippets_are_linted_with_md_line_numbers(tmp_path):
    (tmp_path / "docs").mkdir()
    (tmp_path / "src").mkdir()
    md = tmp_path / "docs" / "guide.md"
    md.write_text(textwrap.dedent("""\
        # Guide

        ```python
        sweep = rank_contraction_sweep(spec, grid, suite=s)
        ```
    """))
    result = run_lint(tmp_path, baseline=Counter())
    assert [(f.checker, f.path, f.line) for f in result.findings] == \
        [("deprecated-kwarg", "docs/guide.md", 4)]


def test_python_snippets_honors_skip_mark(tmp_path):
    md = tmp_path / "x.md"
    md.write_text(textwrap.dedent("""\
        <!-- docs-check: skip -->
        ```python
        not python at all (
        ```
        ```python
        ok = 1
        ```
    """))
    assert [src for _, src in python_snippets(md)] == ["ok = 1"]


def test_baseline_round_trip(tmp_path):
    f1 = Finding("host-sync", "src/a.py", 10, "msg one")
    f2 = Finding("retrace", "src/b.py", 20, "msg two")
    path = tmp_path / "baseline.json"
    write_baseline([f1, f2], path)
    base = load_baseline(path)
    assert base == Counter({f1.key(): 1, f2.key(): 1})
    # line numbers are deliberately absent: drift must not invalidate it
    assert "10" not in json.dumps(json.loads(path.read_text())["findings"])


def test_baseline_grandfathers_exactly_once(tmp_path):
    (tmp_path / "src").mkdir()
    (tmp_path / "src" / "a.py").write_text(textwrap.dedent("""
        import jax

        # reprolint: hot-path
        def tick(state):
            jax.block_until_ready(state)
            return state
    """))
    live = run_lint(tmp_path, baseline=Counter())
    assert len(live.findings) == 1
    base = Counter({f.key(): 1 for f in live.findings})
    again = run_lint(tmp_path, baseline=base)
    assert not again.findings and len(again.baselined) == 1


def test_parse_error_is_a_finding_not_a_crash(tmp_path):
    (tmp_path / "src").mkdir()
    (tmp_path / "src" / "bad.py").write_text("def f(:\n")
    result = run_lint(tmp_path, baseline=Counter())
    assert [f.checker for f in result.findings] == ["parse"]


def test_finding_render_formats():
    f = Finding("host-sync", "src/a.py", 3, "boom")
    assert f.render() == "src/a.py:3: [host-sync] boom"
    assert f.render_github() == \
        "::error file=src/a.py,line=3,title=reprolint host-sync::boom"


def test_registry_has_the_six_checkers():
    assert set(REGISTRY) == {"host-sync", "retrace", "deprecated-kwarg",
                             "oracle-coverage", "metric-tracking",
                             "store-schema"}


# -------------------------------------------------------------- repo gate --

def test_repository_lints_clean():
    """The gate CI enforces: the repo itself has no active findings."""
    result = run_lint(ROOT)
    assert not result.findings, "\n" + "\n".join(
        f.render() for f in result.findings)
