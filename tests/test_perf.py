"""Perf-layer tests: HLO collective parsing, trip-count scaling, roofline
terms, tile tuner, analytic cost model."""

import pytest

from repro.perf.analytic import cell_cost, forward_flops
from repro.perf.hlo_scale import scaled_collective_bytes, split_computations
from repro.perf.roofline import (RooflineTerms, collective_bytes,
                                 model_flops_for)
from repro.perf.tile_tuner import predict_tile_time, select_tiles
from repro.configs import SHAPES, get_config

_HLO = """\
HloModule test

%body.1 (p: (s32[], f32[128,256])) -> (s32[], f32[128,256]) {
  %ar = f32[128,256]{1,0} all-reduce(%x), replica_groups={{0,1,2,3}}, to_apply=%add
  %ag = f32[512,256]{1,0} all-gather(%y), replica_groups=[4,4]<=[16], dimensions={0}
  ROOT %t = tuple(%i, %ar)
}

%cond.1 (p: (s32[], f32[128,256])) -> pred[] {
  %c = s32[] constant(10)
  ROOT %cmp = pred[] compare(%i, %c), direction=LT
}

ENTRY %main (a: f32[128,256]) -> f32[128,256] {
  %rs = f32[32,256]{1,0} reduce-scatter(%a), replica_groups={{0,1,2,3}}, dimensions={0}
  %w = (s32[], f32[128,256]) while(%init), condition=%cond.1, body=%body.1
  ROOT %out = f32[128,256] get-tuple-element(%w), index=1
}
"""


def test_collective_bytes_flat():
    cb = collective_bytes(_HLO)
    assert cb["all-reduce"] == 128 * 256 * 4
    # all-gather result / group size (groups of 4)
    assert cb["all-gather"] == 512 * 256 * 4 // 4
    # reduce-scatter result * group size
    assert cb["reduce-scatter"] == 32 * 256 * 4 * 4


def test_scaled_collectives_multiply_by_trip_count():
    comps = split_computations(_HLO)
    assert set(comps) >= {"body.1", "cond.1", "main"}
    cb = scaled_collective_bytes(_HLO)
    assert cb["all-reduce"] == 10 * 128 * 256 * 4
    assert cb["reduce-scatter"] == 32 * 256 * 4 * 4   # outside the loop


def test_roofline_terms_dominance():
    t = RooflineTerms(flops=197e12, bytes_accessed=0.0,
                      coll_bytes={"all-reduce": 0}, n_devices=1,
                      model_flops=197e12)
    assert t.dominant == "compute"
    assert t.compute_s == pytest.approx(1.0)
    assert t.roofline_fraction == pytest.approx(1.0)
    t2 = RooflineTerms(flops=1.0, bytes_accessed=819e9,
                       coll_bytes={"all-reduce": 0}, n_devices=1)
    assert t2.dominant == "memory"
    assert t2.memory_s == pytest.approx(1.0)


def test_model_flops_factors():
    cfg = get_config("deepseek-7b")
    train = model_flops_for(cfg, SHAPES["train_4k"])
    prefill = model_flops_for(cfg, SHAPES["prefill_32k"])
    n = cfg.param_count(active_only=True)
    assert train == pytest.approx(6 * n * 256 * 4096)
    assert prefill == pytest.approx(2 * n * 32 * 32768)


def test_moe_uses_active_params():
    cfg = get_config("arctic-480b")
    mf = model_flops_for(cfg, SHAPES["train_4k"])
    assert mf < 6 * cfg.param_count() * 256 * 4096 / 10  # 128e top-2


def test_analytic_flops_close_to_model_flops():
    """Analytic forward FLOPs must be within ~2x of 2*N*D for dense LMs."""
    for arch in ("deepseek-7b", "gemma2-27b", "phi3-mini-3.8b"):
        cfg = get_config(arch)
        fwd = forward_flops(cfg, 1, 4096)
        ref = 2 * cfg.param_count(active_only=True) * 4096
        assert 0.8 < fwd / ref < 2.2, (arch, fwd / ref)


def test_cell_cost_kinds():
    cfg = get_config("mamba2-2.7b")
    tr = cell_cost(cfg, SHAPES["train_4k"])
    de = cell_cost(cfg, SHAPES["long_500k"])
    assert tr.flops > de.flops             # decode is one token
    assert de.hbm_bytes > 0


def test_tile_tuner_selects_legal_aligned():
    c = select_tiles(4096, 4096, 4096)
    assert c.bm % 128 == 0 and c.bn % 128 == 0 and c.bk % 128 == 0
    # small matrices: clamped tiles
    c2 = select_tiles(64, 64, 64, candidates=(64, 128))
    assert (c2.bm, c2.bn, c2.bk) == (64, 64, 64)


def test_tile_tuner_selection_is_argmin_and_vmem_safe():
    from repro.kernels.matmul import vmem_bytes

    choice = select_tiles(4096, 4096, 4096)
    # selected tile fits VMEM and beats (or ties) other legal candidates
    assert vmem_bytes(choice.bm, choice.bn, choice.bk) <= 16 * 2 ** 20
    for cand in ((128, 128, 128), (256, 256, 128), (512, 128, 128)):
        t = predict_tile_time(4096, 4096, 4096, *cand)
        assert choice.predicted_s <= t * (1 + 1e-9)


def test_dryrun_artifacts_exist_and_complete():
    """The committed dry-run sweep must cover every (arch x shape x mesh)."""
    import json
    from pathlib import Path

    from repro.configs import all_configs

    d = Path(__file__).resolve().parents[1] / "experiments" / "dryrun"
    if not d.exists():
        pytest.skip("dry-run artifacts not generated yet")
    for arch, cfg in all_configs().items():
        for shape in cfg.shapes:
            for mesh in ("16x16", "2x16x16"):
                f = d / f"{arch}__{shape}__{mesh}.json"
                assert f.exists(), f.name
                meta = json.loads(f.read_text())
                assert meta["compute_s"] > 0
                assert meta["memory"]["temp_size_in_bytes"] >= 0
