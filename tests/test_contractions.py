"""Tests for Ch. 6: contraction algorithm generation + prediction."""

import numpy as np
import pytest

from repro.core.contractions import (ContractionAlgorithm, ContractionSpec,
                                     access_distance, cold_pool_size,
                                     execute, execute_reference,
                                     generate_algorithms,
                                     predict_contraction,
                                     rank_contraction_algorithms)

RNG = np.random.default_rng(3)


def test_paper_example_has_36_algorithms():
    # C_abc := A_ai B_ibc has exactly 36 algorithms (Example 1.4)
    spec = ContractionSpec.parse("abc=ai,ibc")
    algs = generate_algorithms(spec)
    assert len(algs) == 36
    gemm = [a for a in algs if a.kernel == "gemm"]
    assert len(gemm) == 2              # the two dgemm-based algorithms


def test_parse_einsum_style():
    spec = ContractionSpec.parse("ai,ibc->abc")
    assert spec.contracted == ("i",)
    assert spec.out_idx == "abc"
    assert spec.flops({"a": 2, "b": 3, "c": 4, "i": 5}) == 2 * 2 * 3 * 4 * 5


@pytest.mark.slow
@pytest.mark.parametrize("expr,sizes", [
    ("abc=ai,ibc", dict(a=24, b=20, c=16, i=8)),
    ("a=iaj,ji", dict(a=16, i=8, j=12)),       # §6.3.2 vector contraction
    ("abc=ija,jbic", dict(a=8, b=8, c=8, i=6, j=6)),  # §6.3.3 challenging
])
def test_all_algorithms_correct(expr, sizes):
    spec = ContractionSpec.parse(expr)
    algs = generate_algorithms(spec)
    assert algs, expr
    A = RNG.standard_normal([sizes[i] for i in spec.a_idx]
                            ).astype(np.float32)
    B = RNG.standard_normal([sizes[i] for i in spec.b_idx]
                            ).astype(np.float32)
    ref = execute_reference(spec, A, B)
    # every algorithm computes the same contraction
    for alg in algs[::3]:              # stride for speed; all kernels hit
        got = execute(alg, A, B, sizes)
        np.testing.assert_allclose(got, ref, rtol=2e-4, atol=2e-4)


def test_batch_index_classification():
    # an index shared by A, B and C is a batch dimension, not a contraction
    spec = ContractionSpec.parse("bij,bjk->bik")
    assert spec.contracted == ("j",)
    assert spec.batch == ("b",)
    assert spec.all_indices == ("b", "i", "j", "k")
    # no batch index: nothing changes
    assert ContractionSpec.parse("ai,ibc->abc").batch == ()


def test_batched_spec_algorithms_match_reference():
    """Regression: `b` in bij,bjk->bik was misclassified as contracted, so
    the generator could hand batch dimensions to the kernel patterns.  Batch
    indices must only ever be loop indices, and every generated algorithm
    must reproduce the einsum reference."""
    spec = ContractionSpec.parse("bij,bjk->bik")
    algs = generate_algorithms(spec)
    assert algs
    for alg in algs:
        assert "b" not in alg.kernel_dims, alg.name
        assert "b" in alg.loop_order, alg.name
    sizes = dict(b=3, i=4, j=5, k=6)
    A = RNG.standard_normal([sizes[i] for i in spec.a_idx]).astype(np.float32)
    B = RNG.standard_normal([sizes[i] for i in spec.b_idx]).astype(np.float32)
    ref = execute_reference(spec, A, B)
    for alg in algs:
        got = execute(alg, A, B, sizes)
        np.testing.assert_allclose(got, ref, rtol=2e-4, atol=2e-4,
                                   err_msg=alg.name)


def test_access_distance_known_loop_nest():
    """Pin §6.2.3 access distances for hand-built loop nests (4-byte items).

    ``C[ab] = A[ai] * B[ib]`` with a dot kernel over ``i``: one call touches
    the two length-i fibers plus the scalar output — 4*(i + i + 1) bytes.
    """
    spec = ContractionSpec.parse("ab=ai,ib")
    sizes = dict(a=10, b=7, i=4)
    dot = ContractionAlgorithm(spec, "dot", ("i",), ("a", "b"))
    call_bytes = 4 * (4 + 4 + 1)
    d = access_distance(dot, sizes)
    # A[a,:] is reused only after the whole inner b-loop cycles
    assert d["A"] == call_bytes * sizes["b"]
    # B and C are indexed by the innermost loop: one working set apart
    assert d["B"] == call_bytes
    assert d["C"] == call_bytes
    # axpy over the a-fiber, loops (b, i): C[:, b] is constant across the
    # inner i-loop, so its reuse distance spans the i iterations
    axpy = ContractionAlgorithm(spec, "axpy_a", ("a",), ("b", "i"))
    call_bytes = 4 * (10 + 1 + 10)
    d = access_distance(axpy, sizes)
    assert d["A"] == call_bytes
    assert d["B"] == call_bytes
    assert d["C"] == call_bytes * sizes["i"]


def test_access_distance_loopless_and_untouched_operands():
    # no loops at all: a single gemm call computes everything; every operand
    # is one working set away (paper-correct: never distance 0 — a call
    # whose working set overflows the cache leaves nothing warm)
    spec = ContractionSpec.parse("ab=ai,ib")
    sizes = dict(a=10, b=7, i=4)
    gemm = ContractionAlgorithm(spec, "gemm", ("a", "b", "i"), ())
    call_bytes = 4 * (10 * 4 + 4 * 7 + 10 * 7)
    assert access_distance(gemm, sizes) == {
        "A": call_bytes, "B": call_bytes, "C": call_bytes}
    # operand not indexed by ANY loop (A below): touched every iteration,
    # one call's working set between consecutive uses — not 0
    spec2 = ContractionSpec.parse("abc=ai,ibc")
    sizes2 = dict(a=24, b=20, c=16, i=8)
    alg = ContractionAlgorithm(spec2, "gemm", ("a", "b", "i"), ("c",))
    call_bytes2 = 4 * (24 * 8 + 8 * 20 + 24 * 20)
    assert access_distance(alg, sizes2)["A"] == call_bytes2


def test_access_distance_monotonic():
    spec = ContractionSpec.parse("abc=ai,ibc")
    algs = generate_algorithms(spec)
    gemm = [a for a in algs if a.kernel == "gemm"][0]
    d = access_distance(gemm, dict(a=100, b=100, c=100, i=8))
    assert set(d) == {"A", "B", "C"}
    assert all(v >= 0 for v in d.values())


def test_cold_pool_not_capped():
    """Regression: the cold-operand pool was hard-capped at 8 buffers, so
    ``repetitions > 8`` cycled cold operands back into cache.  The pool must
    grow with the repetition count until it spans the cache capacity."""
    cache = 32 * 2 ** 20
    assert cold_pool_size(32, 4 * (4 + 4 + 1), cache) == 33
    # once cycling spans the cache, more buffers add nothing
    assert cold_pool_size(32, cache // 2, cache) == 3


def test_predict_contraction_includes_first_call_overhead():
    spec = ContractionSpec.parse("ab=ai,ib")
    sizes = dict(a=4, b=4, i=4)
    alg = ContractionAlgorithm(spec, "gemm", ("a", "b", "i"), ())
    bd = predict_contraction(alg, sizes, repetitions=2, breakdown=True)
    assert set(bd) == {"total_s", "first_call_s", "loop_s", "per_call_s",
                      "n_iterations"}
    assert bd["n_iterations"] == 1
    assert bd["first_call_s"] > 0
    assert bd["total_s"] == pytest.approx(
        bd["first_call_s"] + bd["per_call_s"] * bd["n_iterations"])
    assert bd["loop_s"] == pytest.approx(
        bd["per_call_s"] * bd["n_iterations"])


@pytest.mark.slow
def test_prediction_positive_and_scales():
    spec = ContractionSpec.parse("abc=ai,ibc")
    algs = generate_algorithms(spec)
    gemm = [a for a in algs if a.kernel == "gemm"][0]
    dot = [a for a in algs if a.kernel == "dot"][0]
    sizes = dict(a=32, b=32, c=32, i=8)
    t_gemm = predict_contraction(gemm, sizes, repetitions=3)
    t_dot = predict_contraction(dot, sizes, repetitions=3)
    assert t_gemm > 0 and t_dot > 0
    # a dot-based algorithm makes ~32x32x32 tiny calls: predicted slower
    assert t_dot > t_gemm


@pytest.mark.slow
def test_ranking_prefers_fewer_larger_calls():
    spec = ContractionSpec.parse("abc=ai,ibc")
    sizes = dict(a=32, b=32, c=32, i=8)
    algs = generate_algorithms(spec)
    pick = ([a for a in algs if a.kernel == "gemm"][:1] +
            [a for a in algs if a.kernel == "dot"][:1] +
            [a for a in algs if a.kernel == "ger"][:1])
    ranked = rank_contraction_algorithms(spec, sizes, algorithms=pick,
                                         repetitions=3)
    assert ranked[0][0].kernel in ("gemm", "ger")
    assert ranked[-1][0].kernel == "dot"
