"""Tests for Ch. 6: contraction algorithm generation + prediction."""

import numpy as np
import pytest

from repro.core.contractions import (ContractionSpec, access_distance,
                                     execute, execute_reference,
                                     generate_algorithms,
                                     predict_contraction,
                                     rank_contraction_algorithms)

RNG = np.random.default_rng(3)


def test_paper_example_has_36_algorithms():
    # C_abc := A_ai B_ibc has exactly 36 algorithms (Example 1.4)
    spec = ContractionSpec.parse("abc=ai,ibc")
    algs = generate_algorithms(spec)
    assert len(algs) == 36
    gemm = [a for a in algs if a.kernel == "gemm"]
    assert len(gemm) == 2              # the two dgemm-based algorithms


def test_parse_einsum_style():
    spec = ContractionSpec.parse("ai,ibc->abc")
    assert spec.contracted == ("i",)
    assert spec.out_idx == "abc"
    assert spec.flops({"a": 2, "b": 3, "c": 4, "i": 5}) == 2 * 2 * 3 * 4 * 5


@pytest.mark.slow
@pytest.mark.parametrize("expr,sizes", [
    ("abc=ai,ibc", dict(a=24, b=20, c=16, i=8)),
    ("a=iaj,ji", dict(a=16, i=8, j=12)),       # §6.3.2 vector contraction
    ("abc=ija,jbic", dict(a=8, b=8, c=8, i=6, j=6)),  # §6.3.3 challenging
])
def test_all_algorithms_correct(expr, sizes):
    spec = ContractionSpec.parse(expr)
    algs = generate_algorithms(spec)
    assert algs, expr
    A = RNG.standard_normal([sizes[i] for i in spec.a_idx]
                            ).astype(np.float32)
    B = RNG.standard_normal([sizes[i] for i in spec.b_idx]
                            ).astype(np.float32)
    ref = execute_reference(spec, A, B)
    # every algorithm computes the same contraction
    for alg in algs[::3]:              # stride for speed; all kernels hit
        got = execute(alg, A, B, sizes)
        np.testing.assert_allclose(got, ref, rtol=2e-4, atol=2e-4)


def test_access_distance_monotonic():
    spec = ContractionSpec.parse("abc=ai,ibc")
    algs = generate_algorithms(spec)
    gemm = [a for a in algs if a.kernel == "gemm"][0]
    d = access_distance(gemm, dict(a=100, b=100, c=100, i=8))
    assert set(d) == {"A", "B", "C"}
    assert all(v >= 0 for v in d.values())


@pytest.mark.slow
def test_prediction_positive_and_scales():
    spec = ContractionSpec.parse("abc=ai,ibc")
    algs = generate_algorithms(spec)
    gemm = [a for a in algs if a.kernel == "gemm"][0]
    dot = [a for a in algs if a.kernel == "dot"][0]
    sizes = dict(a=32, b=32, c=32, i=8)
    t_gemm = predict_contraction(gemm, sizes, repetitions=3)
    t_dot = predict_contraction(dot, sizes, repetitions=3)
    assert t_gemm > 0 and t_dot > 0
    # a dot-based algorithm makes ~32x32x32 tiny calls: predicted slower
    assert t_dot > t_gemm


@pytest.mark.slow
def test_ranking_prefers_fewer_larger_calls():
    spec = ContractionSpec.parse("abc=ai,ibc")
    sizes = dict(a=32, b=32, c=32, i=8)
    algs = generate_algorithms(spec)
    pick = ([a for a in algs if a.kernel == "gemm"][:1] +
            [a for a in algs if a.kernel == "dot"][:1] +
            [a for a in algs if a.kernel == "ger"][:1])
    ranked = rank_contraction_algorithms(spec, sizes, algorithms=pick,
                                         repetitions=3)
    assert ranked[0][0].kernel in ("gemm", "ger")
    assert ranked[-1][0].kernel == "dot"
