"""repro.core — measurement-based performance modeling & prediction.

The paper's primary contribution (Peise 2017): piecewise-polynomial kernel
performance models generated once per setup, instantaneous predictions of
blocked-algorithm runtime, algorithm ranking, block-size optimization, and
cache-aware micro-benchmarks for tensor contractions.
"""

from .fitting import (Polynomial, StackedPolynomials, error_measure,
                      fit_relative, monomial_basis, relative_errors,
                      stack_polynomials)
from .grids import Domain, grid_points
from .model import CaseModel, ModelSet, PerformanceModel, Piece
from .modelgen import (GenerationReport, KernelBenchmark, generate_model,
                       generate_model_set)
from .predict import (BACKENDS, CompiledCalls, FusedBatch, KernelCall,
                      PredictionEngine, TraceCache, absolute_relative_error,
                      compile_calls, predict_efficiency, predict_performance,
                      predict_runtime, relative_error)
from .refinement import GeneratorConfig, refine, stats_sample_fn
from .sampler import STATS, Stats, measure_calls, measure_single
from .selection import (RankedAlgorithm, optimize_algorithm_and_block_size,
                        optimize_block_size, performance_yield,
                        rank_algorithms, rank_einsum_paths, select_algorithm,
                        select_contraction_algorithm, select_einsum_path)
from .transfer import (D2H, H2D, TransferModel, fit_transfer,
                       measure_transfers)

__all__ = [
    "Polynomial", "StackedPolynomials", "error_measure", "fit_relative",
    "monomial_basis", "relative_errors", "stack_polynomials", "Domain",
    "grid_points", "CaseModel", "ModelSet",
    "PerformanceModel", "Piece", "GenerationReport", "KernelBenchmark",
    "generate_model", "generate_model_set", "BACKENDS", "CompiledCalls",
    "FusedBatch", "KernelCall", "PredictionEngine", "TraceCache",
    "compile_calls",
    "absolute_relative_error", "predict_efficiency", "predict_performance",
    "predict_runtime", "relative_error", "GeneratorConfig", "refine",
    "stats_sample_fn", "STATS", "Stats", "measure_calls", "measure_single",
    "RankedAlgorithm", "optimize_algorithm_and_block_size",
    "optimize_block_size", "performance_yield", "rank_algorithms",
    "rank_einsum_paths", "select_algorithm",
    "select_contraction_algorithm", "select_einsum_path",
    "D2H", "H2D", "TransferModel", "fit_transfer", "measure_transfers",
]
