"""Model-based prediction of blocked algorithms (paper §4.1, Eq. 4.1–4.6).

A blocked algorithm's execution is a deterministic sequence of kernel calls;
its predicted runtime is the sum of the per-call model estimates.  Summary
statistics propagate: min/med/max/mean add, standard deviations add in
quadrature (uncorrelated-estimate assumption, Eq. 4.3).  Performance and
efficiency predictions follow Eq. 4.4–4.6 including the second/first-order
Taylor corrections for the mean/std of the reciprocal.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterable, Optional, Sequence, Tuple

from .model import ModelSet
from .sampler import STATS, Stats


@dataclass(frozen=True)
class KernelCall:
    """One kernel invocation inside an algorithm's call sequence."""

    kernel: str          # e.g. "gemm"
    case: Tuple          # flag/layout case, e.g. ("N", "T")
    sizes: Tuple[int, ...]

    def __repr__(self) -> str:  # compact trace printing
        c = ",".join(map(str, self.case))
        s = "x".join(map(str, self.sizes))
        return f"{self.kernel}[{c}]({s})"


def predict_runtime(calls: Iterable[KernelCall], models: ModelSet) -> Stats:
    """t_pred^s = sum over calls of t_est^s  (Eq. 4.2/4.3)."""
    acc = {s: 0.0 for s in STATS}
    var = 0.0
    for call in calls:
        est = models.estimate(call.kernel, call.case, call.sizes)
        for s in ("min", "med", "max", "mean"):
            acc[s] += est[s]
        var += est["std"] ** 2
    acc["std"] = var ** 0.5
    return Stats(**{"min": acc["min"], "med": acc["med"], "max": acc["max"],
                    "mean": acc["mean"], "std": acc["std"]})


def predict_performance(runtime: Stats, cost_flops: float) -> Dict[str, float]:
    """FLOP-rate prediction from a runtime prediction (Eq. 4.4/4.5)."""
    mu, sigma = runtime.mean, runtime.std
    out = {
        "min": cost_flops / runtime.max if runtime.max > 0 else float("inf"),
        "med": cost_flops / runtime.med if runtime.med > 0 else float("inf"),
        "max": cost_flops / runtime.min if runtime.min > 0 else float("inf"),
    }
    if mu > 0:
        out["mean"] = cost_flops / mu * (1.0 + sigma ** 2 / mu ** 2)
        out["std"] = cost_flops * sigma / mu ** 2
    else:
        out["mean"], out["std"] = float("inf"), 0.0
    return out


def predict_efficiency(performance: Dict[str, float],
                       peak_flops: float) -> Dict[str, float]:
    """Eq. 4.6: efficiency = performance / peak."""
    return {s: v / peak_flops for s, v in performance.items()}


# ------------------------------------------------------------------ errors --

def relative_error(pred: float, meas: float) -> float:
    """x_RE = (pred - meas) / meas  (§4.2)."""
    return (pred - meas) / meas


def absolute_relative_error(pred: float, meas: float) -> float:
    return abs(relative_error(pred, meas))
