"""Model-based prediction of blocked algorithms (paper §4.1, Eq. 4.1–4.6).

A blocked algorithm's execution is a deterministic sequence of kernel calls;
its predicted runtime is the sum of the per-call model estimates.  Summary
statistics propagate: min/med/max/mean add, standard deviations add in
quadrature (uncorrelated-estimate assumption, Eq. 4.3).  Performance and
efficiency predictions follow Eq. 4.4–4.6 including the second/first-order
Taylor corrections for the mean/std of the reciprocal.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np

from .fitting import monomials_jnp
from .model import ModelSet
from .sampler import STATS, Stats

_STD = STATS.index("std")


@dataclass(frozen=True)
class KernelCall:
    """One kernel invocation inside an algorithm's call sequence."""

    kernel: str          # e.g. "gemm"
    case: Tuple          # flag/layout case, e.g. ("N", "T")
    sizes: Tuple[int, ...]

    def __repr__(self) -> str:  # compact trace printing
        c = ",".join(map(str, self.case))
        s = "x".join(map(str, self.sizes))
        return f"{self.kernel}[{c}]({s})"


def predict_runtime(calls: Iterable[KernelCall], models: ModelSet) -> Stats:
    """t_pred^s = sum over calls of t_est^s  (Eq. 4.2/4.3)."""
    acc = {s: 0.0 for s in STATS}
    var = 0.0
    for call in calls:
        est = models.estimate(call.kernel, call.case, call.sizes)
        for s in ("min", "med", "max", "mean"):
            acc[s] += est[s]
        var += est["std"] ** 2
    acc["std"] = var ** 0.5
    return Stats(**{"min": acc["min"], "med": acc["med"], "max": acc["max"],
                    "mean": acc["mean"], "std": acc["std"]})


# ----------------------------------------------------------------- batched --

@dataclass(frozen=True)
class CallGroup:
    """All calls to one (kernel, case) across a batch of call sequences."""

    kernel: str
    case: Tuple
    sizes: np.ndarray    # (K, d) float64 size arguments, one row per call
    config: np.ndarray   # (K,) intp — index of the originating call sequence


@dataclass(frozen=True)
class FusedBatch:
    """Padded size tensors + scatter indices for one-dispatch prediction.

    The per-(kernel, case) groups of a :class:`CompiledCalls` batch, padded
    to one rectangular ``(group, rows, dims)`` tensor so the whole batch
    evaluates as a single device program with no host round-trips:

    * ``sizes`` — ``(G, R, d_max)`` float64 size points.  Rows beyond a
      group's true call count are all-zero (the engine's degenerate-call
      mask turns them into exact-zero estimates), and dimensions beyond a
      group's true size rank are a benign ``1.0`` (every monomial carries
      exponent 0 there, so they contribute an exact factor of one);
    * ``segments`` — ``(G * R,)`` int32 config index per padded row, in
      row-major ``(group, row)`` order.  Padding rows map to the extra
      segment ``n_configs``, which the scatter-add drops — so padding can
      never leak into a real config's total;
    * ``flat_config`` — ``(n_calls,)`` intp config index per *real* call,
      concatenated in group order: the precomputed scatter indices the
      numpy backend accumulates all groups with in one ``np.add.at``;
    * ``dims`` / ``rows`` — each group's true size rank and call count
      (what the padding padded *from*).
    """

    sizes: np.ndarray
    segments: np.ndarray
    flat_config: np.ndarray
    dims: Tuple[int, ...]
    rows: Tuple[int, ...]


def _fuse_batch(groups: Tuple[CallGroup, ...], n_configs: int,
                pad_rows_to: Optional[int] = None) -> FusedBatch:
    """Pad per-group size matrices into one rectangular batch tensor."""
    if not groups:
        return FusedBatch(sizes=np.zeros((0, 0, 0), dtype=np.float64),
                          segments=np.zeros(0, dtype=np.int32),
                          flat_config=np.zeros(0, dtype=np.intp),
                          dims=(), rows=())
    rows = tuple(g.sizes.shape[0] for g in groups)
    dims = tuple(g.sizes.shape[1] for g in groups)
    n_rows = max(max(rows), pad_rows_to or 0)
    d_max = max(dims)
    sizes = np.zeros((len(groups), n_rows, d_max), dtype=np.float64)
    segments = np.full((len(groups), n_rows), n_configs, dtype=np.int32)
    for gi, g in enumerate(groups):
        k, d = g.sizes.shape
        sizes[gi, :k, :d] = g.sizes
        sizes[gi, :k, d:] = 1.0
        segments[gi, :k] = g.config
    return FusedBatch(sizes=sizes, segments=segments.reshape(-1),
                      flat_config=np.concatenate([g.config for g in groups]),
                      dims=dims, rows=rows)


@dataclass(frozen=True)
class CompiledCalls:
    """A batch of call sequences compiled to per-(kernel, case) matrices.

    This is the "compiled" form of §4.1's deterministic call sequences: the
    per-call Python structure is gone, and prediction reduces to one fused
    polynomial evaluation plus a scatter-add back onto configs.  Besides
    the per-group matrices (kept for the per-group reference path and
    introspection), the batch carries a :class:`FusedBatch` — the padded
    ``(group, rows, dims)`` size tensor and the segment/config scatter
    indices — emitted once by :func:`compile_calls` so no predict call
    ever re-derives them.
    """

    n_configs: int
    groups: Tuple[CallGroup, ...]
    fused: Optional[FusedBatch] = None

    @property
    def n_calls(self) -> int:
        return sum(g.sizes.shape[0] for g in self.groups)

    def fused_batch(self) -> FusedBatch:
        """The padded tensors + scatter indices (:class:`FusedBatch`).

        :func:`compile_calls` emits them eagerly; hand-built instances
        (``fused=None``) derive and memoize them on first use."""
        if self.fused is None:
            object.__setattr__(self, "fused",
                               _fuse_batch(self.groups, self.n_configs))
        return self.fused


def compile_calls(calls_per_config: Sequence[Iterable[KernelCall]], *,
                  pad_rows_to: Optional[int] = None) -> CompiledCalls:
    """Group a batch of call sequences into per-(kernel, case) size matrices.

    The returned :class:`CompiledCalls` also carries the padded
    :class:`FusedBatch` tensors the fused prediction path consumes.
    ``pad_rows_to`` forces the row axis to at least that width — results
    are bit-identical under any padding (padding rows scatter into a
    dropped segment), which the property tests pin.
    """
    seqs = list(calls_per_config)
    buckets: Dict[Tuple[str, Tuple], Tuple[list, list]] = {}
    for i, calls in enumerate(seqs):
        for call in calls:
            szs, cfg = buckets.setdefault((call.kernel, call.case), ([], []))
            szs.append(call.sizes)
            cfg.append(i)
    groups = tuple(
        CallGroup(kernel=kernel, case=case,
                  sizes=np.asarray(szs, dtype=np.float64),
                  config=np.asarray(cfg, dtype=np.intp))
        for (kernel, case), (szs, cfg) in buckets.items()
    )
    return CompiledCalls(n_configs=len(seqs), groups=groups,
                         fused=_fuse_batch(groups, len(seqs), pad_rows_to))


Tracer = Callable[[int, int], List[KernelCall]]


class TraceCache:
    """Memoizes tracer call sequences and compiled batches across sweeps.

    Tracing a blocked algorithm is a pure function of ``(n, b)`` — the call
    sequence is fully determined by the problem and block size (§4.1) — yet
    it is the one remaining Python loop on the prediction hot path.  The
    cache is keyed on ``(tracer identity, n, b)`` (the tracer object itself,
    which also keeps it alive, so ids are never recycled into stale hits)
    and additionally memoizes whole compiled sweep/grid batches, so repeated
    sweeps over the same candidate set reuse one :class:`CompiledCalls`
    instead of re-tracing and re-grouping every point.

    Entries are never evicted: hold ON to the tracer objects you sweep with
    (rebuilding a tracer closure per request defeats the cache and grows it
    unboundedly in a long-lived shared engine); call :meth:`clear` to reset.
    """

    def __init__(self):
        self._calls: Dict[Tuple, Tuple[KernelCall, ...]] = {}
        self._compiled: Dict[Tuple, CompiledCalls] = {}
        self.hits = 0
        self.misses = 0

    def calls(self, tracer: Tracer, n: int, b: int) -> Tuple[KernelCall, ...]:
        """The (cached) call sequence of one traced configuration."""
        key = (tracer, n, b)
        out = self._calls.get(key)
        if out is None:
            self.misses += 1
            out = tuple(tracer(n, b))
            self._calls[key] = out
        else:
            self.hits += 1
        return out

    def compiled_sweep(self, tracer: Tracer, n: int,
                       candidates: Sequence[int]) -> CompiledCalls:
        """One reusable compiled batch for a whole block-size sweep."""
        key = ("sweep", tracer, n, tuple(candidates))
        out = self._compiled.get(key)
        if out is None:
            out = compile_calls([self.calls(tracer, n, b)
                                 for b in candidates])
            self._compiled[key] = out
        else:
            self.hits += 1   # whole-batch reuse: calls() is never consulted
        return out

    def compiled_grid(self, tracer: Tracer, ns: Sequence[int],
                      bs: Sequence[int]) -> CompiledCalls:
        """One reusable compiled batch for a full (n, b) grid."""
        key = ("grid", tracer, tuple(ns), tuple(bs))
        out = self._compiled.get(key)
        if out is None:
            out = compile_calls([self.calls(tracer, n, b)
                                 for n in ns for b in bs])
            self._compiled[key] = out
        else:
            self.hits += 1   # whole-batch reuse: calls() is never consulted
        return out

    def clear(self) -> None:
        self._calls.clear()
        self._compiled.clear()
        self.hits = self.misses = 0


#: evaluation backends a PredictionEngine can run its stacked polynomial
#: models on
BACKENDS = ("numpy", "jax")


# ------------------------------------------------------- fused evaluation --

def _zero_case_tensors(d: int):
    """An always-inside single piece evaluating to exactly zero — the
    stand-in for a (kernel, case) whose every call is degenerate and which
    therefore needs no model (Example 4.1 semantics)."""
    return (np.zeros((1, d)), np.full((1, d), np.inf),
            np.zeros((1, 1, d)), np.ones((1, 1, d)),
            np.zeros((1, 1, len(STATS))))


def _pad_model_tensors(per_case, fused: FusedBatch):
    """Pad per-case piece tensors to one (G, P, M, ·) batch.

    Padding *pieces* get ``lo=+inf, hi=-inf``: never inside, and at
    infinite clamp distance, so the piece lookup can never select them.
    Padding *monomials* are exact no-op rows (exponent 0, scale 1,
    coefficient 0), and padding *dims* of real pieces are always-inside
    (``lo=0, hi=+inf``) with exponent 0 — every pad contributes exactly
    nothing, which keeps the fused program bit-compatible with the
    per-group path's arithmetic.
    """
    d_max = fused.sizes.shape[2]
    tensors = [t if t is not None else _zero_case_tensors(d)
               for t, d in zip(per_case, fused.dims)]
    p_max = max(t[0].shape[0] for t in tensors)
    m_max = max(t[2].shape[1] for t in tensors)
    g = len(tensors)
    lo = np.full((g, p_max, d_max), np.inf)
    hi = np.full((g, p_max, d_max), -np.inf)
    exps = np.zeros((g, p_max, m_max, d_max))
    scl = np.ones((g, p_max, m_max, d_max))
    cof = np.zeros((g, p_max, m_max, len(STATS)))
    for gi, ((tlo, thi, te, ts, tc), d) in enumerate(zip(tensors,
                                                         fused.dims)):
        p, m = te.shape[0], te.shape[1]
        lo[gi, :p, :d] = tlo
        lo[gi, :p, d:] = 0.0
        hi[gi, :p, :d] = thi
        hi[gi, :p, d:] = np.inf
        exps[gi, :p, :m, :d] = te
        scl[gi, :p, :m, :d] = ts
        cof[gi, :p, :m, :] = tc
    return lo, hi, exps, scl, cof


_FUSED_JIT = None


def _fused_predict_impl(pts, lo, hi, exps, scl, cof, seg, *,
                        n_configs, std_col):
    """The whole compiled batch as ONE device program.

    ``pts (G, R, d)`` padded size points; ``lo/hi (G, P, d)`` piece
    domains; ``exps/scl (G, P, M, d)`` and ``cof (G, P, M, S)`` padded
    piece polynomials; ``seg (G*R,)`` config segment per row (padding
    rows map to the dropped segment ``n_configs``).  Fuses degenerate
    masking, piece lookup, design matrices, the stacked matmuls AND the
    config-wise scatter-add (std in quadrature) into a single dispatch;
    mirrors the per-group path exactly: first containing piece wins,
    out-of-domain rows clamp to the smallest squared distance, estimates
    clip at 0, degenerate rows are exact zeros.
    """
    import jax
    import jax.numpy as jnp

    live = jnp.all(pts > 0, axis=-1)                           # (G, R)
    safe = jnp.where(live[..., None], pts, 1.0)
    inside = jnp.all((safe[:, :, None, :] >= lo[:, None]) &
                     (safe[:, :, None, :] <= hi[:, None]), axis=-1)
    below = jnp.maximum(lo[:, None] - safe[:, :, None, :], 0.0)
    above = jnp.maximum(safe[:, :, None, :] - hi[:, None], 0.0)
    dist = (below ** 2).sum(-1) + (above ** 2).sum(-1)         # (G, R, P)
    pidx = jnp.where(inside.any(axis=-1), jnp.argmax(inside, axis=-1),
                     jnp.argmin(dist, axis=-1))                # (G, R)
    e = jnp.take_along_axis(exps, pidx[:, :, None, None], axis=1)
    s = jnp.take_along_axis(scl, pidx[:, :, None, None], axis=1)
    c = jnp.take_along_axis(cof, pidx[:, :, None, None], axis=1)
    # row-flatten (G, R) -> N so the shared design-matrix implementation
    # (monomials_jnp, also behind the per-group path) serves this one too
    flat_pts = safe.reshape(-1, safe.shape[-1])                # (N, d)
    x = monomials_jnp(flat_pts, e.reshape(-1, *e.shape[2:]),
                      s.reshape(-1, *s.shape[2:]))             # (N, M)
    out = jnp.maximum(
        jnp.einsum("nm,nms->ns", x, c.reshape(-1, *c.shape[2:])), 0.0)
    out = jnp.where(live.reshape(-1)[:, None], out, 0.0)       # (N, S)
    w = out.at[:, std_col].set(out[:, std_col] ** 2)
    tot = jax.ops.segment_sum(w, seg, num_segments=n_configs + 1)[:n_configs]
    return tot.at[:, std_col].set(jnp.sqrt(tot[:, std_col]))


def _fused_predict_jax(inputs, n_configs: int) -> np.ndarray:
    """Run the fused program jitted in float64 (one compile per batch
    shape signature, then cached by jax).  ``inputs`` is the device-
    resident ``(sizes, lo, hi, exps, scl, cof, segments)`` tuple, so a
    repeated sweep re-uploads nothing."""
    global _FUSED_JIT
    import jax
    from jax.experimental import enable_x64

    if _FUSED_JIT is None:
        _FUSED_JIT = jax.jit(_fused_predict_impl,
                             static_argnames=("n_configs", "std_col"))
    with enable_x64():
        return np.asarray(_FUSED_JIT(*inputs, n_configs=n_configs,
                                     std_col=_STD))


class PredictionEngine:
    """Vectorized batched prediction over configuration sweeps (§4.5/§4.6).

    Where :func:`predict_runtime` walks one call sequence through per-call
    dict lookups and per-stat polynomial evaluations, this engine compiles a
    whole batch of call sequences (one per candidate configuration) into
    per-(kernel, case) size matrices and predicts every configuration with a
    handful of stacked matrix products.  Statistics propagate exactly as in
    Eq. 4.2/4.3: min/med/max/mean sum per config, std adds in quadrature.
    The scalar path remains the reference oracle; both agree to ~1e-10.

    ``backend`` selects how the stacked polynomials are evaluated:
    ``"numpy"`` (the reference batched path — per-group evaluation, all
    groups accumulated with one precomputed scatter) or ``"jax"`` — piece
    lookup, design matrices, every group's stacked matmuls AND the
    config-wise scatter-add fused into ONE ``jax.jit``-compiled float64
    program over the batch's padded ``(group, rows, ...)`` tensors, so a
    whole compiled batch is a single dispatch with no host round-trips
    (agrees with numpy to ~1e-8; XLA compiles once per batch shape).  The
    per-group path survives as :meth:`predict_compiled_grouped`, the
    fused path's equivalence oracle.

    Every engine owns a :class:`TraceCache` (pass ``cache=`` to share one
    across engines): ``sweep``/``grid`` compile their whole candidate set
    once into a reusable :class:`CompiledCalls` artifact — also available
    directly via :meth:`compile_sweep`/:meth:`compile_grid` — so repeated
    sweeps skip both the Python tracing loop and the re-grouping, and
    ``predict_compiled`` consumes the artifact directly.
    """

    def __init__(self, models: ModelSet, *, backend: str = "numpy",
                 cache: Optional[TraceCache] = None):
        if backend not in BACKENDS:
            raise ValueError(f"unknown backend {backend!r}; "
                             f"choose from {BACKENDS}")
        self.models = models
        self.backend = backend
        self.cache = cache if cache is not None else TraceCache()

    def predict_compiled(self, compiled: CompiledCalls) -> np.ndarray:
        """(n_configs, len(STATS)) runtime statistics for a compiled batch.

        The fused path: on ``backend="jax"`` the whole batch — every
        group's piece lookup, design matrices and matmuls plus the
        config scatter-add — runs as one jitted device program over the
        batch's :class:`FusedBatch` tensors; on ``"numpy"`` groups are
        evaluated batch-wise and accumulated with a single ``np.add.at``
        over the precomputed ``flat_config`` scatter indices.  Either
        way there is no per-group Python accumulation loop.
        """
        if not compiled.groups:
            return np.zeros((compiled.n_configs, len(STATS)),
                            dtype=np.float64)
        fused = compiled.fused_batch()
        if self.backend == "jax":
            return _fused_predict_jax(self._fused_device_inputs(compiled),
                                      compiled.n_configs)
        est = np.concatenate(
            [np.asarray(self.models[g.kernel].estimate_batch(g.case,
                                                             g.sizes))
             for g in compiled.groups], axis=0)
        est[:, _STD] **= 2
        acc = np.zeros((compiled.n_configs, len(STATS)), dtype=np.float64)
        np.add.at(acc, fused.flat_config, est)
        acc[:, _STD] = np.sqrt(acc[:, _STD])
        return acc

    def predict_compiled_grouped(self, compiled: CompiledCalls) -> np.ndarray:
        """The per-group reference path (PR-2 semantics), kept as the
        fused path's equivalence oracle.

        One ``estimate_batch`` evaluation — and, on ``backend="jax"``,
        one jitted dispatch — per (kernel, case) group, accumulated
        host-side with per-stat ``np.bincount``; agrees with
        :meth:`predict_compiled` to ~1e-8 (the two paths associate the
        per-config additions differently, so agreement is to rounding,
        not bit-for-bit).
        """
        acc = np.zeros((compiled.n_configs, len(STATS)), dtype=np.float64)
        for g in compiled.groups:
            est = np.asarray(self.models[g.kernel].estimate_batch(
                g.case, g.sizes, backend=self.backend))
            for j in range(len(STATS)):
                w = est[:, j] ** 2 if j == _STD else est[:, j]
                acc[:, j] += np.bincount(g.config, weights=w,
                                         minlength=compiled.n_configs)
        acc[:, _STD] = np.sqrt(acc[:, _STD])
        return acc

    def _fused_model_tensors(self, compiled: CompiledCalls):
        """Padded (G, P, M, ·) model tensors for a compiled batch.

        Built from each case's :meth:`~repro.core.model.CaseModel.
        padded_tensors` and memoized ON the batch (a single entry,
        replaced whenever the model set or any per-case tensor identity
        changes — so a mutated model never serves stale tensors, and a
        long-lived batch never accumulates tensors for model sets it no
        longer predicts with).  A case that is missing but whose every
        call is degenerate gets an exact-zero stand-in — the same
        no-model-needed semantics as the scalar path; a live call to a
        missing case raises ``KeyError``.
        """
        per_case = []
        for g in compiled.groups:
            model = self.models[g.kernel]
            cm = model.cases.get(tuple(g.case))
            if cm is not None and cm.pieces:
                per_case.append(cm.padded_tensors())
                continue
            if np.any(np.all(g.sizes > 0, axis=1)):
                if cm is not None:
                    raise KeyError("empty case model")
                raise KeyError(f"{g.kernel}: no model for case {g.case!r} "
                               f"(have {list(model.cases)})")
            per_case.append(None)
        hit = compiled.__dict__.get("_fused_model_cache")
        if hit is not None and hit[0] is self.models \
                and len(hit[1]) == len(per_case) \
                and all(a is b for a, b in zip(hit[1], per_case)):
            return hit[2]
        tensors = _pad_model_tensors(per_case, compiled.fused_batch())
        object.__setattr__(compiled, "_fused_model_cache",
                           (self.models, tuple(per_case), tensors))
        return tensors

    def _fused_device_inputs(self, compiled: CompiledCalls):
        """Device-resident float64 inputs for the fused jax program.

        The padded size/model tensors are immutable once built, so their
        ``jnp`` copies are memoized on the batch (a single entry keyed
        by the model tensors' identity, which
        :meth:`_fused_model_tensors` already revalidates against
        mutation and model-set changes) — a repeated sweep is one
        dispatch with zero host-to-device transfers, and stale device
        buffers are dropped as soon as the model tensors change.
        """
        tensors = self._fused_model_tensors(compiled)
        hit = compiled.__dict__.get("_fused_device_cache")
        if hit is not None and hit[0] is tensors:
            return hit[1]
        import jax.numpy as jnp
        from jax.experimental import enable_x64

        fused = compiled.fused_batch()
        with enable_x64():
            inputs = (jnp.asarray(fused.sizes),
                      *(jnp.asarray(t) for t in tensors),
                      jnp.asarray(fused.segments))
        object.__setattr__(compiled, "_fused_device_cache",
                           (tensors, inputs))
        return inputs

    def predict_batch(self,
                      calls_per_config: Sequence[Iterable[KernelCall]],
                      ) -> np.ndarray:
        """Predict runtime stats for many call sequences at once: (N, 5)."""
        return self.predict_compiled(compile_calls(calls_per_config))

    def predict_stats(self,
                      calls_per_config: Sequence[Iterable[KernelCall]],
                      ) -> List[Stats]:
        return [Stats(*map(float, row))
                for row in self.predict_batch(calls_per_config)]

    # ------------------------------------------------- cached sweep/grid --
    def compile_sweep(self, tracer: Tracer, n: int,
                      candidates: Sequence[int]) -> CompiledCalls:
        """Trace + compile a block-size sweep once; cached across calls."""
        return self.cache.compiled_sweep(tracer, n, candidates)

    def compile_grid(self, tracer: Tracer, ns: Sequence[int],
                     bs: Sequence[int]) -> CompiledCalls:
        """Trace + compile a full (n, b) grid once; cached across calls."""
        return self.cache.compiled_grid(tracer, ns, bs)

    def sweep(self, tracer: Tracer, n: int,
              candidates: Sequence[int]) -> np.ndarray:
        """Predict one algorithm over a block-size grid: (len(candidates), 5)."""
        return self.predict_compiled(self.compile_sweep(tracer, n,
                                                        candidates))

    def grid(self, tracer: Tracer,
             ns: Sequence[int], bs: Sequence[int]) -> np.ndarray:
        """Predict a full (n, b) grid in one shot: (len(ns), len(bs), 5)."""
        flat = self.predict_compiled(self.compile_grid(tracer, ns, bs))
        return flat.reshape(len(ns), len(bs), len(STATS))


def resolve_engine(models: ModelSet, backend: Optional[str],
                   engine: Optional[PredictionEngine]) -> PredictionEngine:
    """Resolve the ``backend=``/``engine=`` pair a selection entry point got.

    An explicit ``backend`` (or the ``models`` argument itself) must not be
    silently overridden by a supplied engine — conflicting requests raise
    instead of handing back results from the wrong evaluation path or the
    wrong model set.
    """
    if engine is not None:
        if backend is not None and backend != engine.backend:
            raise ValueError(
                f"backend={backend!r} conflicts with the supplied engine's "
                f"backend={engine.backend!r}; pass one or the other")
        if engine.models is not models:
            raise ValueError(
                "the supplied engine was built on a different ModelSet than "
                "the models argument; predictions would silently come from "
                "the engine's set")
        return engine
    return PredictionEngine(models, backend=backend or "numpy")


def predict_performance(runtime: Stats, cost_flops: float) -> Dict[str, float]:
    """FLOP-rate prediction from a runtime prediction (Eq. 4.4/4.5)."""
    mu, sigma = runtime.mean, runtime.std
    out = {
        "min": cost_flops / runtime.max if runtime.max > 0 else float("inf"),
        "med": cost_flops / runtime.med if runtime.med > 0 else float("inf"),
        "max": cost_flops / runtime.min if runtime.min > 0 else float("inf"),
    }
    if mu > 0:
        out["mean"] = cost_flops / mu * (1.0 + sigma ** 2 / mu ** 2)
        out["std"] = cost_flops * sigma / mu ** 2
    else:
        out["mean"], out["std"] = float("inf"), 0.0
    return out


def predict_efficiency(performance: Dict[str, float],
                       peak_flops: float) -> Dict[str, float]:
    """Eq. 4.6: efficiency = performance / peak."""
    return {s: v / peak_flops for s, v in performance.items()}


# ------------------------------------------------------------------ errors --

def relative_error(pred: float, meas: float) -> float:
    """x_RE = (pred - meas) / meas  (§4.2).

    A zero measurement has no defined relative error; return ``nan`` so
    error sweeps over empty/degenerate measurements don't crash.
    """
    if meas == 0:
        return float("nan")
    return (pred - meas) / meas


def absolute_relative_error(pred: float, meas: float) -> float:
    """``|pred - meas| / meas`` — the magnitude of :func:`relative_error`
    (nan when the measurement is zero)."""
    return abs(relative_error(pred, meas))
