"""Model-based prediction of blocked algorithms (paper §4.1, Eq. 4.1–4.6).

A blocked algorithm's execution is a deterministic sequence of kernel calls;
its predicted runtime is the sum of the per-call model estimates.  Summary
statistics propagate: min/med/max/mean add, standard deviations add in
quadrature (uncorrelated-estimate assumption, Eq. 4.3).  Performance and
efficiency predictions follow Eq. 4.4–4.6 including the second/first-order
Taylor corrections for the mean/std of the reciprocal.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np

from .model import ModelSet
from .sampler import STATS, Stats

_STD = STATS.index("std")


@dataclass(frozen=True)
class KernelCall:
    """One kernel invocation inside an algorithm's call sequence."""

    kernel: str          # e.g. "gemm"
    case: Tuple          # flag/layout case, e.g. ("N", "T")
    sizes: Tuple[int, ...]

    def __repr__(self) -> str:  # compact trace printing
        c = ",".join(map(str, self.case))
        s = "x".join(map(str, self.sizes))
        return f"{self.kernel}[{c}]({s})"


def predict_runtime(calls: Iterable[KernelCall], models: ModelSet) -> Stats:
    """t_pred^s = sum over calls of t_est^s  (Eq. 4.2/4.3)."""
    acc = {s: 0.0 for s in STATS}
    var = 0.0
    for call in calls:
        est = models.estimate(call.kernel, call.case, call.sizes)
        for s in ("min", "med", "max", "mean"):
            acc[s] += est[s]
        var += est["std"] ** 2
    acc["std"] = var ** 0.5
    return Stats(**{"min": acc["min"], "med": acc["med"], "max": acc["max"],
                    "mean": acc["mean"], "std": acc["std"]})


# ----------------------------------------------------------------- batched --

@dataclass(frozen=True)
class CallGroup:
    """All calls to one (kernel, case) across a batch of call sequences."""

    kernel: str
    case: Tuple
    sizes: np.ndarray    # (K, d) float64 size arguments, one row per call
    config: np.ndarray   # (K,) intp — index of the originating call sequence


@dataclass(frozen=True)
class CompiledCalls:
    """A batch of call sequences compiled to per-(kernel, case) matrices.

    This is the "compiled" form of §4.1's deterministic call sequences: the
    per-call Python structure is gone, and prediction reduces to one batched
    polynomial evaluation per group plus a scatter-add back onto configs.
    """

    n_configs: int
    groups: Tuple[CallGroup, ...]

    @property
    def n_calls(self) -> int:
        return sum(g.sizes.shape[0] for g in self.groups)


def compile_calls(calls_per_config: Sequence[Iterable[KernelCall]],
                  ) -> CompiledCalls:
    """Group a batch of call sequences into per-(kernel, case) size matrices."""
    seqs = list(calls_per_config)
    buckets: Dict[Tuple[str, Tuple], Tuple[list, list]] = {}
    for i, calls in enumerate(seqs):
        for call in calls:
            szs, cfg = buckets.setdefault((call.kernel, call.case), ([], []))
            szs.append(call.sizes)
            cfg.append(i)
    groups = tuple(
        CallGroup(kernel=kernel, case=case,
                  sizes=np.asarray(szs, dtype=np.float64),
                  config=np.asarray(cfg, dtype=np.intp))
        for (kernel, case), (szs, cfg) in buckets.items()
    )
    return CompiledCalls(n_configs=len(seqs), groups=groups)


Tracer = Callable[[int, int], List[KernelCall]]


class TraceCache:
    """Memoizes tracer call sequences and compiled batches across sweeps.

    Tracing a blocked algorithm is a pure function of ``(n, b)`` — the call
    sequence is fully determined by the problem and block size (§4.1) — yet
    it is the one remaining Python loop on the prediction hot path.  The
    cache is keyed on ``(tracer identity, n, b)`` (the tracer object itself,
    which also keeps it alive, so ids are never recycled into stale hits)
    and additionally memoizes whole compiled sweep/grid batches, so repeated
    sweeps over the same candidate set reuse one :class:`CompiledCalls`
    instead of re-tracing and re-grouping every point.

    Entries are never evicted: hold ON to the tracer objects you sweep with
    (rebuilding a tracer closure per request defeats the cache and grows it
    unboundedly in a long-lived shared engine); call :meth:`clear` to reset.
    """

    def __init__(self):
        self._calls: Dict[Tuple, Tuple[KernelCall, ...]] = {}
        self._compiled: Dict[Tuple, CompiledCalls] = {}
        self.hits = 0
        self.misses = 0

    def calls(self, tracer: Tracer, n: int, b: int) -> Tuple[KernelCall, ...]:
        """The (cached) call sequence of one traced configuration."""
        key = (tracer, n, b)
        out = self._calls.get(key)
        if out is None:
            self.misses += 1
            out = tuple(tracer(n, b))
            self._calls[key] = out
        else:
            self.hits += 1
        return out

    def compiled_sweep(self, tracer: Tracer, n: int,
                       candidates: Sequence[int]) -> CompiledCalls:
        """One reusable compiled batch for a whole block-size sweep."""
        key = ("sweep", tracer, n, tuple(candidates))
        out = self._compiled.get(key)
        if out is None:
            out = compile_calls([self.calls(tracer, n, b)
                                 for b in candidates])
            self._compiled[key] = out
        else:
            self.hits += 1   # whole-batch reuse: calls() is never consulted
        return out

    def compiled_grid(self, tracer: Tracer, ns: Sequence[int],
                      bs: Sequence[int]) -> CompiledCalls:
        """One reusable compiled batch for a full (n, b) grid."""
        key = ("grid", tracer, tuple(ns), tuple(bs))
        out = self._compiled.get(key)
        if out is None:
            out = compile_calls([self.calls(tracer, n, b)
                                 for n in ns for b in bs])
            self._compiled[key] = out
        else:
            self.hits += 1   # whole-batch reuse: calls() is never consulted
        return out

    def clear(self) -> None:
        self._calls.clear()
        self._compiled.clear()
        self.hits = self.misses = 0


#: evaluation backends a PredictionEngine can run its stacked polynomial
#: models on
BACKENDS = ("numpy", "jax")


class PredictionEngine:
    """Vectorized batched prediction over configuration sweeps (§4.5/§4.6).

    Where :func:`predict_runtime` walks one call sequence through per-call
    dict lookups and per-stat polynomial evaluations, this engine compiles a
    whole batch of call sequences (one per candidate configuration) into
    per-(kernel, case) size matrices and predicts every configuration with a
    handful of stacked matrix products.  Statistics propagate exactly as in
    Eq. 4.2/4.3: min/med/max/mean sum per config, std adds in quadrature.
    The scalar path remains the reference oracle; both agree to ~1e-10.

    ``backend`` selects how the per-group stacked polynomials are evaluated:
    ``"numpy"`` (the reference batched path) or ``"jax"`` — piece lookup,
    design-matrix construction and the per-group matmuls fused into one
    ``jax.jit``-compiled float64 program over padded per-(kernel, case)
    tensors (agrees with numpy to ~1e-8; XLA compiles once per group shape).

    Every engine owns a :class:`TraceCache` (pass ``cache=`` to share one
    across engines): ``sweep``/``grid`` compile their whole candidate set
    once into a reusable :class:`CompiledCalls` artifact — also available
    directly via :meth:`compile_sweep`/:meth:`compile_grid` — so repeated
    sweeps skip both the Python tracing loop and the re-grouping, and
    ``predict_compiled`` consumes the artifact directly.
    """

    def __init__(self, models: ModelSet, *, backend: str = "numpy",
                 cache: Optional[TraceCache] = None):
        if backend not in BACKENDS:
            raise ValueError(f"unknown backend {backend!r}; "
                             f"choose from {BACKENDS}")
        self.models = models
        self.backend = backend
        self.cache = cache if cache is not None else TraceCache()

    def predict_compiled(self, compiled: CompiledCalls) -> np.ndarray:
        """(n_configs, len(STATS)) runtime statistics for a compiled batch."""
        acc = np.zeros((compiled.n_configs, len(STATS)), dtype=np.float64)
        for g in compiled.groups:
            est = np.asarray(self.models[g.kernel].estimate_batch(
                g.case, g.sizes, backend=self.backend))
            for j in range(len(STATS)):
                w = est[:, j] ** 2 if j == _STD else est[:, j]
                acc[:, j] += np.bincount(g.config, weights=w,
                                         minlength=compiled.n_configs)
        acc[:, _STD] = np.sqrt(acc[:, _STD])
        return acc

    def predict_batch(self,
                      calls_per_config: Sequence[Iterable[KernelCall]],
                      ) -> np.ndarray:
        """Predict runtime stats for many call sequences at once: (N, 5)."""
        return self.predict_compiled(compile_calls(calls_per_config))

    def predict_stats(self,
                      calls_per_config: Sequence[Iterable[KernelCall]],
                      ) -> List[Stats]:
        return [Stats(*map(float, row))
                for row in self.predict_batch(calls_per_config)]

    # ------------------------------------------------- cached sweep/grid --
    def compile_sweep(self, tracer: Tracer, n: int,
                      candidates: Sequence[int]) -> CompiledCalls:
        """Trace + compile a block-size sweep once; cached across calls."""
        return self.cache.compiled_sweep(tracer, n, candidates)

    def compile_grid(self, tracer: Tracer, ns: Sequence[int],
                     bs: Sequence[int]) -> CompiledCalls:
        """Trace + compile a full (n, b) grid once; cached across calls."""
        return self.cache.compiled_grid(tracer, ns, bs)

    def sweep(self, tracer: Tracer, n: int,
              candidates: Sequence[int]) -> np.ndarray:
        """Predict one algorithm over a block-size grid: (len(candidates), 5)."""
        return self.predict_compiled(self.compile_sweep(tracer, n,
                                                        candidates))

    def grid(self, tracer: Tracer,
             ns: Sequence[int], bs: Sequence[int]) -> np.ndarray:
        """Predict a full (n, b) grid in one shot: (len(ns), len(bs), 5)."""
        flat = self.predict_compiled(self.compile_grid(tracer, ns, bs))
        return flat.reshape(len(ns), len(bs), len(STATS))


def resolve_engine(models: ModelSet, backend: Optional[str],
                   engine: Optional[PredictionEngine]) -> PredictionEngine:
    """Resolve the ``backend=``/``engine=`` pair a selection entry point got.

    An explicit ``backend`` (or the ``models`` argument itself) must not be
    silently overridden by a supplied engine — conflicting requests raise
    instead of handing back results from the wrong evaluation path or the
    wrong model set.
    """
    if engine is not None:
        if backend is not None and backend != engine.backend:
            raise ValueError(
                f"backend={backend!r} conflicts with the supplied engine's "
                f"backend={engine.backend!r}; pass one or the other")
        if engine.models is not models:
            raise ValueError(
                "the supplied engine was built on a different ModelSet than "
                "the models argument; predictions would silently come from "
                "the engine's set")
        return engine
    return PredictionEngine(models, backend=backend or "numpy")


def predict_performance(runtime: Stats, cost_flops: float) -> Dict[str, float]:
    """FLOP-rate prediction from a runtime prediction (Eq. 4.4/4.5)."""
    mu, sigma = runtime.mean, runtime.std
    out = {
        "min": cost_flops / runtime.max if runtime.max > 0 else float("inf"),
        "med": cost_flops / runtime.med if runtime.med > 0 else float("inf"),
        "max": cost_flops / runtime.min if runtime.min > 0 else float("inf"),
    }
    if mu > 0:
        out["mean"] = cost_flops / mu * (1.0 + sigma ** 2 / mu ** 2)
        out["std"] = cost_flops * sigma / mu ** 2
    else:
        out["mean"], out["std"] = float("inf"), 0.0
    return out


def predict_efficiency(performance: Dict[str, float],
                       peak_flops: float) -> Dict[str, float]:
    """Eq. 4.6: efficiency = performance / peak."""
    return {s: v / peak_flops for s, v in performance.items()}


# ------------------------------------------------------------------ errors --

def relative_error(pred: float, meas: float) -> float:
    """x_RE = (pred - meas) / meas  (§4.2).

    A zero measurement has no defined relative error; return ``nan`` so
    error sweeps over empty/degenerate measurements don't crash.
    """
    if meas == 0:
        return float("nan")
    return (pred - meas) / meas


def absolute_relative_error(pred: float, meas: float) -> float:
    """``|pred - meas| / meas`` — the magnitude of :func:`relative_error`
    (nan when the measurement is zero)."""
    return abs(relative_error(pred, meas))
