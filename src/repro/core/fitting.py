"""Relative least-squares multivariate polynomial fitting (paper §3.2.4).

The polynomial ``p(x) = sum_j beta_j m_j(x)`` is fitted by minimizing the
*relative* squared error ``sum_i ((y_i - p(x_i)) / y_i)^2``, which reduces to
an ordinary least-squares problem on the row-scaled design matrix
``X[i, j] = m_j(x_i) / y_i`` with right-hand side ``1`` (the paper's normal
equations); we solve it with the SVD-based ``numpy.linalg.lstsq`` for
numerical stability, exactly as the paper does.

The monomial basis is bounded by the kernel's asymptotic complexity — a list
of maximal exponent tuples (e.g. ``[(2, 1)]`` for trsm's m^2 n cost) — plus an
optional uniform degree increase ("overfitting", §3.3.1).
"""

from __future__ import annotations

import itertools
from dataclasses import dataclass
from typing import Dict, Sequence, Tuple

import numpy as np

Exponents = Tuple[int, ...]


def monomial_basis(max_exponents: Sequence[Exponents],
                   overfit: int = 0) -> Tuple[Exponents, ...]:
    """All monomials dominated by any of the given maximal exponent tuples.

    ``max_exponents=[(2, 1)]`` (cost m^2 n) yields
    1, x1, x2, x1^2, x1 x2, x1^2 x2 — Example 3.12.  ``overfit`` raises every
    maximal exponent by that amount in each dimension.
    """
    max_exponents = [tuple(e) for e in max_exponents]
    if not max_exponents:
        raise ValueError("need at least one maximal exponent tuple")
    ndim = len(max_exponents[0])
    if any(len(e) != ndim for e in max_exponents):
        raise ValueError("inconsistent exponent rank")
    caps = [tuple(x + overfit for x in e) for e in max_exponents]
    upper = tuple(max(c[d] for c in caps) for d in range(ndim))
    basis = []
    for exps in itertools.product(*[range(u + 1) for u in upper]):
        if any(all(x <= c for x, c in zip(exps, cap)) for cap in caps):
            basis.append(exps)
    basis.sort(key=lambda e: (sum(e), e))
    return tuple(basis)


def _design_matrix(points: np.ndarray, basis: Sequence[Exponents],
                   scale: np.ndarray) -> np.ndarray:
    # points: (N, d) float; scale: (d,) normalization to keep X well-conditioned
    cols = []
    normed = points / scale
    for exps in basis:
        col = np.ones(points.shape[0])
        for d, e in enumerate(exps):
            if e:
                col = col * normed[:, d] ** e
        cols.append(col)
    return np.stack(cols, axis=1)


@dataclass(frozen=True)
class Polynomial:
    """A fitted multivariate polynomial with input normalization."""

    basis: Tuple[Exponents, ...]
    coeffs: np.ndarray       # (M,)
    scale: np.ndarray        # (d,) per-dim normalization used during fitting

    def __call__(self, points) -> np.ndarray:
        pts = np.atleast_2d(np.asarray(points, dtype=np.float64))
        X = _design_matrix(pts, self.basis, self.scale)
        out = X @ self.coeffs
        return out if out.size > 1 else float(out[0])

    def to_dict(self) -> dict:
        return {"basis": [list(b) for b in self.basis],
                "coeffs": self.coeffs.tolist(),
                "scale": self.scale.tolist()}

    @staticmethod
    def from_dict(d: dict) -> "Polynomial":
        return Polynomial(tuple(tuple(b) for b in d["basis"]),
                          np.asarray(d["coeffs"], dtype=np.float64),
                          np.asarray(d["scale"], dtype=np.float64))


@dataclass(frozen=True)
class StackedPolynomials:
    """Several polynomials evaluated together on one batch of points.

    Polynomials sharing a (basis, scale) pair are stacked into a single
    coefficient matrix so one design matrix and one matmul produce all of
    their values — the core primitive of the batched prediction engine.
    Heterogeneous bases (e.g. a constant-only std polynomial next to full
    cost-bounded stat polynomials) fall into separate groups and still
    evaluate with one design matrix per group, not one per polynomial.

    Besides the numpy path (``__call__``), :meth:`flattened` exports the
    groups as dense per-row tensors — the form the prediction engine's
    ``backend="jax"`` path pads and gathers per (kernel, case) — and
    :meth:`eval_jax` evaluates them standalone in one ``jax.jit``-compiled
    float64 program (same :func:`monomials_jnp` core as the engine path).
    """

    #: per group: (basis, scale, coeff matrix (M, k), output column indices)
    groups: Tuple[Tuple[Tuple[Exponents, ...], np.ndarray, np.ndarray,
                        Tuple[int, ...]], ...]
    n_out: int

    def __call__(self, points: np.ndarray) -> np.ndarray:
        """Evaluate all stacked polynomials: (N, d) points -> (N, n_out)."""
        pts = np.atleast_2d(np.asarray(points, dtype=np.float64))
        out = np.empty((pts.shape[0], self.n_out), dtype=np.float64)
        for basis, scale, coeff_mat, cols in self.groups:
            X = _design_matrix(pts, basis, scale)
            out[:, cols] = X @ coeff_mat
        return out

    def flattened(self) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """All groups merged into per-row dense tensors for the JAX path.

        Returns ``(exps (M, d), scale (M, d), coeffs (M, n_out))`` where row
        ``m`` contributes ``coeffs[m, j] * prod_d (x_d / scale[m, d]) **
        exps[m, d]`` to output column ``j``.  Carrying the normalization per
        row keeps the evaluation bit-for-bit equivalent in structure to the
        grouped numpy path, and zero-padded rows (exponent 0, coefficient 0)
        contribute exactly nothing — so flattened tensors of different
        stacks can be padded to a common width and batched together.
        """
        cached = self.__dict__.get("_flattened_cache")
        if cached is None:
            exps, scl, cof = [], [], []
            for basis, scale, coeff_mat, cols in self.groups:
                for r, e in enumerate(basis):
                    exps.append(e)
                    scl.append(scale)
                    row = np.zeros(self.n_out, dtype=np.float64)
                    row[list(cols)] = coeff_mat[r]
                    cof.append(row)
            cached = (np.asarray(exps, dtype=np.float64),
                      np.asarray(scl, dtype=np.float64),
                      np.stack(cof))
            object.__setattr__(self, "_flattened_cache", cached)
        return cached

    def eval_jax(self, points) -> np.ndarray:
        """JAX-jitted equivalent of ``__call__`` (float64, agrees ~1e-8)."""
        pts = np.atleast_2d(np.asarray(points, dtype=np.float64))
        return np.asarray(jax_eval_flattened(pts, *self.flattened()))


# ------------------------------------------------------------ JAX backend --
#
# jax is imported lazily so the numpy-only fitting/prediction path never
# pays for (or depends on) an accelerator runtime import.

_JAX_EVAL = None


def monomials_jnp(pts, exps, scl):
    """``X[..., n, m] = prod_d (pts[n, d] / scl[..., m, d]) ** exps[..., m, d]``.

    The one jnp implementation of the normalized design matrix, shared by
    every jitted evaluation path: ``exps``/``scl`` may be ``(M, d)`` (one
    polynomial stack for all points) or ``(N, M, d)`` (per-point gathered
    tensors, as in the model layer's fused piece lookup).
    """
    import jax.numpy as jnp

    return jnp.prod((pts[:, None, :] / scl) ** exps, axis=-1)


def _eval_flattened_impl(pts, exps, scl, cof):
    # pts (N, d); exps/scl (M, d); cof (M, n_out)
    return monomials_jnp(pts, exps, scl) @ cof              # (N, n_out)


def jax_eval_flattened(pts, exps, scl, cof):
    """Evaluate flattened polynomial tensors under jit, in float64."""
    global _JAX_EVAL
    import jax
    from jax.experimental import enable_x64

    if _JAX_EVAL is None:
        _JAX_EVAL = jax.jit(_eval_flattened_impl)
    with enable_x64():
        return _JAX_EVAL(pts, exps, scl, cof)


def stack_polynomials(polys: Sequence[Polynomial]) -> StackedPolynomials:
    """Compile polynomials into grouped coefficient matrices for batch eval."""
    by_key: Dict[Tuple, list] = {}
    for j, p in enumerate(polys):
        by_key.setdefault((p.basis, tuple(p.scale)), []).append(j)
    groups = []
    for (basis, scale), cols in by_key.items():
        coeff_mat = np.stack([polys[j].coeffs for j in cols], axis=1)
        groups.append((basis, np.asarray(scale, dtype=np.float64),
                       coeff_mat, tuple(cols)))
    return StackedPolynomials(tuple(groups), len(polys))


def fit_relative(points: Sequence[Sequence[float]], values: Sequence[float],
                 basis: Sequence[Exponents]) -> Polynomial:
    """Fit ``p`` minimizing sum((y - p(x))/y)^2 — §3.2.4."""
    pts = np.asarray(points, dtype=np.float64)
    y = np.asarray(values, dtype=np.float64)
    if pts.ndim != 2:
        pts = pts.reshape(len(y), -1)
    if np.any(y <= 0):
        raise ValueError("relative fitting requires strictly positive values")
    scale = np.maximum(pts.max(axis=0), 1.0)
    X = _design_matrix(pts, basis, scale)
    Xs = X / y[:, None]
    rhs = np.ones_like(y)
    coeffs, *_ = np.linalg.lstsq(Xs, rhs, rcond=None)
    return Polynomial(tuple(tuple(b) for b in basis), coeffs, scale)


def relative_errors(poly: Polynomial, points, values) -> np.ndarray:
    """Point-wise |y - p(x)| / y (§3.2.5)."""
    pts = np.atleast_2d(np.asarray(points, dtype=np.float64))
    y = np.asarray(values, dtype=np.float64)
    pred = np.atleast_1d(poly(pts))
    return np.abs(y - pred) / y


def error_measure(errors: np.ndarray, kind: str = "maximum") -> float:
    """Aggregate point-wise errors: average / maximum / 90th percentile."""
    if kind == "average":
        return float(np.mean(errors))
    if kind == "maximum":
        return float(np.max(errors))
    if kind in ("p90", "90th"):
        return float(np.percentile(errors, 90))
    raise ValueError(f"unknown error measure {kind!r}")
