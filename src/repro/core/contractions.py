"""BLAS-based tensor-contraction algorithms + micro-benchmark prediction
(paper Ch. 6).

A contraction like ``C[abc] = A[ai] * B[ibc]`` can be computed by many
alternative algorithms, each consisting of nested **for**-loops around a
single fixed-size compute kernel (gemm / gemv / ger / dot / axpy analogues —
here: jitted einsums over the kernel dimensions).  §6.1's generator
enumerates them systematically: choose which indices become loop indices,
check the remainder matches a kernel pattern, and permute the loop order.

Since each algorithm performs its *entire* computation in repeated calls to
ONE kernel with FIXED operand sizes, a micro-benchmark of a handful of calls
predicts the whole algorithm (§6.2).  The benchmark is *cache-aware*: for
each operand the *access distance* (bytes touched between consecutive uses
of the same operand slice, §6.2.3) decides whether the timed calls reuse a
warm buffer or cycle through fresh buffers, recreating the cache state of
the real loop nest.  First-iteration overhead (§6.2.6) is measured
separately and added once.
"""

from __future__ import annotations

import functools
import itertools
import math
import time
from dataclasses import dataclass, field
from typing import Dict, List, Mapping, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from .sampler import Stats, measure_calls

_DTYPE = np.float32
_ITEM = 4


# ------------------------------------------------------------------- spec --

@dataclass(frozen=True)
class ContractionSpec:
    """``C[out] = A[a_idx] * B[b_idx]`` in Einstein notation."""

    a_idx: str
    b_idx: str
    out_idx: str

    @staticmethod
    def parse(expr: str) -> "ContractionSpec":
        """Parse e.g. ``"abc=ai,ibc"`` or einsum-style ``"ai,ibc->abc"``."""
        if "->" in expr:
            ins, out = expr.split("->")
            a, b = ins.split(",")
        else:
            out, ins = expr.split("=")
            a, b = ins.split(",")
        return ContractionSpec(a.strip(), b.strip(), out.strip())

    @property
    def contracted(self) -> Tuple[str, ...]:
        """Indices summed over: shared by A and B but absent from C.

        An index shared by A and B that *also* appears in the output is a
        batch index (e.g. ``b`` in ``bij,bjk->bik``), not a contraction —
        treating it as contracted would let the generator build kernels that
        sum over it.
        """
        return tuple(i for i in self.a_idx
                     if i in self.b_idx and i not in self.out_idx)

    @property
    def batch(self) -> Tuple[str, ...]:
        """Indices shared by A, B and C (batch dimensions).

        The §6.1 kernels are plain BLAS calls without batching, so batch
        indices can only ever be loop indices.
        """
        return tuple(i for i in self.a_idx
                     if i in self.b_idx and i in self.out_idx)

    @property
    def all_indices(self) -> Tuple[str, ...]:
        seen = []
        for i in self.a_idx + self.b_idx:
            if i not in seen:
                seen.append(i)
        return tuple(seen)

    def flops(self, sizes: Mapping[str, int]) -> float:
        return 2.0 * math.prod(sizes[i] for i in self.all_indices)

    def einsum_expr(self) -> str:
        return f"{self.a_idx},{self.b_idx}->{self.out_idx}"


# -------------------------------------------------------------- algorithms --

#: kernel patterns: (#free-A kernel dims, #free-B kernel dims, #contracted)
_KERNEL_PATTERNS = {
    "gemm": (1, 1, 1),
    "gemv": (1, 0, 1),   # A matrix, B vector
    "gevm": (0, 1, 1),   # row-vector x matrix
    "ger": (1, 1, 0),    # outer-product update
    "dot": (0, 0, 1),
    "axpy_a": (1, 0, 0),  # scaled copy of an A fiber
    "axpy_b": (0, 1, 0),
}


@dataclass(frozen=True)
class ContractionAlgorithm:
    """One loop-nest + kernel decomposition of a contraction (§6.1)."""

    spec: ContractionSpec
    kernel: str
    kernel_dims: Tuple[str, ...]   # indices handled inside the kernel call
    loop_order: Tuple[str, ...]    # outer-to-inner loop indices

    @property
    def name(self) -> str:
        loops = "".join(self.loop_order) or "-"
        return f"loops[{loops}]_{self.kernel}[{''.join(self.kernel_dims)}]"

    def kernel_equation(self) -> str:
        """Einsum equation of one kernel invocation."""
        a = "".join(i for i in self.spec.a_idx if i in self.kernel_dims)
        b = "".join(i for i in self.spec.b_idx if i in self.kernel_dims)
        o = "".join(i for i in self.spec.out_idx if i in self.kernel_dims)
        return f"{a},{b}->{o}"

    def n_iterations(self, sizes: Mapping[str, int]) -> int:
        return math.prod(sizes[i] for i in self.loop_order) if \
            self.loop_order else 1

    def kernel_shapes(self, sizes: Mapping[str, int]):
        a = tuple(sizes[i] for i in self.spec.a_idx if i in self.kernel_dims)
        b = tuple(sizes[i] for i in self.spec.b_idx if i in self.kernel_dims)
        o = tuple(sizes[i] for i in self.spec.out_idx
                  if i in self.kernel_dims)
        return a, b, o

    def kernel_flops(self, sizes: Mapping[str, int]) -> float:
        return 2.0 * math.prod(sizes[i] for i in self.kernel_dims)


def generate_algorithms(spec: ContractionSpec,
                        max_loop_perms: int = 24) -> List[ContractionAlgorithm]:
    """Enumerate all loop/kernel decompositions (§6.1).

    For every kernel pattern, choose kernel indices (free-A, free-B,
    contracted) consistent with the pattern, make the rest loop indices, and
    emit one algorithm per loop-order permutation.
    """
    contracted = set(spec.contracted)
    batch = set(spec.batch)
    # batch indices are neither free nor contracted: the BLAS-style kernel
    # patterns cannot absorb them, so they may only become loop indices
    free_a = [i for i in spec.a_idx if i not in contracted and i not in batch]
    free_b = [i for i in spec.b_idx if i not in contracted and i not in batch]
    algs: List[ContractionAlgorithm] = []
    seen = set()
    for kernel, (nfa, nfb, nc) in _KERNEL_PATTERNS.items():
        for ka in itertools.combinations(free_a, nfa):
            for kb in itertools.combinations(free_b, nfb):
                for kc in itertools.combinations(sorted(contracted), nc):
                    kdims = tuple(ka) + tuple(kb) + tuple(kc)
                    loops = [i for i in spec.all_indices if i not in kdims]
                    perms = list(itertools.permutations(loops))
                    if len(perms) > max_loop_perms:
                        perms = perms[:max_loop_perms]
                    for order in perms:
                        key = (kernel, kdims, order)
                        if key in seen:
                            continue
                        seen.add(key)
                        algs.append(ContractionAlgorithm(
                            spec, kernel, kdims, order))
    return algs


# --------------------------------------------------------------- execution --

_ALPHABET = "abcdefghijklmnopqrstuvwxyzABCDEFGHIJKLMNOPQRSTUVWXYZ"


def canonical_equation(equation: str) -> str:
    """Relabel an einsum equation by order of first appearance.

    ``ik,kl->il`` and ``ij,jk->ik`` both become ``ab,bc->ac``: einsum is
    invariant under index renaming (operand *shapes* are positional), so
    one jitted kernel — and one micro-benchmark — serves every renaming.
    Execution (:func:`execute`) and the ``repro.tc`` suite both key on
    the canonical form, which keeps "first-call overhead once per
    distinct signature" true in practice: a chain step renamed from an
    earlier one reuses its compiled kernel instead of recompiling.
    """
    ins, out = equation.split("->")
    a, b = ins.split(",")
    mapping: Dict[str, str] = {}
    for ch in a + b + out:
        if ch not in mapping:
            mapping[ch] = _ALPHABET[len(mapping)]
    rename = lambda s: "".join(mapping[c] for c in s)  # noqa: E731
    return f"{rename(a)},{rename(b)}->{rename(out)}"


@functools.lru_cache(maxsize=None)
def _canonical_kernel_fn(equation: str):
    return jax.jit(lambda a, b: jnp.einsum(equation, a, b))


def _kernel_fn(equation: str):
    # one jit object per CANONICAL equation: renamed-identical kernels
    # share one compiled program (per shape), matching the suite's dedup
    # keys — canonicalize BEFORE the cache lookup, or every raw spelling
    # would get its own jit object and recompile
    return _canonical_kernel_fn(canonical_equation(equation))


def _slicer(idx: str, kernel_dims, assignment):
    return tuple(
        slice(None) if i in kernel_dims else assignment[i] for i in idx)


def execute(alg: ContractionAlgorithm, A: np.ndarray, B: np.ndarray,
            sizes: Mapping[str, int]) -> np.ndarray:
    """Run the loop nest, calling the jitted kernel per iteration."""
    spec = alg.spec
    out_shape = tuple(sizes[i] for i in spec.out_idx)
    C = np.zeros(out_shape, dtype=_DTYPE)
    fn = _kernel_fn(alg.kernel_equation())
    ranges = [range(sizes[i]) for i in alg.loop_order]
    accumulate = any(i in spec.contracted for i in alg.loop_order)
    for combo in itertools.product(*ranges):
        assign = dict(zip(alg.loop_order, combo))
        a = A[_slicer(spec.a_idx, alg.kernel_dims, assign)]
        b = B[_slicer(spec.b_idx, alg.kernel_dims, assign)]
        r = np.asarray(fn(jnp.asarray(a), jnp.asarray(b)))
        csl = _slicer(spec.out_idx, alg.kernel_dims, assign)
        if accumulate:
            C[csl] += r
        else:
            C[csl] = r
    return C


def execute_reference(spec: ContractionSpec, A: np.ndarray,
                      B: np.ndarray) -> np.ndarray:
    return np.einsum(spec.einsum_expr(), A, B)


# ----------------------------------------------------- cache-aware predict --

#: effective cache capacity used for access-distance decisions (bytes).
CACHE_BYTES = 32 * 2 ** 20


def access_distance(alg: ContractionAlgorithm,
                    sizes: Mapping[str, int]) -> Dict[str, float]:
    """Bytes touched between consecutive uses of the same slice (§6.2.3).

    For each operand, count the iterations of the innermost loops that do
    NOT index it: the same slice is reused once those loops cycle, and the
    data touched in between — that many calls' working sets — is the access
    distance.  Operands indexed by the innermost loop change slice every
    iteration, and operands not indexed by any loop are touched on *every*
    iteration; in both cases one call's working set separates consecutive
    uses (distance = ``call_bytes``, §6.2.3 — never 0: even an
    always-touched operand is evicted between uses if a single call's
    operands overflow the cache).
    """
    spec = alg.spec
    a_sh, b_sh, o_sh = alg.kernel_shapes(sizes)
    call_bytes = _ITEM * (math.prod(a_sh) + math.prod(b_sh) +
                          math.prod(o_sh))
    out = {}
    for name, idx in (("A", spec.a_idx), ("B", spec.b_idx),
                      ("C", spec.out_idx)):
        # walk loops inner -> outer; accumulate iteration space not touching
        # this operand, up to the innermost loop that does index it
        reuse_span = 1
        indexed = False
        for loop in reversed(alg.loop_order):
            if loop in idx:
                indexed = True
                break
            reuse_span *= sizes[loop]
        # not indexed by any loop (including loop-less algorithms): the
        # operand is touched on every call, one working set apart
        out[name] = call_bytes * (reuse_span if indexed else 1)
    return out


def _make_buffers(shape, n_copies, rng):
    return [jnp.asarray(rng.standard_normal(shape), dtype=jnp.float32)
            for _ in range(n_copies)]


def cold_pool_size(repetitions: int, call_bytes: int,
                   cache_bytes: int = CACHE_BYTES) -> int:
    """Buffers needed to keep a cold operand cold across the benchmark.

    A cold operand (access distance beyond the cache) must not re-enter the
    cache between timed calls.  Cycling through ``n`` buffers re-uses each
    one every ``n`` calls — i.e. with ``n * call_bytes`` of kernel traffic in
    between — so ``n`` must span the cache capacity; alternatively
    ``repetitions + 1`` buffers (one per timed call plus the overhead call)
    suffice outright because no buffer is ever re-used.  A fixed cap (the
    old ``min(8, repetitions)``) silently turned cold measurements warm
    whenever ``repetitions > 8`` and eight calls' traffic fit in cache.
    """
    span = math.ceil(cache_bytes / max(call_bytes, 1)) + 1
    return max(2, min(repetitions + 1, span))


def run_kernel_benchmark(equation: str, a_shape: Sequence[int],
                         b_shape: Sequence[int], out_shape: Sequence[int], *,
                         cold_a: bool, cold_b: bool, repetitions: int,
                         cache_bytes: int = CACHE_BYTES,
                         rng: Optional[np.random.Generator] = None,
                         ) -> Tuple[Stats, float]:
    """The §6.2 measurement protocol for one kernel signature.

    Returns (per-call stats, first-call overhead in seconds).  Cold input
    operands cycle through a pool of distinct buffers between timed calls —
    sized by :func:`cold_pool_size` from the repetition count and cache
    capacity — while warm ones reuse one buffer.  The kernel is a
    functional jitted einsum that allocates its output, so no output-cache
    precondition can (or need) be established.  Shared by the per-algorithm
    :func:`microbenchmark` and the deduplicated ``repro.tc`` suite, so the
    two paths can never desynchronize.
    """
    rng = rng or np.random.default_rng(0)
    fn = _kernel_fn(equation)
    call_bytes = _ITEM * (math.prod(a_shape) + math.prod(b_shape) +
                          math.prod(out_shape))
    n_cyc = cold_pool_size(repetitions, call_bytes, cache_bytes)
    a_bufs = _make_buffers(tuple(a_shape), n_cyc if cold_a else 1, rng)
    b_bufs = _make_buffers(tuple(b_shape), n_cyc if cold_b else 1, rng)

    counter = [0]

    def call():
        i = counter[0]
        counter[0] += 1
        # the sync IS the measurement: the §2.1.2 protocol brackets exactly
        # one kernel execution, so the timed call must drain the device
        # reprolint: allow[host-sync]
        fn(a_bufs[i % len(a_bufs)],
           b_bufs[i % len(b_bufs)]).block_until_ready()

    # first-call overhead (compile + cold libraries), measured separately
    t0 = time.perf_counter()
    call()
    first = time.perf_counter() - t0
    stats = measure_calls({"k": call}, repetitions=repetitions,
                          warm_pairs=False, warmup=False)["k"]
    return stats, first


def microbenchmark(alg: ContractionAlgorithm, sizes: Mapping[str, int], *,
                   repetitions: int = 5, cache_bytes: int = CACHE_BYTES,
                   rng: Optional[np.random.Generator] = None,
                   ) -> Tuple[Stats, float]:
    """Cache-aware micro-benchmark of ONE kernel invocation (§6.2).

    Classifies each input operand warm/cold by its access distance versus
    the cache capacity and delegates the measurement to
    :func:`run_kernel_benchmark`.
    """
    a_sh, b_sh, o_sh = alg.kernel_shapes(sizes)
    dists = access_distance(alg, sizes)
    return run_kernel_benchmark(alg.kernel_equation(), a_sh, b_sh, o_sh,
                                cold_a=dists["A"] > cache_bytes,
                                cold_b=dists["B"] > cache_bytes,
                                repetitions=repetitions,
                                cache_bytes=cache_bytes, rng=rng)


def predict_contraction(alg: ContractionAlgorithm,
                        sizes: Mapping[str, int], *,
                        repetitions: int = 5,
                        stat: str = "med",
                        breakdown: bool = False):
    """Predicted total runtime: first-call overhead + n_iterations x per-call.

    The measured first-call overhead (§6.2.6: library/compile setup paid
    once per contraction) is included once in the total; ``breakdown=True``
    returns the components instead of the single total.
    """
    stats, first = microbenchmark(alg, sizes, repetitions=repetitions)
    n = alg.n_iterations(sizes)
    per_call = getattr(stats, stat)
    total = first + per_call * n
    if breakdown:
        return {"total_s": total, "first_call_s": first,
                "loop_s": per_call * n, "per_call_s": per_call,
                "n_iterations": n}
    return total


def _session_for(fn: str, session, *, backend=None, suite=None, cache=None,
                 repetitions=None, extra_deprecated=None):
    """The one shim implementation behind every legacy entry point.

    A supplied ``session=`` conflicts with the legacy resource kwargs it
    replaced (silently preferring one would hide a caller bug); legacy
    kwargs construct a session internally under a single
    :class:`DeprecationWarning`; a bare call gets a fresh default session
    — exactly the resources it would have built before the redesign.
    """
    from ..tc.session import (PredictorSession,  # lazy: tc builds on core
                              warn_deprecated_kwargs)
    legacy = {"backend": backend, "suite": suite, "cache": cache,
              "repetitions": repetitions, **(extra_deprecated or {})}
    if session is not None:
        used = [k for k, v in legacy.items() if v is not None]
        if used:
            raise ValueError(
                f"{fn}: session= already owns the "
                f"{', '.join(k + '=' for k in used)} resource(s); pass "
                f"one or the other")
        return session
    warn_deprecated_kwargs(fn, "the session's methods", legacy,
                           stacklevel=4)
    return PredictorSession(backend=backend or "numpy", suite=suite,
                            cache=cache, repetitions=repetitions)


def rank_contraction_algorithms(spec: ContractionSpec,
                                sizes: Optional[Mapping[str, int]] = None, *,
                                algorithms: Optional[Sequence[
                                    ContractionAlgorithm]] = None,
                                repetitions: Optional[int] = None,
                                stat: str = "med",
                                batched: bool = True,
                                backend: Optional[str] = None,
                                suite=None,
                                cache=None,
                                sizes_grid: Optional[Sequence[
                                    Mapping[str, int]]] = None,
                                session=None,
                                ) -> Union[
                                    List[Tuple[ContractionAlgorithm, float]],
                                    List[List[Tuple[ContractionAlgorithm,
                                                    float]]]]:
    """Predict every algorithm and sort ascending by predicted runtime.

    By default this runs on :class:`repro.tc.ContractionPredictor`: the
    candidate set (including batched-kernel algorithms when ``algorithms``
    is not given) shares one deduplicated micro-benchmark suite and is
    predicted through the batched :class:`PredictionEngine`.  Pass
    ``session=`` (a :class:`repro.tc.PredictorSession`) to share its
    suite, trace cache and backend across calls — the sprawl of
    per-call ``backend=``/``suite=``/``cache=``/``repetitions=``/
    ``sizes_grid=`` keywords is DEPRECATED in favor of the session and
    its methods (one release of shim support: they still work, warning,
    by constructing a session internally).  ``batched=False`` keeps the
    original per-algorithm path — one independent micro-benchmark per
    candidate — as the equivalence oracle.

    Size-sweep mode: pass ``sizes_grid=`` (a sequence of size mappings)
    instead of ``sizes`` to rank the candidate set at every size point
    from ONE shared suite — returns one ranked list per size point, and
    only the genuinely new (equation, shapes, cache-class) keys are
    measured (deprecated alias of
    :meth:`repro.tc.PredictorSession.rank_contraction_sweep`, which also
    exposes the shared suite and per-point predictors).
    """
    algorithms = list(algorithms) if algorithms is not None else None
    if sizes_grid is not None:
        if sizes is not None:
            raise ValueError("pass sizes= or sizes_grid=, not both")
        if not batched:
            raise ValueError("sizes_grid= runs on the batched predictor; "
                             "the scalar oracle (batched=False) has no "
                             "size-sweep mode")
        sess = _session_for("rank_contraction_algorithms", session,
                            backend=backend, suite=suite, cache=cache,
                            repetitions=repetitions,
                            extra_deprecated={"sizes_grid": sizes_grid})
        sweep = sess.rank_contraction_sweep(spec, sizes_grid, stat=stat,
                                            algorithms=algorithms)
        return [[(r.algorithm, getattr(r.runtime, stat)) for r in ranking]
                for ranking in sweep.rankings]
    if sizes is None:
        raise ValueError("sizes is required (or pass sizes_grid= for the "
                         "size-sweep mode)")
    if batched:
        sess = _session_for("rank_contraction_algorithms", session,
                            backend=backend, suite=suite, cache=cache,
                            repetitions=repetitions)
        ranked = sess.rank_contraction_algorithms(spec, sizes, stat=stat,
                                                  algorithms=algorithms)
        return [(r.algorithm, getattr(r.runtime, stat)) for r in ranked]
    if backend is not None or suite is not None or cache is not None:
        raise ValueError("backend=/suite=/cache= apply to the batched "
                         "predictor; the scalar oracle (batched=False) has "
                         "none of them")
    if session is not None:
        raise ValueError("session= applies to the batched predictor; the "
                         "scalar oracle (batched=False) runs without one")
    algs = algorithms if algorithms is not None else \
        generate_algorithms(spec)
    reps = 5 if repetitions is None else repetitions
    ranked = [(a, predict_contraction(a, sizes, repetitions=reps,
                                      stat=stat)) for a in algs]
    ranked.sort(key=lambda t: t[1])
    return ranked


def measure_contraction(alg: ContractionAlgorithm, A: np.ndarray,
                        B: np.ndarray, sizes: Mapping[str, int],
                        repetitions: int = 3) -> Stats:
    """Time full algorithm executions (the expensive reference, §6.3)."""
    execute(alg, A, B, sizes)  # warm-up/compile
    samples = []
    for _ in range(repetitions):
        t0 = time.perf_counter()
        execute(alg, A, B, sizes)
        samples.append(time.perf_counter() - t0)
    return Stats.from_samples(samples)
