"""Adaptive-refinement model generation (paper §3.2.5, §3.3).

Starting from one hyper-cuboidal domain, fit one polynomial per summary
statistic to measurements on a sampling grid; if the *error measure* of the
*reference statistic*'s fit exceeds the *target error bound*, bisect the
domain along its relatively largest dimension and recurse, until either the
bound or the *minimum width* is reached.  The eight configuration parameters
of §3.3.1 are grouped in :class:`GeneratorConfig`; its defaults are the
paper's selected default configuration (Table 3.3, row 10).
"""

from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Callable, Dict, List, Mapping, Optional, Sequence, Tuple

import numpy as np

from .fitting import (Exponents, Polynomial, error_measure, fit_relative,
                      monomial_basis, relative_errors)
from .grids import Domain, Point, grid_points
from .model import Piece
from .sampler import STATS, Stats


@dataclass(frozen=True)
class GeneratorConfig:
    """§3.3.1 configuration parameters (defaults = Table 3.3 line 10)."""

    overfit: int = 2
    oversampling: int = 4
    grid: str = "chebyshev"          # or "cartesian"
    repetitions: int = 10
    reference_stat: str = "min"      # or "med"
    error_kind: str = "maximum"      # or "average" / "p90"
    error_bound: float = 0.01
    min_width: int = 32
    round_to: int = 8
    max_pieces: int = 128            # safety cap (not in the paper)
    #: measurement budget (not in the paper): once this many points have
    #: been *freshly* sampled, the current pieces become terminal — no
    #: further bisection.  The root grid is always sampled in full, so
    #: the total may overshoot by at most one grid.  ``None`` = unbounded.
    max_points: Optional[int] = None


SampleFn = Callable[[Sequence[Point]], Mapping[Point, Stats]]


def _points_per_dim(basis: Sequence[Exponents], ndim: int,
                    oversampling: int) -> List[int]:
    # at least degree+1 points per dim, plus `oversampling` extra (§3.3.1)
    out = []
    for d in range(ndim):
        deg = max(e[d] for e in basis)
        out.append(deg + 1 + oversampling)
    return out


class _Cache:
    """Measurement cache enabling point reuse across refinement levels.

    ``known`` pre-seeds the cache with measurements taken elsewhere (e.g.
    a suite's exact-shape results): those points are served without
    sampling and do NOT count toward :attr:`measured_points`, so a
    measurement budget (:attr:`GeneratorConfig.max_points`) bounds only
    the *fresh* work refinement causes.
    """

    def __init__(self, sample_fn: SampleFn,
                 known: Optional[Mapping[Point, Stats]] = None):
        self.sample_fn = sample_fn
        self.data: Dict[Point, Stats] = dict(known) if known else {}
        self.measured_points = 0

    def get(self, points: Sequence[Point]) -> Dict[Point, Stats]:
        missing = [p for p in points if p not in self.data]
        if missing:
            new = self.sample_fn(missing)
            self.data.update(new)
            self.measured_points += len(missing)
        return {p: self.data[p] for p in points}


def _fit_piece(domain: Domain, stats: Mapping[Point, Stats],
               basis: Sequence[Exponents],
               ref_stat: str) -> Tuple[Piece, np.ndarray]:
    points = list(stats.keys())
    pts = np.asarray(points, dtype=np.float64)
    polys: Dict[str, Polynomial] = {}
    for s in STATS:
        vals = np.asarray([getattr(stats[p], s) for p in points])
        if s == "std":
            # std can be 0 -> relative fit undefined; fit on mean-relative floor
            floor = max(1e-12, float(np.median(
                [getattr(stats[p], "mean") for p in points])) * 1e-6)
            vals = np.maximum(vals, floor)
        polys[s] = fit_relative(pts, vals, basis)
    ref_vals = np.asarray([getattr(stats[p], ref_stat) for p in points])
    errs = relative_errors(polys[ref_stat], pts, ref_vals)
    return Piece(domain=domain, polys=polys), errs


def refine(domain: Domain, sample_fn: SampleFn,
           cost_exponents: Sequence[Exponents],
           config: GeneratorConfig = GeneratorConfig(), *,
           known: Optional[Mapping[Point, Stats]] = None) -> List[Piece]:
    """Generate the piecewise-polynomial sub-model for one case (§3.2.5).

    ``known`` pre-seeds the measurement cache (see :class:`_Cache`):
    points already measured elsewhere are reused without sampling and
    without counting toward ``config.max_points``.
    """
    basis = monomial_basis(cost_exponents, overfit=config.overfit)
    cache = _Cache(sample_fn, known=known)
    pieces: List[Piece] = []
    stack = [domain]
    while stack:
        dom = stack.pop()
        ppd = _points_per_dim(basis, dom.ndim, config.oversampling)
        pts = grid_points(dom, ppd, kind=config.grid,
                          round_to=config.round_to)
        if len(pts) < len(basis):
            # rounding collapsed the grid below the basis size: densify
            pts = grid_points(dom, [p * 2 for p in ppd], kind="cartesian",
                              round_to=config.round_to)
        stats = cache.get(pts)
        piece, errs = _fit_piece(dom, stats, basis, config.reference_stat)
        err = error_measure(errs, config.error_kind)
        terminal = (
            err <= config.error_bound
            or dom.min_width() < config.min_width
            or len(pieces) + len(stack) + 2 > config.max_pieces
            or (config.max_points is not None
                and cache.measured_points >= config.max_points)
        )
        if terminal:
            pieces.append(piece)
        else:
            lo_half, hi_half, _ = dom.split(config.round_to)
            if lo_half.widths() == dom.widths() or \
               hi_half.widths() == dom.widths():
                pieces.append(piece)  # split made no progress
            else:
                stack.extend((lo_half, hi_half))
    return pieces


def stats_sample_fn(measure: Callable[[Point], Callable[[], None]],
                    repetitions: int = 10, seed: int = 0) -> SampleFn:
    """Wrap a call builder into a SampleFn using the ELAPS-style sampler."""
    from .sampler import measure_calls

    def sample(points: Sequence[Point]) -> Dict[Point, Stats]:
        calls = {p: measure(p) for p in points}
        return dict(measure_calls(calls, repetitions=repetitions, seed=seed))

    return sample
