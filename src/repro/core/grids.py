"""Sampling-point distributions over hyper-cuboidal size domains (paper §3.2.2).

Two regular grids are supported:

* a *Cartesian* grid — evenly spaced, maximal point reuse under the
  adaptive-refinement bisection (§3.2.5);
* a *Chebyshev* grid — the boundary-including variant
  ``x_i = cos(i/(n-1) * pi)`` mapped onto each interval, which concentrates
  points near the domain boundary and minimizes polynomial-fit error.

All generated points are rounded to multiples of ``round_to`` (8 in the
paper, §3.1.5.1; 128 for MXU-aligned TPU tiles) to avoid small-scale
vectorization artefacts.
"""

from __future__ import annotations

import itertools
from dataclasses import dataclass
from typing import Iterator, Sequence, Tuple

import numpy as np

Point = Tuple[int, ...]


@dataclass(frozen=True)
class Domain:
    """A hyper-cuboidal domain of size arguments: [lo_i, hi_i] per dim."""

    lo: Tuple[int, ...]
    hi: Tuple[int, ...]

    def __post_init__(self):
        if len(self.lo) != len(self.hi):
            raise ValueError("lo/hi rank mismatch")
        for l, h in zip(self.lo, self.hi):
            if l > h:
                raise ValueError(f"empty domain interval [{l}, {h}]")

    @property
    def ndim(self) -> int:
        return len(self.lo)

    def widths(self) -> Tuple[int, ...]:
        return tuple(h - l for l, h in zip(self.lo, self.hi))

    def contains(self, point: Sequence[int]) -> bool:
        return all(l <= p <= h for l, p, h in zip(self.lo, point, self.hi))

    def relative_widths(self) -> Tuple[float, ...]:
        """u_i / l_i — the paper splits along the relatively largest dim."""
        return tuple(h / max(l, 1) for l, h in zip(self.lo, self.hi))

    def split(self, round_to: int = 8) -> Tuple["Domain", "Domain", int]:
        """Bisect along the relatively largest dimension (§3.2.5).

        The midpoint is rounded to the nearest multiple of ``round_to``.
        Returns (lower_half, upper_half, split_dim).
        """
        rel = self.relative_widths()
        dim = int(np.argmax(rel))
        l, h = self.lo[dim], self.hi[dim]
        mid = round_to * int(np.floor((l + h + round_to) / (2 * round_to)))
        mid = min(max(mid, l), h)
        lo_a, hi_a = list(self.lo), list(self.hi)
        lo_b, hi_b = list(self.lo), list(self.hi)
        hi_a[dim] = mid
        lo_b[dim] = mid
        return (Domain(tuple(lo_a), tuple(hi_a)),
                Domain(tuple(lo_b), tuple(hi_b)), dim)

    def min_width(self) -> int:
        return min(self.widths())


def _axis_points(lo: int, hi: int, n: int, kind: str, round_to: int) -> np.ndarray:
    if n < 1:
        raise ValueError("need at least one point per axis")
    if n == 1 or lo == hi:
        pts = np.array([0.5 * (lo + hi)])
    elif kind == "cartesian":
        pts = lo + (hi - lo) * np.arange(n) / (n - 1)
    elif kind == "chebyshev":
        # boundary-including Chebyshev grid: cos(i/(n-1) * pi) on [-1, 1]
        t = np.cos(np.arange(n) / (n - 1) * np.pi)  # 1 .. -1
        pts = lo + (hi - lo) * (1.0 - t) / 2.0
    else:
        raise ValueError(f"unknown grid kind {kind!r}")
    pts = round_to * np.round(pts / round_to)
    pts = np.clip(pts, round_to * np.ceil(lo / round_to),
                  round_to * np.floor(hi / round_to))
    return np.unique(pts.astype(np.int64))


def grid_points(domain: Domain, points_per_dim: Sequence[int],
                kind: str = "chebyshev", round_to: int = 8) -> list:
    """Full tensor grid of sampling points, rounded & deduplicated."""
    if len(points_per_dim) != domain.ndim:
        raise ValueError("points_per_dim rank mismatch")
    axes = [
        _axis_points(l, h, n, kind, round_to)
        for l, h, n in zip(domain.lo, domain.hi, points_per_dim)
    ]
    return [tuple(int(v) for v in p) for p in itertools.product(*axes)]


def reused_points(old: Sequence[Point], new_domain: Domain) -> list:
    """Points from a parent grid that fall inside a refined sub-domain.

    Cartesian grids get perfect reuse under bisection (§3.2.2/Fig 3.10);
    Chebyshev grids only reuse the shared boundary points.
    """
    return [p for p in old if new_domain.contains(p)]
