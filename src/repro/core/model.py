"""Performance-model structure (paper §3.2.1, Fig 3.9).

A :class:`PerformanceModel` represents the runtime of ONE kernel on ONE setup
(hardware, thread count, library).  It is composed of *cases* — discrete
combinations of flag-like arguments — and, per case, a *piecewise polynomial*
over the hyper-cuboidal domain of size arguments.  Each polynomial piece
actually carries one polynomial per runtime summary statistic
(min/med/max/mean/std), so estimates are distributions, not point values.
"""

from __future__ import annotations

import json
from dataclasses import dataclass, field
from typing import Dict, Hashable, List, Mapping, Optional, Sequence, Tuple

import numpy as np

from .fitting import (Polynomial, StackedPolynomials, monomials_jnp,
                      stack_polynomials)
from .grids import Domain
from .sampler import STATS

Case = Tuple  # hashable combination of flag/scalar-class/layout arguments


def _freeze(value):
    """Lists to tuples, recursively: the inverse of a JSON round trip for
    the hashable nested-tuple cases models are keyed by."""
    if isinstance(value, list):
        return tuple(_freeze(v) for v in value)
    return value


# ------------------------------------------------------------ JAX backend --

_JAX_CASE_EVAL = None


def _case_eval_impl(pts, lo, hi, exps, scl, cof, *, mask_degenerate):
    """Fused piece lookup + stacked polynomial evaluation (one XLA program).

    ``pts (N, d)``; ``lo/hi (P, d)`` piece domains; ``exps/scl (P, M, d)``
    and ``cof (P, M, S)`` zero-padded flattened piece polynomials.  Mirrors
    the numpy path exactly: first containing piece wins, rows outside every
    domain clamp to the smallest squared clamp distance (first on ties),
    estimates clip at 0, and — with ``mask_degenerate`` — rows with any
    non-positive size are zero-work calls estimating to all-zero statistics.
    """
    import jax.numpy as jnp

    live = jnp.all(pts > 0, axis=1)
    # degenerate rows are masked out at the end; evaluate them at a benign
    # in-range point so 0/negative sizes never hit the power/divide
    safe = jnp.where(live[:, None], pts, 1.0) if mask_degenerate else pts
    inside = jnp.all((safe[:, None, :] >= lo[None]) &
                     (safe[:, None, :] <= hi[None]), axis=-1)    # (N, P)
    below = jnp.maximum(lo[None] - safe[:, None, :], 0.0)
    above = jnp.maximum(safe[:, None, :] - hi[None], 0.0)
    dist = (below ** 2).sum(-1) + (above ** 2).sum(-1)           # (N, P)
    pidx = jnp.where(inside.any(axis=1), jnp.argmax(inside, axis=1),
                     jnp.argmin(dist, axis=1))
    e, s, c = exps[pidx], scl[pidx], cof[pidx]                   # (N, M, *)
    X = monomials_jnp(safe, e, s)                                # (N, M)
    out = jnp.maximum(jnp.einsum("nm,nms->ns", X, c), 0.0)
    if mask_degenerate:
        out = jnp.where(live[:, None], out, 0.0)
    return out


def _jax_case_eval(pts: np.ndarray, tensors, *,
                   mask_degenerate: bool) -> np.ndarray:
    """Run the jitted case evaluator in float64 (~1e-8 vs numpy)."""
    global _JAX_CASE_EVAL
    import jax
    from jax.experimental import enable_x64

    if _JAX_CASE_EVAL is None:
        _JAX_CASE_EVAL = jax.jit(_case_eval_impl,
                                 static_argnames="mask_degenerate")
    with enable_x64():
        return np.asarray(_JAX_CASE_EVAL(
            pts, *tensors, mask_degenerate=mask_degenerate))


@dataclass(frozen=True)
class Piece:
    """One polynomial piece: a domain plus per-statistic polynomials."""

    domain: Domain
    polys: Dict[str, Polynomial]  # stat name -> polynomial

    def estimate(self, sizes: Sequence[int]) -> Dict[str, float]:
        return {s: max(float(p(np.asarray(sizes, dtype=np.float64)[None, :])),
                       0.0)
                for s, p in self.polys.items()}

    def _stacked(self) -> StackedPolynomials:
        """Lazily compiled batch evaluator over the canonical STATS order."""
        cached = self.__dict__.get("_stacked_cache")
        if cached is None:
            cached = stack_polynomials([self.polys[s] for s in STATS])
            object.__setattr__(self, "_stacked_cache", cached)
        return cached

    def estimate_batch(self, sizes: np.ndarray) -> np.ndarray:
        """Estimates for (N, d) size points: (N, len(STATS)), clipped at 0."""
        pts = np.atleast_2d(np.asarray(sizes, dtype=np.float64))
        return np.maximum(self._stacked()(pts), 0.0)


@dataclass
class CaseModel:
    """One kernel case's piecewise model: the pieces covering its domain.

    ``estimate``/``estimate_batch`` look up the piece containing the
    requested sizes and evaluate its per-statistic polynomials.
    """

    pieces: List[Piece] = field(default_factory=list)

    def find_piece(self, sizes: Sequence[int]) -> Optional[Piece]:
        for piece in self.pieces:
            if piece.domain.contains(sizes):
                return piece
        return None

    def nearest_piece(self, sizes: Sequence[int]) -> Piece:
        """Clamp out-of-domain queries to the closest piece (extrapolation)."""
        if not self.pieces:
            raise KeyError("empty case model")
        best, best_d = None, None
        for piece in self.pieces:
            d = 0.0
            for lo, hi, x in zip(piece.domain.lo, piece.domain.hi, sizes):
                if x < lo:
                    d += (lo - x) ** 2
                elif x > hi:
                    d += (x - hi) ** 2
            if best_d is None or d < best_d:
                best, best_d = piece, d
        return best

    # ----------------------------------------------------------- batched --
    def piece_indices(self, sizes: np.ndarray,
                      *, extrapolate: bool = True) -> np.ndarray:
        """Vectorized piece lookup for (N, d) size points.

        Mirrors the scalar path exactly: the first containing piece wins;
        rows outside every domain are clamped to the piece with the smallest
        squared clamp distance (first piece on ties, like ``nearest_piece``).
        """
        if not self.pieces:
            raise KeyError("empty case model")
        pts = np.atleast_2d(np.asarray(sizes, dtype=np.float64))
        n = pts.shape[0]
        idx = np.full(n, -1, dtype=np.intp)
        for i, piece in enumerate(self.pieces):
            lo = np.asarray(piece.domain.lo, dtype=np.float64)
            hi = np.asarray(piece.domain.hi, dtype=np.float64)
            inside = np.all((pts >= lo) & (pts <= hi), axis=1)
            idx = np.where((idx < 0) & inside, i, idx)
        missing = idx < 0
        if missing.any():
            if not extrapolate:
                raise KeyError(f"{int(missing.sum())} points outside domain")
            out_pts = pts[missing]
            dist = np.empty((out_pts.shape[0], len(self.pieces)))
            for i, piece in enumerate(self.pieces):
                lo = np.asarray(piece.domain.lo, dtype=np.float64)
                hi = np.asarray(piece.domain.hi, dtype=np.float64)
                below = np.maximum(lo - out_pts, 0.0)
                above = np.maximum(out_pts - hi, 0.0)
                dist[:, i] = (below ** 2).sum(axis=1) + (above ** 2).sum(axis=1)
            idx[missing] = np.argmin(dist, axis=1)
        return idx

    def estimate_batch(self, sizes: np.ndarray,
                       *, extrapolate: bool = True,
                       backend: str = "numpy") -> np.ndarray:
        """Batched estimates for (N, d) size points: (N, len(STATS))."""
        pts = np.atleast_2d(np.asarray(sizes, dtype=np.float64))
        if backend == "jax":
            if not extrapolate:
                # keep the numpy path's out-of-domain error semantics; the
                # jitted program itself always clamps
                self.piece_indices(pts, extrapolate=False)
            return _jax_case_eval(pts, self.padded_tensors(),
                                  mask_degenerate=False)
        idx = self.piece_indices(pts, extrapolate=extrapolate)
        out = np.empty((pts.shape[0], len(STATS)), dtype=np.float64)
        for i, piece in enumerate(self.pieces):
            rows = np.nonzero(idx == i)[0]
            if rows.size:
                out[rows] = piece.estimate_batch(pts[rows])
        return out

    def padded_tensors(self):
        """Per-piece flattened polynomials padded to one (P, M, ·) tensor.

        Returns ``(lo (P, d), hi (P, d), exps (P, M, d), scl (P, M, d),
        cof (P, M, S))`` — the case's whole piecewise model as dense
        tensors.  Pieces with fewer monomial rows are zero-padded
        (exponent 0, scale 1, coefficient 0 — an exact no-op row), so one
        gather + einsum serves the whole case; the prediction engine pads
        these further across (kernel, case) groups into its fused
        one-dispatch program.  Memoized, and rebuilt whenever the piece
        list changes (compared by identity: ``pieces`` is a public
        mutable list, and a replaced piece must not serve stale tensors);
        ``modelgen`` emits them eagerly via :meth:`PerformanceModel.
        finalize` so first predictions don't pay the derivation.
        """
        if not self.pieces:
            raise KeyError("empty case model")
        cached = getattr(self, "_jax_cache", None)
        if cached is not None and len(cached[0]) == len(self.pieces) \
                and all(a is b for a, b in zip(cached[0], self.pieces)):
            return cached[1]
        flat = [p._stacked().flattened() for p in self.pieces]
        m_max = max(e.shape[0] for e, _, _ in flat)
        exps, scl, cof = [], [], []
        for e, s, c in flat:
            pad = m_max - e.shape[0]
            exps.append(np.pad(e, ((0, pad), (0, 0))))
            scl.append(np.pad(s, ((0, pad), (0, 0)), constant_values=1.0))
            cof.append(np.pad(c, ((0, pad), (0, 0))))
        tensors = (
            np.asarray([p.domain.lo for p in self.pieces], dtype=np.float64),
            np.asarray([p.domain.hi for p in self.pieces], dtype=np.float64),
            np.stack(exps), np.stack(scl), np.stack(cof),
        )
        self._jax_cache = (tuple(self.pieces), tensors)
        return tensors


@dataclass
class PerformanceModel:
    """Piecewise-polynomial runtime model of one kernel (§3.2.1)."""

    kernel: str
    setup: str = "default"
    cases: Dict[Case, CaseModel] = field(default_factory=dict)

    def add_piece(self, case: Case, piece: Piece) -> None:
        self.cases.setdefault(tuple(case), CaseModel()).pieces.append(piece)

    def finalize(self) -> "PerformanceModel":
        """Emit every case's padded tensors eagerly (returns ``self``).

        ``modelgen`` calls this after fitting, so the dense per-case
        tensors the fused prediction engine gathers from are part of the
        generated artifact rather than re-derived on first predict."""
        for cm in self.cases.values():
            if cm.pieces:
                cm.padded_tensors()
        return self

    def estimate(self, case: Case, sizes: Sequence[int],
                 *, extrapolate: bool = True) -> Dict[str, float]:
        """Runtime summary-statistic estimates for one kernel invocation."""
        if any(s <= 0 for s in sizes):
            # degenerate call: zero work (Example 4.1's 0-width panels)
            return {s: 0.0 for s in STATS}
        cm = self.cases.get(tuple(case))
        if cm is None:
            raise KeyError(f"{self.kernel}: no model for case {case!r} "
                           f"(have {list(self.cases)})")
        piece = cm.find_piece(sizes)
        if piece is None:
            if not extrapolate:
                raise KeyError(f"{self.kernel}{case}: {sizes} outside domain")
            piece = cm.nearest_piece(sizes)
        return piece.estimate(sizes)

    def estimate_batch(self, case: Case, sizes: np.ndarray,
                       *, extrapolate: bool = True,
                       backend: str = "numpy") -> np.ndarray:
        """Batched estimates: (N, d) size points -> (N, len(STATS)).

        Rows with any non-positive size are degenerate zero-work calls
        (Example 4.1) and estimate to all-zero statistics, exactly like the
        scalar :meth:`estimate` — including before the case lookup, so a
        case whose every call is degenerate needs no model at all.

        ``backend="jax"`` runs piece lookup, design matrices, matmuls and
        the degenerate mask as one jitted float64 XLA program over the
        case's padded tensors (one compile per input shape, then cached).
        """
        pts = np.atleast_2d(np.asarray(sizes, dtype=np.float64))
        live = np.all(pts > 0, axis=1)
        if not live.any():
            return np.zeros((pts.shape[0], len(STATS)), dtype=np.float64)
        cm = self.cases.get(tuple(case))
        if cm is None:
            raise KeyError(f"{self.kernel}: no model for case {case!r} "
                           f"(have {list(self.cases)})")
        if backend == "jax":
            if not extrapolate:
                cm.piece_indices(pts[live], extrapolate=False)
            return _jax_case_eval(pts, cm.padded_tensors(),
                                  mask_degenerate=True)
        out = np.zeros((pts.shape[0], len(STATS)), dtype=np.float64)
        out[live] = cm.estimate_batch(pts[live], extrapolate=extrapolate)
        return out

    # ---------------------------------------------------------------- io --
    def to_dict(self) -> dict:
        return {
            "kernel": self.kernel,
            "setup": self.setup,
            "cases": [
                {
                    "case": list(case),
                    "pieces": [
                        {"lo": list(p.domain.lo), "hi": list(p.domain.hi),
                         "polys": {s: poly.to_dict()
                                   for s, poly in p.polys.items()}}
                        for p in cm.pieces
                    ],
                }
                for case, cm in self.cases.items()
            ],
        }

    @staticmethod
    def from_dict(d: dict) -> "PerformanceModel":
        m = PerformanceModel(kernel=d["kernel"], setup=d.get("setup", ""))
        for case_entry in d["cases"]:
            # deep-freeze: JSON turns the case's nested tuples (operand
            # shapes, cache classes in the tc per-signature cases) into
            # lists, which would neither hash nor compare equal to the
            # tuples lookups are keyed by
            case = _freeze(case_entry["case"])
            for p in case_entry["pieces"]:
                piece = Piece(
                    domain=Domain(tuple(p["lo"]), tuple(p["hi"])),
                    polys={s: Polynomial.from_dict(pd)
                           for s, pd in p["polys"].items()},
                )
                m.add_piece(case, piece)
        # re-finalize: the padded case tensors finalize() emitted before
        # the save are part of the artifact and must be part of the load
        return m.finalize()

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.to_dict(), f)

    @staticmethod
    def load(path: str) -> "PerformanceModel":
        with open(path) as f:
            return PerformanceModel.from_dict(json.load(f))


class ModelSet:
    """The per-setup database of kernel models (Fig 3.9 top level)."""

    def __init__(self, models: Mapping[str, PerformanceModel] = ()):
        self.models: Dict[str, PerformanceModel] = dict(models)

    def __getitem__(self, kernel: str) -> PerformanceModel:
        return self.models[kernel]

    def __contains__(self, kernel: str) -> bool:
        return kernel in self.models

    def add(self, model: PerformanceModel) -> None:
        self.models[model.kernel] = model

    def finalize(self) -> "ModelSet":
        """:meth:`PerformanceModel.finalize` every model (returns
        ``self``): all padded case tensors emitted up front."""
        for model in self.models.values():
            model.finalize()
        return self

    def estimate(self, kernel: str, case: Case,
                 sizes: Sequence[int]) -> Dict[str, float]:
        return self.models[kernel].estimate(case, sizes)

    def estimate_batch(self, kernel: str, case: Case, sizes: np.ndarray,
                       *, backend: str = "numpy") -> np.ndarray:
        return self.models[kernel].estimate_batch(case, sizes,
                                                  backend=backend)

    # ---------------------------------------------------------------- io --
    def to_dict(self) -> dict:
        return {"models": [self.models[k].to_dict()
                           for k in sorted(self.models)]}

    @staticmethod
    def from_dict(d: dict) -> "ModelSet":
        ms = ModelSet()
        for entry in d["models"]:
            # from_dict finalizes each model, so the loaded set's padded
            # case tensors match what finalize() emitted before the save
            ms.add(PerformanceModel.from_dict(entry))
        return ms

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.to_dict(), f)

    @staticmethod
    def load(path: str) -> "ModelSet":
        with open(path) as f:
            return ModelSet.from_dict(json.load(f))
