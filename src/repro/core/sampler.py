"""ELAPS-style measurement harness (paper Ch. 2), adapted to JAX.

The paper's SAMPLER times BLAS calls in CPU cycles; here the measured unit is
a zero-argument callable that executes one jitted JAX kernel invocation and
blocks until the result is ready.  The harness reproduces the paper's
methodology for stable timings:

* **initialization overhead** (§2.1.1): every callable is invoked once,
  untimed, before measurement (this also triggers XLA compilation);
* **fluctuations / performance levels** (§2.1.2): repetitions of all calls are
  *shuffled* across the whole experiment rather than batched per call;
* **cache preconditions** (§2.1.4, §3.2.3): in ``warm_pairs`` mode each
  repetition executes the call twice back-to-back and only the second (warm)
  execution is recorded;
* **summary statistics** (§2.1.2.1): min / median / max / mean / std are kept,
  never a single sample.
"""

from __future__ import annotations

import random
import time
from dataclasses import dataclass
from typing import Callable, Dict, Hashable, Iterable, List, Mapping

#: the five summary statistics every measurement and prediction carries,
#: in the column order of all batched (N, 5) statistics arrays
STATS = ("min", "med", "max", "mean", "std")


@dataclass(frozen=True)
class Stats:
    """Summary statistics of repeated runtime measurements (seconds)."""

    min: float
    med: float
    max: float
    mean: float
    std: float

    def as_dict(self) -> Dict[str, float]:
        return {"min": self.min, "med": self.med, "max": self.max,
                "mean": self.mean, "std": self.std}

    @staticmethod
    def from_samples(samples: Iterable[float]) -> "Stats":
        xs = sorted(samples)
        n = len(xs)
        if n == 0:
            raise ValueError("no samples")
        mean = sum(xs) / n
        var = sum((x - mean) ** 2 for x in xs) / n
        med = xs[n // 2] if n % 2 else 0.5 * (xs[n // 2 - 1] + xs[n // 2])
        return Stats(min=xs[0], med=med, max=xs[-1], mean=mean,
                     std=var ** 0.5)


def _time_once(call: Callable[[], None]) -> float:
    t0 = time.perf_counter()
    call()
    return time.perf_counter() - t0


def measure_calls(calls: Mapping[Hashable, Callable[[], None]],
                  repetitions: int = 10,
                  *,
                  shuffle: bool = True,
                  warm_pairs: bool = True,
                  warmup: bool = True,
                  seed: int = 0) -> Dict[Hashable, Stats]:
    """Measure a set of calls with shuffled repetitions.

    ``calls`` maps an arbitrary key (e.g. a sampling point) to a callable
    executing one kernel invocation synchronously.
    """
    keys = list(calls.keys())
    if warmup:
        for k in keys:
            calls[k]()  # compile + library init, untimed
    schedule: List[Hashable] = [k for k in keys for _ in range(repetitions)]
    if shuffle:
        random.Random(seed).shuffle(schedule)
    samples: Dict[Hashable, List[float]] = {k: [] for k in keys}
    for k in schedule:
        call = calls[k]
        if warm_pairs:
            call()  # establish warm cache precondition, untimed
        samples[k].append(_time_once(call))
    return {k: Stats.from_samples(v) for k, v in samples.items()}


def measure_single(call: Callable[[], None], repetitions: int = 10,
                   **kw) -> Stats:
    """Time one nullary ``call`` ``repetitions`` times and summarize.

    Convenience wrapper over :func:`measure_calls` for a single call;
    keyword arguments (``warm_pairs``, ``warmup``, ...) pass through.
    Returns the per-call runtime :class:`Stats` in seconds.
    """
    return measure_calls({"_": call}, repetitions, **kw)["_"]
