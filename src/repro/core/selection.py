"""Algorithm selection and block-size optimization (paper §4.5 / §4.6).

Given a set of mathematically-equivalent blocked-algorithm variants — each
represented by a *tracer* producing its kernel-call sequence for a problem
size n and block size b — rank them by predicted runtime, entirely without
executing any of them.  Block-size optimization evaluates the prediction over
a candidate grid of b and returns the argmin plus the whole profile (used to
compute the paper's "performance yield" against empirical optima).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Dict, Iterable, List, Mapping, Optional, Sequence, Tuple

from .model import ModelSet
from .predict import KernelCall, predict_runtime
from .sampler import Stats

Tracer = Callable[[int, int], List[KernelCall]]  # (n, b) -> call sequence


@dataclass(frozen=True)
class RankedAlgorithm:
    name: str
    runtime: Stats
    block_size: int


def rank_algorithms(tracers: Mapping[str, Tracer], models: ModelSet,
                    n: int, b: int, *,
                    stat: str = "med") -> List[RankedAlgorithm]:
    """Predict every variant's runtime and sort ascending (§4.5)."""
    ranked = [
        RankedAlgorithm(name=name,
                        runtime=predict_runtime(tracer(n, b), models),
                        block_size=b)
        for name, tracer in tracers.items()
    ]
    ranked.sort(key=lambda r: getattr(r.runtime, stat))
    return ranked


def select_algorithm(tracers: Mapping[str, Tracer], models: ModelSet,
                     n: int, b: int, *, stat: str = "med") -> str:
    return rank_algorithms(tracers, models, n, b, stat=stat)[0].name


def optimize_block_size(tracer: Tracer, models: ModelSet, n: int,
                        candidates: Sequence[int], *,
                        stat: str = "med") -> Tuple[int, Dict[int, float]]:
    """b_pred = argmin_b t_pred(n, b) over the candidate grid (§4.6)."""
    profile = {
        b: getattr(predict_runtime(tracer(n, b), models), stat)
        for b in candidates
    }
    b_pred = min(profile, key=profile.get)
    return b_pred, profile


def optimize_algorithm_and_block_size(
        tracers: Mapping[str, Tracer], models: ModelSet, n: int,
        candidates: Sequence[int], *, stat: str = "med",
) -> Tuple[str, int, float]:
    """Joint variant + block-size selection: the paper's two goals combined."""
    best: Optional[Tuple[str, int, float]] = None
    for name, tracer in tracers.items():
        b, profile = optimize_block_size(tracer, models, n, candidates,
                                         stat=stat)
        t = profile[b]
        if best is None or t < best[2]:
            best = (name, b, t)
    assert best is not None
    return best


def performance_yield(measured_runtime: Mapping[int, float], b_pred: int,
                      ) -> Tuple[int, float]:
    """§4.6: yield = t_meas(b_opt) / t_meas(b_pred) ∈ (0, 1].

    ``measured_runtime`` maps block size -> measured (median) runtime.
    Returns (b_opt, yield).
    """
    b_opt = min(measured_runtime, key=measured_runtime.get)
    y = measured_runtime[b_opt] / measured_runtime[b_pred]
    return b_opt, y
