"""Algorithm selection and block-size optimization (paper §4.5 / §4.6).

Given a set of mathematically-equivalent blocked-algorithm variants — each
represented by a *tracer* producing its kernel-call sequence for a problem
size n and block size b — rank them by predicted runtime, entirely without
executing any of them.  Block-size optimization evaluates the prediction over
a candidate grid of b and returns the argmin plus the whole profile (used to
compute the paper's "performance yield" against empirical optima).

Both entry points run on the vectorized :class:`PredictionEngine` by default
(the batch of candidate configurations is predicted with a handful of array
ops); pass ``batched=False`` to fall back to the scalar per-call reference
path, which is kept as the equivalence oracle.  ``backend="jax"`` evaluates
the stacked polynomials in jitted XLA programs, and passing a shared
``engine=`` lets repeated selections reuse its trace cache (traced call
sequences and compiled sweep batches) instead of re-tracing.

:func:`select_contraction_algorithm` extends the same selection interface
to tensor contractions (paper Ch. 6) via :mod:`repro.tc` — micro-benchmark
based candidate models ranked through the identical batched engine.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Mapping, Optional, Sequence, Tuple

from .model import ModelSet
from .predict import (PredictionEngine, Tracer, predict_runtime,
                      resolve_engine)
from .sampler import STATS, Stats


@dataclass(frozen=True)
class RankedAlgorithm:
    """One ranked blocked-algorithm variant: its name, predicted runtime
    statistics, and the block size the prediction was made at."""

    name: str
    runtime: Stats
    block_size: int


def _check_scalar_path(batched, backend, engine):
    if not batched and (backend is not None or engine is not None):
        raise ValueError("backend=/engine= apply to the batched engine; "
                         "the scalar oracle (batched=False) has neither")


def rank_algorithms(tracers: Mapping[str, Tracer], models: ModelSet,
                    n: int, b: int, *,
                    stat: str = "med", batched: bool = True,
                    backend: Optional[str] = None,
                    engine: Optional[PredictionEngine] = None,
                    ) -> List[RankedAlgorithm]:
    """Predict every variant's runtime and sort ascending (§4.5)."""
    _check_scalar_path(batched, backend, engine)
    names = list(tracers)
    if batched:
        eng = resolve_engine(models, backend, engine)
        runtimes = eng.predict_stats([eng.cache.calls(tracers[name], n, b)
                                      for name in names])
    else:
        runtimes = [predict_runtime(tracers[name](n, b), models)
                    for name in names]
    ranked = [RankedAlgorithm(name=name, runtime=rt, block_size=b)
              for name, rt in zip(names, runtimes)]
    ranked.sort(key=lambda r: getattr(r.runtime, stat))
    return ranked


def select_algorithm(tracers: Mapping[str, Tracer], models: ModelSet,
                     n: int, b: int, *, stat: str = "med",
                     batched: bool = True, backend: Optional[str] = None,
                     engine: Optional[PredictionEngine] = None) -> str:
    """The name of the variant with the fastest predicted runtime —
    ``rank_algorithms(...)[0].name``, same keywords."""
    return rank_algorithms(tracers, models, n, b, stat=stat, batched=batched,
                           backend=backend, engine=engine)[0].name


def optimize_block_size(tracer: Tracer, models: ModelSet, n: int,
                        candidates: Sequence[int], *,
                        stat: str = "med", batched: bool = True,
                        backend: Optional[str] = None,
                        engine: Optional[PredictionEngine] = None,
                        ) -> Tuple[int, Dict[int, float]]:
    """b_pred = argmin_b t_pred(n, b) over the candidate grid (§4.6)."""
    _check_scalar_path(batched, backend, engine)
    if batched:
        eng = resolve_engine(models, backend, engine)
        col = STATS.index(stat)
        vals = eng.sweep(tracer, n, candidates)[:, col]
        profile = {b: float(v) for b, v in zip(candidates, vals)}
    else:
        profile = {
            b: getattr(predict_runtime(tracer(n, b), models), stat)
            for b in candidates
        }
    b_pred = min(profile, key=profile.get)
    return b_pred, profile


def optimize_algorithm_and_block_size(
        tracers: Mapping[str, Tracer], models: ModelSet, n: int,
        candidates: Sequence[int], *, stat: str = "med",
        batched: bool = True, backend: Optional[str] = None,
        engine: Optional[PredictionEngine] = None,
) -> Tuple[str, int, float]:
    """Joint variant + block-size selection: the paper's two goals combined."""
    _check_scalar_path(batched, backend, engine)
    if batched:
        # one compiled batch over the whole variants x candidates grid;
        # np.argmin's first-minimum tie-breaking matches the scalar loop
        eng = resolve_engine(models, backend, engine)
        names = list(tracers)
        col = STATS.index(stat)
        vals = eng.predict_batch([eng.cache.calls(tracers[name], n, b)
                                  for name in names for b in candidates])
        grid = vals[:, col].reshape(len(names), len(candidates))
        flat = int(grid.argmin())
        vi, bi = divmod(flat, len(candidates))
        return names[vi], candidates[bi], float(grid[vi, bi])
    best: Optional[Tuple[str, int, float]] = None
    for name, tracer in tracers.items():
        b, profile = optimize_block_size(tracer, models, n, candidates,
                                         stat=stat, batched=False)
        t = profile[b]
        if best is None or t < best[2]:
            best = (name, b, t)
    assert best is not None
    return best


# ------------------------------------------------- contractions (Ch. 6) --

def select_contraction_algorithm(spec, sizes: Mapping[str, int], *,
                                 stat: str = "med",
                                 backend: Optional[str] = None,
                                 repetitions: Optional[int] = None,
                                 predictor=None, session=None) -> str:
    """Ch. 6 counterpart of :func:`select_algorithm`: the contraction
    algorithm (traversal x kernel, batched kernels included) with the
    fastest predicted total runtime.

    Runs on :class:`repro.tc.ContractionPredictor` — deduplicated
    cache-aware micro-benchmarks compiled through the same batched
    :class:`PredictionEngine` the blocked-algorithm entry points use.
    Pass ``session=`` (a :class:`repro.tc.PredictorSession`) to share its
    suite measurements and compiled batches across calls; the per-call
    ``backend=``/``repetitions=``/``predictor=`` keywords are DEPRECATED
    in favor of the session (one release of shim support).
    """
    from ..tc.session import warn_deprecated_kwargs  # lazy: tc needs core
    from .contractions import ContractionSpec, _session_for
    if predictor is not None:
        if session is not None:
            raise ValueError("session= already owns the predictor "
                             "resources; pass one or the other")
        if repetitions is not None:
            raise ValueError("repetitions= applies to a newly built "
                             "predictor; the supplied predictor's suite "
                             "already fixes it")
        want = spec if isinstance(spec, ContractionSpec) else \
            ContractionSpec.parse(spec)
        if predictor.spec != want or predictor.sizes != dict(sizes):
            raise ValueError(
                f"the supplied predictor was built for "
                f"{predictor.spec.einsum_expr()} at {predictor.sizes}, not "
                f"{want.einsum_expr()} at {dict(sizes)}; the selection "
                f"would silently answer the wrong contraction")
        warn_deprecated_kwargs(
            "select_contraction_algorithm",
            "session.select_contraction_algorithm (the session memoizes "
            "the predictor)",
            {"predictor": predictor, "backend": backend})
        return predictor.rank(stat=stat, backend=backend or "numpy")[0].name
    sess = _session_for("select_contraction_algorithm", session,
                        backend=backend, repetitions=repetitions)
    return sess.select_contraction_algorithm(spec, sizes, stat=stat)


def _resolve_chain_predictor(chain, sizes, repetitions, predictor):
    """Build (or consistency-check a supplied) ChainPredictor."""
    from ..tc.chains import ChainPredictor, ChainSpec  # lazy: tc needs core
    if predictor is None:
        return ChainPredictor(chain, sizes, repetitions=repetitions)
    if repetitions is not None:
        raise ValueError("repetitions= applies to a newly built predictor; "
                         "the supplied predictor's suite already fixes it")
    want = ChainSpec.parse(chain)
    if predictor.chain != want or predictor.sizes != dict(sizes):
        raise ValueError(
            f"the supplied predictor was built for "
            f"{predictor.chain.einsum_expr()} at {predictor.sizes}, not "
            f"{want.einsum_expr()} at {dict(sizes)}; the selection would "
            f"silently answer the wrong einsum")
    return predictor


def rank_einsum_paths(chain, sizes: Optional[Mapping[str, int]] = None, *,
                      stat: str = "med",
                      backend: Optional[str] = None,
                      repetitions: Optional[int] = None,
                      predictor=None,
                      sizes_grid: Optional[Sequence[
                          Mapping[str, int]]] = None,
                      suite=None, cache=None, session=None):
    """Rank every pairwise contraction path of an N-operand einsum.

    The chain counterpart of :func:`rank_algorithms`: all candidate paths
    (``chain`` is a :class:`repro.tc.ChainSpec` or an expression like
    ``"ij,jk,kl->il"``) are predicted through one shared deduplicated
    micro-benchmark suite and the batched engine
    (``backend="numpy"|"jax"``) and returned fastest-first as
    :class:`repro.tc.RankedChain` records — per-step winning algorithms
    included.  Pass ``predictor=`` (a :class:`repro.tc.ChainPredictor`)
    to reuse measurements and compiled batches across calls; the
    step-by-step per-algorithm oracle remains available on the predictor
    as :meth:`~repro.tc.ChainPredictor.rank_paths_oracle`.

    Size-sweep mode: pass ``sizes_grid=`` (a sequence of size mappings)
    instead of ``sizes`` to rank every path at every size point from ONE
    shared suite — returns one fastest-first ranking per size point; only
    the genuinely new micro-benchmark keys are measured.

    Pass ``session=`` (a :class:`repro.tc.PredictorSession`) to share its
    suite, trace cache and backend across calls; the per-call
    ``backend=``/``repetitions=``/``predictor=``/``suite=``/``cache=``/
    ``sizes_grid=`` keywords are DEPRECATED in favor of the session and
    its :meth:`~repro.tc.PredictorSession.rank_einsum_paths` /
    :meth:`~repro.tc.PredictorSession.rank_einsum_sweep` methods (one
    release of shim support).
    """
    from ..tc.session import warn_deprecated_kwargs  # lazy: tc needs core
    from .contractions import _session_for
    if sizes_grid is not None:
        if sizes is not None or predictor is not None:
            raise ValueError("sizes_grid= replaces sizes= and builds its "
                             "own per-point predictors; pass one mode or "
                             "the other")
        sess = _session_for("rank_einsum_paths", session, backend=backend,
                            suite=suite, cache=cache,
                            repetitions=repetitions,
                            extra_deprecated={"sizes_grid": sizes_grid})
        return list(sess.rank_einsum_sweep(chain, sizes_grid,
                                           stat=stat).rankings)
    if suite is not None or cache is not None:
        raise ValueError("suite=/cache= apply to the sizes_grid= sweep "
                         "mode; the single-size path shares state via "
                         "session= (or the deprecated predictor=)")
    if sizes is None:
        raise ValueError("sizes is required (or pass sizes_grid= for the "
                         "size-sweep mode)")
    if predictor is not None:
        if session is not None:
            raise ValueError("session= already owns the predictor "
                             "resources; pass one or the other")
        pred = _resolve_chain_predictor(chain, sizes, repetitions, predictor)
        warn_deprecated_kwargs(
            "rank_einsum_paths",
            "session.rank_einsum_paths (the session memoizes the "
            "predictor)",
            {"predictor": predictor, "backend": backend})
        return pred.rank_paths(stat=stat, backend=backend or "numpy")
    sess = _session_for("rank_einsum_paths", session, backend=backend,
                        repetitions=repetitions)
    return sess.rank_einsum_paths(chain, sizes, stat=stat)


def select_einsum_path(chain, sizes: Mapping[str, int], *,
                       stat: str = "med",
                       backend: Optional[str] = None,
                       repetitions: Optional[int] = None,
                       predictor=None, session=None):
    """The fastest-predicted contraction path of an N-operand einsum.

    ``rank_einsum_paths(...)[0]``: one :class:`repro.tc.RankedChain`
    carrying the chosen path (``.name`` is its nested-parenthesis form,
    e.g. ``((0.1).(2.3))``), the selected algorithm per step and the
    composed total-runtime prediction.  Same keywords (and the same
    deprecations) as :func:`rank_einsum_paths`.
    """
    # shim plumbing: forwards the caller's own (possibly deprecated)
    # kwargs verbatim so the deprecation warning fires exactly once
    # reprolint: allow[deprecated-kwarg]
    return rank_einsum_paths(chain, sizes, stat=stat, backend=backend,
                             repetitions=repetitions,
                             predictor=predictor, session=session)[0]


def performance_yield(measured_runtime: Mapping[int, float], b_pred: int,
                      ) -> Tuple[int, float]:
    """§4.6: yield = t_meas(b_opt) / t_meas(b_pred) ∈ (0, 1].

    ``measured_runtime`` maps block size -> measured (median) runtime.
    Returns (b_opt, yield).
    """
    b_opt = min(measured_runtime, key=measured_runtime.get)
    y = measured_runtime[b_opt] / measured_runtime[b_pred]
    return b_opt, y
