"""Cache-aware kernel timings (paper Ch. 5 / §2.1.4).

The paper measures each kernel under controlled *cache preconditions*:

* **in-cache** ("warm"): repeated invocations on the same operands — the
  steady state inside a blocked algorithm with high temporal locality;
* **out-of-cache** ("cold"): every invocation uses operands at a fresh
  memory location, so each call pays the full main-memory transfer.

Ch. 5's finding — warm/cold deltas are large for bandwidth-bound kernels
and the *mixture* inside an algorithm is too complex to model
platform-independently — is reproduced here: ``cache_overhead`` quantifies
the cold-call penalty per kernel, ``combine_estimates`` implements the
paper's §5.1.3 convex mixing of in/out-of-cache estimates for a blocked
algorithm, with the mixing weight alpha fitted on ONE algorithm execution
(the paper's calibration) — the honest scope of what Ch. 5 achieves.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Dict, List, Sequence, Tuple

import numpy as np

from .sampler import Stats, measure_calls


@dataclass(frozen=True)
class CacheTimings:
    warm: Stats
    cold: Stats

    @property
    def overhead(self) -> float:
        """Cold-call penalty in seconds (paper Tab 2.2's 'overhead')."""
        return self.cold.med - self.warm.med

    @property
    def overhead_rel(self) -> float:
        return self.overhead / self.warm.med if self.warm.med else 0.0


def measure_cache_effects(make_call_at: Callable[[int], Callable[[], None]],
                          repetitions: int = 10,
                          n_buffers: int = 8) -> CacheTimings:
    """Measure one kernel warm vs cold.

    ``make_call_at(i)`` builds a call whose operands live in buffer set
    ``i``; warm timing reuses set 0 (``warm_pairs``), cold timing cycles
    through ``n_buffers`` distinct sets so operands are evicted between
    repetitions (the paper's "different memory location per repetition").
    """
    warm = measure_calls({"w": make_call_at(0)}, repetitions=repetitions,
                         warm_pairs=True)["w"]
    calls = [make_call_at(i) for i in range(n_buffers)]
    counter = [0]

    def cold_call():
        i = counter[0]
        counter[0] += 1
        calls[i % n_buffers]()

    cold = measure_calls({"c": cold_call}, repetitions=repetitions,
                         warm_pairs=False)["c"]
    return CacheTimings(warm=warm, cold=cold)


def combine_estimates(warm_s: float, cold_s: float, alpha: float) -> float:
    """Paper §5.1.3: t ≈ alpha * t_cold + (1 - alpha) * t_warm."""
    return alpha * cold_s + (1.0 - alpha) * warm_s


def calibrate_alpha(pred_warm: float, pred_cold: float,
                    measured: float) -> float:
    """Fit the mixing weight from one measured algorithm execution."""
    denom = pred_cold - pred_warm
    if abs(denom) < 1e-18:
        return 0.0
    return float(np.clip((measured - pred_warm) / denom, 0.0, 1.0))
