"""Host<->device transfer-term models: ``T_h2d`` / ``T_d2h`` (additive).

The SUMMA-on-WSE work decomposes every kernel execution into
``T_total = T_h2d + T_compute + T_d2h`` with *per-direction* constants —
a fixed dispatch overhead plus a bandwidth term, and markedly asymmetric
directions (the reference implementation's D2H bandwidth is ~3x worse
than H2D).  This module gives the prediction stack the same additive
transfer pieces on the JAX substrate: a :class:`TransferModel` per
direction, fitted from a small memcpy micro-benchmark with the paper's
relative least squares (§3.2.4) over the affine basis
``time(bytes) = overhead + bytes / bandwidth``.

Measurement here is *deliberately synchronizing*: a memcpy probe's
``device_put``/``np.asarray`` round-trips ARE the quantity being
measured, so this module is not a reprolint hot path — unlike the
device-resident kernel sweep (:mod:`repro.tc.device`), which must stay
sync-free.  ``measure_fn`` is injectable so tests fit against synthetic
bandwidth/overhead constants deterministically.

Fitted models serialize as ordinary :class:`~repro.core.model.Piece`
objects (per-stat polynomials replicated from the one affine fit), so a
:class:`repro.store.ModelStore` persists them bit-exactly inside a
:class:`~repro.core.model.ModelSet` like any other kernel model.
"""

from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Callable, List, Optional, Sequence, Tuple

import numpy as np

from .fitting import Polynomial, fit_relative
from .grids import Domain
from .model import Piece
from .sampler import STATS, Stats

#: transfer directions; model-set kernel names are ``memcpy_<direction>``
H2D, D2H = "h2d", "d2h"

#: default memcpy probe sizes (bytes): spans the fixed-overhead-dominated
#: and the bandwidth-dominated regimes so the affine fit is conditioned
DEFAULT_SIZES = (1 << 12, 1 << 15, 1 << 18, 1 << 21)

#: a direction's raw probe: (direction, nbytes, repetitions) -> samples (s)
TransferMeasureFn = Callable[[str, int, int], Sequence[float]]


@dataclass(frozen=True)
class TransferModel:
    """One direction's fitted transfer-time model (seconds over bytes).

    ``poly`` is the §3.2.4 relative fit over the affine basis
    ``((0,), (1,))`` — evaluating it at 0 bytes isolates the fixed
    overhead, and the slope between two sizes isolates the bandwidth, so
    both constants are recoverable from the fit (the test contract).
    """

    direction: str               # H2D or D2H
    poly: Polynomial

    def time(self, nbytes: float) -> float:
        """Predicted one-way transfer time for ``nbytes`` (clipped >= 0)."""
        return max(float(self.poly(np.asarray([[nbytes]], float))), 0.0)

    @property
    def overhead_s(self) -> float:
        """The fixed per-transfer overhead: the fit at 0 bytes."""
        return float(self.poly(np.asarray([[0.0]], float)))

    @property
    def bytes_per_s(self) -> float:
        """The fitted bandwidth: bytes over the affine slope."""
        n = float(self.poly.scale[0])          # a well-conditioned probe pt
        slope = (float(self.poly(np.asarray([[n]], float))) -
                 self.overhead_s) / n
        return 1.0 / slope if slope > 0 else float("inf")

    # ---------------------------------------------------------- persistence --
    def to_piece(self, hi_bytes: float = 1 << 40) -> Piece:
        """The model as one piece (the affine fit replicated per stat)."""
        return Piece(domain=Domain((0.0,), (float(hi_bytes),)),
                     polys={s: self.poly for s in STATS})

    @classmethod
    def from_piece(cls, direction: str, piece: Piece) -> "TransferModel":
        return cls(direction=direction, poly=piece.polys["med"])


def fit_transfer(direction: str, sizes_bytes: Sequence[int],
                 seconds: Sequence[float]) -> TransferModel:
    """Fit one direction's affine transfer model (§3.2.4 relative LS)."""
    points = np.asarray(sizes_bytes, dtype=np.float64)[:, None]
    poly = fit_relative(points, np.asarray(seconds, dtype=np.float64),
                        basis=((0,), (1,)))
    return TransferModel(direction=direction, poly=poly)


def _measure_memcpy(direction: str, nbytes: int,
                    repetitions: int) -> List[float]:
    """The real probe: time H2D ``device_put`` / D2H ``np.asarray``.

    Synchronization is the point here — each sample brackets exactly one
    blocking one-way copy (plus, on H2D, the block that makes the copy
    observable), matching how the transfer constants are consumed.
    """
    import jax

    n = max(nbytes // 4, 1)
    host = np.zeros(n, dtype=np.float32)
    dev = jax.block_until_ready(jax.device_put(host))   # warm both paths
    np.asarray(dev)
    samples = []
    for _ in range(repetitions):
        t0 = time.perf_counter()
        if direction == H2D:
            jax.block_until_ready(jax.device_put(host))
        else:
            np.asarray(dev)
        samples.append(time.perf_counter() - t0)
    return samples


def measure_transfers(*, sizes: Sequence[int] = DEFAULT_SIZES,
                      repetitions: int = 5,
                      measure_fn: Optional[TransferMeasureFn] = None,
                      ) -> Tuple[TransferModel, TransferModel, float]:
    """Fit both directions from the memcpy micro-benchmark.

    Returns ``(h2d, d2h, cost_seconds)`` where ``cost_seconds`` is the
    probe's total wall-clock — callers fold it into their suite's cost
    accounting.  Each size contributes its *median* sample to the fit
    (the §2.1.2 stance: a summary statistic, never a single sample).
    """
    fn = measure_fn or _measure_memcpy
    t0 = time.perf_counter()
    models = []
    for direction in (H2D, D2H):
        meds = [Stats.from_samples(fn(direction, n, repetitions)).med
                for n in sizes]
        models.append(fit_transfer(direction, sizes, meds))
    return models[0], models[1], time.perf_counter() - t0
