"""End-to-end performance-model generation (paper §3.2/§3.3).

A :class:`KernelBenchmark` describes everything the generator needs for one
kernel: its discrete cases, the size-argument domain per case, the maximal
monomial exponents implied by the kernel's asymptotic FLOP count, and a
factory that builds a timed callable for a concrete (case, sizes) invocation.
``generate_model`` runs the adaptive refinement per case and assembles the
:class:`~repro.core.model.PerformanceModel`; ``generate_model_set`` builds the
per-setup database.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Mapping, Optional, Sequence, Tuple

from .grids import Domain, Point
from .model import Case, ModelSet, PerformanceModel, Piece
from .refinement import GeneratorConfig, refine, stats_sample_fn


@dataclass
class KernelBenchmark:
    """Specification of one kernel for the model generator."""

    name: str
    #: discrete cases (flag/layout combinations) to model
    cases: Sequence[Case]
    #: size-argument domain per case (falls back to ``domain`` if absent)
    domain: Domain = None
    case_domains: Dict[Case, Domain] = field(default_factory=dict)
    #: maximal monomial exponents per case, e.g. trsm side=L -> [(2, 1)]
    cost_exponents: Callable[[Case], Sequence[Tuple[int, ...]]] = None
    #: (case, sizes) -> zero-arg callable running ONE synchronous invocation
    make_call: Callable[[Case, Point], Callable[[], None]] = None

    def domain_for(self, case: Case) -> Domain:
        return self.case_domains.get(tuple(case), self.domain)


@dataclass
class GenerationReport:
    """What one model generation cost: measured points, pieces per case
    and wall-clock seconds — the §3.3 accuracy-vs-cost bookkeeping."""

    kernel: str
    seconds: float
    measured_points: int
    pieces_per_case: Dict[Case, int]


def generate_model(bench: KernelBenchmark,
                   config: GeneratorConfig = GeneratorConfig(),
                   setup: str = "default",
                   ) -> Tuple[PerformanceModel, GenerationReport]:
    """Generate one kernel's performance model by adaptive refinement (§3.3).

    For every case of ``bench``, measures the kernel over adaptively
    refined sub-domains (:func:`~repro.core.refinement.refine` under
    ``config``) and fits piecewise polynomials.  The model is returned
    *finalized*: every case's padded piece tensors (the dense form the
    fused prediction engine gathers from) are emitted here, as part of
    generation, instead of being re-derived on the first predict.
    Returns the :class:`~repro.core.model.PerformanceModel` plus a
    :class:`GenerationReport` with the measured-point count, pieces per
    case and wall-clock seconds.
    """
    model = PerformanceModel(kernel=bench.name, setup=setup)
    t0 = time.perf_counter()
    total_points = 0
    pieces_per_case: Dict[Case, int] = {}
    for case in bench.cases:
        case = tuple(case)
        sample_fn = stats_sample_fn(
            lambda p, _case=case: bench.make_call(_case, p),
            repetitions=config.repetitions,
        )
        counted: List[int] = [0]

        def counting_sample(points, _fn=sample_fn, _c=counted):
            _c[0] += len(points)
            return _fn(points)

        pieces = refine(bench.domain_for(case), counting_sample,
                        bench.cost_exponents(case), config)
        for piece in pieces:
            model.add_piece(case, piece)
        pieces_per_case[case] = len(pieces)
        total_points += counted[0]
    model.finalize()
    report = GenerationReport(
        kernel=bench.name,
        seconds=time.perf_counter() - t0,
        measured_points=total_points,
        pieces_per_case=pieces_per_case,
    )
    return model, report


def generate_model_set(benches: Sequence[KernelBenchmark],
                       config: GeneratorConfig = GeneratorConfig(),
                       setup: str = "default",
                       verbose: bool = False,
                       ) -> Tuple[ModelSet, List[GenerationReport]]:
    """Run :func:`generate_model` for every benchmark in ``benches``.

    Returns the combined :class:`~repro.core.model.ModelSet` (one model
    per kernel) and the per-kernel generation reports, optionally
    printing a progress line per kernel when ``verbose``.
    """
    ms = ModelSet()
    reports = []
    for bench in benches:
        model, report = generate_model(bench, config, setup)
        ms.add(model)
        reports.append(report)
        if verbose:
            print(f"[modelgen] {bench.name}: {report.measured_points} points, "
                  f"{sum(report.pieces_per_case.values())} pieces, "
                  f"{report.seconds:.1f}s")
    return ms, reports
