"""Model-based Pallas tile selection (the paper's block-size optimization
applied to BlockSpec tiles).

The paper tunes a blocked algorithm's block size b by predicting runtime
over candidate b and taking the argmin (§4.6).  The TPU analogue tunes the
matmul kernel's (bm, bn, bk): candidates are filtered by *legality* (MXU
alignment + VMEM capacity — the cache-line/cache-size constraints of §3.1
transplanted to the TPU memory hierarchy) and ranked by a three-term cost
model; on hardware the same ranking would come from measured piecewise-
polynomial models (``repro.core``), which this module can also consume.

Cost model per grid step (napkin math recorded in EXPERIMENTS.md §Perf):

* compute:   bm*bn*bk MACs at MXU efficiency eff(bm,bn,bk) — tiles below
  128 in the contracted/lane dims waste systolic-array occupancy;
* memory:    HBM->VMEM traffic: A tile + B tile per step; the output tile
  is resident.  Total traffic = m*k*(n/bn) + k*n*(m/bm) + m*n — small
  bm/bn re-stream the other operand;
* overhead:  per-step fixed grid cost.
"""

from __future__ import annotations

import itertools
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

from ..kernels.matmul import tile_legal, vmem_bytes
from .roofline import HBM_BW, PEAK_FLOPS

_GRID_STEP_OVERHEAD_S = 1e-6
_CANDIDATES = (128, 256, 512, 1024)


def _mxu_eff(b: int) -> float:
    """Systolic utilization of a tile dim (multiples of 128 are full)."""
    return min(1.0, b / 128.0)


@dataclass(frozen=True)
class TileChoice:
    bm: int
    bn: int
    bk: int
    predicted_s: float


def predict_tile_time(m: int, n: int, k: int, bm: int, bn: int,
                      bk: int, itemsize: int = 2) -> float:
    eff = _mxu_eff(min(bm, 128)) * _mxu_eff(min(bn, 128)) * \
        _mxu_eff(min(bk, 128))
    compute = 2.0 * m * n * k / (PEAK_FLOPS * eff)
    traffic = itemsize * (m * k * (n / bn) + k * n * (m / bm) + m * n)
    memory = traffic / HBM_BW
    steps = (m // bm) * (n // bn) * (k // bk)
    return max(compute, memory) + steps * _GRID_STEP_OVERHEAD_S


def select_tiles(m: int, n: int, k: int, *,
                 vmem_limit: int = 16 * 2 ** 20,
                 candidates: Sequence[int] = _CANDIDATES,
                 models=None) -> TileChoice:
    """Pick (bm, bn, bk) without executing any candidate (the paper's
    prediction-not-execution principle).

    ``models`` may supply a measured :class:`repro.core.ModelSet` with a
    "pallas_matmul" kernel; absent that, the analytic cost model ranks.
    """
    best: Optional[TileChoice] = None
    for bm, bn, bk in itertools.product(candidates, repeat=3):
        bm_, bn_, bk_ = min(bm, m), min(bn, n), min(bk, k)
        if not tile_legal(m, n, k, bm_, bn_, bk_, vmem_limit):
            continue
        if models is not None and "pallas_matmul" in models:
            est = models.estimate("pallas_matmul", (bm_, bn_, bk_),
                                  (m, n, k))
            t = est["med"] * (m // bm_) * (n // bn_) * (k // bk_)
        else:
            t = predict_tile_time(m, n, k, bm_, bn_, bk_)
        if best is None or t < best.predicted_s:
            best = TileChoice(bm_, bn_, bk_, t)
    if best is None:
        raise ValueError(f"no legal tile for ({m},{n},{k}) "
                         f"within VMEM {vmem_limit}")
    return best


def tile_table(shapes: Sequence[Tuple[int, int, int]],
               **kw) -> Dict[Tuple[int, int, int], TileChoice]:
    return {s: select_tiles(*s, **kw) for s in shapes}
