"""Measured Pallas tile selection (the paper's block-size optimization
applied to BlockSpec tiles).

The paper tunes a blocked algorithm's block size b by predicting runtime
over candidate b and taking the argmin (§4.6).  The TPU analogue tunes
the matmul kernel's (bm, bn, bk): candidates are filtered by *legality*
(MXU alignment + VMEM capacity — the cache-line/cache-size constraints of
§3.1 transplanted to the TPU memory hierarchy) and ranked by **measured
per-grid-step tile models** served through a
:class:`~repro.tc.session.PredictorSession`'s device facet
(:mod:`repro.tc.device`): each surviving candidate's predicted total is
``T_h2d + per_step(bm, bn, bk) * grid_steps + T_d2h``, with the transfer
terms fitted from the memcpy micro-benchmark.  Measurements are
deduplicated and persisted in the platform
:class:`~repro.store.ModelStore` under its ``__device__`` name, so a warm
session selects tiles with zero fresh measurements.

The pre-device *analytic* three-term model survives two ways:

* ``analytic=True`` (or no session at all) ranks with it — CI and
  hardware-free environments keep a deterministic, measurement-free path;
* it is the equivalence/sanity **oracle** for the measured path: tests
  compare both rankings on CPU-interpret mode (reprolint's
  oracle-coverage gate pins ``select_tiles``/``rank_device_tiles`` to
  ``predict_tile_time`` / ``analytic=True``).

Analytic cost model per grid step (napkin math, EXPERIMENTS.md §Perf):

* compute:   bm*bn*bk MACs at MXU efficiency eff(bm,bn,bk) — tiles that
  are not multiples of 128 waste systolic-array occupancy;
* memory:    HBM->VMEM traffic: A tile + B tile per step; the output tile
  is resident.  Total traffic = m*k*(n/bn) + k*n*(m/bm) + m*n — small
  bm/bn re-stream the other operand;
* overhead:  per-step fixed grid cost.
"""

from __future__ import annotations

import itertools
import math
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

from ..kernels.matmul import tile_legal
from .roofline import HBM_BW, PEAK_FLOPS

_GRID_STEP_OVERHEAD_S = 1e-6
_CANDIDATES = (128, 256, 512, 1024)


def _mxu_eff(b: int) -> float:
    """Systolic utilization of one tile dim.

    A dim occupies ``ceil(b / 128)`` full 128-wide passes of the array;
    utilization is the filled fraction of those passes: ``b / (128 *
    ceil(b / 128))``.  Multiples of 128 are full, b < 128 reduces to
    ``b / 128``, and a non-multiple above 128 (e.g. 192 -> 0.75) wastes
    its final pass — the case the old ``min(1, b / 128)`` missed.
    """
    return b / (128.0 * math.ceil(b / 128.0))


@dataclass(frozen=True)
class TileChoice:
    """One selected/ranked tile config.  ``predicted_s`` is the ranking
    total; the transfer/compute split and provenance are populated on the
    measured path (zeros and ``"analytic"`` on the analytic one)."""

    bm: int
    bn: int
    bk: int
    predicted_s: float
    t_h2d: float = 0.0
    t_compute: float = 0.0
    t_d2h: float = 0.0
    source: str = "analytic"     # "analytic" | "measured" | "model"


def predict_tile_time(m: int, n: int, k: int, bm: int, bn: int,
                      bk: int, itemsize: int = 2) -> float:
    """The analytic three-term estimate — the measured path's oracle."""
    eff = _mxu_eff(bm) * _mxu_eff(bn) * _mxu_eff(bk)
    compute = 2.0 * m * n * k / (PEAK_FLOPS * eff)
    traffic = itemsize * (m * k * (n / bn) + k * n * (m / bm) + m * n)
    memory = traffic / HBM_BW
    steps = (m // bm) * (n // bn) * (k // bk)
    return max(compute, memory) + steps * _GRID_STEP_OVERHEAD_S


def _legal_candidates(m: int, n: int, k: int, candidates: Sequence[int],
                      vmem_limit: int) -> List[Tuple[int, int, int]]:
    """Clamped-to-dims, deduplicated, legality-filtered candidate tiles."""
    legal = []
    seen = set()
    for bm, bn, bk in itertools.product(candidates, repeat=3):
        cfg = (min(bm, m), min(bn, n), min(bk, k))
        if cfg in seen:
            continue
        seen.add(cfg)
        if tile_legal(m, n, k, *cfg, vmem_limit):
            legal.append(cfg)
    return legal


def rank_tiles(m: int, n: int, k: int, *,
               session=None, analytic: bool = False,
               vmem_limit: int = 16 * 2 ** 20,
               candidates: Sequence[int] = _CANDIDATES,
               stat: str = "med", transfer: bool = True,
               itemsize: int = 4) -> List[TileChoice]:
    """Every legal tile config ranked fastest-predicted first.

    With a ``session`` (a :class:`~repro.tc.PredictorSession`) and
    ``analytic=False``, rankings come from measured per-grid-step device
    models plus fitted H2D/D2H transfer terms
    (:meth:`~repro.tc.session.PredictorSession.rank_device_tiles`);
    measurements already in the session's suite — including ones
    warm-loaded from a :class:`~repro.store.ModelStore` — are never
    re-taken.  ``analytic=True`` (or ``session=None``) ranks with the
    deterministic three-term model instead — the hardware-free fallback
    and the measured path's sanity oracle.
    """
    legal = _legal_candidates(m, n, k, candidates, vmem_limit)
    if not legal:
        raise ValueError(f"no legal tile for ({m},{n},{k}) "
                         f"within VMEM {vmem_limit}")
    if analytic or session is None:
        ranked = [TileChoice(bm, bn, bk,
                             predict_tile_time(m, n, k, bm, bn, bk))
                  for bm, bn, bk in legal]
        ranked.sort(key=lambda t: (t.predicted_s, (t.bm, t.bn, t.bk)))
        return ranked
    device = session.rank_device_tiles("pallas_matmul", (m, n, k), legal,
                                       stat=stat, transfer=transfer,
                                       itemsize=itemsize)
    return [TileChoice(r.config[0], r.config[1], r.config[2],
                       predicted_s=r.t_total, t_h2d=r.t_h2d,
                       t_compute=r.t_compute, t_d2h=r.t_d2h,
                       source=r.source)
            for r in device]


def select_tiles(m: int, n: int, k: int, *,
                 session=None, analytic: bool = False,
                 vmem_limit: int = 16 * 2 ** 20,
                 candidates: Sequence[int] = _CANDIDATES,
                 stat: str = "med", transfer: bool = True,
                 itemsize: int = 4) -> TileChoice:
    """Pick (bm, bn, bk) without executing any candidate at problem size
    (the paper's prediction-not-execution principle): the argmin of
    :func:`rank_tiles` — measured models through the session's device
    facet by default, the analytic three-term model with
    ``analytic=True`` or no session."""
    return rank_tiles(m, n, k, session=session, analytic=analytic,
                      vmem_limit=vmem_limit, candidates=candidates,
                      stat=stat, transfer=transfer, itemsize=itemsize)[0]


def tile_table(shapes: Sequence[Tuple[int, int, int]],
               **kw) -> Dict[Tuple[int, int, int], TileChoice]:
    """``select_tiles`` over many shapes; one session's measurements are
    shared across the whole table (proxy-problem keys depend only on the
    tile config, not the problem size)."""
    return {s: select_tiles(*s, **kw) for s in shapes}
