"""Distributed-configuration predictor — the paper's algorithm selection
(§4.5) applied at cluster scale.

The paper ranks mathematically-equivalent blocked algorithms by summing
per-kernel model estimates, never executing the candidates.  Here the
"algorithms" are *sharding configurations* of one (arch x shape) cell —
e.g. Megatron-TP vs pure-FSDP vs hybrid axis splits — and the "model" is
the three-term roofline evaluated on each candidate's compiled dry-run:
lowering + compiling takes seconds, executing a candidate on 256 chips to
time it is what this avoids.  The predicted step time is
``max(compute_s, memory_s, collective_s)`` (bound model; terms overlap on
real hardware).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Dict, List, Mapping, Optional, Tuple

from ..core.model import ModelSet
from ..core.predict import (KernelCall, PredictionEngine,
                            resolve_engine)
from ..core.sampler import Stats
from .roofline import RooflineTerms


@dataclass(frozen=True)
class ConfigCandidate:
    """One sharding configuration: a name + a builder returning compiled."""

    name: str
    build: Callable[[], object]      # () -> (compiled, meta) or RooflineTerms
    note: str = ""


@dataclass
class RankedConfig:
    name: str
    terms: RooflineTerms
    note: str = ""

    @property
    def predicted_s(self) -> float:
        return self.terms.bound_s


def rank_configs(candidates: List[ConfigCandidate],
                 extract: Callable[[object], RooflineTerms],
                 ) -> List[RankedConfig]:
    """Compile every candidate and sort by predicted step time."""
    ranked = []
    for cand in candidates:
        built = cand.build()
        terms = built if isinstance(built, RooflineTerms) else extract(built)
        ranked.append(RankedConfig(cand.name, terms, cand.note))
    ranked.sort(key=lambda r: r.predicted_s)
    return ranked


# --------------------------------------------------------- batched ranking --

@dataclass(frozen=True)
class RankedTracedConfig:
    """A candidate ranked by the batched kernel-model prediction engine."""

    name: str
    runtime: Stats
    note: str = ""
    stat: str = "med"    # the statistic the ranking sorted by

    @property
    def predicted_s(self) -> float:
        return getattr(self.runtime, self.stat)


def rank_traced_configs(tracers: Mapping[str, Callable[..., List[KernelCall]]],
                        models: ModelSet,
                        *tracer_args,
                        stat: str = "med",
                        backend: Optional[str] = None,
                        engine: Optional[PredictionEngine] = None,
                        ) -> List[RankedTracedConfig]:
    """Rank trace-producing candidates on the batched prediction engine.

    The roofline path above compiles each candidate to extract bound terms;
    this path never compiles anything: each candidate's kernel-call trace is
    batched through :class:`PredictionEngine`, so sweeping hundreds of
    configurations costs a handful of array ops — the §4.5 selection applied
    at config-sweep scale.  ``backend="jax"`` evaluates the models in jitted
    XLA programs; ``engine=`` exists for symmetry with the core selection
    entry points (jit caches are process-wide, and these tracers take
    arbitrary ``*tracer_args``, so the per-(n, b) trace cache does not
    apply — a shared engine buys consistency checks, not reuse).
    """
    names = list(tracers)
    engine = resolve_engine(models, backend, engine)
    runtimes = engine.predict_stats(
        [tracers[name](*tracer_args) for name in names])
    ranked = [RankedTracedConfig(name=name, runtime=rt, stat=stat)
              for name, rt in zip(names, runtimes)]
    ranked.sort(key=lambda r: r.predicted_s)
    return ranked
