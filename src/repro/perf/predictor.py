"""Distributed-configuration predictor — the paper's algorithm selection
(§4.5) applied at cluster scale.

The paper ranks mathematically-equivalent blocked algorithms by summing
per-kernel model estimates, never executing the candidates.  Here the
"algorithms" are *sharding configurations* of one (arch x shape) cell —
e.g. Megatron-TP vs pure-FSDP vs hybrid axis splits — and the "model" is
the three-term roofline evaluated on each candidate's compiled dry-run:
lowering + compiling takes seconds, executing a candidate on 256 chips to
time it is what this avoids.  The predicted step time is
``max(compute_s, memory_s, collective_s)`` (bound model; terms overlap on
real hardware).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Tuple

from .roofline import RooflineTerms


@dataclass(frozen=True)
class ConfigCandidate:
    """One sharding configuration: a name + a builder returning compiled."""

    name: str
    build: Callable[[], object]      # () -> (compiled, meta) or RooflineTerms
    note: str = ""


@dataclass
class RankedConfig:
    name: str
    terms: RooflineTerms
    note: str = ""

    @property
    def predicted_s(self) -> float:
        return self.terms.bound_s


def rank_configs(candidates: List[ConfigCandidate],
                 extract: Callable[[object], RooflineTerms],
                 ) -> List[RankedConfig]:
    """Compile every candidate and sort by predicted step time."""
    ranked = []
    for cand in candidates:
        built = cand.build()
        terms = built if isinstance(built, RooflineTerms) else extract(built)
        ranked.append(RankedConfig(cand.name, terms, cand.note))
    ranked.sort(key=lambda r: r.predicted_s)
    return ranked
