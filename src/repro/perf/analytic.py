"""Analytic per-cell FLOP and HBM-byte accounting (MaxText-style).

XLA's ``cost_analysis()`` counts ``while``-loop bodies ONCE, so any scanned
model (layers, chunked attention, chunked CE) is undercounted by the trip
counts.  The dry-run therefore reports BOTH the raw HLO numbers and this
analytic matmul-level accounting; the roofline terms use the analytic
values.  Every formula is per GLOBAL step; callers divide by device count.

Conventions:
* attention score/value FLOPs use the *average* causal kv length
  (S+1)/2, window-clipped;
* training = 3x forward (fwd + 2x bwd) + 1x forward for the per-period
  remat recompute;
* HBM bytes: parameter reads (fwd + bwd), optimizer moment traffic,
  activation carries, KV/state cache traffic for decode — a deliberate
  first-order model (documented in EXPERIMENTS.md).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Tuple

from ..configs.base import ArchConfig, ShapeSpec

BF16 = 2
F32 = 4


def _attn_flops_per_token(cfg: ArchConfig, kv_len: float,
                          window: int) -> float:
    d, hd = cfg.d_model, cfg.head_dim_
    hq, hkv = cfg.n_heads, cfg.n_kv_heads
    proj = 2 * d * hq * hd + 2 * 2 * d * hkv * hd + 2 * hq * hd * d
    eff = min(kv_len, window) if window else kv_len
    scores = 2 * 2 * hq * hd * eff          # QK^T + PV
    return proj + scores


def _ssm_flops_per_token(cfg: ArchConfig) -> float:
    d, di = cfg.d_model, cfg.d_inner
    g, n, h, p = (cfg.ssm_groups, cfg.ssm_state, cfg.ssm_heads,
                  cfg.ssm_head_dim)
    q = cfg.ssm_chunk
    proj = 2 * d * (2 * di + 2 * g * n + h) + 2 * di * d
    # SSD per chunk: CB^T (q^2 n h), GX (q^2 p h), state update + inter
    intra = 2 * q * h * (n + p)             # per token: two q x q matmuls
    state = 6 * h * n * p                   # update + inter-chunk read
    return proj + intra + state


def _ffn_flops_per_token(cfg: ArchConfig, ffn: str) -> float:
    d, f = cfg.d_model, cfg.d_ff
    dense = 3 * 2 * d * f
    if ffn == "dense":
        return dense
    moe = (cfg.top_k * dense                         # expert matmuls
           + 2 * d * cfg.n_experts                   # router
           + 2 * 2 * d * cfg.n_experts * 1.25 * cfg.top_k)  # dispatch+combine
    if ffn == "moe":
        return moe
    if ffn == "moe+dense":
        return moe + dense
    return 0.0


def forward_flops(cfg: ArchConfig, batch: int, seq: int, *,
                  kv_len: float = None, decode: bool = False) -> float:
    """FLOPs of one forward pass over batch x seq tokens."""
    tokens = batch * seq
    kv = kv_len if kv_len is not None else (seq + 1) / 2.0
    total = 0.0
    for spec in cfg.layer_specs():
        if spec.mixer == "attn":
            w = spec.window
            if cfg.long_context_kv_cap and kv > cfg.long_context_kv_cap:
                w = min(w or cfg.long_context_kv_cap,
                        cfg.long_context_kv_cap)
            total += tokens * _attn_flops_per_token(cfg, kv, w)
        else:
            if decode:
                # O(1) recurrence step: projections + state update
                d, di = cfg.d_model, cfg.d_inner
                g, n, h, p = (cfg.ssm_groups, cfg.ssm_state, cfg.ssm_heads,
                              cfg.ssm_head_dim)
                total += tokens * (2 * d * (2 * di + 2 * g * n + h)
                                   + 2 * di * d + 6 * h * n * p)
            else:
                total += tokens * _ssm_flops_per_token(cfg)
        total += tokens * _ffn_flops_per_token(cfg, spec.ffn)
    total += tokens * 2 * cfg.d_model * cfg.vocab      # head
    return total


@dataclass(frozen=True)
class CellCost:
    flops: float          # global FLOPs per step
    hbm_bytes: float      # global HBM bytes per step


def cell_cost(cfg: ArchConfig, shape: ShapeSpec,
              remat_policy=None) -> CellCost:
    b, s = shape.global_batch, shape.seq_len
    n_params = cfg.param_count()
    n_active = cfg.param_count(active_only=True)
    if shape.kind == "train":
        fwd = forward_flops(cfg, b, s)
        # full remat recomputes the whole forward (4x fwd total);
        # the "dots" policy saves matmul outputs -> ~3.2x fwd
        factor = 3.2 if remat_policy == "dots" else 4.0
        flops = factor * fwd
        act_carry = cfg.n_layers * b * s * cfg.d_model * BF16
        act_factor = 4 if remat_policy != "dots" else 8  # more saved acts
        hbm = (4 * n_active * BF16            # param reads fwd/bwd/remat/upd
               + 3 * n_params * F32           # adam moments r/w + grads
               + act_factor * act_carry)      # carry save + reload
        return CellCost(flops, hbm)
    if shape.kind == "prefill":
        fwd = forward_flops(cfg, b, s)
        act = cfg.n_layers * b * s * cfg.d_model * BF16
        return CellCost(fwd, n_active * BF16 + 2 * act + _cache_bytes(cfg,
                                                                      b, s))
    # decode: one token against a KV/state cache of length s
    kv = min(s, cfg.long_context_kv_cap) if cfg.long_context_kv_cap else s
    flops = forward_flops(cfg, b, 1, kv_len=kv, decode=True)
    hbm = n_active * BF16 + _cache_bytes(cfg, b, s)
    return CellCost(flops, hbm)


def _cache_bytes(cfg: ArchConfig, batch: int, ctx: int) -> float:
    """Bytes of the full decode cache (read each decode step)."""
    total = 0.0
    hd = cfg.head_dim_
    for spec in cfg.layer_specs():
        if spec.mixer == "attn":
            c = ctx
            if cfg.long_context_kv_cap:
                c = min(c, cfg.long_context_kv_cap)
            if spec.window:
                c = min(c, spec.window)
            total += 2 * batch * cfg.n_kv_heads * c * hd * BF16
        else:
            total += batch * cfg.ssm_heads * cfg.ssm_head_dim * \
                cfg.ssm_state * F32
    return total
