"""repro.perf."""
