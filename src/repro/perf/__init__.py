"""repro.perf — rooflines, analytic models, and configuration predictors."""

from .predictor import (ConfigCandidate, RankedConfig, RankedTracedConfig,
                        rank_configs, rank_traced_configs)

__all__ = ["ConfigCandidate", "RankedConfig", "RankedTracedConfig",
           "rank_configs", "rank_traced_configs"]
