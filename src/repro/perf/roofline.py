"""Roofline-term extraction from compiled dry-run artifacts.

Per (arch x shape x mesh) cell the three terms are derived from the
*per-device* SPMD module (what ``lowered.compile()`` returns):

* compute term    = HLO FLOPs / peak FLOP/s          (per chip)
* memory term     = HLO bytes accessed / HBM BW      (per chip)
* collective term = collective operand bytes / ICI link BW

``cost_analysis`` supplies FLOPs and bytes; collective bytes are NOT in
cost_analysis, so ``collective_bytes`` parses the compiled HLO text and sums
the operand sizes of every all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute (including ``-start`` async forms; ``-done``
halves are skipped to avoid double counting).
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, Optional, Tuple

# ----------------------------------------------------- TPU v5e constants --

PEAK_FLOPS = 197e12          # bf16 FLOP/s per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

# post-optimization HLO prints operands as bare names, so operand bytes are
# derived from the RESULT shape + the replica-group size g:
#   all-reduce:          operand = result
#   all-gather:          operand = result / g   (result is the gathered full)
#   reduce-scatter:      operand = result * g   (result is the reduced shard)
#   all-to-all / c-perm: operand = result
_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
_OP_RE = re.compile(
    r"=\s*(\([^)]*\)|[a-z0-9]+\[[0-9,]*\]\S*)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\(")
_GROUPS_BRACE_RE = re.compile(r"replica_groups=\{\{([0-9, ]*)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[([0-9,]+)\]<=\[")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def _result_bytes(result: str) -> int:
    total = 0
    for sm in _SHAPE_RE.finditer(result):
        if sm.group(1) in _DTYPE_BYTES:
            total += _shape_bytes(sm.group(1), sm.group(2))
    return total


def _group_size(line: str) -> int:
    m = _GROUPS_BRACE_RE.search(line)
    if m:
        ids = [x for x in m.group(1).replace(" ", "").split(",") if x]
        return max(1, len(ids))
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        dims = [int(x) for x in m.group(1).split(",")]
        return max(1, dims[-1])
    return 1


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum operand bytes per collective kind over the per-device module.

    Async ``-start`` forms are counted; their ``-done`` halves are not
    (no double counting).
    """
    out: Dict[str, int] = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if not m:
            continue
        result, kind = m.group(1), m.group(2)
        rb = _result_bytes(result)
        g = _group_size(line)
        if kind == "all-gather":
            rb = rb // max(g, 1)
        elif kind == "reduce-scatter":
            rb = rb * g
        out[kind] += rb
    return out


@dataclass
class RooflineTerms:
    flops: float                     # per-device FLOPs (analytic if avail.)
    bytes_accessed: float            # per-device HBM bytes
    coll_bytes: Dict[str, int]      # per-device collective operand bytes
    n_devices: int
    model_flops: float = 0.0         # 6*N*D (global, useful FLOPs)
    hlo_flops: float = 0.0           # raw cost_analysis value (body-once)
    hlo_bytes: float = 0.0

    @property
    def coll_total(self) -> int:
        return sum(self.coll_bytes.values())

    @property
    def compute_s(self) -> float:
        return self.flops / PEAK_FLOPS

    @property
    def memory_s(self) -> float:
        return self.bytes_accessed / HBM_BW

    @property
    def collective_s(self) -> float:
        return self.coll_total / ICI_BW

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_ratio(self) -> float:
        """MODEL_FLOPS / (global HLO FLOPs): remat/redundancy waste."""
        total = self.flops * self.n_devices
        return self.model_flops / total if total else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Useful-compute seconds / bound seconds (the score per cell)."""
        if self.bound_s <= 0:
            return 0.0
        useful_s = self.model_flops / self.n_devices / PEAK_FLOPS
        return useful_s / self.bound_s

    def as_dict(self) -> dict:
        return {
            "flops": self.flops, "bytes": self.bytes_accessed,
            "coll_bytes": dict(self.coll_bytes),
            "coll_total": self.coll_total, "n_devices": self.n_devices,
            "model_flops": self.model_flops,
            "hlo_flops": self.hlo_flops, "hlo_bytes": self.hlo_bytes,
            "compute_s": self.compute_s, "memory_s": self.memory_s,
            "collective_s": self.collective_s, "dominant": self.dominant,
            "useful_ratio": self.useful_ratio,
            "roofline_fraction": self.roofline_fraction,
        }


def extract(compiled, n_devices: int, model_flops: float = 0.0,
            analytic=None) -> RooflineTerms:
    """Build RooflineTerms from a compiled executable.

    ``analytic`` (a ``perf.analytic.CellCost``) supplies GLOBAL flops/bytes;
    when given it overrides cost_analysis (which counts while bodies once —
    see perf/analytic.py).  Collective bytes are always parsed from the HLO
    with trip-count scaling.
    """
    from .hlo_scale import scaled_collective_bytes

    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    hlo_flops = float(ca.get("flops", 0.0))
    hlo_bytes = float(ca.get("bytes accessed", 0.0))
    coll = scaled_collective_bytes(compiled.as_text())
    if analytic is not None:
        flops = analytic.flops / n_devices
        nbytes = analytic.hbm_bytes / n_devices
    else:
        flops, nbytes = hlo_flops, hlo_bytes
    return RooflineTerms(flops=flops, bytes_accessed=nbytes,
                         coll_bytes=coll, n_devices=n_devices,
                         model_flops=model_flops, hlo_flops=hlo_flops,
                         hlo_bytes=hlo_bytes)


def model_flops_for(cfg, shape) -> float:
    """MODEL_FLOPS = 6 N D (dense) / 6 N_active D (MoE) per step.

    D = tokens processed: batch*seq for train/prefill, batch for decode.
    Training includes the backward pass (the factor 6 = 2 fwd + 4 bwd);
    prefill/decode use the forward-only factor 2.
    """
    n_active = cfg.param_count(active_only=True)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    tokens = shape.global_batch          # one token per sequence
    return 2.0 * n_active * tokens
