"""Trip-count-aware collective accounting over compiled HLO text.

Compiled HLO prints each ``while`` body once; collectives inside scanned
layers would be undercounted by the trip count.  This parser splits the
module into computations, finds ``while`` ops with their condition/body
computations, extracts the loop bound from the condition's integer
constants, and recursively scales collective bytes by the trip counts.
"""

from __future__ import annotations

import re
from typing import Dict, List, Tuple

from .roofline import _COLLECTIVES, collective_bytes

_COMP_START = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(")
_WHILE_COND_RE = re.compile(r"condition=%?([\w.\-]+)")
_WHILE_BODY_RE = re.compile(r"body=%?([\w.\-]+)")
_CONST_RE = re.compile(r"=\s*[su]\d+\[\]\s*constant\((\d+)\)")


def split_computations(hlo: str) -> Dict[str, Tuple[str, bool]]:
    """-> {name: (body_text, is_entry)}.

    Computation headers sit at column 0 (ops are indented); a computation
    ends at the column-0 ``}``.  Headers may wrap across lines — everything
    until the closing ``}`` is simply attributed to the computation.
    """
    comps: Dict[str, Tuple[str, bool]] = {}
    lines = hlo.splitlines()
    name, entry, body = None, False, []
    for line in lines:
        if name is None:
            m = _COMP_START.match(line)
            if m:
                name = m.group(2)
                entry = bool(m.group(1))
                body = [line]
        else:
            body.append(line)
            if line.startswith("}"):
                comps[name] = ("\n".join(body), entry)
                name, body = None, []
    if name is not None:
        comps[name] = ("\n".join(body), entry)
    return comps


def _trip_count(cond_text: str) -> int:
    consts = [int(c) for c in _CONST_RE.findall(cond_text)]
    return max(consts) if consts else 1


def scaled_collective_bytes(hlo: str) -> Dict[str, int]:
    """Collective operand bytes with while-loops scaled by trip count."""
    comps = split_computations(hlo)
    memo: Dict[str, Dict[str, int]] = {}

    def total(name: str) -> Dict[str, int]:
        if name in memo:
            return memo[name]
        memo[name] = {k: 0 for k in _COLLECTIVES}   # cycle guard
        text, _ = comps.get(name, ("", False))
        acc = collective_bytes(text)
        for line in text.splitlines():
            if " while(" not in line:
                continue
            cm = _WHILE_COND_RE.search(line)
            bm = _WHILE_BODY_RE.search(line)
            if not (cm and bm):
                continue
            trips = _trip_count(comps.get(cm.group(1), ("", False))[0])
            sub = total(bm.group(1))
            for k in _COLLECTIVES:
                acc[k] += trips * sub[k]
        memo[name] = acc
        return acc

    entries = [n for n, (_, e) in comps.items() if e]
    if not entries:
        return collective_bytes(hlo)
    out = {k: 0 for k in _COLLECTIVES}
    for e in entries:
        sub = total(e)
        for k in _COLLECTIVES:
            out[k] += sub[k]
    return out
