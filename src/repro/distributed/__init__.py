"""repro.distributed."""
