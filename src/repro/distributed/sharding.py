"""Sharding rules: parameter / activation / cache PartitionSpecs.

Rules are *divisibility-checked against the actual mesh*: an axis is only
sharded if the dimension divides evenly (e.g. grok-1's 8 experts cannot be
expert-parallel on a 16-wide model axis, so its MoE weights shard d_ff
instead; phi3-medium's 10 kv heads fall back to replication beyond TP=10 —
see DESIGN.md §4).  After the "model" (TP/EP) assignment, the largest
remaining dimension of every large parameter is sharded over "data"
(FSDP/ZeRO-3) so that 314B/480B-class models fit per-chip HBM; the optimizer
moments inherit these specs element-wise.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..configs.base import ArchConfig

#: parameters smaller than this stay replicated (norms, biases, routers)
_FSDP_MIN_SIZE = 2 ** 20


def _axis_size(mesh: Mesh, axis: Optional[str]) -> int:
    return mesh.shape[axis] if axis else 1


def _fits(dim: int, mesh: Mesh, axis: str) -> bool:
    return dim % mesh.shape[axis] == 0


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "name"):
            parts.append(str(p.name))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


#: (substring match, preferred axis index -> mesh axis) rules; the FIRST rule
#: whose substring occurs in the path applies.  Dims are relative to the
#: UNSTACKED parameter; stacked block params have a leading period dim.
_MODEL_RULES: Tuple[Tuple[str, Dict[int, str]], ...] = (
    ("attn/wq", {1: "model"}),
    ("attn/wk", {1: "model"}),
    ("attn/wv", {1: "model"}),
    ("attn/wo", {0: "model"}),
    ("mlp/w_gate", {1: "model"}),
    ("mlp/w_up", {1: "model"}),
    ("mlp/w_down", {0: "model"}),
    ("moe/router", {}),
    ("moe/w_gate", {0: "model", 2: "model"}),   # EP if E divides, else d_ff
    ("moe/w_up", {0: "model", 2: "model"}),
    ("moe/w_down", {0: "model", 1: "model"}),
    ("ssm/in_proj", {1: "model"}),
    ("ssm/out_proj", {0: "model"}),
    ("embed", {0: "model"}),
    ("head", {1: "model"}),
    ("frontend_proj", {}),
)


def _spec_for(path: str, shape: Tuple[int, ...], mesh: Mesh,
              stacked: bool, strategy: str = "tp") -> P:
    """Strategies (the §Perf hillclimb candidates):

    * ``tp``   — baseline: Megatron-style tensor parallelism on "model"
      (+ EP for MoE experts) with FSDP over "data";
    * ``dp``   — no tensor parallelism: "model" becomes a second pure-data/
      ZeRO axis; MoE experts KEEP expert parallelism on "model" (dense
      replication of 100B+ expert banks is not storable); every large
      param is FSDP-sharded over both axes.
    * ``serve`` — TP like ``tp`` but weights are replicated over "data"
      unless a leaf exceeds 4 GiB: per-step ZeRO weight all-gathers are a
      poor trade for decode latency (§Perf, jamba decode iteration 2).
    """
    offset = 1 if stacked else 0
    axes: list = [None] * len(shape)
    apply_model_rules = strategy in ("tp", "serve")
    is_moe = "moe/" in path
    if strategy == "dp" and is_moe:
        apply_model_rules = True       # EP stays even under pure DP
    if apply_model_rules:
        for pat, rule in _MODEL_RULES:
            if pat in path:
                for dim, mesh_axis in rule.items():
                    d = dim + offset
                    if d < len(shape) and axes[d] is None \
                            and mesh_axis in mesh.shape \
                            and _fits(shape[d], mesh, mesh_axis):
                        axes[d] = mesh_axis
                        break   # one model-axis assignment per param
                break
    # FSDP: shard the largest remaining dims over "data" (and, under the
    # dp strategy, over "model" as well — ZeRO over both axes)
    fsdp_axes = ["data"] if strategy in ("tp", "serve") \
        else ["data", "model"]
    min_size = _FSDP_MIN_SIZE if strategy != "serve" else 2 * 2 ** 30
    if int(np.prod(shape)) >= min_size:
        for mesh_axis in fsdp_axes:
            if mesh_axis not in mesh.shape or mesh_axis in axes:
                continue   # each mesh axis at most once per spec
            order = sorted(range(len(shape)), key=lambda i: -shape[i])
            for d in order:
                if axes[d] is None and _fits(shape[d], mesh, mesh_axis):
                    axes[d] = mesh_axis
                    break
    return P(*axes)


def param_specs(params: Any, mesh: Mesh, strategy: str = "tp") -> Any:
    """PartitionSpec pytree matching a parameter (or optimizer) pytree."""

    def one(path, leaf):
        p = _path_str(path)
        stacked = "blocks/" in p
        return _spec_for(p, leaf.shape, mesh, stacked, strategy)

    return jax.tree_util.tree_map_with_path(one, params)


def shardings_of(specs: Any, mesh: Mesh) -> Any:
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), specs,
        is_leaf=lambda x: isinstance(x, P))


def batch_axes(mesh: Mesh) -> Tuple[str, ...]:
    """Mesh axes the global batch is sharded over (pod outermost)."""
    return tuple(a for a in ("pod", "data") if a in mesh.shape)


def data_specs(mesh: Mesh, batch: int, strategy: str = "tp") -> Dict:
    """Input shardings for (inputs, labels)."""
    tok = simple_batch_spec(mesh, batch, strategy)
    return {"inputs": tok, "labels": tok}


def simple_batch_spec(mesh: Mesh, batch: int, strategy: str = "tp") -> P:
    """Shard batch over as many mesh axes as divisibility allows.

    ``tp`` uses (pod, data); ``dp`` also folds "model" into the batch axes
    (pure data parallelism over the whole mesh).
    """
    cand = list(batch_axes(mesh))
    if strategy == "dp" and "model" in mesh.shape:
        cand.append("model")
    axes = []
    prod = 1
    for a in cand:
        if batch % (prod * mesh.shape[a]) == 0:
            axes.append(a)
            prod *= mesh.shape[a]
    return P(tuple(axes)) if axes else P()


def cache_specs(cfg: ArchConfig, mesh: Mesh, batch: int) -> Dict[str, Any]:
    """Decode-state shardings: kv heads / ssm heads on "model"; batch on
    data axes; for batch=1 (long_500k) the KV sequence dim is sharded over
    "data" instead (KV sequence parallelism)."""
    bspec = simple_batch_spec(mesh, batch)
    b_axes = bspec[0] if len(bspec) else None
    out: Dict[str, Any] = {}
    for pi, spec in enumerate(cfg.block_pattern):
        if spec.mixer == "attn":
            head_ax = "model" if _fits(cfg.n_kv_heads, mesh, "model") \
                else None
            # when kv heads cannot take the model axis, shard the KV
            # sequence over it instead (sequence-parallel decode); with
            # batch unsharded (long_500k) fall back to "data" for seq
            if head_ax is None and "model" in mesh.shape:
                seq_ax = "model"
            elif b_axes is None and "data" in mesh.shape:
                seq_ax = "data"
            else:
                seq_ax = None
            kv = P(None, b_axes, head_ax, seq_ax, None)
            out[f"p{pi}"] = (kv, kv)
        else:
            head_ax = "model" if _fits(cfg.ssm_heads, mesh, "model") \
                else None
            out[f"p{pi}"] = P(None, b_axes, head_ax, None, None)
    return out
