"""Elastic re-scaling: resume a job on a different mesh.

On node failure the launcher re-forms a (smaller or larger) mesh from the
surviving hosts; parameters and optimizer state restore from the last
checkpoint and are **re-placed** under the new mesh's shardings
(``checkpoint.restore(shardings=...)`` -> ``jax.device_put``).  The data
pipeline needs no rewind logic because batches are a pure function of the
step.  This module holds the pure re-placement logic, testable on CPU by
shrinking a local mesh.
"""

from __future__ import annotations

from typing import Any, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from .sharding import param_specs


def reshard_tree(tree: Any, mesh: Mesh) -> Any:
    """Re-place every leaf under the sharding rules evaluated on ``mesh``."""
    specs = param_specs(tree, mesh)
    return jax.tree_util.tree_map(
        lambda leaf, spec: jax.device_put(leaf, NamedSharding(mesh, spec)),
        tree, specs,
        is_leaf=lambda x: hasattr(x, "shape") and not isinstance(x, P))


def resume_on_mesh(ckpt_dir: str, tree_like: Any, mesh: Mesh,
                   step=None) -> Tuple[Any, int]:
    """Restore the latest checkpoint directly onto ``mesh``."""
    from ..train.checkpoint import restore

    specs = param_specs(tree_like, mesh)
    shardings = jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), specs,
        is_leaf=lambda x: isinstance(x, P))
    return restore(ckpt_dir, tree_like, step=step, shardings=shardings)
