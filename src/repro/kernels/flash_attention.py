"""Fused flash attention Pallas kernel (TPU target, interpret-validated).

One kernel covers every attention flavour used by the assigned
architectures:

* **GQA** — kv heads are *indexed*, not materialized: the k/v BlockSpec
  index map divides the query-head grid coordinate by the group size, so no
  repeated kv tensors ever hit VMEM (TPU-native adaptation; a CUDA port would
  have broadcast in shared memory instead).
* **causal masking** with per-block early exit (blocks strictly above the
  diagonal contribute nothing and are masked wholesale),
* **local (sliding-window) attention** — gemma2's alternating layers,
* **logit softcapping** — gemma2's ``cap * tanh(logits / cap)``.

Online softmax keeps running max/denominator in VMEM scratch across the kv
grid dimension.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:
    from jax.experimental.pallas import tpu as pltpu
    _VMEM = pltpu.VMEM
except Exception:  # pragma: no cover
    pltpu = None
    _VMEM = None

_NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                 nkv: int, bq: int, bkv: int, scale: float, causal: bool,
                 window: int, softcap: float):
    i = pl.program_id(2)   # query block
    j = pl.program_id(3)   # kv block

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0]                      # (bq, d)
    k = k_ref[0, 0]                      # (bkv, d)
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
    if softcap > 0.0:
        s = softcap * jnp.tanh(s / softcap)

    q_pos = i * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 0)
    k_pos = j * bkv + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 1)
    mask = jnp.ones((bq, bkv), dtype=jnp.bool_)
    if causal:
        mask &= q_pos >= k_pos
    if window > 0:
        mask &= (q_pos - k_pos) < window
    s = jnp.where(mask, s, _NEG_INF)

    m_prev = m_ref[...]                  # (bq, 1)
    m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * alpha + p.sum(axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
        p.astype(v_ref.dtype), v_ref[0, 0],
        preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(j == nkv - 1)
    def _flush():
        denom = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / denom).astype(o_ref.dtype)


def attn_vmem_bytes(bq: int, bkv: int, d: int, itemsize: int = 4) -> int:
    """VMEM working set of one grid step: q/k/v/o blocks + f32 scratch."""
    blocks = itemsize * (bq * d + 2 * bkv * d + bq * d)
    scratch = 4 * (bq + bq + bq * d)       # running max/denominator/acc
    return blocks + scratch


def attn_grid_steps(b: int, h: int, sq: int, skv: int,
                    bq: int, bkv: int) -> int:
    """Grid steps of one attention call at blocks (bq, bkv)."""
    return b * h * (sq // bq) * (skv // bkv)


def attn_proxy_problem(bq: int, bkv: int, d: int,
                       steps_per_dim: int = 2) -> tuple:
    """(b, h, sq, skv, d) of the canonical small problem measuring
    blocks (bq, bkv): one batch/head, ``steps_per_dim`` query and kv
    blocks — enough to exercise the online-softmax revisiting pattern
    (see :func:`repro.kernels.matmul.proxy_problem`)."""
    return (1, 1, bq * steps_per_dim, bkv * steps_per_dim, d)


@functools.partial(jax.jit, static_argnames=(
    "bq", "bkv", "causal", "window", "softcap", "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    bq: int = 128, bkv: int = 128, causal: bool = True,
                    window: int = 0, softcap: float = 0.0,
                    interpret: bool = False) -> jax.Array:
    """Attention over (B, Hq, Sq, D) queries and (B, Hkv, Skv, D) kv.

    Hq must be a multiple of Hkv (GQA); ``window > 0`` enables sliding-window
    attention; ``softcap > 0`` applies gemma2-style logit soft-capping.
    """
    b, hq, sq, d = q.shape
    _, hkv, skv, _ = k.shape
    assert hq % hkv == 0, (hq, hkv)
    group = hq // hkv
    bq = min(bq, sq)
    bkv = min(bkv, skv)
    assert sq % bq == 0 and skv % bkv == 0, (sq, bq, skv, bkv)
    grid = (b, hq, sq // bq, skv // bkv)
    scale = 1.0 / (d ** 0.5)
    return pl.pallas_call(
        functools.partial(
            _attn_kernel, nkv=grid[3], bq=bq, bkv=bkv, scale=scale,
            causal=causal, window=window, softcap=softcap),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, d), lambda bb, h, i, j: (bb, h, i, 0)),
            pl.BlockSpec((1, 1, bkv, d),
                         lambda bb, h, i, j, g=group: (bb, h // g, j, 0)),
            pl.BlockSpec((1, 1, bkv, d),
                         lambda bb, h, i, j, g=group: (bb, h // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, d),
                               lambda bb, h, i, j: (bb, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            _VMEM((bq, 1), jnp.float32),   # running max
            _VMEM((bq, 1), jnp.float32),   # running denominator
            _VMEM((bq, d), jnp.float32),   # output accumulator
        ],
        interpret=interpret,
    )(q, k, v)
