"""Pallas TPU kernels for the performance-critical compute layers.

* ``matmul``          — tiled MXU matmul (the dgemm analogue; tunable tiles)
* ``flash_attention`` — fused attention (GQA / causal / window / softcap)
* ``ssd``             — Mamba-2 chunked state-space scan

``ops`` holds the jit'd public wrappers, ``ref`` the pure-jnp oracles.
"""

from . import ops, ref
from .flash_attention import flash_attention
from .matmul import matmul, tile_legal, vmem_bytes
from .ssd import ssd

__all__ = ["ops", "ref", "flash_attention", "matmul", "tile_legal",
           "vmem_bytes", "ssd"]
