"""Pure-jnp oracles for every Pallas kernel (the ``ref.py`` contract)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def matmul_ref(x: jax.Array, y: jax.Array) -> jax.Array:
    return jnp.dot(x, y, preferred_element_type=jnp.float32).astype(x.dtype)


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  causal: bool = True, window: int = 0,
                  softcap: float = 0.0) -> jax.Array:
    """Dense reference attention with GQA / causal / window / softcap."""
    b, hq, sq, d = q.shape
    _, hkv, skv, _ = k.shape
    group = hq // hkv
    kr = jnp.repeat(k, group, axis=1)
    vr = jnp.repeat(v, group, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q, kr).astype(jnp.float32)
    s = s / (d ** 0.5)
    if softcap > 0.0:
        s = softcap * jnp.tanh(s / softcap)
    q_pos = jnp.arange(sq)[:, None]
    k_pos = jnp.arange(skv)[None, :]
    mask = jnp.ones((sq, skv), dtype=bool)
    if causal:
        mask &= q_pos >= k_pos
    if window > 0:
        mask &= (q_pos - k_pos) < window
    s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p.astype(v.dtype), vr)


def ssd_ref(x: jax.Array, dt: jax.Array, a_log: jax.Array, b: jax.Array,
            c: jax.Array, *, d_skip: jax.Array = None) -> jax.Array:
    """Sequential (O(L)) reference for the Mamba-2 SSD recurrence.

    x: (B, L, H, P); dt: (B, L, H); a_log: (H,); b, c: (B, L, G, N).
    State h: (B, H, P, N), groups broadcast over heads (H % G == 0).
    """
    B, L, H, P = x.shape
    G, N = b.shape[2], b.shape[3]
    rep = H // G
    a = -jnp.exp(a_log)                       # (H,) negative decay rates

    def step(h, inp):
        xt, dtt, bt, ct = inp                 # (B,H,P), (B,H), (B,G,N) x2
        decay = jnp.exp(a[None, :] * dtt)     # (B, H)
        bt_h = jnp.repeat(bt, rep, axis=1)    # (B, H, N)
        ct_h = jnp.repeat(ct, rep, axis=1)
        h = h * decay[..., None, None] + (
            (dtt[..., None] * xt)[..., :, None] * bt_h[..., None, :])
        y = jnp.einsum("bhpn,bhn->bhp", h, ct_h)
        return h, y

    h0 = jnp.zeros((B, H, P, N), dtype=jnp.float32)
    xs = (jnp.moveaxis(x, 1, 0), jnp.moveaxis(dt, 1, 0),
          jnp.moveaxis(b, 1, 0), jnp.moveaxis(c, 1, 0))
    _, ys = jax.lax.scan(step, h0, xs)
    y = jnp.moveaxis(ys, 0, 1)                # (B, L, H, P)
    if d_skip is not None:
        y = y + d_skip[None, None, :, None] * x
    return y.astype(x.dtype)
