"""Tiled MXU matmul Pallas kernel — the TPU ``dgemm`` analogue.

The BlockSpec tile sizes (bm, bn, bk) are the TPU counterpart of the paper's
algorithmic block size b: they fix the VMEM working set
(bm*bk + bk*bn + 2*bm*bn floats) and the MXU utilization, and are selected by
the model-based tile tuner (``repro.perf.tile_tuner``) instead of exhaustive
sweeps.  Accumulation is f32 in a VMEM scratch buffer across the k grid
dimension (revisiting-output pattern).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:  # TPU-specific memory spaces; interpret mode tolerates their absence
    from jax.experimental.pallas import tpu as pltpu
    _VMEM = pltpu.VMEM
except Exception:  # pragma: no cover
    pltpu = None
    _VMEM = None


def _matmul_kernel(x_ref, y_ref, o_ref, acc_ref, *, nk: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(x_ref[...], y_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == nk - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def vmem_bytes(bm: int, bn: int, bk: int, itemsize: int = 4) -> int:
    """VMEM working set claimed by one grid step (operands + f32 acc)."""
    return itemsize * (bm * bk + bk * bn + bm * bn) + 4 * bm * bn


def tile_legal(m: int, n: int, k: int, bm: int, bn: int, bk: int,
               vmem_limit: int = 16 * 2 ** 20) -> bool:
    """MXU alignment (multiples of 128 where the dim allows) + VMEM bound.

    This is the TPU analogue of the paper's cache-driven constraints on
    leading dimensions and block sizes (§3.1.3, DESIGN.md §2).
    """
    if m % bm or n % bn or k % bk:
        return False
    for b, d in ((bm, m), (bn, n), (bk, k)):
        if d >= 128 and b % 128:
            return False
    return vmem_bytes(bm, bn, bk) <= vmem_limit


def grid_steps(m: int, n: int, k: int, bm: int, bn: int, bk: int) -> int:
    """Grid steps one (m, n, k) problem runs at tiles (bm, bn, bk)."""
    return (m // bm) * (n // bn) * (k // bk)


def proxy_problem(bm: int, bn: int, bk: int,
                  steps_per_dim: int = 2) -> tuple:
    """The canonical small problem that measures tiles (bm, bn, bk).

    The device measurement protocol (:mod:`repro.tc.device`) times a tile
    config on this problem — ``steps_per_dim`` grid steps in each grid
    dimension, so the revisiting-output accumulation pattern is exercised
    — and models the *per-grid-step* cost; a full problem's compute term
    is then that cost scaled by :func:`grid_steps`, exactly the paper's
    measure-the-kernel / predict-the-blocked-algorithm split (§4.6).
    """
    return (bm * steps_per_dim, bn * steps_per_dim, bk * steps_per_dim)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def matmul(x: jax.Array, y: jax.Array, *, bm: int = 128, bn: int = 128,
           bk: int = 128, interpret: bool = False) -> jax.Array:
    """``x @ y`` via a tiled Pallas kernel with explicit VMEM blocking."""
    m, k = x.shape
    k2, n = y.shape
    assert k == k2, (x.shape, y.shape)
    bm, bn, bk = min(bm, m), min(bn, n), min(bk, k)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, \
        f"tiles ({bm},{bn},{bk}) must divide ({m},{n},{k})"
    grid = (m // bm, n // bn, k // bk)
    return pl.pallas_call(
        functools.partial(_matmul_kernel, nk=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        scratch_shapes=[_VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(x, y)
