"""Mamba-2 SSD (state-space duality) chunked Pallas kernel.

The SSD recurrence  h_t = h_{t-1} * exp(a*dt_t) + dt_t * x_t ⊗ b_t,
y_t = c_t · h_t  is computed chunk-wise (the paper-recommended dual form):
within a chunk of length Q the output is a masked, decay-weighted
"attention" matmul (MXU-friendly); across chunks a (P, N) state is carried
in VMEM scratch along the innermost (sequential) grid dimension — the same
revisiting pattern the flash-attention kernel uses for its softmax state.

Grid: (batch, head, n_chunks); b/c projections are group-indexed in the
BlockSpec (G groups shared across H heads, like GQA).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:
    from jax.experimental.pallas import tpu as pltpu
    _VMEM = pltpu.VMEM
except Exception:  # pragma: no cover
    pltpu = None
    _VMEM = None


def _ssd_kernel(x_ref, dt_ref, alog_ref, b_ref, c_ref, o_ref, h_ref, *,
                nchunks: int, q: int):
    ch = pl.program_id(2)

    @pl.when(ch == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    a = -jnp.exp(alog_ref[0])                 # scalar decay rate (< 0)
    x = x_ref[0, :, 0, :].astype(jnp.float32)    # (Q, P)
    dt = dt_ref[0, :, 0].astype(jnp.float32)     # (Q,)
    b = b_ref[0, :, 0, :].astype(jnp.float32)    # (Q, N)
    c = c_ref[0, :, 0, :].astype(jnp.float32)    # (Q, N)

    s = jnp.cumsum(a * dt)                    # (Q,) inclusive log-decay
    i_idx = jax.lax.broadcasted_iota(jnp.int32, (q, q), 0)
    j_idx = jax.lax.broadcasted_iota(jnp.int32, (q, q), 1)
    seg = s[:, None] - s[None, :]
    decay = jnp.where(i_idx >= j_idx, jnp.exp(seg), 0.0)
    # intra-chunk: masked decay-weighted attention
    g = jnp.dot(c, b.T, preferred_element_type=jnp.float32)
    g = g * decay * dt[None, :]
    y = jnp.dot(g, x, preferred_element_type=jnp.float32)     # (Q, P)
    # inter-chunk: contribution of the carried state
    h = h_ref[...]                             # (P, N)
    y = y + jnp.exp(s)[:, None] * jnp.dot(
        c, h.T, preferred_element_type=jnp.float32)
    # state update for the next chunk
    w = dt * jnp.exp(s[-1] - s)                # (Q,)
    h_ref[...] = jnp.exp(s[-1]) * h + jnp.dot(
        x.T, b * w[:, None], preferred_element_type=jnp.float32)
    o_ref[0, :, 0, :] = y.astype(o_ref.dtype)


def ssd_vmem_bytes(chunk: int, p: int, n: int, itemsize: int = 4) -> int:
    """VMEM working set of one grid step: x/dt/b/c/o blocks + f32 state."""
    blocks = itemsize * (chunk * p + chunk + 2 * chunk * n + chunk * p)
    return blocks + 4 * p * n              # carried (P, N) state scratch


def ssd_grid_steps(b: int, l: int, h: int, chunk: int) -> int:
    """Grid steps of one SSD call at chunk length ``chunk``."""
    return b * h * (l // chunk)


def ssd_proxy_problem(chunk: int, p: int, n: int,
                      steps_per_dim: int = 2) -> tuple:
    """(b, l, h, p, g, n) of the canonical small problem measuring
    ``chunk``: one batch/head/group, ``steps_per_dim`` chunks — enough to
    exercise the carried-state revisiting pattern (see
    :func:`repro.kernels.matmul.proxy_problem`)."""
    return (1, chunk * steps_per_dim, 1, p, 1, n)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd(x: jax.Array, dt: jax.Array, a_log: jax.Array, b: jax.Array,
        c: jax.Array, *, chunk: int = 128,
        interpret: bool = False) -> jax.Array:
    """Chunked SSD scan.  x: (B,L,H,P); dt: (B,L,H); a_log: (H,);
    b/c: (B,L,G,N) with H % G == 0.  Returns (B,L,H,P)."""
    B, L, H, P = x.shape
    G, N = b.shape[2], b.shape[3]
    assert H % G == 0
    rep = H // G
    chunk = min(chunk, L)
    assert L % chunk == 0, (L, chunk)
    grid = (B, H, L // chunk)
    return pl.pallas_call(
        functools.partial(_ssd_kernel, nchunks=grid[2], q=chunk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, 1, P), lambda bb, h, ch: (bb, ch, h, 0)),
            pl.BlockSpec((1, chunk, 1), lambda bb, h, ch: (bb, ch, h)),
            pl.BlockSpec((1,), lambda bb, h, ch: (h,)),
            pl.BlockSpec((1, chunk, 1, N),
                         lambda bb, h, ch, r=rep: (bb, ch, h // r, 0)),
            pl.BlockSpec((1, chunk, 1, N),
                         lambda bb, h, ch, r=rep: (bb, ch, h // r, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, 1, P),
                               lambda bb, h, ch: (bb, ch, h, 0)),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        scratch_shapes=[_VMEM((P, N), jnp.float32)],
        interpret=interpret,
    )(x, dt, a_log, b, c)
