"""Public jit'd wrappers for the Pallas kernels.

On a real TPU backend the kernels compile natively; on any other backend
(this container's CPU) they execute in ``interpret=True`` mode, which runs
the kernel body in Python per grid step and is used to validate correctness
against the ``ref.py`` oracles.  ``use_pallas=False`` (or the absence of a
tile configuration) falls back to the XLA reference implementations — this
is also what the distributed model code uses under ``shard_map``/``pjit``
so that dry-run lowering works for every mesh.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import ref
from .flash_attention import flash_attention
from .matmul import matmul as _pallas_matmul
from .matmul import tile_legal, vmem_bytes
from .ssd import ssd as _pallas_ssd


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _interpret() -> bool:
    return not on_tpu()


def matmul(x, y, *, bm=128, bn=128, bk=128, use_pallas=True):
    if not use_pallas:
        return ref.matmul_ref(x, y)
    return _pallas_matmul(x, y, bm=bm, bn=bn, bk=bk, interpret=_interpret())


def attention(q, k, v, *, causal=True, window=0, softcap=0.0,
              bq=128, bkv=128, use_pallas=True):
    if not use_pallas:
        return ref.attention_ref(q, k, v, causal=causal, window=window,
                                 softcap=softcap)
    return flash_attention(q, k, v, bq=bq, bkv=bkv, causal=causal,
                           window=window, softcap=softcap,
                           interpret=_interpret())


def ssd(x, dt, a_log, b, c, *, chunk=128, use_pallas=True):
    if not use_pallas:
        return ref.ssd_ref(x, dt, a_log, b, c)
    return _pallas_ssd(x, dt, a_log, b, c, chunk=chunk,
                       interpret=_interpret())


__all__ = ["matmul", "attention", "ssd", "tile_legal", "vmem_bytes",
           "on_tpu"]
