"""Serving launcher: ``python -m repro.launch.serve --arch <id>``."""

from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import get_config, reduced
from ..models import init_params
from ..serve.engine import Request, ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--ctx", type=int, default=128)
    args = ap.parse_args()

    cfg = reduced(get_config(args.arch))
    if not cfg.causal:
        raise SystemExit(f"{cfg.name} is encoder-only: no decode serving")
    params = init_params(cfg, jax.random.PRNGKey(0), dtype=jnp.float32)
    engine = ServeEngine(cfg, params, batch_slots=args.slots,
                         ctx_len=args.ctx)
    rng = np.random.default_rng(0)
    reqs = [Request(uid=i,
                    prompt=rng.integers(0, cfg.vocab, args.prompt_len,
                                        dtype=np.int32),
                    max_new_tokens=args.max_new)
            for i in range(args.requests)]
    stats = engine.run(reqs)
    for r in reqs:
        assert r.done and len(r.out_tokens) == args.max_new
    print(f"arch={cfg.name} requests={len(reqs)} "
          f"decode_steps={stats.decode_steps} "
          f"tokens={stats.tokens_out} tok/s={stats.tokens_per_s:.1f}")


if __name__ == "__main__":
    main()
