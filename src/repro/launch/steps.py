"""Step functions + abstract input specs for every (arch x shape) cell.

``input_specs`` returns ShapeDtypeStruct stand-ins (weak-type-correct,
shardable, zero allocation) for the dry-run; the same step functions are
jitted with real arrays by the train/serve drivers.
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import SHAPES, ArchConfig, ShapeSpec
from ..models import decode_step, init_decode_state, init_params, loss_fn
from ..models.prefill import prefill
from ..train.optimizer import AdamW, AdamWState, apply_updates

PARAM_DTYPE = jnp.bfloat16


# ------------------------------------------------------------------ steps --

def make_train_step(cfg: ArchConfig, optimizer: AdamW, act_spec=None,
                    accum_steps: int = 1, remat_policy=None):
    """Build the jittable train step.

    ``accum_steps > 1`` enables gradient accumulation over micro-batches
    (scan), dividing activation memory by the factor at the cost of one
    gradient all-reduce per micro-batch; ``remat_policy`` selects the
    activation-checkpoint policy ("dots" saves matmul outputs) — both are
    §Perf hillclimb knobs.
    """

    def grads_of(params, batch):
        def loss_of(p):
            return loss_fn(cfg, p, batch["inputs"], batch["labels"],
                           act_spec=act_spec, remat_policy=remat_policy)
        return jax.value_and_grad(loss_of)(params)

    def train_step(params, opt_state, batch):
        if accum_steps == 1:
            loss, grads = grads_of(params, batch)
        else:
            def split(x):
                return x.reshape((accum_steps, x.shape[0] // accum_steps)
                                 + x.shape[1:])

            micro = jax.tree_util.tree_map(split, batch)

            def acc_step(carry, mb):
                loss_acc, g_acc = carry
                loss, g = grads_of(params, mb)
                g_acc = jax.tree_util.tree_map(
                    lambda a, b: a + b.astype(a.dtype), g_acc, g)
                return (loss_acc + loss, g_acc), None

            g0 = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (loss, grads), _ = jax.lax.scan(
                acc_step, (jnp.zeros((), jnp.float32), g0), micro)
            loss = loss / accum_steps
            grads = jax.tree_util.tree_map(lambda g: g / accum_steps, grads)
        updates, opt_state = optimizer.update(grads, opt_state, params)
        params = apply_updates(params, updates)
        return params, opt_state, loss

    return train_step


def make_prefill_step(cfg: ArchConfig):
    def prefill_step(params, batch):
        return prefill(cfg, params, batch["inputs"])

    return prefill_step


def make_serve_step(cfg: ArchConfig):
    def serve_step(params, caches, token, index):
        return decode_step(cfg, params, caches, token, index)

    return serve_step


# ------------------------------------------------------------ input specs --

def _token_struct(cfg: ArchConfig, batch: int, seq: int):
    if cfg.frontend == "none":
        return jax.ShapeDtypeStruct((batch, seq), jnp.int32)
    return jax.ShapeDtypeStruct((batch, seq, cfg.frontend_dim), PARAM_DTYPE)


def abstract_params(cfg: ArchConfig, dtype=PARAM_DTYPE):
    return jax.eval_shape(
        lambda k: init_params(cfg, k, dtype=dtype), jax.random.PRNGKey(0))


def abstract_opt_state(optimizer: AdamW, params_abs):
    return jax.eval_shape(optimizer.init, params_abs)


def abstract_caches(cfg: ArchConfig, batch: int, ctx: int,
                    dtype=PARAM_DTYPE):
    return jax.eval_shape(
        lambda: init_decode_state(cfg, batch, ctx, dtype=dtype))


def input_specs(cfg: ArchConfig, shape: ShapeSpec) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for one cell's step inputs."""
    b, s = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        return {
            "batch": {
                "inputs": _token_struct(cfg, b, s),
                "labels": jax.ShapeDtypeStruct((b, s), jnp.int32),
            },
        }
    if shape.kind == "prefill":
        return {"batch": {"inputs": _token_struct(cfg, b, s)}}
    if shape.kind == "decode":
        return {
            "caches": abstract_caches(cfg, b, s),
            "token": _token_struct(cfg, b, 1),
            "index": jax.ShapeDtypeStruct((), jnp.int32),
        }
    raise ValueError(shape.kind)
