"""repro.launch."""
