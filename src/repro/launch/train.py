"""Training launcher: ``python -m repro.launch.train --arch <id> [...]``.

On this container it runs reduced configs on the local device; on a real
cluster the same driver runs the full config with the production mesh
(--mesh production) — the step function, sharding rules, checkpointing and
data pipeline are identical code paths.
"""

from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from ..configs import get_config, reduced
from ..train.data import DataConfig
from ..train.optimizer import AdamW
from ..train.train_loop import TrainConfig, train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--full-config", action="store_true",
                    help="use the full architecture (cluster-scale only)")
    ap.add_argument("--d-model", type=int, default=128)
    ap.add_argument("--layers", type=int, default=2)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if not args.full_config:
        cfg = reduced(cfg, n_layers=args.layers, d_model=args.d_model,
                      d_ff=4 * args.d_model, vocab=512)
    data_cfg = DataConfig(vocab=cfg.vocab, seq_len=args.seq,
                          global_batch=args.batch,
                          frontend_dim=cfg.frontend_dim
                          if cfg.frontend != "none" else 0)
    tc = TrainConfig(steps=args.steps, ckpt_dir=args.ckpt_dir,
                     log_every=10)
    opt = AdamW(lr=args.lr)
    params, opt_state, report = train(cfg, data_cfg, tc, opt=opt)
    print(f"arch={cfg.name} steps={len(report.losses)} "
          f"first_loss={report.losses[0]:.4f} "
          f"final_loss={report.final_loss:.4f} "
          f"resumed_from={report.resumed_from} "
          f"stragglers={len(report.straggler_steps)}")


if __name__ == "__main__":
    main()
