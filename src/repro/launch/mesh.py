"""Production mesh construction (assignment-mandated shapes).

``make_production_mesh`` is a FUNCTION (never a module-level constant) so
importing this module never touches JAX device state.  The single-pod mesh
is 16 x 16 = 256 chips ("data", "model"); the multi-pod mesh stacks a "pod"
axis in front: 2 x 16 x 16 = 512 chips.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(data: int = 1, model: int = 1):
    """Small mesh over the actually-available devices (tests/examples)."""
    n = len(jax.devices())
    data = min(data, n)
    model = min(model, n // data)
    return jax.make_mesh((data, model), ("data", "model"))
