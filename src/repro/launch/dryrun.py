import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512")
"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

The two lines above MUST precede every other import (jax locks the device
count on first init).  For each cell this driver:

1. builds the production mesh (16x16 single-pod / 2x16x16 multi-pod),
2. constructs abstract params / optimizer state / inputs
   (ShapeDtypeStruct — no allocation),
3. ``jax.jit(step, in_shardings, out_shardings).lower(...).compile()``,
4. prints ``memory_analysis()`` / ``cost_analysis()`` and writes the
   roofline terms (incl. parsed collective bytes) to
   ``experiments/dryrun/<arch>__<shape>__<mesh>.json``.

Usage:
    python -m repro.launch.dryrun --arch deepseek-7b --shape train_4k
    python -m repro.launch.dryrun --all [--multi-pod] [--skip-existing]
"""

import argparse     # noqa: E402
import json         # noqa: E402
import time         # noqa: E402
import traceback    # noqa: E402
from pathlib import Path  # noqa: E402

import jax  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from ..configs import SHAPES, all_configs, get_config  # noqa: E402
from ..distributed.sharding import (cache_specs, data_specs, param_specs,
                                    simple_batch_spec)  # noqa: E402
from ..perf.analytic import cell_cost  # noqa: E402
from ..perf.roofline import extract, model_flops_for  # noqa: E402
from ..train.optimizer import AdamW  # noqa: E402
from .mesh import make_production_mesh  # noqa: E402
from .steps import (abstract_caches, abstract_opt_state, abstract_params,
                    input_specs, make_prefill_step, make_serve_step,
                    make_train_step)  # noqa: E402

OUT_DIR = Path(__file__).resolve().parents[3] / "experiments" / "dryrun"


def mesh_size_hint(multi_pod: bool) -> int:
    return 512 if multi_pod else 256


def _sh(mesh, spec_tree):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P))


def lower_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
               strategy: str = None, seq_shard: bool = False,
               remat_policy=None, accum_steps: int = 1,
               verbose: bool = True):
    """Lower + compile one cell; returns (compiled, meta dict).

    ``strategy`` selects the sharding configuration ("tp" baseline /
    "dp" pure-DP+ZeRO, see distributed.sharding); ``seq_shard`` puts the
    sequence dim of the hidden states on the "model" axis (sequence
    parallelism) — §Perf hillclimb candidates.
    """
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    if shape_name not in cfg.shapes:
        raise ValueError(f"{arch} skips {shape_name} (see DESIGN.md §4)")
    if strategy is None:
        # §Perf-selected defaults: ZeRO/FSDP hurts decode latency, TP hurts
        # dense train throughput (full log in EXPERIMENTS.md §Perf).
        # Dense single-pod training goes pure-DP; multi-pod keeps TP so the
        # model axis stays productive when the batch cannot cover 512 ways.
        strategy = "tp" if shape.kind == "train" else "serve"
        if shape.kind == "train" and cfg.n_experts == 0 and not multi_pod \
                and shape.global_batch % mesh_size_hint(multi_pod) == 0:
            strategy = "dp"
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = mesh.size
    specs = input_specs(cfg, shape)
    params_abs = abstract_params(cfg)
    pspecs = param_specs(params_abs, mesh, strategy)

    t0 = time.perf_counter()
    if shape.kind == "train":
        opt = AdamW()
        opt_abs = abstract_opt_state(opt, params_abs)
        ospecs = param_specs(opt_abs, mesh, strategy)
        bspecs = data_specs(mesh, shape.global_batch, strategy)
        bspec = simple_batch_spec(mesh, shape.global_batch, strategy)
        seq_ax = "model" if (seq_shard and "model" in mesh.shape
                             and "model" not in (bspec[0] or ())) else None
        act_spec = NamedSharding(
            mesh, P(bspec[0] if len(bspec) else None, seq_ax, None))
        step = make_train_step(cfg, opt, act_spec=act_spec,
                               remat_policy=remat_policy,
                               accum_steps=accum_steps)
        jitted = jax.jit(
            step,
            in_shardings=(_sh(mesh, pspecs), _sh(mesh, ospecs),
                          _sh(mesh, bspecs)),
            out_shardings=(_sh(mesh, pspecs), _sh(mesh, ospecs),
                           NamedSharding(mesh, P())),
            donate_argnums=(0, 1),
        )
        with mesh:
            lowered = jitted.lower(params_abs, opt_abs, specs["batch"])
    elif shape.kind == "prefill":
        bspecs = data_specs(mesh, shape.global_batch, strategy)
        cspecs = cache_specs(cfg, mesh, shape.global_batch)
        step = make_prefill_step(cfg)
        logit_spec = simple_batch_spec(mesh, shape.global_batch, strategy)
        jitted = jax.jit(
            step,
            in_shardings=(_sh(mesh, pspecs),
                          {"inputs": NamedSharding(mesh, bspecs["inputs"])}),
            out_shardings=(NamedSharding(mesh, logit_spec),
                           _sh(mesh, cspecs)),
        )
        with mesh:
            lowered = jitted.lower(params_abs, specs["batch"])
    else:  # decode
        cspecs = cache_specs(cfg, mesh, shape.global_batch)
        tok_spec = simple_batch_spec(mesh, shape.global_batch, strategy)
        step = make_serve_step(cfg)
        jitted = jax.jit(
            step,
            in_shardings=(_sh(mesh, pspecs), _sh(mesh, cspecs),
                          NamedSharding(mesh, tok_spec),
                          NamedSharding(mesh, P())),
            out_shardings=(NamedSharding(mesh, tok_spec),
                           _sh(mesh, cspecs)),
            donate_argnums=(1,),
        )
        with mesh:
            lowered = jitted.lower(params_abs, specs["caches"],
                                   specs["token"], specs["index"])
    t_lower = time.perf_counter() - t0
    t0 = time.perf_counter()
    compiled = lowered.compile()
    t_compile = time.perf_counter() - t0

    terms = extract(compiled, n_dev,
                    model_flops=model_flops_for(cfg, shape),
                    analytic=cell_cost(cfg, shape,
                                       remat_policy=remat_policy))
    mem = compiled.memory_analysis()
    meta = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "strategy": strategy, "seq_shard": seq_shard,
        "n_devices": n_dev,
        "lower_s": t_lower, "compile_s": t_compile,
        "memory": _mem_dict(mem),
        **terms.as_dict(),
    }
    if verbose:
        print(f"== {arch} x {shape_name} x {meta['mesh']} ==")
        print(f"   lower {t_lower:.1f}s compile {t_compile:.1f}s")
        print(f"   memory_analysis: {meta['memory']}")
        ca = compiled.cost_analysis()
        if isinstance(ca, (list, tuple)):
            ca = ca[0]
        print(f"   cost_analysis: flops={ca.get('flops', 0):.3e} "
              f"bytes={ca.get('bytes accessed', 0):.3e}")
        print(f"   roofline: compute={terms.compute_s * 1e3:.2f}ms "
              f"memory={terms.memory_s * 1e3:.2f}ms "
              f"collective={terms.collective_s * 1e3:.2f}ms "
              f"dominant={terms.dominant} "
              f"fraction={terms.roofline_fraction:.3f}")
    return compiled, meta


def _mem_dict(mem) -> dict:
    out = {}
    for attr in ("temp_size_in_bytes", "argument_size_in_bytes",
                 "output_size_in_bytes", "alias_size_in_bytes",
                 "generated_code_size_in_bytes"):
        if hasattr(mem, attr):
            out[attr] = int(getattr(mem, attr))
    if not out:
        out["repr"] = str(mem)
    return out


def run_cells(cells, multi_pod: bool, skip_existing: bool) -> int:
    OUT_DIR.mkdir(parents=True, exist_ok=True)
    failures = 0
    for arch, shape_name in cells:
        mesh_tag = "2x16x16" if multi_pod else "16x16"
        out = OUT_DIR / f"{arch}__{shape_name}__{mesh_tag}.json"
        if skip_existing and out.exists():
            print(f"-- skip existing {out.name}")
            continue
        try:
            _, meta = lower_cell(arch, shape_name, multi_pod=multi_pod)
            out.write_text(json.dumps(meta, indent=1))
        except Exception as e:  # noqa: BLE001
            failures += 1
            print(f"!! FAILED {arch} x {shape_name} x {mesh_tag}: {e}")
            traceback.print_exc()
    return failures


def all_cells():
    cells = []
    for arch, cfg in sorted(all_configs().items()):
        for shape_name in cfg.shapes:
            cells.append((arch, shape_name))
    return cells


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()
    if args.all:
        cells = all_cells()
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells = [(args.arch, args.shape)]
    failures = 0
    if args.both_meshes:
        failures += run_cells(cells, False, args.skip_existing)
        failures += run_cells(cells, True, args.skip_existing)
    else:
        failures += run_cells(cells, args.multi_pod, args.skip_existing)
    print(f"dry-run complete: {failures} failures")
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
