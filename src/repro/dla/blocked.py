"""Blocked algorithms (paper Ch. 1/4): algorithm variants as engine programs.

Every algorithm is written once against the :class:`~repro.dla.engine.Engine`
interface; running it on a :class:`TraceEngine` yields the kernel-call
sequence consumed by the predictor, running it on an :class:`ExecEngine`
computes the actual decomposition (validated against ``jnp.linalg`` oracles
in the tests).

Implemented catalogs:

* Cholesky ``potrf`` — 3 variants (Fig 1.1: bordered / left- / right-looking)
* triangular inversion ``trtri`` — 8 variants (Fig 4.13: lazy-row,
  swapped-lazy-row, right-looking-gemm, wasteful-square ×2 traversals)
* ``lauum``, ``sygst``, ``getrf`` (non-pivoted panel), ``geqrf`` — LAPACK's
  blocked algorithms (Fig 4.8/4.9)
* triangular Sylvester solvers — m1/m2/n1/n2 panel traversals and their 8
  "complete" combinations (Fig 4.15, §4.5.3)
"""

from __future__ import annotations

from typing import Callable, Dict, List

from ..core.predict import KernelCall
from .engine import Engine, ExecEngine, Matrix, TraceEngine


def _steps(n: int, b: int):
    k = 0
    while k < n:
        kb = min(b, n - k)
        yield k, kb
        k += kb


def _steps_rev(n: int, b: int):
    """Bottom-up traversal aligned to the same block boundaries."""
    return reversed(list(_steps(n, b)))


# ------------------------------------------------------------------ potrf --

def potrf(eng: Engine, A: Matrix, n: int, b: int, variant: int = 3) -> None:
    """Lower Cholesky L L^T := A, in place (Fig 1.1 variants 1-3)."""
    for k, kb in _steps(n, b):
        A00 = A.v(0, k, 0, k)
        A10 = A.v(k, k + kb, 0, k)
        A11 = A.v(k, k + kb, k, k + kb)
        A20 = A.v(k + kb, n, 0, k)
        A21 = A.v(k + kb, n, k, k + kb)
        A22 = A.v(k + kb, n, k + kb, n)
        if variant == 1:      # bordered: touch only current block row
            eng.trsm("R", "L", "T", "N", 1, A00, A10)
            eng.syrk("L", "N", -1, 1, A10, A11)
            eng.potf2("L", A11)
        elif variant == 2:    # left-looking (LAPACK dpotrf)
            eng.syrk("L", "N", -1, 1, A10, A11)
            eng.potf2("L", A11)
            eng.gemm("N", "T", -1, 1, A20, A10, A21)
            eng.trsm("R", "L", "T", "N", 1, A11, A21)
        elif variant == 3:    # right-looking ("greedy", Fig 4.1)
            eng.potf2("L", A11)
            eng.trsm("R", "L", "T", "N", 1, A11, A21)
            eng.syrk("L", "N", -1, 1, A21, A22)
        else:
            raise ValueError(f"potrf variant {variant}")


# ------------------------------------------------------------------ trtri --

def trtri(eng: Engine, A: Matrix, n: int, b: int, variant: int = 3) -> None:
    """Lower-triangular inversion A := A^{-1}, in place (Fig 4.13).

    Variants 1-4 traverse ↘, 5-8 are their ↖ mirrors.  Variants 4/8 are the
    wasteful "square" variants (triangular panels treated as full matrices →
    ~2-3× the minimal FLOPs; cf. the paper's unstable algorithms 4/8).
    """
    if variant in (1, 2, 3, 4):
        for k, kb in _steps(n, b):
            A00 = A.v(0, k, 0, k)
            A10 = A.v(k, k + kb, 0, k)
            A11 = A.v(k, k + kb, k, k + kb)
            A20 = A.v(k + kb, n, 0, k)
            A21 = A.v(k + kb, n, k, k + kb)
            if variant == 1:   # lazy row panel (Table 4.1)
                eng.trmm("R", "L", "N", "N", 1, A00, A10)
                eng.trsm("L", "L", "N", "N", -1, A11, A10)
                eng.trti2("L", "N", A11)
            elif variant == 2:  # lazy row panel, swapped update order
                eng.trsm("L", "L", "N", "N", -1, A11, A10)
                eng.trmm("R", "L", "N", "N", 1, A00, A10)
                eng.trti2("L", "N", A11)
            elif variant == 3:  # right-looking, gemm-rich
                eng.trti2("L", "N", A11)
                eng.trmm("R", "L", "N", "N", -1, A11, A21)
                eng.gemm("N", "N", 1, 1, A21, A10, A20)
                eng.trmm("L", "L", "N", "N", 1, A11, A10)
            else:               # 4: wasteful square version of variant 1
                eng.gemm("N", "N", 1, 0, A10, A00, A10)
                eng.trti2("L", "N", A11)
                eng.gemm("N", "N", -1, 0, A11, A10, A10)
        if variant == 4:
            return
    elif variant in (5, 6, 7, 8):
        for k, kb in _steps_rev(n, b):
            A10 = A.v(k, k + kb, 0, k)
            A11 = A.v(k, k + kb, k, k + kb)
            A20 = A.v(k + kb, n, 0, k)
            A21 = A.v(k + kb, n, k, k + kb)
            A22 = A.v(k + kb, n, k + kb, n)
            if variant == 5:   # lazy column panel (LAPACK dtrtri_LN)
                eng.trmm("L", "L", "N", "N", 1, A22, A21)
                eng.trsm("R", "L", "N", "N", -1, A11, A21)
                eng.trti2("L", "N", A11)
            elif variant == 6:  # swapped update order
                eng.trsm("R", "L", "N", "N", -1, A11, A21)
                eng.trmm("L", "L", "N", "N", 1, A22, A21)
                eng.trti2("L", "N", A11)
            elif variant == 7:  # right-looking mirror, gemm-rich
                eng.trti2("L", "N", A11)
                eng.trmm("L", "L", "N", "N", -1, A11, A10)
                eng.gemm("N", "N", 1, 1, A21, A10, A20)
                eng.trmm("R", "L", "N", "N", 1, A11, A21)
            else:               # 8: wasteful square version of variant 5
                eng.gemm("N", "N", 1, 0, A22, A21, A21)
                eng.trti2("L", "N", A11)
                eng.gemm("N", "N", -1, 0, A21, A11, A21)
    else:
        raise ValueError(f"trtri variant {variant}")


# ------------------------------------------------------------------ lauum --

def lauum(eng: Engine, A: Matrix, n: int, b: int) -> None:
    """A := L^T L for lower-triangular L in A (LAPACK dlauum_L, Fig 4.8a)."""
    for k, kb in _steps(n, b):
        A10 = A.v(k, k + kb, 0, k)
        A11 = A.v(k, k + kb, k, k + kb)
        A20 = A.v(k + kb, n, 0, k)
        A21 = A.v(k + kb, n, k, k + kb)
        eng.trmm("L", "L", "T", "N", 1, A11, A10)
        eng.gemm("T", "N", 1, 1, A21, A20, A10)
        eng.lauu2("L", A11)
        eng.syrk("L", "T", 1, 1, A21, A11)


# ------------------------------------------------------------------ sygst --

def sygst(eng: Engine, A: Matrix, L: Matrix, n: int, b: int) -> None:
    """A := L^{-1} A L^{-T} (LAPACK dsygst itype=1 lower, Fig 4.8b)."""
    for k, kb in _steps(n, b):
        A11 = A.v(k, k + kb, k, k + kb)
        A21 = A.v(k + kb, n, k, k + kb)
        A22 = A.v(k + kb, n, k + kb, n)
        L11 = L.v(k, k + kb, k, k + kb)
        L21 = L.v(k + kb, n, k, k + kb)
        L22 = L.v(k + kb, n, k + kb, n)
        eng.sygs2(1, "L", A11, L11)
        if k + kb < n:
            eng.trsm("R", "L", "T", "N", 1, L11, A21)
            eng.symm("R", "L", -0.5, 1, A11, L21, A21)
            eng.syr2k("L", "N", -1, 1, A21, L21, A22)
            eng.symm("R", "L", -0.5, 1, A11, L21, A21)
            eng.trsm("L", "L", "N", "N", 1, L22, A21)


# ------------------------------------------------------------------ getrf --

def getrf(eng: Engine, A: Matrix, n: int, b: int) -> None:
    """Blocked LU (non-pivoted panel; see DESIGN.md §8.5), Fig 4.8e."""
    for k, kb in _steps(n, b):
        panel = A.v(k, n, k, k + kb)
        A11 = A.v(k, k + kb, k, k + kb)
        A12 = A.v(k, k + kb, k + kb, n)
        A21 = A.v(k + kb, n, k, k + kb)
        A22 = A.v(k + kb, n, k + kb, n)
        eng.getf2(panel)
        eng.trsm("L", "L", "N", "U", 1, A11, A12)
        eng.gemm("N", "N", -1, 1, A21, A12, A22)


# ------------------------------------------------------------------ geqrf --

def geqrf(eng: Engine, A: Matrix, m: int, n: int, b: int) -> None:
    """Blocked Householder QR (LAPACK dgeqrf, Fig 4.9) — trace structure.

    Per step: panel factorization (``geqr2`` + ``larft``, modeled as one
    unblocked kernel), then the compact-WY block-reflector update
    ``C := (I - V T^T V^T) C`` as gemm / trmm / gemm.
    """
    for k, kb in _steps(min(m, n), b):
        panel = A.v(k, m, k, k + kb)
        eng.geqr2(panel)
        if k + kb < n:
            trail = A.v(k, m, k + kb, n)
            w = A.v(0, kb, 0, trail.cols)      # sizes-only proxy for W
            t = A.v(0, kb, 0, kb)              # sizes-only proxy for T
            eng.gemm("T", "N", 1, 0, panel, trail, w)   # W := V^T C
            eng.trmm("L", "U", "T", "N", 1, t, w)       # W := T^T W
            eng.gemm("N", "N", -1, 1, panel, w, trail)  # C := C - V W


def _house_panel(P):
    """Householder panel factorization + larft (numpy; the geqr2 analogue).

    Returns (V unit-lower-trapezoidal, T upper-triangular, R11).
    """
    import numpy as np

    P = np.asarray(P, dtype=np.float64)
    mp, nb = P.shape
    R = P.copy()
    V = np.zeros((mp, nb))
    taus = np.zeros(nb)
    for j in range(min(mp, nb)):
        x = R[j:, j].copy()
        normx = np.linalg.norm(x)
        if normx == 0.0:
            V[j, j] = 1.0
            continue
        alpha = -np.copysign(normx, x[0] if x[0] != 0 else 1.0)
        v = x.copy()
        v[0] -= alpha
        if abs(v[0]) < 1e-300:
            V[j, j] = 1.0
            R[j, j] = alpha
            continue
        v = v / v[0]
        tau = 2.0 / (v @ v)
        R[j:, j:] -= tau * np.outer(v, v @ R[j:, j:])
        V[j:, j] = v
        taus[j] = tau
    # larft: T upper triangular with H_1..H_nb = I - V T V^T
    T = np.zeros((nb, nb))
    for j in range(nb):
        T[j, j] = taus[j]
        if j:
            T[:j, j] = -taus[j] * (T[:j, :j] @ (V[:, :j].T @ V[:, j]))
    return V, T, np.triu(R[:nb, :nb])


def geqrf_exec(eng: ExecEngine, A: Matrix, m: int, n: int, b: int) -> list:
    """Executable blocked QR mirroring :func:`geqrf`'s kernel calls.

    Returns [(row offset, V, T)] for Q reconstruction in tests.
    """
    import numpy as np

    fac = []
    for k, kb in _steps(min(m, n), b):
        P = eng.mats[A.key][k:m, k:k + kb]
        V, T, R11 = _house_panel(P)
        out = np.zeros_like(P)
        out[:kb, :kb] = R11
        eng.mats[A.key][k:m, k:k + kb] = out
        fac.append((k, V, T))
        if k + kb < n:
            Vm = eng.bind(f"_V{k}", V)
            Tm = eng.bind(f"_T{k}", T)
            Wm = eng.bind(f"_W{k}", np.zeros((kb, n - k - kb)))
            trail = A.v(k, m, k + kb, n)
            eng.gemm("T", "N", 1, 0, Vm.full(), trail, Wm.full())
            eng.trmm("L", "U", "T", "N", 1, Tm.full(), Wm.full())
            eng.gemm("N", "N", -1, 1, Vm.full(), Wm.full(), trail)
    return fac


# -------------------------------------------------------------- sylvester --

def sylv_m1(eng: Engine, A: Matrix, B: Matrix, C: Matrix,
            m: int, n: int, b: int, inner: Callable) -> None:
    """Vertical traversal, lazy: update row panel, then solve (Fig 4.15)."""
    for k, kb in _steps_rev(m, b):
        C1 = C.v(k, k + kb, 0, n)
        A12 = A.v(k, k + kb, k + kb, m)
        C2 = C.v(k + kb, m, 0, n)
        eng.gemm("N", "N", -1, 1, A12, C2, C1)
        inner(eng, A, B, C, k, kb)


def sylv_m2(eng: Engine, A: Matrix, B: Matrix, C: Matrix,
            m: int, n: int, b: int, inner: Callable) -> None:
    """Vertical traversal, eager: solve, then update remaining rows."""
    for k, kb in _steps_rev(m, b):
        inner(eng, A, B, C, k, kb)
        C1 = C.v(k, k + kb, 0, n)
        A01 = A.v(0, k, k, k + kb)
        C0 = C.v(0, k, 0, n)
        eng.gemm("N", "N", -1, 1, A01, C1, C0)


def _sylv_row_inner(n: int, b: int, col_alg: str):
    """Solve one b x n row sub-problem with a horizontal traversal."""

    def inner(eng: Engine, A: Matrix, B: Matrix, C: Matrix,
              r0: int, rb: int) -> None:
        if col_alg == "n1":
            for j, jb in _steps(n, b):
                C1 = C.v(r0, r0 + rb, j, j + jb)
                C0 = C.v(r0, r0 + rb, 0, j)
                B01 = B.v(0, j, j, j + jb)
                eng.gemm("N", "N", -1, 1, C0, B01, C1)
                eng.trsyl("N", "N", 1, A.v(r0, r0 + rb, r0, r0 + rb),
                          B.v(j, j + jb, j, j + jb), C1)
        elif col_alg == "n2":
            for j, jb in _steps(n, b):
                C1 = C.v(r0, r0 + rb, j, j + jb)
                eng.trsyl("N", "N", 1, A.v(r0, r0 + rb, r0, r0 + rb),
                          B.v(j, j + jb, j, j + jb), C1)
                C2 = C.v(r0, r0 + rb, j + jb, n)
                B12 = B.v(j, j + jb, j + jb, n)
                eng.gemm("N", "N", -1, 1, C1, B12, C2)
        else:
            raise ValueError(col_alg)

    return inner


def sylv_n1(eng: Engine, A: Matrix, B: Matrix, C: Matrix,
            m: int, n: int, b: int, inner: Callable) -> None:
    """Horizontal traversal, lazy."""
    for j, jb in _steps(n, b):
        C1 = C.v(0, m, j, j + jb)
        C0 = C.v(0, m, 0, j)
        B01 = B.v(0, j, j, j + jb)
        eng.gemm("N", "N", -1, 1, C0, B01, C1)
        inner(eng, A, B, C, j, jb)


def sylv_n2(eng: Engine, A: Matrix, B: Matrix, C: Matrix,
            m: int, n: int, b: int, inner: Callable) -> None:
    """Horizontal traversal, eager."""
    for j, jb in _steps(n, b):
        inner(eng, A, B, C, j, jb)
        C1 = C.v(0, m, j, j + jb)
        C2 = C.v(0, m, j + jb, n)
        B12 = B.v(j, j + jb, j + jb, n)
        eng.gemm("N", "N", -1, 1, C1, B12, C2)


def _sylv_col_inner(m: int, b: int, row_alg: str):
    """Solve one m x b column sub-problem with a vertical traversal."""

    def inner(eng: Engine, A: Matrix, B: Matrix, C: Matrix,
              c0: int, cb: int) -> None:
        if row_alg == "m1":
            for k, kb in _steps_rev(m, b):
                C1 = C.v(k, k + kb, c0, c0 + cb)
                A12 = A.v(k, k + kb, k + kb, m)
                C2 = C.v(k + kb, m, c0, c0 + cb)
                eng.gemm("N", "N", -1, 1, A12, C2, C1)
                eng.trsyl("N", "N", 1, A.v(k, k + kb, k, k + kb),
                          B.v(c0, c0 + cb, c0, c0 + cb), C1)
        elif row_alg == "m2":
            for k, kb in _steps_rev(m, b):
                C1 = C.v(k, k + kb, c0, c0 + cb)
                eng.trsyl("N", "N", 1, A.v(k, k + kb, k, k + kb),
                          B.v(c0, c0 + cb, c0, c0 + cb), C1)
                A01 = A.v(0, k, k, k + kb)
                C0 = C.v(0, k, c0, c0 + cb)
                eng.gemm("N", "N", -1, 1, A01, C1, C0)
        else:
            raise ValueError(row_alg)

    return inner


SYLVESTER_ALGORITHMS = ("m1n1", "m1n2", "m2n1", "m2n2",
                        "n1m1", "n1m2", "n2m1", "n2m2")


def sylvester(eng: Engine, A: Matrix, B: Matrix, C: Matrix,
              m: int, n: int, b: int, algorithm: str = "n2m2") -> None:
    """Solve A X + X B = C (A, B upper triangular), X overwrites C (§4.5.3)."""
    outer, inner = algorithm[:2], algorithm[2:]
    if outer.startswith("m"):
        fn = sylv_m1 if outer == "m1" else sylv_m2
        fn(eng, A, B, C, m, n, b, _sylv_row_inner(n, b, inner))
    else:
        fn = sylv_n1 if outer == "n1" else sylv_n2
        fn(eng, A, B, C, m, n, b, _sylv_col_inner(m, b, inner))


# ------------------------------------------------------------ trace entry --

def trace(algorithm: Callable, *args, **kwargs) -> List[KernelCall]:
    """Run an algorithm on a TraceEngine and return its call sequence."""
    eng = TraceEngine()
    algorithm(eng, *args, **kwargs)
    return eng.calls
