"""Dense linear algebra kernels (the BLAS/unblocked-LAPACK layer) in JAX.

Every kernel is exposed through a uniform :class:`KernelDef` so that the
performance-model generator (``repro.core.modelgen``), the blocked-algorithm
tracers (``repro.dla.trace``) and the execution engine all speak the same
vocabulary: ``(kernel name, case, sizes)``.

Cases encode the paper's *flag arguments* (§3.1.1): transpositions, side,
uplo, unit-diagonal.  Scalar arguments are restricted to the special values
the paper identifies ({-1, 0, 1, other}, §3.1.2) and are part of the case.
Leading dimensions/increments do not exist for dense JAX arrays (§ DESIGN.md
hardware-adaptation notes).

Each kernel carries its minimal FLOP count and the maximal monomial exponents
it implies for the polynomial basis (§3.2.4).
"""

from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Callable, Dict, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

Case = Tuple
Sizes = Tuple[int, ...]

_DTYPE = jnp.float32  # double-precision analogue on TPU-class hardware


# ----------------------------------------------------------------- helpers --

def _rng(seed: int = 0):
    return np.random.default_rng(seed)


def _rand(rng, *shape):
    return jnp.asarray(rng.standard_normal(shape), dtype=_DTYPE)


def _spd(rng, n):
    a = rng.standard_normal((n, n))
    return jnp.asarray(a @ a.T + n * np.eye(n), dtype=_DTYPE)


def _lower_nonsing(rng, n):
    a = np.tril(rng.standard_normal((n, n)))
    np.fill_diagonal(a, np.abs(a.diagonal()) + n)
    return jnp.asarray(a, dtype=_DTYPE)


# ------------------------------------------------------------- level 3 ops --

@functools.lru_cache(maxsize=None)
def _gemm_fn(transA: str, transB: str, alpha: float, beta: float):
    def f(A, B, C):
        a = A.T if transA == "T" else A
        b = B.T if transB == "T" else B
        return beta * C + alpha * (a @ b)
    return jax.jit(f)


@functools.lru_cache(maxsize=None)
def _syrk_fn(uplo: str, trans: str, alpha: float, beta: float):
    # C := beta C + alpha A A^T (trans=N) or beta C + alpha A^T A (trans=T)
    def f(A, C):
        aat = A @ A.T if trans == "N" else A.T @ A
        return beta * C + alpha * aat
    return jax.jit(f)


@functools.lru_cache(maxsize=None)
def _syr2k_fn(uplo: str, trans: str, alpha: float, beta: float):
    def f(A, B, C):
        if trans == "N":
            upd = A @ B.T + B @ A.T
        else:
            upd = A.T @ B + B.T @ A
        return beta * C + alpha * upd
    return jax.jit(f)


@functools.lru_cache(maxsize=None)
def _symm_fn(side: str, uplo: str, alpha: float, beta: float):
    def f(A, B, C):
        sym = jnp.tril(A) + jnp.tril(A, -1).T if uplo == "L" else \
            jnp.triu(A) + jnp.triu(A, 1).T
        prod = sym @ B if side == "L" else B @ sym
        return beta * C + alpha * prod
    return jax.jit(f)


@functools.lru_cache(maxsize=None)
def _trsm_fn(side: str, uplo: str, transA: str, diag: str, alpha: float):
    def f(A, B):
        return alpha * lax.linalg.triangular_solve(
            A, B,
            left_side=(side == "L"),
            lower=(uplo == "L"),
            transpose_a=(transA == "T"),
            unit_diagonal=(diag == "U"),
        )
    return jax.jit(f)


@functools.lru_cache(maxsize=None)
def _trmm_fn(side: str, uplo: str, transA: str, diag: str, alpha: float):
    def f(A, B):
        tri = jnp.tril(A) if uplo == "L" else jnp.triu(A)
        if diag == "U":
            tri = tri - jnp.diag(jnp.diag(tri)) + jnp.eye(tri.shape[0],
                                                          dtype=tri.dtype)
        t = tri.T if transA == "T" else tri
        return alpha * (t @ B) if side == "L" else alpha * (B @ t)
    return jax.jit(f)


# ---------------------------------------------------- unblocked LAPACK ops --

@jax.jit
def _potf2(A):
    """Unblocked lower Cholesky (the dpotf2 analogue)."""
    return lax.linalg.cholesky(A)


@jax.jit
def _trti2(A):
    """Unblocked lower-triangular inversion via solve against identity."""
    eye = jnp.eye(A.shape[0], dtype=A.dtype)
    return lax.linalg.triangular_solve(A, eye, left_side=True, lower=True)


@jax.jit
def _lauu2(A):
    """A := L^T L for lower-triangular L stored in A (dlauu2, lower)."""
    L = jnp.tril(A)
    return L.T @ L


@jax.jit
def _sygs2(A, L):
    """A := L^{-1} A L^{-T} (dsygs2 itype=1, lower)."""
    t = lax.linalg.triangular_solve(L, A, left_side=True, lower=True)
    return lax.linalg.triangular_solve(L, t.T, left_side=True, lower=True).T


@jax.jit
def _getf2_nopiv(A):
    """Unblocked LU without pivoting of an m x nb panel (m >= nb)."""
    m, nb = A.shape

    def body(k, a):
        col = a[:, k] / a[k, k]
        col = jnp.where(jnp.arange(m) > k, col, a[:, k])
        a = a.at[:, k].set(col)
        mask = ((jnp.arange(m)[:, None] > k) & (jnp.arange(nb)[None, :] > k))
        update = jnp.outer(col, a[k, :])
        return jnp.where(mask, a - update, a)

    return lax.fori_loop(0, min(m, nb), body, A)


@jax.jit
def _geqr2(A):
    """Unblocked QR panel: returns stacked (R upper, V lower-unit, tau)."""
    q, r = jnp.linalg.qr(A, mode="reduced")
    return q, r


@jax.jit
def _trsyl(A, B, C):
    """Unblocked triangular Sylvester solve A X + X B = C.

    A (m x m) and B (n x n) upper triangular.  Column-by-column
    back-substitution: (A + b_jj I) x_j = c_j - X[:, :j] B[:j, j].
    """
    m, n = C.shape
    eye = jnp.eye(m, dtype=C.dtype)

    def col(carry, j):
        X = carry
        rhs = C[:, j] - X @ (B[:, j] * (jnp.arange(n) < j))
        xj = jnp.linalg.solve(A + B[j, j] * eye, rhs)
        X = X.at[:, j].set(xj)
        return X, None

    X0 = jnp.zeros_like(C)
    X, _ = lax.scan(col, X0, jnp.arange(n))
    return X


# ------------------------------------------------------------- kernel defs --

@dataclass(frozen=True)
class KernelDef:
    name: str
    cases: Tuple[Case, ...]
    #: minimal FLOP count as a function of (case, sizes)
    flops: Callable[[Case, Sizes], float]
    #: maximal monomial exponents for the model basis
    cost_exponents: Callable[[Case], Sequence[Tuple[int, ...]]]
    #: build operands for a concrete invocation
    make_operands: Callable[[Case, Sizes, object], Tuple]
    #: execute one invocation (returns device array(s))
    run: Callable[[Case, Tuple], object]

    def make_call(self, case: Case, sizes: Sizes,
                  seed: int = 0) -> Callable[[], None]:
        """Zero-arg synchronous callable for the model generator.

        The timed call includes the host->device operand conversion and the
        device->host result fetch, because that is exactly what the blocked
        algorithms' ExecEngine does per kernel invocation — the paper's
        principle of modeling the call as the algorithm makes it (§3.2.3).
        """
        ops_np = tuple(np.asarray(o)
                       for o in self.make_operands(case, sizes, _rng(seed)))

        def call():
            out = self.run(case, tuple(jnp.asarray(o) for o in ops_np))
            jax.tree_util.tree_map(np.asarray, out)

        return call


def _gemm_flops(case, sizes):
    m, n, k = sizes
    return 2.0 * m * n * k


def _trsm_flops(case, sizes):
    side = case[0]
    m, n = sizes
    return float(m * m * n) if side == "L" else float(m * n * n)


KERNELS: Dict[str, KernelDef] = {}


def _register(kd: KernelDef):
    KERNELS[kd.name] = kd
    return kd


GEMM = _register(KernelDef(
    name="gemm",
    cases=(("N", "N", 1, 1), ("N", "T", -1, 1), ("T", "N", -1, 1),
           ("N", "N", -1, 1), ("T", "N", 1, 1), ("N", "T", 1, 1),
           ("N", "N", 1, 0), ("N", "N", -1, 0), ("T", "N", 1, 0)),
    flops=_gemm_flops,
    cost_exponents=lambda case: [(1, 1, 1)],
    make_operands=lambda case, s, rng: (
        _rand(rng, *((s[0], s[2]) if case[0] == "N" else (s[2], s[0]))),
        _rand(rng, *((s[2], s[1]) if case[1] == "N" else (s[1], s[2]))),
        _rand(rng, s[0], s[1]),
    ),
    run=lambda case, ops: _gemm_fn(case[0], case[1], float(case[2]),
                                   float(case[3]))(*ops),
))

SYRK = _register(KernelDef(
    name="syrk",
    cases=(("L", "N", -1, 1), ("L", "T", -1, 1), ("L", "T", 1, 1)),
    flops=lambda case, s: float(s[0] * s[0] * s[1]),  # n^2 k
    cost_exponents=lambda case: [(2, 1)],
    make_operands=lambda case, s, rng: (
        _rand(rng, *((s[0], s[1]) if case[1] == "N" else (s[1], s[0]))),
        _rand(rng, s[0], s[0]),
    ),
    run=lambda case, ops: _syrk_fn(case[0], case[1], float(case[2]),
                                   float(case[3]))(*ops),
))

SYR2K = _register(KernelDef(
    name="syr2k",
    cases=(("L", "N", -1, 1),),
    flops=lambda case, s: float(2 * s[0] * s[0] * s[1]),
    cost_exponents=lambda case: [(2, 1)],
    make_operands=lambda case, s, rng: (
        _rand(rng, s[0], s[1]), _rand(rng, s[0], s[1]),
        _rand(rng, s[0], s[0]),
    ),
    run=lambda case, ops: _syr2k_fn(case[0], case[1], float(case[2]),
                                    float(case[3]))(*ops),
))

SYMM = _register(KernelDef(
    name="symm",
    cases=(("R", "L", -0.5, 1), ("L", "L", 1, 0)),
    flops=lambda case, s: float(2 * s[0] * s[1] *
                                (s[1] if case[0] == "R" else s[0])),
    cost_exponents=lambda case: [(1, 2)] if case[0] == "R" else [(2, 1)],
    make_operands=lambda case, s, rng: (
        _rand(rng, *((s[1], s[1]) if case[0] == "R" else (s[0], s[0]))),
        _rand(rng, s[0], s[1]),
        _rand(rng, s[0], s[1]),
    ),
    run=lambda case, ops: _symm_fn(case[0], case[1], float(case[2]),
                                   float(case[3]))(*ops),
))

TRSM = _register(KernelDef(
    name="trsm",
    cases=(("L", "L", "N", "N", 1), ("L", "L", "N", "N", -1),
           ("R", "L", "T", "N", 1), ("R", "L", "N", "N", -1),
           ("L", "L", "N", "U", 1), ("L", "U", "N", "N", 1)),
    flops=_trsm_flops,
    cost_exponents=lambda case: [(2, 1)] if case[0] == "L" else [(1, 2)],
    make_operands=lambda case, s, rng: (
        _lower_nonsing(rng, s[0] if case[0] == "L" else s[1]).T
        if case[1] == "U" else
        _lower_nonsing(rng, s[0] if case[0] == "L" else s[1]),
        _rand(rng, s[0], s[1]),
    ),
    run=lambda case, ops: _trsm_fn(case[0], case[1], case[2], case[3],
                                   float(case[4]))(*ops),
))

TRMM = _register(KernelDef(
    name="trmm",
    cases=(("R", "L", "N", "N", 1), ("L", "L", "T", "N", 1),
           ("L", "L", "N", "N", 1), ("L", "L", "N", "U", 1),
           ("R", "L", "N", "N", -1), ("L", "L", "N", "N", -1),
           ("L", "U", "T", "N", 1)),
    flops=lambda case, s: float(s[0] ** 2 * s[1]) if case[0] == "L"
    else float(s[0] * s[1] ** 2),
    cost_exponents=lambda case: [(2, 1)] if case[0] == "L" else [(1, 2)],
    make_operands=lambda case, s, rng: (
        _lower_nonsing(rng, s[0] if case[0] == "L" else s[1]),
        _rand(rng, s[0], s[1]),
    ),
    run=lambda case, ops: _trmm_fn(case[0], case[1], case[2], case[3],
                                   float(case[4]))(*ops),
))

POTF2 = _register(KernelDef(
    name="potf2",
    cases=(("L",),),
    flops=lambda case, s: s[0] ** 3 / 3.0,
    cost_exponents=lambda case: [(3,)],
    make_operands=lambda case, s, rng: (_spd(rng, s[0]),),
    run=lambda case, ops: _potf2(*ops),
))

TRTI2 = _register(KernelDef(
    name="trti2",
    cases=(("L", "N"),),
    flops=lambda case, s: s[0] ** 3 / 3.0,
    cost_exponents=lambda case: [(3,)],
    make_operands=lambda case, s, rng: (_lower_nonsing(rng, s[0]),),
    run=lambda case, ops: _trti2(*ops),
))

LAUU2 = _register(KernelDef(
    name="lauu2",
    cases=(("L",),),
    flops=lambda case, s: s[0] ** 3 / 3.0,
    cost_exponents=lambda case: [(3,)],
    make_operands=lambda case, s, rng: (_lower_nonsing(rng, s[0]),),
    run=lambda case, ops: _lauu2(*ops),
))

SYGS2 = _register(KernelDef(
    name="sygs2",
    cases=((1, "L"),),
    flops=lambda case, s: 2.0 * s[0] ** 3,
    cost_exponents=lambda case: [(3,)],
    make_operands=lambda case, s, rng: (_spd(rng, s[0]),
                                        _lower_nonsing(rng, s[0])),
    run=lambda case, ops: _sygs2(*ops),
))

GETF2 = _register(KernelDef(
    name="getf2",
    cases=(("NP",),),  # non-pivoted panel (see DESIGN.md §8.5)
    flops=lambda case, s: float(s[0] * s[1] ** 2 - s[1] ** 3 / 3.0),
    cost_exponents=lambda case: [(1, 2), (0, 3)],
    make_operands=lambda case, s, rng: (
        jnp.asarray(rng.standard_normal((s[0], s[1])) +
                    np.eye(s[0], s[1]) * s[0], dtype=_DTYPE),),
    run=lambda case, ops: _getf2_nopiv(ops[0]),
))

GEQR2 = _register(KernelDef(
    name="geqr2",
    cases=(("N",),),
    flops=lambda case, s: float(2 * s[0] * s[1] ** 2),
    cost_exponents=lambda case: [(1, 2)],
    make_operands=lambda case, s, rng: (_rand(rng, s[0], s[1]),),
    run=lambda case, ops: _geqr2(*ops),
))

TRSYL = _register(KernelDef(
    name="trsyl",
    cases=(("N", "N", 1),),
    flops=lambda case, s: float(s[0] ** 2 * s[1] + s[0] * s[1] ** 2),
    cost_exponents=lambda case: [(2, 1), (1, 2)],
    make_operands=lambda case, s, rng: (
        jnp.asarray(np.triu(rng.standard_normal((s[0], s[0]))) +
                    np.eye(s[0]) * s[0], dtype=_DTYPE),
        jnp.asarray(np.triu(rng.standard_normal((s[1], s[1]))) +
                    np.eye(s[1]) * s[1], dtype=_DTYPE),
        _rand(rng, s[0], s[1]),
    ),
    run=lambda case, ops: _trsyl(*ops),
))


def kernel_flops(name: str, case: Case, sizes: Sizes) -> float:
    return KERNELS[name].flops(tuple(case), tuple(sizes))
