"""repro.dla — dense linear algebra substrate (kernels + blocked algorithms)."""

from . import blocked, kernels
from .engine import (ExecEngine, Matrix, TraceEngine, View, compile_traces,
                     trace_calls)
from .kernels import KERNELS, KernelDef, kernel_flops

__all__ = ["blocked", "kernels", "ExecEngine", "Matrix", "TraceEngine",
           "View", "compile_traces", "trace_calls", "KERNELS", "KernelDef",
           "kernel_flops"]
