"""repro.dla — dense linear algebra substrate (kernels + blocked algorithms)."""

from . import blocked, kernels
from .engine import ExecEngine, Matrix, TraceEngine, View
from .kernels import KERNELS, KernelDef, kernel_flops

__all__ = ["blocked", "kernels", "ExecEngine", "Matrix", "TraceEngine",
           "View", "KERNELS", "KernelDef", "kernel_flops"]
