"""Execution and tracing engines for blocked algorithms.

A blocked algorithm is written ONCE against the :class:`Engine` interface and
then either

* **executed** (:class:`ExecEngine`) — sub-matrix views of numpy-backed
  storage are extracted, pushed through the jitted JAX kernels of
  ``repro.dla.kernels`` and written back (the "LAPACK calling BLAS"
  structure; host round-trips are part of the call, and the model generator
  times kernels the same way so predictions and executions see identical
  per-call overhead), or
* **traced** (:class:`TraceEngine`) — only the ``(kernel, case, sizes)``
  sequence is recorded, *without any execution*.  This is what the paper's
  predictions consume (§4.1): the call sequence is fully determined by the
  problem size and block size.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..core.predict import CompiledCalls, KernelCall, compile_calls
from . import kernels as K


@dataclass(frozen=True)
class View:
    """A rectangular sub-matrix view: (matrix key, row range, col range)."""

    mat: str
    r0: int
    r1: int
    c0: int
    c1: int

    @property
    def shape(self) -> Tuple[int, int]:
        return (self.r1 - self.r0, self.c1 - self.c0)

    @property
    def rows(self) -> int:
        return self.r1 - self.r0

    @property
    def cols(self) -> int:
        return self.c1 - self.c0


class Matrix:
    """Handle for a matrix participating in a blocked algorithm."""

    def __init__(self, key: str, n_rows: int, n_cols: int):
        self.key = key
        self.n_rows = n_rows
        self.n_cols = n_cols

    def v(self, r0: int, r1: int, c0: int, c1: int) -> View:
        assert 0 <= r0 <= r1 <= self.n_rows, (r0, r1, self.n_rows)
        assert 0 <= c0 <= c1 <= self.n_cols, (c0, c1, self.n_cols)
        return View(self.key, r0, r1, c0, c1)

    def full(self) -> View:
        return self.v(0, self.n_rows, 0, self.n_cols)


class Engine:
    """Kernel-call interface shared by execution and tracing."""

    # level 3
    def gemm(self, transA, transB, alpha, beta, A: View, B: View, C: View):
        raise NotImplementedError

    def syrk(self, uplo, trans, alpha, beta, A: View, C: View):
        raise NotImplementedError

    def syr2k(self, uplo, trans, alpha, beta, A: View, B: View, C: View):
        raise NotImplementedError

    def symm(self, side, uplo, alpha, beta, A: View, B: View, C: View):
        raise NotImplementedError

    def trsm(self, side, uplo, transA, diag, alpha, A: View, B: View):
        raise NotImplementedError

    def trmm(self, side, uplo, transA, diag, alpha, A: View, B: View):
        raise NotImplementedError

    # unblocked LAPACK
    def potf2(self, uplo, A: View):
        raise NotImplementedError

    def trti2(self, uplo, diag, A: View):
        raise NotImplementedError

    def lauu2(self, uplo, A: View):
        raise NotImplementedError

    def sygs2(self, itype, uplo, A: View, L: View):
        raise NotImplementedError

    def getf2(self, A: View):
        raise NotImplementedError

    def geqr2(self, A: View):
        raise NotImplementedError

    def trsyl(self, transA, transB, sgn, A: View, B: View, C: View):
        raise NotImplementedError


class TraceEngine(Engine):
    """Records the kernel-call sequence without executing (paper §4.1)."""

    def __init__(self):
        self.calls: List[KernelCall] = []

    def _rec(self, kernel: str, case: Tuple, sizes: Tuple[int, ...]):
        # degenerate (zero-size) calls are kept: the models estimate them as
        # 0 s, mirroring Example 4.1's zero-width panels
        self.calls.append(KernelCall(kernel, case, sizes))

    def gemm(self, tA, tB, a, b, A, B, C):
        m, n = C.shape
        k = A.cols if tA == "N" else A.rows
        self._rec("gemm", (tA, tB, a, b), (m, n, k))

    def syrk(self, uplo, trans, a, b, A, C):
        n = C.rows
        k = A.cols if trans == "N" else A.rows
        self._rec("syrk", (uplo, trans, a, b), (n, k))

    def syr2k(self, uplo, trans, a, b, A, B, C):
        n = C.rows
        k = A.cols if trans == "N" else A.rows
        self._rec("syr2k", (uplo, trans, a, b), (n, k))

    def symm(self, side, uplo, a, b, A, B, C):
        self._rec("symm", (side, uplo, a, b), C.shape)

    def trsm(self, side, uplo, tA, diag, a, A, B):
        self._rec("trsm", (side, uplo, tA, diag, a), B.shape)

    def trmm(self, side, uplo, tA, diag, a, A, B):
        self._rec("trmm", (side, uplo, tA, diag, a), B.shape)

    def potf2(self, uplo, A):
        self._rec("potf2", (uplo,), (A.rows,))

    def trti2(self, uplo, diag, A):
        self._rec("trti2", (uplo, diag), (A.rows,))

    def lauu2(self, uplo, A):
        self._rec("lauu2", (uplo,), (A.rows,))

    def sygs2(self, itype, uplo, A, L):
        self._rec("sygs2", (itype, uplo), (A.rows,))

    def getf2(self, A):
        self._rec("getf2", ("NP",), A.shape)

    def geqr2(self, A):
        self._rec("geqr2", ("N",), A.shape)

    def trsyl(self, tA, tB, sgn, A, B, C):
        self._rec("trsyl", (tA, tB, sgn), C.shape)

    def compile(self) -> CompiledCalls:
        """Compile the recorded sequence into per-(kernel, case) size
        matrices — the form the batched :class:`PredictionEngine` consumes."""
        return compile_calls([self.calls])


def trace_calls(fn: Callable[["Engine"], None]) -> List[KernelCall]:
    """Trace one blocked-algorithm execution into its kernel-call sequence."""
    eng = TraceEngine()
    fn(eng)
    return eng.calls


def compile_traces(fns: Sequence[Callable[["Engine"], None]],
                   ) -> CompiledCalls:
    """Trace a whole batch of algorithm builders and compile them into one
    reusable per-(kernel, case) batch — the artifact
    :meth:`repro.core.predict.PredictionEngine.predict_compiled` consumes."""
    return compile_calls([trace_calls(fn) for fn in fns])


class ExecEngine(Engine):
    """Executes blocked algorithms on numpy-backed storage via JAX kernels."""

    def __init__(self, mats: Optional[Dict[str, np.ndarray]] = None):
        self.mats: Dict[str, np.ndarray] = dict(mats or {})
        # QR panels store reflector blocks out-of-place
        self.q_panels: Dict[Tuple, np.ndarray] = {}

    # -------------------------------------------------------------- store --
    def bind(self, key: str, array: np.ndarray) -> Matrix:
        arr = np.array(array, dtype=np.float32, copy=True)
        self.mats[key] = arr
        return Matrix(key, arr.shape[0], arr.shape[1])

    def get(self, v: View) -> np.ndarray:
        return self.mats[v.mat][v.r0:v.r1, v.c0:v.c1]

    def put(self, v: View, value) -> None:
        self.mats[v.mat][v.r0:v.r1, v.c0:v.c1] = np.asarray(value)

    @staticmethod
    def _skip(*views: View) -> bool:
        return any(0 in v.shape for v in views)

    def _run(self, name: str, case: Tuple, *ops: np.ndarray):
        out = K.KERNELS[name].run(case, tuple(K.jnp.asarray(o) for o in ops))
        return out

    # ------------------------------------------------------------ level 3 --
    def gemm(self, tA, tB, a, b, A, B, C):
        if self._skip(C) or (A.cols if tA == "N" else A.rows) == 0:
            return
        out = self._run("gemm", (tA, tB, a, b),
                        self.get(A), self.get(B), self.get(C))
        self.put(C, out)

    def syrk(self, uplo, trans, a, b, A, C):
        if self._skip(C) or (A.cols if trans == "N" else A.rows) == 0:
            return
        self.put(C, self._run("syrk", (uplo, trans, a, b),
                              self.get(A), self.get(C)))

    def syr2k(self, uplo, trans, a, b, A, B, C):
        if self._skip(C) or (A.cols if trans == "N" else A.rows) == 0:
            return
        self.put(C, self._run("syr2k", (uplo, trans, a, b),
                              self.get(A), self.get(B), self.get(C)))

    def symm(self, side, uplo, a, b, A, B, C):
        if self._skip(C, A):
            return
        self.put(C, self._run("symm", (side, uplo, a, b),
                              self.get(A), self.get(B), self.get(C)))

    def trsm(self, side, uplo, tA, diag, a, A, B):
        if self._skip(B):
            return
        self.put(B, self._run("trsm", (side, uplo, tA, diag, a),
                              self.get(A), self.get(B)))

    def trmm(self, side, uplo, tA, diag, a, A, B):
        if self._skip(B):
            return
        self.put(B, self._run("trmm", (side, uplo, tA, diag, a),
                              self.get(A), self.get(B)))

    # -------------------------------------------------- unblocked kernels --
    def potf2(self, uplo, A):
        if self._skip(A):
            return
        self.put(A, self._run("potf2", (uplo,), self.get(A)))

    def trti2(self, uplo, diag, A):
        if self._skip(A):
            return
        self.put(A, self._run("trti2", (uplo, diag), self.get(A)))

    def lauu2(self, uplo, A):
        if self._skip(A):
            return
        self.put(A, self._run("lauu2", (uplo,), self.get(A)))

    def sygs2(self, itype, uplo, A, L):
        if self._skip(A):
            return
        self.put(A, self._run("sygs2", (itype, uplo),
                              self.get(A), self.get(L)))

    def getf2(self, A):
        if self._skip(A):
            return
        self.put(A, self._run("getf2", ("NP",), self.get(A)))

    def geqr2(self, A):
        if self._skip(A):
            return
        q, r = self._run("geqr2", ("N",), self.get(A))
        self.q_panels[(A.mat, A.r0, A.c0)] = np.asarray(q)
        m, nb = A.shape
        out = np.zeros((m, nb), dtype=np.float32)
        out[:nb, :nb] = np.triu(np.asarray(r))
        self.put(A, out)

    def trsyl(self, tA, tB, sgn, A, B, C):
        if self._skip(C):
            return
        self.put(C, self._run("trsyl", (tA, tB, sgn),
                              self.get(A), self.get(B), self.get(C)))
