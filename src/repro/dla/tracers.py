"""Named tracer catalogs: (n, b) -> kernel-call sequence (paper §4.5/§4.6).

These are the algorithm sets the paper ranks: 3 Cholesky variants, 8
triangular-inversion variants, 8 Sylvester combinations, and the blocked
LAPACK algorithms of §4.4.  Each tracer produces the exact call sequence of
one algorithm execution without running any kernel.
"""

from __future__ import annotations

from typing import Dict, List

from ..core.predict import KernelCall, Tracer
from . import blocked
from .engine import Matrix, TraceEngine, trace_calls

_traced = trace_calls


def potrf_tracer(variant: int) -> Tracer:
    def tracer(n: int, b: int) -> List[KernelCall]:
        return _traced(lambda e: blocked.potrf(e, Matrix("A", n, n), n, b,
                                               variant))
    return tracer


def trtri_tracer(variant: int) -> Tracer:
    def tracer(n: int, b: int) -> List[KernelCall]:
        return _traced(lambda e: blocked.trtri(e, Matrix("A", n, n), n, b,
                                               variant))
    return tracer


def lauum_tracer() -> Tracer:
    def tracer(n: int, b: int) -> List[KernelCall]:
        return _traced(lambda e: blocked.lauum(e, Matrix("A", n, n), n, b))
    return tracer


def sygst_tracer() -> Tracer:
    def tracer(n: int, b: int) -> List[KernelCall]:
        return _traced(lambda e: blocked.sygst(e, Matrix("A", n, n),
                                               Matrix("L", n, n), n, b))
    return tracer


def getrf_tracer() -> Tracer:
    def tracer(n: int, b: int) -> List[KernelCall]:
        return _traced(lambda e: blocked.getrf(e, Matrix("A", n, n), n, b))
    return tracer


def geqrf_tracer() -> Tracer:
    def tracer(n: int, b: int) -> List[KernelCall]:
        return _traced(lambda e: blocked.geqrf(e, Matrix("A", n, n), n, n, b))
    return tracer


def sylvester_tracer(algorithm: str) -> Tracer:
    def tracer(n: int, b: int) -> List[KernelCall]:
        return _traced(lambda e: blocked.sylvester(
            e, Matrix("A", n, n), Matrix("B", n, n), Matrix("C", n, n),
            n, n, b, algorithm))
    return tracer


CHOLESKY_TRACERS: Dict[str, Tracer] = {
    f"potrf{v}": potrf_tracer(v) for v in (1, 2, 3)
}

TRTRI_TRACERS: Dict[str, Tracer] = {
    f"trtri{v}": trtri_tracer(v) for v in range(1, 9)
}

SYLVESTER_TRACERS: Dict[str, Tracer] = {
    alg: sylvester_tracer(alg) for alg in blocked.SYLVESTER_ALGORITHMS
}

LAPACK_TRACERS: Dict[str, Tracer] = {
    "lauum": lauum_tracer(),
    "sygst": sygst_tracer(),
    "trtri": trtri_tracer(5),   # LAPACK dtrtri_LN = algorithm 5
    "potrf": potrf_tracer(2),   # LAPACK dpotrf_L  = algorithm 2
    "getrf": getrf_tracer(),
    "geqrf": geqrf_tracer(),
}

#: the full catalog, one flat name -> tracer map (LAPACK aliases shadow the
#: identically-named variant entries they point at)
ALL_TRACERS: Dict[str, Tracer] = {**CHOLESKY_TRACERS, **TRTRI_TRACERS,
                                  **SYLVESTER_TRACERS, **LAPACK_TRACERS}


def required_kernel_cases(tracers=None, n: int = 264, b: int = 56,
                          dims: Dict[str, int] = None) -> dict:
    """All (kernel, case) pairs any catalog algorithm emits — used to decide
    which sub-models to generate (§3.2.1: 'only a limited set').

    Pass a dict as ``dims`` to also collect each kernel's size-argument
    count (the model-domain rank), e.g. for building synthetic model sets.
    """
    cats = tracers or ALL_TRACERS
    need: Dict[str, set] = {}
    for tracer in cats.values():
        for call in tracer(n, b):
            need.setdefault(call.kernel, set()).add(call.case)
            if dims is not None:
                dims[call.kernel] = len(call.sizes)
    return need
