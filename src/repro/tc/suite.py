"""Deduplicated cache-aware micro-benchmarks for contractions (§6.2).

A contraction's candidate algorithms are highly regular: many distinct
traversals call the *same* kernel on the *same* operand shapes under the
*same* cache preconditions, so their micro-benchmarks are interchangeable.
The suite exploits that: each candidate maps to a
:class:`MicroBenchmarkKey` — (kernel equation, kernel operand shapes,
cache class per operand) — and each distinct key is measured exactly once,
shared across every algorithm that maps to it.  The equation is stored
*canonically relabeled* (:func:`canonical_equation`): an einsum is
invariant under index renaming, so ``ij,jk->ik`` and ``ik,kl->il`` at
equal shapes are the same measurement — which is what lets the steps of a
multi-contraction chain (:mod:`repro.tc.chains`) share one suite.

Cache classes come from the §6.2.3 access distance, with two refinements:

* **batched kernels classify per batch slice** — a batched kernel walks
  its batch dims strided, so the cache working set is one slice's
  operands, not the whole stacked call
  (:func:`~repro.tc.kernels.slice_call_bytes`);
* callers may pass **arrival overrides** (``arrival={"A": COLD}``):
  an operand known to arrive cold — e.g. a chain intermediate bigger than
  the cache — is forced cold regardless of its in-loop reuse distance.
  A warm arrival adds nothing the distance does not already say, so only
  COLD overrides have an effect.

The measurement itself is the shared §6.2 protocol
(:func:`~repro.core.contractions.run_kernel_benchmark` — also backing the
per-algorithm oracle): input operands whose access distance exceeds the
cache capacity cycle through a pool of distinct buffers (sized by
:func:`cold_pool_size` from the repetition count and cache capacity — no
hard cap), warm operands reuse one buffer, and the first-call overhead
(§6.2.6) is timed separately.  The cache classes cover the kernel's
*input* operands: the jitted einsum allocates its output, so no
output-cache precondition can be established, and a C-only distinction
would merely split shareable benchmarks.  The suite accounts its own
wall-clock cost (:attr:`~MicroBenchmarkSuite.cost_seconds`) so a
prediction can be stated as a fraction of a measured contraction runtime
— the paper's headline metric for Ch. 6.
"""

from __future__ import annotations

import math
import time
from dataclasses import dataclass
from typing import Callable, Dict, Mapping, Optional, Tuple

import numpy as np

from ..core.contractions import (CACHE_BYTES, _ITEM, ContractionAlgorithm,
                                 access_distance, canonical_equation,
                                 run_kernel_benchmark)
from ..core.sampler import Stats
from .kernels import is_batched_kernel, slice_call_bytes

#: cache classes an operand can be benchmarked under
WARM, COLD = "warm", "cold"


@dataclass(frozen=True)
class MicroBenchmarkKey:
    """Identity of one distinct micro-benchmark.

    Two candidate algorithms with equal keys perform indistinguishable
    kernel calls under indistinguishable cache states, so one measurement
    serves both — the suite's deduplication signature.
    """

    equation: str                      # CANONICAL kernel einsum, "ab,bc->ac"
    a_shape: Tuple[int, ...]
    b_shape: Tuple[int, ...]
    out_shape: Tuple[int, ...]
    classes: Tuple[str, str]           # cache class of the inputs A, B
    #: kernel-config facet for *device* kernel keys (e.g. a Pallas
    #: matmul's (bm, bn, bk) tile) — ``None`` for einsum keys, so every
    #: pre-existing key, payload and call site is unchanged.  Device keys
    #: set ``equation`` to the kernel's registry name and ``classes`` to
    #: its VMEM class (:mod:`repro.tc.device`); two tile configs of one
    #: kernel are distinct measurements exactly like two cache classes of
    #: one einsum.
    config: Optional[Tuple[int, ...]] = None

    @property
    def call_bytes(self) -> int:
        """Bytes one kernel call touches across all three operands."""
        return _ITEM * (math.prod(self.a_shape) + math.prod(self.b_shape) +
                        math.prod(self.out_shape))


def benchmark_key(alg: ContractionAlgorithm, sizes: Mapping[str, int],
                  cache_bytes: int = CACHE_BYTES, *,
                  arrival: Optional[Mapping[str, str]] = None,
                  ) -> MicroBenchmarkKey:
    """Map an algorithm at concrete sizes to its micro-benchmark identity.

    The equation is stored canonically relabeled; classes come from the
    §6.2.3 access distance against ``cache_bytes``.  For batched kernels
    the distance is rescaled to one *batch slice's* call bytes (strided
    batch access: the cache working set is one slice, not the stacked
    operands).  ``arrival`` maps operand names (``"A"``/``"B"``) to a
    known arrival class: ``COLD`` forces the operand cold (a chain
    intermediate that cannot fit in cache arrives evicted no matter how
    tight the in-loop reuse is); ``WARM`` defers to the distance.
    """
    a_sh, b_sh, o_sh = alg.kernel_shapes(sizes)
    dists = dict(access_distance(alg, sizes))
    if is_batched_kernel(alg.kernel):
        call_bytes = _ITEM * (math.prod(a_sh) + math.prod(b_sh) +
                              math.prod(o_sh))
        scale = slice_call_bytes(alg, sizes) / call_bytes
        dists = {op: d * scale for op, d in dists.items()}
    arrival = arrival or {}
    classes = tuple(
        COLD if (dists[op] > cache_bytes or arrival.get(op) == COLD)
        else WARM
        for op in ("A", "B"))
    return MicroBenchmarkKey(canonical_equation(alg.kernel_equation()),
                             a_sh, b_sh, o_sh, classes)


@dataclass(frozen=True)
class MicroBenchmark:
    """One measured micro-benchmark: per-call stats + first-call overhead."""

    key: MicroBenchmarkKey
    stats: Stats         # per-call runtime statistics (seconds)
    first: float         # first-call overhead (compile + cold libraries, s)
    seconds: float       # wall-clock cost of running this benchmark


#: a measurement backend: (key, repetitions) -> (per-call stats, first)
MeasureFn = Callable[[MicroBenchmarkKey, int], Tuple[Stats, float]]


def resolve_suite(suite: Optional["MicroBenchmarkSuite"],
                  repetitions: Optional[int]) -> "MicroBenchmarkSuite":
    """The one implementation of the suite-vs-repetitions contract.

    A supplied suite owns the measurement protocol, so a conflicting
    ``repetitions`` raises instead of being silently ignored; without a
    suite, a fresh one is built (default 5 repetitions).  Every
    predictor and sweep entry point resolves its arguments here.
    """
    if suite is not None:
        if repetitions is not None and repetitions != suite.repetitions:
            raise ValueError(
                f"repetitions={repetitions} conflicts with the supplied "
                f"suite's repetitions={suite.repetitions}; pass one or "
                f"the other")
        return suite
    return MicroBenchmarkSuite(
        repetitions=5 if repetitions is None else repetitions)


class MicroBenchmarkSuite:
    """Runs each distinct micro-benchmark once and shares the result.

    ``measure_fn`` defaults to the real cache-aware measurement; injecting a
    deterministic function of the key (as the equivalence tests do) makes
    deduplicated and per-algorithm predictions bit-comparable.  The suite is
    reusable across predictors and specs — keys are self-contained — and
    keeps running totals: :attr:`cost_seconds` (wall-clock spent measuring),
    :attr:`requests` (benchmarks asked for) vs :attr:`n_benchmarks`
    (distinct ones actually run).
    """

    def __init__(self, *, repetitions: int = 5,
                 cache_bytes: int = CACHE_BYTES, seed: int = 0,
                 measure_fn: Optional[MeasureFn] = None):
        self.repetitions = repetitions
        self.cache_bytes = cache_bytes
        self.seed = seed
        self.measure_fn: MeasureFn = measure_fn or self._measure
        self.results: Dict[MicroBenchmarkKey, MicroBenchmark] = {}
        self.requests = 0
        self.cost_seconds = 0.0
        self.oracle_cost_seconds = 0.0
        # provenance breakdown of self.results: every key is exactly one
        # of measured-here, loaded-from-a-store, or refreshed-in-place
        self.measured = 0
        self.loaded = 0
        self.refreshed = 0
        #: wall-clock the loaded measurements cost where they were
        #: originally run — the amortized (not free!) part of a warm start
        self.loaded_cost_seconds = 0.0
        self._provenance: Dict[MicroBenchmarkKey, str] = {}
        #: optional size-parametric model registry
        #: (:class:`repro.tc.parametric.ParametricModels`): consulted by
        #: :meth:`benchmark` for keys with no stored measurement before
        #: falling back to a fresh one.  Predictions are held apart from
        #: :attr:`results` (they are NOT measurements: a
        #: :class:`repro.store.ModelStore` must never persist them as
        #: such) and counted under :attr:`predicted_parametric`.
        self.parametric = None
        self._predicted: Dict[MicroBenchmarkKey, MicroBenchmark] = {}

    # ------------------------------------------------------------- public --
    def key_for(self, alg: ContractionAlgorithm, sizes: Mapping[str, int],
                *, arrival: Optional[Mapping[str, str]] = None,
                ) -> MicroBenchmarkKey:
        """The dedup signature of ``alg`` at ``sizes`` under this suite's
        cache capacity (see :func:`benchmark_key` for ``arrival``)."""
        return benchmark_key(alg, sizes, self.cache_bytes, arrival=arrival)

    def benchmark(self, alg: ContractionAlgorithm,
                  sizes: Mapping[str, int], *,
                  arrival: Optional[Mapping[str, str]] = None,
                  ) -> MicroBenchmark:
        """The (shared) micro-benchmark backing ``alg`` at ``sizes``.

        ``arrival`` forwards known operand arrival classes into the key
        (chain intermediates); identical keys share one measurement.
        An unmeasured key whose size point a fitted size-parametric
        model covers (:attr:`parametric`) is served as a synthetic
        prediction instead of being measured — stored measurements
        always win over predictions.
        """
        self.requests += 1
        key = self.key_for(alg, sizes, arrival=arrival)
        mb = self.results.get(key)
        if mb is not None:
            return mb
        mb = self._predicted.get(key)
        if mb is not None:
            return mb
        if self.parametric is not None:
            mb = self.parametric.predict(key)
            if mb is not None:
                self._predicted[key] = mb
                return mb
        mb = self._run(key)
        self.results[key] = mb
        self.measured += 1
        self._provenance[key] = "measured"
        return mb

    def measure_key(self, key: MicroBenchmarkKey) -> MicroBenchmark:
        """Measure a concrete key directly, with deduplication.

        The refinement sampling path: parametric fitting lowers its
        grid points to keys (:func:`repro.tc.parametric.key_at`) and
        measures them here — bypassing :attr:`parametric` on purpose (a
        model must never train on its own predictions), but sharing
        :attr:`results` so refinement samples are ordinary
        provenance-tracked measurements any later request reuses.
        """
        self.requests += 1
        mb = self.results.get(key)
        if mb is None:
            mb = self._run(key)
            self.results[key] = mb
            self._predicted.pop(key, None)   # a measurement supersedes it
            self.measured += 1
            self._provenance[key] = "measured"
        return mb

    def benchmark_fresh(self, alg: ContractionAlgorithm,
                        sizes: Mapping[str, int], *,
                        arrival: Optional[Mapping[str, str]] = None,
                        ) -> MicroBenchmark:
        """An independent, un-deduplicated measurement (the oracle path).

        Accounted under :attr:`oracle_cost_seconds`, NOT
        :attr:`cost_seconds`: validating against the oracle must not
        inflate the suite's reported prediction cost.
        """
        return self._run(self.key_for(alg, sizes, arrival=arrival),
                         oracle=True)

    def load_measurement(self, mb: MicroBenchmark) -> None:
        """Insert a measurement taken elsewhere (a model-store warm start).

        Counted under :attr:`loaded` (not :attr:`measured`) and its
        original wall-clock under :attr:`loaded_cost_seconds` — so the
        cost-fraction metrics can distinguish warm-start hits from fresh
        measurements instead of silently treating loaded keys as free.
        A key this suite already holds is not overwritten (the fresher
        local measurement wins).
        """
        if mb.key in self.results:
            return
        self.results[mb.key] = mb
        self.loaded += 1
        self.loaded_cost_seconds += mb.seconds
        self._provenance[mb.key] = "loaded"

    def record_measurement(self, key: MicroBenchmarkKey, stats: Stats,
                           first: float, seconds: float) -> MicroBenchmark:
        """Insert a measurement taken by an external protocol (the
        device-resident sweep of :mod:`repro.tc.device`).

        Device kernel keys are timed by a whole-grid sweep rather than
        per-key ``measure_fn`` calls, but they are accounted exactly like
        einsum keys: deduplicated (an existing result wins — the sweep
        dedups before measuring, so a collision means another sweep got
        there first), counted under :attr:`measured`, and their share of
        the sweep's wall-clock added to :attr:`cost_seconds`.
        """
        mb = self.results.get(key)
        if mb is not None:
            return mb
        mb = MicroBenchmark(key=key, stats=stats, first=first,
                            seconds=seconds)
        self.results[key] = mb
        self._predicted.pop(key, None)   # a measurement supersedes it
        self.measured += 1
        self.cost_seconds += seconds
        self._provenance[key] = "measured"
        return mb

    def refresh(self, key: MicroBenchmarkKey) -> MicroBenchmark:
        """Re-measure ``key`` in place (drift repair).

        The new measurement replaces the stored one; the key moves from
        its previous provenance bucket (loaded or measured) into
        :attr:`refreshed`, and the re-measurement's wall-clock lands in
        :attr:`cost_seconds` like any fresh benchmark.
        """
        mb = self._run(key)
        self.results[key] = mb
        self._predicted.pop(key, None)   # a measurement supersedes it
        previous = self._provenance.get(key)
        if previous == "loaded":
            self.loaded -= 1
        elif previous == "measured":
            self.measured -= 1
        if previous != "refreshed":
            self.refreshed += 1
        self._provenance[key] = "refreshed"
        return mb

    def drop_predictions(self, sig) -> int:
        """Invalidate held predictions whose signature matches ``sig``
        (an object with ``equation``/``classes``) — called when a
        signature's parametric model is refitted, so stale predictions
        from the old fit cannot be served again."""
        stale = [k for k in self._predicted
                 if k.equation == sig.equation and k.classes == sig.classes]
        for k in stale:
            del self._predicted[k]
        return len(stale)

    @property
    def predicted_parametric(self) -> int:
        """Distinct keys currently served from parametric predictions —
        the provenance bucket next to measured/loaded/refreshed, held
        OUTSIDE :attr:`results` (predictions are not measurements)."""
        return len(self._predicted)

    @property
    def predictions(self) -> Dict[MicroBenchmarkKey, MicroBenchmark]:
        """The currently-held parametric predictions (a copy — the
        provenance bookkeeping is not for callers to mutate)."""
        return dict(self._predicted)

    @property
    def n_benchmarks(self) -> int:
        """Distinct micro-benchmarks held so far (< requests under dedup;
        includes loaded warm-start keys)."""
        return len(self.results)

    def cost_fraction(self, measured_seconds: float, *,
                      include_loaded: bool = False) -> float:
        """Suite cost as a fraction of a measured contraction runtime.

        By default only wall-clock *this* suite spent measuring counts —
        the marginal cost of the predictions at hand.  With
        ``include_loaded=True`` the original cost of warm-start loaded
        measurements is added back: the amortized total, for honest
        whole-lifecycle accounting.
        """
        cost = self.cost_seconds
        if include_loaded:
            cost += self.loaded_cost_seconds
        return cost / measured_seconds

    def counters(self) -> Dict[str, float]:
        """Snapshot of the suite's running totals.

        Diff two snapshots to see what one phase genuinely added — e.g.
        how many *new* benchmarks (and how much wall-clock) the second
        size point of a sweep cost on top of the first.  The
        ``loaded``/``measured``/``refreshed`` breakdown partitions
        ``n_benchmarks`` by provenance: a warm-started session proves
        zero fresh measurements by ``measured == 0``.
        ``predicted_parametric`` counts keys served from size-parametric
        models instead — held apart from ``n_benchmarks``, since a
        prediction is not a measurement: a sweep over never-measured
        shapes proves it issued zero fresh micro-benchmarks by
        ``measured`` unchanged AND ``predicted_parametric`` grown."""
        return {"requests": self.requests,
                "n_benchmarks": self.n_benchmarks,
                "measured": self.measured,
                "loaded": self.loaded,
                "refreshed": self.refreshed,
                "predicted_parametric": self.predicted_parametric,
                "cost_seconds": self.cost_seconds,
                "loaded_cost_seconds": self.loaded_cost_seconds,
                "oracle_cost_seconds": self.oracle_cost_seconds}

    # ----------------------------------------------------------- internal --
    def _run(self, key: MicroBenchmarkKey,
             oracle: bool = False) -> MicroBenchmark:
        if key.config is not None:
            # guards every per-key path (benchmark/measure_key/refresh)
            # regardless of the injected measure_fn: device keys are only
            # measured by whole-grid sweeps (repro.tc.device), never by
            # the per-key einsum protocol
            raise ValueError(
                f"device kernel key {key.equation}{key.config} cannot be "
                f"measured per-key; device keys are measured by "
                f"repro.tc.device.DeviceSuite sweeps")
        t0 = time.perf_counter()
        stats, first = self.measure_fn(key, self.repetitions)
        seconds = time.perf_counter() - t0
        if oracle:
            self.oracle_cost_seconds += seconds
        else:
            self.cost_seconds += seconds
        return MicroBenchmark(key=key, stats=stats, first=first,
                              seconds=seconds)

    def _measure(self, key: MicroBenchmarkKey,
                 repetitions: int) -> Tuple[Stats, float]:
        """The shared §6.2 protocol, reconstructed purely from the key."""
        if key.config is not None:
            raise ValueError(
                f"device kernel key {key.equation}{key.config} cannot go "
                f"through the §6.2 einsum protocol; device keys are "
                f"measured by repro.tc.device.DeviceSuite sweeps")
        cls_a, cls_b = key.classes
        return run_kernel_benchmark(
            key.equation, key.a_shape, key.b_shape, key.out_shape,
            cold_a=cls_a == COLD, cold_b=cls_b == COLD,
            repetitions=repetitions, cache_bytes=self.cache_bytes,
            rng=np.random.default_rng(self.seed))
