"""Contraction prediction on the batched PredictionEngine (Ch. 6 x §4.5).

The per-algorithm path (``repro.core.contractions``) micro-benchmarks every
candidate independently and multiplies out the loop count in Python.  The
:class:`ContractionPredictor` instead treats a contraction's candidate set
like any other configuration sweep of the PR-1/2 engine:

1. every candidate maps to a deduplicated suite micro-benchmark
   (:mod:`repro.tc.suite`) — one measurement per distinct
   (kernel equation, shapes, cache classes) signature;
2. each signature becomes a (kernel, case) of a synthetic
   :class:`PerformanceModel` whose polynomials over the single size
   argument ``n_iterations`` encode the §6.2 prediction exactly:
   ``t_stat(n) = first + per_call_stat * n`` for min/med/max/mean and
   ``std(n) = per_call_std * sqrt(n)`` (Eq. 4.3 quadrature over n calls),
   with the measured first-call overhead (§6.2.6) included once;
3. the whole candidate set is compiled through the engine's
   :class:`TraceCache` into one reusable :class:`CompiledCalls` batch
   (the "block size" axis generalizes to the candidate index) and
   predicted with ``backend="numpy"`` or ``"jax"``.

``rank`` returns the traversal x kernel combinations sorted by predicted
total runtime; ``rank_oracle`` is the un-deduplicated per-algorithm
equivalence oracle.  With a deterministic ``measure_fn`` injected into the
suite, both paths agree bit-for-bit on the numpy backend.

Note the *cold-start* semantics of the total: ``first`` is the measured
first-call overhead, which on this JAX substrate is dominated by XLA
compilation (tens of ms, cached per (equation, shape) within a process).
For realistically sized contractions the loop term dominates and the
ranking matches warm measurements; at tiny sizes the overhead term can
dominate and a warm re-execution (e.g. ``measure_contraction``, which
warms up first) will order near-tied candidates differently — compare
against ``runtime`` minus the overhead (see the per-signature ``first``
in :attr:`ContractionPredictor.suite` results) for warm comparisons.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Mapping, Optional, Sequence, Tuple, Union

import numpy as np

from ..core.contractions import ContractionAlgorithm, ContractionSpec
from ..core.fitting import Polynomial
from ..core.grids import Domain
from ..core.model import ModelSet, PerformanceModel, Piece
from ..core.predict import KernelCall, PredictionEngine, TraceCache
from ..core.sampler import STATS, Stats
from .kernels import generate_algorithms
from .suite import (MicroBenchmark, MicroBenchmarkKey, MicroBenchmarkSuite,
                    resolve_suite)

#: domain of the synthetic per-signature models: any positive loop count
_N_DOMAIN = Domain((0,), (10 ** 18,))
_SCALE = np.ones(1)


def _signature_piece(mb: MicroBenchmark) -> Piece:
    """The §6.2 prediction as a polynomial piece over n_iterations."""
    linear = ((0,), (1,))          # t(n) = first + per_call * n
    polys = {s: Polynomial(linear,
                           np.array([mb.first, getattr(mb.stats, s)],
                                    dtype=np.float64), _SCALE)
             for s in ("min", "med", "max", "mean")}
    # std of n uncorrelated calls adds in quadrature (Eq. 4.3)
    polys["std"] = Polynomial(((0.5,),),
                              np.array([mb.stats.std], dtype=np.float64),
                              _SCALE)
    return Piece(domain=_N_DOMAIN, polys=polys)


def _total_stats(mb: MicroBenchmark, n: int) -> Stats:
    """Scalar-path total for one algorithm: what the engine must reproduce."""
    return Stats(min=mb.first + mb.stats.min * n,
                 med=mb.first + mb.stats.med * n,
                 max=mb.first + mb.stats.max * n,
                 mean=mb.first + mb.stats.mean * n,
                 std=mb.stats.std * n ** 0.5)


@dataclass(frozen=True)
class RankedContraction:
    """One ranked traversal x kernel combination.

    ``runtime`` is the predicted TOTAL (first-call overhead included
    once); ``first`` exposes that overhead separately so chain
    composition (:mod:`repro.tc.chains`) can count it once per distinct
    ``benchmark`` signature instead of once per step.
    """

    algorithm: ContractionAlgorithm
    runtime: Stats                 # predicted TOTAL runtime (incl. overhead)
    n_iterations: int
    benchmark: MicroBenchmarkKey   # the suite measurement backing it
    first: float                   # measured first-call overhead (seconds)

    @property
    def name(self) -> str:
        """The backing algorithm's display name."""
        return self.algorithm.name


class ContractionPredictor:
    """Rank a contraction's candidate algorithms from shared micro-benchmarks.

    ``prepare()`` (implicit on first use) runs the deduplicated suite and
    builds the per-signature models; ``rank``/``predict`` then evaluate the
    whole candidate set through one compiled engine batch per backend —
    repeated rankings reuse the suite measurements, the shared
    :class:`TraceCache` and the :class:`CompiledCalls` artifact, so they
    cost a few array ops, not a single kernel execution.
    """

    def __init__(self, spec: Union[ContractionSpec, str],
                 sizes: Mapping[str, int], *,
                 algorithms: Optional[
                     Sequence[ContractionAlgorithm]] = None,
                 include_batched: bool = True,
                 repetitions: Optional[int] = None,
                 suite: Optional[MicroBenchmarkSuite] = None,
                 cache: Optional[TraceCache] = None,
                 arrival: Optional[Mapping[str, str]] = None):
        self.spec = spec if isinstance(spec, ContractionSpec) else \
            ContractionSpec.parse(spec)
        self.sizes = dict(sizes)
        # known operand arrival classes ("A"/"B" -> WARM/COLD), forwarded
        # into every suite key — how chain steps see their intermediates
        self.arrival = dict(arrival) if arrival else None
        self.algorithms: List[ContractionAlgorithm] = (
            list(algorithms) if algorithms is not None
            else generate_algorithms(self.spec,
                                     include_batched=include_batched))
        if not self.algorithms:
            raise ValueError(f"no candidate algorithms for "
                             f"{self.spec.einsum_expr()}")
        self.suite = resolve_suite(suite, repetitions)
        self.cache = cache if cache is not None else TraceCache()
        self._engines: Dict[str, PredictionEngine] = {}
        self._models: Optional[ModelSet] = None
        self._benchmarks: List[MicroBenchmark] = []
        self._call_seqs: List[Tuple[KernelCall, ...]] = []
        self._tracer = self._trace   # stable identity for the TraceCache

    # ------------------------------------------------------------- suite --
    def benchmark_keys(self) -> List[MicroBenchmarkKey]:
        """Every candidate's suite key at this predictor's sizes —
        computed WITHOUT measuring anything (key derivation is pure
        arithmetic).  The parametric pre-pass enumerates these across a
        sweep grid to decide which signatures need fitting before any
        ranking runs (:meth:`repro.tc.session.PredictorSession.
        refine_parametric`)."""
        return [self.suite.key_for(alg, self.sizes, arrival=self.arrival)
                for alg in self.algorithms]

    def prepare(self) -> None:
        """Run the (deduplicated) suite and compile the candidate models."""
        if self._models is not None:
            return
        benchmarks = [self.suite.benchmark(alg, self.sizes,
                                           arrival=self.arrival)
                      for alg in self.algorithms]
        models = ModelSet()
        seqs: List[Tuple[KernelCall, ...]] = []
        for alg, mb in zip(self.algorithms, benchmarks):
            if alg.kernel not in models:
                models.add(PerformanceModel(kernel=alg.kernel,
                                            setup="tc-microbench"))
            model = models[alg.kernel]
            case = (mb.key.equation, mb.key.a_shape, mb.key.b_shape,
                    mb.key.out_shape, mb.key.classes)
            if case not in model.cases:
                model.add_piece(case, _signature_piece(mb))
            seqs.append((KernelCall(kernel=alg.kernel, case=case,
                                    sizes=(alg.n_iterations(self.sizes),)),))
        # emit the padded case tensors now, like modelgen does: the first
        # jax-backend rank should compile + dispatch, not derive tensors
        self._models = models.finalize()
        self._benchmarks = benchmarks
        self._call_seqs = seqs

    @property
    def model_set(self) -> ModelSet:
        """The finalized per-signature :class:`ModelSet` (prepares on
        first access) — the artifact a :class:`repro.store.ModelStore`
        persists alongside the raw measurements."""
        self.prepare()
        return self._models

    def _trace(self, n: int, i: int) -> Tuple[KernelCall, ...]:
        # Tracer-protocol adapter: the engine's block-size axis generalizes
        # to the candidate index; ``n`` is unused (one fixed size mapping)
        return self._call_seqs[i]

    # ----------------------------------------------------------- predict --
    def engine(self, backend: str = "numpy") -> PredictionEngine:
        """The (shared-cache) engine for one backend; models built lazily."""
        self.prepare()
        eng = self._engines.get(backend)
        if eng is None:
            eng = PredictionEngine(self._models, backend=backend,
                                   cache=self.cache)
            self._engines[backend] = eng
        return eng

    def predict(self, backend: str = "numpy") -> np.ndarray:
        """(n_algorithms, len(STATS)) predicted total runtimes."""
        eng = self.engine(backend)
        compiled = eng.compile_sweep(self._tracer, 0,
                                     range(len(self.algorithms)))
        return eng.predict_compiled(compiled)

    def rank(self, *, stat: str = "med",
             backend: str = "numpy") -> List[RankedContraction]:
        """All traversal x kernel combinations, fastest-predicted first."""
        arr = self.predict(backend)
        col = STATS.index(stat)
        order = np.argsort(arr[:, col], kind="stable")
        return [RankedContraction(
                    algorithm=self.algorithms[i],
                    runtime=Stats(*map(float, arr[i])),
                    n_iterations=self.algorithms[i].n_iterations(self.sizes),
                    benchmark=self._benchmarks[i].key,
                    first=self._benchmarks[i].first)
                for i in order]

    def rank_oracle(self, *, stat: str = "med",
                    fresh: bool = True) -> List[RankedContraction]:
        """The per-algorithm equivalence oracle: §6.2 applied in plain
        Python per candidate — no engine, no batching.

        ``fresh=True`` (default) also re-measures every candidate
        independently (no deduplication), as the original path did;
        ``fresh=False`` reuses the suite's shared measurements, isolating
        the engine-vs-scalar arithmetic so the two rankings must agree
        deterministically even with noisy real timings."""
        out = []
        for alg in self.algorithms:
            mb = self.suite.benchmark_fresh(alg, self.sizes,
                                            arrival=self.arrival) if fresh \
                else self.suite.benchmark(alg, self.sizes,
                                          arrival=self.arrival)
            n = alg.n_iterations(self.sizes)
            out.append(RankedContraction(algorithm=alg,
                                         runtime=_total_stats(mb, n),
                                         n_iterations=n, benchmark=mb.key,
                                         first=mb.first))
        out.sort(key=lambda r: getattr(r.runtime, stat))
        return out

    # -------------------------------------------------------------- cost --
    @property
    def n_benchmarks(self) -> int:
        self.prepare()
        return self.suite.n_benchmarks

    def prediction_cost_fraction(self, measured_seconds: float) -> float:
        """Suite cost over a measured contraction runtime (the paper's
        "merely a fraction of a contraction's runtime" metric)."""
        self.prepare()
        return self.suite.cost_fraction(measured_seconds)


# ------------------------------------------------------- size-sweep mode --

@dataclass(frozen=True)
class SizeSweep:
    """Shared shape of a size-sweep result (contraction or chain level).

    ``rankings[i]`` is the full fastest-first ranking at
    ``sizes_grid[i]``; every point was predicted from the ONE shared
    :attr:`suite` / :attr:`cache`, so a new size point re-predicts from
    existing measurements wherever its (equation, shapes, cache-class)
    keys are unchanged and only the genuinely new keys are measured —
    or, when the suite carries fitted size-parametric models
    (:mod:`repro.tc.parametric`), predicted without measuring at all.
    """

    sizes_grid: Tuple[Dict[str, int], ...]
    rankings: Tuple[Tuple, ...]
    suite: MicroBenchmarkSuite
    cache: TraceCache

    @property
    def winners(self) -> List:
        """The fastest-predicted candidate at each size point."""
        return [ranking[0] for ranking in self.rankings]

    @property
    def n_benchmarks(self) -> int:
        """Distinct micro-benchmarks measured across ALL size points."""
        return self.suite.n_benchmarks

    @property
    def predicted_parametric(self) -> int:
        """Distinct grid keys served from size-parametric models instead
        of measurements (0 on a non-parametric suite) — how much of the
        sweep was covered without a single fresh micro-benchmark."""
        return self.suite.predicted_parametric

    def cost_fraction(self, measured_seconds: float) -> float:
        """Total suite cost over one measured execution — the whole
        sweep's prediction cost as a fraction of a single run."""
        return self.suite.cost_fraction(measured_seconds)


@dataclass(frozen=True)
class ContractionSizeSweep(SizeSweep):
    """One contraction's candidate set ranked across a grid of sizes.

    Produced by :func:`rank_contraction_sweep`; ``rankings`` holds
    :class:`RankedContraction` lists, one per size point, and the
    per-signature models are size-parametric (``t(n) = first +
    per_call * n`` over the loop count) — see :class:`SizeSweep` for the
    shared suite/cache semantics.
    """

    spec: ContractionSpec
    predictors: Tuple[ContractionPredictor, ...]


def rank_contraction_sweep(spec: Union[ContractionSpec, str],
                           sizes_grid: Sequence[Mapping[str, int]], *,
                           stat: str = "med", backend: str = "numpy",
                           algorithms: Optional[
                               Sequence[ContractionAlgorithm]] = None,
                           include_batched: bool = True,
                           repetitions: Optional[int] = None,
                           suite: Optional[MicroBenchmarkSuite] = None,
                           cache: Optional[TraceCache] = None,
                           arrival: Optional[Mapping[str, str]] = None,
                           ) -> ContractionSizeSweep:
    """Rank every candidate algorithm at every size point from ONE suite.

    The size-sweep autotuning mode: one :class:`ContractionPredictor`
    per size point, all sharing a single
    :class:`~repro.tc.suite.MicroBenchmarkSuite` and
    :class:`~repro.core.predict.TraceCache` (pass ``suite=``/``cache=``
    to also share them with prior single-size rankings).  Size points
    whose candidates map to already-measured (equation, shapes,
    cache-class) keys re-predict without any new measurement — e.g.
    sweeping a loop-only dimension leaves every loop-nest candidate's
    kernel shapes untouched — so the whole sweep's measurement cost is
    bounded by the number of *distinct* keys, not by
    ``len(sizes_grid) * len(algorithms)``.
    """
    spec = spec if isinstance(spec, ContractionSpec) else \
        ContractionSpec.parse(spec)
    grid = [dict(s) for s in sizes_grid]
    if not grid:
        raise ValueError("sizes_grid must name at least one size point")
    suite = resolve_suite(suite, repetitions)
    cache = cache if cache is not None else TraceCache()
    algs = list(algorithms) if algorithms is not None else \
        generate_algorithms(spec, include_batched=include_batched)
    predictors, rankings = [], []
    for sizes in grid:
        pred = ContractionPredictor(spec, sizes, algorithms=algs,
                                    suite=suite, cache=cache,
                                    arrival=arrival)
        rankings.append(tuple(pred.rank(stat=stat, backend=backend)))
        predictors.append(pred)
    return ContractionSizeSweep(spec=spec, sizes_grid=tuple(grid),
                                rankings=tuple(rankings),
                                predictors=tuple(predictors),
                                suite=suite, cache=cache)
