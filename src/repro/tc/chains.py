"""Einsum-path prediction: compose contraction predictors into chains.

The dissertation's Ch. 6 predicts a *single* BLAS-based contraction from
cache-aware micro-benchmarks.  Real tensor workloads are chains: an
N-operand einsum is evaluated as a sequence of pairwise contractions (a
*contraction path*, as in ``np.einsum_path``), and each pairwise step's
operands arrive warm or cold depending on what the previous step just
wrote (cf. arXiv:1409.8608 on BLAS-based tensor contractions and
arXiv:1402.5897 on caching across kernel sequences).  This module ranks
whole paths without executing any of them:

1. :meth:`ChainSpec.paths` enumerates the pairwise contraction paths of
   an N-operand einsum (N <= :data:`MAX_OPERANDS`), deduplicating
   linearizations of the same contraction tree and operationally
   identical paths (same multiset of step contractions);
2. every path step lowers to an ordinary
   :class:`~repro.core.contractions.ContractionSpec`, predicted by an
   ordinary :class:`~repro.tc.predictor.ContractionPredictor` — all
   steps of all candidate paths share ONE
   :class:`~repro.tc.suite.MicroBenchmarkSuite` and ONE
   :class:`~repro.core.predict.TraceCache` (canonically-relabeled keys
   make renamed-but-identical steps the same measurement);
3. per-step estimates compose into a chain total: min/med/max/mean add,
   std adds in quadrature (Eq. 4.3), and the measured first-call
   overhead is counted once per distinct benchmark signature — NOT once
   per step, since a compiled kernel stays compiled.

The cache class of each step *input* follows the suite's access-distance
rule; intermediates additionally carry a **propagated arrival class**:
warm if the producing step's output fits in the cache capacity, cold
otherwise — never measured fresh.  The per-step per-algorithm scalar
path (:meth:`ChainPredictor.rank_paths_oracle`) is kept as the
equivalence oracle, mirroring the whole prediction stack's convention.
"""

from __future__ import annotations

import itertools
import math
from dataclasses import dataclass
from typing import (Dict, List, Mapping, Optional, Sequence, Tuple, Union)

import numpy as np

from ..core.contractions import _ITEM, ContractionSpec, execute
from ..core.predict import TraceCache
from ..core.sampler import Stats
from .kernels import base_kernel, generate_algorithms
from .predictor import ContractionPredictor, RankedContraction, SizeSweep
from .suite import (COLD, WARM, MicroBenchmarkKey, MicroBenchmarkSuite,
                    resolve_suite)

#: largest supported einsum-chain operand count (path count grows as the
#: double factorial (2N-3)!!: 3, 15, 105 for N = 3, 4, 5)
MAX_OPERANDS = 5


# ------------------------------------------------------------------ chain --

@dataclass(frozen=True)
class ChainStep:
    """One pairwise contraction inside a path.

    ``inputs`` are operand *slots*: slots ``0..N-1`` are the chain's
    original operands, slot ``N + s`` is the intermediate produced by
    step ``s`` (steps are numbered in path order).  ``spec`` is the
    ordinary pairwise contraction the step lowers to; its ``a_idx`` /
    ``b_idx`` are the index strings of the two consumed slots and its
    ``out_idx`` the produced intermediate's indices.
    """

    spec: ContractionSpec
    inputs: Tuple[int, int]


@dataclass(frozen=True)
class ChainPath:
    """One full pairwise-contraction path of an N-operand einsum.

    ``n_operands`` fixes the slot numbering of the steps (see
    :class:`ChainStep`).  Step ``s`` writes slot ``n_operands + s``; the
    last step produces the chain's output.
    """

    n_operands: int
    steps: Tuple[ChainStep, ...]

    @property
    def name(self) -> str:
        """Nested-parenthesis rendering over operand positions, e.g.
        ``((0.1).(2.3))``."""
        rendered: Dict[int, str] = {i: str(i)
                                    for i in range(self.n_operands)}
        for s, step in enumerate(self.steps):
            i, j = step.inputs
            rendered[self.n_operands + s] = \
                f"({rendered[i]}.{rendered[j]})"
        return rendered[self.n_operands + len(self.steps) - 1]

    def intermediate_bytes(self, sizes: Mapping[str, int]) -> List[int]:
        """Footprint (bytes) of each step's output, in path order."""
        return [_ITEM * math.prod(sizes[i] for i in step.spec.out_idx)
                for step in self.steps]


@dataclass(frozen=True)
class ChainSpec:
    """An N-operand einsum ``op0,op1,...->out`` to be evaluated pairwise.

    Index strings follow :class:`~repro.core.contractions.ContractionSpec`
    conventions: one letter per dimension, no repeats within an operand
    (no diagonals), and every index must appear in at least two places —
    an index private to a single operand and absent from the output would
    be a sum-reduction no pairwise BLAS step can express.
    """

    operands: Tuple[str, ...]
    out_idx: str

    @staticmethod
    def parse(expr: Union[str, "ChainSpec"]) -> "ChainSpec":
        """Parse ``"ij,jk,kl->il"`` (passes an existing spec through)."""
        if isinstance(expr, ChainSpec):
            return expr
        ins, out = expr.split("->")
        return ChainSpec(tuple(s.strip() for s in ins.split(",")),
                         out.strip())

    def __post_init__(self) -> None:
        # the dataclass is frozen, so validating here covers every
        # construction site, not just parse()
        n = len(self.operands)
        if not 2 <= n <= MAX_OPERANDS:
            raise ValueError(f"chain needs 2..{MAX_OPERANDS} operands, "
                             f"got {n}: {self.einsum_expr()}")
        for idx in self.operands + (self.out_idx,):
            if len(set(idx)) != len(idx):
                raise ValueError(f"repeated index within {idx!r} "
                                 f"(diagonals unsupported)")
        pool = "".join(self.operands)
        for i in self.out_idx:
            if i not in pool:
                raise ValueError(f"output index {i!r} appears in no "
                                 f"operand")
        for i in set(pool):
            if pool.count(i) == 1 and i not in self.out_idx:
                raise ValueError(
                    f"index {i!r} appears in one operand only and not in "
                    f"the output — a sum reduction no pairwise "
                    f"contraction step can express")

    @property
    def all_indices(self) -> Tuple[str, ...]:
        """Every distinct index, in order of first appearance."""
        seen: List[str] = []
        for i in "".join(self.operands) + self.out_idx:
            if i not in seen:
                seen.append(i)
        return tuple(seen)

    def einsum_expr(self) -> str:
        """The full einsum expression, e.g. ``"ij,jk,kl->il"``."""
        return ",".join(self.operands) + "->" + self.out_idx

    def flops(self, sizes: Mapping[str, int]) -> float:
        """Naive flop count of the un-factored einsum (2x the full index
        space) — an upper bound any decent path undercuts."""
        return 2.0 * math.prod(sizes[i] for i in self.all_indices)

    # --------------------------------------------------------- lowering --
    def _step_spec(self, a_idx: str, b_idx: str,
                   remaining: Sequence[str], final: bool) -> ContractionSpec:
        """Lower one pairwise step: keep every index still needed by a
        remaining operand or by the chain output, contract the rest."""
        if final:
            out = self.out_idx
        else:
            needed = set(self.out_idx).union(*map(set, remaining))
            seen = set()
            out = "".join(
                i for i in a_idx + b_idx
                if i in needed and not (i in seen or seen.add(i)))
        return ContractionSpec(a_idx, b_idx, out)

    def paths(self) -> List[ChainPath]:
        """All deduplicated pairwise contraction paths.

        Enumerates every linearization (pick any two live slots, contract,
        repeat), then deduplicates twice: linearizations of the same
        contraction *tree* are one path (the chain model is
        order-insensitive: intermediates' arrival classes depend only on
        their size), and so are paths whose sorted multiset of step
        contractions coincides (operationally identical: same
        benchmarks, same composed totals).
        """
        n = len(self.operands)
        out: List[ChainPath] = []
        seen_trees: set = set()
        seen_sigs: set = set()

        def rec(live, steps):
            # live: list of (slot, idx_str, tree-key); steps built so far
            if len(live) == 1:
                tree = live[0][2]
                if tree in seen_trees:
                    return
                seen_trees.add(tree)
                sig = tuple(sorted(
                    (s.spec.a_idx, s.spec.b_idx, s.spec.out_idx,
                     s.inputs[0] >= n, s.inputs[1] >= n) for s in steps))
                if sig in seen_sigs:
                    return
                seen_sigs.add(sig)
                out.append(ChainPath(n, tuple(steps)))
                return
            for x, y in itertools.combinations(range(len(live)), 2):
                (sa, a_idx, ta), (sb, b_idx, tb) = live[x], live[y]
                rest = [e for k, e in enumerate(live) if k not in (x, y)]
                spec = self._step_spec(a_idx, b_idx,
                                       [e[1] for e in rest],
                                       final=len(live) == 2)
                step = ChainStep(spec=spec, inputs=(sa, sb))
                slot = n + len(steps)
                rec(rest + [(slot, spec.out_idx,
                             frozenset((ta, tb)))], steps + [step])

        rec([(i, idx, i) for i, idx in enumerate(self.operands)], [])
        return out


# -------------------------------------------------------------- execution --

def _run_steps(path: ChainPath, operands, step_fn):
    slots: Dict[int, np.ndarray] = dict(enumerate(operands))
    for s, step in enumerate(path.steps):
        i, j = step.inputs
        slots[path.n_operands + s] = step_fn(s, step, slots[i], slots[j])
    return slots[path.n_operands + len(path.steps) - 1]


def execute_path_reference(chain: ChainSpec, path: ChainPath,
                           operands: Sequence[np.ndarray]) -> np.ndarray:
    """Execute one path as literal pairwise ``np.einsum`` steps.

    The operational definition of what a path computes; dtypes are
    preserved, so integer-valued float64 operands reproduce the full
    einsum bit-for-bit under ANY path (every association order sums the
    same exact integers).
    """
    return _run_steps(path, operands,
                      lambda s, step, a, b:
                      np.einsum(step.spec.einsum_expr(), a, b))


def execute_chain_reference(chain: ChainSpec,
                            operands: Sequence[np.ndarray]) -> np.ndarray:
    """The un-factored einsum over all operands (the chain's ground
    truth, independent of any path)."""
    return np.einsum(chain.einsum_expr(), *operands)


def execute_chain(chain: ChainSpec, ranked: "RankedChain",
                  operands: Sequence[np.ndarray],
                  sizes: Mapping[str, int]) -> np.ndarray:
    """Execute a ranked chain with its selected per-step algorithms.

    Each step runs through :func:`repro.core.contractions.execute` (the
    real loop nest around the jitted kernel), feeding intermediates
    forward — what the prediction's cost fraction is measured against.
    """
    return _run_steps(ranked.path, operands,
                      lambda s, step, a, b:
                      execute(ranked.steps[s].algorithm, a, b, sizes))


def validate_paths(chain: Union[ChainSpec, str],
                   sizes: Mapping[str, int], *,
                   paths: Optional[Sequence[ChainPath]] = None,
                   rng: Optional[np.random.Generator] = None) -> None:
    """Execute every path on small-integer operands against the full
    einsum; raises ``AssertionError`` naming paths that are not
    bit-equal.

    Integer-valued float64 entries make every association order exact,
    so this checks true equality, not closeness.
    """
    chain = ChainSpec.parse(chain)
    rng = rng or np.random.default_rng(0)
    ops = [rng.integers(-3, 4, size=[sizes[i] for i in idx]
                        ).astype(np.float64)
           for idx in chain.operands]
    ref = execute_chain_reference(chain, ops)
    bad = [p.name for p in (paths if paths is not None else chain.paths())
           if not np.array_equal(execute_path_reference(chain, p, ops), ref)]
    if bad:
        raise AssertionError(
            f"{len(bad)} path(s) disagree with the einsum reference for "
            f"{chain.einsum_expr()}: {bad}")


# ------------------------------------------------------------ composition --

@dataclass(frozen=True)
class RankedChain:
    """One ranked contraction path with its selected per-step algorithms.

    ``runtime`` is the composed chain total (see
    :func:`compose_chain_runtime`); ``steps`` holds each step's winning
    :class:`~repro.tc.predictor.RankedContraction` in path order.
    """

    path: ChainPath
    steps: Tuple[RankedContraction, ...]
    runtime: Stats

    @property
    def name(self) -> str:
        """The path's nested-parenthesis name, e.g. ``((0.1).2)``."""
        return self.path.name


def compose_chain_runtime(steps: Sequence[RankedContraction]) -> Stats:
    """Compose per-step predictions into a chain total.

    min/med/max/mean add across steps and std adds in quadrature
    (Eq. 4.3: steps are uncorrelated estimates).  Each step's runtime
    already includes its benchmark's first-call overhead; repeated
    signatures subtract the duplicate — a kernel compiled by one step is
    still compiled at the next, so the overhead is paid once per
    DISTINCT signature, not once per step.
    """
    sums = {s: 0.0 for s in ("min", "med", "max", "mean")}
    var = 0.0
    dup_first = 0.0
    seen: set = set()
    for r in steps:
        for s in sums:
            sums[s] += getattr(r.runtime, s)
        var += r.runtime.std ** 2
        if r.benchmark in seen:
            dup_first += r.first
        else:
            seen.add(r.benchmark)
    return Stats(min=sums["min"] - dup_first, med=sums["med"] - dup_first,
                 max=sums["max"] - dup_first, mean=sums["mean"] - dup_first,
                 std=var ** 0.5)


# -------------------------------------------------------------- predictor --

class ChainPredictor:
    """Rank an einsum's contraction paths from shared micro-benchmarks.

    Every path step becomes an ordinary
    :class:`~repro.tc.predictor.ContractionPredictor` over the step's
    candidate algorithms; all steps of all paths share this predictor's
    :class:`~repro.tc.suite.MicroBenchmarkSuite` and
    :class:`~repro.core.predict.TraceCache`, and steps appearing in
    several paths (or identical up to index renaming) share one
    predictor outright.  A path's prediction picks each step's fastest
    candidate and composes the totals with
    :func:`compose_chain_runtime`.  Per-step selection is greedy: with
    arrival classes fixed by the path, steps couple only through the
    shared-signature first-call discount, so a greedy winner can miss a
    jointly-cheaper assignment by at most one ``first`` per shared
    signature — negligible for realistic loop counts, and bounded by
    the overhead term, never the loop term.

    ``memory_limit_bytes`` prunes paths whose non-final intermediates
    exceed the limit (the same guard ``np.einsum_path`` applies) —
    outer-product detours can otherwise dwarf every useful candidate.
    ``kernels`` restricts every step's candidate set to the named base
    kernels (e.g. ``("gemm", "gemv")``): fewer distinct micro-benchmark
    signatures, at the price of possibly missing an exotic winner.
    """

    def __init__(self, chain: Union[ChainSpec, str],
                 sizes: Mapping[str, int], *,
                 paths: Optional[Sequence[ChainPath]] = None,
                 suite: Optional[MicroBenchmarkSuite] = None,
                 cache: Optional[TraceCache] = None,
                 repetitions: Optional[int] = None,
                 include_batched: bool = True,
                 kernels: Optional[Sequence[str]] = None,
                 max_loop_perms: int = 24,
                 memory_limit_bytes: Optional[int] = None):
        self.chain = ChainSpec.parse(chain)
        self.sizes = dict(sizes)
        self.include_batched = include_batched
        self.kernels = tuple(kernels) if kernels is not None else None
        self.max_loop_perms = max_loop_perms
        candidates = list(paths) if paths is not None else \
            self.chain.paths()
        if memory_limit_bytes is not None:
            candidates = [
                p for p in candidates
                if all(b <= memory_limit_bytes
                       for b in p.intermediate_bytes(self.sizes)[:-1])]
        if not candidates:
            raise ValueError(
                f"no candidate paths for {self.chain.einsum_expr()} "
                f"(memory_limit_bytes={memory_limit_bytes})")
        self.paths = candidates
        self.suite = resolve_suite(suite, repetitions)
        self.cache = cache if cache is not None else TraceCache()
        self._predictors: Dict[Tuple, ContractionPredictor] = {}

    # ----------------------------------------------------------- steps --
    def arrival_classes(self, step: ChainStep) -> Dict[str, str]:
        """Propagated arrival class per step input.

        Original operands get no entry (the suite's access-distance rule
        applies unmodified); an intermediate arrives WARM iff the
        producing step's output fits in the suite's cache capacity, COLD
        otherwise — its state is what the previous step left behind, so
        it is never measured fresh.
        """
        out: Dict[str, str] = {}
        for op, (slot, idx) in zip(
                ("A", "B"),
                zip(step.inputs, (step.spec.a_idx, step.spec.b_idx))):
            if slot >= len(self.chain.operands):
                bytes_ = _ITEM * math.prod(self.sizes[i] for i in idx)
                out[op] = WARM if bytes_ <= self.suite.cache_bytes else COLD
        return out

    def step_predictor(self, step: ChainStep) -> ContractionPredictor:
        """The (shared) per-step predictor; steps with equal (spec,
        arrival) reuse one predictor — and through the shared suite, even
        differently-named equal steps share measurements."""
        arrival = self.arrival_classes(step)
        key = (step.spec, tuple(sorted(arrival.items())))
        pred = self._predictors.get(key)
        if pred is None:
            algs = generate_algorithms(
                step.spec, include_batched=self.include_batched,
                max_loop_perms=self.max_loop_perms)
            if self.kernels is not None:
                algs = [a for a in algs
                        if base_kernel(a.kernel) in self.kernels]
            pred = ContractionPredictor(
                step.spec, self.sizes, algorithms=algs,
                suite=self.suite, cache=self.cache,
                arrival=arrival or None)
            self._predictors[key] = pred
        return pred

    def prepare(self) -> None:
        """Run the (deduplicated) suite for every step of every path."""
        for path in self.paths:
            for step in path.steps:
                self.step_predictor(step).prepare()

    def benchmark_keys(self) -> List[MicroBenchmarkKey]:
        """Every step candidate's suite key across ALL paths — computed
        without measuring anything (step predictors are constructed but
        never prepared).  The chain-level analogue of
        :meth:`~repro.tc.predictor.ContractionPredictor.benchmark_keys`,
        feeding the session's parametric pre-pass."""
        keys = []
        for path in self.paths:
            for step in path.steps:
                keys.extend(self.step_predictor(step).benchmark_keys())
        return keys

    # ------------------------------------------------------------ rank --
    def rank_paths(self, *, stat: str = "med",
                   backend: str = "numpy") -> List[RankedChain]:
        """All candidate paths, fastest-predicted chain total first.

        Per-step rankings run through the batched
        :class:`~repro.core.predict.PredictionEngine` on ``backend``;
        repeated calls reuse suite measurements and compiled batches.
        """
        return self._rank(stat, lambda step: self.step_predictor(step).rank(
            stat=stat, backend=backend)[0])

    def rank_paths_oracle(self, *, stat: str = "med",
                          fresh: bool = True) -> List[RankedChain]:
        """The step-by-step per-algorithm equivalence oracle.

        Each step is ranked by
        :meth:`~repro.tc.predictor.ContractionPredictor.rank_oracle` —
        §6.2 arithmetic in plain Python, no engine — and composed with
        the same :func:`compose_chain_runtime`.  ``fresh=False`` reuses
        the suite's shared measurements, so engine and oracle rankings
        must agree deterministically; ``fresh=True`` re-measures every
        candidate independently (the original per-algorithm protocol).
        """
        return self._rank(stat, lambda step: self.step_predictor(
            step).rank_oracle(stat=stat, fresh=fresh)[0])

    def _rank(self, stat, step_winner) -> List[RankedChain]:
        # steps shared by several paths resolve to one predictor: compute
        # (and, for the fresh oracle, re-measure) each winner once per
        # distinct predictor, not once per (path, step) occurrence
        winners: Dict[int, RankedContraction] = {}

        def winner(step):
            key = id(self.step_predictor(step))
            if key not in winners:
                winners[key] = step_winner(step)
            return winners[key]

        ranked = []
        for path in self.paths:
            chosen = tuple(winner(step) for step in path.steps)
            ranked.append(RankedChain(
                path=path, steps=chosen,
                runtime=compose_chain_runtime(chosen)))
        ranked.sort(key=lambda r: getattr(r.runtime, stat))
        return ranked

    def select_path(self, *, stat: str = "med",
                    backend: str = "numpy") -> RankedChain:
        """The fastest-predicted path (``rank_paths(...)[0]``)."""
        return self.rank_paths(stat=stat, backend=backend)[0]

    # ------------------------------------------------------------ cost --
    @property
    def n_benchmarks(self) -> int:
        """Distinct micro-benchmarks run across ALL steps of ALL paths."""
        return self.suite.n_benchmarks

    def prediction_cost_fraction(self, measured_seconds: float) -> float:
        """Total suite cost over one measured chain execution — Ch. 6's
        "merely a fraction of a contraction's runtime", lifted to whole
        einsum paths."""
        return self.suite.cost_fraction(measured_seconds)


# ------------------------------------------------------- size-sweep mode --

@dataclass(frozen=True)
class ChainSizeSweep(SizeSweep):
    """An einsum's contraction paths ranked across a grid of sizes.

    Produced by :func:`rank_einsum_sweep`; ``rankings`` holds
    :class:`RankedChain` lists, one per size point — every size point's
    steps were predicted from the ONE shared suite/cache, so a new size
    point only measures the (equation, shapes, cache-class) keys no
    earlier point (or prior single-size ranking sharing the same suite)
    already covered.  Shared members (``winners``, ``n_benchmarks``,
    ``cost_fraction``) come from :class:`~repro.tc.predictor.SizeSweep`.
    """

    chain: ChainSpec
    predictors: Tuple[ChainPredictor, ...]


def rank_einsum_sweep(chain: Union[ChainSpec, str],
                      sizes_grid: Sequence[Mapping[str, int]], *,
                      stat: str = "med", backend: str = "numpy",
                      suite: Optional[MicroBenchmarkSuite] = None,
                      cache: Optional[TraceCache] = None,
                      repetitions: Optional[int] = None,
                      include_batched: bool = True,
                      kernels: Optional[Sequence[str]] = None,
                      max_loop_perms: int = 24,
                      memory_limit_bytes: Optional[int] = None,
                      ) -> ChainSizeSweep:
    """Rank every contraction path at every size point from ONE suite.

    The chain-level size-sweep autotuning mode: one
    :class:`ChainPredictor` per size point, all sharing a single
    :class:`~repro.tc.suite.MicroBenchmarkSuite` and
    :class:`~repro.core.predict.TraceCache` (pass ``suite=``/``cache=``
    to extend a suite that already served single-size rankings).  Steps
    whose kernel signatures are unchanged across sizes — canonical
    relabeling included — re-predict from existing measurements; only
    the genuinely new keys are measured.  ``memory_limit_bytes`` prunes
    per size point (an intermediate may be affordable at one size and
    not another); a point where NO path survives the limit fails the
    sweep with an error naming that point — drop it from the grid (or
    raise the limit) to rank the rest.  The remaining keywords bound
    the per-step candidate sets exactly as on :class:`ChainPredictor`.
    """
    spec = ChainSpec.parse(chain)
    grid = [dict(s) for s in sizes_grid]
    if not grid:
        raise ValueError("sizes_grid must name at least one size point")
    suite = resolve_suite(suite, repetitions)
    cache = cache if cache is not None else TraceCache()
    predictors, rankings = [], []
    for sizes in grid:
        try:
            pred = ChainPredictor(spec, sizes, suite=suite, cache=cache,
                                  include_batched=include_batched,
                                  kernels=kernels,
                                  max_loop_perms=max_loop_perms,
                                  memory_limit_bytes=memory_limit_bytes)
        except ValueError as e:
            raise ValueError(f"size point {sizes}: {e}") from None
        rankings.append(tuple(pred.rank_paths(stat=stat, backend=backend)))
        predictors.append(pred)
    return ChainSizeSweep(chain=spec, sizes_grid=tuple(grid),
                          rankings=tuple(rankings),
                          predictors=tuple(predictors),
                          suite=suite, cache=cache)
