"""repro.tc — cache-aware tensor-contraction prediction (paper Ch. 6).

Promotes the Ch. 6 scenario to a first-class subsystem on the batched
prediction engine: a §6.1 generator extended with batched-kernel patterns
(:mod:`~repro.tc.kernels`), a deduplicated cache-aware micro-benchmark
suite that reports its own cost (:mod:`~repro.tc.suite`), a
:class:`ContractionPredictor` that compiles the whole candidate set
through the PR-1/2 :class:`~repro.core.predict.PredictionEngine`
(:mod:`~repro.tc.predictor`), and an einsum-path layer that composes
per-step predictors into multi-contraction chain rankings with
cache-state propagation between steps (:mod:`~repro.tc.chains`).

Since the session redesign, :class:`~repro.tc.session.PredictorSession`
is the single entry point: one object owning the shared suite, trace
cache and backend, fronting every ranking/selection mode and the serving
scheduler's step-cost models.  The legacy module-level call forms remain
as one-release deprecation shims.

See ``docs/contraction-prediction.md`` for the full walkthrough.
"""

from .chains import (MAX_OPERANDS, ChainPath, ChainPredictor, ChainSizeSweep,
                     ChainSpec, ChainStep, RankedChain, compose_chain_runtime,
                     execute_chain, execute_chain_reference,
                     execute_path_reference, rank_einsum_sweep,
                     validate_paths)
from .kernels import (BATCH_SUFFIX, BATCHABLE_KERNELS, base_kernel,
                      generate_algorithms, generate_batched_algorithms,
                      is_batched_kernel, kernel_batch_dims, slice_call_bytes,
                      validate_algorithms)
from .parametric import (ParametricModel, ParametricModels, SignatureKey,
                         cost_exponents, key_at, signature_dims,
                         signature_of, size_point)
from .predictor import (ContractionPredictor, ContractionSizeSweep,
                        RankedContraction, rank_contraction_sweep)
from .session import PredictorSession, warn_deprecated_kwargs
from .suite import (COLD, WARM, MicroBenchmark, MicroBenchmarkKey,
                    MicroBenchmarkSuite, benchmark_key, canonical_equation)

__all__ = [
    "BATCH_SUFFIX", "BATCHABLE_KERNELS", "base_kernel",
    "generate_algorithms", "generate_batched_algorithms",
    "is_batched_kernel", "kernel_batch_dims", "slice_call_bytes",
    "validate_algorithms",
    "ContractionPredictor", "ContractionSizeSweep", "RankedContraction",
    "rank_contraction_sweep",
    "COLD", "WARM", "MicroBenchmark", "MicroBenchmarkKey",
    "MicroBenchmarkSuite", "benchmark_key", "canonical_equation",
    "MAX_OPERANDS", "ChainPath", "ChainPredictor", "ChainSizeSweep",
    "ChainSpec", "ChainStep", "RankedChain", "compose_chain_runtime",
    "execute_chain", "execute_chain_reference", "execute_path_reference",
    "rank_einsum_sweep", "validate_paths",
    "PredictorSession", "warn_deprecated_kwargs",
    "ParametricModel", "ParametricModels", "SignatureKey",
    "cost_exponents", "key_at", "signature_dims", "signature_of",
    "size_point",
    "DEVICE_KERNELS", "DeviceRanked", "DeviceSuite", "device_key",
    "vmem_class", "RESIDENT", "TIGHT",
]

#: the device measurement facet (:mod:`repro.tc.device`) imports the
#: Pallas kernels — and therefore jax — at module load, so its names are
#: re-exported lazily: ``import repro.tc`` stays numpy-light and only a
#: first device-facet access pays the jax import.
_DEVICE_EXPORTS = frozenset({
    "DEVICE_KERNELS", "DeviceRanked", "DeviceSuite", "device_key",
    "vmem_class", "RESIDENT", "TIGHT"})


def __getattr__(name):
    if name in _DEVICE_EXPORTS:
        from . import device
        return getattr(device, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
