"""Batched-kernel extension of the §6.1 contraction-algorithm generator.

The paper's §6.1 kernels are plain BLAS calls, so any output index that is
not a kernel dimension — in particular every batch index shared by A, B and
C — can only become a loop index.  Modern BLAS-like libraries (and XLA)
provide *batched* kernels: one call evaluating a whole stack of
gemms/gemvs/dots.  This generator promotes them to first-class §6.1
kernels: on top of a base kernel pattern, a nonempty subset of the
remaining output indices is absorbed into the kernel call as batch
dimensions (broadcasting the operand that lacks them), e.g.
``bij,bjk->bik`` executed as ONE batched matmul, or with ``b`` batched
inside a ``bij,bj->bi`` batched gemv while ``k`` stays a loop index.

The absorbed indices simply join ``kernel_dims``, so the existing
:class:`ContractionAlgorithm` machinery — ``kernel_equation``/``execute``
(the kernel is the einsum over the kernel dims), ``kernel_flops`` (2x the
product of all kernel-dim extents) and ``access_distance`` (a walk over
the remaining loops) — handles the new kernel class unchanged; batched
algorithms are distinguished by the ``_batch`` kernel-name suffix.
Algorithms whose kernel equation and loop order coincide with an already
generated one (a batched gemv over the full free range *is* a gemm) are
dropped, and :func:`validate_algorithms` checks every survivor against
``execute_reference``.
"""

from __future__ import annotations

import itertools
import math
from typing import List, Mapping, Optional, Sequence, Tuple

import numpy as np

from ..core.contractions import (ContractionAlgorithm, ContractionSpec,
                                 _ITEM, _KERNEL_PATTERNS, execute,
                                 execute_reference)
from ..core.contractions import generate_algorithms as generate_loop_algorithms

#: kernel-name suffix marking the batched-kernel class
BATCH_SUFFIX = "_batch"

#: base kernels that have a batched counterpart (batched gemm/gemv analogues)
BATCHABLE_KERNELS = ("gemm", "gemv", "gevm", "dot")


def is_batched_kernel(kernel: str) -> bool:
    """Whether ``kernel`` belongs to the batched-kernel class (name carries
    the ``_batch`` suffix, e.g. ``"gemm_batch"``)."""
    return kernel.endswith(BATCH_SUFFIX)


def base_kernel(kernel: str) -> str:
    """The plain-BLAS kernel a (possibly batched) kernel is built on."""
    return kernel[:-len(BATCH_SUFFIX)] if is_batched_kernel(kernel) else kernel


def kernel_batch_dims(alg: ContractionAlgorithm) -> Tuple[str, ...]:
    """The kernel dims ``alg`` absorbed as batch dimensions.

    Empty for plain kernels.  For batched kernels this relies on the
    generator's layout contract: ``kernel_dims`` is always the base
    pattern's dims (free-A, free-B, contracted — their count fixed by
    :data:`_KERNEL_PATTERNS`) followed by the absorbed output indices, so
    the batch dims are exactly the tail beyond the base pattern's arity.
    """
    if not is_batched_kernel(alg.kernel):
        return ()
    nfa, nfb, nc = _KERNEL_PATTERNS[base_kernel(alg.kernel)]
    return alg.kernel_dims[nfa + nfb + nc:]


def slice_call_bytes(alg: ContractionAlgorithm,
                     sizes: Mapping[str, int]) -> int:
    """Bytes one *batch slice* of a kernel call touches.

    A batched kernel walks its batch dimensions slice by slice — strided
    access where at any instant the cache holds one slice's working set,
    not the whole stacked operands.  The footprint relevant for cache
    classification is therefore the per-slice call bytes: each operand
    contributes the product of its non-batch kernel-dim extents (operands
    that lack a batch dim are broadcast, i.e. shared by every slice, and
    contribute their full kernel footprint).  For plain kernels this
    equals the whole call's bytes.
    """
    batch = set(kernel_batch_dims(alg))
    spec = alg.spec
    total = 0
    for idx in (spec.a_idx, spec.b_idx, spec.out_idx):
        dims = [i for i in idx if i in alg.kernel_dims and i not in batch]
        total += math.prod(sizes[i] for i in dims)
    return _ITEM * total


def generate_batched_algorithms(
        spec: ContractionSpec, *,
        kernels: Sequence[str] = BATCHABLE_KERNELS,
        max_loop_perms: int = 24,
        existing: Sequence[ContractionAlgorithm] = (),
) -> List[ContractionAlgorithm]:
    """Enumerate batched-kernel decompositions of ``spec``.

    For every base kernel pattern, choose kernel indices exactly as the
    loop-only generator does, then absorb each nonempty subset of the
    remaining *output* indices into the kernel as batch dimensions (an
    index summed over cannot batch — it would change the result).  The
    rest stay loop indices.  Candidates operationally identical to one in
    ``existing`` or generated earlier — same kernel equation AND same loop
    order — are dropped.
    """
    contracted = set(spec.contracted)
    batch = set(spec.batch)
    free_a = [i for i in spec.a_idx if i not in contracted and i not in batch]
    free_b = [i for i in spec.b_idx if i not in contracted and i not in batch]
    seen = {(a.kernel_equation(), a.loop_order) for a in existing}
    algs: List[ContractionAlgorithm] = []
    for kernel in kernels:
        nfa, nfb, nc = _KERNEL_PATTERNS[kernel]
        for ka in itertools.combinations(free_a, nfa):
            for kb in itertools.combinations(free_b, nfb):
                for kc in itertools.combinations(sorted(contracted), nc):
                    base_dims = tuple(ka) + tuple(kb) + tuple(kc)
                    pool = [i for i in spec.out_idx if i not in base_dims]
                    for r in range(1, len(pool) + 1):
                        for bd in itertools.combinations(pool, r):
                            kdims = base_dims + bd
                            loops = [i for i in spec.all_indices
                                     if i not in kdims]
                            perms = list(itertools.permutations(loops))
                            if len(perms) > max_loop_perms:
                                perms = perms[:max_loop_perms]
                            for order in perms:
                                alg = ContractionAlgorithm(
                                    spec, kernel + BATCH_SUFFIX, kdims, order)
                                key = (alg.kernel_equation(), order)
                                if key in seen:
                                    continue
                                seen.add(key)
                                algs.append(alg)
    return algs


def generate_algorithms(spec: ContractionSpec, *,
                        include_batched: bool = True,
                        max_loop_perms: int = 24,
                        batched_kernels: Sequence[str] = BATCHABLE_KERNELS,
                        ) -> List[ContractionAlgorithm]:
    """All loop/kernel decompositions, batched-kernel class included.

    The superset of the core §6.1 generator: its loop-only algorithms plus
    (unless ``include_batched=False``) the batched-kernel algorithms of
    :func:`generate_batched_algorithms`, deduplicated against them.
    """
    algs = generate_loop_algorithms(spec, max_loop_perms=max_loop_perms)
    if include_batched:
        algs = algs + generate_batched_algorithms(
            spec, kernels=batched_kernels, max_loop_perms=max_loop_perms,
            existing=algs)
    return algs


def validate_algorithms(spec: ContractionSpec,
                        algorithms: Sequence[ContractionAlgorithm],
                        sizes: Mapping[str, int], *,
                        rng: Optional[np.random.Generator] = None,
                        rtol: float = 2e-4, atol: float = 2e-4) -> None:
    """Execute every algorithm on random operands against the einsum
    reference; raises ``AssertionError`` naming the mismatches."""
    rng = rng or np.random.default_rng(0)
    A = rng.standard_normal([sizes[i] for i in spec.a_idx]).astype(np.float32)
    B = rng.standard_normal([sizes[i] for i in spec.b_idx]).astype(np.float32)
    ref = execute_reference(spec, A, B)
    bad = []
    for alg in algorithms:
        got = execute(alg, A, B, sizes)
        if not np.allclose(got, ref, rtol=rtol, atol=atol):
            bad.append(alg.name)
    if bad:
        raise AssertionError(
            f"{len(bad)}/{len(algorithms)} algorithms disagree with "
            f"execute_reference for {spec.einsum_expr()}: {bad}")
