"""Device-resident measurement of the repo's own Pallas kernels.

The suite's micro-benchmarks were einsum-only: every measured key came
from the §6.2 cache-aware protocol over numpy contractions, and the
Pallas tile tuner (:mod:`repro.perf.tile_tuner`) ranked tile candidates
with napkin constants instead of measurements.  This module extends the
:class:`~repro.tc.suite.MicroBenchmarkSuite` with a *device kernel
family*: the repo's own Pallas kernels (``kernels/matmul.py`` (bm, bn,
bk) tiles, ``flash_attention.py`` (bq, bkv) blocks, ``ssd.py`` chunk
lengths), keyed by (kernel name, tile config, VMEM class) via the key's
``config`` facet — deduplicated and cost-accounted exactly like einsum
keys.

**Measurement protocol** (see ``docs/device-measurement.md``): each tile
config is timed on its canonical *proxy problem* (a few grid steps per
grid dimension — :func:`repro.kernels.matmul.proxy_problem` and
friends), so the measured quantity is a per-grid-step kernel cost; a
full problem's compute term is that cost scaled by the problem's grid
step count — the paper's measure-the-kernel / predict-the-blocked-
algorithm split (§4.6) transplanted to BlockSpec tiles.  The sweep is
*device-resident*: per-config calls chain their device-scalar witnesses
through a donated accumulator token (a data dependency that both
serializes the configs on the device queue and prevents XLA from
eliding repeated work), no per-config host round-trips happen inside
the loop, and exactly ONE sanctioned ``block_until_ready`` drains the
queue at sweep end — enforced by reprolint's host-sync checker, whose
``HOT_PATHS`` table lists :meth:`DeviceSuite._sweep`.

**Transfer terms**: predictions decompose as ``T_total = T_h2d +
T_compute + T_d2h`` with per-direction bandwidth + fixed-overhead
models fitted by :mod:`repro.core.transfer` from a small memcpy
micro-benchmark (asymmetric directions, like the reference SUMMA WSE
decomposition's ~3x D2H penalty).

Fitted per-(kernel, VMEM class) config models and the transfer models
export to one :class:`~repro.core.model.ModelSet` that a
:class:`repro.store.ModelStore` persists under its reserved
``__device__`` name; a warm-started session ranks tile candidates with
zero fresh measurements.
"""

from __future__ import annotations

import time
import warnings
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..core.fitting import Polynomial, fit_relative, monomial_basis
from ..core.grids import Domain
from ..core.model import (CaseModel, ModelSet, PerformanceModel, Piece)
from ..core.sampler import STATS, Stats
from ..core.transfer import (D2H, H2D, TransferModel, measure_transfers)
from ..kernels.flash_attention import (attn_grid_steps, attn_proxy_problem,
                                       attn_vmem_bytes, flash_attention)
from ..kernels.matmul import grid_steps as matmul_grid_steps
from ..kernels.matmul import matmul, proxy_problem
from ..kernels.matmul import vmem_bytes as matmul_vmem_bytes
from ..kernels.ssd import ssd, ssd_grid_steps, ssd_proxy_problem, ssd_vmem_bytes
from .suite import MicroBenchmark, MicroBenchmarkKey, MicroBenchmarkSuite

#: VMEM classes a device kernel key is measured under — the TPU-memory
#: analogue of the einsum keys' warm/cold cache classes.  A config whose
#: working set leaves double-buffering headroom (<= half of VMEM) is
#: RESIDENT; one that claims more is TIGHT, and its pipeline behaves
#: measurably differently — so the two must not share measurements.
VMEM_LIMIT = 16 * 2 ** 20
#: the two VMEM classes: double-buffering headroom vs a tight pipeline
RESIDENT, TIGHT = "vmem_resident", "vmem_tight"

#: model-set case tags (mirrors tc.parametric's percall/first split)
_PERCALL, _FIRST = "percall", "first"
_TRANSFER_CASE = ("transfer",)
_VALUE_FLOOR = 1e-12       # relative fits need strictly positive values


def vmem_class(working_set_bytes: int,
               vmem_limit: int = VMEM_LIMIT) -> str:
    """The VMEM class of one grid step's working set."""
    return RESIDENT if working_set_bytes <= vmem_limit // 2 else TIGHT


# --------------------------------------------------------------- registry --
class _MatmulDevice:
    """(bm, bn, bk) tiles of the Pallas matmul (``kernels/matmul.py``)."""

    name = "pallas_matmul"
    config_dims = ("bm", "bn", "bk")

    def vmem_bytes(self, cfg: Tuple[int, ...]) -> int:
        return matmul_vmem_bytes(*cfg)

    def proxy(self, cfg, steps_per_dim: int) -> Tuple[int, ...]:
        return proxy_problem(*cfg, steps_per_dim=steps_per_dim)

    def proxy_steps(self, cfg, steps_per_dim: int) -> int:
        return steps_per_dim ** 3

    def steps(self, problem, cfg) -> int:
        return matmul_grid_steps(*problem, *cfg)

    def operand_shapes(self, problem):
        m, n, k = problem
        return (m, k), (k, n), (m, n)

    def operands(self, problem, rng):
        a_sh, b_sh, _ = self.operand_shapes(problem)
        return (rng.standard_normal(a_sh).astype(np.float32),
                rng.standard_normal(b_sh).astype(np.float32))

    def bind(self, cfg, interpret: bool):
        bm, bn, bk = cfg
        return lambda x, y: matmul(x, y, bm=bm, bn=bn, bk=bk,
                                   interpret=interpret)

    def transfer_bytes(self, problem, itemsize: int = 4):
        m, n, k = problem
        return itemsize * (m * k + k * n), itemsize * m * n


class _FlashAttentionDevice:
    """(bq, bkv, d) blocks of the flash-attention kernel.  The head dim
    rides in the config: it is a static shape parameter of every block,
    so two head dims are two distinct kernel configurations."""

    name = "flash_attention"
    config_dims = ("bq", "bkv", "d")

    def vmem_bytes(self, cfg) -> int:
        return attn_vmem_bytes(*cfg)

    def proxy(self, cfg, steps_per_dim: int):
        return attn_proxy_problem(*cfg, steps_per_dim=steps_per_dim)

    def proxy_steps(self, cfg, steps_per_dim: int) -> int:
        return steps_per_dim ** 2

    def steps(self, problem, cfg) -> int:
        b, h, sq, skv, d = problem
        assert d == cfg[2], (d, cfg)
        return attn_grid_steps(b, h, sq, skv, cfg[0], cfg[1])

    def operand_shapes(self, problem):
        b, h, sq, skv, d = problem
        return (b, h, sq, d), (b, h, skv, d), (b, h, sq, d)

    def operands(self, problem, rng):
        q_sh, kv_sh, _ = self.operand_shapes(problem)
        q = rng.standard_normal(q_sh).astype(np.float32)
        k = rng.standard_normal(kv_sh).astype(np.float32)
        v = rng.standard_normal(kv_sh).astype(np.float32)
        return q, k, v

    def bind(self, cfg, interpret: bool):
        bq, bkv, _ = cfg
        return lambda q, k, v: flash_attention(q, k, v, bq=bq, bkv=bkv,
                                               interpret=interpret)

    def transfer_bytes(self, problem, itemsize: int = 4):
        q_sh, kv_sh, o_sh = self.operand_shapes(problem)
        nin = int(np.prod(q_sh)) + 2 * int(np.prod(kv_sh))
        return itemsize * nin, itemsize * int(np.prod(o_sh))


class _SsdDevice:
    """(chunk, P, N) configs of the Mamba-2 SSD chunked kernel."""

    name = "pallas_ssd"
    config_dims = ("chunk", "p", "n")

    def vmem_bytes(self, cfg) -> int:
        return ssd_vmem_bytes(*cfg)

    def proxy(self, cfg, steps_per_dim: int):
        return ssd_proxy_problem(*cfg, steps_per_dim=steps_per_dim)

    def proxy_steps(self, cfg, steps_per_dim: int) -> int:
        return steps_per_dim

    def steps(self, problem, cfg) -> int:
        b, l, h, p, g, n = problem
        assert (p, n) == (cfg[1], cfg[2]), (problem, cfg)
        return ssd_grid_steps(b, l, h, cfg[0])

    def operand_shapes(self, problem):
        b, l, h, p, g, n = problem
        return (b, l, h, p), (b, l, g, n), (b, l, h, p)

    def operands(self, problem, rng):
        b, l, h, p, g, n = problem
        x = rng.standard_normal((b, l, h, p)).astype(np.float32)
        dt = np.full((b, l, h), 1e-3, dtype=np.float32)
        a_log = np.zeros((h,), dtype=np.float32)
        bb = rng.standard_normal((b, l, g, n)).astype(np.float32)
        cc = rng.standard_normal((b, l, g, n)).astype(np.float32)
        return x, dt, a_log, bb, cc

    def bind(self, cfg, interpret: bool):
        chunk = cfg[0]
        return lambda x, dt, a_log, b, c: ssd(x, dt, a_log, b, c,
                                              chunk=chunk,
                                              interpret=interpret)

    def transfer_bytes(self, problem, itemsize: int = 4):
        b, l, h, p, g, n = problem
        nin = b * l * h * p + b * l * h + h + 2 * b * l * g * n
        return itemsize * nin, itemsize * b * l * h * p


#: the device kernel registry: name -> adapter
DEVICE_KERNELS = {k.name: k for k in (_MatmulDevice(),
                                      _FlashAttentionDevice(),
                                      _SsdDevice())}


def device_key(kernel_name: str, config: Sequence[int], *,
               steps_per_dim: int = 2,
               vmem_limit: int = VMEM_LIMIT) -> MicroBenchmarkKey:
    """The suite key of one (kernel, tile config, VMEM class) benchmark.

    The operand shapes are the config's canonical *proxy problem*
    operands, so the key — like every einsum key — fully reconstructs
    its measurement; two problems tuned at the same config share one
    key, which is what makes warm-store tile ranking measurement-free
    across problem sizes.
    """
    kernel = DEVICE_KERNELS[kernel_name]
    config = tuple(int(c) for c in config)
    problem = kernel.proxy(config, steps_per_dim)
    a_sh, b_sh, o_sh = kernel.operand_shapes(problem)
    cls = vmem_class(kernel.vmem_bytes(config), vmem_limit)
    return MicroBenchmarkKey(equation=kernel_name, a_shape=tuple(a_sh),
                             b_shape=tuple(b_sh), out_shape=tuple(o_sh),
                             classes=(cls, cls), config=config)


@dataclass(frozen=True)
class DeviceRanked:
    """One ranked tile config with its transfer/compute decomposition."""

    config: Tuple[int, ...]
    t_total: float             # T_h2d + T_compute + T_d2h (seconds)
    t_h2d: float
    t_compute: float
    t_d2h: float
    per_step_s: float          # measured/modeled per-grid-step kernel cost
    source: str                # "measured" | "model"


class DeviceSuite:
    """Device-resident sweeps + measured tile models over one shared suite.

    Wraps a :class:`~repro.tc.suite.MicroBenchmarkSuite`: device kernel
    measurements land in ``suite.results`` with ordinary "measured"
    provenance and wall-clock cost accounting, so store persistence,
    warm starts and the ``measured == 0`` zero-fresh-measurement proof
    work unchanged.  ``interpret=None`` auto-gates: interpret mode
    everywhere except a real TPU backend (the CI smoke lane runs
    interpret-only).  ``passes`` defaults to the suite's repetition
    protocol; ``transfer_measure_fn`` injects a synthetic memcpy probe
    (tests fit against known constants).
    """

    def __init__(self, suite: MicroBenchmarkSuite, *,
                 interpret: Optional[bool] = None,
                 vmem_limit: int = VMEM_LIMIT,
                 steps_per_dim: int = 2,
                 passes: Optional[int] = None,
                 transfer_measure_fn=None,
                 transfer_repetitions: int = 5,
                 sweep_fn=None):
        if interpret is None:
            import jax
            interpret = jax.default_backend() != "tpu"
        self.suite = suite
        self.interpret = bool(interpret)
        self.vmem_limit = vmem_limit
        self.steps_per_dim = steps_per_dim
        self.passes = suite.repetitions if passes is None else passes
        self.transfer_measure_fn = transfer_measure_fn
        self.transfer_repetitions = transfer_repetitions
        #: injectable sweep backend: (kernel_name, configs) -> {config:
        #: (Stats, first, seconds)}.  Tests inject a deterministic one;
        #: the default is the real device-resident loop.
        self.sweep_fn = sweep_fn or self._sweep
        self._transfer: Optional[Tuple[TransferModel, TransferModel]] = None
        #: (kernel, classes) -> {"percall": CaseModel, "first": Polynomial}
        #: loaded from a store's ``__device__`` model set
        self._loaded: Dict[Tuple[str, Tuple[str, str]], Dict] = {}

    # -------------------------------------------------------------- keys --
    def key(self, kernel_name: str,
            config: Sequence[int]) -> MicroBenchmarkKey:
        return device_key(kernel_name, config,
                          steps_per_dim=self.steps_per_dim,
                          vmem_limit=self.vmem_limit)

    # ------------------------------------------------------- measurement --
    def measure_grid(self, kernel_name: str,
                     configs: Sequence[Sequence[int]],
                     ) -> Dict[Tuple[int, ...], MicroBenchmark]:
        """Measured benchmarks for every config, deduplicated.

        Only configs whose key the suite does not already hold enter the
        device-resident sweep; the rest are served from ``results`` like
        any shared einsum key.
        """
        configs = [tuple(int(c) for c in cfg) for cfg in configs]
        missing = []
        seen = set()
        for cfg in configs:
            if cfg in seen:
                continue
            seen.add(cfg)
            if self.key(kernel_name, cfg) not in self.suite.results:
                missing.append(cfg)
        if missing:
            for cfg, (stats, first, seconds) in self.sweep_fn(
                    kernel_name, missing).items():
                self.suite.record_measurement(self.key(kernel_name, cfg),
                                              stats, first, seconds)
        return {cfg: self.suite.results[self.key(kernel_name, cfg)]
                for cfg in configs}

    def _sweep(self, kernel_name: str,
               configs: Sequence[Tuple[int, ...]]) -> Dict:
        """The device-resident measurement loop (reprolint hot path).

        Per config: jit-compile the kernel on its proxy problem with the
        accumulator token donated, run one untimed-for-stats warmup
        dispatch (its wall-clock — compile-dominated — is the first-call
        overhead), then ``passes`` timed dispatches.  Configs chain
        through the token (each call adds a witness scalar of the
        previous output), so the device executes them serially and no
        repetition can be elided; the host only *enqueues* inside the
        loop.  Exactly one sanctioned sync drains the queue at sweep
        end; the drained tail is redistributed over the samples
        proportionally, keeping totals exact on asynchronous backends
        (on the CPU/interpret CI platform dispatch is effectively
        synchronous and the tail is ~0).
        """
        import jax
        import jax.numpy as jnp

        kernel = DEVICE_KERNELS[kernel_name]
        t_start = time.perf_counter()
        rng = np.random.default_rng(self.suite.seed)
        runners = []
        for cfg in configs:
            problem = kernel.proxy(cfg, self.steps_per_dim)
            ops = tuple(jnp.asarray(o)
                        for o in kernel.operands(problem, rng))
            call = kernel.bind(cfg, self.interpret)

            def chain(token, *operands, _call=call):
                out = _call(*operands)
                return token + out.ravel()[0].astype(jnp.float32)

            runners.append((cfg, jax.jit(chain, donate_argnums=(0,)), ops))

        with warnings.catch_warnings():
            # CPU/interpret backends warn that donated buffers went
            # unused — expected off-accelerator, not actionable here
            warnings.filterwarnings("ignore", message=".*[Dd]onat")
            token = jnp.float32(0.0)
            firsts = {}
            for cfg, run, ops in runners:
                t0 = time.perf_counter()
                token = run(token, *ops)
                firsts[cfg] = time.perf_counter() - t0
            samples = {cfg: [] for cfg in configs}
            for _ in range(self.passes):
                for cfg, run, ops in runners:
                    t0 = time.perf_counter()
                    token = run(token, *ops)
                    samples[cfg].append(time.perf_counter() - t0)
            # the single sanctioned sweep-end sync: every chained dispatch
            # above is async; draining the queue once here is what makes
            # the per-config enqueue deltas a complete timing of the sweep
            jax.block_until_ready(token)  # reprolint: allow[host-sync]
        tail = time.perf_counter() - t_start - sum(firsts.values()) \
            - sum(s for v in samples.values() for s in v)
        sampled_total = sum(s for v in samples.values() for s in v)
        scale = 1.0 + max(tail, 0.0) / sampled_total \
            if sampled_total > 0 else 1.0
        wall = time.perf_counter() - t_start
        out = {}
        weights = {cfg: firsts[cfg] + sum(samples[cfg]) for cfg in configs}
        wtotal = sum(weights.values()) or 1.0
        for cfg in configs:
            per_call = [s * scale for s in samples[cfg]]
            out[cfg] = (Stats.from_samples(per_call), firsts[cfg],
                        wall * weights[cfg] / wtotal)
        return out

    # ---------------------------------------------------------- transfer --
    def transfer_models(self) -> Tuple[TransferModel, TransferModel]:
        """The (H2D, D2H) transfer models — measured once per suite (the
        memcpy probe's wall-clock lands in ``suite.cost_seconds``), or
        loaded from a store's ``__device__`` model set."""
        if self._transfer is None:
            h2d, d2h, cost = measure_transfers(
                measure_fn=self.transfer_measure_fn,
                repetitions=self.transfer_repetitions)
            self.suite.cost_seconds += cost
            self._transfer = (h2d, d2h)
        return self._transfer

    # ------------------------------------------------------------ ranking --
    def rank(self, kernel_name: str, problem: Sequence[int],
             configs: Sequence[Sequence[int]], *, stat: str = "med",
             transfer: bool = True, itemsize: int = 4,
             ) -> List[DeviceRanked]:
        """Rank tile configs for ``problem``, fastest-predicted first.

        Per config the total decomposes as ``T_h2d + T_compute +
        T_d2h``: per-grid-step kernel cost (measured, or predicted by a
        loaded ``__device__`` model — zero fresh measurements on a warm
        store) scaled to the problem's step count, plus one H2D
        transfer of the input operands and one D2H of the output.
        """
        kernel = DEVICE_KERNELS[kernel_name]
        problem = tuple(int(p) for p in problem)
        configs = [tuple(int(c) for c in cfg) for cfg in configs]
        est: Dict[Tuple[int, ...], Tuple[float, str]] = {}
        need = []
        for cfg in configs:
            key = self.key(kernel_name, cfg)
            mb = self.suite.results.get(key)
            if mb is not None:
                est[cfg] = (getattr(mb.stats, stat), "measured")
                continue
            pred = self._model_predict(kernel_name, key.classes, cfg, stat)
            if pred is not None:
                est[cfg] = (pred, "model")
            else:
                need.append(cfg)
        for cfg, mb in (self.measure_grid(kernel_name, need).items()
                        if need else ()):
            est[cfg] = (getattr(mb.stats, stat), "measured")
        t_h2d = t_d2h = 0.0
        if transfer:
            h2d, d2h = self.transfer_models()
            in_bytes, out_bytes = kernel.transfer_bytes(problem, itemsize)
            t_h2d, t_d2h = h2d.time(in_bytes), d2h.time(out_bytes)
        ranked = []
        for cfg in configs:
            per_call, source = est[cfg]
            per_step = per_call / kernel.proxy_steps(cfg,
                                                     self.steps_per_dim)
            t_compute = per_step * kernel.steps(problem, cfg)
            ranked.append(DeviceRanked(
                config=cfg, t_total=t_h2d + t_compute + t_d2h,
                t_h2d=t_h2d, t_compute=t_compute, t_d2h=t_d2h,
                per_step_s=per_step, source=source))
        ranked.sort(key=lambda r: (r.t_total, r.config))
        return ranked

    def _model_predict(self, kernel_name: str, classes: Tuple[str, str],
                       cfg: Tuple[int, ...],
                       stat: str) -> Optional[float]:
        entry = self._loaded.get((kernel_name, classes))
        if entry is None:
            return None
        piece = entry[_PERCALL].find_piece(cfg)
        if piece is None:
            return None               # outside the fitted config domain
        return piece.estimate(cfg)[stat]

    # -------------------------------------------------------- persistence --
    def to_model_set(self) -> ModelSet:
        """Measured device kernels + transfer models as one finalized
        :class:`ModelSet` — the payload of the store's ``__device__``
        name.  Per (kernel, VMEM classes): per-call-stat polynomials
        fitted over the measured config points (relative LS on the
        cost-bounded basis, §3.2.4) under case ``(classes, "percall")``,
        and a constant first-call fit under ``(classes, "first")`` whose
        piece domain records the fitted config bounding box.  Transfer
        models ride as ``memcpy_h2d`` / ``memcpy_d2h`` kernels.
        """
        groups: Dict[Tuple[str, Tuple[str, str]], List] = {}
        for key, mb in self.suite.results.items():
            if key.config is not None and key.equation in DEVICE_KERNELS:
                groups.setdefault((key.equation, key.classes),
                                  []).append((key.config, mb))
        ms = ModelSet()
        for (name, classes) in sorted(groups):
            entries = sorted(groups[(name, classes)], key=lambda e: e[0])
            points = np.asarray([cfg for cfg, _ in entries], float)
            ndim = points.shape[1]
            lo = tuple(float(v) for v in points.min(axis=0))
            hi = tuple(float(v) for v in points.max(axis=0))
            basis = monomial_basis(((1,) * ndim,))
            polys = {}
            for s in STATS:
                vals = np.maximum([getattr(mb.stats, s)
                                   for _, mb in entries], _VALUE_FLOOR)
                polys[s] = fit_relative(points, vals, basis)
            first_vals = np.maximum([mb.first for _, mb in entries],
                                    _VALUE_FLOOR)
            first_poly = fit_relative(points, first_vals, ((0,) * ndim,))
            if name not in ms:
                ms.add(PerformanceModel(kernel=name, setup="tc-device"))
            pm = ms[name]
            pm.add_piece((classes, _PERCALL),
                         Piece(domain=Domain(lo, hi), polys=polys))
            pm.add_piece((classes, _FIRST),
                         Piece(domain=Domain(lo, hi),
                               polys={s: first_poly for s in STATS}))
        if self._transfer is not None:
            for model in self._transfer:
                pm = PerformanceModel(kernel=f"memcpy_{model.direction}",
                                      setup="tc-device")
                pm.add_piece(_TRANSFER_CASE, model.to_piece())
                ms.add(pm)
        return ms.finalize()

    def load_model_set(self, ms: ModelSet) -> int:
        """Restore :meth:`to_model_set` output (a store warm start);
        returns how many (kernel, classes) config models were loaded.
        In-memory models win over loaded ones."""
        loaded = 0
        transfer: Dict[str, TransferModel] = {}
        for name, pm in ms.models.items():
            if name.startswith("memcpy_"):
                direction = name[len("memcpy_"):]
                piece = pm.cases[_TRANSFER_CASE].pieces[0]
                transfer[direction] = TransferModel.from_piece(direction,
                                                              piece)
                continue
            percall: Dict[Tuple[str, str], CaseModel] = {}
            first: Dict[Tuple[str, str], Polynomial] = {}
            for case, cm in pm.cases.items():
                classes, kind = case
                if kind == _PERCALL:
                    percall[tuple(classes)] = cm
                elif kind == _FIRST:
                    first[tuple(classes)] = cm.pieces[0].polys["med"]
            for classes, cm in percall.items():
                slot = (name, classes)
                if slot in self._loaded or classes not in first:
                    continue
                self._loaded[slot] = {_PERCALL: cm,
                                      _FIRST: first[classes]}
                loaded += 1
        if self._transfer is None and H2D in transfer and D2H in transfer:
            self._transfer = (transfer[H2D], transfer[D2H])
        return loaded
