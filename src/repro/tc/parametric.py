"""Size-parametric per-signature suite models with active refinement.

The suite's per-signature models were exact-shape: every distinct
(kernel equation, operand shapes, cache classes) key needed its own
micro-benchmark, so the suite was really "generated once per *shape*".
This module makes the paper's "generated once per *platform*" promise
real for the contraction stack (§3.2.5, §3.3; cf. arXiv:1409.8602 on
adaptively-sampled cache-aware models and arXiv:1409.8608 on parametric
per-cache-class kernel timings):

* a **signature** is a (canonical kernel equation, cache classes) pair —
  the shape-free part of a :class:`~repro.tc.suite.MicroBenchmarkKey`;
  its **size point** is the tuple of distinct index extents in order of
  first appearance (``ab,bc->ac`` at shapes (64, 32)x(32, 16) is the
  point ``(64, 32, 16)``);
* per signature, a piecewise polynomial over size points is fitted to
  per-call statistics with the seed's dormant adaptive-refinement loop
  (:func:`repro.core.refinement.refine`): sample a grid, bisect where
  the reference statistic's relative fit error exceeds the bound, stop
  at the target confidence (``error_bound``) or the measurement budget
  (``budget`` -> :attr:`~repro.core.refinement.GeneratorConfig.
  max_points`).  Which shapes get measured is thereby *driven by model
  uncertainty*, not by whichever grid a sweep happens to request;
* predictions inside a fitted domain synthesize a
  :class:`~repro.tc.suite.MicroBenchmark` (per-call stats from the
  containing piece, first-call overhead from a constant relative fit
  over the signature's measured points, ``seconds=0.0`` — predictions
  are free) which flows through the engine exactly like a measurement.
  Out-of-domain points return ``None`` and fall back to the exact-shape
  measurement path, which remains intact as the per-shape equivalence
  oracle (``benchmark_fresh`` / ``rank_oracle``).

The fitted models serialize into one :class:`~repro.core.model.ModelSet`
(cases ``(classes, "percall")`` and ``(classes, "first")`` per kernel
equation), which a :class:`repro.store.ModelStore` persists under its
reserved name — a warm-started session covers shapes it never saw.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np

from ..core.fitting import Exponents, Polynomial, fit_relative
from ..core.grids import Domain, Point
from ..core.model import CaseModel, ModelSet, PerformanceModel, Piece
from ..core.refinement import GeneratorConfig, refine
from ..core.sampler import STATS, Stats
from .suite import MicroBenchmark, MicroBenchmarkKey, MicroBenchmarkSuite

#: floor for first-call overheads entering the relative fit (a measured
#: first of exactly 0.0 — possible with injected measure_fns — would
#: make the relative least-squares system singular)
_FIRST_FLOOR = 1e-12


@dataclass(frozen=True)
class SignatureKey:
    """The shape-free identity of a suite signature.

    Two :class:`~repro.tc.suite.MicroBenchmarkKey`\\ s with equal
    ``SignatureKey`` differ only in operand sizes — exactly the axis the
    parametric models interpolate over.
    """

    equation: str                  # canonical kernel einsum, "ab,bc->ac"
    classes: Tuple[str, str]       # cache class of the inputs A, B


def signature_dims(equation: str) -> Tuple[str, ...]:
    """The equation's distinct indices in order of first appearance —
    the dimension order of every size point of that signature."""
    seen: List[str] = []
    ins, out = equation.split("->")
    a, b = ins.split(",")
    for ch in a + b + out:
        if ch not in seen:
            seen.append(ch)
    return tuple(seen)


def signature_of(key: MicroBenchmarkKey) -> SignatureKey:
    """The shape-free signature of a concrete benchmark key."""
    return SignatureKey(equation=key.equation, classes=key.classes)


def size_point(key: MicroBenchmarkKey) -> Point:
    """The key's operand sizes as a point over its signature's dims.

    Inverts :meth:`~repro.core.contractions.ContractionAlgorithm.
    kernel_shapes`: each equation index maps positionally onto the
    operand shapes; an index appearing in several operands must carry
    one consistent extent (keys built by ``benchmark_key`` always do).
    """
    ins, out = key.equation.split("->")
    a, b = ins.split(",")
    sizes: Dict[str, int] = {}
    for idx, shape in ((a, key.a_shape), (b, key.b_shape),
                       (out, key.out_shape)):
        if len(idx) != len(shape):
            raise ValueError(f"{key.equation}: index string {idx!r} does "
                             f"not match shape {shape}")
        for ch, n in zip(idx, shape):
            if sizes.setdefault(ch, n) != n:
                raise ValueError(f"{key.equation}: index {ch!r} has "
                                 f"inconsistent extents "
                                 f"{sizes[ch]} != {n}")
    return tuple(sizes[ch] for ch in signature_dims(key.equation))


def key_at(sig: SignatureKey, point: Sequence[int]) -> MicroBenchmarkKey:
    """The concrete benchmark key of ``sig`` at one size point — the
    inverse of :func:`size_point`, used to lower refinement sampling
    points into real (deduplicated) suite measurements."""
    dims = signature_dims(sig.equation)
    if len(point) != len(dims):
        raise ValueError(f"{sig.equation}: point {tuple(point)} has "
                         f"{len(point)} dims, signature has {len(dims)}")
    sizes = dict(zip(dims, (int(p) for p in point)))
    ins, out = sig.equation.split("->")
    a, b = ins.split(",")
    shape = lambda idx: tuple(sizes[ch] for ch in idx)  # noqa: E731
    return MicroBenchmarkKey(equation=sig.equation, a_shape=shape(a),
                             b_shape=shape(b), out_shape=shape(out),
                             classes=sig.classes)


def cost_exponents(equation: str) -> Tuple[Exponents, ...]:
    """Maximal monomial exponents bounding a kernel's cost (§3.2.4).

    One kernel call's flops are ``2 * prod(all kernel dims)`` and its
    traffic a sum of per-operand products — every term is dominated by
    the all-ones exponent tuple over the signature's dims.
    """
    return ((1,) * len(signature_dims(equation)),)


@dataclass
class ParametricModel:
    """One signature's fitted size-parametric model.

    ``case`` holds the refined per-call-statistic pieces over
    :attr:`domain`; ``first_poly`` is the constant relative fit of the
    first-call overhead over the signature's measured points (compile
    cost varies weakly with shape — a constant extrapolates safely
    where a full polynomial would not).  Predictions outside the fitted
    domain are refused (``None``): extrapolation falls back to the
    exact-shape measurement path instead of guessing.
    """

    sig: SignatureKey
    domain: Domain
    case: CaseModel
    first_poly: Polynomial
    n_refine_measured: int = 0

    def covers(self, point: Sequence[int]) -> bool:
        """Whether ``point`` lies inside a fitted piece's domain."""
        return self.case.find_piece(tuple(point)) is not None

    def predict(self, point: Sequence[int]) -> Optional[Tuple[Stats, float]]:
        """(per-call stats, first-call overhead) at ``point``, or
        ``None`` outside the fitted domain."""
        piece = self.case.find_piece(tuple(point))
        if piece is None:
            return None
        est = piece.estimate(tuple(point))
        first = max(float(self.first_poly(
            np.asarray(point, dtype=np.float64)[None, :])), 0.0)
        return Stats(**{s: est[s] for s in STATS}), first


class ParametricModels:
    """The per-suite registry of fitted size-parametric models.

    Hooked onto a :class:`~repro.tc.suite.MicroBenchmarkSuite` (its
    ``parametric`` attribute), it serves synthetic benchmarks for keys
    whose signature has a fitted model covering the key's size point;
    :meth:`ensure` fits (or refits on a widened domain) whatever a
    grid of upcoming keys needs, sampling through the suite's
    deduplicated ``measure_key`` path so refinement measurements are
    ordinary provenance-tracked suite results and pre-existing
    measurements pre-seed the refinement cache for free.

    ``error_bound`` is the target relative-confidence (maximum relative
    error of the reference statistic's fit on the sampled points) and
    ``budget`` the per-signature fresh-measurement cap — the two knobs
    :class:`~repro.tc.session.PredictorSession` exposes.
    """

    def __init__(self, suite: MicroBenchmarkSuite, *,
                 error_bound: float = 0.05,
                 budget: Optional[int] = 32,
                 reference_stat: str = "med",
                 overfit: int = 0, oversampling: int = 1,
                 grid: str = "cartesian", min_width: int = 8,
                 round_to: int = 8, max_pieces: int = 16):
        self.suite = suite
        self.error_bound = error_bound
        self.budget = budget
        # cheap refinement protocol: overfit 0 keeps the basis at the
        # cost-bounded monomials and oversampling 1 keeps root grids at
        # 3 points per *varying* dim (fixed dims collapse to one point);
        # the cartesian grid maximizes point reuse under bisection
        self.config = GeneratorConfig(
            overfit=overfit, oversampling=oversampling, grid=grid,
            reference_stat=reference_stat, error_kind="maximum",
            error_bound=error_bound, min_width=min_width,
            round_to=round_to, max_pieces=max_pieces, max_points=budget)
        self.models: Dict[SignatureKey, ParametricModel] = {}
        #: fresh measurements issued by refinement fits, total
        self.measured_points = 0

    # ------------------------------------------------------------ predict --
    @property
    def n_signatures(self) -> int:
        return len(self.models)

    def covers(self, key: MicroBenchmarkKey) -> bool:
        model = self.models.get(signature_of(key))
        return model is not None and model.covers(size_point(key))

    def predict(self, key: MicroBenchmarkKey) -> Optional[MicroBenchmark]:
        """A synthetic benchmark for ``key``, or ``None`` when no fitted
        model covers its size point (the caller measures instead).

        ``seconds=0.0``: a prediction costs no measurement wall-clock —
        which is the entire point.
        """
        model = self.models.get(signature_of(key))
        if model is None:
            return None
        pred = model.predict(size_point(key))
        if pred is None:
            return None
        stats, first = pred
        return MicroBenchmark(key=key, stats=stats, first=first,
                              seconds=0.0)

    # ---------------------------------------------------------------- fit --
    def ensure(self, keys: Iterable[MicroBenchmarkKey]) -> Dict[str, int]:
        """Fit whatever models the upcoming ``keys`` need (budgeted).

        Keys are grouped by signature; a signature needs (re)fitting only
        if some of its keys are neither measured already nor covered by
        an existing model.  A refit widens the domain to the bounding box
        of the requested points plus the existing model's domain (old
        coverage is never lost), pre-seeding refinement with every
        already-measured in-domain point.  Returns a summary:
        ``signatures_fitted`` / ``signatures_covered`` (no work needed) /
        ``measured`` (fresh measurements this call issued).
        """
        by_sig: Dict[SignatureKey, List[Point]] = {}
        for key in keys:
            by_sig.setdefault(signature_of(key), []).append(size_point(key))
        fitted = covered = 0
        measured_before = self.suite.measured
        for sig in sorted(by_sig, key=lambda s: (s.equation, s.classes)):
            points = sorted(set(by_sig[sig]))
            missing = [p for p in points
                       if key_at(sig, p) not in self.suite.results]
            model = self.models.get(sig)
            if not missing or (model is not None and
                               all(model.covers(p) for p in missing)):
                covered += 1
                continue
            self.models[sig] = self._fit(sig, points, model)
            self.suite.drop_predictions(sig)
            fitted += 1
        return {"signatures_fitted": fitted,
                "signatures_covered": covered,
                "measured": self.suite.measured - measured_before}

    def _fit(self, sig: SignatureKey, points: Sequence[Point],
             previous: Optional[ParametricModel]) -> ParametricModel:
        ndim = len(signature_dims(sig.equation))
        corners = list(points)
        if previous is not None:
            corners += [previous.domain.lo, previous.domain.hi]
        lo = tuple(min(p[d] for p in corners) for d in range(ndim))
        hi = tuple(max(p[d] for p in corners) for d in range(ndim))
        domain = Domain(lo, hi)
        known = {size_point(k): mb.stats
                 for k, mb in self.suite.results.items()
                 if signature_of(k) == sig
                 and domain.contains(size_point(k))}

        def sample(pts: Sequence[Point]) -> Dict[Point, Stats]:
            return {p: self.suite.measure_key(key_at(sig, p)).stats
                    for p in pts}

        measured_before = self.suite.measured
        pieces = refine(domain, sample, cost_exponents(sig.equation),
                        self.config, known=known)
        n_measured = self.suite.measured - measured_before
        self.measured_points += n_measured
        # first-call overhead: constant relative fit over every measured
        # in-domain point of this signature (refinement samples included)
        pts, firsts = [], []
        for k, mb in self.suite.results.items():
            if signature_of(k) != sig:
                continue
            p = size_point(k)
            if domain.contains(p):
                pts.append(p)
                firsts.append(max(mb.first, _FIRST_FLOOR))
        first_poly = fit_relative(np.asarray(pts, dtype=np.float64),
                                  np.asarray(firsts), ((0,) * ndim,))
        return ParametricModel(sig=sig, domain=domain,
                               case=CaseModel(pieces),
                               first_poly=first_poly,
                               n_refine_measured=n_measured)

    # ------------------------------------------------------- persistence --
    def to_model_set(self) -> ModelSet:
        """All fitted models as one finalized :class:`ModelSet`.

        Per signature: the refined per-call pieces under case
        ``(classes, "percall")`` and the first-call constant (replicated
        across the five statistic slots) under ``(classes, "first")``
        whose single piece's domain records the model's fitted domain.
        Round-trips bit-exactly through :class:`repro.store.ModelStore`
        JSON (``float.__repr__`` is shortest-round-trip).
        """
        ms = ModelSet()
        for sig in sorted(self.models, key=lambda s: (s.equation,
                                                      s.classes)):
            model = self.models[sig]
            if sig.equation not in ms:
                ms.add(PerformanceModel(kernel=sig.equation,
                                        setup="tc-parametric"))
            pm = ms[sig.equation]
            for piece in model.case.pieces:
                pm.add_piece((sig.classes, "percall"), piece)
            pm.add_piece((sig.classes, "first"),
                         Piece(domain=model.domain,
                               polys={s: model.first_poly for s in STATS}))
        return ms.finalize()

    def load_model_set(self, ms: ModelSet) -> int:
        """Restore fitted models from :meth:`to_model_set` output (e.g. a
        store warm start); returns how many signatures were loaded.
        Existing in-memory models win over loaded ones (they are at
        least as fresh)."""
        loaded = 0
        for equation, pm in ms.models.items():
            percall: Dict[Tuple[str, str], List[Piece]] = {}
            first: Dict[Tuple[str, str], Piece] = {}
            for case, cm in pm.cases.items():
                classes, kind = case
                if kind == "percall":
                    percall[tuple(classes)] = cm.pieces
                elif kind == "first":
                    first[tuple(classes)] = cm.pieces[0]
            for classes, pieces in percall.items():
                sig = SignatureKey(equation=equation, classes=classes)
                if sig in self.models or classes not in first:
                    continue
                anchor = first[classes]
                self.models[sig] = ParametricModel(
                    sig=sig, domain=anchor.domain,
                    case=CaseModel(list(pieces)),
                    first_poly=anchor.polys["med"])
                loaded += 1
        return loaded
