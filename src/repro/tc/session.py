"""One unified entry point for the prediction stack: `PredictorSession`.

The ranking entry points grew organically: every one of
``rank_contraction_algorithms`` / ``rank_einsum_paths`` /
``rank_contraction_sweep`` / ``rank_einsum_sweep`` /
``select_contraction_algorithm`` / ``select_einsum_path`` sprouted its own
``backend=`` / ``suite=`` / ``cache=`` / ``repetitions=`` / ``sizes_grid=``
keywords, and sharing measurements across calls meant threading the same
suite and trace cache through every call site by hand.

:class:`PredictorSession` replaces that sprawl with ONE object that owns
the four shared resources —

* the :class:`~repro.tc.suite.MicroBenchmarkSuite` (deduplicated
  cache-aware measurements, cost accounting),
* the :class:`~repro.core.predict.TraceCache` (compiled sweep batches),
* the evaluation **backend** (``"numpy"`` or ``"jax"``),
* the per-(spec, sizes) predictor instances themselves (so a repeated
  ranking reuses the compiled :class:`~repro.core.predict.CompiledCalls`
  batch, not just the measurements)

— and exposes every ranking/selection mode as a method.  Two sessions can
still share measurements by passing one session's ``suite``/``cache`` into
the other's constructor (e.g. a numpy and a jax session over one suite).

The legacy module-level call forms keep working for one release as thin
deprecation shims that construct a session internally (see
:func:`warn_deprecated_kwargs`); ``docs/architecture.md`` documents the
session as the single entry point, and the serving scheduler
(:mod:`repro.serve.scheduler`) builds its step-cost models exclusively
through a session.
"""

from __future__ import annotations

import warnings
from typing import Dict, List, Mapping, Optional, Sequence, Tuple, Union

from ..core.contractions import ContractionAlgorithm, ContractionSpec
from ..core.predict import TraceCache
from .chains import (ChainPredictor, ChainSizeSweep, ChainSpec, RankedChain,
                     rank_einsum_sweep)
from .parametric import ParametricModels
from .predictor import (ContractionPredictor, ContractionSizeSweep,
                        RankedContraction, rank_contraction_sweep)
from .suite import MicroBenchmarkSuite, resolve_suite


def warn_deprecated_kwargs(fn: str, replacement: str,
                           kwargs: Mapping[str, object], *,
                           stacklevel: int = 3) -> bool:
    """Emit ONE :class:`DeprecationWarning` for legacy resource kwargs.

    ``kwargs`` maps keyword names to the values the caller passed; every
    non-``None`` entry is deprecated.  Returns whether any were used, so
    the shim knows to route through an internally-constructed session.
    The warning names the replacement explicitly — these shims are
    scheduled for removal after one release.
    """
    used = [k for k, v in kwargs.items() if v is not None]
    if not used:
        return False
    warnings.warn(
        f"{fn}: the {', '.join(k + '=' for k in used)} keyword(s) are "
        f"deprecated; construct a repro.tc.PredictorSession and use "
        f"{replacement} instead (one release of shim support)",
        DeprecationWarning, stacklevel=stacklevel)
    return True


class PredictorSession:
    """Owns the shared prediction resources and every ranking entry point.

    ``backend`` fixes how compiled batches are evaluated (``"numpy"`` or
    ``"jax"``) for every method of this session; build a second session
    over the same ``suite``/``cache`` to compare backends without
    re-measuring or re-tracing.  ``repetitions`` configures a freshly
    built suite and conflicts with passing ``suite=`` (the suite owns its
    measurement protocol — see
    :func:`~repro.tc.suite.resolve_suite`).

    Predictors are memoized per (spec, sizes, candidate-set) signature:
    calling :meth:`rank_contraction_algorithms` twice with equal
    arguments reuses the first call's compiled batch outright.

    With ``parametric=True`` the session carries a
    :class:`~repro.tc.parametric.ParametricModels` registry hooked onto
    its suite: sweeps pre-fit size-parametric per-signature models with
    budgeted adaptive refinement (:meth:`refine_parametric`) and grid
    points inside a fitted domain are *predicted*, not measured.
    ``parametric_error_bound`` is the target relative confidence of a
    fit and ``parametric_budget`` the per-signature fresh-measurement
    cap.  A store warm start that holds fitted parametric models
    enables the registry automatically; a suite shared from another
    session brings its registry along.
    """

    def __init__(self, *, backend: str = "numpy",
                 suite: Optional[MicroBenchmarkSuite] = None,
                 cache: Optional[TraceCache] = None,
                 repetitions: Optional[int] = None,
                 store=None, allow_mismatch: bool = False,
                 parametric: bool = False,
                 parametric_error_bound: float = 0.05,
                 parametric_budget: Optional[int] = 32):
        self.backend = backend
        param_sets = None
        if store is not None:
            # warm start from a repro.store.ModelStore (object or path):
            # the store's measurement protocol builds the suite and every
            # stored measurement is pre-loaded, so rankings the store
            # covers need zero new micro-benchmarks.  Lazy import keeps
            # the dependency arrow store -> tc (never tc -> store at
            # module load).
            if suite is not None:
                raise ValueError(
                    "pass store= or suite=, not both: a warm-started "
                    "session builds its suite from the store")
            from ..store.modelstore import ModelStore
            if not isinstance(store, ModelStore):
                store = ModelStore.load(store,
                                        allow_mismatch=allow_mismatch)
            self.suite = store.build_suite(repetitions=repetitions)
            param_sets = store.parametric_model_set()
            if param_sets is not None:
                parametric = True
            self._device_models = store.device_model_set()
        else:
            self.suite = resolve_suite(suite, repetitions)
            self._device_models = None
        self.cache = cache if cache is not None else TraceCache()
        if parametric:
            if self.suite.parametric is not None:
                # a shared suite brings its registry along; the knobs
                # were fixed by whoever built it
                self.parametric = self.suite.parametric
            else:
                self.parametric = ParametricModels(
                    self.suite, error_bound=parametric_error_bound,
                    budget=parametric_budget)
                self.suite.parametric = self.parametric
            if param_sets is not None:
                self.parametric.load_model_set(param_sets)
        else:
            self.parametric = self.suite.parametric
        self._contraction: Dict[Tuple, ContractionPredictor] = {}
        self._chain: Dict[Tuple, ChainPredictor] = {}
        self._device = None

    # -------------------------------------------------------- predictors --
    def contraction_predictor(self, spec: Union[ContractionSpec, str],
                              sizes: Mapping[str, int], *,
                              algorithms: Optional[
                                  Sequence[ContractionAlgorithm]] = None,
                              include_batched: bool = True,
                              arrival: Optional[Mapping[str, str]] = None,
                              ) -> ContractionPredictor:
        """The (memoized) per-contraction predictor on this session's
        suite/cache.  Explicit ``algorithms`` bypass the memo — a custom
        candidate set is the caller's to manage."""
        spec = spec if isinstance(spec, ContractionSpec) else \
            ContractionSpec.parse(spec)
        if algorithms is not None:
            return ContractionPredictor(spec, sizes, algorithms=algorithms,
                                        include_batched=include_batched,
                                        suite=self.suite, cache=self.cache,
                                        arrival=arrival)
        key = (spec, tuple(sorted(sizes.items())), include_batched,
               tuple(sorted(arrival.items())) if arrival else None)
        pred = self._contraction.get(key)
        if pred is None:
            pred = ContractionPredictor(spec, sizes,
                                        include_batched=include_batched,
                                        suite=self.suite, cache=self.cache,
                                        arrival=arrival)
            self._contraction[key] = pred
        return pred

    def chain_predictor(self, chain, sizes: Mapping[str, int], *,
                        include_batched: bool = True,
                        kernels: Optional[Sequence[str]] = None,
                        max_loop_perms: int = 24,
                        memory_limit_bytes: Optional[int] = None,
                        ) -> ChainPredictor:
        """The (memoized) per-einsum chain predictor on this session's
        suite/cache."""
        chain = ChainSpec.parse(chain)
        key = (chain, tuple(sorted(sizes.items())), include_batched,
               tuple(kernels) if kernels is not None else None,
               max_loop_perms, memory_limit_bytes)
        pred = self._chain.get(key)
        if pred is None:
            pred = ChainPredictor(chain, sizes, suite=self.suite,
                                  cache=self.cache,
                                  include_batched=include_batched,
                                  kernels=kernels,
                                  max_loop_perms=max_loop_perms,
                                  memory_limit_bytes=memory_limit_bytes)
            self._chain[key] = pred
        return pred

    # ---------------------------------------------------- contractions --
    def rank_contraction_algorithms(
            self, spec: Union[ContractionSpec, str],
            sizes: Mapping[str, int], *, stat: str = "med",
            algorithms: Optional[Sequence[ContractionAlgorithm]] = None,
            include_batched: bool = True,
            arrival: Optional[Mapping[str, str]] = None,
            ) -> List[RankedContraction]:
        """All candidate algorithms fastest-predicted first (Ch. 6) as
        :class:`~repro.tc.predictor.RankedContraction` records."""
        pred = self.contraction_predictor(spec, sizes,
                                          algorithms=algorithms,
                                          include_batched=include_batched,
                                          arrival=arrival)
        return pred.rank(stat=stat, backend=self.backend)

    def select_contraction_algorithm(
            self, spec: Union[ContractionSpec, str],
            sizes: Mapping[str, int], *, stat: str = "med",
            include_batched: bool = True) -> str:
        """The fastest-predicted candidate's name —
        ``rank_contraction_algorithms(...)[0].name``."""
        return self.rank_contraction_algorithms(
            spec, sizes, stat=stat,
            include_batched=include_batched)[0].name

    def rank_contraction_sweep(
            self, spec: Union[ContractionSpec, str],
            sizes_grid: Sequence[Mapping[str, int]], *, stat: str = "med",
            algorithms: Optional[Sequence[ContractionAlgorithm]] = None,
            include_batched: bool = True,
            arrival: Optional[Mapping[str, str]] = None,
            ) -> ContractionSizeSweep:
        """Size-sweep autotuning on this session's shared suite: only
        genuinely new (equation, shapes, cache-class) keys are measured
        across the grid.  On a parametric session the grid's signatures
        are pre-fitted first (:meth:`refine_parametric`), so grid points
        inside a fitted domain are predicted without any measurement."""
        if self.parametric is not None:
            self.refine_parametric(spec, sizes_grid,
                                   algorithms=algorithms,
                                   include_batched=include_batched,
                                   arrival=arrival)
        # the sanctioned delegation site: the session IS the owner these
        # kwargs were deprecated in favor of
        # reprolint: allow[deprecated-kwarg]
        return rank_contraction_sweep(
            spec, sizes_grid, stat=stat, backend=self.backend,
            algorithms=algorithms, include_batched=include_batched,
            suite=self.suite, cache=self.cache, arrival=arrival)

    # ----------------------------------------------------------- chains --
    def rank_einsum_paths(self, chain, sizes: Mapping[str, int], *,
                          stat: str = "med",
                          include_batched: bool = True,
                          kernels: Optional[Sequence[str]] = None,
                          max_loop_perms: int = 24,
                          memory_limit_bytes: Optional[int] = None,
                          ) -> List[RankedChain]:
        """All pairwise contraction paths of an einsum, fastest-predicted
        chain total first, from this session's shared suite."""
        pred = self.chain_predictor(chain, sizes,
                                    include_batched=include_batched,
                                    kernels=kernels,
                                    max_loop_perms=max_loop_perms,
                                    memory_limit_bytes=memory_limit_bytes)
        return pred.rank_paths(stat=stat, backend=self.backend)

    def select_einsum_path(self, chain, sizes: Mapping[str, int], *,
                           stat: str = "med",
                           include_batched: bool = True) -> RankedChain:
        """The fastest-predicted path — ``rank_einsum_paths(...)[0]``."""
        return self.rank_einsum_paths(
            chain, sizes, stat=stat, include_batched=include_batched)[0]

    def rank_einsum_sweep(self, chain,
                          sizes_grid: Sequence[Mapping[str, int]], *,
                          stat: str = "med",
                          include_batched: bool = True,
                          kernels: Optional[Sequence[str]] = None,
                          max_loop_perms: int = 24,
                          memory_limit_bytes: Optional[int] = None,
                          ) -> ChainSizeSweep:
        """Chain-level size sweep from this session's shared suite.  On
        a parametric session the grid's step signatures are pre-fitted
        first (:meth:`refine_parametric`)."""
        if self.parametric is not None:
            self.refine_parametric(chain, sizes_grid,
                                   include_batched=include_batched,
                                   kernels=kernels,
                                   max_loop_perms=max_loop_perms,
                                   memory_limit_bytes=memory_limit_bytes)
        # the sanctioned delegation site: the session IS the owner these
        # kwargs were deprecated in favor of
        # reprolint: allow[deprecated-kwarg]
        return rank_einsum_sweep(
            chain, sizes_grid, stat=stat, backend=self.backend,
            suite=self.suite, cache=self.cache,
            include_batched=include_batched, kernels=kernels,
            max_loop_perms=max_loop_perms,
            memory_limit_bytes=memory_limit_bytes)

    # ------------------------------------------------------- parametric --
    def refine_parametric(self, spec,
                          sizes_grid: Sequence[Mapping[str, int]], *,
                          algorithms: Optional[
                              Sequence[ContractionAlgorithm]] = None,
                          include_batched: bool = True,
                          arrival: Optional[Mapping[str, str]] = None,
                          kernels: Optional[Sequence[str]] = None,
                          max_loop_perms: int = 24,
                          memory_limit_bytes: Optional[int] = None,
                          ) -> Dict[str, int]:
        """Fit size-parametric models for everything a sweep will need.

        The pre-pass enumerates every micro-benchmark key the grid's
        candidates map to (pure key arithmetic — nothing is measured),
        groups them by (canonical kernel equation, cache classes)
        signature, and fits a budgeted adaptive-refinement model per
        signature with unmeasured keys
        (:meth:`repro.tc.parametric.ParametricModels.ensure`): sampling
        happens where the fit's relative error is highest and stops at
        the session's ``parametric_error_bound`` or
        ``parametric_budget``.  ``spec`` may be a pairwise contraction
        or an N-operand einsum chain (the chain keywords apply only
        then).  Returns the ensure summary — ``signatures_fitted`` /
        ``signatures_covered`` / ``measured`` (fresh refinement
        measurements).  The exact-shape measurement path
        (``benchmark_fresh`` / ``rank_oracle``) stays intact as the
        per-shape oracle for these fits.
        """
        if self.parametric is None:
            raise ValueError(
                "parametric models are disabled: construct the session "
                "with parametric=True (or warm-start from a store "
                "holding fitted parametric models)")
        chain = isinstance(spec, ChainSpec) or (
            not isinstance(spec, ContractionSpec)
            and str(spec).split("->")[0].count(",") >= 2)
        keys = []
        for sizes in sizes_grid:
            if chain:
                pred = self.chain_predictor(
                    spec, sizes, include_batched=include_batched,
                    kernels=kernels, max_loop_perms=max_loop_perms,
                    memory_limit_bytes=memory_limit_bytes)
            else:
                pred = self.contraction_predictor(
                    spec, sizes, algorithms=algorithms,
                    include_batched=include_batched, arrival=arrival)
            keys.extend(pred.benchmark_keys())
        return self.parametric.ensure(keys)

    # ------------------------------------------------------------ device --
    def device_suite(self, **kwargs):
        """This session's device measurement facet — a
        :class:`repro.tc.device.DeviceSuite` over the shared suite
        (created lazily on first use; ``kwargs`` configure that first
        construction — ``interpret=``, ``passes=``,
        ``transfer_measure_fn=``, ...).  A store warm start that holds
        device models (:data:`repro.store.DEVICE_MODEL_SET`) pre-loads
        them, so tile rankings inside the fitted config domain take zero
        fresh measurements.
        """
        if self._device is None:
            from .device import DeviceSuite
            self._device = DeviceSuite(self.suite, **kwargs)
            if self._device_models is not None:
                self._device.load_model_set(self._device_models)
        elif kwargs:
            raise ValueError(
                "the session's device suite is already built; its "
                "configuration kwargs must go to the first device_suite "
                "call")
        return self._device

    def rank_device_tiles(self, kernel: str, problem: Sequence[int],
                          configs: Sequence[Sequence[int]], *,
                          stat: str = "med", transfer: bool = True,
                          itemsize: int = 4):
        """Rank Pallas tile configs for one problem from measured device
        models, fastest-predicted total first — each entry carries the
        ``T_h2d + T_compute + T_d2h`` decomposition (see
        :meth:`repro.tc.device.DeviceSuite.rank`).  ``kernel`` is a
        :data:`repro.tc.device.DEVICE_KERNELS` name."""
        return self.device_suite().rank(kernel, problem, configs,
                                        stat=stat, transfer=transfer,
                                        itemsize=itemsize)

    # ---------------------------------------------------------- serving --
    def step_cost_model(self, cfg, *, slots: int):
        """Measured per-tick cost model of a serve engine's step kernels.

        Lazy import: serving builds ON the prediction stack (the same
        direction every other layer reaches), the session merely fronts
        it.  See :func:`repro.serve.scheduler.build_step_cost_model`.
        """
        from ..serve.scheduler import build_step_cost_model
        return build_step_cost_model(self, cfg, slots=slots)

    def guided_scheduler(self, cfg, *, slots: int, **kwargs):
        """A :class:`repro.serve.scheduler.ModelGuidedScheduler` driven by
        this session's measured step-cost model (``kwargs`` forward to the
        scheduler constructor: ``window=``, ``max_defer=``, ...)."""
        from ..serve.scheduler import ModelGuidedScheduler
        return ModelGuidedScheduler(self.step_cost_model(cfg, slots=slots),
                                    **kwargs)

    # ------------------------------------------------------------ store --
    def save_store(self, path=None, *, fingerprint=None):
        """Capture this session's measurements (and every prepared
        per-contraction :class:`~repro.core.model.ModelSet`) into a
        :class:`repro.store.ModelStore`; write it to ``path`` if given.

        A session on another process warm-starts from the file via
        ``PredictorSession(store=path)`` and — measurements being the
        only input to the per-signature models — produces bit-identical
        rankings with zero new micro-benchmarks.  Fitted size-parametric
        models ride along under the store's reserved name, so the
        warm-started session also covers every *unmeasured* shape the
        fitted domains span (and re-enables ``parametric`` mode
        automatically).
        """
        from ..store.modelstore import ModelStore
        store = ModelStore.from_suite(self.suite, fingerprint=fingerprint)
        for key, pred in self._contraction.items():
            if pred._models is None:
                continue             # never ranked: nothing fitted to keep
            spec, sizes = key[0], key[1]
            name = f"{spec.einsum_expr()}|" + ",".join(
                f"{k}={v}" for k, v in sizes)
            store.add_model_set(name, pred.model_set)
        if self.parametric is not None and self.parametric.models:
            store.add_parametric_models(self.parametric)
        if self._device is not None:
            device_models = self._device.to_model_set()
            if device_models.models:
                store.add_device_models(device_models)
        if path is not None:
            store.save(path)
        return store

    def check_drift(self, *, max_keys: int = 8, threshold: float = 1.5,
                    refresh: bool = False, measure_fn=None):
        """Probe a deterministic subset of the suite's stored keys for
        platform drift (see :class:`repro.store.DriftProbe`).

        Warns (:class:`UserWarning`) when any probed key drifted beyond
        ``threshold``; with ``refresh=True`` the stale keys are
        re-measured in place (the suite's ``refreshed`` counter records
        the repairs).  Returns the probe's readings.
        """
        from ..store.drift import DriftProbe
        probe = DriftProbe(self.suite, max_keys=max_keys,
                           threshold=threshold, measure_fn=measure_fn)
        readings = probe.probe()
        stale = probe.stale()
        if stale:
            worst = max(stale, key=lambda r: max(r.ratio, 1 / r.ratio))
            warnings.warn(
                f"model drift: {len(stale)}/{len(readings)} probed "
                f"micro-benchmarks moved beyond {threshold}x (worst "
                f"ratio {worst.ratio:.2f} on {worst.key.equation} "
                f"{worst.key.a_shape}x{worst.key.b_shape}); "
                + ("stale keys refreshed in place" if refresh else
                   "re-measure with refresh=True or re-generate the "
                   "store"),
                UserWarning, stacklevel=2)
            if refresh:
                probe.refresh()
        return readings

    # ------------------------------------------------------------- cost --
    def counters(self) -> Dict[str, float]:
        """The shared suite's running totals plus trace-cache hit/miss
        counts — diff two snapshots to see what one phase added."""
        out = dict(self.suite.counters())
        out["trace_hits"] = self.cache.hits
        out["trace_misses"] = self.cache.misses
        return out
