"""Drift detection: is a stored platform model still this platform?

A :class:`~repro.store.modelstore.ModelStore` pins measurements to a
:class:`~repro.store.fingerprint.PlatformFingerprint`, but fingerprints
only catch *discrete* platform changes (new CPU, new jax, new dtype).
Thermal state, background load, frequency governors and library
micro-updates shift kernel timings without touching any fingerprint
field.  The :class:`DriftProbe` catches that continuous kind of
staleness: re-measure a small **deterministic** subset of the stored
keys, compare each fresh median against the stored one, and report the
per-key drift ratio.  Determinism matters — two runs on the same store
probe the same keys, so drift readings are comparable across CI runs and
the probe's cost is a fixed, budgetable quantity rather than a sample of
luck.

Policy (see ``docs/model-store.md``): a key is *stale* when its ratio
``probed_median / stored_median`` falls outside ``[1/threshold,
threshold]`` — both speedups and slowdowns are drift; a model that has
silently become pessimistic mis-ranks just as surely as one that became
optimistic.  ``PredictorSession.check_drift`` warns on any stale key and
can repair in place via :meth:`DriftProbe.refresh`, which re-measures
exactly the stale keys through the suite's ``refresh`` (counted under
the suite's ``refreshed`` counter, never inflating ``loaded``).
"""

from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Dict, List, Optional

from ..tc.suite import (MeasureFn, MicroBenchmark, MicroBenchmarkKey,
                        MicroBenchmarkSuite)
from .modelstore import sort_key


@dataclass(frozen=True)
class DriftReading:
    """One probed key: stored median vs freshly measured median."""

    key: MicroBenchmarkKey
    stored_med: float       # median the store remembers (seconds)
    probed_med: float       # median measured just now (seconds)
    probe_seconds: float    # wall-clock the probe measurement cost

    @property
    def ratio(self) -> float:
        """``probed / stored`` — 1.0 means the platform has not moved."""
        if self.stored_med == 0.0:
            return float("inf") if self.probed_med else 1.0
        return self.probed_med / self.stored_med

    def stale(self, threshold: float) -> bool:
        """Outside ``[1/threshold, threshold]``: drift in either
        direction invalidates the stored measurement."""
        r = self.ratio
        return not (1.0 / threshold <= r <= threshold)


class DriftProbe:
    """Re-measures a deterministic subset of a suite's stored keys.

    ``max_keys`` keys are chosen by evenly striding the canonically
    sorted key list (:func:`~repro.store.modelstore.sort_key`), so the
    subset spans small and large signatures instead of clustering at one
    end, and is identical across runs on the same store.  ``measure_fn``
    overrides the probe's measurement backend (tests inject a distorted
    one); by default the suite's own backend is used, so probe and
    stored measurements go through the same §6.2 protocol.

    The probe does **not** touch the suite's results or counters — it
    answers "has the platform moved?" without mutating the model.
    Repair is explicit: :meth:`refresh` re-measures the stale keys
    through ``suite.refresh``, replacing them in place.
    """

    def __init__(self, suite: MicroBenchmarkSuite, *, max_keys: int = 8,
                 threshold: float = 1.5,
                 measure_fn: Optional[MeasureFn] = None):
        if threshold <= 1.0:
            raise ValueError(f"threshold must exceed 1.0 (got {threshold}):"
                             f" it bounds the ratio band [1/t, t]")
        self.suite = suite
        self.max_keys = max_keys
        self.threshold = threshold
        self.measure_fn: MeasureFn = measure_fn or suite.measure_fn
        self.cost_seconds = 0.0
        self._readings: Optional[List[DriftReading]] = None

    def keys(self) -> List[MicroBenchmarkKey]:
        """The deterministic probe subset: evenly strided canonical order.

        Device kernel keys (``config`` facet set) are excluded: they are
        measured by :class:`repro.tc.device.DeviceSuite` sweeps, not the
        §6.2 einsum protocol behind ``measure_fn``, so probing one here
        would compare incomparable measurements (and ``suite.refresh``
        would refuse it)."""
        stored = sorted((k for k in self.suite.results
                         if k.config is None), key=sort_key)
        if len(stored) <= self.max_keys:
            return stored
        stride = len(stored) / self.max_keys
        return [stored[int(i * stride)] for i in range(self.max_keys)]

    def probe(self) -> List[DriftReading]:
        """Measure the probe subset once; cached on the probe instance."""
        if self._readings is not None:
            return self._readings
        readings = []
        for key in self.keys():
            t0 = time.perf_counter()
            stats, _first = self.measure_fn(key, self.suite.repetitions)
            seconds = time.perf_counter() - t0
            self.cost_seconds += seconds
            readings.append(DriftReading(
                key=key, stored_med=self.suite.results[key].stats.med,
                probed_med=stats.med, probe_seconds=seconds))
        self._readings = readings
        return readings

    def stale(self) -> List[DriftReading]:
        """The probed keys whose drift exceeds the threshold."""
        return [r for r in self.probe() if r.stale(self.threshold)]

    def max_ratio(self) -> float:
        """The worst drift seen, folded to >= 1 (1.0 = no drift)."""
        ratios = [max(r.ratio, 1.0 / r.ratio) if r.ratio > 0 else
                  float("inf") for r in self.probe()]
        return max(ratios, default=1.0)

    def refresh(self) -> List[MicroBenchmark]:
        """Re-measure every stale key in place through ``suite.refresh``.

        The suite's ``measure_fn`` is temporarily pointed at the probe's
        (they differ only when a test injected one), so the repaired
        measurement reflects the platform the probe actually saw.
        Returns the replacement measurements; the probe's cached
        readings are dropped so a subsequent :meth:`probe` re-examines
        the repaired state.
        """
        stale = self.stale()
        replaced = []
        original = self.suite.measure_fn
        self.suite.measure_fn = self.measure_fn
        try:
            for reading in stale:
                replaced.append(self.suite.refresh(reading.key))
        finally:
            self.suite.measure_fn = original
        self._readings = None
        return replaced

    def report(self) -> Dict[str, float]:
        """Summary counters for metrics emission."""
        readings = self.probe()
        return {"probed": float(len(readings)),
                "stale": float(len(self.stale())),
                "max_ratio": self.max_ratio(),
                "probe_cost_seconds": self.cost_seconds}
