"""repro.store — the persistent platform model store.

Three layers (see ``docs/model-store.md``):

* :mod:`repro.store.modelstore` — versioned on-disk persistence of
  micro-benchmark measurements and finalized model sets under a
  :mod:`platform fingerprint <repro.store.fingerprint>`;
* :mod:`repro.store.drift` — deterministic re-measurement probes that
  detect when a stored model has drifted off the platform;
* :mod:`repro.store.tournament` — named predictor snapshots scored
  against a measured oracle on frozen workloads.

``repro.tc`` never imports this package at module level (the session
lazy-imports it), so the dependency arrow stays ``store -> tc -> core``.
"""

from .drift import DriftProbe, DriftReading
from .fingerprint import PlatformFingerprint, current_fingerprint
from .modelstore import (DEVICE_MODEL_SET, PARAMETRIC_MODEL_SET,
                         SCHEMA_VERSION, ModelStore, StoreMismatchError)
from .tournament import (Snapshot, SnapshotScore, TournamentResult,
                         Workload, frozen_workloads, kendall_tau,
                         run_tournament, workload)

__all__ = [
    "DEVICE_MODEL_SET", "PARAMETRIC_MODEL_SET", "SCHEMA_VERSION",
    "ModelStore",
    "StoreMismatchError",
    "PlatformFingerprint", "current_fingerprint",
    "DriftProbe", "DriftReading",
    "Snapshot", "SnapshotScore", "TournamentResult", "Workload",
    "frozen_workloads", "kendall_tau", "run_tournament", "workload",
]
