"""Predictor tournaments: quantified accuracy-vs-cost scoreboards.

Every change to the prediction stack shifts a trade-off: measurement
protocol (repetitions, cache capacity), store freshness, backend, model
form.  The tournament harness makes that trade-off a number instead of a
hunch — it pits named predictor *snapshots* (a
:class:`~repro.store.modelstore.ModelStore` file plus session config)
against each other on **frozen workload suites** (the smoke specs from
``bench_contractions`` / ``bench_einsum_paths`` / ``bench_serving``, so
scores are comparable across commits) and scores each snapshot against a
freshly measured oracle session on four axes:

* **rel_err** — mean relative error of predicted medians vs the
  oracle's, matched per candidate: absolute accuracy;
* **top1_rate** — how often the snapshot's fastest-predicted candidate
  is the oracle's: what selection actually gets right;
* **rank_agreement** — mean Kendall-tau between snapshot and oracle
  orderings: rank agreement matters more than absolute error for
  selection (Peise & Bientinesi, arXiv:1409.8602);
* **suite_cost_seconds** — what the snapshot's measurements cost
  (including the amortized cost of loaded keys): accuracy per second.

The scoreboard is written as ``TOURNAMENT.json`` (stamped with the store
``SCHEMA_VERSION``) and its headline numbers are tracked across commits
by ``benchmarks/compare_smoke.py``.
"""

from __future__ import annotations

import json
from dataclasses import dataclass, field
from pathlib import Path
from typing import Dict, List, Mapping, Optional, Sequence, Tuple, Union

from ..tc.session import PredictorSession
from .modelstore import SCHEMA_VERSION, ModelStore


@dataclass(frozen=True)
class Workload:
    """One frozen ranking problem every snapshot must answer.

    ``kind`` selects the session entry point: ``"contraction"`` ranks
    candidate algorithms of one contraction
    (:meth:`~repro.tc.session.PredictorSession.rank_contraction_algorithms`),
    ``"chain"`` ranks the einsum paths of a multi-contraction chain
    (:meth:`~repro.tc.session.PredictorSession.rank_einsum_paths`).
    ``options`` forwards to the entry point (``kernels=``,
    ``max_loop_perms=``, ``memory_limit_bytes=``, ``include_batched=``).
    """

    name: str
    kind: str                                  # "contraction" | "chain"
    expr: str
    sizes: Tuple[Tuple[str, int], ...]
    options: Tuple[Tuple[str, object], ...] = ()

    def rank(self, session: PredictorSession) -> List[Tuple[str, float]]:
        """(candidate name, predicted median seconds), fastest first."""
        sizes = dict(self.sizes)
        opts = dict(self.options)
        if self.kind == "contraction":
            ranked = session.rank_contraction_algorithms(
                self.expr, sizes, **opts)
        elif self.kind == "chain":
            ranked = session.rank_einsum_paths(self.expr, sizes, **opts)
        else:
            raise ValueError(f"unknown workload kind {self.kind!r}")
        return [(r.name, r.runtime.med) for r in ranked]


def workload(name: str, kind: str, expr: str, sizes: Mapping[str, int],
             **options) -> Workload:
    """Hashable-workload convenience constructor (dicts to sorted tuples)."""
    return Workload(name=name, kind=kind, expr=expr,
                    sizes=tuple(sorted(sizes.items())),
                    options=tuple(sorted(options.items())))


def frozen_workloads(*, smoke: bool = False) -> List[Workload]:
    """The cross-commit workload suite.

    FROZEN literals, deliberately — scores are only comparable across
    commits if the problems never move.  The values mirror the smoke
    specs of ``bench_contractions`` / ``bench_einsum_paths`` /
    ``bench_serving`` (``tests/test_store.py`` pins the correspondence;
    the benches cannot be imported here — ``repro`` must not reach up
    into the ``benchmarks/`` tree).  ``smoke=True`` keeps only the cheap
    contraction workloads (the chain workload enumerates einsum paths
    and is the expensive one).
    """
    loads = [
        # bench_contractions.SMOKE_SPEC / SMOKE_SIZES
        workload("contraction_smoke", "contraction",
                 "bij,bjk->bik", dict(b=8, i=64, j=64, k=64)),
        # one serve-step projection at bench_serving.SMOKE_ARCH
        # (d_model=64, d_ff=128) across SLOTS=3 decode slots
        workload("serving_step_proj", "contraction", "bij,jk->bik",
                 dict(b=3, i=1, j=64, k=128)),
    ]
    if not smoke:
        # bench_einsum_paths smoke constants
        loads.append(workload(
            "einsum_path_smoke", "chain", "aij,ijb,bkl,klc->ac",
            dict(a=4, b=4, c=4, i=2048, j=2048, k=2048, l=2048),
            kernels=("gemm", "gemv", "gevm"), max_loop_perms=2,
            memory_limit_bytes=96 * 2 ** 20))
    return loads


def kendall_tau(order_a: Sequence[str], order_b: Sequence[str]) -> float:
    """Kendall rank correlation between two orderings of one candidate
    set: (concordant - discordant) / total pairs, in [-1, 1].

    Candidates missing from either ordering are ignored (a snapshot that
    cannot rank a candidate simply is not scored on it); fewer than two
    shared candidates yields 1.0 (nothing to disagree about).
    """
    common = [n for n in order_a if n in set(order_b)]
    if len(common) < 2:
        return 1.0
    pos_b = {n: i for i, n in enumerate(order_b)}
    concordant = discordant = 0
    for i in range(len(common)):
        for j in range(i + 1, len(common)):
            # common is in order_a's order, so pair (i, j) is ascending
            # in a; it is concordant iff also ascending in b
            if pos_b[common[i]] < pos_b[common[j]]:
                concordant += 1
            else:
                discordant += 1
    total = concordant + discordant
    return (concordant - discordant) / total


@dataclass
class Snapshot:
    """A named contender: a store (file or object) + session config."""

    name: str
    store: Union[ModelStore, str, Path]
    backend: str = "numpy"

    def open(self, *, allow_mismatch: bool = False,
             fingerprint=None) -> ModelStore:
        if isinstance(self.store, ModelStore):
            return self.store
        return ModelStore.load(self.store, allow_mismatch=allow_mismatch,
                               fingerprint=fingerprint)


@dataclass
class SnapshotScore:
    """One snapshot's scoreboard row."""

    name: str
    rel_err: float                 # mean relative error vs oracle medians
    top1_rate: float               # fraction of workloads with agreeing #1
    rank_agreement: float          # mean Kendall-tau vs oracle orderings
    suite_cost_seconds: float      # measurement cost incl. amortized loads
    new_benchmarks: int            # fresh measurements (0 = fully warm)
    per_workload: Dict[str, Dict[str, float]] = field(default_factory=dict)

    def as_dict(self) -> Dict[str, object]:
        return {"name": self.name, "rel_err": self.rel_err,
                "top1_rate": self.top1_rate,
                "rank_agreement": self.rank_agreement,
                "suite_cost_seconds": self.suite_cost_seconds,
                "new_benchmarks": self.new_benchmarks,
                "per_workload": self.per_workload}


@dataclass
class TournamentResult:
    """The scoreboard: snapshots best-first, plus the oracle's cost."""

    scores: List[SnapshotScore]
    workloads: List[str]
    oracle_cost_seconds: float

    @property
    def winner(self) -> SnapshotScore:
        return self.scores[0]

    def to_payload(self) -> dict:
        return {
            "schema_version": SCHEMA_VERSION,
            "workloads": list(self.workloads),
            "oracle_cost_seconds": self.oracle_cost_seconds,
            "scoreboard": [s.as_dict() for s in self.scores],
        }

    def save(self, path: Union[str, Path]) -> None:
        with open(path, "w") as f:
            json.dump(self.to_payload(), f, indent=1)

    def describe(self) -> str:
        lines = [f"tournament over {len(self.workloads)} workload(s):"]
        for rank, s in enumerate(self.scores, 1):
            lines.append(
                f"  {rank}. {s.name}: top1={s.top1_rate:.2f} "
                f"tau={s.rank_agreement:+.2f} rel_err={s.rel_err:.3f} "
                f"cost={s.suite_cost_seconds:.2f}s "
                f"new={s.new_benchmarks}")
        return "\n".join(lines)


def score_snapshot(name: str, session: PredictorSession,
                   workloads: Sequence[Workload],
                   oracle_rankings: Mapping[str, List[Tuple[str, float]]],
                   ) -> SnapshotScore:
    """Rank every workload through ``session`` and score vs the oracle."""
    before = session.counters()
    per_workload: Dict[str, Dict[str, float]] = {}
    errs: List[float] = []
    taus: List[float] = []
    top1 = 0
    for load in workloads:
        ranked = load.rank(session)
        oracle = oracle_rankings[load.name]
        oracle_med = dict(oracle)
        pair_errs = [abs(med - oracle_med[n]) / oracle_med[n]
                     for n, med in ranked
                     if n in oracle_med and oracle_med[n] > 0]
        err = sum(pair_errs) / len(pair_errs) if pair_errs else 0.0
        tau = kendall_tau([n for n, _ in ranked], [n for n, _ in oracle])
        agree = bool(ranked and oracle and ranked[0][0] == oracle[0][0])
        top1 += agree
        errs.append(err)
        taus.append(tau)
        per_workload[load.name] = {"rel_err": err, "tau": tau,
                                   "top1": float(agree)}
    after = session.counters()
    suite = session.suite
    return SnapshotScore(
        name=name,
        rel_err=sum(errs) / len(errs) if errs else 0.0,
        top1_rate=top1 / len(workloads) if workloads else 1.0,
        rank_agreement=sum(taus) / len(taus) if taus else 1.0,
        suite_cost_seconds=suite.cost_seconds + suite.loaded_cost_seconds,
        new_benchmarks=int(after["measured"] - before["measured"]),
        per_workload=per_workload)


def run_tournament(snapshots: Sequence[Snapshot],
                   workloads: Optional[Sequence[Workload]] = None, *,
                   oracle_session: Optional[PredictorSession] = None,
                   allow_mismatch: bool = False,
                   fingerprint=None,
                   measure_fn=None,
                   smoke: bool = False) -> TournamentResult:
    """Score every snapshot against a freshly measured oracle.

    ``oracle_session`` supplies the ground-truth measurements (tests
    inject one with a deterministic ``measure_fn``; by default a fresh
    in-memory session measures for real).  Each snapshot gets its own
    warm-started session over its store, so its ``new_benchmarks``
    exposes how many benchmarks the store could *not* answer —
    ``measure_fn`` backs exactly those gap measurements (tests point it
    at the oracle's backend; by default the real §6.2 protocol runs).
    The scoreboard sorts by (top-1 agreement, rank agreement, -relative
    error, -cost) — selection quality first, per arXiv:1409.8602.
    """
    if len(snapshots) < 2:
        raise ValueError("a tournament needs at least 2 snapshots "
                         f"(got {len(snapshots)})")
    loads = list(workloads) if workloads is not None else \
        frozen_workloads(smoke=smoke)
    oracle = oracle_session or PredictorSession()
    oracle_before = oracle.counters()["cost_seconds"]
    oracle_rankings = {load.name: load.rank(oracle) for load in loads}
    oracle_cost = oracle.counters()["cost_seconds"] - oracle_before

    scores = []
    for snap in snapshots:
        store = snap.open(allow_mismatch=allow_mismatch,
                          fingerprint=fingerprint)
        session = PredictorSession(
            backend=snap.backend,
            suite=store.build_suite(measure_fn=measure_fn))
        scores.append(score_snapshot(snap.name, session, loads,
                                     oracle_rankings))
    scores.sort(key=lambda s: (-s.top1_rate, -s.rank_agreement,
                               s.rel_err, s.suite_cost_seconds))
    return TournamentResult(scores=scores,
                            workloads=[load.name for load in loads],
                            oracle_cost_seconds=oracle_cost)
