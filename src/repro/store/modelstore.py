"""The persistent platform model store (versioned, fingerprint-keyed).

A :class:`ModelStore` makes the paper's "once per platform" amortization
real across processes: it serializes a
:class:`~repro.tc.suite.MicroBenchmarkSuite`'s measurements (keyed by the
canonical :class:`~repro.tc.suite.MicroBenchmarkKey` — equation, kernel
shapes, per-operand cache classes), plus any finalized
:class:`~repro.core.model.ModelSet` artifacts, under a
:class:`~repro.store.fingerprint.PlatformFingerprint`.  A serve process
or CI run warm-starts by loading the store into a fresh suite: every
ranking drawn from it re-predicts from the stored measurements with
*zero* new micro-benchmarks, and — because the measurements round-trip
bit-exactly through JSON (``float.__repr__`` is shortest-round-trip) —
the predictions are bit-identical to the in-memory session the store was
saved from.

Two guards protect the load path:

* **schema**: a payload whose ``schema_version`` differs from this
  module's :data:`SCHEMA_VERSION` cannot be interpreted by this code and
  refuses outright (``allow_mismatch`` does not override a schema gap);
* **fingerprint**: a payload written on a different platform (CPU,
  cores, jax backend/device, library stack, dtype, repro version)
  refuses unless ``allow_mismatch=True`` — measurements are facts about
  a platform, not about the code.

Reprolint's ``store-schema`` checker statically forbids writing store
payloads anywhere in this package without the ``SCHEMA_VERSION``
constant in the payload, so a format change can never ship silently.
"""

from __future__ import annotations

import json
import math
from pathlib import Path
from typing import Dict, Mapping, Optional, Union

from ..core.contractions import CACHE_BYTES
from ..core.model import ModelSet
from ..core.sampler import Stats
from ..tc.suite import (MicroBenchmark, MicroBenchmarkKey,
                        MicroBenchmarkSuite)
from .fingerprint import PlatformFingerprint, current_fingerprint

#: store file-format version.  Bump on any payload layout change; the
#: loader refuses mismatched schemas even under ``allow_mismatch=True``.
SCHEMA_VERSION = 1

#: reserved ``model_sets`` name holding fitted size-parametric models
#: (:meth:`repro.tc.parametric.ParametricModels.to_model_set`).  Riding
#: inside the existing schema-versioned ``model_sets`` mapping keeps the
#: payload layout — and therefore :data:`SCHEMA_VERSION` — unchanged:
#: stores written before parametric models existed load exactly as
#: before, and old readers see just another named model set.
PARAMETRIC_MODEL_SET = "__parametric__"

#: reserved ``model_sets`` name holding fitted *device kernel* models —
#: per-(Pallas kernel, VMEM class) tile-config polynomials plus the
#: memcpy H2D/D2H transfer models
#: (:meth:`repro.tc.device.DeviceSuite.to_model_set`).  Same schema
#: trick as :data:`PARAMETRIC_MODEL_SET`.  Device measurements are even
#: more platform-bound than einsum ones (they time the accelerator
#: itself), so loading them under a mismatched fingerprint is refused by
#: the standard gate — :meth:`ModelStore.device_model_set` is only
#: reachable after :meth:`ModelStore.load` has already verified the
#: fingerprint.
DEVICE_MODEL_SET = "__device__"


class StoreMismatchError(ValueError):
    """A store file refusing to load: wrong schema or wrong platform."""


def _key_to_dict(key: MicroBenchmarkKey) -> dict:
    d = {"equation": key.equation,
         "a_shape": list(key.a_shape),
         "b_shape": list(key.b_shape),
         "out_shape": list(key.out_shape),
         "classes": list(key.classes)}
    if key.config is not None:
        # device kernel keys only: einsum keys keep the pre-device
        # payload entry byte-for-byte, so old stores load unchanged
        d["config"] = list(key.config)
    return d


def _key_from_dict(d: Mapping) -> MicroBenchmarkKey:
    config = d.get("config")
    return MicroBenchmarkKey(equation=d["equation"],
                             a_shape=tuple(d["a_shape"]),
                             b_shape=tuple(d["b_shape"]),
                             out_shape=tuple(d["out_shape"]),
                             classes=tuple(d["classes"]),
                             config=None if config is None
                             else tuple(config))


def sort_key(key: MicroBenchmarkKey) -> tuple:
    """The canonical deterministic ordering of benchmark keys — used for
    stable payload layout and for the drift probe's subset selection.
    The config facet sorts as ``()`` when absent: ``None`` would not
    compare against device keys' tuples."""
    return (key.equation, key.a_shape, key.b_shape, key.out_shape,
            key.classes, key.config or ())


def _finite(value: float, what: str) -> float:
    """Stored measurements must be finite: NaN/inf would round-trip into
    silently poisoned rankings."""
    value = float(value)
    if not math.isfinite(value):
        raise ValueError(f"non-finite {what} ({value!r}) cannot be stored")
    return value


class ModelStore:
    """Measurements + finalized model artifacts under one fingerprint.

    Build one with :meth:`from_suite` (capture a measured suite), extend
    it with :meth:`add_model_set` (finalized per-signature or
    generated-model artifacts), persist with :meth:`save`, and
    reconstruct with :meth:`load` — which re-finalizes every model set so
    the padded-tensor artifacts the fused engine gathers from are part
    of the loaded object, not re-derived on first predict.
    """

    def __init__(self, *,
                 fingerprint: Optional[PlatformFingerprint] = None):
        self.fingerprint = fingerprint if fingerprint is not None \
            else current_fingerprint()
        self.measurements: Dict[MicroBenchmarkKey, MicroBenchmark] = {}
        self.model_sets: Dict[str, ModelSet] = {}
        #: the captured suite's measurement protocol + accumulated cost
        self.suite_meta: Dict[str, float] = {
            "repetitions": 5, "cache_bytes": CACHE_BYTES, "seed": 0,
            "cost_seconds": 0.0}

    # ------------------------------------------------------------ capture --
    @classmethod
    def from_suite(cls, suite: MicroBenchmarkSuite, *,
                   fingerprint: Optional[PlatformFingerprint] = None,
                   ) -> "ModelStore":
        """Capture a suite's measurements (and protocol) into a store."""
        store = cls(fingerprint=fingerprint)
        store.add_suite(suite)
        return store

    def add_suite(self, suite: MicroBenchmarkSuite) -> None:
        """Merge a suite's measurements into the store.

        The suite's measurement protocol (repetitions, cache capacity,
        seed) becomes the store's — merging suites with conflicting
        protocols raises, since their measurements are not comparable.
        """
        meta = {"repetitions": suite.repetitions,
                "cache_bytes": suite.cache_bytes, "seed": suite.seed}
        for name, value in meta.items():
            if self.measurements and self.suite_meta[name] != value:
                raise ValueError(
                    f"suite {name}={value} conflicts with the store's "
                    f"{name}={self.suite_meta[name]}; one store holds one "
                    f"measurement protocol")
        self.suite_meta.update(meta)
        self.measurements.update(suite.results)
        # total wall-clock behind the stored measurements: what a warm
        # start amortizes (fresh + any loaded-from-elsewhere cost)
        self.suite_meta["cost_seconds"] = float(
            sum(mb.seconds for mb in self.measurements.values()))

    def add_model_set(self, name: str, models: ModelSet) -> None:
        """Attach a finalized :class:`ModelSet` artifact under ``name``."""
        self.model_sets[name] = models

    def model_set(self, name: str) -> ModelSet:
        return self.model_sets[name]

    def add_parametric_models(self, models) -> None:
        """Attach fitted size-parametric models under the reserved name
        (:data:`PARAMETRIC_MODEL_SET`).

        Accepts a :class:`repro.tc.parametric.ParametricModels` registry
        (exported via its ``to_model_set``) or an already-exported
        :class:`ModelSet`.  The models round-trip bit-exactly: a session
        warm-started from this store predicts unmeasured shapes
        bit-identically to the session that fitted them.
        """
        ms = models.to_model_set() if hasattr(models, "to_model_set") \
            else models
        self.model_sets[PARAMETRIC_MODEL_SET] = ms

    def parametric_model_set(self) -> Optional[ModelSet]:
        """The stored size-parametric models, or ``None`` if this store
        holds none (e.g. written before they existed)."""
        return self.model_sets.get(PARAMETRIC_MODEL_SET)

    def add_device_models(self, models) -> None:
        """Attach fitted device kernel models under the reserved name
        (:data:`DEVICE_MODEL_SET`).

        Accepts a :class:`repro.tc.device.DeviceSuite` (exported via its
        ``to_model_set``) or an already-exported :class:`ModelSet`.
        With these stored, a warm-started session ranks Pallas tile
        configs with zero fresh device sweeps and no memcpy probe.
        """
        ms = models.to_model_set() if hasattr(models, "to_model_set") \
            else models
        self.model_sets[DEVICE_MODEL_SET] = ms

    def device_model_set(self) -> Optional[ModelSet]:
        """The stored device kernel + transfer models, or ``None`` if
        this store holds none (e.g. written before they existed)."""
        return self.model_sets.get(DEVICE_MODEL_SET)

    # ---------------------------------------------------------- warm start --
    def load_into(self, suite: MicroBenchmarkSuite) -> int:
        """Inject every stored measurement into ``suite`` (warm start).

        Keys the suite already measured keep their fresh result.  Loaded
        keys are counted under the suite's ``loaded`` counter and their
        original wall-clock cost under ``loaded_cost_seconds`` — so a
        warm-started cost fraction can state its amortized cost instead
        of silently claiming the measurements were free.
        """
        n = 0
        for key, mb in self.measurements.items():
            if key not in suite.results:
                suite.load_measurement(mb)
                n += 1
        return n

    def build_suite(self, *, repetitions: Optional[int] = None,
                    measure_fn=None) -> MicroBenchmarkSuite:
        """A fresh suite under the store's measurement protocol, with
        every stored measurement pre-loaded.

        ``repetitions`` may restate the stored value but not contradict
        it (the stored measurements were taken under that protocol);
        ``measure_fn`` backs any *new* keys and the drift probe.
        """
        stored = int(self.suite_meta["repetitions"])
        if repetitions is not None and repetitions != stored:
            raise ValueError(
                f"repetitions={repetitions} conflicts with the store's "
                f"measurement protocol (repetitions={stored})")
        suite = MicroBenchmarkSuite(
            repetitions=stored,
            cache_bytes=int(self.suite_meta["cache_bytes"]),
            seed=int(self.suite_meta["seed"]),
            measure_fn=measure_fn)
        self.load_into(suite)
        return suite

    # ------------------------------------------------------------------ io --
    def to_payload(self) -> dict:
        """The JSON payload: schema version first, fingerprint second —
        the two gates the loader checks before touching measurements."""
        return {
            "schema_version": SCHEMA_VERSION,
            "fingerprint": self.fingerprint.as_dict(),
            "suite": dict(self.suite_meta),
            "measurements": [
                {"key": _key_to_dict(key),
                 "stats": {s: _finite(v, f"stat {s}")
                           for s, v in mb.stats.as_dict().items()},
                 "first": _finite(mb.first, "first-call overhead"),
                 "seconds": _finite(mb.seconds, "benchmark cost")}
                for key, mb in sorted(self.measurements.items(),
                                      key=lambda kv: sort_key(kv[0]))],
            "model_sets": {name: ms.to_dict()
                           for name, ms in sorted(self.model_sets.items())},
        }

    def save(self, path: Union[str, Path]) -> None:
        """Write the store to ``path`` (atomic enough for CI artifacts:
        one ``json.dump`` into a freshly truncated file)."""
        with open(path, "w") as f:
            json.dump(self.to_payload(), f, indent=1)

    @classmethod
    def load(cls, path: Union[str, Path], *, allow_mismatch: bool = False,
             fingerprint: Optional[PlatformFingerprint] = None,
             ) -> "ModelStore":
        """Load a store, refusing schema and fingerprint mismatches.

        ``fingerprint`` overrides the running platform's (tests pin it);
        ``allow_mismatch=True`` downgrades a *fingerprint* mismatch to
        acceptance — a *schema* mismatch always refuses, since this code
        cannot interpret another schema's payload at all.
        """
        with open(path) as f:
            payload = json.load(f)
        schema = payload.get("schema_version")
        if schema != SCHEMA_VERSION:
            raise StoreMismatchError(
                f"{path}: store schema_version={schema!r} but this code "
                f"reads schema_version={SCHEMA_VERSION}; re-generate the "
                f"store (allow_mismatch cannot bridge a schema gap)")
        stored_fp = PlatformFingerprint.from_dict(
            payload.get("fingerprint", {}))
        current = fingerprint if fingerprint is not None \
            else current_fingerprint()
        mismatched = stored_fp.mismatches(current)
        if mismatched and not allow_mismatch:
            detail = ", ".join(
                f"{name}: stored={getattr(stored_fp, name)!r} != "
                f"current={getattr(current, name)!r}" for name in mismatched)
            raise StoreMismatchError(
                f"{path}: platform fingerprint mismatch ({detail}); pass "
                f"allow_mismatch=True to load another platform's "
                f"measurements anyway")
        store = cls(fingerprint=stored_fp)
        store.suite_meta.update(payload.get("suite", {}))
        for entry in payload.get("measurements", []):
            key = _key_from_dict(entry["key"])
            store.measurements[key] = MicroBenchmark(
                key=key, stats=Stats(**entry["stats"]),
                first=entry["first"], seconds=entry["seconds"])
        for name, ms in payload.get("model_sets", {}).items():
            # from_dict re-finalizes: padded case tensors are part of the
            # loaded artifact, exactly as ModelSet.finalize emitted them
            store.model_sets[name] = ModelSet.from_dict(ms)
        return store

    # ------------------------------------------------------------- summary --
    @property
    def n_keys(self) -> int:
        """Distinct stored micro-benchmark measurements."""
        return len(self.measurements)

    @property
    def cost_seconds(self) -> float:
        """Wall-clock the stored measurements originally cost — what a
        warm start amortizes instead of re-spending."""
        return float(self.suite_meta.get("cost_seconds", 0.0))

    def describe(self) -> str:
        fp = self.fingerprint
        return (f"ModelStore(schema={SCHEMA_VERSION}, keys={self.n_keys}, "
                f"model_sets={len(self.model_sets)}, "
                f"cost={self.cost_seconds:.2f}s, platform={fp.backend}/"
                f"{fp.device_kind}, {fp.cores} cores)")
