"""Platform fingerprints: what a stored model is a model *of*.

The paper's amortization argument — models are "generated automatically
once per platform" (Ch. 4) — only holds while *platform* means the same
thing across processes.  A :class:`PlatformFingerprint` pins down the
identity a :class:`~repro.store.modelstore.ModelStore` file is valid
for: the CPU, the core count, the jax backend and device kind the
kernels dispatch to, the library versions the measurements went
through, the measurement dtype, and the repro version that produced the
artifact.  Loading a store whose fingerprint differs from the running
platform refuses by default (``allow_mismatch=True`` opts into reuse,
e.g. for cross-machine tournaments) — a silently wrong platform model
is worse than a re-measured one.

The file-format *schema* version is deliberately not a fingerprint
field: it is checked first and separately by the store loader (see
``SCHEMA_VERSION`` in :mod:`repro.store.modelstore`), because a schema
bump means "this code cannot read that payload", not "that platform is
not this platform".
"""

from __future__ import annotations

import os
import platform
from dataclasses import asdict, dataclass, fields
from typing import Dict, List

#: fallback package version when importlib metadata is unavailable
#: (running from a source tree via PYTHONPATH, not an installed wheel)
_FALLBACK_VERSION = "0.1.0"


def repro_version() -> str:
    """The repro package version stamped into every store artifact."""
    try:
        from importlib.metadata import version
        return version("repro")
    except Exception:
        return _FALLBACK_VERSION


def _cpu_model() -> str:
    """A best-effort CPU model string (portable across linux/mac CI)."""
    model = platform.processor() or platform.machine() or "unknown"
    try:
        with open("/proc/cpuinfo") as f:
            for line in f:
                if line.lower().startswith("model name"):
                    model = line.split(":", 1)[1].strip()
                    break
    except OSError:
        pass
    return model


def _library_versions() -> str:
    """The measurement-relevant library stack, one canonical string."""
    import numpy as np
    parts = [f"numpy={np.__version__}"]
    try:
        import jax
        parts.append(f"jax={jax.__version__}")
    except Exception:
        parts.append("jax=absent")
    return ",".join(parts)


def _jax_backend() -> str:
    try:
        import jax
        return jax.default_backend()
    except Exception:
        return "absent"


def _jax_device_kind() -> str:
    try:
        import jax
        devices = jax.devices()
        return devices[0].device_kind if devices else "none"
    except Exception:
        return "none"


@dataclass(frozen=True)
class PlatformFingerprint:
    """The platform identity a stored suite/model is valid for."""

    cpu: str              # CPU model string
    cores: int            # logical core count
    backend: str          # jax default backend ("cpu"/"gpu"/"tpu")
    device_kind: str      # jax device kind of device 0
    libraries: str        # "numpy=...,jax=..." measurement library stack
    dtype: str            # operand dtype the micro-benchmarks run in
    repro_version: str    # repro package version that wrote the store

    def as_dict(self) -> Dict[str, object]:
        return asdict(self)

    @staticmethod
    def from_dict(d: Dict[str, object]) -> "PlatformFingerprint":
        names = [f.name for f in fields(PlatformFingerprint)]
        return PlatformFingerprint(**{n: d.get(n, "missing") for n in names})

    def mismatches(self, other: "PlatformFingerprint") -> List[str]:
        """Field names on which the two fingerprints disagree."""
        return [f.name for f in fields(self)
                if getattr(self, f.name) != getattr(other, f.name)]


def current_fingerprint(*, dtype: str = "float32",
                        ) -> PlatformFingerprint:
    """The running process's platform fingerprint.

    ``dtype`` names the operand dtype of the stored measurements — the
    contraction micro-benchmarks run in float32
    (:data:`repro.core.contractions._ITEM` is 4 bytes), so that is the
    default; a store of float64 Pallas-kernel measurements would carry
    its own.
    """
    return PlatformFingerprint(
        cpu=_cpu_model(),
        cores=os.cpu_count() or 1,
        backend=_jax_backend(),
        device_kind=_jax_device_kind(),
        libraries=_library_versions(),
        dtype=dtype,
        repro_version=repro_version(),
    )
