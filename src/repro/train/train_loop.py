"""Fault-tolerant training loop.

Scale features wired in (all exercised by tests / the quickstart example):

* deterministic restart-safe data (``data.batch_at(seed, step)``),
* checkpoint/restore with atomic commit + CRC + keep-N (``checkpoint``),
* async checkpoint cadence,
* **straggler watchdog**: per-step wall time is tracked against a rolling
  median; steps slower than ``straggler_factor`` x median are counted and
  reported (on a real cluster this feeds the controller that evicts or
  re-shards around slow hosts),
* loss-NaN circuit breaker (skips the update and re-tries with the next
  batch rather than corrupting the params),
* metrics log (CSV) for the examples/benchmarks.
"""

from __future__ import annotations

import statistics
import time
from dataclasses import dataclass, field
from pathlib import Path
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ArchConfig
from . import checkpoint as ckpt_lib
from .data import DataConfig, batch_at
from .optimizer import AdamW


@dataclass
class TrainConfig:
    steps: int = 100
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 50
    log_every: int = 10
    straggler_factor: float = 3.0
    seed: int = 0


@dataclass
class TrainReport:
    losses: List[float] = field(default_factory=list)
    step_times: List[float] = field(default_factory=list)
    straggler_steps: List[int] = field(default_factory=list)
    skipped_nan_steps: List[int] = field(default_factory=list)
    resumed_from: Optional[int] = None

    @property
    def final_loss(self) -> float:
        return self.losses[-1] if self.losses else float("nan")


def train(cfg: ArchConfig, data_cfg: DataConfig, tc: TrainConfig,
          *, params=None, opt: Optional[AdamW] = None,
          train_step: Optional[Callable] = None,
          dtype=jnp.float32) -> tuple:
    """Run (or resume) a training job.  Returns (params, opt_state, report)."""
    from ..launch.steps import make_train_step
    from ..models import init_params

    opt = opt or AdamW(lr=3e-4)
    if params is None:
        params = init_params(cfg, jax.random.PRNGKey(data_cfg.seed),
                             dtype=dtype)
    opt_state = opt.init(params)
    step_fn = train_step or jax.jit(make_train_step(cfg, opt))

    report = TrainReport()
    start_step = 0
    ckptr = None
    if tc.ckpt_dir:
        ckptr = ckpt_lib.AsyncCheckpointer(tc.ckpt_dir)
        latest = ckpt_lib.latest_step(tc.ckpt_dir)
        if latest is not None:
            (params, opt_state), start_step = ckpt_lib.restore(
                tc.ckpt_dir, (params, opt_state), step=latest)
            start_step = latest + 1
            report.resumed_from = latest

    times: List[float] = []
    step = start_step
    while step < tc.steps:
        batch = batch_at(data_cfg, step)
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        t0 = time.perf_counter()
        new_params, new_opt_state, loss = step_fn(params, opt_state, batch)
        # the NaN circuit breaker and straggler watchdog both need the
        # per-step loss and wall time on the host before the next step
        # reprolint: allow[host-sync]
        loss = float(jax.block_until_ready(loss))
        dt = time.perf_counter() - t0
        if np.isnan(loss) or np.isinf(loss):
            # circuit breaker: drop the update, keep going
            report.skipped_nan_steps.append(step)
            step += 1
            continue
        params, opt_state = new_params, new_opt_state
        report.losses.append(loss)
        report.step_times.append(dt)
        times.append(dt)
        if len(times) > 20:
            times.pop(0)
        if len(times) >= 5:
            med = statistics.median(times)
            if dt > tc.straggler_factor * med:
                report.straggler_steps.append(step)
        if ckptr and tc.ckpt_every and (step + 1) % tc.ckpt_every == 0:
            ckptr.save_async(step, (params, opt_state))
        step += 1
    if ckptr:
        ckptr.save_async(tc.steps - 1, (params, opt_state))
        ckptr.wait()
    return params, opt_state, report
