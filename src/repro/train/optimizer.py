"""AdamW optimizer (pytree-based, sharding-transparent).

Optimizer state inherits the parameter sharding (moments are element-wise),
so under FSDP-style parameter sharding the optimizer state is automatically
ZeRO-sharded.  Master moments are kept in f32 regardless of the parameter
dtype (bf16-safe training).
"""

from __future__ import annotations

from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array
    mu: Any        # first moment  (f32 pytree)
    nu: Any        # second moment (f32 pytree)


class AdamW(NamedTuple):
    lr: float = 1e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0

    def init(self, params) -> AdamWState:
        zeros = jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, dtype=jnp.float32), params)
        return AdamWState(step=jnp.zeros((), dtype=jnp.int32),
                          mu=zeros,
                          nu=jax.tree_util.tree_map(jnp.copy, zeros))

    def update(self, grads, state: AdamWState,
               params) -> Tuple[Any, AdamWState]:
        step = state.step + 1
        # global-norm clip
        if self.grad_clip > 0:
            gsq = sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                      for g in jax.tree_util.tree_leaves(grads))
            gnorm = jnp.sqrt(gsq)
            scale = jnp.minimum(1.0, self.grad_clip / (gnorm + 1e-9))
        else:
            scale = 1.0

        def upd(g, m, v, p):
            g = g.astype(jnp.float32) * scale
            m = self.b1 * m + (1 - self.b1) * g
            v = self.b2 * v + (1 - self.b2) * g * g
            mh = m / (1 - self.b1 ** step)
            vh = v / (1 - self.b2 ** step)
            u = mh / (jnp.sqrt(vh) + self.eps)
            if self.weight_decay and p.ndim > 1:
                u = u + self.weight_decay * p.astype(jnp.float32)
            return (-self.lr * u).astype(p.dtype), m, v

        flat_g, treedef = jax.tree_util.tree_flatten(grads)
        flat_m = treedef.flatten_up_to(state.mu)
        flat_v = treedef.flatten_up_to(state.nu)
        flat_p = treedef.flatten_up_to(params)
        out = [upd(g, m, v, p)
               for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
        updates = treedef.unflatten([o[0] for o in out])
        mu = treedef.unflatten([o[1] for o in out])
        nu = treedef.unflatten([o[2] for o in out])
        return updates, AdamWState(step=step, mu=mu, nu=nu)


def apply_updates(params, updates):
    return jax.tree_util.tree_map(lambda p, u: p + u, params, updates)
