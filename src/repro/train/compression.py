"""Gradient compression for the slow cross-pod axis.

int8 per-chunk-scaled quantization with **error feedback**: the
quantization residual is carried to the next step so compression error
does not bias convergence.  Intended for gradients synchronized over the
"pod" axis where DCI bandwidth is an order of magnitude below ICI: wire
bytes drop 4x (f32->int8) at the cost of two elementwise passes.
"""

from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

CHUNK = 1024


def _quantize(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    flat = x.reshape(-1)
    pad = (-flat.size) % CHUNK
    flat = jnp.pad(flat, (0, pad))
    chunks = flat.reshape(-1, CHUNK)
    scale = jnp.max(jnp.abs(chunks), axis=1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(chunks / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def _dequantize(q: jax.Array, scale: jax.Array, shape,
                size: int) -> jax.Array:
    flat = (q.astype(jnp.float32) * scale).reshape(-1)[:size]
    return flat.reshape(shape)


def compress_tree(grads: Any, error: Any) -> Tuple[Any, Any]:
    """Quantize grads+carried error; returns (quantized tree, new error)."""

    def one(g, e):
        g32 = g.astype(jnp.float32) + e
        q, s = _quantize(g32)
        deq = _dequantize(q, s, g.shape, g.size)
        return (q, s), g32 - deq

    leaves, treedef = jax.tree_util.tree_flatten(grads)
    e_leaves = treedef.flatten_up_to(error)
    out = [one(g, e) for g, e in zip(leaves, e_leaves)]
    return (treedef.unflatten([o[0] for o in out]),
            treedef.unflatten([o[1] for o in out]))


def decompress_tree(qtree: Any, like: Any) -> Any:
    def one(qs, g):
        q, s = qs
        return _dequantize(q, s, g.shape, g.size).astype(g.dtype)

    leaves, treedef = jax.tree_util.tree_flatten(like)
    q_leaves = treedef.flatten_up_to(qtree)
    return treedef.unflatten([one(q, g)
                              for q, g in zip(q_leaves, leaves)])


def init_error(params: Any) -> Any:
    return jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)
