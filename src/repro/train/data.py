"""Deterministic, restart-safe data pipeline.

Batches are a pure function of ``(seed, step)`` — no iterator state to
checkpoint, and after a node failure the resumed job regenerates exactly
the batches it would have seen (skip-ahead is O(1)).  The synthetic token
stream models a tokenized corpus (Zipfian unigram + short-range structure);
the same interface accommodates a real corpus by replacing ``_tokens``.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    frontend_dim: int = 0     # > 0 => emit frame embeddings, not tokens


def _fold(seed: int, step: int) -> np.random.Generator:
    return np.random.default_rng(np.random.SeedSequence([seed, step]))


def _tokens(rng: np.random.Generator, shape, vocab: int) -> np.ndarray:
    # Zipfian unigram draw with local repetition structure
    ranks = rng.zipf(1.3, size=shape).astype(np.int64)
    toks = (ranks - 1) % vocab
    rep = rng.random(shape) < 0.1
    shifted = np.roll(toks, 1, axis=-1)
    return np.where(rep, shifted, toks).astype(np.int32)


def batch_at(cfg: DataConfig, step: int) -> Dict[str, np.ndarray]:
    """The batch for one step — pure function of (seed, step)."""
    rng = _fold(cfg.seed, step)
    b, s = cfg.global_batch, cfg.seq_len
    if cfg.frontend_dim:
        inputs = rng.standard_normal((b, s, cfg.frontend_dim),
                                     dtype=np.float32)
        labels = _tokens(rng, (b, s), cfg.vocab)
        return {"inputs": inputs, "labels": labels}
    stream = _tokens(rng, (b, s + 1), cfg.vocab)
    return {"inputs": stream[:, :-1], "labels": stream[:, 1:]}


def iterate(cfg: DataConfig, start_step: int = 0) -> Iterator[Dict]:
    step = start_step
    while True:
        yield batch_at(cfg, step)
        step += 1


def shard_batch(batch: Dict[str, np.ndarray], shardings) -> Dict:
    """Place a host batch onto the mesh with the given shardings."""
    return {k: jax.device_put(v, shardings[k]) for k, v in batch.items()}
