"""repro.train."""
