"""Sharding-aware checkpointing with atomic commit and async snapshots.

Layout::

    <dir>/step_000123/
        manifest.json      # tree structure, shapes, dtypes, CRCs, step
        arr_00000.npy ...  # one file per leaf (process-local shards on a
                           # real cluster; full arrays on a single host)
    <dir>/LATEST           # atomically-renamed pointer file

Fault-tolerance properties:

* **atomic commit** — data is written into ``step_x.tmp`` and renamed only
  after every file + manifest landed; a crash mid-write never corrupts the
  latest valid checkpoint;
* **CRC validation** — every leaf carries a crc32; ``restore`` falls back
  to the previous valid checkpoint on mismatch (torn-write protection);
* **keep-N GC** — old checkpoints are pruned after commit;
* **async mode** — ``save_async`` snapshots device arrays to host
  (blocking only for the device->host copy) and writes on a thread, so
  training overlaps the I/O.
"""

from __future__ import annotations

import json
import shutil
import threading
import zlib
from pathlib import Path
from typing import Any, Optional, Tuple

import jax
import numpy as np


def _flatten(tree: Any):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def _crc(arr: np.ndarray) -> int:
    return zlib.crc32(arr.tobytes())


def save(ckpt_dir: str, step: int, tree: Any, *, keep: int = 3) -> Path:
    """Synchronous checkpoint save with atomic commit."""
    base = Path(ckpt_dir)
    base.mkdir(parents=True, exist_ok=True)
    final = base / f"step_{step:09d}"
    tmp = base / f"step_{step:09d}.tmp"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)
    leaves, treedef = _flatten(tree)
    entries = []
    for i, leaf in enumerate(leaves):
        arr = np.asarray(leaf)
        fname = f"arr_{i:05d}.npy"
        np.save(tmp / fname, arr, allow_pickle=False)
        entries.append({"file": fname, "shape": list(arr.shape),
                        "dtype": str(arr.dtype), "crc": _crc(arr)})
    manifest = {"step": step, "n_leaves": len(leaves), "leaves": entries,
                "treedef": str(treedef)}
    (tmp / "manifest.json").write_text(json.dumps(manifest))
    if final.exists():
        shutil.rmtree(final)
    tmp.rename(final)                      # atomic commit
    latest_tmp = base / "LATEST.tmp"
    latest_tmp.write_text(final.name)
    latest_tmp.rename(base / "LATEST")
    _gc(base, keep)
    return final


def _gc(base: Path, keep: int) -> None:
    ckpts = sorted(p for p in base.glob("step_*") if p.is_dir()
                   and not p.name.endswith(".tmp"))
    for old in ckpts[:-keep]:
        shutil.rmtree(old, ignore_errors=True)


def _validate(ckpt: Path) -> bool:
    try:
        manifest = json.loads((ckpt / "manifest.json").read_text())
        for e in manifest["leaves"]:
            arr = np.load(ckpt / e["file"], allow_pickle=False)
            if _crc(arr) != e["crc"]:
                return False
        return True
    except Exception:
        return False


def latest_step(ckpt_dir: str) -> Optional[int]:
    base = Path(ckpt_dir)
    pointer = base / "LATEST"
    candidates = []
    if pointer.exists():
        candidates.append(base / pointer.read_text().strip())
    candidates += sorted((p for p in base.glob("step_*") if p.is_dir()
                          and not p.name.endswith(".tmp")), reverse=True)
    for c in candidates:
        if c.exists() and _validate(c):
            return int(c.name.split("_")[1])
    return None


def restore(ckpt_dir: str, tree_like: Any,
            step: Optional[int] = None,
            shardings: Any = None) -> Tuple[Any, int]:
    """Restore into the structure of ``tree_like``.

    ``shardings`` (optional pytree of NamedSharding) re-places each leaf on
    the (possibly different) mesh — the elastic-restart path: a checkpoint
    written on N hosts restores onto M hosts by resharding at load.
    """
    base = Path(ckpt_dir)
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no valid checkpoint under {ckpt_dir}")
    ckpt = base / f"step_{step:09d}"
    if not _validate(ckpt):
        raise IOError(f"checkpoint {ckpt} failed CRC validation")
    manifest = json.loads((ckpt / "manifest.json").read_text())
    leaves, treedef = _flatten(tree_like)
    assert len(leaves) == manifest["n_leaves"], \
        (len(leaves), manifest["n_leaves"])
    shard_leaves = (jax.tree_util.tree_flatten(shardings)[0]
                    if shardings is not None else [None] * len(leaves))
    out = []
    for e, ref, sh in zip(manifest["leaves"], leaves, shard_leaves):
        arr = np.load(ckpt / e["file"], allow_pickle=False)
        if sh is not None:
            out.append(jax.device_put(arr, sh))
        else:
            out.append(jax.numpy.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, out), step


class AsyncCheckpointer:
    """Overlaps checkpoint I/O with training (one in flight at a time)."""

    def __init__(self, ckpt_dir: str, keep: int = 3):
        self.ckpt_dir = ckpt_dir
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self.last_path: Optional[Path] = None

    def save_async(self, step: int, tree: Any) -> None:
        self.wait()
        host_tree = jax.tree_util.tree_map(np.asarray, tree)  # snapshot

        def work():
            self.last_path = save(self.ckpt_dir, step, host_tree,
                                  keep=self.keep)

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
