"""repro.models — NN substrate for the assigned architecture pool."""

from .attention import attn_forward, chunked_attention, init_attn
from .layers import apply_rope, cross_entropy, rmsnorm, softcap, swiglu
from .mamba import init_mamba, init_ssm_state, mamba_forward
from .moe import init_moe, moe_forward
from .transformer import (decode_step, forward, init_decode_state,
                          init_params, loss_fn, n_periods)

__all__ = ["attn_forward", "chunked_attention", "init_attn", "apply_rope",
           "cross_entropy", "rmsnorm", "softcap", "swiglu", "init_mamba",
           "init_ssm_state", "mamba_forward", "init_moe", "moe_forward",
           "decode_step", "forward", "init_decode_state", "init_params",
           "loss_fn", "n_periods"]
