"""Mixture-of-Experts FFN (top-k routing, capacity-based dispatch).

Classic TPU-style MoE: router -> top-k -> one-hot dispatch/combine einsums.
The expert dimension E is sharded on the "model" mesh axis (expert
parallelism); GSPMD turns the dispatch/combine einsums into all-to-alls.
Capacity factor bounds per-expert work so the computation is static-shaped
(dropped tokens fall through the residual connection).
"""

from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig


class MoEParams(NamedTuple):
    router: jax.Array    # (d_model, E)
    w_gate: jax.Array    # (E, d_model, d_ff)
    w_up: jax.Array      # (E, d_model, d_ff)
    w_down: jax.Array    # (E, d_ff, d_model)


def init_moe(cfg: ArchConfig, key, dtype) -> MoEParams:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    k0, k1, k2, k3 = jax.random.split(key, 4)
    s = d ** -0.5
    return MoEParams(
        router=(jax.random.normal(k0, (d, e)) * s).astype(dtype),
        w_gate=(jax.random.normal(k1, (e, d, f)) * s).astype(dtype),
        w_up=(jax.random.normal(k2, (e, d, f)) * s).astype(dtype),
        w_down=(jax.random.normal(k3, (e, f, d)) * (f ** -0.5)).astype(dtype),
    )


def _maybe_constrain(x: jax.Array, spec) -> jax.Array:
    """Sharding constraint that degrades to a no-op outside a mesh."""
    try:
        from jax.sharding import PartitionSpec as P
        return jax.lax.with_sharding_constraint(x, P(*spec))
    except Exception:
        return x


def moe_forward(cfg: ArchConfig, p: MoEParams, x: jax.Array,
                capacity_factor: float = None) -> jax.Array:
    """Default MoE forward: shard-local scatter dispatch.

    Tokens are viewed as (D, t_local, d) where D = ``cfg.moe_data_shards``
    (the data-axis width used for the dry-run; 1 on a single host — the
    algorithm is pure reshape semantics either way).  Routing, capacity
    positions and the dispatch scatter are all *local to a data shard*;
    only the expert computation is expert-sharded ("model" axis), so the
    per-layer communication is O(activations), not O(t*e*c) like the
    one-hot einsum dispatch (kept as :func:`moe_forward_einsum`) that made
    the arctic baseline collective-bound (§Perf log).
    """
    if getattr(cfg, "moe_impl", "scatter") == "einsum":
        return moe_forward_einsum(cfg, p, x, capacity_factor)
    if capacity_factor is None:
        capacity_factor = cfg.moe_capacity_factor
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    t = b * s
    D = max(1, getattr(cfg, "moe_data_shards", 1))
    if t % D:
        D = 1
    tl = t // D
    xt = x.reshape(D, tl, d)
    xt = _maybe_constrain(xt, ("data", None, None)) if D > 1 else xt

    logits = jnp.einsum("Dtd,de->Dte", xt, p.router).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, experts = jax.lax.top_k(probs, k)             # (D, tl, k)
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9)
    cap = max(1, int(math.ceil(capacity_factor * k * tl / e)))

    onehot = jax.nn.one_hot(experts, e, dtype=jnp.int32)     # (D, tl, k, e)
    flat = onehot.reshape(D, tl * k, e)
    pos = (jnp.cumsum(flat, axis=1) - flat).reshape(D, tl, k, e)
    pos = (pos * onehot).sum(-1)                             # (D, tl, k)
    keep = pos < cap
    slot = experts * cap + jnp.minimum(pos, cap - 1)
    slot = jnp.where(keep, slot, e * cap).reshape(D, tl * k)

    src = jnp.broadcast_to(xt[:, :, None, :],
                           (D, tl, k, d)).reshape(D, tl * k, d)
    buf = jnp.zeros((D, e * cap + 1, d), dtype=x.dtype)
    if D > 1:
        # keep the scatter shard-local: src, indices and buffer all live on
        # the data axis
        buf = _maybe_constrain(buf, ("data", None, None))
        src = _maybe_constrain(src, ("data", None, None))
    buf = buf.at[jnp.arange(D)[:, None], slot].set(src)
    if D > 1:
        buf = _maybe_constrain(buf, ("data", None, None))
    xe = buf[:, :e * cap].reshape(D, e, cap, d)
    if D > 1:
        xe = _maybe_constrain(xe, ("data", "model", None, None))

    g = jnp.einsum("Decd,edf->Decf", xe, p.w_gate)
    u = jnp.einsum("Decd,edf->Decf", xe, p.w_up)
    ye = jnp.einsum("Decf,efd->Decd", jax.nn.silu(g) * u, p.w_down,
                    preferred_element_type=x.dtype)
    if D > 1:
        ye = _maybe_constrain(ye, ("data", "model", None, None))

    ye_flat = jnp.concatenate(
        [ye.reshape(D, e * cap, d),
         jnp.zeros((D, 1, d), dtype=ye.dtype)], axis=1)
    y_tok = ye_flat[jnp.arange(D)[:, None], slot].reshape(D, tl, k, d)
    w = (gate_vals * keep).astype(y_tok.dtype)
    yt = jnp.einsum("Dtkd,Dtk->Dtd", y_tok, w)
    return yt.reshape(b, s, d)


def moe_forward_einsum(cfg: ArchConfig, p: MoEParams, x: jax.Array,
                       capacity_factor: float = None) -> jax.Array:
    """Classic one-hot dispatch/combine einsum MoE (baseline)."""
    if capacity_factor is None:
        capacity_factor = cfg.moe_capacity_factor
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    t = b * s
    xt = x.reshape(t, d)
    logits = jnp.einsum("td,de->te", xt, p.router).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, experts = jax.lax.top_k(probs, k)             # (t, k)
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9)
    capacity = max(1, int(math.ceil(capacity_factor * k * t / e)))

    # position of each (token, choice) within its expert's capacity buffer
    onehot = jax.nn.one_hot(experts, e, dtype=jnp.int32)     # (t, k, e)
    flat = onehot.reshape(t * k, e)
    pos_in_expert = (jnp.cumsum(flat, axis=0) - flat).reshape(t, k, e)
    pos = (pos_in_expert * onehot).sum(-1)                   # (t, k)
    keep = pos < capacity
    slot = experts * capacity + jnp.minimum(pos, capacity - 1)  # (t, k)
    slot = jnp.where(keep, slot, e * capacity)               # drop -> pad row

    # scatter tokens into (e*c, d) expert buffers (pad row absorbs drops)
    buf = jnp.zeros((e * capacity + 1, d), dtype=x.dtype)
    src = jnp.broadcast_to(xt[:, None, :], (t, k, d)).reshape(t * k, d)
    buf = buf.at[slot.reshape(t * k)].set(src)
    xe = buf[:e * capacity].reshape(e, capacity, d)

    g = jnp.einsum("ecd,edf->ecf", xe, p.w_gate)
    u = jnp.einsum("ecd,edf->ecf", xe, p.w_up)
    ye = jnp.einsum("ecf,efd->ecd", jax.nn.silu(g) * u, p.w_down)

    # gather back + combine with gate weights
    ye_flat = jnp.concatenate(
        [ye.reshape(e * capacity, d),
         jnp.zeros((1, d), dtype=ye.dtype)], axis=0)
    y_tok = ye_flat[slot.reshape(t * k)].reshape(t, k, d)
    w = (gate_vals * keep).astype(y_tok.dtype)               # (t, k)
    yt = jnp.einsum("tkd,tk->td", y_tok, w)
    return yt.reshape(b, s, d)


def moe_forward_einsum(cfg: ArchConfig, p: MoEParams, x: jax.Array,
                       capacity_factor: float = None) -> jax.Array:
    """Classic one-hot dispatch/combine einsum MoE (baseline)."""
    if capacity_factor is None:
        capacity_factor = cfg.moe_capacity_factor
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    t = b * s
    xt = x.reshape(t, d)
    logits = jnp.einsum("td,de->te", xt, p.router).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, experts = jax.lax.top_k(probs, k)             # (t, k)
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9)
    capacity = max(1, int(math.ceil(capacity_factor * k * t / e)))

    # position of each (token, choice) within its expert's buffer
    onehot = jax.nn.one_hot(experts, e, dtype=jnp.int32)     # (t, k, e)
    flat = onehot.reshape(t * k, e)
    pos_in_expert = (jnp.cumsum(flat, axis=0) - flat).reshape(t, k, e)
    pos = (pos_in_expert * onehot).sum(-1)                   # (t, k)
    keep = pos < capacity

    # dispatch tensor: (t, k, e, c) one-hot -> combine weights
    dispatch = (jax.nn.one_hot(experts, e, dtype=x.dtype)[..., None] *
                jax.nn.one_hot(pos, capacity, dtype=x.dtype)[..., None, :])
    dispatch = dispatch * keep[..., None, None].astype(x.dtype)
    combine = dispatch * gate_vals[..., None, None].astype(x.dtype)
    dispatch = dispatch.sum(axis=1)                          # (t, e, c)
    combine = combine.sum(axis=1)                            # (t, e, c)

    xe = jnp.einsum("td,tec->ecd", xt, dispatch)             # (e, c, d)
    g = jnp.einsum("ecd,edf->ecf", xe, p.w_gate)
    u = jnp.einsum("ecd,edf->ecf", xe, p.w_up)
    ye = jnp.einsum("ecf,efd->ecd", jax.nn.silu(g) * u, p.w_down)
    yt = jnp.einsum("ecd,tec->td", ye, combine)
    return yt.reshape(b, s, d)


def aux_load_balance_loss(logits: jax.Array, experts: jax.Array,
                          e: int) -> jax.Array:
    """Switch-style load-balancing auxiliary loss."""
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    density = jax.nn.one_hot(experts[..., 0], e).mean(axis=0)
    density_proxy = probs.mean(axis=0)
    return e * jnp.sum(density * density_proxy)
