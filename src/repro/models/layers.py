"""Shared neural-net layers (pure-functional JAX)."""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def rmsnorm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * (1.0 + scale.astype(jnp.float32))).astype(dtype)


def swiglu(x: jax.Array, w_gate: jax.Array, w_up: jax.Array,
           w_down: jax.Array) -> jax.Array:
    g = jnp.einsum("...d,df->...f", x, w_gate)
    u = jnp.einsum("...d,df->...f", x, w_up)
    # activation-dtype partial sums: the TP all-reduce of the down-proj
    # output runs at bf16 instead of f32 (§Perf)
    return jnp.einsum("...f,fd->...d", jax.nn.silu(g) * u, w_down,
                      preferred_element_type=x.dtype)


def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2,
                                       dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array,
               theta: float = 10000.0) -> jax.Array:
    """Rotary embedding. x: (..., S, D); positions: (S,) or broadcastable."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                       # (D/2,)
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (S, D/2)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin],
                          axis=-1)
    return out.astype(x.dtype)


def softcap(x: jax.Array, cap: float) -> jax.Array:
    if cap <= 0.0:
        return x
    return cap * jnp.tanh(x / cap)


def cross_entropy(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """Mean token-level cross entropy; logits (..., V), labels (...) int."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None],
                               axis=-1).squeeze(-1)
    return jnp.mean(logz - gold)
