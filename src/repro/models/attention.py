"""Attention layers: blockwise XLA implementation + Pallas fast path.

The XLA path (``chunked_attention``) is an online-softmax scan over kv
blocks — memory-bounded (never materializes S x S), shardable under
pjit/GSPMD (heads on the "model" axis, batch/sequence on "data"), and lowers
on every backend, so it is what the distributed train/serve steps and the
multi-pod dry-run use.  On a real TPU the Pallas flash-attention kernel
(``repro.kernels.flash_attention``) replaces it 1:1.
"""

from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from .layers import apply_rope, softcap

_NEG_INF = -1e30


def chunked_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                      causal: bool = True, window: int = 0,
                      attn_softcap: float = 0.0, chunk: int = 1024,
                      q_offset: int = 0) -> jax.Array:
    """Online-softmax attention, scanning kv in blocks.

    q: (B, Hq, Sq, D); k, v: (B, Hkv, Skv, D); GQA via head folding.
    ``q_offset`` places the query block at absolute positions
    ``q_offset + [0..Sq)`` (used by decode with a KV cache).
    """
    b, hq, sq, d = q.shape
    _, hkv, skv, _ = k.shape
    group = hq // hkv
    qg = q.reshape(b, hkv, group, sq, d)
    scale = 1.0 / (d ** 0.5)
    if sq == 1:
        # decode fast path: one softmax over the (possibly seq-sharded) KV
        # cache — scores are (B, H, 1, S); the PV contraction reduces over
        # the sharded seq dim with a tiny (B, H, 1, D) partial-sum
        # all-reduce instead of gathering K/V chunks (§Perf, jamba decode)
        s = jnp.einsum("bhgqd,bhkd->bhgqk", qg, k).astype(jnp.float32)
        s = s * scale
        if attn_softcap > 0.0:
            s = softcap(s, attn_softcap)
        k_pos = jnp.arange(skv)
        q_pos = q_offset + jnp.zeros((), jnp.int32)
        mask = jnp.ones((skv,), dtype=bool)
        if causal:
            mask &= q_pos >= k_pos
        if window > 0:
            mask &= (q_pos - k_pos) < window
        s = jnp.where(mask[None, None, None, None, :], s, _NEG_INF)
        p = jax.nn.softmax(s, axis=-1)
        out = jnp.einsum("bhgqk,bhkd->bhgqd", p.astype(v.dtype), v)
        return out.reshape(b, hq, sq, d).astype(q.dtype)
    chunk = min(chunk, skv)
    # pad kv to a multiple of chunk with masked slots
    pad = (-skv) % chunk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
    nkv = (skv + pad) // chunk
    kb = k.reshape(b, hkv, nkv, chunk, d).transpose(2, 0, 1, 3, 4)
    vb = v.reshape(b, hkv, nkv, chunk, d).transpose(2, 0, 1, 3, 4)
    q_pos = q_offset + jnp.arange(sq)

    def step(carry, inp):
        m_run, l_run, acc = carry
        kc, vc, j = inp
        s = jnp.einsum("bhgqd,bhkd->bhgqk", qg, kc).astype(jnp.float32)
        s = s * scale
        if attn_softcap > 0.0:
            s = softcap(s, attn_softcap)
        k_pos = j * chunk + jnp.arange(chunk)
        mask = jnp.ones((sq, chunk), dtype=bool)
        if causal:
            mask &= q_pos[:, None] >= k_pos[None, :]
        if window > 0:
            mask &= (q_pos[:, None] - k_pos[None, :]) < window
        mask &= (k_pos < skv)[None, :]
        s = jnp.where(mask[None, None, None], s, _NEG_INF)
        m_new = jnp.maximum(m_run, s.max(axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_run - m_new)
        l_new = l_run * alpha + p.sum(axis=-1, keepdims=True)
        acc = acc * alpha + jnp.einsum("bhgqk,bhkd->bhgqd",
                                       p.astype(vc.dtype), vc)
        return (m_new, l_new, acc), None

    m0 = jnp.full((b, hkv, group, sq, 1), _NEG_INF, dtype=jnp.float32)
    l0 = jnp.zeros((b, hkv, group, sq, 1), dtype=jnp.float32)
    a0 = jnp.zeros((b, hkv, group, sq, d), dtype=jnp.float32)
    # checkpoint per kv block: backward recomputes the S x chunk softmax
    # instead of storing it (flash-attention memory behaviour in pure XLA)
    (m_f, l_f, acc), _ = jax.lax.scan(
        jax.checkpoint(step), (m0, l0, a0), (kb, vb, jnp.arange(nkv)))
    out = acc / jnp.maximum(l_f, 1e-30)
    return out.reshape(b, hq, sq, d).astype(q.dtype)


class AttnParams(NamedTuple):
    wq: jax.Array   # (d_model, Hq * D)
    wk: jax.Array   # (d_model, Hkv * D)
    wv: jax.Array   # (d_model, Hkv * D)
    wo: jax.Array   # (Hq * D, d_model)


def init_attn(cfg: ArchConfig, key, dtype) -> AttnParams:
    d, hd = cfg.d_model, cfg.head_dim_
    k1, k2, k3, k4 = jax.random.split(key, 4)
    s = d ** -0.5
    return AttnParams(
        wq=(jax.random.normal(k1, (d, cfg.n_heads * hd)) * s).astype(dtype),
        wk=(jax.random.normal(k2, (d, cfg.n_kv_heads * hd)) * s).astype(dtype),
        wv=(jax.random.normal(k3, (d, cfg.n_kv_heads * hd)) * s).astype(dtype),
        wo=(jax.random.normal(k4, (cfg.n_heads * hd, d)) * s).astype(dtype),
    )


def attn_forward(cfg: ArchConfig, p: AttnParams, x: jax.Array, *,
                 window: int = 0, positions: Optional[jax.Array] = None,
                 kv_cache: Optional[Tuple[jax.Array, jax.Array]] = None,
                 cache_index: Optional[jax.Array] = None,
                 mask_offset: Optional[jax.Array] = None):
    """Self-attention with optional KV cache.

    Training/prefill: ``kv_cache=None`` — full-sequence causal attention.
    Decode: ``kv_cache=(K, V)`` of shape (B, Hkv, S_ctx, D); the current
    token's k/v are written at ring slot ``cache_index``; ``mask_offset``
    (default: ``cache_index``) is the highest cache slot considered "past" —
    callers with a wrapped ring buffer pass ``S_ctx - 1`` to attend every
    slot.  Returns (output, updated cache).
    """
    b, s, d = x.shape
    hd = cfg.head_dim_
    q = jnp.einsum("bsd,de->bse", x, p.wq).reshape(b, s, cfg.n_heads, hd)
    k = jnp.einsum("bsd,de->bse", x, p.wk).reshape(b, s, cfg.n_kv_heads, hd)
    v = jnp.einsum("bsd,de->bse", x, p.wv).reshape(b, s, cfg.n_kv_heads, hd)
    if positions is None:
        positions = jnp.arange(s) if cache_index is None \
            else cache_index + jnp.arange(s)
    q = apply_rope(q.transpose(0, 2, 1, 3), positions, cfg.rope_theta)
    k = apply_rope(k.transpose(0, 2, 1, 3), positions, cfg.rope_theta)
    v = v.transpose(0, 2, 1, 3)
    new_cache = None
    if kv_cache is None:
        out = chunked_attention(q, k, v, causal=cfg.causal, window=window,
                                attn_softcap=cfg.attn_softcap)
    else:
        ck, cv = kv_cache
        ck = jax.lax.dynamic_update_slice_in_dim(ck, k.astype(ck.dtype),
                                                 cache_index, axis=2)
        cv = jax.lax.dynamic_update_slice_in_dim(cv, v.astype(cv.dtype),
                                                 cache_index, axis=2)
        new_cache = (ck, cv)
        off = cache_index if mask_offset is None else mask_offset
        out = chunked_attention(q, ck.astype(q.dtype), cv.astype(q.dtype),
                                causal=True, window=window,
                                attn_softcap=cfg.attn_softcap,
                                q_offset=off)
    out = out.transpose(0, 2, 1, 3).reshape(b, s, cfg.n_heads * hd)
    # preferred_element_type pins the cross-shard partial-sum (and its TP
    # all-reduce) to the activation dtype instead of f32 (§Perf: halves
    # the dominant collective for TP configs)
    return jnp.einsum("bse,ed->bsd", out, p.wo,
                      preferred_element_type=out.dtype), new_cache
