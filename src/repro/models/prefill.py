"""Prefill forward: populate decode caches + last-token logits.

Unlike ``forward`` (training: full logits), prefill returns only the final
position's logits — materializing (B, S, V) logits at 32k context would be
absurd — plus the KV caches / SSM states the decode loop continues from.
Window-capped caches keep the last ``ctx`` positions; all prefill lengths in
the assignment are multiples of every cap, so ring slots align
(slot = position % ctx).
"""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from .attention import AttnParams, attn_forward
from .layers import rmsnorm
from .mamba import MambaParams, mamba_forward
from .moe import MoEParams, moe_forward
from .transformer import Params, _head, _embed
from .layers import swiglu


def _prefill_block(cfg: ArchConfig, spec, p, x):
    """Like _block_forward but returns the kv/state produced."""
    h = rmsnorm(x, p["ln1"])
    if spec.mixer == "attn":
        ap = p["attn"] if isinstance(p["attn"], AttnParams) \
            else AttnParams(*p["attn"])
        window = spec.window
        if cfg.long_context_kv_cap and x.shape[1] > cfg.long_context_kv_cap:
            window = min(window or cfg.long_context_kv_cap,
                         cfg.long_context_kv_cap)
        y, _ = attn_forward(cfg, ap, h, window=window)
        # recompute k/v for the cache (cheap vs attention itself)
        b, s, _ = h.shape
        hd = cfg.head_dim_
        from .layers import apply_rope
        k = jnp.einsum("bsd,de->bse", h, ap.wk).reshape(
            b, s, cfg.n_kv_heads, hd).transpose(0, 2, 1, 3)
        k = apply_rope(k, jnp.arange(s), cfg.rope_theta)
        v = jnp.einsum("bsd,de->bse", h, ap.wv).reshape(
            b, s, cfg.n_kv_heads, hd).transpose(0, 2, 1, 3)
        ctx = s
        if cfg.long_context_kv_cap and s > cfg.long_context_kv_cap:
            ctx = cfg.long_context_kv_cap
        if spec.window:
            ctx = min(ctx, spec.window)
        cache = (k[:, :, -ctx:, :], v[:, :, -ctx:, :])
    else:
        y, state = mamba_forward(cfg, MambaParams(*p["ssm"]), h,
                                 return_state=True)
        cache = state
    x = x + y
    if spec.ffn == "none":
        return x, cache
    h = rmsnorm(x, p["ln2"])
    y = 0.0
    if spec.ffn in ("dense", "moe+dense"):
        y = y + swiglu(h, p["mlp"]["w_gate"], p["mlp"]["w_up"],
                       p["mlp"]["w_down"])
    if spec.ffn in ("moe", "moe+dense"):
        y = y + moe_forward(cfg, MoEParams(*p["moe"]), h)
    return x + y, cache


def prefill(cfg: ArchConfig, params: Params, inputs: jax.Array
            ) -> Tuple[jax.Array, Dict[str, Any]]:
    """Returns (last-token logits (B, 1, V), decode caches)."""
    x = _embed(cfg, params, inputs)

    def period(x, pblocks):
        caches = {}
        for pi, spec in enumerate(cfg.block_pattern):
            x, c = _prefill_block(cfg, spec, pblocks[f"p{pi}"], x)
            caches[f"p{pi}"] = c
        return x, caches

    x, caches = jax.lax.scan(period, x, params["blocks"])
    logits = _head(cfg, params, x[:, -1:, :])
    return logits, caches
