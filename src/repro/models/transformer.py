"""Model assembly: embedding -> scanned block periods -> tied head.

Layers are executed as ``lax.scan`` over *pattern periods* (the repeating
block pattern of the config: length 1 for dense archs, 2 for gemma2, 8 for
jamba).  Parameters are stacked with a leading ``n_periods`` dimension per
pattern position — this keeps compile time flat in depth (crucial for the
40-cell x 2-mesh dry-run) and is the standard production layout for big
JAX models.

Three entry points:

* ``forward_train``  — full-sequence causal (or bidirectional) forward
* ``decode_step``    — one token with KV caches / SSM states
* ``init_params`` / ``init_decode_state`` — parameter & cache construction
  (both usable under ``jax.eval_shape`` for the dry-run).
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig, LayerSpec
from .attention import AttnParams, attn_forward, init_attn
from .layers import cross_entropy, rmsnorm, softcap, swiglu
from .mamba import MambaParams, init_mamba, init_ssm_state, mamba_forward
from .moe import MoEParams, init_moe, moe_forward

Params = Dict[str, Any]


def n_periods(cfg: ArchConfig) -> int:
    period = len(cfg.block_pattern)
    assert cfg.n_layers % period == 0, (cfg.n_layers, period)
    return cfg.n_layers // period


# ----------------------------------------------------------------- params --

def _init_block(cfg: ArchConfig, spec: LayerSpec, key, dtype) -> Params:
    keys = jax.random.split(key, 4)
    p: Params = {"ln1": jnp.zeros((cfg.d_model,), dtype=dtype)}
    if spec.mixer == "attn":
        p["attn"] = init_attn(cfg, keys[0], dtype)
    else:
        p["ssm"] = init_mamba(cfg, keys[0], dtype)
    if spec.ffn != "none":
        p["ln2"] = jnp.zeros((cfg.d_model,), dtype=dtype)
    if spec.ffn in ("dense", "moe+dense"):
        s = cfg.d_model ** -0.5
        p["mlp"] = {
            "w_gate": (jax.random.normal(keys[1],
                                         (cfg.d_model, cfg.d_ff)) * s
                       ).astype(dtype),
            "w_up": (jax.random.normal(keys[2],
                                       (cfg.d_model, cfg.d_ff)) * s
                     ).astype(dtype),
            "w_down": (jax.random.normal(keys[3], (cfg.d_ff, cfg.d_model))
                       * cfg.d_ff ** -0.5).astype(dtype),
        }
    if spec.ffn in ("moe", "moe+dense"):
        p["moe"] = init_moe(cfg, keys[1], dtype)
    return p


def init_params(cfg: ArchConfig, key, dtype=jnp.bfloat16) -> Params:
    k_embed, k_blocks, k_head = jax.random.split(key, 3)
    params: Params = {}
    if cfg.frontend == "none":
        params["embed"] = (jax.random.normal(
            k_embed, (cfg.vocab, cfg.d_model)) * cfg.d_model ** -0.5
        ).astype(dtype)
    else:
        # modality frontend stub: linear projection of precomputed embeddings
        params["frontend_proj"] = (jax.random.normal(
            k_embed, (cfg.frontend_dim, cfg.d_model))
            * cfg.frontend_dim ** -0.5).astype(dtype)
        params["head"] = (jax.random.normal(
            k_head, (cfg.d_model, cfg.vocab)) * cfg.d_model ** -0.5
        ).astype(dtype)
    np_ = n_periods(cfg)
    block_keys = jax.random.split(k_blocks, np_ * len(cfg.block_pattern))
    per_position = []
    for pi, spec in enumerate(cfg.block_pattern):
        stacked = [
            _init_block(cfg, spec, block_keys[per * len(cfg.block_pattern)
                                              + pi], dtype)
            for per in range(np_)
        ]
        per_position.append(jax.tree_util.tree_map(
            lambda *xs: jnp.stack(xs), *stacked))
    params["blocks"] = {f"p{pi}": blk for pi, blk in
                        enumerate(per_position)}
    params["final_norm"] = jnp.zeros((cfg.d_model,), dtype=dtype)
    return params


# ---------------------------------------------------------------- forward --

def _block_forward(cfg: ArchConfig, spec: LayerSpec, p: Params,
                   x: jax.Array, *, window_cap: int = 0,
                   cache: Optional[Any] = None,
                   cache_index: Optional[jax.Array] = None,
                   positions: Optional[jax.Array] = None,
                   mask_offset: Optional[jax.Array] = None):
    new_cache = None
    h = rmsnorm(x, p["ln1"])
    if spec.mixer == "attn":
        window = spec.window
        if window_cap:
            window = min(window or window_cap, window_cap)
        ap = p["attn"] if isinstance(p["attn"], AttnParams) \
            else AttnParams(*p["attn"])
        y, kv = attn_forward(cfg, ap, h, window=window,
                             positions=positions, kv_cache=cache,
                             cache_index=cache_index,
                             mask_offset=mask_offset)
        new_cache = kv
    else:
        if cache is not None:
            y, st = mamba_forward(cfg, MambaParams(*p["ssm"]), h,
                                  state=cache, return_state=True)
            new_cache = st
        else:
            y = mamba_forward(cfg, MambaParams(*p["ssm"]), h)
    x = x + y
    if spec.ffn == "none":
        return x, new_cache
    h = rmsnorm(x, p["ln2"])
    y = 0.0
    if spec.ffn in ("dense", "moe+dense"):
        y = y + swiglu(h, p["mlp"]["w_gate"], p["mlp"]["w_up"],
                       p["mlp"]["w_down"])
    if spec.ffn in ("moe", "moe+dense"):
        y = y + moe_forward(cfg, MoEParams(*p["moe"]), h)
    return x + y, new_cache


def _embed(cfg: ArchConfig, params: Params, inputs: jax.Array) -> jax.Array:
    if cfg.frontend == "none":
        return params["embed"][inputs]
    return jnp.einsum("bsf,fd->bsd", inputs, params["frontend_proj"])


def _head(cfg: ArchConfig, params: Params, x: jax.Array) -> jax.Array:
    x = rmsnorm(x, params["final_norm"])
    if cfg.frontend == "none":
        logits = jnp.einsum("bsd,vd->bsv", x, params["embed"])
    else:
        logits = jnp.einsum("bsd,dv->bsv", x, params["head"])
    return softcap(logits, cfg.logit_softcap)


def _constrain(x: jax.Array, act_spec) -> jax.Array:
    if act_spec is None:
        return x
    return jax.lax.with_sharding_constraint(x, act_spec)


def _remat_policy(name):
    if name is None or name == "full":
        return None
    if name == "dots":
        return jax.checkpoint_policies.dots_with_no_batch_dims_saveable
    raise ValueError(name)


def forward_hidden(cfg: ArchConfig, params: Params, inputs: jax.Array,
                   *, window_cap: int = 0, remat: bool = False,
                   remat_policy=None, act_spec=None) -> jax.Array:
    """Embedding + scanned blocks + final norm -> hidden states (B, S, d).

    ``remat=True`` applies per-period activation checkpointing: the scan
    stores only the carried hidden state and recomputes block internals in
    the backward pass (keeps the memory term off the attention S^2 and MoE
    dispatch intermediates).

    ``act_spec`` (a PartitionSpec for (B, S, d)) pins the hidden-state
    sharding at every period boundary — without it GSPMD may keep
    activations replicated and turn the FSDP weight sharding into per-matmul
    partial-sum all-reduces (observed in the dry-run baseline).
    """
    x = _constrain(_embed(cfg, params, inputs), act_spec)

    def period(x, pblocks):
        for pi, spec in enumerate(cfg.block_pattern):
            x, _ = _block_forward(cfg, spec, pblocks[f"p{pi}"], x,
                                  window_cap=window_cap)
            x = _constrain(x, act_spec)
        return x, None

    if remat:
        pol = _remat_policy(remat_policy)
        fn = jax.checkpoint(period, policy=pol) if pol is not None \
            else jax.checkpoint(period)
    else:
        fn = period
    x, _ = jax.lax.scan(fn, x, params["blocks"])
    return rmsnorm(x, params["final_norm"])


def forward(cfg: ArchConfig, params: Params, inputs: jax.Array,
            *, window_cap: int = 0, remat: bool = False) -> jax.Array:
    """Full-sequence forward -> logits (B, S, V)."""
    x = forward_hidden(cfg, params, inputs, window_cap=window_cap,
                       remat=remat)
    return _head_logits(cfg, params, x)


def _head_logits(cfg: ArchConfig, params: Params, x: jax.Array) -> jax.Array:
    if cfg.frontend == "none":
        logits = jnp.einsum("bsd,vd->bsv", x, params["embed"])
    else:
        logits = jnp.einsum("bsd,dv->bsv", x, params["head"])
    return softcap(logits, cfg.logit_softcap)


def chunked_cross_entropy(cfg: ArchConfig, params: Params, x: jax.Array,
                          labels: jax.Array, chunk: int = 512) -> jax.Array:
    """Sequence-chunked CE: never materializes full (B, S, V) logits.

    Each chunk's logits are produced, reduced to (logZ - gold) and
    discarded; ``jax.checkpoint`` makes the backward recompute them
    chunk-by-chunk as well.
    """
    b, s, d = x.shape
    chunk = min(chunk, s)
    assert s % chunk == 0, (s, chunk)
    nch = s // chunk
    xs = jnp.moveaxis(x.reshape(b, nch, chunk, d), 1, 0)
    ls = jnp.moveaxis(labels.reshape(b, nch, chunk), 1, 0)

    @jax.checkpoint
    def step(acc, inp):
        xc, lc = inp
        logits = _head_logits(cfg, params, xc).astype(jnp.float32)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, lc[..., None],
                                   axis=-1).squeeze(-1)
        return acc + jnp.sum(logz - gold), None

    total, _ = jax.lax.scan(step, jnp.zeros((), jnp.float32), (xs, ls))
    return total / (b * s)


def loss_fn(cfg: ArchConfig, params: Params, inputs: jax.Array,
            labels: jax.Array, *, act_spec=None,
            remat_policy=None) -> jax.Array:
    x = forward_hidden(cfg, params, inputs, remat=True,
                       remat_policy=remat_policy, act_spec=act_spec)
    return chunked_cross_entropy(cfg, params, x, labels)


# ----------------------------------------------------------------- decode --

def init_decode_state(cfg: ArchConfig, batch: int, ctx_len: int,
                      dtype=jnp.bfloat16) -> Dict[str, Any]:
    """KV caches / SSM states stacked over periods, per pattern position."""
    np_ = n_periods(cfg)
    hd = cfg.head_dim_
    caches: Dict[str, Any] = {}
    for pi, spec in enumerate(cfg.block_pattern):
        if spec.mixer == "attn":
            ctx = ctx_len
            if cfg.long_context_kv_cap and ctx_len > cfg.long_context_kv_cap:
                ctx = cfg.long_context_kv_cap
            if spec.window:
                ctx = min(ctx, max(spec.window, 1))
            shape = (np_, batch, cfg.n_kv_heads, ctx, hd)
            caches[f"p{pi}"] = (jnp.zeros(shape, dtype=dtype),
                                jnp.zeros(shape, dtype=dtype))
        else:
            caches[f"p{pi}"] = jnp.zeros(
                (np_, batch, cfg.ssm_heads, cfg.ssm_head_dim,
                 cfg.ssm_state), dtype=jnp.float32)
    return caches


def decode_step(cfg: ArchConfig, params: Params, caches: Dict[str, Any],
                token: jax.Array, index: jax.Array):
    """One decode step.  token: (B, 1) int (or (B, 1, F) frames).

    Returns (logits (B, 1, V), new caches).  ``index`` is the absolute
    position; attention caches with capped context store at
    ``index % ctx`` (ring buffer).
    """
    x = _embed(cfg, params, token)

    def period(x, inp):
        pblocks, pcaches = inp
        new = {}
        for pi, spec in enumerate(cfg.block_pattern):
            cache = pcaches[f"p{pi}"]
            if spec.mixer == "attn":
                ctx = cache[0].shape[2]
                idx = index % ctx                      # ring slot
                moff = jnp.minimum(index, ctx - 1)     # wrapped => attend all
                pos = index[None] if index.ndim == 0 else index
            else:
                idx, moff, pos = None, None, None
            x, nc = _block_forward(cfg, spec, pblocks[f"p{pi}"], x,
                                   cache=cache, cache_index=idx,
                                   positions=pos, mask_offset=moff)
            new[f"p{pi}"] = nc
        return x, new

    x, new_caches = jax.lax.scan(period, x, (params["blocks"], caches))
    return _head(cfg, params, x), new_caches
