"""Mamba-2 (SSD) block — pure-JAX chunked scan + O(1) decode step.

The chunked formulation mirrors the Pallas kernel in
``repro.kernels.ssd`` (which replaces the inner computation on real TPU):
within chunks the recurrence is a masked decay-weighted matmul (MXU work),
across chunks a (H, P, N) state is carried by ``lax.scan``.  Decode keeps
the state explicitly — O(1) per token, which is what makes ``long_500k``
runnable for SSM/hybrid architectures.
"""

from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig


class MambaParams(NamedTuple):
    in_proj: jax.Array    # (d_model, 2*d_inner + 2*G*N + H)
    a_log: jax.Array      # (H,)
    d_skip: jax.Array     # (H,)
    dt_bias: jax.Array    # (H,)
    norm_g: jax.Array     # (d_inner,) gated rmsnorm scale
    out_proj: jax.Array   # (d_inner, d_model)


def init_mamba(cfg: ArchConfig, key, dtype) -> MambaParams:
    d, di = cfg.d_model, cfg.d_inner
    g, n, h = cfg.ssm_groups, cfg.ssm_state, cfg.ssm_heads
    k1, k2, k3 = jax.random.split(key, 3)
    s = d ** -0.5
    width = 2 * di + 2 * g * n + h
    return MambaParams(
        in_proj=(jax.random.normal(k1, (d, width)) * s).astype(dtype),
        a_log=jnp.zeros((h,), dtype=jnp.float32),
        d_skip=jnp.ones((h,), dtype=jnp.float32),
        dt_bias=jnp.full((h,), -2.0, dtype=jnp.float32),
        norm_g=jnp.zeros((di,), dtype=dtype),
        out_proj=(jax.random.normal(k3, (di, d)) * di ** -0.5).astype(dtype),
    )


def _split_proj(cfg: ArchConfig, zxbcdt: jax.Array):
    di, g, n, h = (cfg.d_inner, cfg.ssm_groups, cfg.ssm_state,
                   cfg.ssm_heads)
    z, x, bc, dt = jnp.split(zxbcdt, [di, 2 * di, 2 * di + 2 * g * n],
                             axis=-1)
    b, c = jnp.split(bc, 2, axis=-1)
    return z, x, b, c, dt


def _ssd_chunked(x, dt, a, b, c, chunk: int, h0=None):
    """x: (B,L,H,P); dt: (B,L,H); a: (H,); b/c: (B,L,G,N).

    Returns (y (B,L,H,P), h_final (B,H,P,N)).
    """
    B, L, H, P = x.shape
    G, N = b.shape[2], b.shape[3]
    rep = H // G
    nch = L // chunk
    xr = x.reshape(B, nch, chunk, H, P)
    dtr = dt.reshape(B, nch, chunk, H)
    br = jnp.repeat(b, rep, axis=2).reshape(B, nch, chunk, H, N)
    cr = jnp.repeat(c, rep, axis=2).reshape(B, nch, chunk, H, N)

    idx = jnp.arange(chunk)
    tri = idx[:, None] >= idx[None, :]

    def step(h, inp):
        xc, dtc, bc_, cc = inp      # (B,chunk,H,P), (B,chunk,H), (B,chunk,H,N)
        s = jnp.cumsum(a[None, None, :] * dtc, axis=1)       # (B,chunk,H)
        seg = s[:, :, None, :] - s[:, None, :, :]            # (B,q,q,H)
        decay = jnp.where(tri[None, :, :, None], jnp.exp(seg), 0.0)
        gmat = jnp.einsum("bihn,bjhn->bijh", cc, bc_) * decay \
            * dtc[:, None, :, :]
        y = jnp.einsum("bijh,bjhp->bihp", gmat, xc)
        y = y + jnp.exp(s)[..., None] * jnp.einsum("bihn,bhpn->bihp", cc, h)
        w = dtc * jnp.exp(s[:, -1:, :] - s)                  # (B,chunk,H)
        h = jnp.exp(s[:, -1])[..., None, None] * h + jnp.einsum(
            "bjhp,bjhn->bhpn", xc * w[..., None], bc_)
        return h, y

    if h0 is None:
        h0 = jnp.zeros((B, H, P, N), dtype=jnp.float32)
    hT, ys = jax.lax.scan(step, h0,
                          (jnp.moveaxis(xr, 1, 0), jnp.moveaxis(dtr, 1, 0),
                           jnp.moveaxis(br, 1, 0), jnp.moveaxis(cr, 1, 0)))
    y = jnp.moveaxis(ys, 0, 1).reshape(B, L, H, P)
    return y, hT


def mamba_forward(cfg: ArchConfig, p: MambaParams, x_in: jax.Array, *,
                  state: Optional[jax.Array] = None,
                  return_state: bool = False):
    """x_in: (B, S, d_model).  Training/prefill: state=None.

    Decode: S==1 and ``state`` (B, H, P, N) -> O(1) recurrence step.
    """
    Bsz, S, _ = x_in.shape
    h, pdim = cfg.ssm_heads, cfg.ssm_head_dim
    g, n = cfg.ssm_groups, cfg.ssm_state
    zxbcdt = jnp.einsum("bsd,dw->bsw", x_in, p.in_proj)
    z, xi, bb, cc, dt = _split_proj(cfg, zxbcdt)
    xh = xi.reshape(Bsz, S, h, pdim)
    bg = bb.reshape(Bsz, S, g, n)
    cg = cc.reshape(Bsz, S, g, n)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p.dt_bias)
    a = -jnp.exp(p.a_log)

    if S == 1 and state is not None:
        rep = h // g
        decay = jnp.exp(a[None, :] * dt[:, 0])               # (B, H)
        b1 = jnp.repeat(bg[:, 0], rep, axis=1)               # (B, H, N)
        c1 = jnp.repeat(cg[:, 0], rep, axis=1)
        new_state = state * decay[..., None, None] + (
            (dt[:, 0, :, None] * xh[:, 0])[..., None] * b1[..., None, :])
        y = jnp.einsum("bhpn,bhn->bhp", new_state, c1)[:, None]
    else:
        chunk = min(cfg.ssm_chunk, S)
        assert S % chunk == 0, (S, chunk)
        y, new_state = _ssd_chunked(xh, dt, a, bg, cg, chunk, h0=state)

    y = y + p.d_skip[None, None, :, None] * xh.astype(jnp.float32)
    y = y.reshape(Bsz, S, cfg.d_inner).astype(x_in.dtype)
    # gated RMSNorm (Mamba-2 norm before out_proj)
    yf = y.astype(jnp.float32) * jax.nn.silu(z.astype(jnp.float32))
    var = jnp.mean(yf * yf, axis=-1, keepdims=True)
    yf = yf * jax.lax.rsqrt(var + 1e-6) * (1.0 + p.norm_g.astype(jnp.float32))
    out = jnp.einsum("bsi,id->bsd", yf.astype(x_in.dtype), p.out_proj)
    if return_state:
        return out, new_state
    return out


def init_ssm_state(cfg: ArchConfig, batch: int) -> jax.Array:
    return jnp.zeros((batch, cfg.ssm_heads, cfg.ssm_head_dim,
                      cfg.ssm_state), dtype=jnp.float32)
