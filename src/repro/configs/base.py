"""Architecture configuration schema for the assigned model pool.

One declarative :class:`ArchConfig` drives model construction
(``repro.models``), input specs, sharding rules and the dry-run.  Layers are
described by a repeating *block pattern* so dense / MoE / SSM / hybrid
architectures share one code path.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field, replace
from typing import Dict, List, Optional, Sequence, Tuple


@dataclass(frozen=True)
class LayerSpec:
    """One transformer block: mixer (attention or SSM) + FFN flavour."""

    mixer: str = "attn"         # "attn" | "ssm"
    ffn: str = "dense"          # "dense" | "moe" | "moe+dense" | "none"
    window: int = 0             # sliding-window size for local attention


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                 # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None
    causal: bool = True          # False => encoder-only (hubert)
    # attention details
    rope_theta: float = 10000.0
    attn_softcap: float = 0.0
    logit_softcap: float = 0.0
    # block pattern (repeated/truncated to n_layers)
    block_pattern: Tuple[LayerSpec, ...] = (LayerSpec(),)
    # MoE
    n_experts: int = 0
    top_k: int = 2
    moe_capacity_factor: float = 1.25
    #: data-axis width for shard-local MoE dispatch (set by the launcher to
    #: the mesh's data extent; 1 = single shard, same semantics)
    moe_data_shards: int = 1
    #: "scatter" (O(t*k*d) dispatch, default) or "einsum" (one-hot O(t*e*c);
    #: best compiling config for arctic's 128 experts — EXPERIMENTS §Perf)
    moe_impl: str = "scatter"
    # SSM (Mamba-2)
    ssm_state: int = 0
    ssm_heads: int = 0
    ssm_head_dim: int = 64
    ssm_groups: int = 1
    ssm_expand: int = 2
    ssm_chunk: int = 128
    # modality frontend stub
    frontend: str = "none"       # none | audio | vision
    frontend_dim: int = 0        # precomputed frame/patch embedding dim
    # which shapes this arch supports (see DESIGN.md §4)
    shapes: Tuple[str, ...] = ("train_4k", "prefill_32k", "decode_32k")
    # long-context KV window cap for attention layers (jamba/gemma2 long_500k)
    long_context_kv_cap: int = 0

    # ------------------------------------------------------------ derived --
    @property
    def head_dim_(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    def layer_specs(self) -> List[LayerSpec]:
        pat = self.block_pattern
        return [pat[i % len(pat)] for i in range(self.n_layers)]

    def param_count(self, active_only: bool = False) -> float:
        """Approximate parameter count N (for MODEL_FLOPS = 6 N D)."""
        d, hd = self.d_model, self.head_dim_
        n = 0.0
        for spec in self.layer_specs():
            if spec.mixer == "attn":
                n += d * hd * self.n_heads            # q
                n += 2 * d * hd * self.n_kv_heads     # k, v
                n += hd * self.n_heads * d            # o
            else:  # ssm
                di, g, ns, h = (self.d_inner, self.ssm_groups,
                                self.ssm_state, self.ssm_heads)
                n += d * (2 * di + 2 * g * ns + h)    # in_proj
                n += di * d                           # out_proj
            dense_ffn = 3 * d * self.d_ff             # SwiGLU
            if spec.ffn == "dense":
                n += dense_ffn
            elif spec.ffn == "moe":
                k = self.n_experts if not active_only else self.top_k
                n += k * dense_ffn
            elif spec.ffn == "moe+dense":
                k = self.n_experts if not active_only else self.top_k
                n += k * dense_ffn + dense_ffn
            n += 2 * d                                # norms
        n += self.vocab * d                           # embed (tied head)
        return n


# -------------------------------------------------------------- shapes ----

@dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}


# ------------------------------------------------------------- registry ---

_REGISTRY: Dict[str, ArchConfig] = {}


def register(cfg: ArchConfig) -> ArchConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ArchConfig:
    import repro.configs  # noqa: F401  (ensure arch modules imported)
    return _REGISTRY[name]


def all_configs() -> Dict[str, ArchConfig]:
    import repro.configs  # noqa: F401
    return dict(_REGISTRY)


def reduced(cfg: ArchConfig, *, n_layers: int = 2, d_model: int = 64,
            d_ff: int = 128, vocab: int = 512) -> ArchConfig:
    """Smoke-test-sized config of the same family (assignment requirement)."""
    heads = max(2, min(4, cfg.n_heads))
    kv = max(1, min(heads, cfg.n_kv_heads * heads // cfg.n_heads)) or heads
    kv = heads // max(1, heads // max(1, kv))
    while heads % kv:
        kv -= 1
    kw = {}
    if cfg.n_experts:
        kw["n_experts"] = min(4, cfg.n_experts)
        # lossless capacity at smoke scale: prefill == decode numerics
        kw["moe_capacity_factor"] = float(kw["n_experts"]) / cfg.top_k
    if cfg.ssm_heads:
        kw["ssm_head_dim"] = 16
        kw["ssm_heads"] = cfg.ssm_expand * d_model // 16  # = d_inner / hd
        kw["ssm_state"] = 16
        kw["ssm_groups"] = 1
        kw["ssm_chunk"] = 32
    if cfg.frontend_dim:
        kw["frontend_dim"] = 32
    period = len(cfg.block_pattern)
    n_layers = max(n_layers, period)
    n_layers += (-n_layers) % period
    return replace(cfg, name=cfg.name + "-smoke", n_layers=n_layers,
                   d_model=d_model, n_heads=heads, n_kv_heads=kv,
                   d_ff=d_ff, vocab=vocab, head_dim=None, **kw)
