"""hubert-xlarge — encoder-only audio transformer [arXiv:2106.07447].

48L d_model=1280 16H (kv=16) d_ff=5120 vocab=504 (k-means target units).
Encoder-only (bidirectional, non-causal): no decode step — shapes limited to
train_4k and prefill_32k (encoder forward); decode_32k / long_500k skipped
(DESIGN.md §4).  The CNN waveform frontend is a STUB: ``input_specs()``
provides precomputed 20ms frame embeddings.
"""

from .base import ArchConfig, LayerSpec, register

CONFIG = register(ArchConfig(
    name="hubert-xlarge",
    family="audio",
    n_layers=48,
    d_model=1280,
    n_heads=16,
    n_kv_heads=16,
    d_ff=5120,
    vocab=504,
    causal=False,
    block_pattern=(LayerSpec(mixer="attn", ffn="dense"),),
    frontend="audio",
    frontend_dim=512,    # conv feature extractor output dim (stub)
    shapes=("train_4k", "prefill_32k"),
))
