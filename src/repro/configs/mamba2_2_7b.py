"""mamba2-2.7b — pure SSM (attention-free) LM [arXiv:2405.21060].

64L d_model=2560, vocab=50280, ssm_state=128, SSD (state-space duality).
d_ff=0: no separate FFN — the Mamba-2 block carries all per-layer compute.
Sub-quadratic state => long_500k runs (DESIGN.md §4).
"""

from .base import ArchConfig, LayerSpec, register

CONFIG = register(ArchConfig(
    name="mamba2-2.7b",
    family="ssm",
    n_layers=64,
    d_model=2560,
    n_heads=1,            # no attention heads (attn-free)
    n_kv_heads=1,
    d_ff=0,
    vocab=50280,
    block_pattern=(LayerSpec(mixer="ssm", ffn="none"),),
    ssm_state=128,
    ssm_heads=80,         # d_inner 5120 / head_dim 64
    ssm_head_dim=64,
    ssm_groups=1,
    ssm_expand=2,
    ssm_chunk=256,
    shapes=("train_4k", "prefill_32k", "decode_32k", "long_500k"),
))
