"""jamba-v0.1-52b — hybrid Mamba+attention 1:7 interleave, MoE 16e top-2
[arXiv:2403.19887; hf].

32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=65536.  Each period of 8
layers has 1 attention + 7 Mamba layers; MoE (16 experts, top-2) on every
second layer.  7/8 of layers are SSM => long_500k runs; the attention
layers use a KV window capped at 4096 for that shape (DESIGN.md §4).
"""

from .base import ArchConfig, LayerSpec, register

_P = []
for i in range(8):
    mixer = "attn" if i == 4 else "ssm"   # 1:7 attn:mamba per period
    ffn = "moe" if i % 2 == 1 else "dense"
    _P.append(LayerSpec(mixer=mixer, ffn=ffn))

CONFIG = register(ArchConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab=65536,
    block_pattern=tuple(_P),
    n_experts=16,
    top_k=2,
    moe_impl="einsum",   # beats scatter dispatch for 16 experts (§Perf)
    ssm_state=16,
    ssm_heads=128,        # d_inner 8192 / head_dim 64
    ssm_head_dim=64,
    ssm_groups=1,
    ssm_expand=2,
    ssm_chunk=256,
    shapes=("train_4k", "prefill_32k", "decode_32k", "long_500k"),
    long_context_kv_cap=4096,
))
