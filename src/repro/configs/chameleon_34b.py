"""chameleon-34b — early-fusion VLM, VQ image tokens [arXiv:2405.09818].

48L d_model=8192 64H (GQA kv=8) d_ff=22016 vocab=65536.  The modality
frontend is a STUB per the assignment: ``input_specs()`` provides
precomputed patch-token embeddings; the backbone is a dense GQA decoder over
the fused token stream.
"""

from .base import ArchConfig, LayerSpec, register

CONFIG = register(ArchConfig(
    name="chameleon-34b",
    family="vlm",
    n_layers=48,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=22016,
    vocab=65536,
    block_pattern=(LayerSpec(mixer="attn", ffn="dense"),),
    frontend="vision",
    frontend_dim=1024,   # VQ-VAE patch embedding dim (stub)
    shapes=("train_4k", "prefill_32k", "decode_32k"),
))
