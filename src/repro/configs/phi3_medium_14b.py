"""phi3-medium-14b — RoPE SwiGLU GQA dense LM [arXiv:2404.14219].

40L d_model=5120 40H (GQA kv=10) d_ff=17920 vocab=100352.
40 q-heads / 10 kv-heads: with TP > 10 the kv heads are replicated
x(tp/10) by the sharding rules (DESIGN.md §4).
Pure full attention: long_500k skipped.
"""

from .base import ArchConfig, LayerSpec, register

CONFIG = register(ArchConfig(
    name="phi3-medium-14b",
    family="dense",
    n_layers=40,
    d_model=5120,
    n_heads=40,
    n_kv_heads=10,
    d_ff=17920,
    vocab=100352,
    block_pattern=(LayerSpec(mixer="attn", ffn="dense"),),
    shapes=("train_4k", "prefill_32k", "decode_32k"),
))
