"""arctic-480b — 128-expert top-2 MoE + dense residual
[hf:Snowflake/snowflake-arctic-base].

35L d_model=7168 56H (GQA kv=8) d_ff=4864 vocab=32000.  Every layer combines
a dense residual MLP with a 128-expert top-2 MoE (``ffn="moe+dense"``) — the
heaviest expert-parallel case in the pool.
Pure full attention: long_500k skipped (DESIGN.md §4).
"""

from .base import ArchConfig, LayerSpec, register

CONFIG = register(ArchConfig(
    name="arctic-480b",
    family="moe",
    n_layers=35,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    d_ff=4864,
    vocab=32000,
    block_pattern=(LayerSpec(mixer="attn", ffn="moe+dense"),),
    n_experts=128,
    top_k=2,
    moe_impl="einsum",   # best compiling config at 128 experts (§Perf)
    shapes=("train_4k", "prefill_32k", "decode_32k"),
))
