"""gemma2-27b — local+global alternating attention, logit softcap
[arXiv:2408.00118; hf].

46L d_model=4608 32H (GQA kv=16) d_ff=36864 vocab=256000, head_dim=128.
Alternating sliding-window (4096) / global layers; attention softcap 50,
final-logit softcap 30.  long_500k decode runs with global-layer KV windowed
to 32k (deviation documented in DESIGN.md §4).
"""

from .base import ArchConfig, LayerSpec, register

CONFIG = register(ArchConfig(
    name="gemma2-27b",
    family="dense",
    n_layers=46,
    d_model=4608,
    n_heads=32,
    n_kv_heads=16,
    d_ff=36864,
    vocab=256000,
    head_dim=128,
    attn_softcap=50.0,
    logit_softcap=30.0,
    block_pattern=(
        LayerSpec(mixer="attn", ffn="dense", window=4096),  # local
        LayerSpec(mixer="attn", ffn="dense", window=0),     # global
    ),
    shapes=("train_4k", "prefill_32k", "decode_32k", "long_500k"),
    long_context_kv_cap=32768,
))
