"""grok-1-314b — MoE 8 experts top-2 [hf:xai-org/grok-1].

64L d_model=6144 48H (GQA kv=8) d_ff=32768 vocab=131072.
Pure full attention: long_500k skipped (DESIGN.md §4).
"""

from .base import ArchConfig, LayerSpec, register

CONFIG = register(ArchConfig(
    name="grok-1-314b",
    family="moe",
    n_layers=64,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=32768,
    vocab=131072,
    block_pattern=(LayerSpec(mixer="attn", ffn="moe"),),
    n_experts=8,
    top_k=2,
    shapes=("train_4k", "prefill_32k", "decode_32k"),
))
