"""deepseek-7b — llama-arch dense LM [arXiv:2401.02954; hf].

30L d_model=4096 32H (GQA kv=32 => MHA) d_ff=11008 vocab=102400.
Pure full attention: long_500k skipped (DESIGN.md §4).
"""

from .base import ArchConfig, LayerSpec, register

CONFIG = register(ArchConfig(
    name="deepseek-7b",
    family="dense",
    n_layers=30,
    d_model=4096,
    n_heads=32,
    n_kv_heads=32,
    d_ff=11008,
    vocab=102400,
    block_pattern=(LayerSpec(mixer="attn", ffn="dense"),),
    shapes=("train_4k", "prefill_32k", "decode_32k"),
))
