"""phi3-mini-3.8b — RoPE SwiGLU dense LM [arXiv:2404.14219].

32L d_model=3072 32H (GQA kv=32 => MHA) d_ff=8192 vocab=32064.
Pure full attention: long_500k skipped (DESIGN.md §4).
"""

from .base import ArchConfig, LayerSpec, register

CONFIG = register(ArchConfig(
    name="phi3-mini-3.8b",
    family="dense",
    n_layers=32,
    d_model=3072,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,
    vocab=32064,
    block_pattern=(LayerSpec(mixer="attn", ffn="dense"),),
    shapes=("train_4k", "prefill_32k", "decode_32k"),
))
