"""Assigned architecture configs (``--arch <id>``)."""

from . import (arctic_480b, chameleon_34b, deepseek_7b, gemma2_27b,
               grok_1_314b, hubert_xlarge, jamba_v0_1_52b, mamba2_2_7b,
               phi3_medium_14b, phi3_mini_3_8b)
from .base import (SHAPES, ArchConfig, LayerSpec, ShapeSpec, all_configs,
                   get_config, reduced, register)

ALL_ARCHS = tuple(sorted(all_configs()))

__all__ = ["SHAPES", "ArchConfig", "LayerSpec", "ShapeSpec", "all_configs",
           "get_config", "reduced", "register", "ALL_ARCHS"]
