"""Batched serving engine: continuous-batching prefill + decode loop.

A minimal production-shaped server: requests join a batch slot, prefill
populates their KV cache region, decode steps advance every active slot
one token per step, finished sequences free their slot for waiting
requests.  Runs on CPU for the examples/tests; the same step functions are
what the dry-run lowers for the 256/512-chip meshes.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ArchConfig
from ..models import decode_step, forward, init_decode_state


@dataclass
class Request:
    uid: int
    prompt: np.ndarray           # (S,) int32
    max_new_tokens: int = 16
    out_tokens: List[int] = field(default_factory=list)
    done: bool = False


@dataclass
class EngineStats:
    prefill_s: float = 0.0
    decode_steps: int = 0
    decode_s: float = 0.0
    tokens_out: int = 0

    @property
    def tokens_per_s(self) -> float:
        return self.tokens_out / self.decode_s if self.decode_s else 0.0


class ServeEngine:
    """Static-batch serving engine (batch slots, per-slot position)."""

    def __init__(self, cfg: ArchConfig, params, *, batch_slots: int = 4,
                 ctx_len: int = 512, dtype=jnp.float32):
        assert cfg.causal, "decoder-only architectures serve"
        self.cfg = cfg
        self.params = params
        self.slots = batch_slots
        self.ctx = ctx_len
        self.caches = init_decode_state(cfg, batch_slots, ctx_len,
                                        dtype=dtype)
        self.positions = np.zeros(batch_slots, dtype=np.int64)
        self.active: Dict[int, Request] = {}
        self.stats = EngineStats()
        self._decode = jax.jit(
            lambda p, c, t, i: decode_step(cfg, p, c, t, i))

    # ------------------------------------------------------------ prefill --
    def add_request(self, req: Request) -> bool:
        """Admit a request into a free slot; prefill via decode replay."""
        free = [s for s in range(self.slots) if s not in self.active]
        if not free:
            return False
        slot = free[0]
        t0 = time.perf_counter()
        # single-slot prefill: replay prompt tokens through decode_step
        # (keeps one compiled step; a bulk prefill kernel is lowered for the
        # dry-run separately)
        for i, tok in enumerate(req.prompt):
            token = jnp.zeros((self.slots, 1), dtype=jnp.int32
                              ).at[slot, 0].set(int(tok))
            _, self.caches = self._decode(self.params, self.caches, token,
                                          jnp.asarray(i, dtype=jnp.int32))
        self.positions[slot] = len(req.prompt)
        self.active[slot] = req
        self.stats.prefill_s += time.perf_counter() - t0
        return True

    # ------------------------------------------------------------- decode --
    def step(self) -> None:
        """Advance every active slot one token."""
        if not self.active:
            return
        t0 = time.perf_counter()
        token = np.zeros((self.slots, 1), dtype=np.int32)
        for slot, req in self.active.items():
            last = req.out_tokens[-1] if req.out_tokens else \
                int(req.prompt[-1])
            token[slot, 0] = last
        index = int(max(self.positions[s] for s in self.active))
        logits, self.caches = self._decode(
            self.params, self.caches, jnp.asarray(token),
            jnp.asarray(index, dtype=jnp.int32))
        nxt = np.asarray(jnp.argmax(logits[:, 0, :], axis=-1))
        finished = []
        for slot, req in self.active.items():
            req.out_tokens.append(int(nxt[slot]))
            self.positions[slot] += 1
            self.stats.tokens_out += 1
            if len(req.out_tokens) >= req.max_new_tokens:
                req.done = True
                finished.append(slot)
        for slot in finished:
            del self.active[slot]
        self.stats.decode_steps += 1
        self.stats.decode_s += time.perf_counter() - t0

    def run(self, requests: List[Request]) -> EngineStats:
        queue = list(requests)
        while queue or self.active:
            while queue and self.add_request(queue[0]):
                queue.pop(0)
            self.step()
        return self.stats
