"""Batched serving engine: continuous-batching prefill + decode loop.

A minimal production-shaped server: requests join a batch slot, prefill
populates their KV cache region, decode steps advance every active slot
one token per step, finished sequences free their slot for waiting
requests.  Runs on CPU for the examples/tests; the same step functions are
what the dry-run lowers for the 256/512-chip meshes.

Since the scheduler refactor the engine exposes its per-tick mechanics as
*step hooks* — :meth:`ServeEngine.add_request` (blocking prefill),
:meth:`ServeEngine.begin_prefill` (interleaved prefill lane),
:meth:`ServeEngine.advance` (ONE fused step over every decode and prefill
lane) and :meth:`ServeEngine.free_slots` — and delegates the tick loop to
a pluggable scheduler (:mod:`repro.serve.scheduler`).  ``run()`` with the
default :class:`~repro.serve.scheduler.FifoScheduler` reproduces the
pre-refactor behavior action-for-action (the equivalence oracle pinned by
``tests/test_serve_scheduler.py``); a
:class:`~repro.serve.scheduler.ModelGuidedScheduler` instead drives
admission, slot packing and prefill interleaving from measured step-cost
predictions.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ArchConfig
from ..models import decode_step, init_decode_state


@dataclass
class Request:
    """One generation request.

    ``arrival_s`` is the request's open-loop arrival offset on the
    ``run()`` clock (0 = available immediately — the closed-loop default);
    ``submitted_s``/``finished_s`` are stamped by the serve loop, and
    :attr:`latency_s` is the submit→finish span the load generator
    reports percentiles over.
    """

    uid: int
    prompt: np.ndarray           # (S,) int32
    max_new_tokens: int = 16
    out_tokens: List[int] = field(default_factory=list)
    done: bool = False
    arrival_s: float = 0.0       # open-loop arrival time (run() clock)
    submitted_s: Optional[float] = None   # entered the waiting queue
    finished_s: Optional[float] = None    # last token produced

    @property
    def latency_s(self) -> Optional[float]:
        """Submit→finish latency (seconds), once finished."""
        if self.submitted_s is None or self.finished_s is None:
            return None
        return self.finished_s - self.submitted_s


@dataclass
class EngineStats:
    """Aggregated serving counters (one engine run).

    ``prefill_s``/``decode_s`` time the jitted step calls *synchronized*
    (``block_until_ready``) — under async dispatch an unsynchronized
    wall-clock stop under-reports by whatever was still in flight.
    ``latencies_s`` collects per-request submit→finish spans;
    ``tick_overhead_s``/``ticks`` account the scheduler's own planning
    cost per tick.
    """

    prefill_s: float = 0.0
    decode_steps: int = 0
    decode_s: float = 0.0
    tokens_out: int = 0
    ticks: int = 0
    tick_overhead_s: float = 0.0
    latencies_s: List[float] = field(default_factory=list)

    @property
    def tokens_per_s(self) -> float:
        return self.tokens_out / self.decode_s if self.decode_s else 0.0

    @property
    def tick_overhead_ms(self) -> float:
        """Mean scheduler planning overhead per tick, milliseconds."""
        return 1e3 * self.tick_overhead_s / self.ticks if self.ticks else 0.0

    def latency_ms(self, percentile: float) -> float:
        """A submit→finish latency percentile (milliseconds)."""
        if not self.latencies_s:
            return 0.0
        return 1e3 * float(np.percentile(np.asarray(self.latencies_s),
                                         percentile))


class ServeEngine:
    """Static-batch serving engine (batch slots, per-slot position).

    Slot states: *free* (neither active nor prefilling), *prefilling*
    (an interleaved-prefill lane consuming one prompt token per fused
    step) and *active* (decoding one output token per step).  The legacy
    blocking path (:meth:`add_request`) prefills a slot to completion in
    one call; the interleaved path (:meth:`begin_prefill` +
    :meth:`advance`) folds prefill tokens into the same fused steps that
    advance decode lanes — prompt processing then costs no dedicated
    engine steps while decode work exists.
    """

    def __init__(self, cfg: ArchConfig, params, *, batch_slots: int = 4,
                 ctx_len: int = 512, dtype=jnp.float32, scheduler=None):
        assert cfg.causal, "decoder-only architectures serve"
        self.cfg = cfg
        self.params = params
        self.slots = batch_slots
        self.ctx = ctx_len
        self.caches = init_decode_state(cfg, batch_slots, ctx_len,
                                        dtype=dtype)
        self.positions = np.zeros(batch_slots, dtype=np.int64)
        self.active: Dict[int, Request] = {}
        self.prefilling: Dict[int, Request] = {}
        self.prefill_done: Dict[int, int] = {}   # prompt tokens consumed
        self.stats = EngineStats()
        self.scheduler = scheduler
        self._decode = jax.jit(
            lambda p, c, t, i: decode_step(cfg, p, c, t, i))

    # -------------------------------------------------------------- slots --
    def free_slots(self) -> List[int]:
        """Slots neither decoding nor mid-prefill, lowest first."""
        return [s for s in range(self.slots)
                if s not in self.active and s not in self.prefilling]

    # ------------------------------------------------------------ prefill --
    def add_request(self, req: Request) -> bool:
        """Admit a request into a free slot; prefill via decode replay.

        The *blocking* prefill hook: the whole prompt is replayed through
        the fused step before this returns, so every other lane stalls
        for ``len(prompt)`` steps — exactly the pre-refactor behavior the
        FIFO baseline preserves.
        """
        free = self.free_slots()
        if not free:
            return False
        slot = free[0]
        t0 = time.perf_counter()
        # single-slot prefill: replay prompt tokens through decode_step
        # (keeps one compiled step; a bulk prefill kernel is lowered for the
        # dry-run separately)
        for i, tok in enumerate(req.prompt):
            token = jnp.zeros((self.slots, 1), dtype=jnp.int32
                              ).at[slot, 0].set(int(tok))
            _, self.caches = self._decode(self.params, self.caches, token,
                                          jnp.asarray(i, dtype=jnp.int32))
        self.positions[slot] = len(req.prompt)
        self.active[slot] = req
        # prefill_s is a wall-clock bill: the request is not admitted until
        # its cache writes land, so the clock must stop on a drained queue
        # reprolint: allow[host-sync]
        jax.block_until_ready(self.caches)
        self.stats.prefill_s += time.perf_counter() - t0
        return True

    def begin_prefill(self, req: Request, slot: Optional[int] = None) -> int:
        """Open an *interleaved* prefill lane for ``req``.

        The lane consumes one prompt token per :meth:`advance` call,
        riding along with the decode lanes in the same fused step; when
        the last prompt token is consumed the slot transitions to decode.
        Returns the slot used.
        """
        free = self.free_slots()
        if slot is None:
            if not free:
                raise ValueError("no free slot for prefill")
            slot = free[0]
        elif slot not in free:
            raise ValueError(f"slot {slot} is not free")
        self.prefilling[slot] = req
        self.prefill_done[slot] = 0
        return slot

    # ------------------------------------------------------------- decode --
    def advance(self) -> List[Request]:
        """ONE fused engine step: advance every decode and prefill lane.

        Decode lanes are fed their last token and append the argmax
        output; prefill lanes consume their next prompt token (the slot
        flips to decode once the prompt is exhausted, after which it
        behaves exactly like a blocking-prefilled slot).  Returns the
        requests that finished on this step.  With no prefill lanes this
        is bit-identical to the pre-refactor ``step()``.
        """
        if not self.active and not self.prefilling:
            return []
        t0 = time.perf_counter()
        token = np.zeros((self.slots, 1), dtype=np.int32)
        for slot, req in self.active.items():
            last = req.out_tokens[-1] if req.out_tokens else \
                int(req.prompt[-1])
            token[slot, 0] = last
        for slot, req in self.prefilling.items():
            token[slot, 0] = int(req.prompt[self.prefill_done[slot]])
        index = int(max(
            [int(self.positions[s]) for s in self.active] +
            [self.prefill_done[s] for s in self.prefilling]))
        logits, self.caches = self._decode(
            self.params, self.caches, jnp.asarray(token),
            jnp.asarray(index, dtype=jnp.int32))
        had_decode = bool(self.active)
        finished: List[Request] = []
        if had_decode:
            # the engine's one designed D2H point per step: the argmax
            # tokens must reach the host to extend request state
            # reprolint: allow[host-sync]
            nxt = np.asarray(jnp.argmax(logits[:, 0, :], axis=-1))
            for slot, req in list(self.active.items()):
                req.out_tokens.append(int(nxt[slot]))
                self.positions[slot] += 1
                self.stats.tokens_out += 1
                if len(req.out_tokens) >= req.max_new_tokens:
                    req.done = True
                    finished.append(req)
                    del self.active[slot]
        for slot in list(self.prefilling):
            self.prefill_done[slot] += 1
            req = self.prefilling[slot]
            if self.prefill_done[slot] >= len(req.prompt):
                self.positions[slot] = len(req.prompt)
                del self.prefilling[slot]
                del self.prefill_done[slot]
                self.active[slot] = req
        # decode_s/prefill_s time one fused step end-to-end; StepCostModel
        # calibrates against these, so the step must be complete here
        # reprolint: allow[host-sync]
        jax.block_until_ready(self.caches)
        dt = time.perf_counter() - t0
        if had_decode:
            self.stats.decode_steps += 1
            self.stats.decode_s += dt
        else:
            self.stats.prefill_s += dt
        return finished

    def step(self) -> None:
        """Advance every active slot one token (legacy decode hook —
        :meth:`advance` restricted to the no-prefill-lane case)."""
        self.advance()

    # ---------------------------------------------------------------- run --
    def run(self, requests: List[Request], *,
            scheduler=None) -> EngineStats:
        """Serve ``requests`` to completion under a scheduling policy.

        ``scheduler`` (or the engine's constructor-time one) decides
        per-tick admissions; the default
        :class:`~repro.serve.scheduler.FifoScheduler` preserves the
        pre-refactor first-come-first-served blocking-prefill behavior.
        Open-loop traces (``Request.arrival_s > 0``) are released onto
        the waiting queue as the run clock passes their arrival time.
        """
        from .scheduler import FifoScheduler, serve_loop
        sched = scheduler if scheduler is not None else \
            (self.scheduler if self.scheduler is not None
             else FifoScheduler())
        return serve_loop(self, list(requests), sched)
