"""repro.serve."""
