"""repro.serve — production-shaped serving on the prediction stack.

:mod:`~repro.serve.engine` is the static-batch continuous-batching engine
(step hooks: blocking prefill, interleaved prefill lanes, one fused
``advance()`` step); :mod:`~repro.serve.scheduler` drives it — the FIFO
baseline or the :class:`~repro.serve.scheduler.ModelGuidedScheduler`,
which scores admit/defer/interleave candidates on step-cost predictions
measured through a :class:`~repro.tc.session.PredictorSession`.  See
``docs/serving-prediction.md``.
"""

from .engine import EngineStats, Request, ServeEngine
from .scheduler import (FifoScheduler, ModelGuidedScheduler, Plan,
                        StepCostModel, build_step_cost_model, serve_loop)

__all__ = [
    "EngineStats", "Request", "ServeEngine",
    "FifoScheduler", "ModelGuidedScheduler", "Plan", "StepCostModel",
    "build_step_cost_model", "serve_loop",
]
