"""Model-guided scheduling for the continuous-batching serve engine.

The dissertation's claim is that measurement-based kernel models pick the
fastest configuration *without executing candidates*.  This module puts
that claim in the request hot path: every scheduling tick the engine must
choose between candidate actions — admit a waiting request or defer it,
which request to pack into a free slot, prefill in a blocking burst or
interleave it with decode — and a :class:`ModelGuidedScheduler` scores
those candidates on **predicted completion-time deltas** from a
:class:`StepCostModel` measured once through the shared
:class:`~repro.tc.suite.MicroBenchmarkSuite` (via a
:class:`~repro.tc.session.PredictorSession`), instead of executing any of
them.

Two schedulers implement the ``plan()`` protocol:

* :class:`FifoScheduler` — the ``policy="fifo"`` escape hatch: admit the
  head of the queue whenever a slot is free, blocking prefill, then one
  decode step.  Action-for-action identical to the pre-refactor engine
  loop, kept as the baseline and equivalence oracle.
* :class:`ModelGuidedScheduler` — per tick, rolls each candidate action
  forward on predicted per-tick costs (warm/cold arrival classes
  propagated across ticks: the first tick after an admission is predicted
  under the COLD class, steady decode under WARM) and picks the action
  with the lowest predicted sum of completion times.  Admitted requests
  prefill *interleaved* — prompt tokens ride along with decode tokens in
  the same fused step — because the model predicts a fused tick costs the
  same as a decode-only tick on this static-batch engine.

The per-tick planning work is a few dict lookups plus a bounded rollout
over predicted costs (no measurement, no compilation), so scheduling
overhead stays well under a millisecond — the regression test pins it.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Mapping, Optional, Sequence, Tuple

from ..tc.suite import COLD, WARM
from .engine import EngineStats, Request, ServeEngine


@dataclass(frozen=True)
class Plan:
    """One tick's scheduling decision.

    ``admit_blocking`` requests are prefilled to completion before the
    next fused step (the FIFO baseline's behavior); ``admit_interleaved``
    requests open prefill lanes that advance one prompt token per fused
    step.  An empty plan means: just advance the engine.
    """

    admit_blocking: Tuple[Request, ...] = ()
    admit_interleaved: Tuple[Request, ...] = ()


@dataclass(frozen=True)
class StepCostModel:
    """Predicted cost of ONE fused engine step, per (occupancy, class).

    ``tick_s[(occ, cls)]`` is the predicted seconds of a fused step with
    ``occ`` busy lanes whose operands arrive under cache class ``cls``
    (:data:`~repro.tc.suite.WARM` for steady-state decode,
    :data:`~repro.tc.suite.COLD` for the first tick after an admission,
    whose prompt streaming left the operand cache evicted).  On the
    static-batch engine the measured cost is occupancy-invariant — the
    step always runs full batch width — but the mapping keys occupancy
    explicitly so dynamic-batch engines (and scripted test models) can
    express occupancy-dependent costs; lookups clamp to the nearest
    measured occupancy.
    """

    tick_s: Mapping[Tuple[int, str], float]
    slots: int
    build_seconds: float = 0.0     # wall-clock spent building the model
    n_benchmarks: int = 0          # distinct suite measurements it took

    def tick_cost(self, occupancy: int, cls: str = WARM) -> float:
        """Predicted seconds of one fused step at ``occupancy`` lanes."""
        occ = min(max(int(occupancy), 1), self.slots)
        got = self.tick_s.get((occ, cls))
        if got is None:
            got = self.tick_s[(occ, WARM)]
        return got

    def service_ticks(self, req: Request) -> int:
        """Fused steps to fully serve ``req`` on an interleaved lane:
        one per prompt token plus one per output token."""
        return len(req.prompt) + req.max_new_tokens - len(req.out_tokens)


#: the contraction patterns one decode step is dominated by, as
#: (sizes-builder, calls-per-layer): the q/k/v/o projections and the two
#: FFN matmuls, each a batched (occupancy, 1, d) x (d, k) matmul —
#: exactly the shape class `repro.tc.kernels` absorbs into one
#: gemm_batch call
STEP_KERNEL_EQUATION = "bij,jk->bik"


def step_kernel_sizes(cfg, batch: int) -> List[Tuple[Dict[str, int], int]]:
    """(sizes, calls-per-layer) for the step-dominating contractions of
    one fused decode step of ``cfg`` at batch width ``batch``."""
    d = cfg.d_model
    f = getattr(cfg, "d_ff", 4 * d) or 4 * d
    return [
        (dict(b=batch, i=1, j=d, k=d), 4),    # q/k/v/o projections
        (dict(b=batch, i=1, j=d, k=f), 1),    # FFN up
        (dict(b=batch, i=1, j=f, k=d), 1),    # FFN down
    ]


def _steady_seconds(session, ranked) -> float:
    """The fastest candidate's *steady-state* total: per-call median of
    its backing suite measurement times its iteration count.  The ranked
    ``runtime`` includes the one-time first-call overhead (jit compile,
    library init) — irrelevant for an engine whose step is compiled once
    — so candidates are re-scored on the steady figure here."""
    best = None
    for r in ranked:
        mb = session.suite.results[r.benchmark]
        steady = mb.stats.med * r.n_iterations
        if best is None or steady < best:
            best = steady
    return best


def build_step_cost_model(session, cfg, *, slots: int) -> StepCostModel:
    """Measure-and-fit the per-tick cost model through a session.

    For both arrival classes, the step-dominating contractions at FULL
    batch width are ranked through the session's
    :class:`~repro.tc.predictor.ContractionPredictor` — deduplicated
    cache-aware micro-benchmarks compiled through the batched
    :class:`~repro.core.predict.PredictionEngine` — and the fastest
    candidate's steady-state figure (per-call median × iterations, see
    :func:`_steady_seconds`) is summed over the per-layer call counts.
    The static-batch engine runs every step at full width whatever the
    occupancy, so one measured width serves every occupancy key.  The
    candidate set is restricted to the gemm/dot-based algorithms (the
    engine's step IS one batched matmul per projection), which keeps the
    suite to a handful of distinct signatures; everything is measured
    exactly once per platform and reused by every scheduler built on the
    same session.
    """
    from ..core.contractions import ContractionSpec
    from ..tc.kernels import base_kernel, generate_algorithms

    t0 = time.perf_counter()
    before = session.suite.n_benchmarks
    spec = ContractionSpec.parse(STEP_KERNEL_EQUATION)
    algs = [a for a in generate_algorithms(spec, include_batched=True)
            if base_kernel(a.kernel) in ("gemm", "dot")]
    tick_s: Dict[Tuple[int, str], float] = {}
    for cls in (WARM, COLD):
        arrival = {"A": COLD, "B": COLD} if cls == COLD else None
        total = 0.0
        for sizes, count in step_kernel_sizes(cfg, slots):
            ranked = session.rank_contraction_algorithms(
                STEP_KERNEL_EQUATION, sizes,
                algorithms=algs or None, arrival=arrival)
            total += count * cfg.n_layers * _steady_seconds(session, ranked)
        for occ in range(1, slots + 1):
            tick_s[(occ, cls)] = total
    return StepCostModel(tick_s=tick_s, slots=slots,
                         build_seconds=time.perf_counter() - t0,
                         n_benchmarks=session.suite.n_benchmarks - before)


# -------------------------------------------------------------- schedulers --

class FifoScheduler:
    """First-come-first-served, blocking prefill: the pre-refactor loop.

    Admits as many head-of-queue requests as there are free slots, each
    with a blocking prefill, then lets the engine take one decode step —
    exactly what ``ServeEngine.run`` did before the scheduler existed.
    The model-guided policy is benchmarked against this baseline, and
    the equivalence test pins it action-for-action to a manually-driven
    legacy loop.
    """

    def plan(self, engine: ServeEngine, waiting: List[Request]) -> Plan:
        """Admit ``waiting[:free]`` blocking, in arrival order."""
        free = len(engine.free_slots())
        return Plan(admit_blocking=tuple(waiting[:free]))


class ModelGuidedScheduler:
    """Score candidate actions on predicted completion-time deltas.

    Per tick (only when a slot is free AND requests wait — otherwise the
    plan is trivially empty and costs a dict lookup):

    1. candidate actions are *defer* (admit nothing this tick) and
       *admit r* for each of the first ``window`` waiting requests;
    2. each candidate is rolled forward on the :class:`StepCostModel`:
       simulated fused ticks advance every lane one token, completions
       free slots, remaining waiting requests are admitted
       shortest-predicted-service-first as slots free, and the tick
       after any admission is costed under the COLD class (the arrival
       state the admission leaves behind);
    3. the action with the lowest predicted **sum of completion times**
       wins.  Ties prefer admitting (earlier queue positions first).

    A request passed over ``max_defer`` times is force-admitted — the
    shortest-job preference must not starve long prompts.  Admissions
    are interleaved prefills: the model predicts a fused tick costs what
    a decode tick costs on this engine, so folding prompt tokens into
    decode steps strictly beats the FIFO baseline's blocking bursts.
    """

    def __init__(self, model: StepCostModel, *, window: int = 4,
                 max_defer: int = 32, horizon: int = 512):
        self.model = model
        self.window = window
        self.max_defer = max_defer
        self.horizon = horizon
        self._deferrals: Dict[int, int] = {}

    # ------------------------------------------------------------ rollout --
    def _rollout(self, lanes: List[List[int]],
                 queue: List[Tuple[int, int]], *,
                 hold_first: bool, cold_now: bool) -> float:
        """Predicted sum of completion times of every known request.

        ``lanes`` holds ``[prefill_left, decode_left]`` per busy slot;
        ``queue`` holds ``(prefill, decode)`` service estimates of the
        still-waiting requests, admitted shortest-first whenever a slot
        frees (``hold_first`` blocks admissions until the first
        completion — the *defer* candidate's semantics).  Costs come
        from the step model; the tick after any admission is COLD.
        """
        model = self.model
        lanes = [list(lane) for lane in lanes]
        queue = sorted(queue, key=lambda s: s[0] + s[1])
        t = 0.0
        total = 0.0
        cold = cold_now
        held = hold_first
        ticks = 0
        while lanes or queue:
            if not held:
                while queue and len(lanes) < model.slots:
                    p, d = queue.pop(0)
                    lanes.append([p, d])
                    cold = True
            if not lanes:      # nothing running and admissions held
                held = False
                continue
            t += model.tick_cost(len(lanes), COLD if cold else WARM)
            cold = False
            ticks += 1
            done = []
            for lane in lanes:
                if lane[0] > 0:
                    lane[0] -= 1
                else:
                    lane[1] -= 1
                if lane[0] <= 0 and lane[1] <= 0:
                    done.append(lane)
            for lane in done:
                lanes.remove(lane)
                total += t
                held = False
            if ticks >= self.horizon:
                # truncate: close out remaining lanes/queue analytically
                # at the steady warm decode rate
                warm = model.tick_cost(len(lanes) or 1, WARM)
                for lane in lanes:
                    total += t + (lane[0] + lane[1]) * warm
                for p, d in queue:
                    total += t + (p + d) * warm
                break
        return total

    def _lanes(self, engine: ServeEngine) -> List[List[int]]:
        lanes = [[0, req.max_new_tokens - len(req.out_tokens)]
                 for req in engine.active.values()]
        lanes += [[len(req.prompt) - engine.prefill_done[slot],
                   req.max_new_tokens]
                  for slot, req in engine.prefilling.items()]
        return lanes

    # --------------------------------------------------------------- plan --
    def plan(self, engine: ServeEngine, waiting: List[Request]) -> Plan:
        """The tick decision: admit one of the first ``window`` waiting
        requests (interleaved prefill) or defer, whichever minimizes the
        predicted sum of completion times."""
        if not waiting or not engine.free_slots():
            return Plan()
        cands = waiting[:self.window]
        for req in cands:
            if self._deferrals.get(req.uid, 0) >= self.max_defer:
                self._deferrals.pop(req.uid, None)
                return Plan(admit_interleaved=(req,))
        lanes = self._lanes(engine)
        service = {req.uid: (len(req.prompt),
                             req.max_new_tokens - len(req.out_tokens))
                   for req in waiting}
        defer = self._rollout(
            lanes, [service[r.uid] for r in waiting],
            hold_first=True, cold_now=False)
        best_req: Optional[Request] = None
        best = float("inf")
        for req in cands:
            rest = [service[r.uid] for r in waiting if r.uid != req.uid]
            p, d = service[req.uid]
            score = self._rollout(lanes + [[p, d]], rest,
                                  hold_first=False, cold_now=True)
            # ties vs defer admit; ties among candidates keep the
            # earliest queue position
            if score <= defer * (1 + 1e-9) and score < best - 1e-12:
                best, best_req = score, req
        if best_req is None:
            for req in cands:
                self._deferrals[req.uid] = \
                    self._deferrals.get(req.uid, 0) + 1
            return Plan()
        for req in cands:
            if req is not best_req:
                self._deferrals[req.uid] = \
                    self._deferrals.get(req.uid, 0) + 1
        self._deferrals.pop(best_req.uid, None)
        return Plan(admit_interleaved=(best_req,))


# --------------------------------------------------------------- the loop --

def serve_loop(engine: ServeEngine, requests: Sequence[Request],
               scheduler) -> EngineStats:
    """Drive the engine to completion under ``scheduler``.

    The tick loop: release open-loop arrivals onto the waiting queue as
    the run clock passes their ``arrival_s``, ask the scheduler for a
    :class:`Plan` (its planning time is accounted as
    ``stats.tick_overhead_s`` — the < 1 ms budget the regression test
    pins), apply the admissions through the engine's step hooks, advance
    one fused step, and stamp finish times / latencies on completed
    requests.
    """
    stats = engine.stats
    t0 = time.perf_counter()
    pending = sorted(requests, key=lambda r: r.arrival_s)
    waiting: List[Request] = []
    while pending or waiting or engine.active or engine.prefilling:
        now = time.perf_counter() - t0
        while pending and pending[0].arrival_s <= now:
            req = pending.pop(0)
            req.submitted_s = max(now, req.arrival_s)
            waiting.append(req)
        if not waiting and not engine.active and not engine.prefilling:
            # idle: nothing to schedule until the next arrival
            time.sleep(min(5e-4, max(0.0,
                                     pending[0].arrival_s - now)))
            continue
        t_plan = time.perf_counter()
        plan = scheduler.plan(engine, waiting)
        stats.tick_overhead_s += time.perf_counter() - t_plan
        stats.ticks += 1
        for req in plan.admit_blocking:
            if not engine.add_request(req):
                break
            waiting.remove(req)
        for req in plan.admit_interleaved:
            if not engine.free_slots():
                break
            engine.begin_prefill(req)
            waiting.remove(req)
        finished = engine.advance()
        if finished:
            now = time.perf_counter() - t0
            for req in finished:
                req.finished_s = now
                stats.latencies_s.append(
                    now - (req.submitted_s or 0.0))
    return stats
