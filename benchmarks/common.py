"""Shared benchmark utilities: kernel model generation with disk caching."""

from __future__ import annotations

import json
import time
from pathlib import Path
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import (GeneratorConfig, KernelBenchmark, ModelSet,
                        PerformanceModel, generate_model)
from repro.core.grids import Domain
from repro.dla.kernels import KERNELS

ROOT = Path(__file__).resolve().parents[1]
MODEL_DIR = ROOT / "experiments" / "models"

#: smoke mode: tiny sizes, single repetition, measurement-free models —
#: toggled by ``benchmarks.run --smoke`` so CI can track the perf trajectory
SMOKE = False


def set_smoke(on: bool = True) -> None:
    global SMOKE
    SMOKE = on


def is_smoke() -> bool:
    return SMOKE

#: the kernel/case catalog every blocked algorithm in the benchmarks needs
DEFAULT_SPECS: List[Tuple[str, Tuple, Tuple[int, ...], Tuple[int, ...]]] = [
    ("potf2", (("L",),), (16,), (304,)),
    ("trti2", (("L", "N"),), (16,), (304,)),
    ("lauu2", (("L",),), (16,), (304,)),
    ("getf2", (("NP",),), (16, 16), (304, 144)),
    ("trsyl", (("N", "N", 1),), (16, 16), (144, 144)),
    ("trsm", (("R", "L", "T", "N", 1), ("L", "L", "N", "N", -1),
              ("R", "L", "N", "N", -1), ("L", "L", "N", "U", 1)),
     (16, 16), (304, 304)),
    ("trmm", (("R", "L", "N", "N", 1), ("L", "L", "N", "N", 1),
              ("R", "L", "N", "N", -1), ("L", "L", "N", "N", -1),
              ("L", "L", "T", "N", 1)),
     (16, 16), (304, 304)),
    ("syrk", (("L", "N", -1, 1), ("L", "T", 1, 1)),
     (16, 16), (304, 304)),
    ("gemm", (("N", "T", -1, 1), ("N", "N", -1, 1), ("N", "N", 1, 1),
              ("T", "N", 1, 1), ("N", "N", 1, 0), ("N", "N", -1, 0)),
     (16, 16, 16), (208, 208, 208)),
]

BENCH_GEN_CONFIG = GeneratorConfig(overfit=0, oversampling=2,
                                   repetitions=5, error_bound=0.04,
                                   min_width=64, max_pieces=6)


def build_model_set(specs=DEFAULT_SPECS,
                    config: GeneratorConfig = BENCH_GEN_CONFIG,
                    cache: str = "default",
                    verbose: bool = True) -> Tuple[ModelSet, float]:
    """Generate (or load cached) models; returns (set, generation seconds)."""
    MODEL_DIR.mkdir(parents=True, exist_ok=True)
    cache_file = MODEL_DIR / f"{cache}.json"
    if cache_file.exists():
        data = json.loads(cache_file.read_text())
        ms = ModelSet()
        for d in data["models"]:
            ms.add(PerformanceModel.from_dict(d))
        return ms, data.get("gen_seconds", 0.0)
    ms = ModelSet()
    t0 = time.perf_counter()
    for name, cases, lo, hi in specs:
        kd = KERNELS[name]
        bench = KernelBenchmark(
            name=name, cases=cases, domain=Domain(lo, hi),
            cost_exponents=kd.cost_exponents,
            make_call=lambda case, sizes, _kd=kd: _kd.make_call(case, sizes),
        )
        model, report = generate_model(bench, config)
        ms.add(model)
        if verbose:
            print(f"  [modelgen] {name}: {report.measured_points} pts, "
                  f"{sum(report.pieces_per_case.values())} pieces, "
                  f"{report.seconds:.1f}s", flush=True)
    gen_s = time.perf_counter() - t0
    cache_file.write_text(json.dumps({
        "gen_seconds": gen_s,
        "models": [m.to_dict() for m in ms.models.values()],
    }))
    return ms, gen_s


def best_of(fn, repetitions: int) -> float:
    """Best-of-N wall time of ``fn()`` — the shared timing protocol behind
    the CI-tracked smoke metrics (one copy, so the suites cannot drift)."""
    best = float("inf")
    for _ in range(repetitions):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def median_time(fn, repetitions: int = 5) -> float:
    if SMOKE:
        repetitions = 1
    fn()  # warm-up
    ts = []
    for _ in range(repetitions):
        t0 = time.perf_counter()
        fn()
        ts.append(time.perf_counter() - t0)
    return sorted(ts)[len(ts) // 2]


#: synthetic-model calibration: an arbitrary but fixed machine balance
SYNTH_RATE_FLOPS = 5e10
SYNTH_OVERHEAD_S = 2e-6


def synthetic_model_set(specs=DEFAULT_SPECS,
                        points_per_dim: int = 5) -> ModelSet:
    """Measurement-free model set fitted to analytic flop counts.

    Every kernel/case in ``specs`` gets two polynomial pieces (the domain is
    bisected once) fitted to ``flops / rate + overhead`` with slightly spread
    per-statistic factors, through the real relative-LSQ pipeline — so bases,
    scales and piece lookup behave exactly like measured models, without
    timing a single kernel.  Prediction-path benchmarks and the CI smoke lane
    run on this set.
    """
    from repro.core import Piece, fit_relative, monomial_basis
    from repro.core.grids import grid_points
    from repro.dla.kernels import kernel_flops

    stat_factor = {"min": 0.97, "med": 1.0, "max": 1.08, "mean": 1.01}
    ms = ModelSet()
    for name, cases, lo, hi in specs:
        kd = KERNELS[name]
        model = PerformanceModel(kernel=name, setup="synthetic")
        for case in cases:
            basis = monomial_basis(kd.cost_exponents(case))
            dom = Domain(lo, hi)
            lo_half, hi_half, _ = dom.split()
            for sub in (lo_half, hi_half):
                pts = grid_points(sub, [points_per_dim] * dom.ndim,
                                  kind="cartesian", round_to=8)
                arr = np.asarray(pts, dtype=np.float64)
                base = np.asarray([kernel_flops(name, case, p) for p in pts])
                # analytic counts can dip negative outside a kernel's valid
                # shape regime (e.g. getf2 panels wider than tall): floor them
                base = np.maximum(base, 1.0) / SYNTH_RATE_FLOPS \
                    + SYNTH_OVERHEAD_S
                polys = {s: fit_relative(arr, base * f, basis)
                         for s, f in stat_factor.items()}
                polys["std"] = fit_relative(
                    arr, np.maximum(base * 0.02, 1e-9), basis)
                model.add_piece(case, Piece(domain=sub, polys=polys))
        ms.add(model)
    return ms


def catalog_synthetic_model_set(n: int = 264, b: int = 56) -> ModelSet:
    """Synthetic models covering every (kernel, case) the full tracer catalog
    (``repro.dla.tracers.ALL_TRACERS``) emits — the model set the backend
    equivalence tests sweep the whole catalog against."""
    from repro.dla.tracers import required_kernel_cases

    dims: Dict[str, int] = {}
    need = required_kernel_cases(n=n, b=b, dims=dims)
    specs = [(kernel, tuple(sorted(cases, key=repr)),
              (16,) * dims[kernel], (304,) * dims[kernel])
             for kernel, cases in sorted(need.items())]
    return synthetic_model_set(specs)


def spd(n: int, seed: int = 0) -> np.ndarray:
    rng = np.random.default_rng(seed)
    a = rng.standard_normal((n, n))
    return a @ a.T + n * np.eye(n)


def lower_nonsing(n: int, seed: int = 0) -> np.ndarray:
    rng = np.random.default_rng(seed)
    a = np.tril(rng.standard_normal((n, n)))
    np.fill_diagonal(a, np.abs(a.diagonal()) + n)
    return a
