"""Shared benchmark utilities: kernel model generation with disk caching."""

from __future__ import annotations

import json
import time
from pathlib import Path
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import (GeneratorConfig, KernelBenchmark, ModelSet,
                        PerformanceModel, generate_model)
from repro.core.grids import Domain
from repro.dla.kernels import KERNELS

ROOT = Path(__file__).resolve().parents[1]
MODEL_DIR = ROOT / "experiments" / "models"

#: the kernel/case catalog every blocked algorithm in the benchmarks needs
DEFAULT_SPECS: List[Tuple[str, Tuple, Tuple[int, ...], Tuple[int, ...]]] = [
    ("potf2", (("L",),), (16,), (304,)),
    ("trti2", (("L", "N"),), (16,), (304,)),
    ("lauu2", (("L",),), (16,), (304,)),
    ("getf2", (("NP",),), (16, 16), (304, 144)),
    ("trsyl", (("N", "N", 1),), (16, 16), (144, 144)),
    ("trsm", (("R", "L", "T", "N", 1), ("L", "L", "N", "N", -1),
              ("R", "L", "N", "N", -1), ("L", "L", "N", "U", 1)),
     (16, 16), (304, 304)),
    ("trmm", (("R", "L", "N", "N", 1), ("L", "L", "N", "N", 1),
              ("R", "L", "N", "N", -1), ("L", "L", "N", "N", -1),
              ("L", "L", "T", "N", 1)),
     (16, 16), (304, 304)),
    ("syrk", (("L", "N", -1, 1), ("L", "T", 1, 1)),
     (16, 16), (304, 304)),
    ("gemm", (("N", "T", -1, 1), ("N", "N", -1, 1), ("N", "N", 1, 1),
              ("T", "N", 1, 1), ("N", "N", 1, 0), ("N", "N", -1, 0)),
     (16, 16, 16), (208, 208, 208)),
]

BENCH_GEN_CONFIG = GeneratorConfig(overfit=0, oversampling=2,
                                   repetitions=5, error_bound=0.04,
                                   min_width=64, max_pieces=6)


def build_model_set(specs=DEFAULT_SPECS,
                    config: GeneratorConfig = BENCH_GEN_CONFIG,
                    cache: str = "default",
                    verbose: bool = True) -> Tuple[ModelSet, float]:
    """Generate (or load cached) models; returns (set, generation seconds)."""
    MODEL_DIR.mkdir(parents=True, exist_ok=True)
    cache_file = MODEL_DIR / f"{cache}.json"
    if cache_file.exists():
        data = json.loads(cache_file.read_text())
        ms = ModelSet()
        for d in data["models"]:
            ms.add(PerformanceModel.from_dict(d))
        return ms, data.get("gen_seconds", 0.0)
    ms = ModelSet()
    t0 = time.perf_counter()
    for name, cases, lo, hi in specs:
        kd = KERNELS[name]
        bench = KernelBenchmark(
            name=name, cases=cases, domain=Domain(lo, hi),
            cost_exponents=kd.cost_exponents,
            make_call=lambda case, sizes, _kd=kd: _kd.make_call(case, sizes),
        )
        model, report = generate_model(bench, config)
        ms.add(model)
        if verbose:
            print(f"  [modelgen] {name}: {report.measured_points} pts, "
                  f"{sum(report.pieces_per_case.values())} pieces, "
                  f"{report.seconds:.1f}s", flush=True)
    gen_s = time.perf_counter() - t0
    cache_file.write_text(json.dumps({
        "gen_seconds": gen_s,
        "models": [m.to_dict() for m in ms.models.values()],
    }))
    return ms, gen_s


def median_time(fn, repetitions: int = 5) -> float:
    fn()  # warm-up
    ts = []
    for _ in range(repetitions):
        t0 = time.perf_counter()
        fn()
        ts.append(time.perf_counter() - t0)
    return sorted(ts)[len(ts) // 2]


def spd(n: int, seed: int = 0) -> np.ndarray:
    rng = np.random.default_rng(seed)
    a = rng.standard_normal((n, n))
    return a @ a.T + n * np.eye(n)


def lower_nonsing(n: int, seed: int = 0) -> np.ndarray:
    rng = np.random.default_rng(seed)
    a = np.tril(rng.standard_normal((n, n)))
    np.fill_diagonal(a, np.abs(a.diagonal()) + n)
    return a
