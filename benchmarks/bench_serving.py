"""Benchmark: model-guided serving vs the FIFO baseline.

The first benchmark where the predictor earns its keep *inside* the
system it models: an open-loop load generator (Poisson arrivals, mixed
prompt lengths) drives the continuous-batching :class:`ServeEngine` twice
over one identical arrival trace — once under the
:class:`~repro.serve.scheduler.FifoScheduler` baseline (blocking prefill,
first-come-first-served: the pre-refactor behavior) and once under the
:class:`~repro.serve.scheduler.ModelGuidedScheduler`, whose per-tick
admit/defer/interleave decisions compare predicted completion-time deltas
from a :class:`~repro.serve.scheduler.StepCostModel` measured once
through a shared :class:`~repro.tc.session.PredictorSession`.

Reported per policy: p50/p99 submit→finish latency, goodput (completed
output tokens per wall-clock second), and the scheduler's own per-tick
planning overhead.  Smoke mode emits the ``serve_*`` metrics CI tracks —
``compare_smoke.py`` warns when the model-guided goodput falls below the
FIFO baseline or the tick overhead leaves its sub-ms budget.
"""

from __future__ import annotations

import time
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.configs import get_config, reduced
from repro.models import init_params
from repro.serve import (FifoScheduler, ModelGuidedScheduler, Request,
                         ServeEngine)
from repro.serve.engine import EngineStats
from repro.tc import PredictorSession

from .common import is_smoke

#: tiny decoder the smoke lane serves (compiles in seconds on CPU)
SMOKE_ARCH = dict(n_layers=2, d_model=64, d_ff=128, vocab=128)
SLOTS = 3
CTX = 64

#: the open-loop workload: mixed prompt lengths, Poisson arrivals
PROMPT_LENGTHS = (4, 16, 48)
MEAN_INTERARRIVAL_S = 0.010
MAX_NEW_TOKENS = 8


def _config(smoke: bool):
    cfg = reduced(get_config("deepseek-7b"), **SMOKE_ARCH)
    if not smoke:
        cfg = reduced(get_config("deepseek-7b"), n_layers=4, d_model=128,
                      d_ff=256, vocab=256)
    return cfg


def make_trace(cfg, n: int, seed: int = 0) -> List[Request]:
    """One fixed arrival trace: regenerate (same seed) per policy so both
    schedulers see identical requests at identical arrival offsets."""
    rng = np.random.default_rng(seed)
    reqs, t = [], 0.0
    for uid in range(n):
        plen = int(rng.choice(PROMPT_LENGTHS))
        prompt = rng.integers(1, cfg.vocab, size=plen).astype(np.int32)
        reqs.append(Request(uid=uid, prompt=prompt,
                            max_new_tokens=MAX_NEW_TOKENS, arrival_s=t))
        t += float(rng.exponential(MEAN_INTERARRIVAL_S))
    return reqs


def serve_once(cfg, params, scheduler, n: int,
               ) -> Tuple[EngineStats, float, float]:
    """(stats, goodput tok/s, wall seconds) of one policy over the trace."""
    eng = ServeEngine(cfg, params, batch_slots=SLOTS, ctx_len=CTX)
    # compile the fused step outside the measured window
    eng.run([Request(uid=-1, prompt=np.ones(4, dtype=np.int32),
                     max_new_tokens=2)])
    eng.stats = EngineStats()
    reqs = make_trace(cfg, n)
    t0 = time.perf_counter()
    stats = eng.run(reqs, scheduler=scheduler)
    wall = time.perf_counter() - t0
    tokens = sum(len(r.out_tokens) for r in reqs)
    assert all(r.done for r in reqs)
    return stats, tokens / wall, wall


def _bench(report: List[str], results: Dict[str, object], *,
           smoke: bool) -> None:
    import jax

    cfg = _config(smoke)
    n = 12 if smoke else 48
    params = init_params(cfg, jax.random.PRNGKey(0))

    session = PredictorSession()
    t0 = time.perf_counter()
    model = session.step_cost_model(cfg, slots=SLOTS)
    t_model = time.perf_counter() - t0

    fifo_stats, fifo_goodput, fifo_wall = serve_once(
        cfg, params, FifoScheduler(), n)
    guided_stats, goodput, wall = serve_once(
        cfg, params, ModelGuidedScheduler(model), n)

    report.append(
        f"serving {n} reqs (prompts {PROMPT_LENGTHS}, "
        f"mean arrival {MEAN_INTERARRIVAL_S * 1e3:.0f}ms, "
        f"slots={SLOTS}): step model {t_model:.2f}s "
        f"({model.n_benchmarks} benchmarks)")
    report.append(
        f"  fifo  : goodput={fifo_goodput:7.1f} tok/s "
        f"p50={fifo_stats.latency_ms(50):7.1f}ms "
        f"p99={fifo_stats.latency_ms(99):7.1f}ms "
        f"wall={fifo_wall:5.2f}s ticks={fifo_stats.ticks}")
    report.append(
        f"  guided: goodput={goodput:7.1f} tok/s "
        f"p50={guided_stats.latency_ms(50):7.1f}ms "
        f"p99={guided_stats.latency_ms(99):7.1f}ms "
        f"wall={wall:5.2f}s ticks={guided_stats.ticks} "
        f"tick_overhead={guided_stats.tick_overhead_ms:.3f}ms")
    report.append(
        f"  model-guided vs fifo: goodput {goodput / fifo_goodput:.2f}x, "
        f"p99 {guided_stats.latency_ms(99) / fifo_stats.latency_ms(99):.2f}x")
    results.update({
        "serve_model_build_s": t_model,
        "serve_fifo_goodput_tok_s": fifo_goodput,
        "serve_fifo_p50_ms": fifo_stats.latency_ms(50),
        "serve_fifo_p99_ms": fifo_stats.latency_ms(99),
        "serve_goodput_tok_s": goodput,
        "serve_p50_ms": guided_stats.latency_ms(50),
        "serve_p99_ms": guided_stats.latency_ms(99),
        "serve_tick_overhead_ms": guided_stats.tick_overhead_ms,
        "serve_goodput_ratio": goodput / fifo_goodput,
        "serve_p99_ratio": (guided_stats.latency_ms(99) /
                            fifo_stats.latency_ms(99)),
    })


def run(report: List[str],
        results: Optional[Dict[str, object]] = None) -> None:
    _bench(report, results if results is not None else {},
           smoke=is_smoke())


def main() -> None:
    report: List[str] = []
    run(report)
    print("\n".join(report))


if __name__ == "__main__":
    main()
