"""Benchmark: roofline table per (arch x shape x mesh) from the dry-run
artifacts (deliverable g).  Reads experiments/dryrun/*.json — run
``python -m repro.launch.dryrun --all --both-meshes`` first."""

from __future__ import annotations

import json
from pathlib import Path
from typing import List

ROOT = Path(__file__).resolve().parents[1]
DRYRUN = ROOT / "experiments" / "dryrun"


def rows(mesh: str = "16x16"):
    out = []
    for f in sorted(DRYRUN.glob(f"*__{mesh}.json")):
        out.append(json.loads(f.read_text()))
    return out


def run(report: List[str]) -> None:
    if not DRYRUN.exists():
        report.append("no dry-run artifacts; run repro.launch.dryrun first")
        return
    for mesh in ("16x16", "2x16x16"):
        data = rows(mesh)
        if not data:
            continue
        report.append(f"--- mesh {mesh} ({len(data)} cells) ---")
        report.append(
            f"{'arch':16s} {'shape':12s} {'comp_ms':>9s} {'mem_ms':>9s} "
            f"{'coll_ms':>9s} {'dominant':>10s} {'useful':>7s} {'frac':>6s}")
        for m in data:
            report.append(
                f"{m['arch']:16s} {m['shape']:12s} "
                f"{m['compute_s'] * 1e3:9.2f} {m['memory_s'] * 1e3:9.2f} "
                f"{m['collective_s'] * 1e3:9.2f} {m['dominant']:>10s} "
                f"{m['useful_ratio']:7.2f} {m['roofline_fraction']:6.3f}")


def main() -> None:
    report: List[str] = []
    run(report)
    print("\n".join(report))


if __name__ == "__main__":
    main()
