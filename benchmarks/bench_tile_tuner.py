"""Benchmark: measured Pallas tile selection vs exhaustive execution.

The tile tuner's claim after the device-measurement PR is the paper's
central one transplanted to BlockSpec tiles: rank tile candidates from
*measured per-grid-step models* (plus fitted H2D/D2H transfer terms) at
a fraction of what executing the candidates would cost, and answer from
a warm :class:`~repro.store.ModelStore` with zero fresh measurements.
This bench proves those economics on the CI runner every commit:

* **sweep cost fraction** — one device-resident proxy sweep of the
  candidate tile configs (plus the memcpy transfer probe) serves a whole
  *table* of problem shapes; the baseline is what an exhaustive tuner
  pays instead: executing every candidate at every table shape under the
  suite's own warmup + repetitions protocol.  ``tile_sweep_cost_frac``
  must stay < 0.25 (asserted — the calibrated margin is ~3x);
* **measured vs analytic** — ``tile_top1_agree`` compares the measured
  ranking's top-1 against the analytic three-term oracle on a sub-128
  problem where small tiles are legal.  Interpret mode inflates per-step
  proxy cost (dispatch overhead dominates tiny grids), so this is
  reported, not asserted — the tier-1 tests pin the candidate-set
  equivalence;
* **transfer decomposition** — ``tile_h2d_share`` / ``tile_d2h_share``
  report the fitted transfer terms' share of the selected tile's
  predicted total (asymmetric: D2H is the slow direction);
* **warm store** — save the store, warm-start a fresh session, re-rank
  the whole shape table: ZERO new measurements and bit-identical
  predicted totals (both asserted — the ``__device__`` model-set
  contract).  ``tile_warm_rank_ms`` is the trended headline: what a
  warm process pays instead of sweeping.

Full (non-smoke) mode prepends the analytic tile table for the assigned
architectures' matmul shapes and an interpret-mode correctness check of
one selected tiling.
"""

from __future__ import annotations

import time
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.kernels import matmul
from repro.kernels.ref import matmul_ref
from repro.perf.tile_tuner import rank_tiles, select_tiles
from repro.tc import PredictorSession

from .common import is_smoke

STORE_PATH = "TILE_STORE.json"
#: same cheap protocol as the other smoke suites (warmup + 2 repetitions)
SMOKE_REPETITIONS = 2
#: the problem-shape table one proxy sweep serves; candidate tiles are
#: cubic so the exhaustive baseline stays ~5s on the CI runner while the
#: worst candidate's grid (32**3 steps at 256**3) is still large enough
#: that execution, not compilation, dominates the baseline
TABLE_SHAPES = ((256, 256, 256), (192, 192, 192))
TABLE_CONFIGS = ((8, 8, 8), (16, 16, 16))
#: sub-128 problem for the measured-vs-analytic probe: tile_legal only
#: admits small tiles while every dim is < 128
AGREE_PROBLEM = (96, 96, 96)
AGREE_CANDIDATES = (8, 16)


def _arch_matmul_shapes():
    shapes = []
    for arch in ("deepseek-7b", "gemma2-27b", "grok-1-314b"):
        cfg = get_config(arch)
        d, f = cfg.d_model, max(cfg.d_ff, cfg.d_model)
        tokens = 4096
        shapes.append((arch + ":qkv", tokens, cfg.n_heads * cfg.head_dim_,
                       d))
        shapes.append((arch + ":ffn", tokens, f, d))
    return shapes


def _analytic_table(report: List[str]) -> None:
    for name, m, n, k in _arch_matmul_shapes():
        c = select_tiles(m, n, k)
        report.append(
            f"{name:22s} ({m:5d}x{n:5d}x{k:5d}) -> tiles "
            f"({c.bm:4d},{c.bn:4d},{c.bk:4d}) pred={c.predicted_s * 1e3:.2f}ms")


def _correctness_check(report: List[str], interpret: bool) -> None:
    """One selected tiling executed against the reference matmul."""
    m, n, k = 256, 256, 256
    c = select_tiles(m, n, k, candidates=(64, 128))
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((m, k)), jnp.float32)
    y = jnp.asarray(rng.standard_normal((k, n)), jnp.float32)
    out = matmul(x, y, bm=c.bm, bn=c.bn, bk=c.bk, interpret=interpret)
    err = float(jnp.abs(out - matmul_ref(x, y)).max())
    report.append(f"selected tile correctness err={err:.2e}")


def _exec_protocol(mnk: Tuple[int, int, int], cfg: Tuple[int, int, int],
                   interpret: bool, rng) -> float:
    """What exhaustive tuning pays for ONE candidate at ONE shape: the
    suite's own measurement protocol (1 warmup + SMOKE_REPETITIONS timed
    calls) executed at full problem size, wall-clocked."""
    m, n, k = mnk
    x = jnp.asarray(rng.standard_normal((m, k)).astype(np.float32))
    y = jnp.asarray(rng.standard_normal((k, n)).astype(np.float32))
    t0 = time.perf_counter()
    for _ in range(1 + SMOKE_REPETITIONS):
        out = matmul(x, y, bm=cfg[0], bn=cfg[1], bk=cfg[2],
                     interpret=interpret)
    jax.block_until_ready(out)
    return time.perf_counter() - t0


def _rank_table(sess: PredictorSession) -> List[Tuple[float, ...]]:
    """Predicted totals for the whole (shape x config) table — the
    warm-start bit-identity witness."""
    out = []
    for mnk in TABLE_SHAPES:
        ranked = sess.rank_device_tiles("pallas_matmul", mnk,
                                        TABLE_CONFIGS)
        out.append(tuple(r.t_total for r in ranked))
    return out


def _run(report: List[str], results: Dict[str, object], *,
         smoke: bool) -> None:
    interpret = jax.default_backend() != "tpu"
    if not smoke:
        _analytic_table(report)
    # runs first in both modes: validates the selected tiling AND heats
    # the process (jax init, pallas lowering) so neither side of the
    # sweep-vs-exhaustive comparison pays cold-process overhead
    _correctness_check(report, interpret)

    # ---- one proxy sweep + transfer probe serves the whole table ----
    sess = PredictorSession(repetitions=SMOKE_REPETITIONS)
    cost0 = sess.suite.cost_seconds
    table = _rank_table(sess)
    sweep_s = sess.suite.cost_seconds - cost0
    ranked = sess.rank_device_tiles("pallas_matmul", TABLE_SHAPES[0],
                                    TABLE_CONFIGS)
    best = ranked[0]

    # ---- the exhaustive baseline: execute every candidate everywhere ----
    rng = np.random.default_rng(0)
    exec_s = sum(_exec_protocol(mnk, cfg, interpret, rng)
                 for mnk in TABLE_SHAPES for cfg in TABLE_CONFIGS)
    cost_frac = sweep_s / exec_s
    report.append(
        f"sweep {len(TABLE_CONFIGS)} configs -> {len(TABLE_SHAPES)} shapes: "
        f"cost={sweep_s:5.2f}s vs exhaustive exec={exec_s:5.2f}s "
        f"(fraction {cost_frac:.3f})")
    report.append(
        f"  best @{TABLE_SHAPES[0]}: ({best.config[0]},{best.config[1]},"
        f"{best.config[2]}) total={best.t_total * 1e3:.2f}ms "
        f"h2d={best.t_h2d * 1e6:.0f}us d2h={best.t_d2h * 1e6:.0f}us "
        f"[{best.source}]")
    # the economics the device-measurement protocol exists for: one
    # proxy sweep must undercut exhaustive execution by 4x or more
    assert cost_frac < 0.25, \
        f"sweep cost fraction {cost_frac:.3f} >= 0.25"

    # ---- measured-vs-analytic top-1 on a small-tile-legal problem ----
    measured = rank_tiles(*AGREE_PROBLEM, session=sess,
                          candidates=AGREE_CANDIDATES)
    analytic = rank_tiles(*AGREE_PROBLEM, analytic=True,
                          candidates=AGREE_CANDIDATES)
    agree = (measured[0].bm, measured[0].bn, measured[0].bk) == \
        (analytic[0].bm, analytic[0].bn, analytic[0].bk)
    report.append(
        f"  top-1 @{AGREE_PROBLEM}: measured=({measured[0].bm},"
        f"{measured[0].bn},{measured[0].bk}) analytic=({analytic[0].bm},"
        f"{analytic[0].bn},{analytic[0].bk}) "
        f"{'==' if agree else '!='} (interpret={interpret})")

    # ---- warm store: zero fresh measurements, identical totals ----
    sess.save_store(STORE_PATH)
    t0 = time.perf_counter()
    warm = PredictorSession(store=STORE_PATH)
    warm_table = _rank_table(warm)
    t_warm = time.perf_counter() - t0
    counters = warm.counters()
    identical = warm_table == table
    # the __device__ model-set contract, enforced every commit: a warm
    # session ranks the stored tile table without sweeping or probing
    assert counters["measured"] == 0, \
        f"warm tile ranking measured {counters['measured']} benchmarks"
    assert identical, "warm-started tile totals differ from in-memory"
    report.append(
        f"  warm store: load+rank={t_warm * 1e3:6.1f}ms "
        f"new_measurements={int(counters['measured'])} "
        f"totals {'==' if identical else '!='} in-memory")

    results.update({
        "tile_shapes": len(TABLE_SHAPES),
        "tile_configs": len(TABLE_CONFIGS),
        "tile_sweep_s": sweep_s,
        "tile_exec_s": exec_s,
        "tile_sweep_cost_frac": cost_frac,
        "tile_top1_agree": float(agree),
        "tile_h2d_share": best.t_h2d / best.t_total,
        "tile_d2h_share": best.t_d2h / best.t_total,
        "tile_warm_rank_ms": t_warm * 1e3,
        "tile_warm_new_measurements": int(counters["measured"]),
        "tile_warm_identical": bool(identical),
    })


def run(report: List[str],
        results: Optional[Dict[str, object]] = None) -> None:
    _run(report, results if results is not None else {},
         smoke=is_smoke())


def main() -> None:
    report: List[str] = []
    run(report)
    print("\n".join(report))


if __name__ == "__main__":
    main()
