"""Benchmark: model-based Pallas tile selection (beyond-paper, DESIGN.md §3).

Apply the paper's "predict, don't execute" block-size optimization to the
Pallas matmul BlockSpec tiles for the matmul shapes of the assigned
architectures; report the selected tiles + predicted times, and validate
one selection against interpret-mode execution for correctness.
"""

from __future__ import annotations

from typing import List

import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.kernels import matmul
from repro.kernels.ref import matmul_ref
from repro.perf.tile_tuner import select_tiles


def _arch_matmul_shapes():
    shapes = []
    for arch in ("deepseek-7b", "gemma2-27b", "grok-1-314b"):
        cfg = get_config(arch)
        d, f = cfg.d_model, max(cfg.d_ff, cfg.d_model)
        tokens = 4096
        shapes.append((arch + ":qkv", tokens, cfg.n_heads * cfg.head_dim_,
                       d))
        shapes.append((arch + ":ffn", tokens, f, d))
    return shapes


def run(report: List[str]) -> None:
    for name, m, n, k in _arch_matmul_shapes():
        c = select_tiles(m, n, k)
        report.append(
            f"{name:22s} ({m:5d}x{n:5d}x{k:5d}) -> tiles "
            f"({c.bm:4d},{c.bn:4d},{c.bk:4d}) pred={c.predicted_s * 1e3:.2f}ms")
    # correctness spot-check of the selected tiling (interpret mode)
    m, n, k = 256, 256, 256
    c = select_tiles(m, n, k, candidates=(64, 128))
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((m, k)), jnp.float32)
    y = jnp.asarray(rng.standard_normal((k, n)), jnp.float32)
    out = matmul(x, y, bm=c.bm, bn=c.bn, bk=c.bk, interpret=True)
    err = float(jnp.abs(out - matmul_ref(x, y)).max())
    report.append(f"selected tile correctness err={err:.2e}")


def main() -> None:
    report: List[str] = []
    run(report)
    print("\n".join(report))


if __name__ == "__main__":
    main()
