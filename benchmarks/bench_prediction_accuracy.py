"""Benchmark: blocked-algorithm prediction accuracy (paper Table 4.3).

For each blocked LAPACK algorithm, compare model-based runtime predictions
against measured executions over a range of problem sizes; report the
median-runtime absolute relative error (the paper's t_ARE^med).
"""

from __future__ import annotations

import time
from typing import Dict, List

import numpy as np

from repro.core import predict_runtime
from repro.dla import ExecEngine, blocked
from repro.dla.tracers import (getrf_tracer, lauum_tracer, potrf_tracer,
                               trtri_tracer)

from .common import build_model_set, lower_nonsing, median_time, spd

SIZES = (96, 160, 224, 288)
BLOCK = 48


def _exec_fns(n: int):
    A_spd, A_low = spd(n), lower_nonsing(n)
    rng = np.random.default_rng(1)
    A_gen = rng.standard_normal((n, n)) + n * np.eye(n)

    def run_potrf():
        eng = ExecEngine()
        blocked.potrf(eng, eng.bind("A", A_spd), n, BLOCK, variant=3)

    def run_trtri():
        eng = ExecEngine()
        blocked.trtri(eng, eng.bind("A", A_low), n, BLOCK, variant=3)

    def run_lauum():
        eng = ExecEngine()
        blocked.lauum(eng, eng.bind("A", A_low), n, BLOCK)

    def run_getrf():
        eng = ExecEngine()
        blocked.getrf(eng, eng.bind("A", A_gen), n, BLOCK)

    return {"potrf3": run_potrf, "trtri3": run_trtri, "lauum": run_lauum,
            "getrf": run_getrf}


TRACERS = {"potrf3": potrf_tracer(3), "trtri3": trtri_tracer(3),
           "lauum": lauum_tracer(), "getrf": getrf_tracer()}


def run(report: List[str]) -> None:
    ms, gen_s = build_model_set()
    header = f"{'algorithm':10s} " + " ".join(f"n={n:4d}" for n in SIZES) \
        + "   avg_ARE"
    report.append(header)
    for name, tracer in TRACERS.items():
        ares = []
        t_pred_total = 0.0
        for n in SIZES:
            t0 = time.perf_counter()
            pred = predict_runtime(tracer(n, BLOCK), ms).med
            t_pred_total += time.perf_counter() - t0
            meas = median_time(_exec_fns(n)[name], repetitions=5)
            ares.append(abs(pred - meas) / meas)
        avg = float(np.mean(ares))
        row = f"{name:10s} " + " ".join(f"{a:6.1%}" for a in ares) + \
            f"   {avg:6.1%}"
        report.append(row)
        report.append(
            f"  ({name}: prediction {t_pred_total * 1e3:.1f} ms total)")


def main() -> None:
    report: List[str] = []
    run(report)
    print("\n".join(report))


if __name__ == "__main__":
    main()
