"""Benchmark: algorithm selection (paper §4.5, Figs 4.12/4.14/4.17).

Rank the 3 Cholesky variants, 8 triangular-inversion variants, and 8
Sylvester combinations by model prediction; verify against exhaustive
timing; report winner agreement and the prediction-vs-measurement speedup
(the paper reports 100x-1500x).
"""

from __future__ import annotations

import time
from typing import Dict, List

import numpy as np

from repro.dla import ExecEngine, blocked
from repro.dla.tracers import (CHOLESKY_TRACERS, SYLVESTER_TRACERS,
                               TRTRI_TRACERS)
from repro.core import rank_algorithms

from .common import build_model_set, lower_nonsing, median_time, spd

N, B = 224, 48


def _measure_all(catalog: str) -> Dict[str, float]:
    out = {}
    if catalog == "cholesky":
        A0 = spd(N)
        for v in (1, 2, 3):
            def run(v=v):
                eng = ExecEngine()
                blocked.potrf(eng, eng.bind("A", A0), N, B, variant=v)
            out[f"potrf{v}"] = median_time(run, 5)
    elif catalog == "trtri":
        L0 = lower_nonsing(N)
        for v in range(1, 9):
            def run(v=v):
                eng = ExecEngine()
                blocked.trtri(eng, eng.bind("A", L0), N, B, variant=v)
            out[f"trtri{v}"] = median_time(run, 5)
    else:  # sylvester
        rng = np.random.default_rng(0)
        Au = np.triu(rng.standard_normal((N, N))) + N * np.eye(N)
        Bu = np.triu(rng.standard_normal((N, N))) + N * np.eye(N)
        C0 = rng.standard_normal((N, N))
        for alg in blocked.SYLVESTER_ALGORITHMS:
            def run(alg=alg):
                eng = ExecEngine()
                blocked.sylvester(eng, eng.bind("A", Au), eng.bind("B", Bu),
                                  eng.bind("C", C0), N, N, B, algorithm=alg)
            out[alg] = median_time(run, 3)
    return out


def run(report: List[str]) -> None:
    ms, _ = build_model_set()
    for catalog, tracers in (("cholesky", CHOLESKY_TRACERS),
                             ("trtri", TRTRI_TRACERS),
                             ("sylvester", SYLVESTER_TRACERS)):
        t0 = time.perf_counter()
        ranked = rank_algorithms(tracers, ms, N, B)
        t_pred = time.perf_counter() - t0
        t0 = time.perf_counter()
        ranked_scalar = rank_algorithms(tracers, ms, N, B, batched=False)
        t_pred_scalar = time.perf_counter() - t0
        # numerically tied variants may swap winners between the two paths'
        # summation orders — only a >1e-9 relative disagreement is a bug
        assert (ranked_scalar[0].name == ranked[0].name
                or abs(ranked_scalar[0].runtime.med - ranked[0].runtime.med)
                <= 1e-9 * max(ranked_scalar[0].runtime.med, 1e-300))
        t0 = time.perf_counter()
        measured = _measure_all(catalog)
        t_meas = time.perf_counter() - t0
        pred_winner = ranked[0].name
        meas_sorted = sorted(measured, key=measured.get)
        meas_winner = meas_sorted[0]
        # "correct" = predicted winner within 5% of the measured optimum
        within = measured[pred_winner] <= 1.05 * measured[meas_winner]
        worst = meas_sorted[-1]
        spread = measured[worst] / measured[meas_winner]
        report.append(
            f"{catalog:10s} algs={len(tracers)} "
            f"pred_winner={pred_winner:8s} meas_winner={meas_winner:8s} "
            f"agree={'Y' if within else 'N'} spread={spread:5.2f}x "
            f"pred_time={t_pred * 1e3:7.1f}ms "
            f"(scalar {t_pred_scalar * 1e3:7.1f}ms, "
            f"{t_pred_scalar / t_pred:4.0f}x) meas_time={t_meas:5.1f}s "
            f"speedup={t_meas / t_pred:7.0f}x")


def main() -> None:
    report: List[str] = []
    run(report)
    print("\n".join(report))


if __name__ == "__main__":
    main()
