"""Benchmark: einsum-path (contraction-chain) prediction (beyond-paper).

Full mode: for small demonstration chains, rank every candidate pairwise
contraction path through :class:`repro.tc.ChainPredictor`, execute the
predicted-best and predicted-worst paths with their selected per-step
algorithms, and report winner agreement, the measured spread between
paths, micro-benchmark deduplication across steps, and the prediction
cost as a fraction of the chosen chain's execution.

Smoke mode (the CI lane): a 4-operand chain whose steps contract two
indices each — no kernel can absorb a second contracted index, so even
the best per-step algorithm is a genuine loop nest and one chain
execution dwarfs the (deduplicated, canonically-shared) micro-benchmark
suite.  The candidate set is restricted to the gemm/gemv/gevm kernel
classes without batched variants: a batched one-call candidate's
micro-benchmark IS a step execution (cost fraction -> the repetition
count, never "a fraction"), and each extra kernel class costs one XLA
compile per distinct signature — the full-mode run keeps the complete
set.  The ``tc_chain_*`` metrics CI tracks across commits: suite cost,
path-rank time on both engine backends, backend and oracle agreement on
the top-ranked path, and the suite cost as a fraction of ONE execution
of the chosen chain (< 0.25 required).  A ``tc_sweep_chain_*`` section
re-ranks the same chain across three values of ``a`` from the SAME
suite — size-sweep autotuning at the einsum-path level, with the total
suite cost still a fraction of one chosen-chain execution.
"""

from __future__ import annotations

import time
from typing import Dict, List, Optional

import numpy as np

from repro.tc import (ChainPredictor, ChainSpec, PredictorSession,
                      execute_chain, execute_chain_reference)

from .common import best_of as _best_of
from .common import is_smoke

#: full-mode demonstration chains
CASES = [
    ("ij,jk,kl->il", dict(i=48, j=48, k=48, l=48), None),
    ("aij,ijb,bk->ak", dict(a=24, b=24, i=32, j=32, k=24), 64 * 2 ** 20),
]

#: smoke chain: steps 0.1 and 2.3 contract TWO indices (i,j / k,l), so
#: their fastest algorithms still loop over a full index extent
SMOKE_CHAIN = "aij,ijb,bkl,klc->ac"
SMOKE_SIZES = dict(a=4, b=4, c=4, i=2048, j=2048, k=2048, l=2048)
#: prune outer-product detours (aij x bkl etc.) whose intermediates the
#: suite could never afford to benchmark
SMOKE_LIMIT = 96 * 2 ** 20
SMOKE_REPETITIONS = 2
SMOKE_LOOP_PERMS = 2
SMOKE_KERNELS = ("gemm", "gemv", "gevm")
#: chain-level size-sweep grid: vary ``a`` (a loop/batch-like output
#: dimension) so most step signatures are shared with the a=4 run above
SWEEP_A = (4, 8, 16)


def _operands(chain: ChainSpec, sizes, seed: int = 0):
    rng = np.random.default_rng(seed)
    return [rng.standard_normal([sizes[i] for i in idx]).astype(np.float32)
            for idx in chain.operands]


def _run_full(report: List[str]) -> None:
    for expr, sizes, limit in CASES:
        chain = ChainSpec.parse(expr)
        t0 = time.perf_counter()
        pred = ChainPredictor(chain, sizes, repetitions=3,
                              memory_limit_bytes=limit)
        ranked = pred.rank_paths()
        t_pred = time.perf_counter() - t0
        best, worst = ranked[0], ranked[-1]
        ops = _operands(chain, sizes)
        t0 = time.perf_counter()
        out_best = execute_chain(chain, best, ops, sizes)
        t_best = time.perf_counter() - t0
        t0 = time.perf_counter()
        execute_chain(chain, worst, ops, sizes)
        t_worst = time.perf_counter() - t0
        # norm-relative: float32 chains differ from the one-shot einsum by
        # association order, element-wise near cancellations
        ref = execute_chain_reference(chain, ops)
        ok = np.linalg.norm(out_best - ref) / np.linalg.norm(ref) < 1e-3
        report.append(
            f"{expr:18s} paths={len(pred.paths):2d} "
            f"benchmarks={pred.n_benchmarks:3d} best={best.name:14s} "
            f"pred={t_pred:5.1f}s exec best/worst="
            f"{t_best:6.2f}/{t_worst:6.2f}s "
            f"({t_worst / max(t_best, 1e-9):4.1f}x) "
            f"correct={'Y' if ok else 'N'} "
            f"cost/exec={pred.prediction_cost_fraction(t_best):5.2f}")


def _run_smoke(report: List[str], results: Dict[str, object]) -> None:
    chain = ChainSpec.parse(SMOKE_CHAIN)
    sess = PredictorSession(repetitions=SMOKE_REPETITIONS)
    pred = sess.chain_predictor(chain, SMOKE_SIZES,
                                include_batched=False,
                                kernels=SMOKE_KERNELS,
                                max_loop_perms=SMOKE_LOOP_PERMS,
                                memory_limit_bytes=SMOKE_LIMIT)
    ranked_np = pred.rank_paths(backend="numpy")    # suite runs here once
    t_suite = pred.suite.cost_seconds
    t_np = _best_of(lambda: pred.rank_paths(backend="numpy"), 3)
    ranked_jax = pred.rank_paths(backend="jax")
    t_jax = _best_of(lambda: pred.rank_paths(backend="jax"), 3)
    backend_agree = [r.name for r in ranked_np] == \
        [r.name for r in ranked_jax]

    # the step-by-step per-algorithm scalar oracle on the SAME measurements
    # (fresh=True would re-measure: only top-1 agreement would be noise-
    # robust, and the smoke lane must stay deterministic)
    oracle = pred.rank_paths_oracle(fresh=False)
    oracle_top_agree = oracle[0].name == ranked_np[0].name

    # ONE execution of the chosen chain as the cost-fraction denominator:
    # the acceptance bar is suite cost < 0.25 of the runtime it predicts
    best = ranked_np[0]
    ops = _operands(chain, SMOKE_SIZES)
    t0 = time.perf_counter()
    execute_chain(chain, best, ops, SMOKE_SIZES)
    t_exec = time.perf_counter() - t0
    fraction = pred.prediction_cost_fraction(t_exec)

    n_steps = sum(len(p.steps) for p in pred.paths)
    report.append(
        f"tc_chain {SMOKE_CHAIN} sizes={SMOKE_SIZES}: "
        f"paths={len(pred.paths)} steps={n_steps} "
        f"benchmarks={pred.n_benchmarks} suite={t_suite:5.2f}s")
    report.append(
        f"  rank: numpy={t_np * 1e3:6.2f}ms jax={t_jax * 1e3:6.2f}ms "
        f"backends {'==' if backend_agree else '!='} "
        f"oracle-top {'==' if oracle_top_agree else '!='} "
        f"winner={best.name} "
        f"steps={'|'.join(s.name[:24] for s in best.steps)}")
    report.append(
        f"  exec chosen chain: {t_exec:5.2f}s -> suite cost fraction "
        f"{fraction:5.3f} ({'<' if fraction < 0.25 else '>='} 0.25 target)")
    results.update({
        "tc_chain_paths": len(pred.paths),
        "tc_chain_steps": n_steps,
        "tc_chain_benchmarks": pred.n_benchmarks,
        "tc_chain_suite_s": t_suite,
        "tc_chain_rank_numpy_s": t_np,
        "tc_chain_rank_jax_s": t_jax,
        "tc_chain_backend_agree": bool(backend_agree),
        "tc_chain_oracle_agree": bool(oracle_top_agree),
        "tc_chain_exec_s": t_exec,
        "tc_chain_cost_frac": fraction,
    })

    # ---- chain-level size sweep: 3 values of a, SAME suite ----
    # the a=4 ranking above already measured most step signatures; new
    # points only measure the signatures whose shapes contain a
    before = pred.suite.counters()
    grid = [dict(SMOKE_SIZES, a=a) for a in SWEEP_A]
    sweep = sess.rank_einsum_sweep(chain, grid, include_batched=False,
                                   kernels=SMOKE_KERNELS,
                                   max_loop_perms=SMOKE_LOOP_PERMS,
                                   memory_limit_bytes=SMOKE_LIMIT)
    added = pred.suite.counters()
    new_benchmarks = int(added["n_benchmarks"] - before["n_benchmarks"])
    sweep_fraction = sweep.cost_fraction(t_exec)
    report.append(
        f"tc_sweep_chain a={list(SWEEP_A)}: points={len(grid)} "
        f"new_benchmarks={new_benchmarks} (total {sweep.n_benchmarks}) "
        f"winners={'|'.join(w.name for w in sweep.winners)} -> "
        f"total suite cost fraction {sweep_fraction:5.3f} "
        f"({'<' if sweep_fraction < 0.25 else '>='} 0.25 target)")
    results.update({
        "tc_sweep_chain_points": len(grid),
        "tc_sweep_chain_new_benchmarks": new_benchmarks,
        "tc_sweep_chain_suite_s": sweep.suite.cost_seconds,
        "tc_sweep_chain_cost_frac": sweep_fraction,
    })


def run(report: List[str],
        results: Optional[Dict[str, object]] = None) -> None:
    if is_smoke():
        _run_smoke(report, results if results is not None else {})
    else:
        _run_full(report)


def main() -> None:
    report: List[str] = []
    run(report)
    print("\n".join(report))


if __name__ == "__main__":
    main()
