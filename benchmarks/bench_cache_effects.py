"""Benchmark: cache-precondition effects (paper §2.1.4 Tab 2.2 / Ch. 5).

Measure warm vs cold invocations of a bandwidth-bound (gemv-like) and a
compute-bound (gemm) kernel, then reproduce §5.1.3's combined in/out-of-
cache prediction for a blocked Cholesky: alpha is calibrated on ONE
execution and the combined estimate is compared against plain warm-model
prediction.
"""

from __future__ import annotations

import functools
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.cachestudy import (CacheTimings, calibrate_alpha,
                                   combine_estimates, measure_cache_effects)


@functools.lru_cache(maxsize=None)
def _gemv():
    return jax.jit(lambda a, x: a @ x)


@functools.lru_cache(maxsize=None)
def _gemm():
    return jax.jit(lambda a, b: a @ b)


def _kernel_timings(kind: str, n: int) -> CacheTimings:
    rng = np.random.default_rng(0)

    if kind == "gemv":
        fn = _gemv()
        bufs = [(jnp.asarray(rng.standard_normal((n, n)), jnp.float32),
                 jnp.asarray(rng.standard_normal((n,)), jnp.float32))
                for _ in range(8)]
    else:
        fn = _gemm()
        bufs = [(jnp.asarray(rng.standard_normal((n, n)), jnp.float32),
                 jnp.asarray(rng.standard_normal((n, n)), jnp.float32))
                for _ in range(8)]

    def make_call_at(i):
        a, b = bufs[i % len(bufs)]
        return lambda: fn(a, b).block_until_ready()

    return measure_cache_effects(make_call_at, repetitions=10)


def run(report: List[str]) -> None:
    # Tab 2.2 analogue: the bandwidth-bound kernel suffers far more from
    # cold operands than the compute-bound one
    for kind, n in (("gemv", 1024), ("gemm", 512)):
        t = _kernel_timings(kind, n)
        report.append(
            f"{kind} n={n}: warm={t.warm.med * 1e6:8.1f}us "
            f"cold={t.cold.med * 1e6:8.1f}us "
            f"overhead={t.overhead * 1e6:7.1f}us ({t.overhead_rel:+.0%})")
    # Ch 5 mixing: calibrate alpha on one measured execution
    warm_pred, cold_pred = 1.0e-3, 1.6e-3        # illustrative units
    measured = 1.25e-3
    alpha = calibrate_alpha(warm_pred, cold_pred, measured)
    combined = combine_estimates(warm_pred, cold_pred, alpha)
    report.append(
        f"ch5 mixing: alpha={alpha:.2f} combined={combined * 1e3:.3f}ms "
        f"(measured {measured * 1e3:.3f}ms; warm-only would be "
        f"{warm_pred * 1e3:.3f}ms)")


def main() -> None:
    report: List[str] = []
    run(report)
    print("\n".join(report))


if __name__ == "__main__":
    main()
