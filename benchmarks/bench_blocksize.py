"""Benchmark: block-size optimization (paper §4.6, Figs 4.19/4.20).

Predict the optimal block size for blocked Cholesky (variant 3) and
triangular inversion (variant 3) at several problem sizes; compare with the
empirical optimum and report the paper's *performance yield*
t_meas(b_opt)/t_meas(b_pred) — the paper achieves >= 96-99%.
"""

from __future__ import annotations

from typing import Dict, List

import numpy as np

from repro.core import optimize_block_size, performance_yield
from repro.dla import ExecEngine, blocked
from repro.dla.tracers import potrf_tracer, trtri_tracer

from .common import build_model_set, lower_nonsing, median_time, spd

SIZES = (160, 256)
CANDIDATES = (16, 32, 48, 64, 96, 128)


def _measured_profile(kind: str, n: int) -> Dict[int, float]:
    out = {}
    A0 = spd(n) if kind == "potrf" else lower_nonsing(n)
    for b in CANDIDATES:
        def run(b=b):
            eng = ExecEngine()
            A = eng.bind("A", A0)
            if kind == "potrf":
                blocked.potrf(eng, A, n, b, variant=3)
            else:
                blocked.trtri(eng, A, n, b, variant=3)
        out[b] = median_time(run, 5)
    return out


def run(report: List[str]) -> None:
    import time

    ms, _ = build_model_set()
    for kind, tracer in (("potrf", potrf_tracer(3)),
                         ("trtri", trtri_tracer(3))):
        for n in SIZES:
            t0 = time.perf_counter()
            b_pred, profile = optimize_block_size(tracer, ms, n, CANDIDATES)
            t_batched = time.perf_counter() - t0
            t0 = time.perf_counter()
            b_scalar, prof_scalar = optimize_block_size(tracer, ms, n,
                                                        CANDIDATES,
                                                        batched=False)
            t_scalar = time.perf_counter() - t0
            # candidates tied at float level may swap argmins between the
            # paths; the two profiles at either argmin must still agree
            assert (b_scalar == b_pred
                    or abs(prof_scalar[b_scalar] - profile[b_pred])
                    <= 1e-9 * max(prof_scalar[b_scalar], 1e-300)), \
                (b_scalar, b_pred)
            measured = _measured_profile(kind, n)
            b_opt, yld = performance_yield(measured, b_pred)
            report.append(
                f"{kind} n={n:4d}: b_pred={b_pred:3d} b_opt={b_opt:3d} "
                f"yield={yld:6.1%} "
                f"(t_pred(b)={profile[b_pred] * 1e3:.2f}ms "
                f"t_meas(b_pred)={measured[b_pred] * 1e3:.2f}ms "
                f"sweep {t_scalar * 1e3:.1f}ms->{t_batched * 1e3:.1f}ms "
                f"{t_scalar / t_batched:.0f}x)")


def main() -> None:
    report: List[str] = []
    run(report)
    print("\n".join(report))


if __name__ == "__main__":
    main()
