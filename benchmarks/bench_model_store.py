"""Model-store smoke: warm start, drift probe, predictor tournament.

The store subsystem's whole claim is "measure once per platform, predict
forever" — this bench proves the three pieces of that claim on the CI
runner every commit:

* **persistence + warm start** — measure the smoke contraction
  workloads, save ``PLATFORM_STORE.json``, reload it into a fresh
  session, and re-rank: the warm session must answer with ZERO new
  micro-benchmarks (``measured == 0`` in the suite counters) and
  *bit-identical* rankings.  ``store_warmstart_ms`` (load + both
  re-rankings) is the trended headline — it is what a serve process pays
  instead of re-measuring;
* **drift probe** — re-measure the deterministic probe subset against
  the just-written store; on a healthy runner the max drift ratio stays
  near 1 (it is reported, not asserted: shared runners wobble);
* **tournament** — score the fresh store against a deliberately
  protocol-degraded snapshot (repetitions=1: same platform, noisier
  measurements) on the frozen workloads, vs a freshly measured oracle,
  and write the ``TOURNAMENT.json`` scoreboard.
  ``tournament_rank_agreement`` (the winner's mean Kendall-tau vs the
  oracle) is trended across commits — rank agreement is the selection
  metric that matters (arXiv:1409.8602).

When CI carries the previous run's store (``REPRO_STORE_PREV``), the
bench also tries a cross-run warm start under the strict fingerprint
check — ``store_prev_hit`` says whether the runner platform held still.
"""

from __future__ import annotations

import os
import time
from typing import Dict, List, Optional

from repro.store import (ModelStore, Snapshot, StoreMismatchError,
                         frozen_workloads, run_tournament)
from repro.tc import PredictorSession

from .common import is_smoke

STORE_PATH = "PLATFORM_STORE.json"
TOURNAMENT_PATH = "TOURNAMENT.json"
#: cheap measurement protocol for the smoke lane (bench_contractions uses
#: the same repetition count for its smoke suite)
SMOKE_REPETITIONS = 2


def _rank_workloads(sess: PredictorSession, loads) -> List[List[tuple]]:
    return [load.rank(sess) for load in loads]


def _run(report: List[str], results: Dict[str, object], *,
         smoke: bool) -> None:
    loads = frozen_workloads(smoke=smoke)

    # throwaway warm-up pass: compiles every jitted kernel and heats the
    # caches, so the measurements the store persists (and the drift probe
    # and oracle later re-take) all run on a hot process — without this,
    # the first session's timings carry process warm-up and read as
    # "drift" the moment anything re-measures.  Its wall-clock is what a
    # COLD process pays on top of the hot measurement cost, so the
    # warm-start amortization is stated against warmup + measure.
    t0 = time.perf_counter()
    _rank_workloads(PredictorSession(repetitions=1), loads)
    t_warmup = time.perf_counter() - t0

    # ---- measure once, persist ----
    sess = PredictorSession(repetitions=SMOKE_REPETITIONS)
    rankings = _rank_workloads(sess, loads)
    t_measure = sess.suite.cost_seconds + t_warmup
    t0 = time.perf_counter()
    sess.save_store(STORE_PATH)
    t_save = time.perf_counter() - t0
    store_bytes = os.path.getsize(STORE_PATH)

    # ---- warm start: load + re-rank, zero new measurements ----
    t0 = time.perf_counter()
    warm = PredictorSession(store=STORE_PATH)
    warm_rankings = _rank_workloads(warm, loads)
    t_warm = time.perf_counter() - t0
    counters = warm.counters()
    identical = warm_rankings == rankings
    # the store's contract, enforced every commit: a warm start answers
    # the stored workloads without measuring, and predictions are a pure
    # function of the (bit-exactly round-tripped) measurements
    assert counters["measured"] == 0, \
        f"warm start measured {counters['measured']} new benchmarks"
    assert identical, "warm-started rankings differ from in-memory"
    report.append(
        f"store {STORE_PATH}: keys={int(counters['loaded'])} "
        f"({store_bytes / 1024:.0f} KiB) measure={t_measure:5.2f}s "
        f"save={t_save * 1e3:6.1f}ms")
    amortizes = t_measure / t_warm if t_warm else float("inf")
    report.append(
        f"  warm start: load+rank={t_warm * 1e3:6.1f}ms "
        f"new_measurements={int(counters['measured'])} "
        f"rankings {'==' if identical else '!='} in-memory "
        f"(amortizes {amortizes:6.1f}x)")
    results.update({
        "store_keys": int(counters["loaded"]),
        "store_bytes": store_bytes,
        "store_measure_s": t_measure,
        "store_save_ms": t_save * 1e3,
        "store_warmstart_ms": t_warm * 1e3,
        "store_new_measurements": int(counters["measured"]),
        "store_roundtrip_identical": bool(identical),
    })

    # ---- drift probe on the warm session (real re-measurement) ----
    probe_readings = warm.check_drift(max_keys=4)
    max_ratio = max((max(r.ratio, 1 / r.ratio) for r in probe_readings),
                    default=1.0)
    report.append(
        f"  drift probe: {len(probe_readings)} keys, "
        f"max ratio {max_ratio:5.2f} "
        f"(threshold 1.5; shared-runner noise expected)")
    results.update({
        "store_drift_probed": len(probe_readings),
        "store_drift_max_ratio": max_ratio,
    })

    # ---- tournament: fresh protocol vs degraded protocol ----
    noisy = PredictorSession(repetitions=1)
    _rank_workloads(noisy, loads)
    snapshots = [
        Snapshot(f"rep{SMOKE_REPETITIONS}", ModelStore.load(STORE_PATH)),
        Snapshot("rep1", noisy.save_store()),
    ]
    tourney = run_tournament(snapshots, loads,
                             oracle_session=PredictorSession(
                                 repetitions=SMOKE_REPETITIONS))
    tourney.save(TOURNAMENT_PATH)
    report.append(tourney.describe())
    winner = tourney.winner
    results.update({
        "tournament_snapshots": len(tourney.scores),
        "tournament_rank_agreement": winner.rank_agreement,
        "tournament_top1_rate": winner.top1_rate,
        "tournament_rel_err": winner.rel_err,
        "tournament_oracle_cost_s": tourney.oracle_cost_seconds,
    })

    # ---- cross-run warm start from the previous CI run's store ----
    prev = os.environ.get("REPRO_STORE_PREV", "prev-smoke/PLATFORM_STORE.json")
    hit = 0.0
    if os.path.exists(prev):
        try:
            prev_store = ModelStore.load(prev)   # strict fingerprint check
            hit = 1.0
            report.append(f"  prev-run store {prev}: fingerprint match, "
                          f"{prev_store.n_keys} keys reusable")
        except StoreMismatchError as e:
            report.append(f"  prev-run store {prev}: REFUSED ({e})")
    else:
        report.append(f"  prev-run store {prev}: absent "
                      f"(first run or artifact expired)")
    results["store_prev_hit"] = hit


def run(report: List[str],
        results: Optional[Dict[str, object]] = None) -> None:
    _run(report, results if results is not None else {},
         smoke=is_smoke())


def main() -> None:
    report: List[str] = []
    run(report)
    print("\n".join(report))


if __name__ == "__main__":
    main()
