"""Benchmark: model accuracy vs generation cost (paper §3.3, Fig 3.13).

Generate trsm models under several generator configurations, evaluate each
against an exhaustive measurement sweep, and report the accuracy/cost
trade-off the paper uses to pick its default configuration.
"""

from __future__ import annotations

import time
from typing import List

import numpy as np

from repro.core import (GeneratorConfig, KernelBenchmark, generate_model)
from repro.core.grids import Domain
from repro.dla.kernels import KERNELS

CASE = ("L", "L", "N", "N", -1)
DOMAIN = Domain((16, 16), (272, 272))

CONFIGS = {
    "cheap": GeneratorConfig(overfit=0, oversampling=1, repetitions=3,
                             error_bound=0.05, min_width=128, max_pieces=4),
    "default": GeneratorConfig(overfit=0, oversampling=2, repetitions=5,
                               error_bound=0.03, min_width=64,
                               max_pieces=12),
    "accurate": GeneratorConfig(overfit=1, oversampling=3, repetitions=5,
                                error_bound=0.015, min_width=32,
                                max_pieces=24),
}


def _exhaustive(points, repetitions=5):
    kd = KERNELS["trsm"]
    from repro.core.sampler import measure_calls
    calls = {p: kd.make_call(CASE, p) for p in points}
    return measure_calls(calls, repetitions=repetitions)


def run(report: List[str]) -> None:
    kd = KERNELS["trsm"]
    rng = np.random.default_rng(0)
    eval_points = [tuple(int(8 * round(v / 8)) for v in p)
                   for p in rng.integers(24, 264, size=(25, 2))]
    truth = _exhaustive(eval_points)
    for name, cfg in CONFIGS.items():
        bench = KernelBenchmark(
            name="trsm", cases=(CASE,), domain=DOMAIN,
            cost_exponents=kd.cost_exponents,
            make_call=lambda case, sizes: kd.make_call(case, sizes))
        t0 = time.perf_counter()
        model, rep = generate_model(bench, cfg)
        cost_s = time.perf_counter() - t0
        errs = []
        for p in eval_points:
            est = model.estimate(CASE, p)["min"]
            errs.append(abs(est - truth[p].min) / truth[p].min)
        report.append(
            f"config={name:9s} model_error={np.mean(errs):6.1%} "
            f"max={np.max(errs):6.1%} pieces="
            f"{sum(rep.pieces_per_case.values()):2d} "
            f"points={rep.measured_points:4d} cost={cost_s:5.1f}s")


def main() -> None:
    report: List[str] = []
    run(report)
    print("\n".join(report))


if __name__ == "__main__":
    main()
