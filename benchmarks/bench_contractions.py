"""Benchmark: tensor-contraction prediction on the tc subsystem (paper Ch. 6).

Full mode: for the paper's example contraction C_abc := A_ai B_ibc (skewed
i=8), the vector contraction C_a := A_iaj B_ji and a batched spec
bij,bjk->bik, rank every candidate (batched-kernel algorithms included)
through :class:`repro.tc.ContractionPredictor`, execute a representative
subset, and report winner agreement, micro-benchmark deduplication and the
prediction-cost fraction (the paper: merely a fraction of a contraction's
runtime).

Smoke mode (the CI lane): the batched spec at i=j=k=64 — the ``tc_rank64_*``
metrics CI tracks across commits: suite cost, rank time on both engine
backends, and the suite cost as a fraction of one measured contraction
execution (a pinned representative candidate, executed once, so the
denominator's identity cannot drift with the ranking).  A second smoke
section exercises size-sweep autotuning (``tc_sweep_*``): the same
candidate set ranked across three batch sizes from the SAME suite the
single-size ranking already filled — sweeping the loop-only dimension
``b`` re-predicts the loop-nest candidates without any new measurement
(only batched-kernel signatures, whose shapes contain ``b``, are new),
and the whole sweep's suite cost must stay < 0.25 of the one pinned
execution.

A third smoke section (``tc_param_*``) exercises the size-parametric
suite models: budgeted adaptive refinement at the endpoints of an i-grid,
then a sweep over held-out sizes that were NEVER measured — zero fresh
micro-benchmarks (hard-asserted via the suite's ``measured`` counter),
holdout accuracy and top-1 agreement vs the fresh measured oracle and
the refinement cost fraction reported as metrics.
"""

from __future__ import annotations

import time
from typing import Dict, List, Optional

import numpy as np

from repro.core.contractions import (ContractionSpec, execute,
                                     measure_contraction)
from repro.tc import (ContractionPredictor, PredictorSession,
                      is_batched_kernel)

from .common import best_of as _best_of
from .common import is_smoke

CASES = [
    ("abc=ai,ibc", dict(a=48, b=48, c=48, i=8)),
    ("a=iaj,ji", dict(a=48, i=24, j=24)),
    ("bij,bjk->bik", dict(b=8, i=48, j=48, k=48)),
]

SMOKE_SPEC = "bij,bjk->bik"
SMOKE_SIZES = dict(b=8, i=64, j=64, k=64)
#: size-sweep smoke grid: b is loop-only for every non-batched candidate,
#: so two of the three points re-predict from b=8's measurements
SWEEP_GRID = [dict(SMOKE_SIZES, b=b) for b in (8, 16, 32)]
#: size-parametric smoke: refinement sees only the ENDPOINTS of i in
#: [32, 96] (its cartesian root grid samples i-derived extents at
#: 32/64/96); the holdouts 40/56 are inside every fitted domain but on
#: no refinement grid — predicting them must cost zero measurements
PARAM_REFINE_GRID = [dict(SMOKE_SIZES, i=i) for i in (32, 96)]
PARAM_HOLDOUTS = [dict(SMOKE_SIZES, i=i) for i in (40, 56)]


def _operands(spec: ContractionSpec, sizes, seed: int = 0):
    rng = np.random.default_rng(seed)
    A = rng.standard_normal([sizes[i] for i in spec.a_idx]).astype(np.float32)
    B = rng.standard_normal([sizes[i] for i in spec.b_idx]).astype(np.float32)
    return A, B


def _run_full(report: List[str]) -> None:
    for expr, sizes in CASES:
        spec = ContractionSpec.parse(expr)
        t0 = time.perf_counter()
        pred = ContractionPredictor(spec, sizes, repetitions=3)
        ranked = pred.rank()
        t_pred = time.perf_counter() - t0
        n_batched = sum(is_batched_kernel(a.kernel) for a in pred.algorithms)
        # execute the predicted-best, the predicted-worst and two middles
        A, B = _operands(spec, sizes)
        picks = [ranked[0], ranked[len(ranked) // 3],
                 ranked[2 * len(ranked) // 3], ranked[-1]]
        t0 = time.perf_counter()
        meas = {r.name: measure_contraction(r.algorithm, A, B, sizes, 3).med
                for r in picks}
        t_meas = time.perf_counter() - t0
        order_meas = sorted(meas, key=meas.get)
        agree = picks[0].name == order_meas[0]
        spread = meas[order_meas[-1]] / meas[order_meas[0]]
        frac = pred.prediction_cost_fraction(meas[picks[1].name])
        report.append(
            f"{expr:14s} algs={len(pred.algorithms):3d} "
            f"(batched {n_batched}) benchmarks={pred.n_benchmarks:3d} "
            f"best_pred={picks[0].name[:26]:26s} "
            f"agree={'Y' if agree else 'N'} spread={spread:7.1f}x "
            f"pred={t_pred:5.1f}s meas(4 algs)={t_meas:6.1f}s "
            f"cost/exec={frac:5.2f}")


def _run_smoke(report: List[str], results: Dict[str, object]) -> None:
    spec = ContractionSpec.parse(SMOKE_SPEC)
    sess = PredictorSession(repetitions=2)
    pred = sess.contraction_predictor(spec, SMOKE_SIZES)
    pred.prepare()
    t_suite = pred.suite.cost_seconds
    n_batched = sum(is_batched_kernel(a.kernel) for a in pred.algorithms)

    ranked_np = pred.rank(backend="numpy")          # engine + compile warmup
    t_np = _best_of(lambda: pred.rank(backend="numpy"), 3)
    ranked_jax = pred.rank(backend="jax")
    t_jax = _best_of(lambda: pred.rank(backend="jax"), 3)
    backend_agree = [r.name for r in ranked_np] == [r.name for r in ranked_jax]

    # the per-algorithm scalar oracle on the SAME measurements: isolates the
    # engine-vs-scalar arithmetic, so the whole ordering must agree exactly
    # (fresh=True would re-measure and only top-1 agreement would be noise-
    # robust enough to track)
    oracle = pred.rank_oracle(fresh=False)
    oracle_agree = [r.name for r in oracle] == [r.name for r in ranked_np]

    # one measured contraction execution as the cost-fraction denominator:
    # a PINNED candidate (the dot kernel under loops b,i,k — a typical
    # mid-field traversal), so the metric stays comparable across commits
    # even if the ranking shifts
    pinned = next(a for a in pred.algorithms
                  if a.kernel == "dot" and a.loop_order == ("b", "i", "k"))
    A, B = _operands(spec, SMOKE_SIZES)
    t0 = time.perf_counter()
    execute(pinned, A, B, SMOKE_SIZES)
    t_exec = time.perf_counter() - t0
    fraction = t_suite / t_exec

    report.append(
        f"tc_rank64 {SMOKE_SPEC} sizes={SMOKE_SIZES}: "
        f"algs={len(pred.algorithms)} (batched {n_batched}) "
        f"benchmarks={pred.n_benchmarks} suite={t_suite:5.2f}s")
    report.append(
        f"  rank: numpy={t_np * 1e3:6.2f}ms jax={t_jax * 1e3:6.2f}ms "
        f"backends {'==' if backend_agree else '!='} "
        f"oracle {'==' if oracle_agree else '!='} "
        f"winner={ranked_np[0].name}")
    report.append(
        f"  exec pinned ({pinned.name}): {t_exec:5.2f}s -> "
        f"suite cost fraction {fraction:5.3f} "
        f"({'<' if fraction < 0.25 else '>='} 0.25 target)")
    results.update({
        "tc_rank64_algorithms": len(pred.algorithms),
        "tc_rank64_batched_algorithms": n_batched,
        "tc_rank64_benchmarks": pred.n_benchmarks,
        "tc_rank64_suite_s": t_suite,
        "tc_rank64_rank_numpy_s": t_np,
        "tc_rank64_rank_jax_s": t_jax,
        "tc_rank64_backend_agree": bool(backend_agree),
        "tc_rank64_oracle_agree": bool(oracle_agree),
        "tc_rank64_exec_s": t_exec,
        "tc_rank64_cost_frac": fraction,
    })

    # ---- size-sweep autotuning over 3 batch sizes, ONE shared suite ----
    # the single-size ranking above already measured every signature at
    # b=8; sweeping b re-predicts the loop-nest candidates for free and
    # only measures the batched-kernel signatures whose shapes contain b
    before = pred.suite.counters()
    sweep = sess.rank_contraction_sweep(spec, SWEEP_GRID)
    added = pred.suite.counters()
    t_sweep_np = _best_of(lambda: [p.rank(backend="numpy")
                                   for p in sweep.predictors], 3)
    [p.rank(backend="jax") for p in sweep.predictors]   # compile warmup
    t_sweep_jax = _best_of(lambda: [p.rank(backend="jax")
                                    for p in sweep.predictors], 3)
    new_benchmarks = int(added["n_benchmarks"] - before["n_benchmarks"])
    # the pinned execution above is the denominator: the TOTAL suite cost
    # (single-size ranking + whole sweep) must stay a fraction of ONE run
    sweep_fraction = sweep.cost_fraction(t_exec)
    report.append(
        f"tc_sweep {SMOKE_SPEC} b={[g['b'] for g in SWEEP_GRID]}: "
        f"points={len(SWEEP_GRID)} new_benchmarks={new_benchmarks} "
        f"(total {sweep.n_benchmarks}) suite={sweep.suite.cost_seconds:5.2f}s")
    report.append(
        f"  rank all points: numpy={t_sweep_np * 1e3:6.2f}ms "
        f"jax={t_sweep_jax * 1e3:6.2f}ms "
        f"winners={'|'.join(w.name[:24] for w in sweep.winners)} -> "
        f"total suite cost fraction {sweep_fraction:5.3f} "
        f"({'<' if sweep_fraction < 0.25 else '>='} 0.25 target)")
    results.update({
        "tc_sweep_points": len(SWEEP_GRID),
        "tc_sweep_new_benchmarks": new_benchmarks,
        "tc_sweep_benchmarks": sweep.n_benchmarks,
        "tc_sweep_suite_s": sweep.suite.cost_seconds,
        "tc_sweep_rank_numpy_s": t_sweep_np,
        "tc_sweep_rank_jax_s": t_sweep_jax,
        "tc_sweep_cost_frac": sweep_fraction,
    })

    # ---- size-parametric models: predict a NEVER-measured size grid ----
    # a fresh parametric session refines per-signature models at the
    # grid endpoints (budgeted, uncertainty-driven sampling), then the
    # sweep covers the held-out sizes purely from the fitted models —
    # zero fresh micro-benchmarks is a hard in-bench invariant, the
    # holdout accuracy and top-1 agreement vs the fresh measured oracle
    # are reported metrics (real timings are noisy; the deterministic
    # equivalences live in tests/test_parametric.py)
    psess = PredictorSession(repetitions=2, parametric=True)
    refined = psess.refine_parametric(spec, PARAM_REFINE_GRID)
    t_param_suite = psess.suite.cost_seconds
    before = psess.suite.counters()
    psweep = psess.rank_contraction_sweep(spec, PARAM_HOLDOUTS)
    after = psess.suite.counters()
    assert after["measured"] == before["measured"], \
        "parametric sweep over held-out sizes issued fresh micro-benchmarks"
    assert psweep.predicted_parametric > 0, \
        "parametric sweep predicted nothing — models cover no holdout key"
    # holdout accuracy, per predicted KEY: the fitted per-call MIN (the
    # only statistic stable at repetitions=2 on these microsecond
    # kernels — one scheduler hiccup makes med 8x min) vs one fresh
    # exact measurement of the same key through the suite's own protocol
    # (comparing totals against a fresh oracle would mostly measure the
    # jit cache: stored first-call overheads include XLA compile, a
    # re-measurement's do not)
    relerr = 0.0
    for key, mb in psess.suite.predictions.items():
        fresh_stats, _ = psess.suite.measure_fn(key,
                                                psess.suite.repetitions)
        relerr = max(relerr,
                     abs(mb.stats.min - fresh_stats.min) / fresh_stats.min)
    # top-1 vs the fresh measured oracle on first-excluded min totals
    # (same jit-cache and noise reasoning), noise-robust: the predicted
    # winner's measured runtime must be within 25% of the measured
    # optimum — near-tied candidates on real timings are legitimate ties
    top1_agree = True
    for sizes_h, ranking in zip(PARAM_HOLDOUTS, psweep.rankings):
        oracle = psess.contraction_predictor(spec, sizes_h).rank_oracle(
            stat="min", fresh=True)
        best = {r.name: r.runtime.min - r.first for r in oracle}
        winner = min(ranking, key=lambda r: r.runtime.min - r.first)
        top1_agree &= best[winner.name] <= min(best.values()) * 1.25
    param_fraction = psess.suite.cost_seconds / t_exec
    report.append(
        f"tc_param {SMOKE_SPEC} refine i={[g['i'] for g in PARAM_REFINE_GRID]}"
        f" holdouts i={[g['i'] for g in PARAM_HOLDOUTS]}: "
        f"signatures={psess.parametric.n_signatures} "
        f"refine_measured={refined['measured']} suite={t_param_suite:5.2f}s")
    report.append(
        f"  sweep: measured +{int(after['measured'] - before['measured'])} "
        f"predicted={psweep.predicted_parametric} "
        f"top1_oracle_agree={'Y' if top1_agree else 'N'} "
        f"holdout_relerr={relerr:6.3f} -> "
        f"suite cost fraction {param_fraction:5.3f} "
        f"({'<' if param_fraction < 0.25 else '>='} 0.25 target)")
    results.update({
        "tc_param_signatures": psess.parametric.n_signatures,
        "tc_param_refine_measured": refined["measured"],
        "tc_param_refine_suite_s": t_param_suite,
        "tc_param_predicted": psweep.predicted_parametric,
        "tc_param_top1_agree": bool(top1_agree),
        "tc_param_holdout_relerr": relerr,
        "tc_param_cost_frac": param_fraction,
    })


def run(report: List[str],
        results: Optional[Dict[str, object]] = None) -> None:
    if is_smoke():
        _run_smoke(report, results if results is not None else {})
    else:
        _run_full(report)


def main() -> None:
    report: List[str] = []
    run(report)
    print("\n".join(report))


if __name__ == "__main__":
    main()
