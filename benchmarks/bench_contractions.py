"""Benchmark: tensor-contraction micro-benchmark prediction (paper Ch. 6).

For the paper's example contraction C_abc := A_ai B_ibc (skewed i=8) and
the vector contraction C_a := A_iaj B_ji, predict every algorithm via
cache-aware micro-benchmarks, execute a representative subset, and report
winner agreement plus the prediction speedup (the paper: orders of
magnitude faster than one execution).
"""

from __future__ import annotations

import time
from typing import List

import numpy as np

from repro.core.contractions import (ContractionSpec, execute,
                                     generate_algorithms,
                                     measure_contraction,
                                     rank_contraction_algorithms)

CASES = [
    ("abc=ai,ibc", dict(a=48, b=48, c=48, i=8)),
    ("a=iaj,ji", dict(a=48, i=24, j=24)),
]


def run(report: List[str]) -> None:
    for expr, sizes in CASES:
        spec = ContractionSpec.parse(expr)
        algs = generate_algorithms(spec)
        t0 = time.perf_counter()
        ranked = rank_contraction_algorithms(spec, sizes, algorithms=algs,
                                             repetitions=3)
        t_pred = time.perf_counter() - t0
        # execute the predicted-best, the predicted-worst and two middles
        rng = np.random.default_rng(0)
        A = rng.standard_normal([sizes[i] for i in spec.a_idx]
                                ).astype(np.float32)
        B = rng.standard_normal([sizes[i] for i in spec.b_idx]
                                ).astype(np.float32)
        picks = [ranked[0], ranked[len(ranked) // 3],
                 ranked[2 * len(ranked) // 3], ranked[-1]]
        t0 = time.perf_counter()
        meas = {a.name: measure_contraction(a, A, B, sizes, 3).med
                for a, _ in picks}
        t_meas = time.perf_counter() - t0
        order_pred = [a.name for a, _ in picks]
        order_meas = sorted(meas, key=meas.get)
        agree = order_pred[0] == order_meas[0]
        spread = meas[order_meas[-1]] / meas[order_meas[0]]
        report.append(
            f"{expr:14s} algs={len(algs):3d} "
            f"best_pred={order_pred[0][:26]:26s} "
            f"agree={'Y' if agree else 'N'} spread={spread:7.1f}x "
            f"pred={t_pred:5.1f}s meas(4 algs)={t_meas:6.1f}s")


def main() -> None:
    report: List[str] = []
    run(report)
    print("\n".join(report))


if __name__ == "__main__":
    main()
