"""Benchmark driver: one section per paper table/figure.

Run with ``PYTHONPATH=src python -m benchmarks.run [--only <name>]``.
"""

from __future__ import annotations

import argparse
import time
import traceback

from . import (bench_algorithm_selection, bench_blocksize,
               bench_cache_effects, bench_contractions,
               bench_model_accuracy, bench_prediction_accuracy,
               bench_roofline, bench_tile_tuner)

SUITES = {
    "model_accuracy": (bench_model_accuracy,
                       "paper §3.3 / Fig 3.13: model accuracy vs cost"),
    "cache_effects": (bench_cache_effects,
                      "paper §2.1.4 / Ch 5: warm-vs-cold kernel timings"),
    "prediction_accuracy": (bench_prediction_accuracy,
                            "paper Tab 4.3: blocked-algorithm prediction"),
    "algorithm_selection": (bench_algorithm_selection,
                            "paper §4.5: variant ranking + speedup"),
    "blocksize": (bench_blocksize,
                  "paper §4.6: block-size optimization yield"),
    "contractions": (bench_contractions,
                     "paper Ch 6: contraction micro-benchmark prediction"),
    "tile_tuner": (bench_tile_tuner,
                   "beyond-paper: Pallas BlockSpec tile selection"),
    "roofline": (bench_roofline,
                 "deliverable (g): per-cell roofline table"),
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    args = ap.parse_args()
    failures = 0
    for name, (mod, desc) in SUITES.items():
        if args.only and name != args.only:
            continue
        print(f"\n===== {name}: {desc} =====", flush=True)
        t0 = time.perf_counter()
        try:
            report = []
            mod.run(report)
            print("\n".join(report))
        except Exception:
            failures += 1
            traceback.print_exc()
        print(f"[{name}: {time.perf_counter() - t0:.1f}s]", flush=True)
    if failures:
        raise SystemExit(f"{failures} benchmark suites failed")


if __name__ == "__main__":
    main()
