"""Benchmark driver: one section per paper table/figure.

Run with ``PYTHONPATH=src python -m benchmarks.run [--only <name>]``.

``--smoke`` runs the CI fast lane and writes a ``BENCH_smoke.json``
artifact so CI can track the prediction-path performance trajectory per
PR.  Its ``batched_sweep`` probe is measurement-free (tiny sizes, 1
repetition, synthetic models); the ``contractions`` probe necessarily
runs real (but tiny, deduplicated) kernel micro-benchmarks plus one
pinned contraction execution, so its ``tc_rank64_*`` timings carry
shared-runner noise — the cross-commit comparison only warns, never
fails.
"""

from __future__ import annotations

import argparse
import json
import platform
import time
import traceback

from . import (bench_algorithm_selection, bench_batched_sweep,
               bench_blocksize, bench_cache_effects, bench_contractions,
               bench_einsum_paths, bench_model_accuracy, bench_model_store,
               bench_prediction_accuracy, bench_roofline, bench_serving,
               bench_tile_tuner, common)

SUITES = {
    "model_accuracy": (bench_model_accuracy,
                       "paper §3.3 / Fig 3.13: model accuracy vs cost"),
    "cache_effects": (bench_cache_effects,
                      "paper §2.1.4 / Ch 5: warm-vs-cold kernel timings"),
    "prediction_accuracy": (bench_prediction_accuracy,
                            "paper Tab 4.3: blocked-algorithm prediction"),
    "algorithm_selection": (bench_algorithm_selection,
                            "paper §4.5: variant ranking + speedup"),
    "blocksize": (bench_blocksize,
                  "paper §4.6: block-size optimization yield"),
    "batched_sweep": (bench_batched_sweep,
                      "beyond-paper: batched engine vs scalar prediction"),
    "contractions": (bench_contractions,
                     "paper Ch 6: contraction micro-benchmark prediction"),
    "einsum_paths": (bench_einsum_paths,
                     "beyond-paper: einsum-path (chain) prediction"),
    "serving": (bench_serving,
                "beyond-paper: model-guided serving vs FIFO baseline"),
    "model_store": (bench_model_store,
                    "beyond-paper: store warm start, drift, tournament"),
    "tile_tuner": (bench_tile_tuner,
                   "beyond-paper: Pallas BlockSpec tile selection"),
    "roofline": (bench_roofline,
                 "deliverable (g): per-cell roofline table"),
}

#: the CI smoke lane: the measurement-free prediction-path probe, the
#: (cheap, deduplicated) contraction probes with their tc_rank64_* and
#: tc_chain_* metrics, the model-guided-serving probe (serve_*), the
#: model-store warm-start/tournament probe (store_*/tournament_*), and
#: the measured tile-selection economics probe (tile_*)
SMOKE_SUITES = ("batched_sweep", "contractions", "einsum_paths", "serving",
                "model_store", "tile_tuner")


def _run_suite(name: str, mod, desc: str, smoke: bool) -> dict:
    print(f"\n===== {name}: {desc} =====", flush=True)
    t0 = time.perf_counter()
    report: list = []
    metrics: dict = {}
    ok = True
    try:
        if smoke and name in SMOKE_SUITES:
            mod.run(report, results=metrics)
        else:
            mod.run(report)
        print("\n".join(report))
    except Exception:
        ok = False
        tb = traceback.format_exc()
        print(tb, flush=True)
    seconds = time.perf_counter() - t0
    print(f"[{name}: {seconds:.1f}s]", flush=True)
    result = {"ok": ok, "seconds": seconds, "report": report,
              "metrics": metrics}
    if not ok:
        result["traceback"] = tb   # carried into the CI smoke artifact
    return result


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    ap.add_argument("--suites", default=None,
                    help="comma-separated suite filter (a multi-suite "
                         "--only); combines with --smoke, so the fast lane "
                         "can time each smoke suite independently")
    ap.add_argument("--smoke", action="store_true",
                    help="the CI fast lane: tiny sizes, synthetic models "
                         "(batched_sweep) + deduplicated real contraction "
                         "micro-benchmarks (contractions); writes the "
                         "BENCH_smoke.json artifact")
    ap.add_argument("--out", default="BENCH_smoke.json",
                    help="smoke-artifact path (with --smoke)")
    args = ap.parse_args()
    if args.smoke:
        common.set_smoke(True)
    if args.only and args.suites:
        raise SystemExit("pass --only or --suites, not both")
    selected = None
    if args.only:
        selected = [args.only]
    elif args.suites:
        selected = [s.strip() for s in args.suites.split(",") if s.strip()]
    unknown = [s for s in selected or [] if s not in SUITES]
    if unknown:
        raise SystemExit(f"unknown suite(s) {', '.join(unknown)}; "
                         f"choose from: {', '.join(SUITES)}")
    names = [n for n in SUITES
             if (selected is None or n in selected)
             and (not args.smoke or n in SMOKE_SUITES)]
    if not names:
        raise SystemExit(f"no suites selected ({selected!r} is not in the "
                         f"smoke lane: {', '.join(SMOKE_SUITES)})")
    results = {name: _run_suite(name, *SUITES[name], smoke=args.smoke)
               for name in names}
    failures = sum(not r["ok"] for r in results.values())
    if args.smoke:
        artifact = {
            "mode": "smoke",
            "timestamp": time.strftime("%Y-%m-%dT%H:%M:%SZ", time.gmtime()),
            "python": platform.python_version(),
            "machine": platform.machine(),
            "suites": results,
        }
        with open(args.out, "w") as f:
            json.dump(artifact, f, indent=2)
        print(f"\nwrote {args.out}")
    if failures:
        raise SystemExit(f"{failures} benchmark suites failed")


if __name__ == "__main__":
    main()
