"""Compare two ``BENCH_smoke.json`` artifacts and warn on perf regressions.

Usage::

    python -m benchmarks.compare_smoke PREVIOUS.json CURRENT.json \
        [--threshold 1.5]

CI downloads the previous run's smoke artifact and calls this after the
current one is written.  A tracked metric that grew by more than
``threshold`` x emits a GitHub Actions ``::warning::`` annotation (the job
still passes — smoke timings on shared runners are noisy, so regressions
are flagged for a human, not hard-failed).  Unreadable artifacts are also
only warned about.

Besides cross-commit trends, the CURRENT artifact alone is checked for
backend inversions: the fused jax path must not be slower than its numpy
counterpart (the original motivation for fusing the engine into one XLA
program), so every ``BACKEND_RATIOS`` pair warns when jax > numpy —
also when no previous artifact exists (pass ``-`` as PREVIOUS).

One check DOES fail the job: a metric the current artifact carries that
neither :data:`METRICS` nor :data:`UNTRACKED` lists.  A number computed
on every PR but watched by nobody is a blind spot; adding the metric to
a table (trended, or waived with a reason) is a one-line fix.  The
``reprolint`` metric-tracking checker enforces the same invariant
statically at the bench call sites — both read the literal tables below,
so source and CI can never disagree on what is tracked.
"""

from __future__ import annotations

import argparse
import json

#: (suite, metric, higher_better) triples trended across commits — the
#: declarative heart of the smoke lane.  ``higher_better`` inverts the
#: comparison ratio (a drop below 1/threshold warns).  reprolint's
#: metric-tracking checker parses this literal, so keep it constants-only.
METRICS = (
    ("batched_sweep", "sweep64_jax_cached_s", False),
    ("batched_sweep", "sweep64_numpy_s", False),
    ("batched_sweep", "sweep64_numpy_cached_s", False),
    ("batched_sweep", "sweep_batched_s", False),
    ("batched_sweep", "grid_s", False),
    ("contractions", "tc_rank64_suite_s", False),
    ("contractions", "tc_rank64_rank_numpy_s", False),
    ("contractions", "tc_rank64_rank_jax_s", False),
    ("contractions", "tc_sweep_suite_s", False),
    ("contractions", "tc_sweep_rank_jax_s", False),
    ("contractions", "tc_param_refine_suite_s", False),
    ("einsum_paths", "tc_chain_suite_s", False),
    ("einsum_paths", "tc_chain_rank_numpy_s", False),
    ("einsum_paths", "tc_chain_rank_jax_s", False),
    ("serving", "serve_p99_ms", False),
    ("serving", "serve_tick_overhead_ms", False),
    ("serving", "serve_goodput_tok_s", True),
    ("model_store", "store_warmstart_ms", False),
    ("model_store", "tournament_rank_agreement", True),
    ("tile_tuner", "tile_sweep_s", False),
    ("tile_tuner", "tile_warm_rank_ms", False),
)

#: (suite, metric) pairs a smoke bench emits that CI deliberately does
#: NOT trend — each group states why.  An emitted metric in neither
#: table fails the lane (see :func:`check_tracking`).
UNTRACKED = (
    # problem-shape descriptors: constants unless the bench is edited
    ("batched_sweep", "n"),
    ("batched_sweep", "grid_size"),
    ("batched_sweep", "grid_configs"),
    ("contractions", "tc_rank64_algorithms"),
    ("contractions", "tc_rank64_batched_algorithms"),
    ("contractions", "tc_rank64_benchmarks"),
    ("contractions", "tc_sweep_points"),
    ("contractions", "tc_sweep_benchmarks"),
    ("contractions", "tc_sweep_new_benchmarks"),
    ("contractions", "tc_param_signatures"),
    ("contractions", "tc_param_refine_measured"),
    ("contractions", "tc_param_predicted"),
    ("einsum_paths", "tc_chain_paths"),
    ("einsum_paths", "tc_chain_steps"),
    ("einsum_paths", "tc_chain_benchmarks"),
    ("einsum_paths", "tc_sweep_chain_points"),
    ("einsum_paths", "tc_sweep_chain_new_benchmarks"),
    # correctness booleans: the tier-1 tests already hard-pin these;
    # trending a 0/1 across commits adds nothing
    ("batched_sweep", "argmin_agree"),
    ("batched_sweep", "rank_order_agree"),
    ("batched_sweep", "sweep64_jax_beats_numpy"),
    ("contractions", "tc_rank64_backend_agree"),
    ("contractions", "tc_rank64_oracle_agree"),
    ("contractions", "tc_param_top1_agree"),
    ("einsum_paths", "tc_chain_backend_agree"),
    ("einsum_paths", "tc_chain_oracle_agree"),
    # numerical-agreement magnitudes: bounded by in-bench assertions
    ("batched_sweep", "max_rel_diff"),
    ("batched_sweep", "max_rel_backend_diff"),
    ("batched_sweep", "max_rel_fused_diff"),
    # scalar-path / one-shot reference timings and derived speedups: the
    # slow side of a ratio whose fast side is already trended above
    ("batched_sweep", "sweep_scalar_s"),
    ("batched_sweep", "sweep_speedup"),
    ("batched_sweep", "rank_scalar_s"),
    ("batched_sweep", "rank_batched_s"),
    ("batched_sweep", "sweep64_jax_grouped_s"),
    ("batched_sweep", "sweep64_fused_speedup"),
    ("batched_sweep", "sweep64_speedup"),
    # single-execution denominators and their cost fractions: one real
    # kernel execution each — too noisy on shared runners to trend
    ("contractions", "tc_rank64_exec_s"),
    ("contractions", "tc_rank64_cost_frac"),
    ("contractions", "tc_sweep_cost_frac"),
    ("contractions", "tc_param_cost_frac"),
    # holdout prediction error vs ONE fresh oracle measurement per
    # candidate — real-timing noise dominates; the deterministic band
    # is pinned in tests/test_parametric.py instead
    ("contractions", "tc_param_holdout_relerr"),
    ("einsum_paths", "tc_chain_exec_s"),
    ("einsum_paths", "tc_chain_cost_frac"),
    ("einsum_paths", "tc_sweep_chain_suite_s"),
    ("einsum_paths", "tc_sweep_chain_cost_frac"),
    # serving: FIFO-baseline percentiles and ratios — the guided/FIFO
    # comparison is already enforced by SERVING_RATIOS
    ("serving", "serve_model_build_s"),
    ("serving", "serve_p50_ms"),
    ("serving", "serve_fifo_p50_ms"),
    ("serving", "serve_fifo_p99_ms"),
    ("serving", "serve_goodput_ratio"),
    ("serving", "serve_p99_ratio"),
    # model store: shape descriptors and invariants the bench itself (or
    # tier-1 tests) already pin hard — zero new measurements and
    # bit-identical rankings fail the bench, not a trend line
    ("model_store", "store_keys"),
    ("model_store", "store_bytes"),
    ("model_store", "store_measure_s"),
    ("model_store", "store_save_ms"),
    ("model_store", "store_new_measurements"),
    ("model_store", "store_roundtrip_identical"),
    ("model_store", "store_drift_probed"),
    # drift ratio and prev-run fingerprint hit: shared-runner facts
    # (thermal wobble, runner-image rotation), informative but untrendable
    ("model_store", "store_drift_max_ratio"),
    ("model_store", "store_prev_hit"),
    # tournament: snapshot count is a constant; the winner's secondary
    # scores back up the trended rank_agreement headline
    ("model_store", "tournament_snapshots"),
    ("model_store", "tournament_top1_rate"),
    ("model_store", "tournament_rel_err"),
    ("model_store", "tournament_oracle_cost_s"),
    # tile tuner: table descriptors are constants; the exhaustive
    # execution denominator and its cost fraction carry one real kernel
    # execution per (shape, candidate) — too noisy to trend, and the
    # bench hard-asserts the fraction < 0.25 in place
    ("tile_tuner", "tile_shapes"),
    ("tile_tuner", "tile_configs"),
    ("tile_tuner", "tile_exec_s"),
    ("tile_tuner", "tile_sweep_cost_frac"),
    # measured-vs-analytic top-1 and the transfer shares are platform
    # facts (interpret mode inflates per-step proxy cost; transfer
    # bandwidths are the runner's); tier-1 tests pin the invariants
    ("tile_tuner", "tile_top1_agree"),
    ("tile_tuner", "tile_h2d_share"),
    ("tile_tuner", "tile_d2h_share"),
    # warm-store contract metrics: zero new measurements and identical
    # totals fail the bench itself, not a trend line
    ("tile_tuner", "tile_warm_new_measurements"),
    ("tile_tuner", "tile_warm_identical"),
)

#: derived views used by the comparison code below (and by older callers)
TRACKED = tuple((s, m) for s, m, _ in METRICS)
HIGHER_BETTER = frozenset((s, m) for s, m, hb in METRICS if hb)

#: (suite, guided metric, baseline metric) pairs checked WITHIN one
#: artifact: the model-guided scheduler falling below its FIFO baseline
#: means the predictions stopped paying for themselves
SERVING_RATIOS = (
    ("serving", "serve_goodput_tok_s", "serve_fifo_goodput_tok_s"),
)

#: (suite, jax metric, numpy metric) pairs checked WITHIN one artifact:
#: a jax path slower than its numpy counterpart is a regression of the
#: fused engine and warns on every PR
BACKEND_RATIOS = (
    ("batched_sweep", "sweep64_jax_cached_s", "sweep64_numpy_cached_s"),
    ("contractions", "tc_rank64_rank_jax_s", "tc_rank64_rank_numpy_s"),
    ("contractions", "tc_sweep_rank_jax_s", "tc_sweep_rank_numpy_s"),
    ("einsum_paths", "tc_chain_rank_jax_s", "tc_chain_rank_numpy_s"),
)


def _metric(artifact: dict, suite: str, name: str):
    value = artifact.get("suites", {}).get(suite, {}).get("metrics",
                                                          {}).get(name)
    return float(value) if isinstance(value, (int, float)) else None


def compare(prev: dict, curr: dict, threshold: float) -> int:
    """Print a comparison table; return the number of flagged regressions."""
    flagged = 0
    for suite, name in TRACKED:
        old, new = _metric(prev, suite, name), _metric(curr, suite, name)
        if old is None or new is None or old <= 0:
            print(f"  {suite}.{name}: not comparable "
                  f"(old={old!r} new={new!r})")
            continue
        # higher-is-better metrics regress when they SHRINK: invert the
        # ratio so one threshold covers both directions
        ratio = old / new if (suite, name) in HIGHER_BETTER and new > 0 \
            else new / old
        line = f"  {suite}.{name}: {old:.4g} -> {new:.4g} ({ratio:.2f}x)"
        if ratio > threshold:
            flagged += 1
            direction = "dropped" if (suite, name) in HIGHER_BETTER \
                else "slowed"
            print(f"::warning title=smoke perf regression::{suite}.{name} "
                  f"{direction} {ratio:.2f}x ({old:.4g} -> {new:.4g}, "
                  f"threshold {threshold}x)")
        print(line)
    return flagged


def check_backend_ratios(curr: dict) -> int:
    """Warn on jax-slower-than-numpy inversions in ONE artifact."""
    flagged = 0
    for suite, jax_name, numpy_name in BACKEND_RATIOS:
        t_jax = _metric(curr, suite, jax_name)
        t_np = _metric(curr, suite, numpy_name)
        if t_jax is None or t_np is None or t_np <= 0:
            print(f"  {suite}.{jax_name} vs {numpy_name}: not comparable "
                  f"(jax={t_jax!r} numpy={t_np!r})")
            continue
        ratio = t_jax / t_np
        if ratio > 1.0:
            flagged += 1
            print(f"::warning title=jax backend slower than numpy::"
                  f"{suite}.{jax_name} = {t_jax * 1e3:.2f}ms > "
                  f"{suite}.{numpy_name} = {t_np * 1e3:.2f}ms "
                  f"({ratio:.2f}x) — the fused jax path should win")
        print(f"  {suite}.{jax_name}: {t_jax * 1e3:.2f}ms vs "
              f"{numpy_name}: {t_np * 1e3:.2f}ms ({ratio:.2f}x)")
    return flagged


def check_serving_ratios(curr: dict) -> int:
    """Warn when model-guided serving loses to its FIFO baseline."""
    flagged = 0
    for suite, guided_name, fifo_name in SERVING_RATIOS:
        guided = _metric(curr, suite, guided_name)
        fifo = _metric(curr, suite, fifo_name)
        if guided is None or fifo is None or fifo <= 0:
            print(f"  {suite}.{guided_name} vs {fifo_name}: not comparable "
                  f"(guided={guided!r} fifo={fifo!r})")
            continue
        ratio = guided / fifo
        if ratio < 1.0:
            flagged += 1
            print(f"::warning title=model-guided serving below FIFO::"
                  f"{suite}.{guided_name} = {guided:.4g} < "
                  f"{suite}.{fifo_name} = {fifo:.4g} ({ratio:.2f}x) — "
                  f"the step-cost predictions stopped paying for "
                  f"themselves")
        print(f"  {suite}.{guided_name}: {guided:.4g} vs "
              f"{fifo_name}: {fifo:.4g} ({ratio:.2f}x)")
    return flagged


def check_tracking(curr: dict) -> int:
    """HARD check: every metric in the artifact is in METRICS/UNTRACKED.

    Returns the number of unknown metrics (the only condition that fails
    the smoke lane — unlike timings it is deterministic, and the fix is
    a one-line table entry here).  Ratio-table metric names also count
    as known: they are consumed within one artifact, not trended.
    """
    known = set(TRACKED) | set(UNTRACKED)
    for suite, a, b in BACKEND_RATIOS + SERVING_RATIOS:
        known.update({(suite, a), (suite, b)})
    unknown = 0
    for suite, payload in curr.get("suites", {}).items():
        for name in payload.get("metrics", {}):
            if (suite, name) not in known:
                unknown += 1
                print(f"::error title=untracked smoke metric::"
                      f"{suite}.{name} is emitted but appears in neither "
                      f"METRICS nor UNTRACKED in benchmarks/"
                      f"compare_smoke.py — add it (trended, or waived "
                      f"with a reason)")
    return unknown


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("previous",
                    help="previous BENCH_smoke.json ('-' when none exists: "
                         "only the current artifact's backend ratios are "
                         "checked)")
    ap.add_argument("current", help="current BENCH_smoke.json")
    ap.add_argument("--threshold", type=float, default=1.5,
                    help="warn when metric grows by more than this factor")
    args = ap.parse_args()
    try:
        prev = None
        if args.previous != "-":
            with open(args.previous) as f:
                prev = json.load(f)
        with open(args.current) as f:
            curr = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        # warn-only contract: a truncated/missing artifact (e.g. a previous
        # run killed mid-write) must not fail the lane for unrelated commits
        print(f"::warning title=smoke comparison skipped::"
              f"cannot read artifacts: {e}")
        return
    flagged = 0
    if prev is not None:
        print(f"smoke comparison (warn beyond {args.threshold}x):")
        flagged += compare(prev, curr, args.threshold)
    else:
        print("no previous artifact; cross-commit comparison skipped")
    print("backend ratios (jax must not be slower than numpy):")
    flagged += check_backend_ratios(curr)
    print("serving ratios (model-guided must not lose to FIFO):")
    flagged += check_serving_ratios(curr)
    print(f"{flagged} regression(s) flagged" if flagged
          else "no regressions flagged")
    unknown = check_tracking(curr)
    if unknown:
        raise SystemExit(f"{unknown} untracked smoke metric(s)")


if __name__ == "__main__":
    main()
