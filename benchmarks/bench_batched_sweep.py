"""Benchmark: batched prediction engine vs scalar per-call prediction.

The paper's promise is *instantaneous* model-based selection (§4.5/§4.6).
This suite times a block-size sweep and a multi-variant ranking on the scalar
per-call reference path vs the vectorized :class:`PredictionEngine`, checks
that both select the same configuration with statistics agreeing to ~1e-10,
and reports the sweep speedup.  It also pits the engine's backends against
each other on a fixed 64-candidate sweep: the plain NumPy batched path
(re-traced every call, as in PR 1), the numpy + trace-cached path, the
pre-fusion per-group jax path (one jitted program per (kernel, case)
group plus host-side bincounts) and the fused path (``backend="jax"``
with the whole compiled batch as ONE jitted dispatch) — the
``sweep64_*`` metrics CI tracks across commits, including the
fused-vs-grouped speedup and the jax-vs-numpy backend ratio.  The models are analytic
(measurement-free, ``common.synthetic_model_set``), so the suite runs
identically on any machine — it is also the CI smoke lane's
perf-trajectory probe.
"""

from __future__ import annotations

import time
from typing import Dict, List, Optional

import numpy as np

from repro.core import (PredictionEngine, optimize_block_size,
                        rank_algorithms)
from repro.core.sampler import STATS
from repro.dla.tracers import CHOLESKY_TRACERS, TRTRI_TRACERS, potrf_tracer

from .common import best_of as _best_of
from .common import is_smoke, synthetic_model_set


def run(report: List[str],
        results: Optional[Dict[str, object]] = None) -> None:
    smoke = is_smoke()
    n = 256 if smoke else 768
    n_cand = 16 if smoke else 64
    reps = 1 if smoke else 3
    candidates = [8 * (i + 1) for i in range(n_cand)]
    ms = synthetic_model_set()
    tracer = potrf_tracer(3)

    # ---- block-size sweep: scalar loop vs batched engine ----
    b_scalar, prof_scalar = optimize_block_size(tracer, ms, n, candidates,
                                                batched=False)
    b_batched, prof_batched = optimize_block_size(tracer, ms, n, candidates)
    max_rel = max(abs(prof_batched[b] - prof_scalar[b]) /
                  max(prof_scalar[b], 1e-300) for b in candidates)
    t_scalar = _best_of(lambda: optimize_block_size(
        tracer, ms, n, candidates, batched=False), reps)
    t_batched = _best_of(lambda: optimize_block_size(
        tracer, ms, n, candidates), reps)
    speedup = t_scalar / t_batched
    report.append(
        f"blocksize sweep n={n} |grid|={n_cand}: "
        f"scalar={t_scalar * 1e3:8.1f}ms batched={t_batched * 1e3:6.1f}ms "
        f"speedup={speedup:6.1f}x argmin {'==' if b_scalar == b_batched else '!='} "
        f"(b={b_batched}) max_rel_diff={max_rel:.1e}")

    # ---- multi-variant ranking (11 algorithms in one compiled batch) ----
    tracers = {**CHOLESKY_TRACERS, **TRTRI_TRACERS}
    b_rank = candidates[len(candidates) // 2]
    ranked_scalar = rank_algorithms(tracers, ms, n, b_rank, batched=False)
    t_rank_scalar = _best_of(lambda: rank_algorithms(
        tracers, ms, n, b_rank, batched=False), reps)
    ranked_batched = rank_algorithms(tracers, ms, n, b_rank)
    t_rank_batched = _best_of(lambda: rank_algorithms(
        tracers, ms, n, b_rank), reps)
    # variants with numerically-tied predictions may swap under the two
    # paths' different summation orders; only a >1e-9 inversion is a mismatch
    order_agree = all(
        s.name == b.name
        or abs(s.runtime.med - b.runtime.med)
        <= 1e-9 * max(abs(s.runtime.med), 1e-300)
        for s, b in zip(ranked_scalar, ranked_batched))
    report.append(
        f"ranking {len(tracers)} variants n={n} b={b_rank}: "
        f"scalar={t_rank_scalar * 1e3:8.1f}ms "
        f"batched={t_rank_batched * 1e3:6.1f}ms "
        f"speedup={t_rank_scalar / t_rank_batched:6.1f}x "
        f"order {'==' if order_agree else '!='} winner={ranked_batched[0].name}")

    # ---- backends on the fixed 64-candidate sweep (the CI metric) ----
    # the PR-1 baseline: numpy batched, re-tracing the candidates per call
    cand64 = [8 * (i + 1) for i in range(64)]
    t_np64 = _best_of(lambda: PredictionEngine(ms).sweep(
        tracer, n, cand64), max(reps, 15))
    # fused + trace-cached: candidate set compiled once, the WHOLE batch
    # (piece lookup, matmuls and the config scatter-add) one jitted
    # XLA dispatch per sweep
    eng_jax = PredictionEngine(ms, backend="jax")
    sweep_jax = eng_jax.sweep(tracer, n, cand64)        # jit + trace warmup
    t_jax64 = _best_of(lambda: eng_jax.sweep(tracer, n, cand64),
                       max(reps, 15))
    # the pre-fusion reference: one jitted program per (kernel, case)
    # group plus host-side bincounts — what the fused path must beat >=2x
    compiled64 = eng_jax.compile_sweep(tracer, n, cand64)
    sweep_jax_grouped = eng_jax.predict_compiled_grouped(compiled64)
    t_jax64_grouped = _best_of(
        lambda: eng_jax.predict_compiled_grouped(compiled64), max(reps, 15))
    # numpy + trace-cached isolates the cache's share of the win
    eng_np = PredictionEngine(ms)
    sweep_np = eng_np.sweep(tracer, n, cand64)
    t_npc64 = _best_of(lambda: eng_np.sweep(tracer, n, cand64),
                       max(reps, 15))
    max_rel_backend = float(np.max(
        np.abs(sweep_jax - sweep_np) / np.maximum(np.abs(sweep_np), 1e-300)))
    max_rel_fused = float(np.max(
        np.abs(sweep_jax - sweep_jax_grouped) /
        np.maximum(np.abs(sweep_jax_grouped), 1e-300)))
    report.append(
        f"64-candidate sweep n={n}: numpy={t_np64 * 1e3:6.2f}ms "
        f"numpy+cache={t_npc64 * 1e3:6.2f}ms "
        f"jax grouped={t_jax64_grouped * 1e3:6.2f}ms "
        f"jax fused={t_jax64 * 1e3:6.2f}ms "
        f"fused_speedup={t_jax64_grouped / t_jax64:4.1f}x "
        f"jax{'<' if t_jax64 < t_npc64 else '>='}numpy "
        f"max_rel_backend_diff={max_rel_backend:.1e} "
        f"max_rel_fused_diff={max_rel_fused:.1e}")

    # ---- full (n, b) grid in one shot ----
    engine = PredictionEngine(ms)
    ns = [128, 192, 256] if smoke else [256, 512, 768, 1024]
    t0 = time.perf_counter()
    grid = engine.grid(tracer, ns, candidates)
    t_grid = time.perf_counter() - t0
    med = grid[..., STATS.index("med")]
    report.append(
        f"(n, b) grid {len(ns)}x{n_cand} = {len(ns) * n_cand} configs: "
        f"{t_grid * 1e3:6.1f}ms "
        f"({t_grid / (len(ns) * n_cand) * 1e6:6.1f}us/config), "
        f"argmin_b per n: "
        + " ".join(f"n={nn}:b={candidates[int(i)]}"
                   for nn, i in zip(ns, med.argmin(axis=1))))

    if results is not None:
        results.update({
            "n": n, "grid_size": n_cand,
            "sweep_scalar_s": t_scalar, "sweep_batched_s": t_batched,
            "sweep_speedup": speedup,
            "argmin_agree": bool(b_scalar == b_batched),
            "max_rel_diff": float(max_rel),
            "rank_scalar_s": t_rank_scalar,
            "rank_batched_s": t_rank_batched,
            "rank_order_agree": bool(order_agree),
            "sweep64_numpy_s": t_np64,
            "sweep64_numpy_cached_s": t_npc64,
            "sweep64_jax_cached_s": t_jax64,
            "sweep64_jax_grouped_s": t_jax64_grouped,
            "sweep64_fused_speedup": t_jax64_grouped / t_jax64,
            "sweep64_jax_beats_numpy": bool(t_jax64 < t_npc64),
            "sweep64_speedup": t_np64 / t_jax64,
            "max_rel_backend_diff": max_rel_backend,
            "max_rel_fused_diff": max_rel_fused,
            "grid_configs": len(ns) * n_cand, "grid_s": t_grid,
        })


def main() -> None:
    report: List[str] = []
    run(report)
    print("\n".join(report))


if __name__ == "__main__":
    main()
