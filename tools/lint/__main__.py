"""CLI for reprolint: ``python -m tools.lint [paths...]``.

Exit code 1 when any active (non-baselined, non-suppressed) finding
remains — CI runs this as a hard gate before the test lane.  ``--format
github`` renders findings as ``::error`` workflow annotations so they
land on the PR diff; ``--write-baseline`` grandfathers the current
finding set into ``tools/lint/baseline.json``.
"""

from __future__ import annotations

import argparse
import json
import sys
import time
from pathlib import Path

from . import REGISTRY, load_baseline, run_lint, write_baseline
from .core import BASELINE_PATH, ROOT


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m tools.lint",
        description="AST-based invariant checker for the prediction stack")
    ap.add_argument("paths", nargs="*",
                    help="files/dirs to lint (default: src, benchmarks, "
                         "examples + docs snippets)")
    ap.add_argument("--format", choices=("text", "json", "github"),
                    default="text")
    ap.add_argument("--select", default=None,
                    help="comma-separated checker ids to run "
                         "(default: all)")
    ap.add_argument("--baseline", default=str(BASELINE_PATH),
                    help="baseline file of grandfathered findings "
                         "('-' to ignore)")
    ap.add_argument("--write-baseline", action="store_true",
                    help="rewrite the baseline with the current active "
                         "findings and exit 0")
    ap.add_argument("--list-checkers", action="store_true")
    args = ap.parse_args(argv)

    if args.list_checkers:
        for cid in sorted(REGISTRY):
            print(f"{cid:18s} {REGISTRY[cid].description}")
        return 0

    checkers = None
    if args.select:
        checkers = [c.strip() for c in args.select.split(",") if c.strip()]
        unknown = [c for c in checkers if c not in REGISTRY]
        if unknown:
            ap.error(f"unknown checker(s): {', '.join(unknown)} "
                     f"(have: {', '.join(sorted(REGISTRY))})")

    missing = [p for p in args.paths if not Path(p).exists()]
    if missing:
        ap.error(f"no such file or directory: {', '.join(missing)}")

    baseline_path = None if args.baseline == "-" else Path(args.baseline)
    t0 = time.perf_counter()
    result = run_lint(
        ROOT,
        paths=[Path(p) for p in args.paths] or None,
        checkers=checkers,
        baseline=load_baseline(baseline_path) if baseline_path else None,
    )
    elapsed = time.perf_counter() - t0

    if args.write_baseline:
        path = baseline_path or BASELINE_PATH
        write_baseline(result.findings, path)
        print(f"wrote {len(result.findings)} finding(s) to {path}")
        return 0

    if args.format == "json":
        print(json.dumps({
            "findings": [f.__dict__ for f in result.findings],
            "baselined": [f.__dict__ for f in result.baselined],
            "suppressed": result.suppressed,
            "files": result.files,
            "seconds": round(elapsed, 3),
        }, indent=2))
    else:
        for f in result.findings:
            print(f.render_github() if args.format == "github"
                  else f.render())
        status = "ok" if result.ok else \
            f"{len(result.findings)} finding(s)"
        print(f"reprolint: {status} ({result.files} files, "
              f"{result.suppressed} pragma-suppressed, "
              f"{len(result.baselined)} baselined, {elapsed:.2f}s)",
              file=sys.stderr)
    return 0 if result.ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
