"""reprolint — AST-based invariant checks for the prediction stack.

The ROADMAP carries a set of cross-cutting constraints in prose: hot
paths stay fused and dispatch-free (no stray host syncs), jit call sites
declare their Python-config parameters static (no silent retraces), every
ranking entry point goes through :class:`repro.tc.PredictorSession`
(no resurrected per-call kwargs), every prediction fast path is pinned to
its equivalence oracle by a test, and every smoke metric the benchmarks
emit is either tracked across commits or explicitly allowlisted.  Each of
those used to be a reviewer checklist item; ``reprolint`` makes them a
CI gate checked once per commit.

Usage::

    python -m tools.lint [paths...] [--format text|json|github]
    python -m tools.lint --write-baseline   # grandfather current findings

Architecture: :mod:`tools.lint.core` holds the finding model, the
``# reprolint: allow[checker-id]`` pragma machinery, the committed
baseline, and the runner; each module under :mod:`tools.lint.checkers`
registers one :class:`~tools.lint.core.Checker` (per-file AST visitors,
or repo-level cross-reference checks).  ``docs/static-analysis.md``
documents every checker and the invariant it encodes.
"""

from .core import (Checker, FileContext, Finding, LintResult, REGISTRY,
                   load_baseline, run_lint, write_baseline)

# importing the subpackage registers every checker with the REGISTRY
from . import checkers  # noqa: F401  (import for side effect)

__all__ = [
    "Checker", "FileContext", "Finding", "LintResult", "REGISTRY",
    "load_baseline", "run_lint", "write_baseline",
]
