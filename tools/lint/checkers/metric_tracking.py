"""metric-tracking: every smoke metric is tracked or explicitly waived.

The CI fast lane's whole value is its trend line: ``benchmarks/run.py
--smoke`` writes ``BENCH_smoke.json`` and ``benchmarks/compare_smoke.py``
compares it against the previous commit's artifact.  A metric a bench
emits but the comparison tables don't know about is a silent blind spot
— the number is computed every PR and watched by nobody.  This checker
closes the loop statically (pure AST, no jax import, so it fits the
<10s lint budget):

* parse ``benchmarks/run.py`` for ``SMOKE_SUITES`` and the ``SUITES``
  module mapping;
* extract every metric key each smoke bench writes (literal
  ``results.update({...})`` dicts and ``results["key"] = ...``
  assignments — non-literal keys are themselves flagged, since a key
  the linter cannot read is a key the tables cannot list);
* parse ``benchmarks/compare_smoke.py`` for the declarative ``METRICS``
  / ``UNTRACKED`` tables (plus the ``BACKEND_RATIOS`` /
  ``SERVING_RATIOS`` metric references, which count as known);
* flag emitted-but-unknown keys at their emit site, table entries no
  bench emits anymore (stale rows), and unit-suffix aliases — timings
  are ``_s``/``_ms``, rates ``_tok_s``, ratios-of-totals ``_frac``.
"""

from __future__ import annotations

import ast
from pathlib import Path
from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple

from ..core import Checker, FileContext, Finding, register

RUN = "benchmarks/run.py"
COMPARE = "benchmarks/compare_smoke.py"

#: suffix alias -> the canonical unit suffix the repo's metrics use
UNIT_ALIASES = {
    "_sec": "_s", "_secs": "_s", "_seconds": "_s",
    "_msec": "_ms", "_msecs": "_ms", "_millis": "_ms",
    "_milliseconds": "_ms",
    "_toks_s": "_tok_s", "_tok_per_s": "_tok_s", "_tokens_per_s": "_tok_s",
    "_fraction": "_frac", "_pct": "_frac", "_percent": "_frac",
}


def _assigned_literal(tree: ast.AST, name: str) -> Optional[ast.expr]:
    """The value node of a module-level ``name = <literal>`` assignment."""
    for node in tree.body if isinstance(tree, ast.Module) else []:
        if isinstance(node, ast.Assign):
            targets = node.targets
        elif isinstance(node, ast.AnnAssign) and node.value is not None:
            targets = [node.target]
        else:
            continue
        for t in targets:
            if isinstance(t, ast.Name) and t.id == name:
                return node.value
    return None


def _str_elts(node: Optional[ast.expr]) -> List[str]:
    if not isinstance(node, (ast.Tuple, ast.List, ast.Set)):
        return []
    return [e.value for e in node.elts
            if isinstance(e, ast.Constant) and isinstance(e.value, str)]


def _tuple_rows(node: Optional[ast.expr]) -> List[Tuple]:
    """Rows of a literal tuple/list-of-tuples table (constants only)."""
    rows: List[Tuple] = []
    if not isinstance(node, (ast.Tuple, ast.List)):
        return rows
    for e in node.elts:
        if isinstance(e, (ast.Tuple, ast.List)) and all(
                isinstance(x, ast.Constant) for x in e.elts):
            rows.append(tuple(x.value for x in e.elts))
    return rows


def _smoke_modules(run_tree: ast.AST) -> Dict[str, str]:
    """smoke suite name -> bench module name, from run.py's literals."""
    smoke = set(_str_elts(_assigned_literal(run_tree, "SMOKE_SUITES")))
    suites = _assigned_literal(run_tree, "SUITES")
    out: Dict[str, str] = {}
    if not isinstance(suites, ast.Dict):
        return out
    for key, value in zip(suites.keys, suites.values):
        if not (isinstance(key, ast.Constant) and key.value in smoke):
            continue
        mod = value.elts[0] if isinstance(value, (ast.Tuple, ast.List)) \
            and value.elts else value
        if isinstance(mod, ast.Name):
            out[key.value] = mod.id
        elif isinstance(mod, ast.Attribute):
            out[key.value] = mod.attr
    return out


def _emitted_keys(tree: ast.AST) -> List[Tuple[str, int]]:
    """(metric key, line) for each literal write into ``results``."""
    out: List[Tuple[str, int]] = []
    for node in ast.walk(tree):
        if isinstance(node, ast.Call) and \
                isinstance(node.func, ast.Attribute) and \
                node.func.attr == "update" and \
                isinstance(node.func.value, ast.Name) and \
                node.func.value.id == "results":
            for arg in node.args:
                if isinstance(arg, ast.Dict):
                    for k in arg.keys:
                        if isinstance(k, ast.Constant) and \
                                isinstance(k.value, str):
                            out.append((k.value, k.lineno))
                        elif k is not None:
                            out.append(("", k.lineno))   # non-literal
        elif isinstance(node, ast.Assign):
            for t in node.targets:
                if isinstance(t, ast.Subscript) and \
                        isinstance(t.value, ast.Name) and \
                        t.value.id == "results":
                    s = t.slice
                    if isinstance(s, ast.Constant) and \
                            isinstance(s.value, str):
                        out.append((s.value, t.lineno))
                    else:
                        out.append(("", t.lineno))       # non-literal
    return out


@register
class MetricTrackingChecker(Checker):
    id = "metric-tracking"
    description = ("every metric a smoke bench emits appears in "
                   "compare_smoke's METRICS or UNTRACKED tables, with "
                   "canonical unit suffixes (_s/_ms/_tok_s/_frac)")

    def check_repo(self, ctxs: Sequence[FileContext],
                   root: Path) -> Iterable[Finding]:
        by_rel = {c.rel: c for c in ctxs}
        run_ctx, cmp_ctx = by_rel.get(RUN), by_rel.get(COMPARE)
        if run_ctx is None or cmp_ctx is None:
            return   # nothing to cross-reference (partial lint run)

        metrics_node = _assigned_literal(cmp_ctx.tree, "METRICS")
        known: Dict[str, Set[str]] = {}
        tracked_rows: List[Tuple[str, str]] = []
        if metrics_node is None:
            # pre-refactor layout: TRACKED pairs only
            for suite, metric in _tuple_rows(
                    _assigned_literal(cmp_ctx.tree, "TRACKED")):
                known.setdefault(suite, set()).add(metric)
                tracked_rows.append((suite, metric))
        else:
            for row in _tuple_rows(metrics_node):
                suite, metric = row[0], row[1]
                known.setdefault(suite, set()).add(metric)
                tracked_rows.append((suite, metric))
        for suite, metric in _tuple_rows(
                _assigned_literal(cmp_ctx.tree, "UNTRACKED")):
            known.setdefault(suite, set()).add(metric)
            tracked_rows.append((suite, metric))
        for table in ("BACKEND_RATIOS", "SERVING_RATIOS"):
            for row in _tuple_rows(_assigned_literal(cmp_ctx.tree, table)):
                suite = row[0]
                for metric in row[1:]:
                    known.setdefault(suite, set()).add(metric)

        emitted: Dict[str, Set[str]] = {}
        for suite, mod in sorted(_smoke_modules(run_ctx.tree).items()):
            ctx = by_rel.get(f"benchmarks/{mod}.py")
            if ctx is None:
                continue
            emitted.setdefault(suite, set())
            for key, line in _emitted_keys(ctx.tree):
                if not key:
                    yield Finding(
                        self.id, ctx.rel, line,
                        f"suite {suite} writes a non-literal metric key "
                        f"— compare_smoke's tables can only list literal "
                        f"keys, so this metric is untrackable")
                    continue
                emitted[suite].add(key)
                if key not in known.get(suite, set()):
                    yield Finding(
                        self.id, ctx.rel, line,
                        f"suite {suite} emits metric {key!r} that "
                        f"compare_smoke knows nothing about — add it to "
                        f"METRICS (to trend it) or UNTRACKED (to waive "
                        f"it, with a reason)")
                for alias, canon in UNIT_ALIASES.items():
                    if key.endswith(alias):
                        yield Finding(
                            self.id, ctx.rel, line,
                            f"metric {key!r} uses unit suffix "
                            f"'{alias}' — the repo's canonical suffix "
                            f"is '{canon}' (_s/_ms/_tok_s/_frac)")
                        break

        table_line = metrics_node.lineno if metrics_node is not None else 1
        for suite, metric in tracked_rows:
            if suite in emitted and metric not in emitted[suite]:
                yield Finding(
                    self.id, cmp_ctx.rel, table_line,
                    f"stale table row: suite {suite} no longer emits "
                    f"metric {metric!r} — drop the row or restore the "
                    f"metric")
