"""host-sync: no device synchronization inside dispatch-free hot paths.

The paper's promise — predictions "at merely a fraction of a
contraction's runtime" — survives only while the hot paths stay fused
and dispatch-free: PR 5 fused the whole compiled-batch evaluation into
ONE XLA program precisely to eliminate host round-trips, and PR 6's
scheduler tick budget (< 1 ms) assumes planning never blocks on the
device.  One stray ``block_until_ready`` (or a ``float()`` /
``np.asarray`` D2H pull) re-serializes the pipeline and the regression
is silent until a benchmark notices.

Flagged synchronization forms (syntactic — no type inference, so
legitimate sites carry a ``# reprolint: allow[host-sync]`` pragma with a
justification):

* ``jax.block_until_ready(x)`` / ``x.block_until_ready()``,
* ``x.item()``,
* ``np.asarray(x)`` / ``np.array(x)`` (device -> host transfer),
* ``float(x)`` on a non-literal (forces the value to the host).

Hot contexts:

* bodies of jit-decorated functions (and of functions/lambdas passed to
  ``jax.jit`` in the same module) — a sync here is either a trace-time
  error waiting to happen or a per-call dispatch break;
* the serve/engine tick and scheduler rollout loops, plus the §6.2
  measurement kernel, via the :data:`HOT_PATHS` table;
* any function whose ``def`` line carries ``# reprolint: hot-path``.

Nested functions inherit their enclosing hot context (the §6.2 timed
``call()`` closure is exactly such a nest).
"""

from __future__ import annotations

import ast
from typing import Iterable, List, Mapping, Optional, Set, Tuple

from ..core import Checker, FileContext, Finding, register
from ._jit import collect_jit_sites, is_jit_decorated

#: path -> qualnames that are hot by construction: the per-tick serve
#: loop + scheduler rollout (PR 6's < 1 ms budget), the fused engine's
#: step hooks, and the §6.2 measurement protocol (its sync placement is
#: the measurement, so its one sync is pragma-justified in place)
HOT_PATHS: Mapping[str, Set[str]] = {
    "src/repro/serve/engine.py": {
        "ServeEngine.advance", "ServeEngine.step", "ServeEngine.add_request",
    },
    "src/repro/serve/scheduler.py": {
        "serve_loop", "FifoScheduler.plan", "ModelGuidedScheduler.plan",
        "ModelGuidedScheduler._rollout", "StepCostModel.tick_cost",
    },
    "src/repro/train/train_loop.py": {"train"},
    "src/repro/core/contractions.py": {"run_kernel_benchmark"},
    # the device-resident tile sweep: per-config dispatches chain through
    # a donated token and ONLY the sweep-end drain may sync (its single
    # block_until_ready is pragma-justified in place)
    "src/repro/tc/device.py": {"DeviceSuite._sweep"},
}

#: receivers recognized as numpy for the D2H-transfer forms
_NUMPY_NAMES = {"np", "numpy"}


def sync_reason(node: ast.AST) -> Optional[str]:
    """If ``node`` is a host-synchronizing call, why it synchronizes."""
    if not isinstance(node, ast.Call):
        return None
    f = node.func
    if isinstance(f, ast.Attribute):
        if f.attr == "block_until_ready":
            return ("block_until_ready blocks the host until the device "
                    "queue drains")
        if f.attr == "item" and not node.args and not node.keywords:
            return ".item() pulls a device scalar to the host"
        if f.attr in ("asarray", "array") and \
                isinstance(f.value, ast.Name) and \
                f.value.id in _NUMPY_NAMES:
            return (f"np.{f.attr}() on a device value is a blocking "
                    f"device->host transfer")
    elif isinstance(f, ast.Name) and f.id == "float" and node.args and \
            not isinstance(node.args[0], ast.Constant):
        return "float() forces the value to the host (implicit sync)"
    return None


def _function_nodes(ctx: FileContext):
    """(qualname, node, enclosing-class) for every def, qualnames built
    with ``Class.method`` / ``outer.<locals>.inner`` collapsed to the
    pragmatic ``Class.method`` and ``outer`` forms used by HOT_PATHS."""
    out: List[Tuple[str, ast.AST]] = []

    def visit(node, prefix):
        for child in ast.iter_child_nodes(node):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                qual = f"{prefix}{child.name}"
                out.append((qual, child))
                visit(child, f"{qual}.")
            elif isinstance(child, ast.ClassDef):
                visit(child, f"{prefix}{child.name}.")
            else:
                visit(child, prefix)

    visit(ctx.tree, "")
    return out


@register
class HostSyncChecker(Checker):
    id = "host-sync"
    description = ("no block_until_ready/.item()/np.asarray/float() "
                   "inside jitted bodies or the serve/measurement hot "
                   "paths")

    def check_file(self, ctx: FileContext) -> Iterable[Finding]:
        hot_qualnames = HOT_PATHS.get(ctx.rel, set())
        hot_bodies: List[Tuple[str, ast.AST]] = []
        covered: Set[int] = set()   # defs inside an already-hot body

        def add_hot(qual: str, node: ast.AST) -> None:
            if id(node) in covered:
                return              # its enclosing hot body walks it
            hot_bodies.append((qual, node))
            for sub in ast.walk(node):
                covered.add(id(sub))

        # _function_nodes visits outer defs before inner ones, so an
        # enclosing hot function claims its nested defs (the §6.2 timed
        # call() closure) before they are considered separately
        for qual, node in _function_nodes(ctx):
            if (qual in hot_qualnames or
                    is_jit_decorated(node) or
                    ctx.is_hot_marked(node.lineno)):
                add_hot(qual, node)

        # functions / lambdas jitted at call sites in this module
        for site in collect_jit_sites(ctx.tree):
            if site.form in ("call", "lambda"):
                add_hot(getattr(site.fn, "name", "<lambda>"), site.fn)

        for qual, fn in hot_bodies:
            body = fn.body if isinstance(fn.body, list) else [fn.body]
            for stmt in body:
                for node in ast.walk(stmt):
                    reason = sync_reason(node)
                    if reason is None:
                        continue
                    yield Finding(
                        self.id, ctx.rel, node.lineno,
                        f"host sync in hot path {qual}(): {reason}; keep "
                        f"the hot path dispatch-free or annotate with "
                        f"`# reprolint: allow[host-sync]` + justification")
