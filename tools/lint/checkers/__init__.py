"""Checker registry: importing this package registers every checker.

One module per checker; each encodes one standing ROADMAP invariant:

* :mod:`.host_sync` — hot paths stay dispatch-free (PR 5/6 fused engine
  + sub-ms scheduler ticks);
* :mod:`.retrace` — jit call sites declare Python-config params static
  (one compile per platform, not one per config value);
* :mod:`.deprecated_kwargs` — every ranking entry point goes through
  :class:`repro.tc.PredictorSession` (PR 6 API redesign);
* :mod:`.oracle_coverage` — every prediction fast path is pinned to its
  equivalence oracle by a test (the docs/architecture.md convention);
* :mod:`.metric_tracking` — every smoke metric is tracked or explicitly
  allowlisted in ``benchmarks/compare_smoke.py``;
* :mod:`.store_schema` — model-store writers stamp the
  ``SCHEMA_VERSION`` constant into every payload (PR 8 persistence
  layer), and ``schema_version`` keys are never hard-coded numbers.
"""

from . import (deprecated_kwargs, host_sync, metric_tracking,  # noqa: F401
               oracle_coverage, retrace, store_schema)

__all__ = ["deprecated_kwargs", "host_sync", "metric_tracking",
           "oracle_coverage", "retrace", "store_schema"]
