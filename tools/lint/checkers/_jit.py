"""Shared jit-site analysis for the host-sync and retrace checkers.

Recognized jit spellings (all present in this repo):

* ``@jax.jit`` / ``@jit`` decorators,
* ``@functools.partial(jax.jit, static_argnames=...)`` decorators,
* call sites ``jax.jit(fn, ...)`` / ``jax.jit(lambda ...: ...)`` where
  ``fn`` resolves to a ``def`` in the same module.

A :class:`JitSite` carries the target function node (or lambda), the
declared static argument names, and the anchor line — enough for the
host-sync checker to treat the body as a hot context and for the retrace
checker to cross-check parameters against ``static_argnames``.
"""

from __future__ import annotations

import ast
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Set, Union

FunctionNode = Union[ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda]


def is_jit_name(node: ast.AST) -> bool:
    """``jax.jit`` / bare ``jit`` (imported name), as an expression."""
    return (isinstance(node, ast.Attribute) and node.attr == "jit") or \
        (isinstance(node, ast.Name) and node.id == "jit")


def _partial_of_jit(call: ast.Call) -> bool:
    """``functools.partial(jax.jit, ...)`` / ``partial(jit, ...)``."""
    f = call.func
    is_partial = (isinstance(f, ast.Attribute) and f.attr == "partial") or \
        (isinstance(f, ast.Name) and f.id == "partial")
    return is_partial and bool(call.args) and is_jit_name(call.args[0])


def jit_decorator(node: ast.expr) -> Optional[ast.Call]:
    """If ``node`` is a jit decorator, the Call carrying its kwargs
    (``None`` for the bare ``@jax.jit`` form, which has none)."""
    if is_jit_name(node):
        return None
    if isinstance(node, ast.Call) and (_partial_of_jit(node) or
                                       is_jit_name(node.func)):
        return node
    return None


def is_jit_decorated(fn: Union[ast.FunctionDef, ast.AsyncFunctionDef],
                     ) -> bool:
    return any(is_jit_name(d) or
               (isinstance(d, ast.Call) and
                (_partial_of_jit(d) or is_jit_name(d.func)))
               for d in fn.decorator_list)


def static_names_of(call: Optional[ast.Call],
                    fn: Optional[FunctionNode]) -> Set[str]:
    """The parameter names a jit call declares static.

    Handles ``static_argnames=`` (str or tuple/list of str) and
    ``static_argnums=`` (int or tuple/list of int, resolved against the
    target's positional parameters when known).
    """
    out: Set[str] = set()
    if call is None:
        return out
    pos_params: List[str] = []
    if fn is not None and not isinstance(fn, ast.Lambda):
        pos_params = [a.arg for a in fn.args.posonlyargs + fn.args.args]
    elif isinstance(fn, ast.Lambda):
        pos_params = [a.arg for a in fn.args.posonlyargs + fn.args.args]
    for kw in call.keywords:
        if kw.arg == "static_argnames":
            v = kw.value
            if isinstance(v, ast.Constant) and isinstance(v.value, str):
                out.add(v.value)
            elif isinstance(v, (ast.Tuple, ast.List)):
                out.update(e.value for e in v.elts
                           if isinstance(e, ast.Constant)
                           and isinstance(e.value, str))
        elif kw.arg == "static_argnums":
            v = kw.value
            nums: List[int] = []
            if isinstance(v, ast.Constant) and isinstance(v.value, int):
                nums = [v.value]
            elif isinstance(v, (ast.Tuple, ast.List)):
                nums = [e.value for e in v.elts
                        if isinstance(e, ast.Constant)
                        and isinstance(e.value, int)]
            out.update(pos_params[n] for n in nums if n < len(pos_params))
    return out


@dataclass
class JitSite:
    """One jit application: target body + declared static params."""

    fn: FunctionNode                      # the jitted function / lambda
    static: Set[str] = field(default_factory=set)
    line: int = 0                         # anchor for findings
    form: str = "decorator"               # decorator | call | lambda

    @property
    def params(self) -> List[ast.arg]:
        a = self.fn.args
        return list(a.posonlyargs) + list(a.args) + list(a.kwonlyargs)


def collect_jit_sites(tree: ast.AST) -> List[JitSite]:
    """Every jit application whose target body is visible in ``tree``."""
    defs: Dict[str, ast.FunctionDef] = {}
    lambdas_by_def: Dict[str, ast.Lambda] = {}
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            # innermost wins is fine: jit targets are module/closure-local
            defs.setdefault(node.name, node)
        elif isinstance(node, ast.Assign) and len(node.targets) == 1 and \
                isinstance(node.targets[0], ast.Name) and \
                isinstance(node.value, ast.Lambda):
            lambdas_by_def.setdefault(node.targets[0].id, node.value)

    sites: List[JitSite] = []
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            for dec in node.decorator_list:
                call = jit_decorator(dec)
                if call is not None or is_jit_name(dec):
                    sites.append(JitSite(
                        fn=node, static=static_names_of(call, node),
                        line=node.lineno, form="decorator"))
                    break
        elif isinstance(node, ast.Call) and is_jit_name(node.func) and \
                node.args:
            target = node.args[0]
            if isinstance(target, ast.Lambda):
                sites.append(JitSite(
                    fn=target, static=static_names_of(node, target),
                    line=target.lineno, form="lambda"))
            elif isinstance(target, ast.Name):
                fn = defs.get(target.id) or lambdas_by_def.get(target.id)
                if fn is not None:
                    sites.append(JitSite(
                        fn=fn, static=static_names_of(node, fn),
                        line=node.lineno, form="call"))
    return sites
