"""store-schema: store payloads always carry the SCHEMA_VERSION constant.

The model store's load path refuses payloads whose ``schema_version``
differs from the code's ``SCHEMA_VERSION`` — that refusal is the only
thing standing between a payload-layout change and silently
misinterpreted measurements.  The refusal only works if every writer
stamps the constant, so this checker enforces two things statically:

* any ``json.dump``/``json.dumps`` call in a module under
  ``src/repro/store/`` requires the module to know ``SCHEMA_VERSION``
  (defined or imported) AND to build at least one dict literal whose
  ``"schema_version"`` key is valued by the ``SCHEMA_VERSION`` *name* —
  a store writer that never references the constant writes files the
  loader cannot version-check;
* anywhere in the linted tree, a dict literal with a ``"schema_version"``
  key valued by a plain constant (``"schema_version": 1``) is flagged:
  a hard-coded version silently diverges from the module constant on the
  next bump, which is exactly the failure the constant exists to
  prevent.

Like every reprolint rule, a deliberate exception carries a
``# reprolint: allow[store-schema]`` pragma with a justification.
"""

from __future__ import annotations

import ast
from typing import Iterable, List, Optional, Tuple

from ..core import Checker, FileContext, Finding, register

STORE_PREFIX = "src/repro/store/"
CONSTANT = "SCHEMA_VERSION"


def _is_json_dump(node: ast.Call) -> bool:
    f = node.func
    return (isinstance(f, ast.Attribute) and
            f.attr in ("dump", "dumps") and
            isinstance(f.value, ast.Name) and f.value.id == "json")


def _knows_constant(tree: ast.AST) -> bool:
    """Does the module define or import ``SCHEMA_VERSION``?"""
    for node in ast.walk(tree):
        if isinstance(node, ast.Assign):
            if any(isinstance(t, ast.Name) and t.id == CONSTANT
                   for t in node.targets):
                return True
        elif isinstance(node, ast.ImportFrom):
            if any(a.name == CONSTANT or a.asname == CONSTANT
                   for a in node.names):
                return True
    return False


def _schema_key_values(tree: ast.AST) -> List[Tuple[ast.expr,
                                                    Optional[ast.expr]]]:
    """(key node, value node) for every dict-literal "schema_version"."""
    out = []
    for node in ast.walk(tree):
        if not isinstance(node, ast.Dict):
            continue
        for k, v in zip(node.keys, node.values):
            if isinstance(k, ast.Constant) and k.value == "schema_version":
                out.append((k, v))
    return out


def _stamps_constant(tree: ast.AST) -> bool:
    """Is there a dict literal stamping the SCHEMA_VERSION *name*?"""
    return any(isinstance(v, ast.Name) and v.id == CONSTANT
               for _, v in _schema_key_values(tree))


@register
class StoreSchemaChecker(Checker):
    id = "store-schema"
    description = ("store-file writers stamp the SCHEMA_VERSION constant "
                   "into their payload; 'schema_version' keys are never "
                   "hard-coded numbers")

    def check_file(self, ctx: FileContext) -> Iterable[Finding]:
        in_store = ctx.rel.startswith(STORE_PREFIX)
        if in_store:
            knows = _knows_constant(ctx.tree)
            stamps = _stamps_constant(ctx.tree)
            for node in ast.walk(ctx.tree):
                if not (isinstance(node, ast.Call) and _is_json_dump(node)):
                    continue
                if not knows:
                    yield Finding(
                        self.id, ctx.rel, node.lineno,
                        "store module writes JSON without defining or "
                        "importing SCHEMA_VERSION — the loader cannot "
                        "version-check files this writer produces")
                elif not stamps:
                    yield Finding(
                        self.id, ctx.rel, node.lineno,
                        "store module writes JSON but no payload dict "
                        "carries '\"schema_version\": SCHEMA_VERSION' — "
                        "stamp the constant so the loader can refuse "
                        "future-schema files")
        # everywhere (store, benches, examples, docs snippets): a
        # hard-coded schema_version bypasses the constant it mirrors
        for k, v in _schema_key_values(ctx.tree):
            if isinstance(v, ast.Constant):
                yield Finding(
                    self.id, ctx.rel, k.lineno,
                    f"hard-coded schema version "
                    f"('schema_version': {v.value!r}) — use the "
                    f"SCHEMA_VERSION constant from repro.store so the "
                    f"payload tracks schema bumps")
