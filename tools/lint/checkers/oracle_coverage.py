"""oracle-coverage: every prediction fast path is test-pinned to its oracle.

``docs/architecture.md`` states the convention: every batched or
composed fast path keeps its original scalar implementation alive as an
*equivalence oracle*, and tests pin the two against each other — that is
what keeps a 30x speedup from silently becoming a 30x wrong answer.
This checker makes the convention structural: for each ranking/
prediction entry point in :data:`ORACLE_PAIRS`, at least one module
under ``tests/`` must both invoke the entry point AND invoke one of its
oracle forms.  An oracle form is either a called name
(``rank_oracle``, ``predict_compiled_grouped``, ``FifoScheduler``) or
the ``batched=False`` keyword that switches a selection entry point onto
the scalar path.

Findings anchor at the entry point's ``def``/``class`` site in ``src/``
— the owner of an uncovered fast path is the code, not the test suite.
"""

from __future__ import annotations

import ast
from pathlib import Path
from typing import Dict, Iterable, Mapping, Sequence, Set, Tuple

from ..core import Checker, FileContext, Finding, register

#: entry point -> acceptable oracle forms (any one suffices).  Names are
#: called-function names in a test module; "kwarg=value" entries match a
#: literal keyword (the scalar-path switch).  Kept declarative so a new
#: fast path is one line here + one test, per the architecture.md table.
ORACLE_PAIRS: Mapping[str, Sequence[str]] = {
    # blocked-algorithm selection (paper §4.5/§4.6) vs the scalar path
    "rank_algorithms": ("predict_runtime", "batched=False"),
    "select_algorithm": ("predict_runtime", "batched=False"),
    "optimize_block_size": ("predict_runtime", "batched=False"),
    # fused one-dispatch engine vs the per-(kernel, case) grouped path
    "predict_compiled": ("predict_compiled_grouped",),
    # contraction ranking (Ch. 6) vs the fresh per-algorithm §6.2 oracle
    "rank_contraction_algorithms": ("rank_oracle", "batched=False"),
    "select_contraction_algorithm": ("rank_oracle", "batched=False"),
    "rank_contraction_sweep": ("rank_oracle",),
    # einsum-path chains vs the step-by-step per-algorithm oracle
    "rank_einsum_paths": ("rank_paths_oracle",),
    "select_einsum_path": ("rank_paths_oracle",),
    "rank_einsum_sweep": ("rank_paths_oracle",),
    # model-guided serving vs the action-for-action FIFO baseline
    "ModelGuidedScheduler": ("FifoScheduler",),
    # size-parametric suite models vs the exact-shape measurement path
    "refine_parametric": ("benchmark_fresh", "rank_oracle"),
    # the unified session fronts all of the above; its tests must reach
    # a scalar path at least once
    "PredictorSession": ("rank_oracle", "rank_paths_oracle",
                        "batched=False"),
    # measured-model tile selection vs the analytic three-term oracle
    # (the pre-device model, kept alive as `analytic=True` fallback)
    "select_tiles": ("predict_tile_time", "analytic=True"),
    "rank_device_tiles": ("predict_tile_time", "analytic=True"),
}


def _module_calls(tree: ast.AST) -> Tuple[Set[str], Set[str]]:
    """(called names, 'kwarg=value' literals) used by one test module."""
    names: Set[str] = set()
    kwargs: Set[str] = set()
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        f = node.func
        if isinstance(f, ast.Name):
            names.add(f.id)
        elif isinstance(f, ast.Attribute):
            names.add(f.attr)
        for kw in node.keywords:
            if kw.arg and isinstance(kw.value, ast.Constant):
                kwargs.add(f"{kw.arg}={kw.value.value!r}".replace("'", ""))
    return names, kwargs


def _def_sites(ctxs: Sequence[FileContext]) -> Dict[str, Tuple[str, int]]:
    """entry-point name -> (path, line) of its def/class in src/."""
    out: Dict[str, Tuple[str, int]] = {}
    for ctx in ctxs:
        if not ctx.rel.startswith("src/"):
            continue
        for node in ast.walk(ctx.tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                                 ast.ClassDef)) and \
                    node.name in ORACLE_PAIRS and node.name not in out:
                out[node.name] = (ctx.rel, node.lineno)
    return out


@register
class OracleCoverageChecker(Checker):
    id = "oracle-coverage"
    description = ("every ranking/prediction entry point has a test that "
                   "also invokes its equivalence oracle")

    def check_repo(self, ctxs: Sequence[FileContext],
                   root: Path) -> Iterable[Finding]:
        tests_dir = root / "tests"
        modules = []
        for path in sorted(tests_dir.glob("test_*.py")):
            try:
                tree = ast.parse(path.read_text())
            except SyntaxError:
                continue            # the parse checker owns that report
            modules.append((path.name, *_module_calls(tree)))
        sites = _def_sites(ctxs)
        for entry, oracles in ORACLE_PAIRS.items():
            if entry not in sites:
                continue   # not defined in the linted sources (partial run)
            calling = [(name, names, kwargs)
                       for name, names, kwargs in modules
                       if entry in names]
            path, line = sites[entry]
            if not calling:
                yield Finding(
                    self.id, path, line,
                    f"entry point {entry} is invoked by no test module — "
                    f"add a test pinning it against one of its oracles "
                    f"({', '.join(oracles)})")
                continue
            covered = any(
                any((o in names) or (o in kwargs) for o in oracles)
                for _, names, kwargs in calling)
            if not covered:
                mods = ", ".join(m for m, _, _ in calling)
                yield Finding(
                    self.id, path, line,
                    f"entry point {entry} is tested ({mods}) but no such "
                    f"module invokes its equivalence oracle "
                    f"({', '.join(oracles)}) — the fast path is unpinned")
