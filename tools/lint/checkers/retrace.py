"""retrace: jit sites must declare Python-config parameters static.

The engine's "compile once per platform, predict forever" economics
(PR 2's TraceCache, PR 5's fused one-dispatch program) die quietly when
a jit boundary treats a Python config value as a traced operand: every
new bool/str/tuple value either retraces the whole program or raises a
``TracerBoolConversionError`` deep inside the body.  Both hazards are
visible statically:

* **undeclared config param** — a parameter of a jitted function whose
  default is a Python bool, string, or tuple/list of constants (the
  classic tile-size/flag signature, e.g. ``interpret: bool = False``)
  but is not listed in ``static_argnames``/``static_argnums``;
* **traced branch** — a Python ``if``/``while`` inside a jitted body
  whose condition references a non-static parameter: at trace time the
  condition is a tracer, so the branch either crashes or silently bakes
  in one side.

Int/float defaults are deliberately NOT flagged: jax traces Python
scalars as weak-typed array operands without retracing, so they are
only a hazard when branched on — which the second rule catches.
Closure variables (the ``_gemm_fn(transA, ...)`` factory idiom, where
``lru_cache`` pins one closure per config) are legitimate and are not
parameters, so they never trigger either rule.
"""

from __future__ import annotations

import ast
from typing import Iterable, Set

from ..core import Checker, FileContext, Finding, register
from ._jit import JitSite, collect_jit_sites

#: parameter names that are never operands (self/cls)
_IGNORED = {"self", "cls"}


def _config_default(node: ast.expr) -> str:
    """'' if not a Python-config default, else a short type tag."""
    if isinstance(node, ast.Constant) and isinstance(node.value, bool):
        return "bool"
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return "str"
    if isinstance(node, (ast.Tuple, ast.List)) and all(
            isinstance(e, ast.Constant) for e in node.elts):
        return "tuple of constants"
    return ""


def _param_defaults(site: JitSite):
    """(param name, default node) pairs of the jitted function."""
    a = site.fn.args
    pos = list(a.posonlyargs) + list(a.args)
    for arg, default in zip(pos[len(pos) - len(a.defaults):], a.defaults):
        yield arg.arg, default
    for arg, default in zip(a.kwonlyargs, a.kw_defaults):
        if default is not None:
            yield arg.arg, default


def _names_in(node: ast.expr) -> Set[str]:
    return {n.id for n in ast.walk(node) if isinstance(n, ast.Name)}


@register
class RetraceChecker(Checker):
    id = "retrace"
    description = ("jitted functions must declare bool/str/tuple config "
                   "params in static_argnames and not branch on traced "
                   "params")

    def check_file(self, ctx: FileContext) -> Iterable[Finding]:
        for site in collect_jit_sites(ctx.tree):
            name = getattr(site.fn, "name", "<lambda>")

            for pname, default in _param_defaults(site):
                tag = _config_default(default)
                if tag and pname not in site.static and \
                        pname not in _IGNORED:
                    yield Finding(
                        self.id, ctx.rel, site.fn.lineno,
                        f"jitted {name}() parameter {pname}= has a "
                        f"Python-config default ({tag}) but is not in "
                        f"static_argnames — every distinct value "
                        f"retraces (or fails tracing); declare it "
                        f"static")

            if isinstance(site.fn, ast.Lambda):
                continue   # a lambda body has no if/while statements
            traced = {a.arg for a in site.params} - site.static - _IGNORED
            for node in ast.walk(site.fn):
                if not isinstance(node, (ast.If, ast.While)):
                    continue
                on = _names_in(node.test) & traced
                if on:
                    yield Finding(
                        self.id, ctx.rel, node.lineno,
                        f"jitted {name}() branches on traced "
                        f"parameter(s) {', '.join(sorted(on))} — at "
                        f"trace time the condition is a tracer "
                        f"(TracerBoolConversionError) or bakes in one "
                        f"side; use lax.cond/jnp.where or declare the "
                        f"parameter static")
