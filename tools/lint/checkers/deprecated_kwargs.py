"""deprecated-kwarg: ranking entry points go through PredictorSession.

PR 6 unified every ranking/selection entry point behind
:class:`repro.tc.PredictorSession` — one object owning the suite, trace
cache and backend — and deprecated the per-call resource kwargs behind
one-release shims.  The shims keep old *external* callers working, but
internal code, benchmarks, examples and docs must not keep the legacy
spelling alive: every such call builds a throwaway session, re-measures
what a shared session would have reused, and teaches readers the dead
API.  This checker is the single source of truth for the rule —
``tools/check_docs.py`` imports :data:`DEPRECATED_KWARGS` and
:func:`deprecated_call_findings` so docs snippets and source share one
definition.

The sanctioned implementation sites (the session's own delegation to
the low-level sweep functions, and the shim plumbing itself) carry
``# reprolint: allow[deprecated-kwarg]`` pragmas with justifications.
"""

from __future__ import annotations

import ast
from typing import Iterable, List, Mapping, Sequence

from ..core import Checker, FileContext, Finding, register

#: entry point -> the per-call resource kwargs PR 6 deprecated on it.
#: (`session=` replaced them; tools/check_docs.py reuses this table for
#: docs snippets, so source and docs can never disagree on the rule.)
DEPRECATED_KWARGS: Mapping[str, Sequence[str]] = {
    "rank_contraction_algorithms": ("suite", "cache", "backend",
                                    "repetitions", "sizes_grid"),
    "select_contraction_algorithm": ("backend", "repetitions", "predictor"),
    "rank_einsum_paths": ("suite", "cache", "backend", "repetitions",
                          "sizes_grid", "predictor"),
    "select_einsum_path": ("backend", "repetitions", "predictor"),
    "rank_contraction_sweep": ("suite", "cache", "backend", "repetitions"),
    "rank_einsum_sweep": ("suite", "cache", "backend", "repetitions"),
}


def _called_name(func: ast.expr) -> str:
    if isinstance(func, ast.Name):
        return func.id
    if isinstance(func, ast.Attribute):
        return func.attr
    return ""


def deprecated_call_findings(tree: ast.AST, rel: str,
                             checker_id: str = "deprecated-kwarg",
                             ) -> List[Finding]:
    """Findings for every call of a tabled entry point passing a
    deprecated kwarg (shared with tools/check_docs.py)."""
    out: List[Finding] = []
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        fn = _called_name(node.func)
        kwargs = DEPRECATED_KWARGS.get(fn)
        if not kwargs:
            continue
        used = [kw.arg for kw in node.keywords
                if kw.arg in kwargs and not _is_none(kw.value)]
        if used:
            out.append(Finding(
                checker_id, rel, node.lineno,
                f"{fn}() called with deprecated kwarg(s) "
                f"{', '.join(k + '=' for k in sorted(used))} — construct "
                f"a repro.tc.PredictorSession and use its methods "
                f"(session= owns the suite/cache/backend)"))
    return out


def _is_none(node: ast.expr) -> bool:
    """Forwarding ``backend=None`` explicitly is the shim plumbing's own
    idiom and behaviorally identical to omitting the kwarg."""
    return isinstance(node, ast.Constant) and node.value is None


@register
class DeprecatedKwargChecker(Checker):
    id = "deprecated-kwarg"
    description = ("no internal/bench/example/docs call to a ranking "
                   "entry point with the PR-6-deprecated per-call "
                   "kwargs (suite=/cache=/backend=/predictor=/...)")

    def check_file(self, ctx: FileContext) -> Iterable[Finding]:
        return deprecated_call_findings(ctx.tree, ctx.rel, self.id)
