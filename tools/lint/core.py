"""reprolint core: finding model, pragmas, baseline, and the runner.

Everything here is stdlib-only (``ast`` + ``json`` + ``re``): the linter
must run in the CI fast lane before anything is installed beyond the
repo itself, and must never import ``repro`` (importing jax to lint a
file would cost more than the whole lint run's < 10 s budget).
"""

from __future__ import annotations

import ast
import json
import re
from collections import Counter
from dataclasses import dataclass
from pathlib import Path
from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple

#: repository root (tools/lint/core.py -> repo)
ROOT = Path(__file__).resolve().parents[2]

#: the directories the default lint sweep covers, plus docs snippets
DEFAULT_CODE_DIRS = ("src", "benchmarks", "examples")

#: ``# reprolint: allow[checker-id]`` (comma list or ``*``), with an
#: optional justification after the bracket — the pragma that suppresses
#: a finding on its own line or the line directly below the pragma
PRAGMA = re.compile(r"#\s*reprolint:\s*allow\[([A-Za-z0-9_*,\s-]+)\]")

#: ``# reprolint: hot-path`` marks a function as a dispatch-free hot
#: context for the host-sync checker (files outside the built-in table)
HOT_MARK = re.compile(r"#\s*reprolint:\s*hot-path")

_FENCE = re.compile(r"^```(\w*)\s*$")
_SKIP_MARK = "<!-- docs-check: skip -->"


@dataclass(frozen=True)
class Finding:
    """One lint finding, anchored at a repo-relative ``path:line``."""

    checker: str
    path: str
    line: int
    message: str

    def key(self) -> Tuple[str, str, str]:
        """Baseline identity: line numbers drift across edits, so a
        grandfathered finding is matched by (checker, path, message)."""
        return (self.checker, self.path, self.message)

    def render(self) -> str:
        return f"{self.path}:{self.line}: [{self.checker}] {self.message}"

    def render_github(self) -> str:
        """GitHub Actions annotation format (shows inline on the PR)."""
        return (f"::error file={self.path},line={self.line},"
                f"title=reprolint {self.checker}::{self.message}")


class FileContext:
    """One parsed python source: a file, or one docs snippet.

    ``rel`` is the repo-relative path reported in findings (for a
    markdown snippet: the ``.md`` file).  ``first_line`` offsets the AST
    line numbers so snippet findings anchor into the markdown file.
    """

    def __init__(self, rel: str, source: str, *, first_line: int = 1):
        self.rel = rel
        self.source = source
        self.first_line = first_line
        self.tree = ast.parse(source)
        if first_line != 1:
            ast.increment_lineno(self.tree, first_line - 1)
        self.pragmas: Dict[int, Set[str]] = {}
        self.hot_marks: Set[int] = set()
        for ln, text in enumerate(source.splitlines(), first_line):
            m = PRAGMA.search(text)
            if m:
                self.pragmas[ln] = {s.strip() for s in m.group(1).split(",")
                                    if s.strip()}
            if HOT_MARK.search(text):
                self.hot_marks.add(ln)

    @classmethod
    def from_file(cls, path: Path, root: Path = ROOT) -> "FileContext":
        rel = path.resolve().relative_to(root).as_posix()
        return cls(rel, path.read_text())

    def allowed(self, checker: str, line: int) -> bool:
        """Is a ``checker`` finding at ``line`` pragma-suppressed?  The
        pragma covers its own line (trailing comment) or the line below
        (standalone comment above the flagged statement)."""
        for ln in (line, line - 1):
            ids = self.pragmas.get(ln)
            if ids and (checker in ids or "*" in ids):
                return True
        return False

    def is_hot_marked(self, line: int) -> bool:
        """Is there a ``# reprolint: hot-path`` marker on the ``def``
        line or the line above it?"""
        return bool(self.hot_marks & {line, line - 1})


class Checker:
    """Base class: subclass, set ``id``/``description``, register.

    ``check_file`` runs once per parsed source (including docs
    snippets); ``check_repo`` runs once over the whole context set for
    cross-file invariants (oracle coverage, metric tracking).  Findings
    are yielded raw — pragma suppression and the baseline are applied by
    the runner, so checkers stay pure syntax -> findings functions.
    """

    id: str = ""
    description: str = ""

    def check_file(self, ctx: FileContext) -> Iterable[Finding]:
        return ()

    def check_repo(self, ctxs: Sequence[FileContext],
                   root: Path) -> Iterable[Finding]:
        return ()


#: checker-id -> instance; populated by :func:`register` at import time
REGISTRY: Dict[str, Checker] = {}


def register(cls):
    """Class decorator: instantiate and add to the global registry."""
    inst = cls()
    if not inst.id:
        raise ValueError(f"{cls.__name__} has no checker id")
    if inst.id in REGISTRY:
        raise ValueError(f"duplicate checker id {inst.id!r}")
    REGISTRY[inst.id] = inst
    return cls


# ------------------------------------------------------------- collection --

def iter_source_files(root: Path, paths: Optional[Sequence[Path]] = None,
                      ) -> List[Path]:
    """The ``.py`` files to lint: an explicit list, or the default
    ``src/`` + ``benchmarks/`` + ``examples/`` sweep."""
    if paths:
        out: List[Path] = []
        for p in paths:
            p = Path(p)
            if p.is_dir():
                out.extend(sorted(p.rglob("*.py")))
            else:
                out.append(p)
        return [p for p in out if "__pycache__" not in p.parts]
    files: List[Path] = []
    for d in DEFAULT_CODE_DIRS:
        base = root / d
        if base.is_dir():
            files.extend(sorted(base.rglob("*.py")))
    return [p for p in files if "__pycache__" not in p.parts]


def python_snippets(path: Path) -> List[Tuple[int, str]]:
    """(first line, source) of every runnable ```` ```python ```` block
    in a markdown file — the same extraction ``tools/check_docs.py``
    executes, minus skip-marked blocks (pseudocode is not linted)."""
    out: List[Tuple[int, str]] = []
    lines = path.read_text().splitlines()
    i = 0
    while i < len(lines):
        m = _FENCE.match(lines[i])
        if not m:
            i += 1
            continue
        lang = m.group(1)
        skip = i > 0 and _SKIP_MARK in lines[i - 1]
        start = i + 2                      # 1-based line after the fence
        i += 1
        block: List[str] = []
        while i < len(lines) and not _FENCE.match(lines[i]):
            block.append(lines[i])
            i += 1
        i += 1                             # closing fence
        if lang == "python" and not skip:
            out.append((start, "\n".join(block)))
    return out


def doc_files(root: Path) -> List[Path]:
    files = sorted((root / "docs").glob("*.md"))
    readme = root / "README.md"
    if readme.exists():
        files.append(readme)
    return files


def build_contexts(root: Path, paths: Optional[Sequence[Path]] = None,
                   *, include_docs: bool = True,
                   ) -> Tuple[List[FileContext], List[Finding]]:
    """Parse every lintable source; unparsable files become findings
    (checker id ``parse``) instead of crashing the run."""
    ctxs: List[FileContext] = []
    problems: List[Finding] = []
    for path in iter_source_files(root, paths):
        rel = path.resolve().relative_to(root).as_posix() \
            if path.resolve().is_relative_to(root) else str(path)
        try:
            ctxs.append(FileContext(rel, path.read_text()))
        except SyntaxError as e:
            problems.append(Finding("parse", rel, e.lineno or 1,
                                    f"does not parse: {e.msg}"))
    if include_docs and not paths:
        for md in doc_files(root):
            rel = md.resolve().relative_to(root).as_posix()
            for start, src in python_snippets(md):
                try:
                    ctxs.append(FileContext(rel, src, first_line=start))
                except SyntaxError as e:
                    problems.append(Finding(
                        "parse", rel, start + (e.lineno or 1) - 1,
                        f"snippet does not parse: {e.msg}"))
    return ctxs, problems


# --------------------------------------------------------------- baseline --

BASELINE_PATH = Path(__file__).resolve().parent / "baseline.json"


def load_baseline(path: Path = BASELINE_PATH) -> Counter:
    """Multiset of grandfathered finding keys (empty if no file)."""
    if not path.exists():
        return Counter()
    data = json.loads(path.read_text())
    return Counter((f["checker"], f["path"], f["message"])
                   for f in data.get("findings", []))


def write_baseline(findings: Sequence[Finding],
                   path: Path = BASELINE_PATH) -> None:
    """Grandfather the given findings (sorted, line-number-free)."""
    entries = sorted(({"checker": f.checker, "path": f.path,
                       "message": f.message} for f in findings),
                     key=lambda e: (e["path"], e["checker"], e["message"]))
    path.write_text(json.dumps(
        {"comment": "grandfathered reprolint findings; regenerate with "
                    "`python -m tools.lint --write-baseline`",
         "findings": entries}, indent=2) + "\n")


# ----------------------------------------------------------------- runner --

@dataclass
class LintResult:
    """Outcome of one lint run."""

    findings: List[Finding]            # active (fail the gate)
    baselined: List[Finding]           # matched the committed baseline
    suppressed: int                    # pragma-suppressed count
    files: int                         # sources linted (incl. snippets)

    @property
    def ok(self) -> bool:
        return not self.findings


def run_lint(root: Path = ROOT, *,
             paths: Optional[Sequence[Path]] = None,
             checkers: Optional[Sequence[str]] = None,
             baseline: Optional[Counter] = None,
             include_docs: bool = True) -> LintResult:
    """Run the registered checkers; apply pragmas, then the baseline."""
    ctxs, raw = build_contexts(root, paths, include_docs=include_docs)
    selected = [REGISTRY[c] for c in checkers] if checkers \
        else list(REGISTRY.values())
    by_rel = {c.rel: c for c in ctxs if c.first_line == 1}
    for checker in selected:
        for ctx in ctxs:
            raw.extend(checker.check_file(ctx))
        raw.extend(checker.check_repo(ctxs, root))
    findings: List[Finding] = []
    suppressed = 0
    for f in raw:
        ctx = by_rel.get(f.path)
        snippet_ctxs = [c for c in ctxs
                        if c.rel == f.path and c.first_line > 1]
        allowed = (ctx is not None and ctx.allowed(f.checker, f.line)) or \
            any(c.allowed(f.checker, f.line) for c in snippet_ctxs)
        if allowed:
            suppressed += 1
        else:
            findings.append(f)
    findings.sort(key=lambda f: (f.path, f.line, f.checker))
    base = load_baseline() if baseline is None else baseline
    remaining = Counter(base)
    active: List[Finding] = []
    grandfathered: List[Finding] = []
    for f in findings:
        if remaining.get(f.key(), 0) > 0:
            remaining[f.key()] -= 1
            grandfathered.append(f)
        else:
            active.append(f)
    return LintResult(findings=active, baselined=grandfathered,
                      suppressed=suppressed, files=len(ctxs))
