#!/usr/bin/env python
"""Docs lint: internal links must resolve, runnable snippets must run.

Usage::

    python tools/check_docs.py [--no-exec] [files...]

Checks every ``docs/*.md`` file plus ``README.md`` (or an explicit file
list):

* **links** — every relative markdown link ``[text](target)`` must point
  at an existing file, and ``#fragment`` anchors must match a heading in
  the target document (GitHub slug rules: lowercase, punctuation
  stripped, spaces to dashes);
* **wiki-links** — ``[[slug]]`` cross-references (prose shorthand for a
  sibling document) must resolve to ``docs/<slug>.md``;
* **snippets** — every fenced ```` ```python ```` block is executed, in
  file order, in one shared namespace per file (so later snippets can
  build on earlier ones).  Put ``<!-- docs-check: skip -->`` on the line
  directly above a fence to exclude a block (e.g. pseudocode);
* **deprecated kwargs** — python snippets must not call the legacy
  prediction entry points with the kwargs the ``PredictorSession``
  redesign deprecated (``suite=``/``cache=``/``backend=``/
  ``repetitions=``/``sizes_grid=``/``predictor=``): docs are the first
  thing readers copy, so the old API must not reappear in examples.

Exit code 0 when everything passes; 1 with a per-finding report
otherwise.  The CI fast lane runs this after the tests, and
``tests/test_docs.py`` runs the link check in-process.
"""

from __future__ import annotations

import argparse
import ast
import re
import sys
from pathlib import Path
from typing import Dict, List, Tuple

ROOT = Path(__file__).resolve().parents[1]
sys.path.insert(0, str(ROOT))                    # for the tools.lint import

from tools.lint.checkers.deprecated_kwargs import (      # noqa: E402
    deprecated_call_findings)


def _rel(path: Path) -> str:
    """Repo-relative rendering when possible, absolute otherwise (explicit
    file arguments may live outside the repo)."""
    try:
        return str(path.relative_to(ROOT))
    except ValueError:
        return str(path)


_LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
_WIKI_LINK = re.compile(r"(?<!\[)\[\[([A-Za-z0-9._-]+)\]\](?!\])")
_HEADING = re.compile(r"^#{1,6}\s+(.*)$")
_FENCE = re.compile(r"^```(\w*)\s*$")
_SKIP_MARK = "<!-- docs-check: skip -->"


def doc_files(explicit: List[str]) -> List[Path]:
    """The files to lint: an explicit list, or docs/*.md + README.md."""
    if explicit:
        return [Path(f).resolve() for f in explicit]
    files = sorted((ROOT / "docs").glob("*.md"))
    readme = ROOT / "README.md"
    if readme.exists():
        files.append(readme)
    return files


def github_slug(heading: str) -> str:
    """GitHub's anchor slug for a heading (sufficient approximation:
    inline code/emphasis markers dropped, punctuation stripped,
    lowercased, spaces to dashes)."""
    text = re.sub(r"[`*_]", "", heading.strip())
    text = re.sub(r"[^\w\- ]", "", text.lower())
    return text.replace(" ", "-")


def anchors_of(path: Path) -> set:
    """All heading anchors a markdown file exposes."""
    out = set()
    in_fence = False
    for line in path.read_text().splitlines():
        if _FENCE.match(line):
            in_fence = not in_fence
            continue
        if in_fence:
            continue
        m = _HEADING.match(line)
        if m:
            out.add(github_slug(m.group(1)))
    return out


def check_links(path: Path) -> List[str]:
    """Unresolvable relative links/anchors in one file, as messages."""
    problems = []
    anchor_cache: Dict[Path, set] = {}
    in_fence = False
    for ln, line in enumerate(path.read_text().splitlines(), 1):
        if _FENCE.match(line):
            in_fence = not in_fence
            continue
        if in_fence:
            continue
        for target in _LINK.findall(line):
            if re.match(r"^[a-z][a-z0-9+.-]*:", target):   # http:, mailto:
                continue
            ref, _, frag = target.partition("#")
            dest = (path.parent / ref).resolve() if ref else path
            if ref and not dest.exists():
                problems.append(f"{_rel(path)}:{ln}: "
                                f"broken link -> {target}")
                continue
            if frag and dest.suffix == ".md":
                if dest not in anchor_cache:
                    anchor_cache[dest] = anchors_of(dest)
                if frag not in anchor_cache[dest]:
                    problems.append(f"{_rel(path)}:{ln}: "
                                    f"missing anchor -> {target}")
    return problems


def check_wiki_links(path: Path) -> List[str]:
    """Unresolvable ``[[slug]]`` cross-references, as messages.

    A wiki-link names a sibling document by slug: ``[[serving-prediction]]``
    must resolve to ``docs/serving-prediction.md``.  Fenced code blocks are
    exempt (``[[...]]`` is valid syntax in several languages).
    """
    problems = []
    in_fence = False
    for ln, line in enumerate(path.read_text().splitlines(), 1):
        if _FENCE.match(line):
            in_fence = not in_fence
            continue
        if in_fence:
            continue
        for slug in _WIKI_LINK.findall(line):
            target = ROOT / "docs" / f"{slug}.md"
            if not target.exists():
                problems.append(f"{_rel(path)}:{ln}: broken wiki-link -> "
                                f"[[{slug}]] (no docs/{slug}.md)")
    return problems


def check_deprecated_kwargs(path: Path) -> List[str]:
    """Doc snippets calling legacy entry points with deprecated kwargs.

    The shims keep the old forms *working* for one release, but docs are
    what readers copy — they must demonstrate the
    ``repro.tc.PredictorSession`` spelling exclusively.  The rule itself
    lives in ``tools/lint/checkers/deprecated_kwargs.py`` (reprolint's
    deprecated-kwarg checker) so docs and source share one definition.
    """
    problems = []
    for start, src in snippets_of(path):
        try:
            tree = ast.parse(src)
        except SyntaxError:
            continue                 # run_snippets reports the real error
        ast.increment_lineno(tree, start)   # anchor into the .md file
        for f in deprecated_call_findings(tree, _rel(path)):
            problems.append(f"{f.path}:{f.line}: snippet: {f.message}")
    return problems


def snippets_of(path: Path) -> List[Tuple[int, str]]:
    """(start line, source) of every runnable python snippet in a file."""
    out = []
    lines = path.read_text().splitlines()
    i = 0
    while i < len(lines):
        m = _FENCE.match(lines[i])
        if not m:
            i += 1
            continue
        lang = m.group(1)
        skip = i > 0 and _SKIP_MARK in lines[i - 1]
        start = i + 1
        i += 1
        block = []
        while i < len(lines) and not _FENCE.match(lines[i]):
            block.append(lines[i])
            i += 1
        i += 1                                   # closing fence
        if lang == "python" and not skip:
            out.append((start, "\n".join(block)))
    return out


def run_snippets(path: Path) -> List[str]:
    """Execute a file's snippets in one shared namespace; return errors."""
    namespace: Dict[str, object] = {"__name__": f"docs:{path.name}"}
    for start, src in snippets_of(path):
        try:
            code = compile(src, f"{_rel(path)}:{start}", "exec")
            exec(code, namespace)                # noqa: S102 (docs lint)
        except Exception as e:                   # noqa: BLE001 (report all)
            return [f"{_rel(path)}:{start}: snippet failed: "
                    f"{type(e).__name__}: {e}"]
    return []


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("files", nargs="*",
                    help="markdown files (default: docs/*.md + README.md)")
    ap.add_argument("--no-exec", action="store_true",
                    help="check links only, skip snippet execution")
    args = ap.parse_args()
    sys.path.insert(0, str(ROOT / "src"))        # snippets import repro.*

    problems: List[str] = []
    n_snippets = 0
    for path in doc_files(args.files):
        problems += check_links(path)
        problems += check_wiki_links(path)
        problems += check_deprecated_kwargs(path)
        if not args.no_exec:
            snips = snippets_of(path)
            n_snippets += len(snips)
            problems += run_snippets(path)
    if problems:
        print("\n".join(problems))
        print(f"docs check FAILED: {len(problems)} problem(s)")
        return 1
    mode = "links only" if args.no_exec else \
        f"links + {n_snippets} snippets executed"
    print(f"docs check OK ({mode})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
