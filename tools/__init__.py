"""Repo tooling: docs lint (``check_docs``) and the ``tools.lint``
static-analysis gate (reprolint)."""
